//! Full-precision DDPM pretraining — builds the "pretrained diffusion
//! model" the paper quantizes (repro band 0: no public checkpoints at this
//! scale, so the repo trains its own; see DESIGN.md §2).

use std::sync::Arc;

use anyhow::Result;

use crate::data::{Corpus, PatchAutoencoder};
use crate::log_info;
use crate::model::manifest::ModelInfo;
use crate::runtime::Engine;
use crate::schedule::Schedule;
use crate::util::rng::Rng;

use super::adam::Adam;

#[derive(Debug, Clone)]
pub struct PretrainCfg {
    pub steps: usize,
    pub lr: f32,
    pub seed: u64,
    pub log_every: usize,
}

impl Default for PretrainCfg {
    fn default() -> Self {
        PretrainCfg { steps: 400, lr: 2e-3, seed: 0, log_every: 50 }
    }
}

/// Map corpus pixels to model inputs (latent encode for LDM variants).
pub fn corpus_batch(
    corpus: Corpus,
    info: &ModelInfo,
    ae: &PatchAutoencoder,
    rng: &mut Rng,
    n: usize,
) -> (Vec<f32>, Vec<f32>) {
    let (px, cls) = corpus.batch(rng, n);
    if corpus.hw() == info.cfg.img_hw {
        (px, cls)
    } else {
        (ae.encode_batch(&px, n), cls)
    }
}

/// Run the pretraining loop; returns final params + the loss curve.
pub fn pretrain(
    engine: &Arc<Engine>,
    info: &ModelInfo,
    sched: &Schedule,
    corpus: Corpus,
    mut params: Vec<f32>,
    cfg: &PretrainCfg,
) -> Result<(Vec<f32>, Vec<f32>)> {
    let exe = engine.load(info.artifact(&format!("pretrain_b{}", info.train_b))?)?;
    let ae = PatchAutoencoder::default();
    let mut rng = Rng::new(cfg.seed ^ 0x70726574);
    let mut opt = Adam::new(params.len(), cfg.lr);
    let b = info.train_b;
    let hw = info.cfg.img_hw as i64;
    let c = info.cfg.in_ch as i64;
    let mut losses = Vec::with_capacity(cfg.steps);

    for step in 0..cfg.steps {
        let (x0, cond) = corpus_batch(corpus, info, &ae, &mut rng, b);
        let noise: Vec<f32> = (0..x0.len()).map(|_| rng.normal()).collect();
        let t: Vec<f32> = (0..b).map(|_| rng.below(sched.t_total) as f32).collect();
        let abar: Vec<f32> = t.iter().map(|&ti| sched.abar[ti as usize]).collect();
        let out = exe.run(&[
            (&params, &[params.len() as i64]),
            (&x0, &[b as i64, hw, hw, c]),
            (&noise, &[b as i64, hw, hw, c]),
            (&t, &[b as i64]),
            (&abar, &[b as i64]),
            (&cond, &[b as i64]),
        ])?;
        let loss = out[0][0];
        let grad = &out[1];
        opt.step(&mut params, grad);
        losses.push(loss);
        if step % cfg.log_every == 0 || step + 1 == cfg.steps {
            log_info!("pretrain[{}] step {step}/{} loss {loss:.4}", corpus.name(), cfg.steps);
        }
    }
    Ok((params, losses))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::manifest::Manifest;
    use crate::model::ParamStore;
    use std::path::PathBuf;

    #[test]
    fn loss_decreases_over_short_run() {
        let d = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if !d.join("manifest.json").exists() {
            crate::log_warn!("skipping: artifacts not built");
            return;
        }
        let m = Manifest::load(&d).unwrap();
        let info = m.model("ddim16").unwrap();
        let engine = Arc::new(Engine::new(&d).unwrap());
        let params = ParamStore::load_init(info, &d).unwrap();
        let sched = Schedule::linear(100);
        let cfg = PretrainCfg { steps: 30, lr: 2e-3, seed: 1, log_every: 100 };
        let (_, losses) =
            pretrain(&engine, info, &sched, Corpus::CifarSyn, params.flat, &cfg).unwrap();
        let head: f32 = losses[..5].iter().sum::<f32>() / 5.0;
        let tail: f32 = losses[losses.len() - 5..].iter().sum::<f32>() / 5.0;
        assert!(tail < head, "loss did not decrease: {head} -> {tail}");
        assert!(losses.iter().all(|l| l.is_finite()));
    }
}
