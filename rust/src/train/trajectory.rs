//! Full-precision denoising trajectories — the fine-tuning dataset.
//!
//! The paper fine-tunes against the FP model's own denoising process
//! (Observation 3 / Eq. 7): at each timestep t the quantized model sees the
//! FP model's x_t and must match the FP model's eps. We roll the FP model
//! from Gaussian noise with DDIM and record (x_t, eps_fp) at every step.

use anyhow::Result;

use crate::model::manifest::ModelInfo;
use crate::runtime::Denoiser;
use crate::schedule::{Sampler, Schedule};
use crate::util::rng::Rng;

/// Trajectories for a set of "calibration images": for each recorded step i
/// (index into tau), the batch of x_t inputs and eps_fp targets.
pub struct TrajectoryBuffer {
    pub tau: Vec<usize>,
    /// per tau-index: stacked x_t of all rollout samples [n, x_size]
    pub x: Vec<Vec<f32>>,
    /// per tau-index: stacked eps_fp targets
    pub eps: Vec<Vec<f32>>,
    /// per sample: class label
    pub cond: Vec<f32>,
    pub n: usize,
}

impl TrajectoryBuffer {
    /// Roll `n` samples (multiple of the denoiser's fp batch classes is
    /// fastest) through the FP model over `tau`, recording every step.
    #[allow(clippy::too_many_arguments)]
    pub fn collect(
        den: &Denoiser,
        info: &ModelInfo,
        sched: &Schedule,
        tau: &[usize],
        params: &[f32],
        n: usize,
        n_classes: usize,
        rng: &mut Rng,
    ) -> Result<TrajectoryBuffer> {
        let xs = info.x_size(1);
        let mut x: Vec<f32> = (0..n * xs).map(|_| rng.normal()).collect();
        let cond: Vec<f32> =
            (0..n).map(|_| if n_classes > 0 { rng.below(n_classes) as f32 } else { 0.0 }).collect();
        let mut buf = TrajectoryBuffer {
            tau: tau.to_vec(),
            x: Vec::with_capacity(tau.len()),
            eps: Vec::with_capacity(tau.len()),
            cond,
            n,
        };
        // one shared DDIM state machine (eta=0 for deterministic targets)
        let mut sampler = crate::schedule::DdimSampler::new(
            std::sync::Arc::new(sched.clone()),
            tau.to_vec(),
            0.0,
        );
        while !sampler.done() {
            let t = sampler.current_t();
            let tb = vec![t; n];
            // chunk through the largest fp batch class
            let mut eps = Vec::with_capacity(n * xs);
            let chunk = *info.batches_fp.iter().max().unwrap();
            let mut i = 0;
            while i < n {
                let m = chunk.min(n - i);
                let e = den.eps_fp(
                    params,
                    &x[i * xs..(i + m) * xs],
                    &tb[i..i + m],
                    &buf.cond[i..i + m],
                )?;
                eps.extend(e);
                i += m;
            }
            buf.x.push(x.clone());
            buf.eps.push(eps.clone());
            sampler.observe(&mut x, &eps, rng);
        }
        Ok(buf)
    }

    /// Sample a training mini-batch for tau index `i`: `b` random rollout
    /// rows' (x_t, eps) pairs + their cond labels.
    pub fn minibatch(
        &self,
        i: usize,
        b: usize,
        rng: &mut Rng,
    ) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
        let xs = self.x[i].len() / self.n;
        let mut x = Vec::with_capacity(b * xs);
        let mut e = Vec::with_capacity(b * xs);
        let mut c = Vec::with_capacity(b);
        for _ in 0..b {
            let r = rng.below(self.n);
            x.extend_from_slice(&self.x[i][r * xs..(r + 1) * xs]);
            e.extend_from_slice(&self.eps[i][r * xs..(r + 1) * xs]);
            c.push(self.cond[r]);
        }
        (x, e, c)
    }

    /// Final denoised images of the FP rollout (x after the last observe is
    /// not stored; decode from the last recorded step): re-runs the last
    /// DDIM update on the stored pair.
    pub fn steps(&self) -> usize {
        self.tau.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::manifest::Manifest;
    use crate::model::ParamStore;
    use crate::runtime::Engine;
    use crate::schedule::timestep_subsequence;
    use std::path::PathBuf;
    use std::sync::Arc;

    #[test]
    fn collects_consistent_shapes() {
        let d = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if !d.join("manifest.json").exists() {
            crate::log_warn!("skipping: artifacts not built");
            return;
        }
        let m = Manifest::load(&d).unwrap();
        let info = m.model("ddim16").unwrap();
        let engine = Arc::new(Engine::new(&d).unwrap());
        let den = Denoiser::new(engine, info).unwrap();
        let params = ParamStore::load_init(info, &d).unwrap();
        let sched = Schedule::linear(100);
        let tau = timestep_subsequence(100, 6);
        let mut rng = Rng::new(3);
        let buf = TrajectoryBuffer::collect(&den, info, &sched, &tau, &params.flat, 4, 0, &mut rng)
            .unwrap();
        assert_eq!(buf.steps(), 6);
        assert_eq!(buf.x[0].len(), 4 * info.x_size(1));
        assert_eq!(buf.eps[3].len(), 4 * info.x_size(1));
        let (x, e, c) = buf.minibatch(2, 8, &mut rng);
        assert_eq!(x.len(), 8 * info.x_size(1));
        assert_eq!(e.len(), 8 * info.x_size(1));
        assert_eq!(c.len(), 8);
        assert!(x.iter().all(|v| v.is_finite()));
    }
}
