//! Training loops. Gradients come from the AOT-lowered JAX graphs (executed
//! via PJRT); Rust owns the optimizer state, the data pipeline and the
//! schedule, so every loop is deterministic from its seed.

pub mod adam;
pub mod pretrain;
pub mod trajectory;
pub mod calib;
pub mod finetune;

pub use adam::Adam;
pub use calib::collect_calibration;
pub use finetune::{
    finetune, finetune_recal, FinetuneCfg, FinetuneRecal, FinetuneStats, RecalEvent,
};
pub use pretrain::{pretrain, PretrainCfg};
pub use trajectory::TrajectoryBuffer;
