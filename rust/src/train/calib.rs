//! Calibration collection (Appendix C): maxval_0 capture via random
//! forward passes + per-layer activation samples along the FP denoising
//! process, Q-Diffusion-style (samples drawn across timesteps).

use anyhow::{bail, Result};

use crate::model::manifest::ModelInfo;
use crate::quant::msfp::LayerCalib;
use crate::runtime::Denoiser;
use crate::schedule::Schedule;
use crate::util::rng::Rng;

/// Collect `rounds` calibration batches. Each round runs the calib graph on
/// noised corpus-free inputs sampled from the model's own rollout regime:
/// x_t = sqrt(abar) * x0_proxy + sqrt(1-abar) * eps with x0_proxy drawn from
/// a previous FP denoising (here: pure-noise rollouts are close enough at
/// init; callers pass real x0s for trained models).
#[allow(clippy::too_many_arguments)]
pub fn collect_calibration(
    den: &Denoiser,
    info: &ModelInfo,
    sched: &Schedule,
    params: &[f32],
    x0s: &[f32], // stacked x0 proposals (>= calib_b samples)
    rounds: usize,
    n_classes: usize,
    rng: &mut Rng,
) -> Result<Vec<LayerCalib>> {
    let b = info.calib_b;
    let xs = info.x_size(1);
    let n_avail = x0s.len() / xs;
    if n_avail == 0 {
        // an empty (or too-short) x0 pool used to assert!-panic here, taking
        // the whole pipeline down; surface it as a recoverable error instead
        bail!(
            "calibration x0 pool is empty: got {} values, need at least one sample of {} \
             (pipeline::calibrate derives the pool from the corpus batch)",
            x0s.len(),
            xs
        );
    }
    let l = info.n_layers;
    let s = info.act_samples;

    let mut acts: Vec<Vec<f32>> = vec![Vec::with_capacity(rounds * s); l];
    let mut mins = vec![f32::INFINITY; l];
    let mut maxs = vec![f32::NEG_INFINITY; l];

    for _ in 0..rounds {
        // build a mixed-timestep noised batch from the x0 pool
        let mut x = Vec::with_capacity(b * xs);
        let mut t = Vec::with_capacity(b);
        let mut cond = Vec::with_capacity(b);
        for _ in 0..b {
            let r = rng.below(n_avail);
            let ti = rng.below(sched.t_total);
            let (a, sg) = sched.forward_coeffs(ti);
            for k in 0..xs {
                x.push(a * x0s[r * xs + k] + sg * rng.normal());
            }
            t.push(ti as f32);
            cond.push(if n_classes > 0 { rng.below(n_classes) as f32 } else { 0.0 });
        }
        let (_eps, a_out, mm) = den.calib_forward(params, &x, &t, &cond)?;
        for li in 0..l {
            acts[li].extend_from_slice(&a_out[li * s..(li + 1) * s]);
            mins[li] = mins[li].min(mm[li * 2]);
            maxs[li] = maxs[li].max(mm[li * 2 + 1]);
        }
    }

    Ok((0..l)
        .map(|li| LayerCalib {
            name: info.layer_specs[li].name.clone(),
            acts: std::mem::take(&mut acts[li]),
            min: mins[li],
            max: maxs[li],
            aal_hint: info.layer_specs[li].aal_hint,
        })
        .collect())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::manifest::Manifest;
    use crate::model::ParamStore;
    use crate::runtime::Engine;
    use std::path::PathBuf;
    use std::sync::Arc;

    #[test]
    fn collects_layer_calibs() {
        let d = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if !d.join("manifest.json").exists() {
            crate::log_warn!("skipping: artifacts not built");
            return;
        }
        let m = Manifest::load(&d).unwrap();
        let info = m.model("ddim16").unwrap();
        let engine = Arc::new(Engine::new(&d).unwrap());
        let den = Denoiser::new(engine, info).unwrap();
        let params = ParamStore::load_init(info, &d).unwrap();
        let sched = Schedule::linear(100);
        let mut rng = Rng::new(5);
        let x0: Vec<f32> = (0..4 * info.x_size(1)).map(|_| rng.normal() * 0.5).collect();
        let calib =
            collect_calibration(&den, info, &sched, &params.flat, &x0, 2, 0, &mut rng).unwrap();
        assert_eq!(calib.len(), info.n_layers);
        for c in &calib {
            assert_eq!(c.acts.len(), 2 * info.act_samples);
            assert!(c.min <= c.max);
            assert!(c.acts.iter().all(|v| v.is_finite()));
        }
        // at least some layers should be flagged AAL by architecture
        assert!(calib.iter().any(|c| c.aal_hint));
    }

    #[test]
    fn empty_x0_pool_errors_instead_of_panicking() {
        let d = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if !d.join("manifest.json").exists() {
            crate::log_warn!("skipping: artifacts not built");
            return;
        }
        let m = Manifest::load(&d).unwrap();
        let info = m.model("ddim16").unwrap();
        let engine = Arc::new(Engine::new(&d).unwrap());
        let den = Denoiser::new(engine, info).unwrap();
        let params = ParamStore::load_init(info, &d).unwrap();
        let sched = Schedule::linear(100);
        let mut rng = Rng::new(6);
        // empty pool and a too-short pool (less than one sample) both error
        for x0 in [Vec::new(), vec![0.1f32; info.x_size(1) - 1]] {
            let err =
                collect_calibration(&den, info, &sched, &params.flat, &x0, 1, 0, &mut rng)
                    .unwrap_err();
            assert!(format!("{err:#}").contains("x0 pool is empty"), "{err:#}");
        }
    }
}
