//! TALoRA + DFA fine-tuning (paper §4.2, §4.3, Appendix C), with optional
//! online recalibration (`crate::recal`).
//!
//! Walks the denoising process step by step (trajectory buffer), at each
//! step draws a minibatch of (x_t, eps_fp) pairs, and executes the
//! fine-tune graph: DFA-weighted eps-MSE, gradients w.r.t. the LoRA hub and
//! the router (STE through the hard selection). Rust runs two Adam
//! instances (lr 1e-4, Appendix C) and records the per-timestep loss curve
//! and router allocations (Figures 3/7/9).
//!
//! With a [`FinetuneRecal`] context and `FinetuneCfg::recal_every > 0`,
//! the loop additionally runs the EfficientDM-style
//! recalibrate-while-tuning cadence: every `recal_every` epochs it probes
//! the calibration graph on trajectory-sourced batches (one uniform
//! timestep per probe, so the activation sketches stay timestep-
//! attributed), scores per-layer drift against the quant session's
//! current calibration, applies `QuantSession::update_layer_calib` to the
//! drifted layers only, and swaps the freshly searched qparams into the
//! remaining fine-tune steps. Because the first probe pass sees the
//! *actual* fine-tuning input distribution (FP-rollout x_t) rather than
//! the noised-x0 proxies of the initial calibration, the first check also
//! absorbs that distribution gap.

use std::sync::Arc;

use anyhow::Result;

use crate::log_info;
use crate::model::manifest::ModelInfo;
use crate::quant::msfp::QuantOpts;
use crate::quant::session::QuantSession;
use crate::recal::{RecalPlanner, SketchSet};
use crate::runtime::{Denoiser, Engine};
use crate::schedule::Schedule;
use crate::train::TrajectoryBuffer;
use crate::util::rng::Rng;

use super::adam::Adam;

#[derive(Debug, Clone)]
pub struct FinetuneCfg {
    /// epochs over the trajectory steps (paper: 160 DDIM / 320 LDM; ours
    /// scaled)
    pub epochs: usize,
    pub lr: f32,
    /// DFA on/off (ablation row)
    pub dfa: bool,
    /// active hub size h (<= H)
    pub h: usize,
    pub seed: u64,
    pub log_every: usize,
    /// run a drift check (and recalibrate drifted layers) every N epochs;
    /// 0 = off. Only effective through [`finetune_recal`] with a
    /// [`FinetuneRecal`] context — the plain [`finetune`] entry point has
    /// no quant session to update and ignores it.
    pub recal_every: usize,
}

impl Default for FinetuneCfg {
    fn default() -> Self {
        FinetuneCfg {
            epochs: 4,
            lr: 1e-4,
            dfa: true,
            h: 2,
            seed: 0,
            log_every: 1,
            recal_every: 0,
        }
    }
}

/// One applied recalibration during fine-tuning.
#[derive(Debug, Clone)]
pub struct RecalEvent {
    /// epoch after which the check ran (0-based)
    pub epoch: usize,
    /// layers whose calibration was replaced
    pub layers: Vec<usize>,
    /// the largest drift score observed in the check
    pub max_score: f32,
}

#[derive(Debug, Clone, Default)]
pub struct FinetuneStats {
    /// mean raw (un-weighted) loss per tau index, last epoch (Fig. 3)
    pub loss_by_step: Vec<f32>,
    /// selection histogram [tau][H] from the last epoch (Figs. 7/9)
    pub sel_by_step: Vec<Vec<f32>>,
    /// loss trajectory over all updates
    pub losses: Vec<f32>,
    /// recalibrations applied by the recal_every cadence
    pub recal_events: Vec<RecalEvent>,
}

/// Everything the recalibrate-while-tuning cadence needs beyond the
/// fine-tune loop itself. The session must be the one the initial qparams
/// were searched on (its calibration is the drift baseline, and it keeps
/// itself current as updates are applied).
pub struct FinetuneRecal<'a> {
    pub den: &'a Denoiser,
    pub session: &'a mut QuantSession<'static>,
    /// knobs the scheme is (re-)searched with — must match the initial
    /// search so untouched layers replay their memoized winners
    pub opts: QuantOpts,
    pub planner: RecalPlanner,
    /// calibration-graph probe batches sketched per check
    pub probe_rounds: usize,
    /// timestep buckets of the activation sketches
    pub n_buckets: usize,
    /// per-(layer, bucket) reservoir capacity
    pub reservoir: usize,
}

impl<'a> FinetuneRecal<'a> {
    pub fn new(den: &'a Denoiser, session: &'a mut QuantSession<'static>, opts: QuantOpts) -> Self {
        FinetuneRecal {
            den,
            session,
            opts,
            planner: RecalPlanner::default(),
            probe_rounds: 2,
            n_buckets: 4,
            reservoir: 256,
        }
    }
}

/// Fine-tune the LoRA hub + router. `qparams` comes from the MSFP (or
/// baseline) search; `lora`/`router` are updated in place. Thin wrapper
/// over [`finetune_recal`] without the recalibration cadence.
#[allow(clippy::too_many_arguments)]
pub fn finetune(
    engine: &Arc<Engine>,
    info: &ModelInfo,
    sched: &Schedule,
    traj: &TrajectoryBuffer,
    params: &[f32],
    qparams: &[f32],
    lora: &mut Vec<f32>,
    router: &mut Vec<f32>,
    cfg: &FinetuneCfg,
) -> Result<FinetuneStats> {
    let mut qp = qparams.to_vec();
    finetune_recal(engine, info, sched, traj, params, &mut qp, lora, router, cfg, None)
}

/// [`finetune`] with the online-recalibration cadence: when `recal` is
/// provided and `cfg.recal_every > 0`, drifted layers are recalibrated
/// mid-run and `qparams` is updated in place with the re-searched scheme
/// (callers keep serving from the final value). Without a context (or with
/// `recal_every == 0`) this is bit-identical to [`finetune`]: the probe
/// rng is a separate stream, so enabling the cadence never perturbs the
/// minibatch draws.
#[allow(clippy::too_many_arguments)]
pub fn finetune_recal(
    engine: &Arc<Engine>,
    info: &ModelInfo,
    sched: &Schedule,
    traj: &TrajectoryBuffer,
    params: &[f32],
    qparams: &mut Vec<f32>,
    lora: &mut Vec<f32>,
    router: &mut Vec<f32>,
    cfg: &FinetuneCfg,
    mut recal: Option<FinetuneRecal<'_>>,
) -> Result<FinetuneStats> {
    let exe = engine.load(info.artifact(&format!("finetune_b{}", info.train_b))?)?;
    let b = info.train_b;
    let hw = info.cfg.img_hw as i64;
    let c = info.cfg.in_ch as i64;
    let l = info.n_layers;
    let h_total = info.cfg.lora_hub;
    let hub_mask: Vec<f32> =
        (0..h_total).map(|i| if i < cfg.h { 1.0 } else { 0.0 }).collect();
    let mut rng = Rng::new(cfg.seed ^ 0x66696e65);
    let mut opt_lora = Adam::new(lora.len(), cfg.lr);
    let mut opt_router = Adam::new(router.len(), cfg.lr);
    let mut stats = FinetuneStats {
        loss_by_step: vec![0.0; traj.steps()],
        sel_by_step: vec![vec![0.0; h_total]; traj.steps()],
        losses: Vec::new(),
        recal_events: Vec::new(),
    };
    // recal state: sketches + an rng stream independent of the minibatch
    // draws (the cadence must not perturb the training trajectory)
    let mut recal_state = recal.as_ref().map(|r| {
        (
            SketchSet::new(l, r.n_buckets, r.reservoir, sched.t_total, cfg.seed ^ 0x726563),
            Rng::new(cfg.seed ^ 0x7265636c),
        )
    });

    for epoch in 0..cfg.epochs {
        let last_epoch = epoch + 1 == cfg.epochs;
        // walk the denoising process in order (outline -> details)
        for i in 0..traj.steps() {
            let t = traj.tau[i] as f32;
            let gamma = if cfg.dfa { sched.gamma(traj.tau[i]) } else { 1.0 };
            let (x_t, eps_t, cond) = traj.minibatch(i, b, &mut rng);
            let out = exe.run(&[
                (params, &[params.len() as i64]),
                (&qparams[..], &[l as i64, 8]),
                (&lora[..], &[lora.len() as i64]),
                (&router[..], &[router.len() as i64]),
                (&hub_mask, &[h_total as i64]),
                (&x_t, &[b as i64, hw, hw, c]),
                (&[t][..], &[]),
                (&[gamma][..], &[]),
                (&eps_t, &[b as i64, hw, hw, c]),
                (&cond, &[b as i64]),
            ])?;
            let loss = out[0][0];
            opt_lora.step(lora, &out[1]);
            opt_router.step(router, &out[2]);
            stats.losses.push(loss);
            if last_epoch {
                stats.loss_by_step[i] = loss / gamma.max(1e-12); // raw eps-MSE
                let sel = &out[3]; // [L, H] one-hot
                for li in 0..l {
                    for hi in 0..h_total {
                        stats.sel_by_step[i][hi] += sel[li * h_total + hi] / l as f32;
                    }
                }
            }
        }
        if epoch % cfg.log_every == 0 || last_epoch {
            let recent = &stats.losses[stats.losses.len().saturating_sub(traj.steps())..];
            let mean: f32 = recent.iter().sum::<f32>() / recent.len().max(1) as f32;
            log_info!("finetune epoch {epoch}/{} mean weighted loss {mean:.5}", cfg.epochs);
        }

        // recalibrate-while-tuning cadence: probe, score drift, rebuild the
        // drifted layers' searches, swap the new qparams into the remaining
        // epochs (the last epoch has no remaining steps to benefit)
        if let (Some(r), Some((sketches, probe_rng))) = (recal.as_mut(), recal_state.as_mut()) {
            if cfg.recal_every > 0 && (epoch + 1) % cfg.recal_every == 0 && !last_epoch {
                if let Some(event) =
                    recal_check(r, info, traj, params, qparams, sketches, probe_rng)?
                {
                    log_info!(
                        "recalibrated {} layer(s) after epoch {epoch} (max drift {:.3})",
                        event.layers.len(),
                        event.max_score
                    );
                    stats.recal_events.push(RecalEvent { epoch, ..event });
                }
            }
        }
    }
    Ok(stats)
}

/// One drift check: sketch `probe_rounds` calibration-graph probes built
/// from the trajectory buffer (uniform t per probe batch, so samples land
/// in the right timestep bucket), plan against the session's current
/// calibration, and apply + re-search if anything drifted. Returns the
/// applied event (epoch filled in by the caller), or None when no layer
/// crossed the threshold.
fn recal_check(
    r: &mut FinetuneRecal<'_>,
    info: &ModelInfo,
    traj: &TrajectoryBuffer,
    params: &[f32],
    qparams: &mut Vec<f32>,
    sketches: &mut SketchSet,
    probe_rng: &mut Rng,
) -> Result<Option<RecalEvent>> {
    let b = info.calib_b;
    for _ in 0..r.probe_rounds.max(1) {
        let i = probe_rng.below(traj.steps());
        let t = traj.tau[i] as f32;
        let (x, _eps, cond) = traj.minibatch(i, b, probe_rng);
        let tb = vec![t; b];
        let (_e, a_out, mm) = r.den.calib_forward(params, &x, &tb, &cond)?;
        sketches.observe_calib(t, &a_out, &mm, info.act_samples);
    }
    let plan = r.planner.plan(r.session.calib(), sketches);
    if plan.is_empty() {
        return Ok(None);
    }
    let layers: Vec<usize> = plan.layers.iter().map(|rl| rl.layer).collect();
    let max_score = plan.layers.iter().map(|rl| rl.score).fold(0.0f32, f32::max);
    for rl in plan.layers {
        r.session.update_layer_calib(rl.layer, rl.calib);
    }
    let scheme = r.session.quantize(&r.opts);
    *qparams = scheme.qparams_rows();
    Ok(Some(RecalEvent { epoch: 0, layers, max_score }))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lora::LoraHub;
    use crate::model::manifest::Manifest;
    use crate::model::ParamStore;
    use crate::runtime::Denoiser;
    use crate::schedule::timestep_subsequence;
    use std::path::PathBuf;

    #[test]
    fn finetune_reduces_loss_on_tiny_run() {
        let d = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if !d.join("manifest.json").exists() {
            crate::log_warn!("skipping: artifacts not built");
            return;
        }
        let m = Manifest::load(&d).unwrap();
        let info = m.model("ddim16").unwrap();
        let engine = Arc::new(Engine::new(&d).unwrap());
        let den = Denoiser::new(Arc::clone(&engine), info).unwrap();
        let mut params = ParamStore::load_init(info, &d).unwrap().flat;
        // perturb conv_out so quantization actually bites
        let mut rng = Rng::new(9);
        for v in params.iter_mut() {
            *v += rng.normal() * 0.01;
        }
        let sched = Schedule::linear(100);
        let tau = timestep_subsequence(100, 4);
        let traj =
            TrajectoryBuffer::collect(&den, info, &sched, &tau, &params, 4, 0, &mut rng).unwrap();
        // aggressive 4-bit-ish quantization
        let mut qp = Vec::new();
        for _ in 0..info.n_layers {
            qp.extend_from_slice(&[0.5, 2.0, 1.0, 1.0, 4.0, 2.0, 1.0, -0.2]);
        }
        let mut lora = LoraHub::init(info, &mut rng).flat;
        let mut router = rng.normal_vec(info.router_size, 0.05);
        let cfg = FinetuneCfg {
            epochs: 6,
            lr: 3e-3,
            dfa: true,
            h: 2,
            seed: 2,
            log_every: 100,
            recal_every: 0,
        };
        let stats = finetune(
            &engine, info, &sched, &traj, &params, &qp, &mut lora, &mut router, &cfg,
        )
        .unwrap();
        let per_epoch = traj.steps();
        let first: f32 =
            stats.losses[..per_epoch].iter().sum::<f32>() / per_epoch as f32;
        let last: f32 = stats.losses[stats.losses.len() - per_epoch..].iter().sum::<f32>()
            / per_epoch as f32;
        assert!(last < first, "finetune loss did not improve: {first} -> {last}");
        // stats populated
        assert_eq!(stats.sel_by_step.len(), 4);
        for row in &stats.sel_by_step {
            assert!((row.iter().sum::<f32>() - 1.0).abs() < 1e-3);
            // h=2: slots 2,3 never selected
            assert_eq!(row[2], 0.0);
            assert_eq!(row[3], 0.0);
        }
        assert!(stats.recal_events.is_empty());
    }

    #[test]
    fn finetune_recal_cadence_recalibrates_and_stays_finite() {
        let d = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if !d.join("manifest.json").exists() {
            crate::log_warn!("skipping: artifacts not built");
            return;
        }
        let m = Manifest::load(&d).unwrap();
        let info = m.model("ddim16").unwrap();
        let engine = Arc::new(Engine::new(&d).unwrap());
        let den = Denoiser::new(Arc::clone(&engine), info).unwrap();
        let params = ParamStore::load_init(info, &d).unwrap().flat;
        let sched = Schedule::linear(100);
        let tau = timestep_subsequence(100, 4);
        let mut rng = Rng::new(19);
        let traj =
            TrajectoryBuffer::collect(&den, info, &sched, &tau, &params, 4, 0, &mut rng).unwrap();

        // initial calibration from noised-x0 proxies (the distribution the
        // recal probes will measure drift against)
        let x0: Vec<f32> = (0..4 * info.x_size(1)).map(|_| rng.normal() * 0.5).collect();
        let calib = crate::train::collect_calibration(
            &den, info, &sched, &params, &x0, 2, 0, &mut rng,
        )
        .unwrap();
        let weights =
            ParamStore::from_vec(info, params.clone()).unwrap().layer_weights(info).unwrap();
        let mut session = QuantSession::from_owned(weights, calib);
        let opts = QuantOpts::new(crate::quant::msfp::Method::Msfp, info.n_layers, 4, 4);
        let scheme = session.quantize(&opts);
        let mut qparams = scheme.qparams_rows();
        let init_qparams = qparams.clone();

        let mut lora = LoraHub::init(info, &mut rng).flat;
        let mut router = rng.normal_vec(info.router_size, 0.05);
        let cfg = FinetuneCfg {
            epochs: 3,
            lr: 1e-3,
            recal_every: 1,
            seed: 4,
            log_every: 100,
            ..Default::default()
        };
        // an eager planner so the trajectory-vs-proxy distribution gap is
        // guaranteed to trip at least one layer on the tiny test budget
        let mut recal = FinetuneRecal::new(&den, &mut session, opts.clone());
        recal.planner.threshold = 0.02;
        recal.planner.min_samples = 8;
        let stats = finetune_recal(
            &engine,
            info,
            &sched,
            &traj,
            &params,
            &mut qparams,
            &mut lora,
            &mut router,
            &cfg,
            Some(recal),
        )
        .unwrap();
        assert!(stats.losses.iter().all(|l| l.is_finite()));
        assert!(!stats.recal_events.is_empty(), "eager cadence never fired");
        let ev = &stats.recal_events[0];
        assert!(!ev.layers.is_empty());
        assert!(ev.max_score > 0.02);
        assert_ne!(qparams, init_qparams, "recalibration did not change the scheme");
        assert_eq!(qparams.len(), info.n_layers * 8);
        // the updated scheme matches a cold re-search on the session's
        // current calibration (the incremental-rebuild parity contract)
        let cold = QuantSession::from_owned(
            ParamStore::from_vec(info, params.clone())
                .unwrap()
                .layer_weights(info)
                .unwrap(),
            session.calib().to_vec(),
        )
        .quantize(&opts);
        assert_eq!(qparams, cold.qparams_rows());
    }
}
