//! TALoRA + DFA fine-tuning (paper §4.2, §4.3, Appendix C).
//!
//! Walks the denoising process step by step (trajectory buffer), at each
//! step draws a minibatch of (x_t, eps_fp) pairs, and executes the
//! fine-tune graph: DFA-weighted eps-MSE, gradients w.r.t. the LoRA hub and
//! the router (STE through the hard selection). Rust runs two Adam
//! instances (lr 1e-4, Appendix C) and records the per-timestep loss curve
//! and router allocations (Figures 3/7/9).

use std::sync::Arc;

use anyhow::Result;

use crate::log_info;
use crate::model::manifest::ModelInfo;
use crate::runtime::Engine;
use crate::schedule::Schedule;
use crate::train::TrajectoryBuffer;
use crate::util::rng::Rng;

use super::adam::Adam;

#[derive(Debug, Clone)]
pub struct FinetuneCfg {
    /// epochs over the trajectory steps (paper: 160 DDIM / 320 LDM; ours
    /// scaled)
    pub epochs: usize,
    pub lr: f32,
    /// DFA on/off (ablation row)
    pub dfa: bool,
    /// active hub size h (<= H)
    pub h: usize,
    pub seed: u64,
    pub log_every: usize,
}

impl Default for FinetuneCfg {
    fn default() -> Self {
        FinetuneCfg { epochs: 4, lr: 1e-4, dfa: true, h: 2, seed: 0, log_every: 1 }
    }
}

#[derive(Debug, Clone, Default)]
pub struct FinetuneStats {
    /// mean raw (un-weighted) loss per tau index, last epoch (Fig. 3)
    pub loss_by_step: Vec<f32>,
    /// selection histogram [tau][H] from the last epoch (Figs. 7/9)
    pub sel_by_step: Vec<Vec<f32>>,
    /// loss trajectory over all updates
    pub losses: Vec<f32>,
}

/// Fine-tune the LoRA hub + router. `qparams` comes from the MSFP (or
/// baseline) search; `lora`/`router` are updated in place.
#[allow(clippy::too_many_arguments)]
pub fn finetune(
    engine: &Arc<Engine>,
    info: &ModelInfo,
    sched: &Schedule,
    traj: &TrajectoryBuffer,
    params: &[f32],
    qparams: &[f32],
    lora: &mut Vec<f32>,
    router: &mut Vec<f32>,
    cfg: &FinetuneCfg,
) -> Result<FinetuneStats> {
    let exe = engine.load(info.artifact(&format!("finetune_b{}", info.train_b))?)?;
    let b = info.train_b;
    let hw = info.cfg.img_hw as i64;
    let c = info.cfg.in_ch as i64;
    let l = info.n_layers;
    let h_total = info.cfg.lora_hub;
    let hub_mask: Vec<f32> =
        (0..h_total).map(|i| if i < cfg.h { 1.0 } else { 0.0 }).collect();
    let mut rng = Rng::new(cfg.seed ^ 0x66696e65);
    let mut opt_lora = Adam::new(lora.len(), cfg.lr);
    let mut opt_router = Adam::new(router.len(), cfg.lr);
    let mut stats = FinetuneStats {
        loss_by_step: vec![0.0; traj.steps()],
        sel_by_step: vec![vec![0.0; h_total]; traj.steps()],
        losses: Vec::new(),
    };

    for epoch in 0..cfg.epochs {
        let last_epoch = epoch + 1 == cfg.epochs;
        // walk the denoising process in order (outline -> details)
        for i in 0..traj.steps() {
            let t = traj.tau[i] as f32;
            let gamma = if cfg.dfa { sched.gamma(traj.tau[i]) } else { 1.0 };
            let (x_t, eps_t, cond) = traj.minibatch(i, b, &mut rng);
            let out = exe.run(&[
                (params, &[params.len() as i64]),
                (qparams, &[l as i64, 8]),
                (&lora[..], &[lora.len() as i64]),
                (&router[..], &[router.len() as i64]),
                (&hub_mask, &[h_total as i64]),
                (&x_t, &[b as i64, hw, hw, c]),
                (&[t][..], &[]),
                (&[gamma][..], &[]),
                (&eps_t, &[b as i64, hw, hw, c]),
                (&cond, &[b as i64]),
            ])?;
            let loss = out[0][0];
            opt_lora.step(lora, &out[1]);
            opt_router.step(router, &out[2]);
            stats.losses.push(loss);
            if last_epoch {
                stats.loss_by_step[i] = loss / gamma.max(1e-12); // raw eps-MSE
                let sel = &out[3]; // [L, H] one-hot
                for li in 0..l {
                    for hi in 0..h_total {
                        stats.sel_by_step[i][hi] += sel[li * h_total + hi] / l as f32;
                    }
                }
            }
        }
        if epoch % cfg.log_every == 0 || last_epoch {
            let recent = &stats.losses[stats.losses.len().saturating_sub(traj.steps())..];
            let mean: f32 = recent.iter().sum::<f32>() / recent.len().max(1) as f32;
            log_info!("finetune epoch {epoch}/{} mean weighted loss {mean:.5}", cfg.epochs);
        }
    }
    Ok(stats)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lora::LoraHub;
    use crate::model::manifest::Manifest;
    use crate::model::ParamStore;
    use crate::runtime::Denoiser;
    use crate::schedule::timestep_subsequence;
    use std::path::PathBuf;

    #[test]
    fn finetune_reduces_loss_on_tiny_run() {
        let d = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if !d.join("manifest.json").exists() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let m = Manifest::load(&d).unwrap();
        let info = m.model("ddim16").unwrap();
        let engine = Arc::new(Engine::new(&d).unwrap());
        let den = Denoiser::new(Arc::clone(&engine), info).unwrap();
        let mut params = ParamStore::load_init(info, &d).unwrap().flat;
        // perturb conv_out so quantization actually bites
        let mut rng = Rng::new(9);
        for v in params.iter_mut() {
            *v += rng.normal() * 0.01;
        }
        let sched = Schedule::linear(100);
        let tau = timestep_subsequence(100, 4);
        let traj =
            TrajectoryBuffer::collect(&den, info, &sched, &tau, &params, 4, 0, &mut rng).unwrap();
        // aggressive 4-bit-ish quantization
        let mut qp = Vec::new();
        for _ in 0..info.n_layers {
            qp.extend_from_slice(&[0.5, 2.0, 1.0, 1.0, 4.0, 2.0, 1.0, -0.2]);
        }
        let mut lora = LoraHub::init(info, &mut rng).flat;
        let mut router = rng.normal_vec(info.router_size, 0.05);
        let cfg = FinetuneCfg { epochs: 6, lr: 3e-3, dfa: true, h: 2, seed: 2, log_every: 100 };
        let stats = finetune(
            &engine, info, &sched, &traj, &params, &qp, &mut lora, &mut router, &cfg,
        )
        .unwrap();
        let per_epoch = traj.steps();
        let first: f32 =
            stats.losses[..per_epoch].iter().sum::<f32>() / per_epoch as f32;
        let last: f32 = stats.losses[stats.losses.len() - per_epoch..].iter().sum::<f32>()
            / per_epoch as f32;
        assert!(last < first, "finetune loss did not improve: {first} -> {last}");
        // stats populated
        assert_eq!(stats.sel_by_step.len(), 4);
        for row in &stats.sel_by_step {
            assert!((row.iter().sum::<f32>() - 1.0).abs() < 1e-3);
            // h=2: slots 2,3 never selected
            assert_eq!(row[2], 0.0);
            assert_eq!(row[3], 0.0);
        }
    }
}
