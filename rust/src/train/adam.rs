//! Adam optimizer over flat f32 parameter vectors (Appendix C: Adam,
//! lr = 1e-4 for both TALoRAs and the router).

#[derive(Debug, Clone)]
pub struct Adam {
    pub lr: f32,
    pub beta1: f32,
    pub beta2: f32,
    pub eps: f32,
    m: Vec<f32>,
    v: Vec<f32>,
    t: u32,
}

impl Adam {
    pub fn new(n: usize, lr: f32) -> Adam {
        Adam { lr, beta1: 0.9, beta2: 0.999, eps: 1e-8, m: vec![0.0; n], v: vec![0.0; n], t: 0 }
    }

    pub fn step(&mut self, params: &mut [f32], grad: &[f32]) {
        assert_eq!(params.len(), grad.len());
        assert_eq!(params.len(), self.m.len());
        self.t += 1;
        let b1t = 1.0 - self.beta1.powi(self.t as i32);
        let b2t = 1.0 - self.beta2.powi(self.t as i32);
        for i in 0..params.len() {
            let g = grad[i];
            self.m[i] = self.beta1 * self.m[i] + (1.0 - self.beta1) * g;
            self.v[i] = self.beta2 * self.v[i] + (1.0 - self.beta2) * g * g;
            let mhat = self.m[i] / b1t;
            let vhat = self.v[i] / b2t;
            params[i] -= self.lr * mhat / (vhat.sqrt() + self.eps);
        }
    }

    pub fn state(&self) -> (Vec<f32>, Vec<f32>, u32) {
        (self.m.clone(), self.v.clone(), self.t)
    }

    pub fn restore(&mut self, m: Vec<f32>, v: Vec<f32>, t: u32) {
        assert_eq!(m.len(), self.m.len());
        self.m = m;
        self.v = v;
        self.t = t;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// minimize f(x) = sum((x - c)^2)
    #[test]
    fn converges_on_quadratic() {
        let c = [3.0f32, -1.5, 0.25];
        let mut x = vec![0.0f32; 3];
        let mut opt = Adam::new(3, 0.1);
        for _ in 0..500 {
            let g: Vec<f32> = x.iter().zip(&c).map(|(xi, ci)| 2.0 * (xi - ci)).collect();
            opt.step(&mut x, &g);
        }
        for (xi, ci) in x.iter().zip(&c) {
            assert!((xi - ci).abs() < 1e-2, "{xi} vs {ci}");
        }
    }

    #[test]
    fn first_step_is_lr_sized() {
        let mut x = vec![0.0f32];
        let mut opt = Adam::new(1, 0.01);
        opt.step(&mut x, &[5.0]);
        // Adam's first update is ~lr * sign(g)
        assert!((x[0] + 0.01).abs() < 1e-3, "{}", x[0]);
    }

    #[test]
    fn state_roundtrip() {
        let mut a = Adam::new(4, 0.05);
        let mut x = vec![1.0f32; 4];
        for _ in 0..10 {
            a.step(&mut x, &[0.3, -0.2, 0.1, 0.0]);
        }
        let (m, v, t) = a.state();
        let mut b = Adam::new(4, 0.05);
        b.restore(m, v, t);
        let mut xa = x.clone();
        let mut xb = x.clone();
        a.step(&mut xa, &[0.1; 4]);
        b.step(&mut xb, &[0.1; 4]);
        assert_eq!(xa, xb);
    }

    #[test]
    #[should_panic]
    fn mismatched_grad_panics() {
        let mut a = Adam::new(2, 0.1);
        let mut x = vec![0.0; 2];
        a.step(&mut x, &[1.0]);
    }
}
