//! # MSFP — 4-bit floating-point quantization for diffusion models
//!
//! Rust reproduction of *Pioneering 4-Bit FP Quantization for Diffusion
//! Models: Mixup-Sign Quantization and Timestep-Aware Fine-Tuning*
//! (Zhao et al., 2025), as the Layer-3 coordinator of a three-layer
//! Rust + JAX + Pallas stack (see DESIGN.md).
//!
//! The crate owns everything at run time: the parameter store, the MSFP
//! quantizer search (the paper's Algorithm 1), the DDPM schedule and
//! samplers, pretraining / TALoRA fine-tuning loops (gradients come from
//! AOT-lowered JAX graphs executed through PJRT), the serving coordinator
//! with step-level continuous batching, proxy FID/IS evaluation, and the
//! experiment harness that regenerates the paper's tables and figures.
//!
//! Python (JAX + Pallas) runs only at build time (`make artifacts`); the
//! binary is self-contained once `artifacts/` exists.

pub mod util;
pub mod linalg;
pub mod quant;
pub mod recal;
pub mod schedule;
pub mod model;
pub mod lora;
pub mod runtime;
pub mod train;
pub mod data;
pub mod eval;
pub mod coordinator;
pub mod obs;
pub mod exp;
pub mod config;
pub mod pipeline;
