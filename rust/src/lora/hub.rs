//! The LoRA hub state: per-layer A[H,r,K] / B[H,N,r] adapters packed into
//! the flat vector the graphs consume, plus allocation-strategy helpers for
//! the Table-1 experiment (single / dual-split / dual-random).

use anyhow::{bail, Result};

use crate::model::manifest::ModelInfo;
use crate::util::rng::Rng;

#[derive(Debug, Clone)]
pub struct LoraHub {
    pub flat: Vec<f32>,
    pub h: usize,
    pub rank: usize,
}

impl LoraHub {
    /// Paper init: A ~ N(0, 0.02), B = 0 (adapters start as no-ops).
    pub fn init(info: &ModelInfo, rng: &mut Rng) -> LoraHub {
        let h = info.cfg.lora_hub;
        let r = info.cfg.lora_rank;
        let mut flat = vec![0.0f32; info.lora_size];
        for spec in &info.layer_specs {
            let a_len = h * r * spec.fan_in;
            for v in &mut flat[spec.lora_offset..spec.lora_offset + a_len] {
                *v = rng.normal() * 0.02;
            }
            // B region stays zero
        }
        LoraHub { flat, h, rank: r }
    }

    pub fn zeros(info: &ModelInfo) -> LoraHub {
        LoraHub { flat: vec![0.0; info.lora_size], h: info.cfg.lora_hub, rank: info.cfg.lora_rank }
    }
}

/// How LoRAs are assigned to timesteps — Table 1's three strategies plus
/// the learned router (TALoRA proper).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AllocStrategy {
    /// one adapter for every timestep (hub slot 0)
    Single,
    /// slot 0 for the first half of the denoising process (large t),
    /// slot 1 for the last half
    DualSplit,
    /// uniformly random slot in {0,1} per timestep (the paper's negative
    /// control — disordered allocation hurts)
    DualRandom,
    /// the learned timestep-aware router
    Learned,
}

impl AllocStrategy {
    /// Fixed (non-learned) selection for timestep t of T; None means the
    /// router decides.
    pub fn fixed_slot(&self, t: usize, t_total: usize, rng: &mut Rng) -> Option<usize> {
        match self {
            AllocStrategy::Single => Some(0),
            AllocStrategy::DualSplit => Some(if t >= t_total / 2 { 0 } else { 1 }),
            AllocStrategy::DualRandom => Some(rng.below(2)),
            AllocStrategy::Learned => None,
        }
    }

    /// Effective hub mask (h=1 for Single, h=2 for Dual*, full for Learned
    /// callers pass their own h).
    pub fn hub_mask(&self, h_total: usize, h_learned: usize) -> Vec<f32> {
        let active = match self {
            AllocStrategy::Single => 1,
            AllocStrategy::DualSplit | AllocStrategy::DualRandom => 2,
            AllocStrategy::Learned => h_learned,
        };
        (0..h_total).map(|i| if i < active { 1.0 } else { 0.0 }).collect()
    }
}

/// Build a one-hot selection matrix [L, H] with every layer on `slot`.
pub fn uniform_selection(n_layers: usize, h: usize, slot: usize) -> Result<Vec<f32>> {
    if slot >= h {
        bail!("slot {slot} >= hub size {h}");
    }
    let mut sel = vec![0.0f32; n_layers * h];
    for l in 0..n_layers {
        sel[l * h + slot] = 1.0;
    }
    Ok(sel)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fixed_slots() {
        let mut rng = Rng::new(1);
        assert_eq!(AllocStrategy::Single.fixed_slot(77, 100, &mut rng), Some(0));
        assert_eq!(AllocStrategy::DualSplit.fixed_slot(80, 100, &mut rng), Some(0));
        assert_eq!(AllocStrategy::DualSplit.fixed_slot(20, 100, &mut rng), Some(1));
        assert_eq!(AllocStrategy::Learned.fixed_slot(5, 100, &mut rng), None);
        let s = AllocStrategy::DualRandom.fixed_slot(5, 100, &mut rng).unwrap();
        assert!(s < 2);
    }

    #[test]
    fn hub_masks() {
        assert_eq!(AllocStrategy::Single.hub_mask(4, 4), vec![1.0, 0.0, 0.0, 0.0]);
        assert_eq!(AllocStrategy::DualSplit.hub_mask(4, 4), vec![1.0, 1.0, 0.0, 0.0]);
        assert_eq!(AllocStrategy::Learned.hub_mask(4, 2), vec![1.0, 1.0, 0.0, 0.0]);
        assert_eq!(AllocStrategy::Learned.hub_mask(4, 4), vec![1.0; 4]);
    }

    #[test]
    fn uniform_selection_onehot() {
        let sel = uniform_selection(3, 4, 2).unwrap();
        assert_eq!(sel.len(), 12);
        for l in 0..3 {
            let row = &sel[l * 4..(l + 1) * 4];
            assert_eq!(row.iter().sum::<f32>(), 1.0);
            assert_eq!(row[2], 1.0);
        }
        assert!(uniform_selection(3, 4, 4).is_err());
    }
}
