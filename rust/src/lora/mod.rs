//! TALoRA: the timestep-aware LoRA hub and its router (paper §4.2).
//!
//! Training happens inside the fine-tune graph (router + STE in JAX);
//! at inference the Rust router mirrors it exactly: sinusoidal(t) → linear
//! → per-layer argmax → one-hot selection fed to the serving graph.

pub mod hub;
pub mod router;

pub use hub::LoraHub;
pub use router::{Router, SelectionCache};
