//! The timestep-aware router — inference-side mirror of
//! quantized.router_select (python). Training updates the router weights
//! through the fine-tune graph (STE); this mirror turns the trained weights
//! into per-timestep one-hot selections on the serving path, so routing
//! costs one tiny matvec in Rust and zero extra graph inputs beyond the
//! sel[L,H] tensor.
//!
//! Agreement with the python forward is pinned by the router-golden
//! integration test (argmax selections must match on ≥ 95% of cases;
//! sin/cos/exp may differ by 1 ulp near ties).

use std::collections::HashMap;
use std::sync::Arc;

use anyhow::{bail, Result};

use crate::model::manifest::ModelInfo;
use crate::model::temb::sinusoidal;
use crate::util::rng::Rng;

#[derive(Debug, Clone)]
pub struct Router {
    /// packed [temb_dim * L * H] weight then [L * H] bias
    pub flat: Vec<f32>,
    pub temb_dim: usize,
    pub n_layers: usize,
    pub h: usize,
}

impl Router {
    pub fn new(info: &ModelInfo, flat: Vec<f32>) -> Result<Router> {
        if flat.len() != info.router_size {
            bail!("router len {} != router_size {}", flat.len(), info.router_size);
        }
        Ok(Router {
            flat,
            temb_dim: info.cfg.temb_dim,
            n_layers: info.n_layers,
            h: info.cfg.lora_hub,
        })
    }

    /// Small random init (matches the fine-tune loop's initialization).
    pub fn init(info: &ModelInfo, rng: &mut Rng) -> Router {
        let flat = rng.normal_vec(info.router_size, 0.1);
        Router::new(info, flat).unwrap()
    }

    /// logits[l*H + h] = temb · W[:, l*H + h] + b[l*H + h], mask applied.
    pub fn logits(&self, t: f32, hub_mask: &[f32]) -> Vec<f32> {
        let d = self.temb_dim;
        let lh = self.n_layers * self.h;
        let temb = sinusoidal(t, d);
        let (w, b) = self.flat.split_at(d * lh);
        let mut out = b.to_vec();
        for (i, &e) in temb.iter().enumerate() {
            if e == 0.0 {
                continue;
            }
            let row = &w[i * lh..(i + 1) * lh];
            for (o, &wv) in out.iter_mut().zip(row) {
                *o += e * wv;
            }
        }
        for l in 0..self.n_layers {
            for k in 0..self.h {
                out[l * self.h + k] += (hub_mask[k] - 1.0) * 1e9;
            }
        }
        out
    }

    /// Per-layer argmax slot (first max wins, matching jnp.argmax).
    pub fn select(&self, t: f32, hub_mask: &[f32]) -> Vec<usize> {
        let logits = self.logits(t, hub_mask);
        (0..self.n_layers)
            .map(|l| {
                let row = &logits[l * self.h..(l + 1) * self.h];
                let mut best = 0;
                for (k, &v) in row.iter().enumerate() {
                    if v > row[best] {
                        best = k;
                    }
                }
                best
            })
            .collect()
    }

    /// One-hot selection matrix [L, H] for the serving graph.
    pub fn selection_onehot(&self, t: f32, hub_mask: &[f32]) -> Vec<f32> {
        let sel = self.select(t, hub_mask);
        let mut out = vec![0.0f32; self.n_layers * self.h];
        for (l, &s) in sel.iter().enumerate() {
            out[l * self.h + s] = 1.0;
        }
        out
    }

    /// Allocation histogram over timesteps: out[t][h] = fraction of layers
    /// routed to hub slot h at timestep t (Figures 7 & 9).
    pub fn allocation_distribution(&self, t_total: usize, hub_mask: &[f32]) -> Vec<Vec<f32>> {
        (0..t_total)
            .map(|t| {
                let sel = self.select(t as f32, hub_mask);
                let mut hist = vec![0.0f32; self.h];
                for s in sel {
                    hist[s] += 1.0;
                }
                for v in &mut hist {
                    *v /= self.n_layers as f32;
                }
                hist
            })
            .collect()
    }
}

/// Serve-mode memo of per-timestep selection matrices.
///
/// A learned selection depends only on `(t, hub_mask)` and the fixed
/// strategies only on `(t, serve seed)` — all constant for a coordinator's
/// lifetime — so selections are cached by t's exact bit pattern and shared
/// (`Arc`) across every batch eval at that timestep. Continuous batching
/// revisits the same timesteps constantly (every request walks the same
/// tau subsequences), so the steady-state hit rate approaches 1.
#[derive(Debug, Default)]
pub struct SelectionCache {
    map: HashMap<u32, Arc<Vec<f32>>>,
    pub hits: u64,
    pub misses: u64,
}

impl SelectionCache {
    /// Retention bound: a long-lived server seeing many distinct step
    /// counts (each tau subsequence yields new t values) must not grow
    /// without limit, so the map is reset when it would exceed this —
    /// selections are cheap to recompute and the working set of t values
    /// in flight at any moment is far smaller.
    pub const MAX_ENTRIES: usize = 4096;

    pub fn new() -> SelectionCache {
        SelectionCache::default()
    }

    /// The cached selection for `t`, computing (and retaining) it on miss.
    pub fn get_or_compute(
        &mut self,
        t: f32,
        compute: impl FnOnce() -> Vec<f32>,
    ) -> Arc<Vec<f32>> {
        let key = t.to_bits();
        if let Some(e) = self.map.get(&key) {
            self.hits += 1;
            return Arc::clone(e);
        }
        self.misses += 1;
        if self.map.len() >= Self::MAX_ENTRIES {
            self.map.clear();
        }
        let v = Arc::new(compute());
        self.map.insert(key, Arc::clone(&v));
        v
    }

    pub fn len(&self) -> usize {
        self.map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_router() -> Router {
        let temb_dim = 8;
        let n_layers = 3;
        let h = 4;
        let mut rng = Rng::new(42);
        Router {
            flat: rng.normal_vec(temb_dim * n_layers * h + n_layers * h, 0.5),
            temb_dim,
            n_layers,
            h,
        }
    }

    #[test]
    fn selection_in_range_and_deterministic() {
        let r = tiny_router();
        let mask = vec![1.0; 4];
        let a = r.select(13.0, &mask);
        let b = r.select(13.0, &mask);
        assert_eq!(a, b);
        assert!(a.iter().all(|&s| s < 4));
    }

    #[test]
    fn hub_mask_excludes_slots() {
        let r = tiny_router();
        let mask = vec![1.0, 1.0, 0.0, 0.0];
        for t in 0..100 {
            assert!(r.select(t as f32, &mask).iter().all(|&s| s < 2));
        }
    }

    #[test]
    fn onehot_rows_valid() {
        let r = tiny_router();
        let sel = r.selection_onehot(5.0, &[1.0; 4]);
        for l in 0..3 {
            let row = &sel[l * 4..(l + 1) * 4];
            assert_eq!(row.iter().sum::<f32>(), 1.0);
            assert!(row.iter().all(|&v| v == 0.0 || v == 1.0));
        }
    }

    #[test]
    fn allocation_distribution_normalized() {
        let r = tiny_router();
        let dist = r.allocation_distribution(50, &[1.0; 4]);
        assert_eq!(dist.len(), 50);
        for row in dist {
            assert!((row.iter().sum::<f32>() - 1.0).abs() < 1e-5);
        }
    }

    #[test]
    fn selection_cache_hits_and_shares_arcs() {
        let r = tiny_router();
        let mask = vec![1.0; 4];
        let mut cache = SelectionCache::new();
        let a = cache.get_or_compute(13.0, || r.selection_onehot(13.0, &mask));
        let b = cache.get_or_compute(13.0, || panic!("must not recompute on hit"));
        assert!(Arc::ptr_eq(&a, &b));
        assert_eq!((cache.hits, cache.misses), (1, 1));
        assert_eq!(*a, r.selection_onehot(13.0, &mask));
        // a different t (even by one ulp) is a distinct entry
        let c = cache.get_or_compute(f32::from_bits(13.0f32.to_bits() + 1), || vec![0.0; 12]);
        assert!(!Arc::ptr_eq(&a, &c));
        assert_eq!(cache.len(), 2);
        assert_eq!((cache.hits, cache.misses), (1, 2));
    }

    #[test]
    fn selection_cache_is_bounded() {
        let mut cache = SelectionCache::new();
        for i in 0..(SelectionCache::MAX_ENTRIES as u32 + 100) {
            cache.get_or_compute(f32::from_bits(0x3f80_0000 + i), || vec![1.0]);
        }
        assert!(cache.len() <= SelectionCache::MAX_ENTRIES);
        assert!(!cache.is_empty());
        // a re-request after the reset still round-trips correctly
        let v = cache.get_or_compute(f32::from_bits(0x3f80_0000), || vec![2.0]);
        assert!(*v == vec![1.0] || *v == vec![2.0]);
    }

    #[test]
    fn different_timesteps_can_route_differently() {
        let r = tiny_router();
        let mask = vec![1.0; 4];
        let any_diff = (0..99).any(|t| r.select(t as f32, &mask) != r.select((t + 1) as f32, &mask));
        assert!(any_diff, "router constant across all timesteps is suspicious");
    }
}
