//! FID-syn / sFID-syn / IS-syn: the paper's metric triple on the fixed
//! random-feature embedding.

use anyhow::Result;

use crate::data::Corpus;
use crate::linalg::stats::{frechet, inception_score, mean_cov, softmax_rows};
use crate::linalg::tensor::Mat;
use crate::util::rng::Rng;

use super::features::FeatureExtractor;

/// Reference statistics of a corpus (the "real data" side of FID).
pub struct RefStats {
    pub mu: Vec<f32>,
    pub cov: Mat,
    pub smu: Vec<f32>,
    pub scov: Mat,
}

/// Build reference stats from n fresh corpus samples.
pub fn reference_stats(
    fx: &FeatureExtractor,
    corpus: Corpus,
    n: usize,
    seed: u64,
) -> Result<RefStats> {
    let mut rng = Rng::new(seed ^ 0x726566);
    let (px, _) = corpus.batch(&mut rng, n);
    let (feat, sfeat, _) = fx.extract(&px, n)?;
    let (mu, cov) = mean_cov(&feat)?;
    let (smu, scov) = mean_cov(&sfeat)?;
    Ok(RefStats { mu, cov, smu, scov })
}

#[derive(Debug, Clone, Copy)]
pub struct EvalResult {
    pub fid: f32,
    pub sfid: f32,
    pub is: f32,
}

impl EvalResult {
    pub fn row(&self) -> String {
        format!("FID-syn {:8.3}  sFID-syn {:8.3}  IS-syn {:6.3}", self.fid, self.sfid, self.is)
    }
}

/// Score generated images against reference stats.
pub fn evaluate(
    fx: &FeatureExtractor,
    refs: &RefStats,
    images: &[f32],
    n: usize,
) -> Result<EvalResult> {
    let (feat, sfeat, logits) = fx.extract(images, n)?;
    let (mu, cov) = mean_cov(&feat)?;
    let (smu, scov) = mean_cov(&sfeat)?;
    let fid = frechet(&refs.mu, &refs.cov, &mu, &cov)?;
    let sfid = frechet(&refs.smu, &refs.scov, &smu, &scov)?;
    let mut probs = logits;
    // temperature sharpens the random projection head into usable
    // class-confidences for the IS proxy
    for v in &mut probs.data {
        *v *= 4.0;
    }
    softmax_rows(&mut probs);
    let is = inception_score(&probs)?;
    Ok(EvalResult { fid, sfid, is })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::manifest::Manifest;
    use crate::runtime::Engine;
    use std::path::PathBuf;
    use std::sync::Arc;

    fn setup16() -> Option<FeatureExtractor> {
        let d = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if !d.join("manifest.json").exists() {
            crate::log_warn!("skipping: artifacts not built");
            return None;
        }
        let m = Manifest::load(&d).unwrap();
        let engine = Arc::new(Engine::new(&d).unwrap());
        Some(FeatureExtractor::new(&engine, &m.features, 16).unwrap())
    }

    #[test]
    fn same_corpus_scores_near_zero_fid() {
        let Some(fx) = setup16() else { return };
        let refs = reference_stats(&fx, Corpus::CelebaSyn, 256, 1).unwrap();
        let mut rng = Rng::new(99);
        let (px, _) = Corpus::CelebaSyn.batch(&mut rng, 256);
        let r = evaluate(&fx, &refs, &px, 256).unwrap();
        assert!(r.fid < 3.0, "same-distribution FID-syn should be small: {}", r.fid);
    }

    #[test]
    fn different_corpus_scores_higher() {
        let Some(fx) = setup16() else { return };
        let refs = reference_stats(&fx, Corpus::CelebaSyn, 256, 2).unwrap();
        let mut rng = Rng::new(100);
        let (same, _) = Corpus::CelebaSyn.batch(&mut rng, 256);
        let (diff, _) = Corpus::CifarSyn.batch(&mut rng, 256);
        let r_same = evaluate(&fx, &refs, &same, 256).unwrap();
        let r_diff = evaluate(&fx, &refs, &diff, 256).unwrap();
        assert!(r_diff.fid > 3.0 * r_same.fid.max(0.1),
            "cross-corpus FID {} vs same {}", r_diff.fid, r_same.fid);
        assert!(r_diff.sfid > r_same.sfid);
    }

    #[test]
    fn noise_scores_much_higher() {
        let Some(fx) = setup16() else { return };
        let refs = reference_stats(&fx, Corpus::CifarSyn, 256, 3).unwrap();
        let mut rng = Rng::new(101);
        let noise: Vec<f32> = (0..128 * 16 * 16 * 3).map(|_| rng.normal().clamp(-1.0, 1.0)).collect();
        let (real, _) = Corpus::CifarSyn.batch(&mut rng, 128);
        let r_noise = evaluate(&fx, &refs, &noise, 128).unwrap();
        let r_real = evaluate(&fx, &refs, &real, 128).unwrap();
        assert!(r_noise.fid > 5.0 * r_real.fid.max(0.1));
    }
}
