//! Run the fixed random-conv feature extractor artifact over image batches.

use std::sync::Arc;

use anyhow::Result;

use crate::linalg::tensor::Mat;
use crate::model::manifest::FeatureInfo;
use crate::runtime::{Engine, Executable};

pub struct FeatureExtractor {
    exe: Arc<Executable>,
    pub hw: usize,
    pub batch: usize,
    pub feat_dim: usize,
    pub sfeat_dim: usize,
    pub n_logits: usize,
}

impl FeatureExtractor {
    pub fn new(engine: &Arc<Engine>, fi: &FeatureInfo, hw: usize) -> Result<FeatureExtractor> {
        let path = match hw {
            16 => &fi.path16,
            32 => &fi.path32,
            _ => anyhow::bail!("no feature extractor for {hw}px"),
        };
        Ok(FeatureExtractor {
            exe: engine.load(path)?,
            hw,
            batch: fi.batch,
            feat_dim: fi.feat_dim,
            sfeat_dim: fi.sfeat_dim,
            n_logits: fi.n_logits,
        })
    }

    /// Featurize n stacked hw*hw*3 images -> (feat [n,F], sfeat [n,S],
    /// logits [n,K]).
    pub fn extract(&self, imgs: &[f32], n: usize) -> Result<(Mat, Mat, Mat)> {
        let per = self.hw * self.hw * 3;
        assert_eq!(imgs.len(), n * per);
        let mut feat = Mat::zeros(n, self.feat_dim);
        let mut sfeat = Mat::zeros(n, self.sfeat_dim);
        let mut logits = Mat::zeros(n, self.n_logits);
        let b = self.batch;
        let dims = [b as i64, self.hw as i64, self.hw as i64, 3];
        let mut i = 0;
        while i < n {
            let m = b.min(n - i);
            // pad by repeating the last image
            let mut chunk = Vec::with_capacity(b * per);
            chunk.extend_from_slice(&imgs[i * per..(i + m) * per]);
            for _ in m..b {
                chunk.extend_from_slice(&imgs[(i + m - 1) * per..(i + m) * per]);
            }
            let out = self.exe.run(&[(&chunk, &dims)])?;
            for r in 0..m {
                feat.data[(i + r) * self.feat_dim..(i + r + 1) * self.feat_dim]
                    .copy_from_slice(&out[0][r * self.feat_dim..(r + 1) * self.feat_dim]);
                sfeat.data[(i + r) * self.sfeat_dim..(i + r + 1) * self.sfeat_dim]
                    .copy_from_slice(&out[1][r * self.sfeat_dim..(r + 1) * self.sfeat_dim]);
                logits.data[(i + r) * self.n_logits..(i + r + 1) * self.n_logits]
                    .copy_from_slice(&out[2][r * self.n_logits..(r + 1) * self.n_logits]);
            }
            i += m;
        }
        Ok((feat, sfeat, logits))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::manifest::Manifest;
    use std::path::PathBuf;

    #[test]
    fn extracts_nontrivial_features() {
        let d = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if !d.join("manifest.json").exists() {
            crate::log_warn!("skipping: artifacts not built");
            return;
        }
        let m = Manifest::load(&d).unwrap();
        let engine = Arc::new(Engine::new(&d).unwrap());
        let fx = FeatureExtractor::new(&engine, &m.features, 16).unwrap();
        let mut rng = crate::util::rng::Rng::new(1);
        let n = 40; // exercises padding (batch is 32)
        let imgs: Vec<f32> = (0..n * 16 * 16 * 3).map(|_| rng.normal() * 0.5).collect();
        let (f, s, l) = fx.extract(&imgs, n).unwrap();
        assert_eq!((f.rows, f.cols), (n, 64));
        assert_eq!((s.rows, s.cols), (n, 256));
        assert_eq!((l.rows, l.cols), (n, 10));
        // different images -> different features
        assert!(f.row(0) != f.row(1));
        assert!(f.data.iter().all(|v| v.is_finite()));
    }
}

/// Regression guards for the HLO-text interchange (elided large constants
/// parse back as zeros — see aot.to_hlo_text).
#[cfg(test)]
mod interchange_tests {
    use super::*;
    use std::path::PathBuf;
    use std::sync::Arc;

    #[test]
    fn baked_constants_survive_hlo_text() {
        let d = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if !d.join("manifest.json").exists() { return; }
        let engine = Arc::new(Engine::new(&d).unwrap());
        let exe = engine.load("features16.hlo.txt").unwrap();
        let mut rng = crate::util::rng::Rng::new(1);
        let img: Vec<f32> = (0..32*16*16*3).map(|_| rng.normal()*0.5).collect();
        let out = exe.run(&[(&img, &[32,16,16,3])]).unwrap();
        // feature weights are baked constants: if the HLO printer elided
        // them, every output collapses to zero
        assert!(out[0].iter().any(|&v| v != 0.0), "baked constants were elided");
        assert!(out[0][..64] != out[0][64..128], "features collapsed");
    }

    #[test]
    fn literal_reshape_roundtrip() {
        let l = xla::Literal::vec1(&[1.0f32, 2.0, 3.0, 4.0, 5.0, 6.0]);
        let r = l.reshape(&[2, 3]).unwrap();
        eprintln!("reshaped: {:?} count {}", r.to_vec::<f32>().unwrap(), r.element_count());
        let big: Vec<f32> = (0..32*16*16*3).map(|i| i as f32).collect();
        let lb = xla::Literal::vec1(&big);
        let rb = lb.reshape(&[32, 16, 16, 3]).unwrap();
        let back = rb.to_vec::<f32>().unwrap();
        eprintln!("big roundtrip ok: {} sum {}", back.len(), back.iter().sum::<f32>());
        assert_eq!(back, big);
    }

}
