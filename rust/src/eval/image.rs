//! PPM image emission for the paper's visual figures (6, 10, 11, 12).

use std::io::Write;
use std::path::Path;

use anyhow::Result;

/// Write a grid of [-1,1] NHWC images as a binary PPM (P6).
pub fn write_grid_ppm(path: &Path, images: &[f32], n: usize, hw: usize, cols: usize) -> Result<()> {
    let rows = n.div_ceil(cols);
    let pad = 2;
    let w = cols * (hw + pad) + pad;
    let h = rows * (hw + pad) + pad;
    let mut buf = vec![30u8; w * h * 3];
    for i in 0..n {
        let gx = (i % cols) * (hw + pad) + pad;
        let gy = (i / cols) * (hw + pad) + pad;
        for y in 0..hw {
            for x in 0..hw {
                for c in 0..3 {
                    let v = images[(i * hw * hw + y * hw + x) * 3 + c];
                    let b = (((v + 1.0) * 0.5).clamp(0.0, 1.0) * 255.0) as u8;
                    buf[((gy + y) * w + gx + x) * 3 + c] = b;
                }
            }
        }
    }
    if let Some(dir) = path.parent() {
        std::fs::create_dir_all(dir)?;
    }
    let mut f = std::fs::File::create(path)?;
    write!(f, "P6\n{w} {h}\n255\n")?;
    f.write_all(&buf)?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn writes_valid_ppm() {
        let path = std::env::temp_dir().join("msfp_grid_test.ppm");
        let images = vec![0.5f32; 4 * 8 * 8 * 3];
        write_grid_ppm(&path, &images, 4, 8, 2).unwrap();
        let bytes = std::fs::read(&path).unwrap();
        assert!(bytes.starts_with(b"P6\n22 22\n255\n"));
        assert_eq!(bytes.len(), b"P6\n22 22\n255\n".len() + 22 * 22 * 3);
    }

    #[test]
    fn clamps_out_of_range() {
        let path = std::env::temp_dir().join("msfp_grid_test2.ppm");
        let images = vec![99.0f32; 1 * 4 * 4 * 3];
        write_grid_ppm(&path, &images, 1, 4, 1).unwrap(); // must not panic
    }
}
