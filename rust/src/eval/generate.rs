//! Sample generation: drive a sampler over the FP or quantized denoiser,
//! decoding latents to pixels for the LDM variants.

use anyhow::Result;

use crate::data::{Corpus, PatchAutoencoder};
use crate::model::manifest::ModelInfo;
use crate::runtime::{Denoiser, QuantState};
use crate::schedule::{DdimSampler, DpmSolver2, PlmsSampler, Sampler, Schedule};
use crate::util::rng::Rng;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SamplerKind {
    Ddim,
    Plms,
    DpmSolver2,
}

impl SamplerKind {
    pub fn parse(s: &str) -> Option<SamplerKind> {
        Some(match s {
            "ddim" => SamplerKind::Ddim,
            "plms" => SamplerKind::Plms,
            "dpm-solver" | "dpm" => SamplerKind::DpmSolver2,
            _ => return None,
        })
    }
}

#[derive(Clone)]
pub enum ModelMode<'a> {
    Fp,
    Quant(&'a QuantState),
}

#[derive(Debug, Clone)]
pub struct GenerateCfg {
    pub n: usize,
    pub steps: usize,
    pub eta: f32,
    pub sampler: SamplerKind,
    pub seed: u64,
}

impl Default for GenerateCfg {
    fn default() -> Self {
        GenerateCfg { n: 64, steps: 100, eta: 0.0, sampler: SamplerKind::Ddim, seed: 0 }
    }
}

fn make_sampler(
    kind: SamplerKind,
    sched: &Schedule,
    tau: Vec<usize>,
    eta: f32,
) -> Box<dyn Sampler> {
    let s = std::sync::Arc::new(sched.clone());
    match kind {
        SamplerKind::Ddim => Box::new(DdimSampler::new(s, tau, eta)),
        SamplerKind::Plms => Box::new(PlmsSampler::new(s, tau)),
        SamplerKind::DpmSolver2 => Box::new(DpmSolver2::new(s, tau)),
    }
}

/// Generate n images (pixels in [-1,1], corpus resolution) plus their class
/// labels. Batches in lockstep: all samples share the sampler state, so the
/// quantized path's per-timestep routing is exercised exactly as in
/// serving.
pub fn generate_images(
    den: &Denoiser,
    info: &ModelInfo,
    sched: &Schedule,
    corpus: Corpus,
    params: &[f32],
    mode: ModelMode<'_>,
    cfg: &GenerateCfg,
) -> Result<(Vec<f32>, Vec<f32>)> {
    let tau = crate::schedule::timestep_subsequence(sched.t_total, cfg.steps);
    let mut rng = Rng::new(cfg.seed ^ 0x67656e);
    let xs = info.x_size(1);
    let n = cfg.n;
    let n_classes = info.cfg.n_classes;
    let cond: Vec<f32> =
        (0..n).map(|_| if n_classes > 0 { rng.below(n_classes) as f32 } else { 0.0 }).collect();
    let mut x: Vec<f32> = (0..n * xs).map(|_| rng.normal()).collect();
    let mut sampler = make_sampler(cfg.sampler, sched, tau, cfg.eta);
    let chunk = match mode {
        ModelMode::Fp => *info.batches_fp.iter().max().unwrap(),
        ModelMode::Quant(_) => den.max_batch_q(),
    };

    while !sampler.done() {
        let t = sampler.current_t();
        let mut eps = Vec::with_capacity(n * xs);
        let mut i = 0;
        while i < n {
            let m = chunk.min(n - i);
            let e = match &mode {
                ModelMode::Fp => {
                    let tb = vec![t; m];
                    den.eps_fp(params, &x[i * xs..(i + m) * xs], &tb, &cond[i..i + m])?
                }
                ModelMode::Quant(qs) => den.eps_q(
                    params,
                    qs,
                    &x[i * xs..(i + m) * xs],
                    t,
                    &cond[i..i + m],
                    &mut rng,
                )?,
            };
            eps.extend(e);
            i += m;
        }
        sampler.observe(&mut x, &eps, &mut rng);
    }

    // decode latents for LDM variants
    let px = if corpus.hw() == info.cfg.img_hw {
        x
    } else {
        PatchAutoencoder::default().decode_batch(&x, n)
    };
    Ok((px, cond))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::manifest::Manifest;
    use crate::model::ParamStore;
    use crate::runtime::Engine;
    use std::path::PathBuf;
    use std::sync::Arc;

    #[test]
    fn generates_fp_images() {
        let d = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if !d.join("manifest.json").exists() {
            crate::log_warn!("skipping: artifacts not built");
            return;
        }
        let m = Manifest::load(&d).unwrap();
        let info = m.model("ddim16").unwrap();
        let engine = Arc::new(Engine::new(&d).unwrap());
        let den = Denoiser::new(engine, info).unwrap();
        let params = ParamStore::load_init(info, &d).unwrap();
        let cfg = GenerateCfg { n: 5, steps: 4, ..Default::default() };
        let (px, cond) = generate_images(
            &den, info, &Schedule::linear(100), Corpus::CifarSyn, &params.flat,
            ModelMode::Fp, &cfg,
        )
        .unwrap();
        assert_eq!(px.len(), 5 * 16 * 16 * 3);
        assert_eq!(cond.len(), 5);
        assert!(px.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn sampler_kind_parse() {
        assert_eq!(SamplerKind::parse("ddim"), Some(SamplerKind::Ddim));
        assert_eq!(SamplerKind::parse("plms"), Some(SamplerKind::Plms));
        assert_eq!(SamplerKind::parse("dpm-solver"), Some(SamplerKind::DpmSolver2));
        assert_eq!(SamplerKind::parse("euler"), None);
    }
}
