//! Evaluation stack: the fixed-random-feature Frechet metrics (FID-syn /
//! sFID-syn), the projection-head Inception-Score proxy (IS-syn), and the
//! generation loop that produces samples from FP or quantized models.
//!
//! These are proxy metrics (DESIGN.md §2): the paper's claims we reproduce
//! are *orderings and gaps* between methods, not absolute values.

pub mod features;
pub mod metrics;
pub mod generate;
pub mod image;

pub use features::FeatureExtractor;
pub use generate::{generate_images, GenerateCfg, ModelMode};
pub use metrics::{evaluate, reference_stats, EvalResult, RefStats};
