//! Experiment report emission: CSV series + aligned-text tables, written
//! under <runs>/reports so EXPERIMENTS.md can cite stable files.

use std::fmt::Write as _;
use std::path::{Path, PathBuf};

use anyhow::Result;

pub struct Report {
    pub dir: PathBuf,
}

impl Report {
    pub fn new(runs_dir: &Path) -> Result<Report> {
        let dir = runs_dir.join("reports");
        std::fs::create_dir_all(&dir)?;
        Ok(Report { dir })
    }

    /// Write a CSV file (header + rows).
    pub fn csv(&self, name: &str, header: &[&str], rows: &[Vec<String>]) -> Result<PathBuf> {
        let path = self.dir.join(name);
        let mut out = String::new();
        out.push_str(&header.join(","));
        out.push('\n');
        for row in rows {
            out.push_str(&row.join(","));
            out.push('\n');
        }
        std::fs::write(&path, out)?;
        Ok(path)
    }

    /// Render + print + persist an aligned table.
    pub fn table(&self, name: &str, title: &str, header: &[&str], rows: &[Vec<String>]) -> Result<()> {
        let mut widths: Vec<usize> = header.iter().map(|h| h.len()).collect();
        for row in rows {
            for (i, cell) in row.iter().enumerate() {
                widths[i] = widths[i].max(cell.len());
            }
        }
        let mut s = String::new();
        let _ = writeln!(s, "\n== {title} ==");
        let line = |cells: &[String], widths: &[usize]| {
            cells
                .iter()
                .zip(widths)
                .map(|(c, w)| format!("{c:<w$}"))
                .collect::<Vec<_>>()
                .join("  ")
        };
        let _ = writeln!(s, "{}", line(&header.iter().map(|h| h.to_string()).collect::<Vec<_>>(), &widths));
        let _ = writeln!(s, "{}", widths.iter().map(|w| "-".repeat(*w)).collect::<Vec<_>>().join("  "));
        for row in rows {
            let _ = writeln!(s, "{}", line(row, &widths));
        }
        print!("{s}");
        let path = self.dir.join(format!("{name}.txt"));
        std::fs::write(path, s)?;
        self.csv(
            &format!("{name}.csv"),
            header,
            rows,
        )?;
        Ok(())
    }
}

pub fn f(v: f32) -> String {
    format!("{v:.2}")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn writes_csv_and_table() {
        let tmp = std::env::temp_dir().join("msfp_report_test");
        let r = Report::new(&tmp).unwrap();
        let rows = vec![
            vec!["FP".into(), "32/32".into(), "4.26".into()],
            vec!["Ours".into(), "4/4".into(), "6.02".into()],
        ];
        r.table("t_test", "Test table", &["Method", "Bits", "FID"], &rows).unwrap();
        let csv = std::fs::read_to_string(tmp.join("reports/t_test.csv")).unwrap();
        assert!(csv.starts_with("Method,Bits,FID\n"));
        assert!(csv.contains("Ours,4/4,6.02"));
        let txt = std::fs::read_to_string(tmp.join("reports/t_test.txt")).unwrap();
        assert!(txt.contains("== Test table =="));
    }
}
