//! Table runners: regenerate the *shape* of every table in the paper's
//! evaluation on the synthetic substrate (DESIGN.md §5 experiment index).
//!
//! Absolute numbers differ from the paper (proxy metrics, tiny models);
//! what must hold is who wins, roughly by how much, and where methods fail.

use anyhow::Result;

use crate::config::MethodSpec;
use crate::data::Corpus;
use crate::eval::generate::SamplerKind;
use crate::eval::EvalResult;
use crate::lora::hub::AllocStrategy;
use crate::pipeline::Pipeline;
use crate::quant::format::{weight_formats, weight_maxval_space};
use crate::quant::msfp::Method;
use crate::train::FinetuneCfg;

use super::report::{f, Report};

pub struct TableRow {
    pub method: String,
    pub bits: String,
    pub result: EvalResult,
}

fn eval_rows(
    pl: &Pipeline,
    corpus: Corpus,
    specs: &[(MethodSpec, &str)],
    sampler: SamplerKind,
    eta: f32,
) -> Result<Vec<TableRow>> {
    let p = pl.prepare(corpus)?;
    // one search session per prepared model: every quantized row re-scores
    // against the same per-tensor engines (FP rows skip quantization)
    let session = pl.build_session(&p)?;
    let mut rows = Vec::new();
    for (spec, bits) in specs {
        let (result, _) = pl.evaluate_spec_with_session(&p, &session, spec, sampler, eta, 42)?;
        rows.push(TableRow { method: spec.label.clone(), bits: bits.to_string(), result });
    }
    Ok(rows)
}

fn emit(report: &Report, name: &str, title: &str, rows: &[TableRow], with_sfid: bool) -> Result<()> {
    let header: Vec<&str> = if with_sfid {
        vec!["Method", "Bits (W/A)", "sFID-syn", "FID-syn", "IS-syn"]
    } else {
        vec!["Method", "Bits (W/A)", "FID-syn", "IS-syn"]
    };
    let body: Vec<Vec<String>> = rows
        .iter()
        .map(|r| {
            if with_sfid {
                vec![r.method.clone(), r.bits.clone(), f(r.result.sfid), f(r.result.fid), f(r.result.is)]
            } else {
                vec![r.method.clone(), r.bits.clone(), f(r.result.fid), f(r.result.is)]
            }
        })
        .collect();
    report.table(name, title, &header, &body)
}

/// Table 1: LoRA count/allocation strategies (single / dual-split /
/// dual-random), 4/4 on celeba-syn.
pub fn table1(pl: &Pipeline, report: &Report) -> Result<Vec<TableRow>> {
    let e = pl.scale.ft_epochs;
    let mk = |label: &str, alloc: AllocStrategy, h: usize| MethodSpec {
        label: label.into(),
        method: Some(Method::Msfp),
        wbits: 4,
        abits: 4,
        finetune: Some(FinetuneCfg { epochs: e, h, dfa: false, ..Default::default() }),
        alloc,
        partial: false,
    };
    let specs = vec![
        (MethodSpec::fp(), "32/32"),
        (mk("Single-LoRA", AllocStrategy::Single, 1), "4/4"),
        (mk("Dual-LoRA (Split Steps in Half)", AllocStrategy::DualSplit, 2), "4/4"),
        (mk("Dual-LoRA (Random Allocation)", AllocStrategy::DualRandom, 2), "4/4"),
    ];
    let rows = eval_rows(pl, Corpus::CelebaSyn, &specs, SamplerKind::Ddim, 0.0)?;
    emit(report, "table1", "Table 1: LoRA allocation strategies (celeba-syn, W4A4)", &rows, false)?;
    Ok(rows)
}

/// Table 2: unconditional generation across corpora, methods x bits.
pub fn table2(pl: &Pipeline, report: &Report, corpora: &[Corpus]) -> Result<Vec<TableRow>> {
    let e = pl.scale.ft_epochs;
    let mut all = Vec::new();
    for &corpus in corpora {
        let eta = if corpus == Corpus::BedroomSyn { 1.0 } else { 0.0 };
        let mut specs = vec![(MethodSpec::fp(), "32/32")];
        for bits in [6, 4] {
            let b = if bits == 6 { "6/6" } else { "4/4" };
            specs.push((MethodSpec::qdiffusion_like(bits), b));
            specs.push((MethodSpec::eda_dm_like(bits), b));
            specs.push((MethodSpec::efficientdm_like(bits, e), b));
            specs.push((MethodSpec::ours(bits, 2, e), b));
            specs.push((MethodSpec::ours(bits, 4, e), b));
        }
        let rows = eval_rows(pl, corpus, &specs, SamplerKind::Ddim, eta)?;
        emit(
            report,
            &format!("table2_{}", corpus.name()),
            &format!("Table 2: unconditional generation on {}", corpus.name()),
            &rows,
            false,
        )?;
        all.extend(rows);
    }
    Ok(all)
}

/// Table 3: conditional generation (imagenet-syn, 20 steps, sFID/FID/IS).
pub fn table3(pl: &Pipeline, report: &Report) -> Result<Vec<TableRow>> {
    let e = pl.scale.ft_epochs;
    let mut specs = vec![(MethodSpec::fp(), "32/32")];
    for bits in [6, 4] {
        let b = if bits == 6 { "6/6" } else { "4/4" };
        specs.push((MethodSpec::eda_dm_like(bits), b));
        specs.push((MethodSpec::quest_like(bits, e), b));
        specs.push((MethodSpec::efficientdm_like(bits, e), b));
        specs.push((MethodSpec::ours(bits, 2, e), b));
        specs.push((MethodSpec::ours(bits, 4, e), b));
    }
    let rows = eval_rows(pl, Corpus::ImagenetSyn, &specs, SamplerKind::Ddim, 0.0)?;
    emit(report, "table3", "Table 3: conditional generation (imagenet-syn, 20 steps)", &rows, true)?;
    Ok(rows)
}

/// Table 4: ablation over {MSFP, TALoRA, DFA} on celeba-syn 4/4.
pub fn table4(pl: &Pipeline, report: &Report) -> Result<Vec<TableRow>> {
    let e = pl.scale.ft_epochs;
    let mk = |label: &str, msfp: bool, talora: bool, dfa: bool| MethodSpec {
        label: label.into(),
        method: Some(if msfp { Method::Msfp } else { Method::SignedFp }),
        wbits: 4,
        abits: 4,
        finetune: Some(FinetuneCfg {
            epochs: e,
            h: if talora { 2 } else { 1 },
            dfa,
            ..Default::default()
        }),
        alloc: if talora { AllocStrategy::Learned } else { AllocStrategy::Single },
        partial: false,
    };
    let specs = vec![
        (mk("baseline (signed FP + single LoRA)", false, false, false), "4/4"),
        (mk("+MSFP", true, false, false), "4/4"),
        (mk("+TALoRA", false, true, false), "4/4"),
        (mk("+MSFP +DFA", true, false, true), "4/4"),
        (mk("+MSFP +TALoRA", true, true, false), "4/4"),
        (mk("+MSFP +TALoRA +DFA (full)", true, true, true), "4/4"),
    ];
    let rows = eval_rows(pl, Corpus::CelebaSyn, &specs, SamplerKind::Ddim, 0.0)?;
    emit(report, "table4", "Table 4: ablation (celeba-syn, W4A4, h=2)", &rows, false)?;
    Ok(rows)
}

/// Table 5: weight maxval search-space sweep (6/32 on celeba-syn).
/// PTQ-quality proxy: mean weight-MSE of the searched quantizers plus the
/// end FID of a weights-only-quantized model.
pub fn table5(pl: &Pipeline, report: &Report) -> Result<()> {
    let p = pl.prepare(Corpus::CelebaSyn)?;
    // one session: every (lo, hi) sweep point re-scores against the same
    // per-tensor engines instead of re-sorting the whole model per point
    let session = pl.build_session(&p)?;
    let spaces: Vec<(String, Option<(f32, f32)>)> = vec![
        ("[0, maxval_0]".into(), Some((0.0001, 1.0))),
        ("[0, 2 maxval_0]".into(), Some((0.0001, 2.0))),
        ("[0.6, 2.0] maxval_0".into(), Some((0.6, 2.0))),
        ("[0.7, 2.0] maxval_0".into(), Some((0.7, 2.0))),
        ("[0.8, 2.0] maxval_0".into(), Some((0.8, 2.0))),
        ("[0.9, 2.0] maxval_0".into(), Some((0.9, 2.0))),
        ("[1.0, 2.0] maxval_0".into(), Some((1.0, 2.0))),
    ];
    let mut rows = Vec::new();
    for (label, space) in spaces {
        let mut opts = crate::quant::msfp::QuantOpts::new(Method::Msfp, p.info.n_layers, 6, 8);
        opts.weight_space = space;
        let scheme = session.quantize(&opts);
        if scheme.layers.is_empty() {
            // zero-layer manifest: an explicit error row beats a NaN mean
            rows.push(vec![label, "6/32".to_string(), "error: no quantized layers".to_string()]);
            continue;
        }
        let w_mse: f64 = scheme.layers.iter().map(|l| l.w_mse).sum::<f64>()
            / scheme.layers.len() as f64;
        rows.push(vec![label, "6/32".to_string(), format!("{w_mse:.3e}")]);
    }
    report.table(
        "table5",
        "Table 5: weight maxval search spaces (celeba-syn, W6, weight-MSE proxy)",
        &["Search Space", "Bits (W/A)", "mean weight MSE"],
        &rows,
    )
}

/// Table 6: echo the format/maxval search spaces (configuration table).
pub fn table6(report: &Report) -> Result<()> {
    let rows: Vec<Vec<String>> = [4, 6, 8]
        .iter()
        .map(|&bits| {
            let (lo, hi) = weight_maxval_space(bits);
            vec![
                bits.to_string(),
                format!("[{lo}·maxval_0, {hi}·maxval_0]"),
                weight_formats(bits).iter().map(|f| f.to_string()).collect::<Vec<_>>().join(" "),
            ]
        })
        .collect();
    report.table(
        "table6",
        "Table 6: weight-initialization search spaces",
        &["Bit", "Search Space (maxval)", "Search Space (format)"],
        &rows,
    )
}

/// Table 7: PTQ-only FP (MSFP, no fine-tuning) vs INT baselines, 6/6.
pub fn table7(pl: &Pipeline, report: &Report) -> Result<Vec<TableRow>> {
    let mk_ptq = |label: &str, m: Method| MethodSpec {
        label: label.into(),
        method: Some(m),
        wbits: 6,
        abits: 6,
        finetune: None,
        alloc: AllocStrategy::Single,
        partial: false,
    };
    let specs = vec![
        (MethodSpec::fp(), "32/32"),
        (mk_ptq("LSQ-like (minmax INT)", Method::IntMinMax), "6/6"),
        (mk_ptq("PTQ4DM/Q-Diffusion-like (MSE INT)", Method::IntMse), "6/6"),
        (mk_ptq("Ours (MSFP, no fine-tuning)", Method::Msfp), "6/6"),
    ];
    let rows = eval_rows(pl, Corpus::CelebaSyn, &specs, SamplerKind::Ddim, 0.0)?;
    emit(report, "table7", "Table 7 / Appendix D: FP vs INT PTQ (celeba-syn, W6A6, no FT)", &rows, false)?;
    Ok(rows)
}

/// Table 8: TALoRA(h=2, rank r) vs rank-scaled single LoRA. Rank is baked
/// at AOT time, so the rank-scaled comparison runs single-LoRA with both
/// hub slots fused (equivalent parameter count) — the paper's point is
/// that timestep-awareness, not capacity, drives the win.
pub fn table8(pl: &Pipeline, report: &Report) -> Result<Vec<TableRow>> {
    let e = pl.scale.ft_epochs;
    let specs = vec![
        (MethodSpec::fp(), "32/32"),
        (
            MethodSpec {
                label: "single-LoRA (capacity-matched)".into(),
                method: Some(Method::Msfp),
                wbits: 4,
                abits: 4,
                finetune: Some(FinetuneCfg { epochs: 2 * e, h: 1, dfa: true, ..Default::default() }),
                alloc: AllocStrategy::Single,
                partial: false,
            },
            "4/4",
        ),
        (MethodSpec::ours(4, 2, e), "4/4"),
    ];
    let rows = eval_rows(pl, Corpus::CelebaSyn, &specs, SamplerKind::Ddim, 0.0)?;
    emit(report, "table8", "Table 8: TALoRA vs rank-scaled LoRA (celeba-syn, W4A4)", &rows, false)?;
    Ok(rows)
}

/// Table 9: celeba-syn full comparison at 4/6 bits.
pub fn table9(pl: &Pipeline, report: &Report) -> Result<Vec<TableRow>> {
    let e = pl.scale.ft_epochs;
    let mut specs = vec![(MethodSpec::fp(), "32/32")];
    for bits in [6, 4] {
        let b = if bits == 6 { "6/6" } else { "4/4" };
        specs.push((MethodSpec::qdiffusion_like(bits), b));
        specs.push((MethodSpec::ours(bits, 2, e), b));
        specs.push((MethodSpec::ours(bits, 4, e), b));
    }
    let rows = eval_rows(pl, Corpus::CelebaSyn, &specs, SamplerKind::Ddim, 0.0)?;
    emit(report, "table9", "Table 9: celeba-syn 4/6-bit", &rows, false)?;
    Ok(rows)
}

/// Table 10: PLMS and DPM-Solver samplers on imagenet-syn.
pub fn table10(pl: &Pipeline, report: &Report) -> Result<Vec<TableRow>> {
    let e = pl.scale.ft_epochs;
    let mut all = Vec::new();
    for (sampler, name) in [(SamplerKind::Plms, "PLMS"), (SamplerKind::DpmSolver2, "DPM-Solver")] {
        let specs = vec![
            (MethodSpec::fp(), "32/32"),
            (MethodSpec::eda_dm_like(4), "4/4"),
            (MethodSpec::efficientdm_like(4, e), "4/4"),
            (MethodSpec::ours(4, 2, e), "4/4"),
            (MethodSpec::ours(6, 2, e), "6/6"),
        ];
        let rows = eval_rows(pl, Corpus::ImagenetSyn, &specs, sampler, 0.0)?;
        emit(
            report,
            &format!("table10_{}", name.to_lowercase().replace('-', "_")),
            &format!("Table 10: {name} sampler (imagenet-syn, 20 steps)"),
            &rows,
            true,
        )?;
        all.extend(rows);
    }
    Ok(all)
}

/// Table 11: partial vs full quantization (church-syn stand-in on ldm8).
pub fn table11(pl: &Pipeline, report: &Report) -> Result<Vec<TableRow>> {
    let e = pl.scale.ft_epochs;
    let mut partial_eff = MethodSpec::efficientdm_like(4, e);
    partial_eff.partial = true;
    partial_eff.label = "EfficientDM-like (partial quant)".into();
    let mut partial_ours = MethodSpec::ours(4, 2, e);
    partial_ours.partial = true;
    partial_ours.label = "Ours h=2 (partial quant)".into();
    let specs = vec![
        (MethodSpec::fp(), "32/32"),
        (partial_eff, "4/4"),
        (partial_ours, "4/4"),
        (MethodSpec::efficientdm_like(4, e), "4/4"),
        (MethodSpec::ours(4, 2, e), "4/4"),
    ];
    let rows = eval_rows(pl, Corpus::ChurchSyn, &specs, SamplerKind::Ddim, 0.0)?;
    emit(report, "table11", "Table 11: partial vs full quantization (church-syn)", &rows, false)?;
    Ok(rows)
}

/// Scale-aware convenience: run one table id.
pub fn run_table(pl: &Pipeline, report: &Report, id: &str) -> Result<()> {
    match id {
        "t1" => table1(pl, report).map(|_| ()),
        "t2" => table2(pl, report, &[Corpus::CifarSyn, Corpus::BedroomSyn, Corpus::ChurchSyn])
            .map(|_| ()),
        "t2-fast" => table2(pl, report, &[Corpus::CifarSyn]).map(|_| ()),
        "t3" => table3(pl, report).map(|_| ()),
        "t4" => table4(pl, report).map(|_| ()),
        "t5" => table5(pl, report),
        "t6" => table6(report),
        "t7" => table7(pl, report).map(|_| ()),
        "t8" => table8(pl, report).map(|_| ()),
        "t9" => table9(pl, report).map(|_| ()),
        "t10" => table10(pl, report).map(|_| ()),
        "t11" => table11(pl, report).map(|_| ()),
        _ => anyhow::bail!("unknown table id '{id}'"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table6_is_pure_config() {
        let tmp = std::env::temp_dir().join("msfp_t6_test");
        let report = Report::new(&tmp).unwrap();
        table6(&report).unwrap();
        let txt = std::fs::read_to_string(tmp.join("reports/table6.txt")).unwrap();
        assert!(txt.contains("E3M0 E2M1 E1M2 E0M3"));
        assert!(txt.contains("0.8"));
    }

    #[test]
    fn unknown_table_errors() {
        // can't build a Pipeline without artifacts; validate the id check
        // via the error path only when artifacts exist
        let dir = Pipeline::default_artifacts_dir();
        if !dir.join("manifest.json").exists() {
            return;
        }
        let pl = Pipeline::new(&dir, crate::config::Scale::fast()).unwrap();
        let tmp = std::env::temp_dir().join("msfp_tbl_err");
        let report = Report::new(&tmp).unwrap();
        assert!(run_table(&pl, &report, "t99").is_err());
    }
}
