//! The experiment harness: per-table and per-figure runners (DESIGN.md §5)
//! plus report emission. `msfp repro --exp <id>` and the benches drive
//! these.

pub mod report;
pub mod tables;
pub mod figures;

pub use report::Report;
