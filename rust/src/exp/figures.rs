//! Figure runners: regenerate the data series behind every figure in the
//! paper (CSV + printed summaries; sample grids as PPM).

use anyhow::Result;

use crate::config::MethodSpec;
use crate::data::Corpus;
use crate::eval::generate::SamplerKind;
use crate::eval::image::write_grid_ppm;
use crate::eval::{generate_images, GenerateCfg, ModelMode};
use crate::pipeline::{Pipeline, Prepared};
use crate::quant::classify::LayerClass;
use crate::quant::format::act_signed_formats;
use crate::quant::search::{fig4_strategies_on, linspace, search_signed_on};
use crate::schedule::Sampler;

use super::report::Report;

fn histogram(xs: &[f32], bins: usize) -> (Vec<f32>, Vec<usize>) {
    let lo = xs.iter().cloned().fold(f32::INFINITY, f32::min);
    let hi = xs.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    let w = ((hi - lo) / bins as f32).max(1e-9);
    let mut counts = vec![0usize; bins];
    for &x in xs {
        let b = (((x - lo) / w) as usize).min(bins - 1);
        counts[b] += 1;
    }
    let centers = (0..bins).map(|i| lo + w * (i as f32 + 0.5)).collect();
    (centers, counts)
}

/// Figure 1: activation distributions of an NAL and two AALs.
pub fn fig1(pl: &Pipeline, report: &Report, p: &Prepared) -> Result<()> {
    let calib = pl.calibrate(p)?;
    let pick = |class: LayerClass, skip: usize| {
        calib
            .iter()
            .filter(move |c| {
                crate::quant::classify::classify(c.min, c.max) == class
            })
            .nth(skip)
    };
    let mut rows = Vec::new();
    for (tag, c) in [
        ("NAL", pick(LayerClass::Nal, 0)),
        ("AAL-b", pick(LayerClass::Aal, 0)),
        ("AAL-c", pick(LayerClass::Aal, 1)),
    ] {
        let Some(c) = c else { continue };
        let (centers, counts) = histogram(&c.acts, 48);
        for (x, n) in centers.iter().zip(&counts) {
            rows.push(vec![tag.to_string(), c.name.clone(), format!("{x:.4}"), n.to_string()]);
        }
        println!(
            "fig1 {tag}: layer {} min {:.3} max {:.3} (AAL trough at -0.278)",
            c.name, c.min, c.max
        );
    }
    report.csv("fig1_activation_histograms.csv", &["panel", "layer", "x", "count"], &rows)?;
    Ok(())
}

/// Figure 2: representation capacity (signed-FP search MSE) vs bit-width,
/// AALs vs NALs. One session engine per layer is shared across all six
/// bit-widths instead of re-sorting the samples per (layer, bits) pair.
pub fn fig2(pl: &Pipeline, report: &Report, p: &Prepared) -> Result<()> {
    let session = pl.build_session(p)?;
    let mut rows = Vec::new();
    for bits in 3..=8 {
        let mut aal = (0.0f64, 0usize);
        let mut nal = (0.0f64, 0usize);
        for (l, c) in session.calib().iter().enumerate() {
            let maxval0 = session.act_maxval0(l);
            let r = search_signed_on(
                session.act_engine(l),
                &act_signed_formats(bits),
                &linspace(maxval0 / 50.0, maxval0, 50),
                1,
            )
            .expect("signed search space is non-empty");
            // normalize by signal power so layers are comparable
            let power: f64 = c.acts.iter().map(|&x| (x as f64) * (x as f64)).sum::<f64>()
                / c.acts.len() as f64;
            let nmse = r.mse / power.max(1e-18);
            match session.class(l) {
                LayerClass::Aal => {
                    aal.0 += nmse;
                    aal.1 += 1;
                }
                LayerClass::Nal => {
                    nal.0 += nmse;
                    nal.1 += 1;
                }
            }
        }
        let aal_m = aal.0 / aal.1.max(1) as f64;
        let nal_m = nal.0 / nal.1.max(1) as f64;
        println!("fig2 bits={bits}: AAL nMSE {aal_m:.3e}  NAL nMSE {nal_m:.3e}  ratio {:.1}x", aal_m / nal_m.max(1e-18));
        rows.push(vec![bits.to_string(), format!("{aal_m:.6e}"), format!("{nal_m:.6e}")]);
    }
    report.csv("fig2_bitwidth_capacity.csv", &["bits", "aal_nmse", "nal_nmse"], &rows)?;
    Ok(())
}

/// Figure 3: fine-tune loss vs the actual per-step performance gap, with
/// and without DFA alignment.
pub fn fig3(pl: &Pipeline, report: &Report, p: &Prepared) -> Result<()> {
    let session = pl.build_session(p)?;
    let spec = MethodSpec::ours(4, 2, pl.scale.ft_epochs);
    let q = pl.quantize_with_session(p, &session, &spec)?;
    let stats = q.ft_stats.as_ref().unwrap();
    // actual gap: MSE(x_{t-1}^fp, x_{t-1}^q) along a shared FP trajectory
    let tau = crate::schedule::timestep_subsequence(pl.sched.t_total, pl.scale.steps);
    let mut rng = crate::util::rng::Rng::new(77);
    let n = 4usize;
    let traj = crate::train::TrajectoryBuffer::collect(
        &p.den, &p.info, &pl.sched, &tau, &p.params, n, p.info.cfg.n_classes, &mut rng,
    )?;
    let mut rows = Vec::new();
    for (i, &t) in tau.iter().enumerate() {
        let x_t = &traj.x[i];
        let eps_fp = &traj.eps[i];
        let eps_q =
            p.den.eps_q(&p.params, &q.state, x_t, t as f32, &traj.cond, &mut rng)?;
        // one DDIM step under both eps
        let mut sampler_fp = crate::schedule::DdimSampler::new(
            std::sync::Arc::new(pl.sched.clone()),
            tau[i..].to_vec(),
            0.0,
        );
        let mut sampler_q = crate::schedule::DdimSampler::new(
            std::sync::Arc::new(pl.sched.clone()),
            tau[i..].to_vec(),
            0.0,
        );
        let mut xf = x_t.clone();
        let mut xq = x_t.clone();
        sampler_fp.observe(&mut xf, eps_fp, &mut rng);
        sampler_q.observe(&mut xq, &eps_q, &mut rng);
        let gap: f32 =
            xf.iter().zip(&xq).map(|(a, b)| (a - b).powi(2)).sum::<f32>() / xf.len() as f32;
        let raw_loss = stats.loss_by_step[i];
        let gamma = pl.sched.gamma(t);
        println!(
            "fig3 t={t:3}: raw eps-loss {raw_loss:.3e}  gamma {gamma:.3}  aligned {:.3e}  actual gap {gap:.3e}",
            raw_loss * gamma
        );
        rows.push(vec![
            t.to_string(),
            format!("{raw_loss:.6e}"),
            format!("{:.6e}", raw_loss * gamma),
            format!("{gap:.6e}"),
        ]);
    }
    report.csv("fig3_loss_alignment.csv", &["t", "raw_loss", "dfa_aligned_loss", "actual_gap"], &rows)?;
    Ok(())
}

/// Figure 4: per-AAL activation MSE under the four quantizer strategies,
/// normalized to plain signed FP. Strategies borrow the session's
/// per-layer engines (one sort per layer, shared by all four).
pub fn fig4(pl: &Pipeline, report: &Report, p: &Prepared, bits: i32) -> Result<(usize, usize)> {
    let session = pl.build_session(p)?;
    let mut improved = 0;
    let mut n_aal = 0;
    let mut rows = Vec::new();
    for (l, c) in session.calib().iter().enumerate() {
        if session.class(l) != LayerClass::Aal {
            continue;
        }
        n_aal += 1;
        let [s, szp, u, uzp] =
            fig4_strategies_on(session.act_engine(l), bits, session.act_maxval0(l), 25);
        if uzp < 1.0 {
            improved += 1;
        }
        rows.push(vec![
            c.name.clone(),
            format!("{s:.4}"),
            format!("{szp:.4}"),
            format!("{u:.4}"),
            format!("{uzp:.4}"),
        ]);
    }
    report.csv(
        "fig4_strategies.csv",
        &["layer", "signed", "signed_zp", "unsigned", "unsigned_zp"],
        &rows,
    )?;
    println!(
        "fig4: unsigned+zp improves {improved}/{n_aal} AALs ({:.0}%) at {bits} bits (paper: >95%)",
        100.0 * improved as f32 / n_aal.max(1) as f32
    );
    Ok((improved, n_aal))
}

/// Figure 6 (and 10/11): sample grids at FP / 6-bit / 4-bit.
pub fn fig6(pl: &Pipeline, report: &Report, p: &Prepared) -> Result<()> {
    // one session: the 6- and 4-bit grids re-score the same engines
    let session = pl.build_session(p)?;
    let n = 16;
    let cfg = GenerateCfg { n, steps: pl.scale.steps, eta: 0.0, sampler: SamplerKind::Ddim, seed: 5 };
    let (fp_px, _) = generate_images(
        &p.den, &p.info, &pl.sched, p.corpus, &p.params, ModelMode::Fp, &cfg,
    )?;
    write_grid_ppm(&report.dir.join("fig6_fp32.ppm"), &fp_px, n, p.corpus.hw(), 4)?;
    for bits in [6, 4] {
        let spec = MethodSpec::ours(bits, 2, pl.scale.ft_epochs);
        let q = pl.quantize_with_session(p, &session, &spec)?;
        let (px, _) = generate_images(
            &p.den, &p.info, &pl.sched, p.corpus, &p.params, ModelMode::Quant(&q.state), &cfg,
        )?;
        write_grid_ppm(&report.dir.join(format!("fig6_w{bits}a{bits}.ppm")), &px, n, p.corpus.hw(), 4)?;
    }
    println!("fig6: grids written to {}", report.dir.display());
    Ok(())
}

/// Figures 7 & 9: router LoRA-allocation distribution over timesteps.
pub fn fig7_9(pl: &Pipeline, report: &Report, p: &Prepared, h: usize) -> Result<Vec<Vec<f32>>> {
    let session = pl.build_session(p)?;
    let spec = MethodSpec::ours(4, h, pl.scale.ft_epochs);
    let q = pl.quantize_with_session(p, &session, &spec)?;
    let dist = q.state.router.allocation_distribution(pl.sched.t_total, &q.state.hub_mask);
    let mut rows = Vec::new();
    for (t, hist) in dist.iter().enumerate() {
        let mut row = vec![t.to_string()];
        row.extend(hist.iter().map(|v| format!("{v:.4}")));
        rows.push(row);
    }
    let header: Vec<String> = std::iter::once("t".to_string())
        .chain((0..q.state.router.h).map(|i| format!("lora{i}")))
        .collect();
    let header_refs: Vec<&str> = header.iter().map(|s| s.as_str()).collect();
    report.csv(&format!("fig7_router_allocation_h{h}.csv"), &header_refs, &rows)?;
    // summary: dominant adapter per phase
    let early: f32 = dist[pl.sched.t_total / 2..].iter().map(|h| h[0]).sum::<f32>();
    let late: f32 = dist[..pl.sched.t_total / 2].iter().map(|h| h[0]).sum::<f32>();
    println!(
        "fig7 (h={h}): adapter-0 mass early(t>T/2)={:.2} late(t<T/2)={:.2} — structured allocation",
        early / (pl.sched.t_total / 2) as f32,
        late / (pl.sched.t_total / 2) as f32
    );
    Ok(dist)
}

/// Figure 8: weight distributions of representative layers.
pub fn fig8(_pl: &Pipeline, report: &Report, p: &Prepared) -> Result<()> {
    let store = crate::model::ParamStore::from_vec(&p.info, p.params.clone())?;
    let mut rows = Vec::new();
    for spec in p.info.layer_specs.iter().step_by(5) {
        let w = store.tensor(&p.info, &spec.param)?;
        let (centers, counts) = histogram(w, 40);
        for (x, n) in centers.iter().zip(&counts) {
            rows.push(vec![spec.name.clone(), format!("{x:.5}"), n.to_string()]);
        }
    }
    report.csv("fig8_weight_histograms.csv", &["layer", "x", "count"], &rows)?;
    println!("fig8: weight histograms written");
    Ok(())
}

pub fn run_figure(pl: &Pipeline, report: &Report, id: &str) -> Result<()> {
    let p = pl.prepare(Corpus::CelebaSyn)?;
    match id {
        "f1" => fig1(pl, report, &p),
        "f2" => fig2(pl, report, &p),
        "f3" => fig3(pl, report, &p),
        "f4" => fig4(pl, report, &p, 4).map(|_| ()),
        "f6" => fig6(pl, report, &p),
        "f7" => fig7_9(pl, report, &p, 2).map(|_| ()),
        "f9" => fig7_9(pl, report, &p, 4).map(|_| ()),
        "f8" => fig8(pl, report, &p),
        _ => anyhow::bail!("unknown figure id '{id}'"),
    }
}
