//! Small CLI argument parser (clap is unavailable offline).
//!
//! Grammar: `msfp <subcommand> [--flag] [--key value]... [positional]...`.
//! Typed accessors with defaults; unknown-flag detection happens in
//! `finish()` so commands list the flags they accept.

use std::collections::BTreeMap;

use anyhow::{bail, Result};

#[derive(Debug, Clone)]
pub struct Args {
    pub subcommand: Option<String>,
    pub flags: BTreeMap<String, String>,
    pub positional: Vec<String>,
    accessed: std::cell::RefCell<Vec<String>>,
}

impl Args {
    pub fn parse_env() -> Result<Args> {
        Self::parse(std::env::args().skip(1))
    }

    pub fn parse<I: IntoIterator<Item = String>>(items: I) -> Result<Args> {
        let mut it = items.into_iter().peekable();
        let mut subcommand = None;
        let mut flags = BTreeMap::new();
        let mut positional = Vec::new();
        if let Some(first) = it.peek() {
            if !first.starts_with('-') {
                subcommand = Some(it.next().unwrap());
            }
        }
        while let Some(a) = it.next() {
            if let Some(name) = a.strip_prefix("--") {
                if name.is_empty() {
                    bail!("bare '--' not supported");
                }
                if let Some((k, v)) = name.split_once('=') {
                    flags.insert(k.to_string(), v.to_string());
                } else if it.peek().map(|n| !n.starts_with("--")).unwrap_or(false) {
                    flags.insert(name.to_string(), it.next().unwrap());
                } else {
                    flags.insert(name.to_string(), "true".to_string());
                }
            } else {
                positional.push(a);
            }
        }
        Ok(Args { subcommand, flags, positional, accessed: Default::default() })
    }

    fn mark(&self, key: &str) {
        self.accessed.borrow_mut().push(key.to_string());
    }

    pub fn str(&self, key: &str, default: &str) -> String {
        self.mark(key);
        self.flags.get(key).cloned().unwrap_or_else(|| default.to_string())
    }

    pub fn opt_str(&self, key: &str) -> Option<String> {
        self.mark(key);
        self.flags.get(key).cloned()
    }

    pub fn usize(&self, key: &str, default: usize) -> Result<usize> {
        self.mark(key);
        match self.flags.get(key) {
            None => Ok(default),
            Some(v) => Ok(v.parse()?),
        }
    }

    pub fn u64(&self, key: &str, default: u64) -> Result<u64> {
        self.mark(key);
        match self.flags.get(key) {
            None => Ok(default),
            Some(v) => Ok(v.parse()?),
        }
    }

    pub fn f32(&self, key: &str, default: f32) -> Result<f32> {
        self.mark(key);
        match self.flags.get(key) {
            None => Ok(default),
            Some(v) => Ok(v.parse()?),
        }
    }

    pub fn bool(&self, key: &str) -> bool {
        self.mark(key);
        matches!(self.flags.get(key).map(|s| s.as_str()), Some("true") | Some("1") | Some("yes"))
    }

    /// Error on flags no accessor consumed (typo detection).
    pub fn finish(&self) -> Result<()> {
        let seen = self.accessed.borrow();
        for k in self.flags.keys() {
            if !seen.contains(k) {
                bail!("unknown flag --{k}");
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn args(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from)).unwrap()
    }

    #[test]
    fn parses_subcommand_and_flags() {
        // NOTE grammar: a bare boolean flag followed by a non-flag token
        // would consume it as a value, so positionals go first (or use
        // --flag=true). This is the documented convention for this CLI.
        let a = args("sample out.ppm --model ddim16 --steps 100 --fast");
        assert_eq!(a.subcommand.as_deref(), Some("sample"));
        assert_eq!(a.str("model", "x"), "ddim16");
        assert_eq!(a.usize("steps", 0).unwrap(), 100);
        assert!(a.bool("fast"));
        assert_eq!(a.positional, vec!["out.ppm"]);
    }

    #[test]
    fn equals_form() {
        let a = args("run --k=v --n=3");
        assert_eq!(a.str("k", ""), "v");
        assert_eq!(a.usize("n", 0).unwrap(), 3);
    }

    #[test]
    fn defaults() {
        let a = args("run");
        assert_eq!(a.str("missing", "d"), "d");
        assert_eq!(a.f32("lr", 0.1).unwrap(), 0.1);
        assert!(!a.bool("nope"));
    }

    #[test]
    fn unknown_flag_detected() {
        let a = args("run --known 1 --typo 2");
        a.usize("known", 0).unwrap();
        assert!(a.finish().is_err());
        a.usize("typo", 0).unwrap();
        assert!(a.finish().is_ok());
    }

    #[test]
    fn bad_number_errors() {
        let a = args("run --n abc");
        assert!(a.usize("n", 0).is_err());
    }
}
