//! Leveled stderr logging with wall-clock-since-start prefixes.

use std::sync::atomic::{AtomicU8, Ordering};
use std::time::Instant;

use once_cell::sync::Lazy;

static START: Lazy<Instant> = Lazy::new(Instant::now);
static LEVEL: AtomicU8 = AtomicU8::new(2); // 0=off 1=warn 2=info 3=debug

pub fn set_level(level: u8) {
    LEVEL.store(level, Ordering::Relaxed);
}

pub fn level() -> u8 {
    LEVEL.load(Ordering::Relaxed)
}

pub fn elapsed() -> f64 {
    START.elapsed().as_secs_f64()
}

#[macro_export]
macro_rules! log_info {
    ($($arg:tt)*) => {
        if $crate::util::logging::level() >= 2 {
            eprintln!("[{:8.2}s INFO] {}", $crate::util::logging::elapsed(), format!($($arg)*));
        }
    };
}

#[macro_export]
macro_rules! log_warn {
    ($($arg:tt)*) => {
        if $crate::util::logging::level() >= 1 {
            eprintln!("[{:8.2}s WARN] {}", $crate::util::logging::elapsed(), format!($($arg)*));
        }
    };
}

#[macro_export]
macro_rules! log_debug {
    ($($arg:tt)*) => {
        if $crate::util::logging::level() >= 3 {
            eprintln!("[{:8.2}s DBG ] {}", $crate::util::logging::elapsed(), format!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_toggles() {
        let old = level();
        set_level(3);
        assert_eq!(level(), 3);
        set_level(old);
    }

    #[test]
    fn elapsed_monotone() {
        let a = elapsed();
        let b = elapsed();
        assert!(b >= a);
    }
}
