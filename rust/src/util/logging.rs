//! Leveled stderr logging with wall-clock-since-start prefixes.
//!
//! The level initializes from `MSFP_LOG=off|warn|info|debug` (or `0..3`)
//! at first use and defaults to `info`; an unrecognized value warns once
//! on stderr and falls back to the default. [`set_level`] still overrides
//! at runtime (tests and the experiment harness use it).
//!
//! Tests assert on log output through [`capture`]: while the returned
//! guard lives, every emitted line is appended to its buffer *instead of*
//! stderr. The capture sink is process-global (tests run multithreaded —
//! a concurrent test's lines may land in the buffer too, so assert with
//! `contains`, not equality).

use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use once_cell::sync::Lazy;

static START: Lazy<Instant> = Lazy::new(Instant::now);
// 0=off 1=warn 2=info 3=debug
static LEVEL: Lazy<AtomicU8> = Lazy::new(|| AtomicU8::new(level_from_env()));

/// Parse one `MSFP_LOG` value; `None` for unrecognized input.
pub fn parse_level(v: &str) -> Option<u8> {
    match v.trim().to_ascii_lowercase().as_str() {
        "off" | "0" => Some(0),
        "warn" | "warning" | "1" => Some(1),
        "info" | "2" => Some(2),
        "debug" | "3" => Some(3),
        _ => None,
    }
}

fn level_from_env() -> u8 {
    match std::env::var("MSFP_LOG") {
        Ok(v) => parse_level(&v).unwrap_or_else(|| {
            // the sink may not exist yet — this warning goes straight to
            // stderr, once, before any leveled logging happens
            eprintln!("MSFP_LOG={v:?} not recognized (off|warn|info|debug); defaulting to info");
            2
        }),
        Err(_) => 2,
    }
}

pub fn set_level(level: u8) {
    LEVEL.store(level, Ordering::Relaxed);
}

pub fn level() -> u8 {
    LEVEL.load(Ordering::Relaxed)
}

pub fn elapsed() -> f64 {
    START.elapsed().as_secs_f64()
}

type Sink = Arc<Mutex<Vec<String>>>;

static SINK: Mutex<Option<Sink>> = Mutex::new(None);

/// Capture guard: collects emitted log lines while alive (see [`capture`]);
/// dropping it restores stderr emission.
pub struct LogCapture {
    buf: Sink,
}

impl LogCapture {
    /// Lines captured so far (formatted exactly as stderr would show them).
    pub fn lines(&self) -> Vec<String> {
        self.buf.lock().unwrap().clone()
    }

    /// Whether any captured line contains `needle`.
    pub fn contains(&self, needle: &str) -> bool {
        self.buf.lock().unwrap().iter().any(|l| l.contains(needle))
    }
}

impl Drop for LogCapture {
    fn drop(&mut self) {
        let mut sink = SINK.lock().unwrap();
        // only uninstall our own buffer — a later capture() owns the slot
        if sink.as_ref().is_some_and(|s| Arc::ptr_eq(s, &self.buf)) {
            *sink = None;
        }
    }
}

/// Install a capturing sink: until the returned guard drops, emitted log
/// lines go to its buffer instead of stderr. Installing a new capture
/// replaces the previous sink.
pub fn capture() -> LogCapture {
    let buf: Sink = Arc::new(Mutex::new(Vec::new()));
    *SINK.lock().unwrap() = Some(Arc::clone(&buf));
    LogCapture { buf }
}

/// Emission point shared by the `log_*` macros: format the line once,
/// then route it to the captured sink (if any) or stderr.
pub fn emit(tag: &str, msg: String) {
    let line = format!("[{:8.2}s {tag}] {msg}", elapsed());
    let sink = SINK.lock().unwrap().clone();
    match sink {
        Some(buf) => buf.lock().unwrap().push(line),
        None => eprintln!("{line}"),
    }
}

#[macro_export]
macro_rules! log_info {
    ($($arg:tt)*) => {
        if $crate::util::logging::level() >= 2 {
            $crate::util::logging::emit("INFO", format!($($arg)*));
        }
    };
}

#[macro_export]
macro_rules! log_warn {
    ($($arg:tt)*) => {
        if $crate::util::logging::level() >= 1 {
            $crate::util::logging::emit("WARN", format!($($arg)*));
        }
    };
}

#[macro_export]
macro_rules! log_debug {
    ($($arg:tt)*) => {
        if $crate::util::logging::level() >= 3 {
            $crate::util::logging::emit("DBG ", format!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_toggles() {
        let old = level();
        set_level(3);
        assert_eq!(level(), 3);
        set_level(old);
    }

    #[test]
    fn elapsed_monotone() {
        let a = elapsed();
        let b = elapsed();
        assert!(b >= a);
    }

    #[test]
    fn env_values_parse() {
        assert_eq!(parse_level("off"), Some(0));
        assert_eq!(parse_level("0"), Some(0));
        assert_eq!(parse_level("WARN"), Some(1));
        assert_eq!(parse_level("warning"), Some(1));
        assert_eq!(parse_level(" info "), Some(2));
        assert_eq!(parse_level("Debug"), Some(3));
        assert_eq!(parse_level("3"), Some(3));
        assert_eq!(parse_level("verbose"), None);
        assert_eq!(parse_level(""), None);
    }

    #[test]
    fn capture_collects_warns_and_restores_on_drop() {
        let old = level();
        set_level(2);
        let cap = capture();
        log_warn!("captured warning {}", 42);
        log_info!("captured info");
        log_debug!("below level — not emitted");
        assert!(cap.contains("captured warning 42"), "{:?}", cap.lines());
        assert!(cap.contains("INFO] captured info"), "{:?}", cap.lines());
        assert!(!cap.contains("not emitted"), "{:?}", cap.lines());
        // formatted exactly like the stderr line: "[  12.34s WARN] ..."
        let line = cap
            .lines()
            .into_iter()
            .find(|l| l.contains("captured warning"))
            .unwrap();
        assert!(line.starts_with('['), "{line}");
        assert!(line.contains("s WARN] "), "{line}");
        drop(cap);
        // a fresh capture starts empty (the old buffer was uninstalled)
        let cap = capture();
        assert!(!cap.contains("captured warning 42"));
        // level 0 suppresses even captured warns (same test to avoid
        // racing the global level against the assertions above)
        set_level(0);
        log_warn!("silenced");
        assert!(!cap.contains("silenced"), "{:?}", cap.lines());
        set_level(old);
    }
}
