//! Substrate utilities built in-repo (the offline image vendors only the
//! `xla` crate's closure — no serde/clap/tokio/criterion), each unit-tested:
//! JSON, RNG, tensor store, CLI parsing, thread pool, logging, and a
//! property-test mini-harness.

pub mod json;
pub mod rng;
pub mod io;
pub mod cli;
pub mod threadpool;
pub mod logging;
pub mod prop;
pub mod bench;
