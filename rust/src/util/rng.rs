//! PCG64-style deterministic RNG (offline image has no `rand` crate).
//!
//! Every stochastic path in the system (data synthesis, noise draws,
//! calibration shuffles, serving workloads) takes an explicit `Rng` so runs
//! are reproducible from a single seed recorded in EXPERIMENTS.md.

/// PCG-XSH-RR 64/32 with 128-bit-ish state emulated by two 64-bit LCGs
/// (splitmix-seeded). Not cryptographic; statistical quality is ample for
/// simulation workloads.
#[derive(Debug, Clone, PartialEq)]
pub struct Rng {
    state: u64,
    inc: u64,
    /// cached second normal from Box-Muller
    spare: Option<f32>,
}

/// SplitMix64 finalizer: a well-mixed bijection on u64. Shared by the
/// seeded stream setup below and by pure-hash users (e.g. the serving
/// shadow prober's probe ranking) so the mixer lives in exactly one place.
pub fn mix64(mut z: u64) -> u64 {
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

fn splitmix(seed: &mut u64) -> u64 {
    *seed = seed.wrapping_add(0x9E3779B97F4A7C15);
    mix64(*seed)
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        let mut s = seed;
        let state = splitmix(&mut s);
        let inc = splitmix(&mut s) | 1;
        let mut rng = Rng { state, inc, spare: None };
        rng.next_u32();
        rng
    }

    /// Derive an independent stream (for per-thread / per-layer use).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0x9E3779B97F4A7C15))
    }

    /// Snapshot the exact generator state — LCG words plus the cached
    /// Box-Muller spare — so a checkpointed stream (e.g. a persisted
    /// reservoir sketch) resumes bit-identically via [`Rng::restore`].
    pub fn snapshot(&self) -> [u64; 4] {
        [
            self.state,
            self.inc,
            self.spare.is_some() as u64,
            self.spare.map_or(0, |v| v.to_bits() as u64),
        ]
    }

    /// Rebuild a generator from a [`Rng::snapshot`]; the restored stream
    /// continues exactly where the snapshotted one stopped.
    pub fn restore(words: [u64; 4]) -> Rng {
        Rng {
            state: words[0],
            inc: words[1],
            spare: (words[2] != 0).then_some(f32::from_bits(words[3] as u32)),
        }
    }

    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old.wrapping_mul(6364136223846793005).wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform in [0, 1).
    pub fn f32(&mut self) -> f32 {
        (self.next_u32() >> 8) as f32 * (1.0 / (1u32 << 24) as f32)
    }

    /// Uniform in [lo, hi).
    pub fn range(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.f32()
    }

    /// Uniform integer in [0, n).
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        // Lemire-style rejection-free enough at our scales.
        (self.next_u64() % n as u64) as usize
    }

    /// Standard normal via Box-Muller.
    pub fn normal(&mut self) -> f32 {
        if let Some(v) = self.spare.take() {
            return v;
        }
        loop {
            let u1 = self.f32();
            if u1 <= 1e-9 {
                continue;
            }
            let u2 = self.f32();
            let r = (-2.0 * u1.ln()).sqrt();
            let th = 2.0 * std::f32::consts::PI * u2;
            self.spare = Some(r * th.sin());
            return r * th.cos();
        }
    }

    pub fn normal_vec(&mut self, n: usize, std: f32) -> Vec<f32> {
        (0..n).map(|_| self.normal() * std).collect()
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, v: &mut [T]) {
        for i in (1..v.len()).rev() {
            let j = self.below(i + 1);
            v.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(
            (0..8).map(|_| a.next_u32()).collect::<Vec<_>>(),
            (0..8).map(|_| b.next_u32()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn uniform_range() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let v = r.f32();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn uniform_mean_var() {
        let mut r = Rng::new(9);
        let n = 100_000;
        let xs: Vec<f32> = (0..n).map(|_| r.f32()).collect();
        let mean = xs.iter().sum::<f32>() / n as f32;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f32>() / n as f32;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
        assert!((var - 1.0 / 12.0).abs() < 0.01, "var={var}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(11);
        let n = 100_000;
        let xs: Vec<f32> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f32>() / n as f32;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f32>() / n as f32;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn below_in_range_and_covers() {
        let mut r = Rng::new(3);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            seen[r.below(10)] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(5);
        let mut v: Vec<usize> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
        assert_ne!(v, (0..50).collect::<Vec<_>>()); // astronomically unlikely
    }

    #[test]
    fn snapshot_restore_resumes_bit_identically() {
        let mut a = Rng::new(77);
        // leave a Box-Muller spare cached so the snapshot must carry it
        let _ = a.normal();
        let snap = a.snapshot();
        let mut b = Rng::restore(snap);
        assert_eq!(a, b);
        for _ in 0..64 {
            assert_eq!(a.normal().to_bits(), b.normal().to_bits());
            assert_eq!(a.next_u64(), b.next_u64());
        }
        // a second snapshot taken mid-stream roundtrips too
        let snap2 = a.snapshot();
        assert_eq!(Rng::restore(snap2).next_u32(), b.next_u32());
    }

    #[test]
    fn fork_streams_independent() {
        let mut base = Rng::new(13);
        let mut a = base.fork(1);
        let mut b = base.fork(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }
}
