//! Scoped thread pool (tokio/rayon unavailable offline; DESIGN.md §1).
//!
//! Two primitives cover the crate's needs:
//!  * [`Pool`] — long-lived workers fed by an MPMC channel, used by the
//!    serving coordinator;
//!  * [`parallel_map`] — fork-join over a slice with std::thread::scope,
//!    used by the MSFP search (per-layer parallelism) and eval batching.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::thread;

type Job = Box<dyn FnOnce() + Send + 'static>;

/// Fixed-size worker pool. Jobs are closures; `join()` blocks until all
/// submitted jobs have completed (the pool stays usable afterwards).
pub struct Pool {
    tx: Option<mpsc::Sender<Job>>,
    handles: Vec<thread::JoinHandle<()>>,
    pending: Arc<(Mutex<usize>, std::sync::Condvar)>,
}

impl Pool {
    pub fn new(n: usize) -> Pool {
        let (tx, rx) = mpsc::channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let pending = Arc::new((Mutex::new(0usize), std::sync::Condvar::new()));
        let handles = (0..n.max(1))
            .map(|_| {
                let rx = Arc::clone(&rx);
                let pending = Arc::clone(&pending);
                thread::spawn(move || loop {
                    let job = {
                        let guard = rx.lock().unwrap();
                        guard.recv()
                    };
                    match job {
                        Ok(job) => {
                            // contain panics: a panicking job must neither
                            // kill this worker nor leak its pending count
                            // (which would deadlock join())
                            let r = std::panic::catch_unwind(
                                std::panic::AssertUnwindSafe(job),
                            );
                            if r.is_err() {
                                crate::log_warn!("thread-pool job panicked");
                            }
                            let (lock, cv) = &*pending;
                            let mut p = lock.lock().unwrap();
                            *p -= 1;
                            if *p == 0 {
                                cv.notify_all();
                            }
                        }
                        Err(_) => return,
                    }
                })
            })
            .collect();
        Pool { tx: Some(tx), handles, pending }
    }

    pub fn submit<F: FnOnce() + Send + 'static>(&self, f: F) {
        let (lock, _) = &*self.pending;
        *lock.lock().unwrap() += 1;
        self.tx.as_ref().unwrap().send(Box::new(f)).expect("pool closed");
    }

    /// Block until every submitted job has run.
    pub fn join(&self) {
        let (lock, cv) = &*self.pending;
        let mut p = lock.lock().unwrap();
        while *p > 0 {
            p = cv.wait(p).unwrap();
        }
    }
}

impl Drop for Pool {
    fn drop(&mut self) {
        self.tx.take(); // close channel; workers exit on recv error
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

/// Resolve a thread-count knob: `0` → available parallelism (fallback 4).
/// Single source of truth for what `threads == 0` means, shared by
/// [`parallel_map`] and callers that budget nested parallelism
/// (e.g. quant::msfp::quantize_model's outer×inner split).
pub fn resolve_threads(threads: usize) -> usize {
    if threads == 0 {
        thread::available_parallelism().map(|p| p.get()).unwrap_or(4)
    } else {
        threads
    }
}

/// Fork-join parallel map preserving order. `threads == 0` → available
/// parallelism. Work is distributed by atomic index so uneven items balance.
pub fn parallel_map<T: Sync, R: Send>(
    items: &[T],
    threads: usize,
    f: impl Fn(usize, &T) -> R + Sync,
) -> Vec<R> {
    let n = items.len();
    if n == 0 {
        return Vec::new();
    }
    let threads = resolve_threads(threads).min(n);
    if threads <= 1 {
        return items.iter().enumerate().map(|(i, t)| f(i, t)).collect();
    }
    let next = AtomicUsize::new(0);
    let mut out: Vec<Option<R>> = (0..n).map(|_| None).collect();
    let slots = Mutex::new(&mut out);
    thread::scope(|s| {
        for _ in 0..threads {
            s.spawn(|| loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    return;
                }
                let r = f(i, &items[i]);
                slots.lock().unwrap()[i] = Some(r);
            });
        }
    });
    out.into_iter().map(|o| o.expect("worker panicked")).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn pool_runs_all_jobs() {
        let pool = Pool::new(4);
        let counter = Arc::new(AtomicU64::new(0));
        for _ in 0..100 {
            let c = Arc::clone(&counter);
            pool.submit(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        pool.join();
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn pool_join_then_reuse() {
        let pool = Pool::new(2);
        let counter = Arc::new(AtomicU64::new(0));
        for round in 0..3 {
            for _ in 0..10 {
                let c = Arc::clone(&counter);
                pool.submit(move || {
                    c.fetch_add(1, Ordering::SeqCst);
                });
            }
            pool.join();
            assert_eq!(counter.load(Ordering::SeqCst), (round + 1) * 10);
        }
    }

    #[test]
    fn panicking_job_does_not_deadlock_or_kill_workers() {
        let pool = Pool::new(2);
        let counter = Arc::new(AtomicU64::new(0));
        pool.submit(|| panic!("injected"));
        for _ in 0..10 {
            let c = Arc::clone(&counter);
            pool.submit(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        pool.join(); // must not hang on the panicked job's pending count
        assert_eq!(counter.load(Ordering::SeqCst), 10);
    }

    #[test]
    fn parallel_map_preserves_order() {
        let items: Vec<usize> = (0..257).collect();
        let out = parallel_map(&items, 8, |i, &x| {
            assert_eq!(i, x);
            x * 2
        });
        assert_eq!(out, items.iter().map(|x| x * 2).collect::<Vec<_>>());
    }

    #[test]
    fn parallel_map_empty_and_single() {
        let empty: Vec<u32> = vec![];
        assert!(parallel_map(&empty, 4, |_, &x| x).is_empty());
        assert_eq!(parallel_map(&[5u32], 4, |_, &x| x + 1), vec![6]);
    }
}
