//! Tensor store: raw little-endian f32 blobs + a sidecar-free named format.
//!
//! Two formats:
//!  * `.f32` — a bare LE f32 vector (what aot.py emits for initial params);
//!  * `.mts` — "msfp tensor store": magic + named sections, used for
//!    checkpoints (params + optimizer state + qparams + lora + router) so a
//!    pipeline stage can resume from disk.

use std::collections::BTreeMap;
use std::fs;
use std::io::Read;
use std::path::Path;
use std::sync::atomic::{AtomicU64, Ordering};

use anyhow::{bail, Context, Result};

const MAGIC: &[u8; 8] = b"MSFPTS01";

/// Write `bytes` to `path` atomically: stage a uniquely named temp file in
/// the same directory, then rename it over the target. A crash mid-write
/// can never leave a truncated file at `path` (the rename either happened
/// or it didn't), and concurrent writers each stage their own temp file —
/// the last completed rename wins whole. Used by every checkpoint path
/// (`Store::save`, `recal::SketchSet::save`): serving restart-resume
/// depends on these files never being torn.
pub fn atomic_write(path: &Path, bytes: &[u8]) -> Result<()> {
    static SEQ: AtomicU64 = AtomicU64::new(0);
    if let Some(dir) = path.parent() {
        fs::create_dir_all(dir)?;
    }
    let tmp = path.with_extension(format!(
        "tmp.{}.{}",
        std::process::id(),
        SEQ.fetch_add(1, Ordering::Relaxed)
    ));
    fs::write(&tmp, bytes).with_context(|| format!("writing {}", tmp.display()))?;
    if let Err(e) = fs::rename(&tmp, path) {
        let _ = fs::remove_file(&tmp);
        return Err(e).with_context(|| format!("renaming {} into place", path.display()));
    }
    Ok(())
}

/// Read a bare little-endian f32 vector.
pub fn read_f32_raw(path: &Path) -> Result<Vec<f32>> {
    let bytes = fs::read(path).with_context(|| format!("reading {}", path.display()))?;
    if bytes.len() % 4 != 0 {
        bail!("{}: length {} not a multiple of 4", path.display(), bytes.len());
    }
    Ok(bytes.chunks_exact(4).map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]])).collect())
}

pub fn write_f32_raw(path: &Path, data: &[f32]) -> Result<()> {
    let mut bytes = Vec::with_capacity(data.len() * 4);
    for v in data {
        bytes.extend_from_slice(&v.to_le_bytes());
    }
    fs::write(path, bytes).with_context(|| format!("writing {}", path.display()))?;
    Ok(())
}

/// Named tensor checkpoint.
#[derive(Debug, Default, Clone)]
pub struct Store {
    pub sections: BTreeMap<String, Vec<f32>>,
}

impl Store {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn put(&mut self, name: &str, data: Vec<f32>) {
        self.sections.insert(name.to_string(), data);
    }

    pub fn get(&self, name: &str) -> Result<&[f32]> {
        self.sections
            .get(name)
            .map(|v| v.as_slice())
            .ok_or_else(|| anyhow::anyhow!("store missing section '{name}'"))
    }

    pub fn opt(&self, name: &str) -> Option<&[f32]> {
        self.sections.get(name).map(|v| v.as_slice())
    }

    /// Serialize and write atomically (temp file + rename): a checkpoint
    /// reader never observes a torn store, even across a crash or a
    /// concurrent re-save of the same path.
    pub fn save(&self, path: &Path) -> Result<()> {
        let total: usize = self.sections.iter().map(|(n, d)| 16 + n.len() + d.len() * 4).sum();
        let mut out = Vec::with_capacity(12 + total);
        out.extend_from_slice(MAGIC);
        out.extend_from_slice(&(self.sections.len() as u32).to_le_bytes());
        for (name, data) in &self.sections {
            let nb = name.as_bytes();
            out.extend_from_slice(&(nb.len() as u32).to_le_bytes());
            out.extend_from_slice(nb);
            out.extend_from_slice(&(data.len() as u64).to_le_bytes());
            for v in data {
                out.extend_from_slice(&v.to_le_bytes());
            }
        }
        atomic_write(path, &out)
    }

    pub fn load(path: &Path) -> Result<Store> {
        let mut f = fs::File::open(path).with_context(|| format!("opening {}", path.display()))?;
        let mut magic = [0u8; 8];
        f.read_exact(&mut magic)?;
        if &magic != MAGIC {
            bail!("{}: not an MSFP tensor store", path.display());
        }
        let mut u32b = [0u8; 4];
        f.read_exact(&mut u32b)?;
        let n = u32::from_le_bytes(u32b) as usize;
        let mut sections = BTreeMap::new();
        for _ in 0..n {
            f.read_exact(&mut u32b)?;
            let name_len = u32::from_le_bytes(u32b) as usize;
            if name_len > 4096 {
                bail!("corrupt store: name length {name_len}");
            }
            let mut name = vec![0u8; name_len];
            f.read_exact(&mut name)?;
            let mut u64b = [0u8; 8];
            f.read_exact(&mut u64b)?;
            let len = u64::from_le_bytes(u64b) as usize;
            let mut bytes = vec![0u8; len * 4];
            f.read_exact(&mut bytes)?;
            let data =
                bytes.chunks_exact(4).map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]])).collect();
            sections.insert(String::from_utf8(name)?, data);
        }
        Ok(Store { sections })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join("msfp_io_tests");
        fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    #[test]
    fn raw_roundtrip() {
        let p = tmp("raw.f32");
        let data = vec![0.0f32, -1.5, 3.25e7, f32::MIN_POSITIVE];
        write_f32_raw(&p, &data).unwrap();
        assert_eq!(read_f32_raw(&p).unwrap(), data);
    }

    #[test]
    fn raw_rejects_bad_length() {
        let p = tmp("bad.f32");
        fs::write(&p, [1, 2, 3]).unwrap();
        assert!(read_f32_raw(&p).is_err());
    }

    #[test]
    fn store_roundtrip() {
        let p = tmp("ckpt.mts");
        let mut s = Store::new();
        s.put("params", vec![1.0, 2.0, 3.0]);
        s.put("adam.m", vec![-0.5; 10]);
        s.put("empty", vec![]);
        s.save(&p).unwrap();
        let s2 = Store::load(&p).unwrap();
        assert_eq!(s2.get("params").unwrap(), &[1.0, 2.0, 3.0]);
        assert_eq!(s2.get("adam.m").unwrap().len(), 10);
        assert_eq!(s2.get("empty").unwrap().len(), 0);
        assert!(s2.get("nope").is_err());
    }

    #[test]
    fn atomic_write_overwrites_and_leaves_no_temp_files() {
        let dir = std::env::temp_dir().join("msfp_io_atomic");
        let _ = fs::remove_dir_all(&dir);
        let p = dir.join("ckpt.bin");
        atomic_write(&p, b"first").unwrap();
        assert_eq!(fs::read(&p).unwrap(), b"first");
        atomic_write(&p, b"second-longer").unwrap();
        assert_eq!(fs::read(&p).unwrap(), b"second-longer");
        // no staged temp files left behind
        let stray: Vec<_> = fs::read_dir(&dir)
            .unwrap()
            .map(|e| e.unwrap().file_name().into_string().unwrap())
            .filter(|n| n != "ckpt.bin")
            .collect();
        assert!(stray.is_empty(), "stray files: {stray:?}");
    }

    #[test]
    fn store_rejects_wrong_magic() {
        let p = tmp("junk.mts");
        fs::write(&p, b"NOTMAGIC????").unwrap();
        assert!(Store::load(&p).is_err());
    }
}
