//! Tensor store: raw little-endian f32 blobs + a sidecar-free named format.
//!
//! Two formats:
//!  * `.f32` — a bare LE f32 vector (what aot.py emits for initial params);
//!  * `.mts` — "msfp tensor store": magic + named sections, used for
//!    checkpoints (params + optimizer state + qparams + lora + router) so a
//!    pipeline stage can resume from disk.

use std::collections::BTreeMap;
use std::fs;
use std::io::{Read, Write};
use std::path::Path;

use anyhow::{bail, Context, Result};

const MAGIC: &[u8; 8] = b"MSFPTS01";

/// Read a bare little-endian f32 vector.
pub fn read_f32_raw(path: &Path) -> Result<Vec<f32>> {
    let bytes = fs::read(path).with_context(|| format!("reading {}", path.display()))?;
    if bytes.len() % 4 != 0 {
        bail!("{}: length {} not a multiple of 4", path.display(), bytes.len());
    }
    Ok(bytes.chunks_exact(4).map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]])).collect())
}

pub fn write_f32_raw(path: &Path, data: &[f32]) -> Result<()> {
    let mut bytes = Vec::with_capacity(data.len() * 4);
    for v in data {
        bytes.extend_from_slice(&v.to_le_bytes());
    }
    fs::write(path, bytes).with_context(|| format!("writing {}", path.display()))?;
    Ok(())
}

/// Named tensor checkpoint.
#[derive(Debug, Default, Clone)]
pub struct Store {
    pub sections: BTreeMap<String, Vec<f32>>,
}

impl Store {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn put(&mut self, name: &str, data: Vec<f32>) {
        self.sections.insert(name.to_string(), data);
    }

    pub fn get(&self, name: &str) -> Result<&[f32]> {
        self.sections
            .get(name)
            .map(|v| v.as_slice())
            .ok_or_else(|| anyhow::anyhow!("store missing section '{name}'"))
    }

    pub fn opt(&self, name: &str) -> Option<&[f32]> {
        self.sections.get(name).map(|v| v.as_slice())
    }

    pub fn save(&self, path: &Path) -> Result<()> {
        if let Some(dir) = path.parent() {
            fs::create_dir_all(dir)?;
        }
        let mut f = fs::File::create(path).with_context(|| format!("creating {}", path.display()))?;
        f.write_all(MAGIC)?;
        f.write_all(&(self.sections.len() as u32).to_le_bytes())?;
        for (name, data) in &self.sections {
            let nb = name.as_bytes();
            f.write_all(&(nb.len() as u32).to_le_bytes())?;
            f.write_all(nb)?;
            f.write_all(&(data.len() as u64).to_le_bytes())?;
            let mut bytes = Vec::with_capacity(data.len() * 4);
            for v in data {
                bytes.extend_from_slice(&v.to_le_bytes());
            }
            f.write_all(&bytes)?;
        }
        Ok(())
    }

    pub fn load(path: &Path) -> Result<Store> {
        let mut f = fs::File::open(path).with_context(|| format!("opening {}", path.display()))?;
        let mut magic = [0u8; 8];
        f.read_exact(&mut magic)?;
        if &magic != MAGIC {
            bail!("{}: not an MSFP tensor store", path.display());
        }
        let mut u32b = [0u8; 4];
        f.read_exact(&mut u32b)?;
        let n = u32::from_le_bytes(u32b) as usize;
        let mut sections = BTreeMap::new();
        for _ in 0..n {
            f.read_exact(&mut u32b)?;
            let name_len = u32::from_le_bytes(u32b) as usize;
            if name_len > 4096 {
                bail!("corrupt store: name length {name_len}");
            }
            let mut name = vec![0u8; name_len];
            f.read_exact(&mut name)?;
            let mut u64b = [0u8; 8];
            f.read_exact(&mut u64b)?;
            let len = u64::from_le_bytes(u64b) as usize;
            let mut bytes = vec![0u8; len * 4];
            f.read_exact(&mut bytes)?;
            let data =
                bytes.chunks_exact(4).map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]])).collect();
            sections.insert(String::from_utf8(name)?, data);
        }
        Ok(Store { sections })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join("msfp_io_tests");
        fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    #[test]
    fn raw_roundtrip() {
        let p = tmp("raw.f32");
        let data = vec![0.0f32, -1.5, 3.25e7, f32::MIN_POSITIVE];
        write_f32_raw(&p, &data).unwrap();
        assert_eq!(read_f32_raw(&p).unwrap(), data);
    }

    #[test]
    fn raw_rejects_bad_length() {
        let p = tmp("bad.f32");
        fs::write(&p, [1, 2, 3]).unwrap();
        assert!(read_f32_raw(&p).is_err());
    }

    #[test]
    fn store_roundtrip() {
        let p = tmp("ckpt.mts");
        let mut s = Store::new();
        s.put("params", vec![1.0, 2.0, 3.0]);
        s.put("adam.m", vec![-0.5; 10]);
        s.put("empty", vec![]);
        s.save(&p).unwrap();
        let s2 = Store::load(&p).unwrap();
        assert_eq!(s2.get("params").unwrap(), &[1.0, 2.0, 3.0]);
        assert_eq!(s2.get("adam.m").unwrap().len(), 10);
        assert_eq!(s2.get("empty").unwrap().len(), 0);
        assert!(s2.get("nope").is_err());
    }

    #[test]
    fn store_rejects_wrong_magic() {
        let p = tmp("junk.mts");
        fs::write(&p, b"NOTMAGIC????").unwrap();
        assert!(Store::load(&p).is_err());
    }
}
