//! Tensor store + durable checkpoint I/O + deterministic storage faults.
//!
//! Three concerns live here:
//!  * `.f32` — a bare LE f32 vector (what aot.py emits for initial params);
//!  * `.mts` — "msfp tensor store": magic + named sections, used for
//!    checkpoints (params + optimizer state + qparams + lora + router) so a
//!    pipeline stage can resume from disk;
//!  * [`FaultFs`] — a seeded storage fault plan (the executor's `FaultPlan`
//!    discipline extended to checkpoint writes and state restores) injected
//!    under [`atomic_write`] / [`read_file`] so crash-consistency drills are
//!    reproducible fixtures instead of flaky kill loops.

use std::collections::BTreeMap;
use std::fs;
use std::io::Write;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

use anyhow::{bail, Context, Result};

use crate::util::rng::mix64;

const MAGIC: &[u8; 8] = b"MSFPTS01";

/// Retry cap shared by every state-restore read ([`Store::load`], sketch
/// snapshots, packed blobs): transient injected read faults redraw per
/// attempt, so a moderate-rate plan clears under this cap while a
/// rate-1000 plan deterministically surfaces the error.
pub const RESTORE_ATTEMPTS: u64 = 3;

// ---------------------------------------------------------------------------
// Storage fault injection
// ---------------------------------------------------------------------------

/// Which storage operation a [`FaultFs`] decision applies to.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FsOp {
    Write,
    Read,
}

/// A fault forced onto one storage operation attempt.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum FsFault {
    #[default]
    None,
    /// The staged temp file is cut short at a seeded fraction of the
    /// payload (`cut_mille`/1000 of the bytes), then the write fails.
    /// The target is never touched — [`atomic_write`] renames whole files
    /// only — so a reader still sees the previous complete checkpoint.
    TornWrite { cut_mille: u32 },
    /// Transient I/O error: the attempt fails before any bytes move. A
    /// retry is a different `attempt` key and redraws.
    Eio,
    /// The full temp file is staged but the "process dies" before the
    /// rename: the write fails and the target keeps its previous content.
    CrashBeforeRename,
}

/// Deterministic storage fault plan — the same mix64-hash purity
/// discipline as `coordinator::FaultPlan`, applied to the state
/// lifecycle. A decision is a pure function of (op, target file name,
/// attempt index): the same plan injects the same faults into the same
/// writes on every run. Rates are per-mille of attempts; write draws
/// split `torn < torn+eio < torn+eio+crash`, read draws use
/// `read_eio_per_mille` alone.
///
/// A plan is armed with [`FaultFs::install`], scoped to every path under
/// one root directory and uninstalled when the returned RAII guard drops,
/// so concurrent tests with their own state roots never see each other's
/// faults. Decisions key on the target's *file name* (not the full path):
/// a fault schedule does not depend on where the state root lives.
#[derive(Debug, Clone, Copy, Default)]
pub struct FaultFs {
    pub seed: u64,
    pub torn_per_mille: u32,
    pub eio_per_mille: u32,
    pub crash_per_mille: u32,
    /// transient failures on the restore (read) path
    pub read_eio_per_mille: u32,
}

static FAULT_ROOTS: Mutex<Vec<(PathBuf, FaultFs)>> = Mutex::new(Vec::new());

/// Uninstalls its [`FaultFs`] plan on drop.
pub struct FaultFsGuard {
    root: PathBuf,
}

impl Drop for FaultFsGuard {
    fn drop(&mut self) {
        let mut roots = FAULT_ROOTS.lock().unwrap();
        if let Some(i) = roots.iter().position(|(r, _)| *r == self.root) {
            roots.remove(i);
        }
    }
}

impl FaultFs {
    pub fn new(seed: u64) -> FaultFs {
        FaultFs { seed, ..FaultFs::default() }
    }

    /// Arm this plan for every path under `root` until the guard drops.
    #[must_use = "the plan is uninstalled when the guard drops"]
    pub fn install(self, root: impl Into<PathBuf>) -> FaultFsGuard {
        let root = root.into();
        FAULT_ROOTS.lock().unwrap().push((root.clone(), self));
        FaultFsGuard { root }
    }

    /// The fault (if any) for `attempt` of operation `op` on `path` —
    /// pure in (self, op, file name, attempt).
    pub fn decide(&self, op: FsOp, path: &Path, attempt: u64) -> FsFault {
        let (torn, eio, crash) = match op {
            FsOp::Write => (self.torn_per_mille, self.eio_per_mille, self.crash_per_mille),
            FsOp::Read => (0, self.read_eio_per_mille, 0),
        };
        let total = torn + eio + crash;
        if total == 0 {
            return FsFault::None;
        }
        let salt: u64 = match op {
            FsOp::Write => 0x6673_5f77_72,
            FsOp::Read => 0x6673_5f72_64,
        };
        let h = mix64(
            self.seed
                ^ mix64(file_key(path) ^ salt)
                ^ mix64(attempt.wrapping_mul(0x9E37_79B9_7F4A_7C15)),
        );
        let d = (h % 1000) as u32;
        if d < torn {
            FsFault::TornWrite { cut_mille: (mix64(h) % 1000) as u32 }
        } else if d < torn + eio {
            FsFault::Eio
        } else if d < total {
            FsFault::CrashBeforeRename
        } else {
            FsFault::None
        }
    }
}

fn file_key(path: &Path) -> u64 {
    let name = path
        .file_name()
        .map(|n| n.to_string_lossy().into_owned())
        .unwrap_or_else(|| path.display().to_string());
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for &b in name.as_bytes() {
        h = mix64(h ^ b as u64);
    }
    h
}

/// The installed plan covering `path`, if any (the longest registered
/// root wins when roots nest).
fn plan_for(path: &Path) -> Option<FaultFs> {
    let roots = FAULT_ROOTS.lock().unwrap();
    roots
        .iter()
        .filter(|(r, _)| path.starts_with(r))
        .max_by_key(|(r, _)| r.as_os_str().len())
        .map(|(_, p)| *p)
}

// ---------------------------------------------------------------------------
// Durable writes and fault-aware reads
// ---------------------------------------------------------------------------

/// Write `bytes` to `path` atomically and durably: stage a uniquely named
/// temp file in the same directory, flush it to disk (`sync_all`), rename
/// it over the target, then fsync the parent directory so the rename
/// itself survives a crash. A crash mid-write can never leave a truncated
/// file at `path` (the rename either happened or it didn't), and
/// concurrent writers each stage their own temp file — the last completed
/// rename wins whole. Used by every checkpoint path (`Store::save`,
/// `recal::SketchSet::save`, `quant::PackedModel::save`): serving
/// restart-resume depends on these files never being torn.
///
/// Every failure path removes its staged temp file, so no `.tmp.*` strays
/// survive an aborted write; strays from a real process kill carry a dead
/// pid in their name and are swept by `quant::msfp::StateDir::sweep_stale_tmp`.
/// With an installed [`FaultFs`] covering `path`, seeded faults are
/// injected here; this is attempt 0 — [`atomic_write_retry`] redraws per
/// attempt.
pub fn atomic_write(path: &Path, bytes: &[u8]) -> Result<()> {
    atomic_write_attempt(path, bytes, 0)
}

fn atomic_write_attempt(path: &Path, bytes: &[u8], attempt: u64) -> Result<()> {
    static SEQ: AtomicU64 = AtomicU64::new(0);
    if let Some(dir) = path.parent() {
        fs::create_dir_all(dir)?;
    }
    let tmp = path.with_extension(format!(
        "tmp.{}.{}",
        std::process::id(),
        SEQ.fetch_add(1, Ordering::Relaxed)
    ));
    match plan_for(path).map(|p| p.decide(FsOp::Write, path, attempt)).unwrap_or_default() {
        FsFault::Eio => {
            bail!("injected fault: transient EIO writing {} (attempt {attempt})", path.display())
        }
        FsFault::TornWrite { cut_mille } => {
            // stage the torn prefix for real, then fail the write; the
            // target is untouched either way
            let cut = bytes.len() * cut_mille as usize / 1000;
            let _ = fs::write(&tmp, &bytes[..cut]);
            let _ = fs::remove_file(&tmp);
            bail!(
                "injected fault: torn write of {} at byte {cut}/{} (attempt {attempt})",
                path.display(),
                bytes.len()
            )
        }
        FsFault::CrashBeforeRename => {
            let _ = fs::write(&tmp, bytes);
            let _ = fs::remove_file(&tmp);
            bail!(
                "injected fault: crash before renaming {} into place (attempt {attempt})",
                path.display()
            )
        }
        FsFault::None => {}
    }
    let mut f = fs::File::create(&tmp).with_context(|| format!("creating {}", tmp.display()))?;
    let staged = f.write_all(bytes).and_then(|()| f.sync_all());
    drop(f);
    if let Err(e) = staged {
        let _ = fs::remove_file(&tmp);
        return Err(e).with_context(|| format!("writing {}", tmp.display()));
    }
    if let Err(e) = fs::rename(&tmp, path) {
        let _ = fs::remove_file(&tmp);
        return Err(e).with_context(|| format!("renaming {} into place", path.display()));
    }
    // the rename is durable only once the directory entry is flushed;
    // best-effort — an unsyncable parent degrades to pre-fsync behavior
    #[cfg(unix)]
    if let Some(dir) = path.parent() {
        if let Ok(d) = fs::File::open(dir) {
            let _ = d.sync_all();
        }
    }
    Ok(())
}

/// [`atomic_write`] with up to `attempts` tries (at least one), redrawing
/// injected faults per attempt — the capped-retry policy of the
/// checkpoint path. Returns the number of retries consumed (0 = the
/// first attempt landed).
pub fn atomic_write_retry(path: &Path, bytes: &[u8], attempts: u64) -> Result<u64> {
    let mut last = None;
    for attempt in 0..attempts.max(1) {
        match atomic_write_attempt(path, bytes, attempt) {
            Ok(()) => return Ok(attempt),
            Err(e) => last = Some(e),
        }
    }
    let attempts = attempts.max(1);
    Err(last
        .expect("at least one attempt ran")
        .context(format!("writing {} ({attempts} attempts)", path.display())))
}

/// Fault-aware whole-file read: every state restore (`Store`, sketch
/// snapshots, packed blobs) funnels through here so an installed
/// [`FaultFs`] can inject transient read failures on the restore path.
/// This is attempt 0; [`read_file_retry`] redraws per attempt.
pub fn read_file(path: &Path) -> Result<Vec<u8>> {
    read_file_attempt(path, 0)
}

fn read_file_attempt(path: &Path, attempt: u64) -> Result<Vec<u8>> {
    if let Some(p) = plan_for(path) {
        if p.decide(FsOp::Read, path, attempt) == FsFault::Eio {
            bail!("injected fault: transient EIO reading {} (attempt {attempt})", path.display());
        }
    }
    fs::read(path).with_context(|| format!("reading {}", path.display()))
}

/// [`read_file`] with up to `attempts` tries (at least one): restores
/// retry transient faults the same way checkpoint writes do.
pub fn read_file_retry(path: &Path, attempts: u64) -> Result<Vec<u8>> {
    let mut last = None;
    for attempt in 0..attempts.max(1) {
        match read_file_attempt(path, attempt) {
            Ok(bytes) => return Ok(bytes),
            Err(e) => last = Some(e),
        }
    }
    let attempts = attempts.max(1);
    Err(last
        .expect("at least one attempt ran")
        .context(format!("reading {} ({attempts} attempts)", path.display())))
}

// ---------------------------------------------------------------------------
// Raw f32 blobs
// ---------------------------------------------------------------------------

/// Read a bare little-endian f32 vector.
pub fn read_f32_raw(path: &Path) -> Result<Vec<f32>> {
    let bytes = fs::read(path).with_context(|| format!("reading {}", path.display()))?;
    if bytes.len() % 4 != 0 {
        bail!("{}: length {} not a multiple of 4", path.display(), bytes.len());
    }
    Ok(bytes.chunks_exact(4).map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]])).collect())
}

pub fn write_f32_raw(path: &Path, data: &[f32]) -> Result<()> {
    let mut bytes = Vec::with_capacity(data.len() * 4);
    for v in data {
        bytes.extend_from_slice(&v.to_le_bytes());
    }
    fs::write(path, bytes).with_context(|| format!("writing {}", path.display()))?;
    Ok(())
}

// ---------------------------------------------------------------------------
// Named tensor checkpoint
// ---------------------------------------------------------------------------

/// Named tensor checkpoint.
#[derive(Debug, Default, Clone)]
pub struct Store {
    pub sections: BTreeMap<String, Vec<f32>>,
}

impl Store {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn put(&mut self, name: &str, data: Vec<f32>) {
        self.sections.insert(name.to_string(), data);
    }

    pub fn get(&self, name: &str) -> Result<&[f32]> {
        self.sections
            .get(name)
            .map(|v| v.as_slice())
            .ok_or_else(|| anyhow::anyhow!("store missing section '{name}'"))
    }

    pub fn opt(&self, name: &str) -> Option<&[f32]> {
        self.sections.get(name).map(|v| v.as_slice())
    }

    /// Serialize to the `.mts` wire format.
    pub fn to_bytes(&self) -> Vec<u8> {
        let total: usize = self.sections.iter().map(|(n, d)| 16 + n.len() + d.len() * 4).sum();
        let mut out = Vec::with_capacity(12 + total);
        out.extend_from_slice(MAGIC);
        out.extend_from_slice(&(self.sections.len() as u32).to_le_bytes());
        for (name, data) in &self.sections {
            let nb = name.as_bytes();
            out.extend_from_slice(&(nb.len() as u32).to_le_bytes());
            out.extend_from_slice(nb);
            out.extend_from_slice(&(data.len() as u64).to_le_bytes());
            for v in data {
                out.extend_from_slice(&v.to_le_bytes());
            }
        }
        out
    }

    /// Serialize and write atomically (temp file + rename + fsync): a
    /// checkpoint reader never observes a torn store, even across a crash
    /// or a concurrent re-save of the same path.
    pub fn save(&self, path: &Path) -> Result<()> {
        atomic_write(path, &self.to_bytes())
    }

    /// Parse the `.mts` wire format; bounds-checked so a truncated or
    /// corrupt blob fails loudly instead of over-reading.
    pub fn from_bytes(bytes: &[u8]) -> Result<Store> {
        let mut c = Cursor { bytes, off: 0 };
        if c.take(8)? != MAGIC {
            bail!("not an MSFP tensor store");
        }
        let n = c.u32()? as usize;
        let mut sections = BTreeMap::new();
        for _ in 0..n {
            let name_len = c.u32()? as usize;
            if name_len > 4096 {
                bail!("corrupt store: name length {name_len}");
            }
            let name = String::from_utf8(c.take(name_len)?.to_vec())?;
            let len = c.u64()? as usize;
            if len > (bytes.len() - c.off) / 4 {
                bail!("corrupt store: section '{name}' length {len} exceeds payload");
            }
            let data = c
                .take(len * 4)?
                .chunks_exact(4)
                .map(|b| f32::from_le_bytes([b[0], b[1], b[2], b[3]]))
                .collect();
            sections.insert(name, data);
        }
        Ok(Store { sections })
    }

    pub fn load(path: &Path) -> Result<Store> {
        let bytes = read_file_retry(path, RESTORE_ATTEMPTS)?;
        Store::from_bytes(&bytes).with_context(|| format!("parsing {}", path.display()))
    }
}

struct Cursor<'a> {
    bytes: &'a [u8],
    off: usize,
}

impl<'a> Cursor<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if n > self.bytes.len() - self.off {
            bail!("truncated store at byte {}", self.off);
        }
        let s = &self.bytes[self.off..self.off + n];
        self.off += n;
        Ok(s)
    }

    fn u32(&mut self) -> Result<u32> {
        let b = self.take(4)?;
        Ok(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }

    fn u64(&mut self) -> Result<u64> {
        let b = self.take(8)?;
        Ok(u64::from_le_bytes([b[0], b[1], b[2], b[3], b[4], b[5], b[6], b[7]]))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join("msfp_io_tests");
        fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    #[test]
    fn raw_roundtrip() {
        let p = tmp("raw.f32");
        let data = vec![0.0f32, -1.5, 3.25e7, f32::MIN_POSITIVE];
        write_f32_raw(&p, &data).unwrap();
        assert_eq!(read_f32_raw(&p).unwrap(), data);
    }

    #[test]
    fn raw_rejects_bad_length() {
        let p = tmp("bad.f32");
        fs::write(&p, [1, 2, 3]).unwrap();
        assert!(read_f32_raw(&p).is_err());
    }

    #[test]
    fn store_roundtrip() {
        let p = tmp("ckpt.mts");
        let mut s = Store::new();
        s.put("params", vec![1.0, 2.0, 3.0]);
        s.put("adam.m", vec![-0.5; 10]);
        s.put("empty", vec![]);
        s.save(&p).unwrap();
        let s2 = Store::load(&p).unwrap();
        assert_eq!(s2.get("params").unwrap(), &[1.0, 2.0, 3.0]);
        assert_eq!(s2.get("adam.m").unwrap().len(), 10);
        assert_eq!(s2.get("empty").unwrap().len(), 0);
        assert!(s2.get("nope").is_err());
    }

    #[test]
    fn atomic_write_overwrites_and_leaves_no_temp_files() {
        let dir = std::env::temp_dir().join("msfp_io_atomic");
        let _ = fs::remove_dir_all(&dir);
        let p = dir.join("ckpt.bin");
        atomic_write(&p, b"first").unwrap();
        assert_eq!(fs::read(&p).unwrap(), b"first");
        atomic_write(&p, b"second-longer").unwrap();
        assert_eq!(fs::read(&p).unwrap(), b"second-longer");
        // no staged temp files left behind
        let stray: Vec<_> = fs::read_dir(&dir)
            .unwrap()
            .map(|e| e.unwrap().file_name().into_string().unwrap())
            .filter(|n| n != "ckpt.bin")
            .collect();
        assert!(stray.is_empty(), "stray files: {stray:?}");
    }

    #[test]
    fn store_rejects_wrong_magic() {
        let p = tmp("junk.mts");
        fs::write(&p, b"NOTMAGIC????").unwrap();
        assert!(Store::load(&p).is_err());
    }

    #[test]
    fn store_from_bytes_rejects_truncation_and_oversized_sections() {
        let mut s = Store::new();
        s.put("w", vec![1.0; 64]);
        let bytes = s.to_bytes();
        assert!(Store::from_bytes(&bytes).is_ok());
        // any truncation point fails loudly, never panics or over-reads
        for cut in [0, 7, 8, 11, 12, 13, bytes.len() / 2, bytes.len() - 1] {
            let err = Store::from_bytes(&bytes[..cut]);
            assert!(err.is_err(), "cut at {cut} should fail");
        }
        // a section header claiming more data than the payload holds
        let mut lying = bytes.clone();
        let len_off = 8 + 4 + 4 + 1; // magic + count + name_len + "w"
        lying[len_off..len_off + 8].copy_from_slice(&u64::MAX.to_le_bytes());
        let err = Store::from_bytes(&lying).unwrap_err();
        assert!(format!("{err:#}").contains("exceeds payload"), "{err:#}");
    }

    #[test]
    fn fault_fs_decide_is_pure_and_rate_bounded() {
        let plan = FaultFs {
            seed: 9,
            torn_per_mille: 300,
            eio_per_mille: 300,
            crash_per_mille: 200,
            read_eio_per_mille: 0,
        };
        let p = Path::new("/anywhere/x.bin");
        let mut torn = 0usize;
        let mut eio = 0usize;
        let mut crash = 0usize;
        let mut none = 0usize;
        for attempt in 0..4000u64 {
            let d = plan.decide(FsOp::Write, p, attempt);
            assert_eq!(d, plan.decide(FsOp::Write, p, attempt), "decide must be pure");
            match d {
                FsFault::TornWrite { cut_mille } => {
                    assert!(cut_mille < 1000);
                    torn += 1;
                }
                FsFault::Eio => eio += 1,
                FsFault::CrashBeforeRename => crash += 1,
                FsFault::None => none += 1,
            }
        }
        for (label, count, rate) in
            [("torn", torn, 300), ("eio", eio, 300), ("crash", crash, 200), ("none", none, 200)]
        {
            let expected = 4000 * rate / 1000;
            assert!(
                count.abs_diff(expected) < 4000 / 10,
                "{label}: {count} vs expected ~{expected}"
            );
        }
        // the read stream draws independently and only from read_eio
        assert_eq!(plan.decide(FsOp::Read, p, 0), FsFault::None);
        let rplan = FaultFs { read_eio_per_mille: 1000, ..FaultFs::new(9) };
        assert_eq!(rplan.decide(FsOp::Read, p, 0), FsFault::Eio);
        assert_eq!(rplan.decide(FsOp::Write, p, 0), FsFault::None);
        // the schedule keys on the file name, not the directory
        assert_eq!(
            plan.decide(FsOp::Write, Path::new("/a/x.bin"), 7),
            plan.decide(FsOp::Write, Path::new("/b/c/x.bin"), 7)
        );
    }

    #[test]
    fn injected_write_faults_preserve_target_and_leave_no_temp() {
        let dir = std::env::temp_dir().join("msfp_io_faults");
        let _ = fs::remove_dir_all(&dir);
        let p = dir.join("state.bin");
        atomic_write(&p, b"old complete checkpoint").unwrap();
        for plan in [
            FaultFs { torn_per_mille: 1000, ..FaultFs::new(4) },
            FaultFs { eio_per_mille: 1000, ..FaultFs::new(4) },
            FaultFs { crash_per_mille: 1000, ..FaultFs::new(4) },
        ] {
            let guard = plan.install(&dir);
            let err = atomic_write(&p, b"new bytes that must not land").unwrap_err();
            assert!(format!("{err:#}").contains("injected fault"), "{err:#}");
            // crash consistency: the previous complete checkpoint survives
            assert_eq!(fs::read(&p).unwrap(), b"old complete checkpoint");
            // no .tmp strays survive an injected crash-before-rename (or
            // any other fault kind)
            let stray: Vec<_> = fs::read_dir(&dir)
                .unwrap()
                .map(|e| e.unwrap().file_name().into_string().unwrap())
                .filter(|n| n != "state.bin")
                .collect();
            assert!(stray.is_empty(), "stray files under {plan:?}: {stray:?}");
            drop(guard);
        }
        // with every guard dropped the path writes clean again
        atomic_write(&p, b"post-chaos").unwrap();
        assert_eq!(fs::read(&p).unwrap(), b"post-chaos");
    }

    #[test]
    fn atomic_write_retry_clears_transient_faults_on_schedule() {
        // seed 0 on "retry.bin" draws Eio, Eio, None for attempts 0..3 at
        // rate 700 (pinned by the mirrored mix64 schedule)
        let dir = std::env::temp_dir().join("msfp_io_retry");
        let _ = fs::remove_dir_all(&dir);
        let p = dir.join("retry.bin");
        let plan = FaultFs { eio_per_mille: 700, ..FaultFs::new(0) };
        assert_eq!(plan.decide(FsOp::Write, &p, 0), FsFault::Eio);
        assert_eq!(plan.decide(FsOp::Write, &p, 1), FsFault::Eio);
        assert_eq!(plan.decide(FsOp::Write, &p, 2), FsFault::None);
        let guard = plan.install(&dir);
        // a single attempt fails; the capped retry clears on attempt 2
        assert!(atomic_write(&p, b"payload").is_err());
        assert!(atomic_write_retry(&p, b"payload", 2).is_err());
        assert_eq!(atomic_write_retry(&p, b"payload", 3).unwrap(), 2);
        assert_eq!(fs::read(&p).unwrap(), b"payload");
        drop(guard);
        assert_eq!(atomic_write_retry(&p, b"clean", 3).unwrap(), 0);
    }

    #[test]
    fn read_faults_inject_transiently_and_clear_when_uninstalled() {
        let dir = std::env::temp_dir().join("msfp_io_read_faults");
        let _ = fs::remove_dir_all(&dir);
        let p = dir.join("blob.bin");
        atomic_write(&p, b"contents").unwrap();
        let guard = FaultFs { read_eio_per_mille: 1000, ..FaultFs::new(3) }.install(&dir);
        let err = read_file(&p).unwrap_err();
        assert!(format!("{err:#}").contains("injected fault"), "{err:#}");
        // rate 1000 faults every attempt, so the capped retry fails too
        assert!(read_file_retry(&p, RESTORE_ATTEMPTS).is_err());
        drop(guard);
        assert_eq!(read_file(&p).unwrap(), b"contents");
        assert_eq!(read_file_retry(&p, RESTORE_ATTEMPTS).unwrap(), b"contents");
    }
}
