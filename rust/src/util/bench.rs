//! Bench harness for `[[bench]] harness = false` targets (criterion is
//! unavailable offline). Auto-calibrates iteration counts to a time budget
//! and reports median / p10 / p90 per-iteration latency, plus a JSON
//! emitter (`write_json`) so BENCH_*.json files keep the perf trajectory
//! machine-readable across PRs.

use std::path::Path;
use std::time::{Duration, Instant};

use crate::util::json::{arr, num, obj, s, Json};

pub struct BenchResult {
    pub name: String,
    pub iters: u64,
    pub median_ns: f64,
    pub p10_ns: f64,
    pub p90_ns: f64,
}

impl BenchResult {
    /// JSON row for BENCH_*.json files.
    pub fn to_json(&self) -> Json {
        obj(vec![
            ("name", s(&self.name)),
            ("iters", num(self.iters as f64)),
            ("median_ns", num(self.median_ns)),
            ("p10_ns", num(self.p10_ns)),
            ("p90_ns", num(self.p90_ns)),
        ])
    }

    pub fn print(&self) {
        println!(
            "bench {:<44} {:>12}/iter  (p10 {}, p90 {}, n={})",
            self.name,
            fmt_ns(self.median_ns),
            fmt_ns(self.p10_ns),
            fmt_ns(self.p90_ns),
            self.iters
        );
    }
}

pub fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.0} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.3} s", ns / 1e9)
    }
}

/// Measure `f`, auto-scaling within `budget`. Returns per-iter stats from
/// (up to) 30 timed samples.
pub fn bench_with_budget(name: &str, budget: Duration, mut f: impl FnMut()) -> BenchResult {
    // warmup + calibration
    let t0 = Instant::now();
    f();
    let once = t0.elapsed().max(Duration::from_nanos(50));
    let samples: u64 = 30;
    let per_sample = budget.as_nanos() as u64 / samples.max(1);
    let iters_per_sample = (per_sample / once.as_nanos().max(1) as u64).clamp(1, 1_000_000);

    let mut times: Vec<f64> = Vec::with_capacity(samples as usize);
    let hard_stop = Instant::now() + budget * 2;
    for _ in 0..samples {
        let t = Instant::now();
        for _ in 0..iters_per_sample {
            f();
        }
        times.push(t.elapsed().as_nanos() as f64 / iters_per_sample as f64);
        if Instant::now() > hard_stop {
            break;
        }
    }
    times.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let pick = |q: f64| times[((times.len() - 1) as f64 * q) as usize];
    let res = BenchResult {
        name: name.to_string(),
        iters: iters_per_sample * times.len() as u64,
        median_ns: pick(0.5),
        p10_ns: pick(0.1),
        p90_ns: pick(0.9),
    };
    res.print();
    res
}

/// Default 1-second budget.
pub fn bench(name: &str, f: impl FnMut()) -> BenchResult {
    bench_with_budget(name, Duration::from_secs(1), f)
}

/// Prevent the optimizer from discarding a value.
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

/// A non-timing metric row for BENCH_*.json files (e.g. a throughput in
/// img/s or a cache hit rate): `{"name": ..., "value": ..., "unit": ...}`.
pub fn metric_row(name: &str, value: f64, unit: &str) -> Json {
    obj(vec![("name", s(name)), ("value", num(value)), ("unit", s(unit))])
}

/// Write bench results as `{"benches": [...]}` so the perf trajectory is
/// machine-readable (diffable) across PRs.
pub fn write_json(path: &Path, results: &[BenchResult]) -> std::io::Result<()> {
    write_json_rows(path, results.iter().map(|r| r.to_json()).collect())
}

/// [`write_json`] for a mix of timing rows ([`BenchResult::to_json`]) and
/// [`metric_row`]s.
pub fn write_json_rows(path: &Path, rows: Vec<Json>) -> std::io::Result<()> {
    let j = obj(vec![("benches", arr(rows))]);
    std::fs::write(path, j.to_string() + "\n")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something() {
        let r = bench_with_budget("noop-ish", Duration::from_millis(50), || {
            black_box(1u64.wrapping_add(2));
        });
        assert!(r.median_ns >= 0.0);
        assert!(r.iters > 0);
    }

    #[test]
    fn json_roundtrip() {
        let r = BenchResult {
            name: "x".into(),
            iters: 3,
            median_ns: 1.5,
            p10_ns: 1.0,
            p90_ns: 2.0,
        };
        let path = std::env::temp_dir().join(format!("msfp_bench_{}.json", std::process::id()));
        write_json(&path, &[r]).unwrap();
        let j = Json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
        let rows = j.get("benches").unwrap().arr().unwrap();
        assert_eq!(rows.len(), 1);
        assert_eq!(rows[0].get("name").unwrap().str().unwrap(), "x");
        assert_eq!(rows[0].get("median_ns").unwrap().f64().unwrap(), 1.5);
    }

    #[test]
    fn mixed_rows_roundtrip() {
        let timing = BenchResult {
            name: "t".into(),
            iters: 1,
            median_ns: 2.0,
            p10_ns: 1.0,
            p90_ns: 3.0,
        };
        let path =
            std::env::temp_dir().join(format!("msfp_bench_rows_{}.json", std::process::id()));
        write_json_rows(
            &path,
            vec![timing.to_json(), metric_row("coordinator_parallel", 123.5, "img/s")],
        )
        .unwrap();
        let j = Json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
        let rows = j.get("benches").unwrap().arr().unwrap();
        assert_eq!(rows.len(), 2);
        assert_eq!(rows[1].get("name").unwrap().str().unwrap(), "coordinator_parallel");
        assert_eq!(rows[1].get("value").unwrap().f64().unwrap(), 123.5);
        assert_eq!(rows[1].get("unit").unwrap().str().unwrap(), "img/s");
    }

    #[test]
    fn fmt_ns_scales() {
        assert!(fmt_ns(500.0).contains("ns"));
        assert!(fmt_ns(5_000.0).contains("µs"));
        assert!(fmt_ns(5_000_000.0).contains("ms"));
        assert!(fmt_ns(5e9).contains(" s"));
    }
}
