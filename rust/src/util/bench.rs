//! Bench harness for `[[bench]] harness = false` targets (criterion is
//! unavailable offline). Auto-calibrates iteration counts to a time budget
//! and reports median / p10 / p90 per-iteration latency, plus a JSON
//! emitter (`write_json`) so BENCH_*.json files keep the perf trajectory
//! machine-readable across PRs. Every emitted file carries a [`run_meta`]
//! header (git rev, worker count, build profile) so a BENCH row is
//! attributable to the commit and machine shape that produced it.

use std::path::Path;
use std::time::{Duration, Instant};

use crate::util::json::{arr, num, obj, s, Json};

pub struct BenchResult {
    pub name: String,
    pub iters: u64,
    pub median_ns: f64,
    pub p10_ns: f64,
    pub p90_ns: f64,
}

impl BenchResult {
    /// JSON row for BENCH_*.json files.
    pub fn to_json(&self) -> Json {
        obj(vec![
            ("name", s(&self.name)),
            ("iters", num(self.iters as f64)),
            ("median_ns", num(self.median_ns)),
            ("p10_ns", num(self.p10_ns)),
            ("p90_ns", num(self.p90_ns)),
        ])
    }

    pub fn print(&self) {
        println!(
            "bench {:<44} {:>12}/iter  (p10 {}, p90 {}, n={})",
            self.name,
            fmt_ns(self.median_ns),
            fmt_ns(self.p10_ns),
            fmt_ns(self.p90_ns),
            self.iters
        );
    }
}

pub fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.0} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.3} s", ns / 1e9)
    }
}

/// Measure `f`, auto-scaling within `budget`. Returns per-iter stats from
/// (up to) 30 timed samples.
pub fn bench_with_budget(name: &str, budget: Duration, mut f: impl FnMut()) -> BenchResult {
    // warmup + calibration
    let t0 = Instant::now();
    f();
    let once = t0.elapsed().max(Duration::from_nanos(50));
    let samples: u64 = 30;
    let per_sample = budget.as_nanos() as u64 / samples.max(1);
    let iters_per_sample = (per_sample / once.as_nanos().max(1) as u64).clamp(1, 1_000_000);

    let mut times: Vec<f64> = Vec::with_capacity(samples as usize);
    let hard_stop = Instant::now() + budget * 2;
    for _ in 0..samples {
        let t = Instant::now();
        for _ in 0..iters_per_sample {
            f();
        }
        times.push(t.elapsed().as_nanos() as f64 / iters_per_sample as f64);
        if Instant::now() > hard_stop {
            break;
        }
    }
    times.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let pick = |q: f64| times[((times.len() - 1) as f64 * q) as usize];
    let res = BenchResult {
        name: name.to_string(),
        iters: iters_per_sample * times.len() as u64,
        median_ns: pick(0.5),
        p10_ns: pick(0.1),
        p90_ns: pick(0.9),
    };
    res.print();
    res
}

/// Default 1-second budget.
pub fn bench(name: &str, f: impl FnMut()) -> BenchResult {
    bench_with_budget(name, Duration::from_secs(1), f)
}

/// Prevent the optimizer from discarding a value.
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

/// A non-timing metric row for BENCH_*.json files (e.g. a throughput in
/// img/s or a cache hit rate): `{"name": ..., "value": ..., "unit": ...}`.
pub fn metric_row(name: &str, value: f64, unit: &str) -> Json {
    obj(vec![("name", s(name)), ("value", num(value)), ("unit", s(unit))])
}

/// Run-metadata header stamped into every BENCH_*.json: the short git
/// revision (plus a `-dirty` suffix when the tree has uncommitted
/// changes; "unknown" outside a git checkout), the resolved worker count
/// of this machine, and the build profile — enough to attribute a perf
/// row across PRs and machines.
pub fn run_meta() -> Json {
    obj(vec![
        ("git_rev", s(&git_rev())),
        ("workers", num(crate::util::threadpool::resolve_threads(0) as f64)),
        ("profile", s(if cfg!(debug_assertions) { "debug" } else { "release" })),
    ])
}

fn git_rev() -> String {
    let out = |args: &[&str]| {
        std::process::Command::new("git")
            .args(args)
            .output()
            .ok()
            .filter(|o| o.status.success())
            .map(|o| String::from_utf8_lossy(&o.stdout).trim().to_string())
    };
    let Some(rev) = out(&["rev-parse", "--short=12", "HEAD"]).filter(|r| !r.is_empty()) else {
        return "unknown".to_string();
    };
    // `git status --porcelain` prints nothing for a clean tree
    match out(&["status", "--porcelain"]) {
        Some(status) if !status.is_empty() => format!("{rev}-dirty"),
        _ => rev,
    }
}

/// Write bench results as `{"meta": {...}, "benches": [...]}` so the perf
/// trajectory is machine-readable (diffable) across PRs.
pub fn write_json(path: &Path, results: &[BenchResult]) -> std::io::Result<()> {
    write_json_rows(path, results.iter().map(|r| r.to_json()).collect())
}

/// [`write_json`] for a mix of timing rows ([`BenchResult::to_json`]) and
/// [`metric_row`]s.
pub fn write_json_rows(path: &Path, rows: Vec<Json>) -> std::io::Result<()> {
    let j = obj(vec![("meta", run_meta()), ("benches", arr(rows))]);
    std::fs::write(path, j.to_string() + "\n")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something() {
        let r = bench_with_budget("noop-ish", Duration::from_millis(50), || {
            black_box(1u64.wrapping_add(2));
        });
        assert!(r.median_ns >= 0.0);
        assert!(r.iters > 0);
    }

    #[test]
    fn json_roundtrip() {
        let r = BenchResult {
            name: "x".into(),
            iters: 3,
            median_ns: 1.5,
            p10_ns: 1.0,
            p90_ns: 2.0,
        };
        let path = std::env::temp_dir().join(format!("msfp_bench_{}.json", std::process::id()));
        write_json(&path, &[r]).unwrap();
        let j = Json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
        let rows = j.get("benches").unwrap().arr().unwrap();
        assert_eq!(rows.len(), 1);
        assert_eq!(rows[0].get("name").unwrap().str().unwrap(), "x");
        assert_eq!(rows[0].get("median_ns").unwrap().f64().unwrap(), 1.5);
    }

    #[test]
    fn mixed_rows_roundtrip() {
        let timing = BenchResult {
            name: "t".into(),
            iters: 1,
            median_ns: 2.0,
            p10_ns: 1.0,
            p90_ns: 3.0,
        };
        let path =
            std::env::temp_dir().join(format!("msfp_bench_rows_{}.json", std::process::id()));
        write_json_rows(
            &path,
            vec![timing.to_json(), metric_row("coordinator_parallel", 123.5, "img/s")],
        )
        .unwrap();
        let j = Json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
        let rows = j.get("benches").unwrap().arr().unwrap();
        assert_eq!(rows.len(), 2);
        assert_eq!(rows[1].get("name").unwrap().str().unwrap(), "coordinator_parallel");
        assert_eq!(rows[1].get("value").unwrap().f64().unwrap(), 123.5);
        assert_eq!(rows[1].get("unit").unwrap().str().unwrap(), "img/s");
    }

    #[test]
    fn meta_header_stamped_on_every_file() {
        let path =
            std::env::temp_dir().join(format!("msfp_bench_meta_{}.json", std::process::id()));
        write_json_rows(&path, vec![metric_row("x", 1.0, "unit")]).unwrap();
        let j = Json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
        let meta = j.get("meta").unwrap();
        assert!(!meta.get("git_rev").unwrap().str().unwrap().is_empty());
        assert!(meta.get("workers").unwrap().usize().unwrap() >= 1);
        let profile = meta.get("profile").unwrap().str().unwrap();
        assert!(profile == "debug" || profile == "release", "{profile}");
        // rows remain under "benches", unchanged by the header
        assert_eq!(j.get("benches").unwrap().arr().unwrap().len(), 1);
    }

    #[test]
    fn fmt_ns_scales() {
        assert!(fmt_ns(500.0).contains("ns"));
        assert!(fmt_ns(5_000.0).contains("µs"));
        assert!(fmt_ns(5_000_000.0).contains("ms"));
        assert!(fmt_ns(5e9).contains(" s"));
    }
}
