//! Minimal JSON parser/writer (serde is unavailable offline; DESIGN.md §1).
//!
//! Supports the full JSON grammar needed by the artifact manifest and the
//! golden files: objects, arrays, strings (with escapes), numbers, bools,
//! null. Numbers are parsed as f64; helpers coerce to the integer types the
//! manifest uses. The writer emits deterministic output (object insertion
//! order is preserved) so reports are diffable.

use std::collections::BTreeMap;
use std::fmt::Write as _;

use anyhow::{anyhow, bail, Context, Result};

/// A JSON value. Objects keep sorted keys (BTreeMap) plus stable access.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(s: &str) -> Result<Json> {
        let mut p = Parser { b: s.as_bytes(), i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            bail!("trailing data at byte {}", p.i);
        }
        Ok(v)
    }

    pub fn get(&self, key: &str) -> Result<&Json> {
        match self {
            Json::Obj(m) => m.get(key).ok_or_else(|| anyhow!("missing key '{key}'")),
            _ => bail!("not an object (looking for '{key}')"),
        }
    }

    pub fn opt(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn arr(&self) -> Result<&[Json]> {
        match self {
            Json::Arr(v) => Ok(v),
            _ => bail!("not an array"),
        }
    }

    pub fn obj(&self) -> Result<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Ok(m),
            _ => bail!("not an object"),
        }
    }

    pub fn str(&self) -> Result<&str> {
        match self {
            Json::Str(s) => Ok(s),
            _ => bail!("not a string"),
        }
    }

    pub fn f64(&self) -> Result<f64> {
        match self {
            Json::Num(n) => Ok(*n),
            _ => bail!("not a number"),
        }
    }

    pub fn f32(&self) -> Result<f32> {
        Ok(self.f64()? as f32)
    }

    pub fn usize(&self) -> Result<usize> {
        let n = self.f64()?;
        if n < 0.0 || n.fract() != 0.0 {
            bail!("not a usize: {n}");
        }
        Ok(n as usize)
    }

    pub fn i64(&self) -> Result<i64> {
        let n = self.f64()?;
        if n.fract() != 0.0 {
            bail!("not an integer: {n}");
        }
        Ok(n as i64)
    }

    pub fn bool(&self) -> Result<bool> {
        match self {
            Json::Bool(b) => Ok(*b),
            _ => bail!("not a bool"),
        }
    }

    /// Array of f32 (common for golden vectors).
    pub fn f32_vec(&self) -> Result<Vec<f32>> {
        self.arr()?.iter().map(|v| v.f32()).collect()
    }

    pub fn usize_vec(&self) -> Result<Vec<usize>> {
        self.arr()?.iter().map(|v| v.usize()).collect()
    }

    /// Serialize (compact).
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(v) => {
                out.push('[');
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, x)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(out, k);
                    out.push(':');
                    x.write(out);
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Convenience constructors for report emission.
pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

pub fn num(n: f64) -> Json {
    Json::Num(n)
}

pub fn s(v: &str) -> Json {
    Json::Str(v.to_string())
}

pub fn arr<I: IntoIterator<Item = Json>>(items: I) -> Json {
    Json::Arr(items.into_iter().collect())
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Result<u8> {
        self.b.get(self.i).copied().ok_or_else(|| anyhow!("unexpected end of input"))
    }

    fn eat(&mut self, c: u8) -> Result<()> {
        if self.peek()? != c {
            bail!("expected '{}' at byte {}, found '{}'", c as char, self.i, self.peek()? as char);
        }
        self.i += 1;
        Ok(())
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            bail!("invalid literal at byte {}", self.i)
        }
    }

    fn value(&mut self) -> Result<Json> {
        match self.peek()? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'n' => self.lit("null", Json::Null),
            b'-' | b'0'..=b'9' => self.number(),
            c => bail!("unexpected byte '{}' at {}", c as char, self.i),
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek()? == b'}' {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string().context("object key")?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            let v = self.value()?;
            m.insert(k, v);
            self.ws();
            match self.peek()? {
                b',' => self.i += 1,
                b'}' => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                c => bail!("expected ',' or '}}' at {}, found '{}'", self.i, c as char),
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.eat(b'[')?;
        let mut v = Vec::new();
        self.ws();
        if self.peek()? == b']' {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.ws();
            v.push(self.value()?);
            self.ws();
            match self.peek()? {
                b',' => self.i += 1,
                b']' => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                c => bail!("expected ',' or ']' at {}, found '{}'", self.i, c as char),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            let c = self.peek()?;
            self.i += 1;
            match c {
                b'"' => return Ok(s),
                b'\\' => {
                    let e = self.peek()?;
                    self.i += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'n' => s.push('\n'),
                        b't' => s.push('\t'),
                        b'r' => s.push('\r'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'u' => {
                            if self.i + 4 > self.b.len() {
                                bail!("truncated \\u escape");
                            }
                            let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])?;
                            let cp = u32::from_str_radix(hex, 16)?;
                            self.i += 4;
                            // Surrogate pairs: combine if a high surrogate.
                            let ch = if (0xD800..0xDC00).contains(&cp) {
                                if self.b.get(self.i) == Some(&b'\\')
                                    && self.b.get(self.i + 1) == Some(&b'u')
                                {
                                    let hex2 =
                                        std::str::from_utf8(&self.b[self.i + 2..self.i + 6])?;
                                    let lo = u32::from_str_radix(hex2, 16)?;
                                    self.i += 6;
                                    let c = 0x10000 + ((cp - 0xD800) << 10) + (lo - 0xDC00);
                                    char::from_u32(c)
                                } else {
                                    None
                                }
                            } else {
                                char::from_u32(cp)
                            };
                            s.push(ch.ok_or_else(|| anyhow!("bad \\u escape"))?);
                        }
                        c => bail!("bad escape '\\{}'", c as char),
                    }
                }
                c if c < 0x80 => s.push(c as char),
                c => {
                    // multi-byte UTF-8: copy the full sequence
                    let len = match c {
                        0xC0..=0xDF => 2,
                        0xE0..=0xEF => 3,
                        _ => 4,
                    };
                    let start = self.i - 1;
                    let chunk = std::str::from_utf8(&self.b[start..start + len])?;
                    s.push_str(chunk);
                    self.i = start + len;
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.i;
        if self.peek()? == b'-' {
            self.i += 1;
        }
        while self.i < self.b.len()
            && matches!(self.b[self.i], b'0'..=b'9' | b'.' | b'e' | b'E' | b'+' | b'-')
        {
            self.i += 1;
        }
        let txt = std::str::from_utf8(&self.b[start..self.i])?;
        Ok(Json::Num(txt.parse::<f64>().with_context(|| format!("bad number '{txt}'"))?))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(Json::parse("\"a\\nb\"").unwrap(), Json::Str("a\nb".into()));
    }

    #[test]
    fn parses_nested() {
        let j = Json::parse(r#"{"a": [1, 2, {"b": false}], "c": "x"}"#).unwrap();
        assert_eq!(j.get("a").unwrap().arr().unwrap().len(), 3);
        assert_eq!(j.get("c").unwrap().str().unwrap(), "x");
        assert!(!j.get("a").unwrap().arr().unwrap()[2].get("b").unwrap().bool().unwrap());
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("tru").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("").is_err());
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"a":[1,2.5,"x\"y"],"b":{"c":null,"d":true}}"#;
        let j = Json::parse(src).unwrap();
        let j2 = Json::parse(&j.to_string()).unwrap();
        assert_eq!(j, j2);
    }

    #[test]
    fn unicode_escapes() {
        let j = Json::parse(r#""é😀""#).unwrap();
        assert_eq!(j.str().unwrap(), "é😀");
        let j2 = Json::parse(&j.to_string()).unwrap();
        assert_eq!(j, j2);
    }

    #[test]
    fn numeric_helpers() {
        let j = Json::parse("[5, 2.5]").unwrap();
        assert_eq!(j.arr().unwrap()[0].usize().unwrap(), 5);
        assert!(j.arr().unwrap()[1].usize().is_err());
        assert_eq!(j.f32_vec().unwrap(), vec![5.0, 2.5]);
    }
}
