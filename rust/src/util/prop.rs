//! Property-test mini-harness (proptest is unavailable offline).
//!
//! `check(name, cases, gen, prop)` draws `cases` seeded inputs from `gen`
//! and asserts `prop`; on failure it performs a simple halving shrink over
//! the seed-driven generator and reports the smallest failing seed. Purely
//! deterministic: the base seed derives from the test name so failures
//! reproduce without flags.

use crate::util::rng::Rng;

fn fnv(name: &str) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    for b in name.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

/// Run a property over `cases` generated inputs. Panics (with the failing
/// seed) if the property returns false or panics.
pub fn check<T: std::fmt::Debug>(
    name: &str,
    cases: usize,
    mut gen: impl FnMut(&mut Rng) -> T,
    mut prop: impl FnMut(&T) -> bool,
) {
    let base = fnv(name);
    for i in 0..cases {
        let seed = base.wrapping_add(i as u64);
        let mut rng = Rng::new(seed);
        let input = gen(&mut rng);
        if !prop(&input) {
            panic!(
                "property '{name}' failed at case {i} (seed {seed}):\n  input = {input:?}"
            );
        }
    }
}

/// Generator helpers.
pub fn vec_f32(rng: &mut Rng, max_len: usize, scale: f32) -> Vec<f32> {
    let n = 1 + rng.below(max_len.max(1));
    (0..n).map(|_| rng.normal() * scale).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check("abs-nonneg", 200, |r| r.normal(), |x| x.abs() >= 0.0);
    }

    #[test]
    #[should_panic(expected = "property 'always-false' failed")]
    fn failing_property_reports() {
        check("always-false", 10, |r| r.f32(), |_| false);
    }

    #[test]
    fn deterministic_inputs() {
        let mut a = Vec::new();
        let mut b = Vec::new();
        check("det", 5, |r| r.next_u64(), |&x| {
            a.push(x);
            true
        });
        check("det", 5, |r| r.next_u64(), |&x| {
            b.push(x);
            true
        });
        assert_eq!(a, b);
    }
}
