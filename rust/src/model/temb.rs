//! Sinusoidal timestep embedding — exact mirror of model.sinusoidal_temb
//! (python). The TALoRA router consumes this at inference, so the Rust and
//! JAX halves must produce matching embeddings (pinned by the router golden
//! test).

/// emb[i] = sin(t * exp(-ln(10000) * i / half)) for i < half, then cos.
pub fn sinusoidal(t: f32, dim: usize) -> Vec<f32> {
    let half = dim / 2;
    let mut out = Vec::with_capacity(dim);
    let ln1e4 = (10000.0f32).ln();
    for i in 0..half {
        let freq = (-ln1e4 * i as f32 / half as f32).exp();
        out.push((t * freq).sin());
    }
    for i in 0..half {
        let freq = (-ln1e4 * i as f32 / half as f32).exp();
        out.push((t * freq).cos());
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zero_timestep() {
        let e = sinusoidal(0.0, 64);
        assert!(e[..32].iter().all(|&v| v == 0.0));
        assert!(e[32..].iter().all(|&v| (v - 1.0).abs() < 1e-6));
    }

    #[test]
    fn bounded_and_distinct() {
        let a = sinusoidal(10.0, 64);
        let b = sinusoidal(11.0, 64);
        assert!(a.iter().all(|v| v.abs() <= 1.0 + 1e-6));
        assert_ne!(a, b);
    }

    #[test]
    fn first_component_is_plain_sin() {
        let e = sinusoidal(2.5, 64);
        assert!((e[0] - 2.5f32.sin()).abs() < 1e-6);
        assert!((e[32] - 2.5f32.cos()).abs() < 1e-6);
    }
}
