//! The flat parameter store. Rust owns all mutable state (the graphs are
//! pure functions); this wraps the flat f32 vector with manifest-indexed
//! slicing and checkpoint IO.

use std::path::Path;

use anyhow::{bail, Result};

use crate::util::io::{read_f32_raw, Store};

use super::manifest::ModelInfo;

#[derive(Debug, Clone)]
pub struct ParamStore {
    pub flat: Vec<f32>,
}

impl ParamStore {
    /// Load the seeded initial parameters emitted by aot.py.
    pub fn load_init(info: &ModelInfo, artifacts_dir: &Path) -> Result<ParamStore> {
        let flat = read_f32_raw(&artifacts_dir.join(&info.init_params))?;
        if flat.len() != info.n_params {
            bail!("init params len {} != n_params {}", flat.len(), info.n_params);
        }
        Ok(ParamStore { flat })
    }

    pub fn from_vec(info: &ModelInfo, flat: Vec<f32>) -> Result<ParamStore> {
        if flat.len() != info.n_params {
            bail!("param len {} != n_params {}", flat.len(), info.n_params);
        }
        Ok(ParamStore { flat })
    }

    /// Slice one named parameter tensor.
    pub fn tensor<'a>(&'a self, info: &ModelInfo, name: &str) -> Result<&'a [f32]> {
        let spec = info.param_spec(name)?;
        Ok(&self.flat[spec.offset..spec.offset + spec.size()])
    }

    /// Weight tensors of all quantized layers, in layer order (for the
    /// MSFP weight search).
    pub fn layer_weights(&self, info: &ModelInfo) -> Result<Vec<Vec<f32>>> {
        info.layer_specs.iter().map(|l| Ok(self.tensor(info, &l.param)?.to_vec())).collect()
    }

    pub fn save(&self, path: &Path) -> Result<()> {
        let mut s = Store::new();
        s.put("params", self.flat.clone());
        s.save(path)
    }

    pub fn load(info: &ModelInfo, path: &Path) -> Result<ParamStore> {
        let s = Store::load(path)?;
        Self::from_vec(info, s.get("params")?.to_vec())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::manifest::Manifest;
    use std::path::PathBuf;

    fn manifest() -> Option<Manifest> {
        let d = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        d.join("manifest.json").exists().then(|| Manifest::load(&d).unwrap())
    }

    #[test]
    fn loads_init_params_and_slices() {
        let Some(m) = manifest() else {
            crate::log_warn!("skipping: artifacts not built");
            return;
        };
        let info = m.model("ddim16").unwrap();
        let p = ParamStore::load_init(info, &m.dir).unwrap();
        assert_eq!(p.flat.len(), info.n_params);
        // conv_in weights exist and are non-trivial
        let w = p.tensor(info, "conv_in.w").unwrap();
        assert!(w.iter().any(|&v| v != 0.0));
        // conv_out is zero-initialized by design
        let wo = p.tensor(info, "conv_out.w").unwrap();
        assert!(wo.iter().all(|&v| v == 0.0));
        // all layer weights sliceable
        let lw = p.layer_weights(info).unwrap();
        assert_eq!(lw.len(), info.n_layers);
    }

    #[test]
    fn checkpoint_roundtrip() {
        let Some(m) = manifest() else {
            return;
        };
        let info = m.model("ldm8").unwrap();
        let p = ParamStore::load_init(info, &m.dir).unwrap();
        let path = std::env::temp_dir().join("msfp_params_test.mts");
        p.save(&path).unwrap();
        let p2 = ParamStore::load(info, &path).unwrap();
        assert_eq!(p.flat, p2.flat);
    }

    #[test]
    fn size_mismatch_rejected() {
        let Some(m) = manifest() else {
            return;
        };
        let info = m.model("ddim16").unwrap();
        assert!(ParamStore::from_vec(info, vec![0.0; 3]).is_err());
    }
}
