//! artifacts/manifest.json — the ABI contract emitted by python/compile/aot.py.
//!
//! Everything Rust needs to drive the graphs: parameter layout (name →
//! offset/shape), the quantized-layer table (order matches the graphs'
//! call-order cursor), artifact filenames per role/batch-size, and model
//! hyperparameters.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

use crate::util::json::Json;

#[derive(Debug, Clone)]
pub struct ParamSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub offset: usize,
}

impl ParamSpec {
    pub fn size(&self) -> usize {
        self.shape.iter().product()
    }
}

#[derive(Debug, Clone)]
pub struct LayerSpec {
    pub name: String,
    pub kind: String, // "conv" | "linear"
    pub fan_in: usize,
    pub fan_out: usize,
    pub k: usize,
    pub stride: usize,
    pub aal_hint: bool,
    pub param: String,
    pub lora_offset: usize,
}

#[derive(Debug, Clone)]
pub struct ModelCfg {
    pub img_hw: usize,
    pub in_ch: usize,
    pub temb_dim: usize,
    pub n_classes: usize,
    pub lora_rank: usize,
    pub lora_hub: usize,
}

#[derive(Debug, Clone)]
pub struct ModelInfo {
    pub name: String,
    pub cfg: ModelCfg,
    pub n_params: usize,
    pub n_layers: usize,
    pub lora_size: usize,
    pub router_size: usize,
    pub act_samples: usize,
    pub param_specs: Vec<ParamSpec>,
    pub layer_specs: Vec<LayerSpec>,
    pub init_params: String,
    pub artifacts: BTreeMap<String, String>,
    pub batches_fp: Vec<usize>,
    pub batches_q: Vec<usize>,
    pub train_b: usize,
    pub calib_b: usize,
}

impl ModelInfo {
    /// x-tensor element count for batch b.
    pub fn x_size(&self, b: usize) -> usize {
        b * self.cfg.img_hw * self.cfg.img_hw * self.cfg.in_ch
    }

    pub fn artifact(&self, role: &str) -> Result<&str> {
        self.artifacts
            .get(role)
            .map(|s| s.as_str())
            .ok_or_else(|| anyhow!("model {} has no artifact '{role}'", self.name))
    }

    pub fn param_spec(&self, name: &str) -> Result<&ParamSpec> {
        self.param_specs
            .iter()
            .find(|p| p.name == name)
            .ok_or_else(|| anyhow!("no param '{name}'"))
    }

    /// Indices of the 8-bit IO layers (first = conv_in preceded by the temb
    /// linears in call order; we mark by name).
    pub fn io_layer_indices(&self) -> Vec<usize> {
        self.layer_specs
            .iter()
            .enumerate()
            .filter(|(_, l)| l.name == "conv_in" || l.name == "conv_out")
            .map(|(i, _)| i)
            .collect()
    }

    /// Indices of skip-connection layers (Table 11's partial-quantization
    /// setting keeps these at high precision).
    pub fn skip_layer_indices(&self) -> Vec<usize> {
        self.layer_specs
            .iter()
            .enumerate()
            .filter(|(_, l)| l.name.ends_with(".skip") || l.name == "up" || l.name == "down")
            .map(|(i, _)| i)
            .collect()
    }
}

#[derive(Debug, Clone)]
pub struct FeatureInfo {
    pub path16: String,
    pub path32: String,
    pub feat_dim: usize,
    pub sfeat_dim: usize,
    pub n_logits: usize,
    pub batch: usize,
}

#[derive(Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub models: BTreeMap<String, ModelInfo>,
    pub features: FeatureInfo,
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {} (run `make artifacts`)", path.display()))?;
        let j = Json::parse(&text).context("parsing manifest.json")?;
        if j.get("schema")?.usize()? != 1 {
            bail!("unsupported manifest schema");
        }
        let mut models = BTreeMap::new();
        for (name, m) in j.get("models")?.obj()? {
            models.insert(name.clone(), parse_model(name, m)?);
        }
        let f = j.get("features")?;
        let features = FeatureInfo {
            path16: f.get("16")?.str()?.to_string(),
            path32: f.get("32")?.str()?.to_string(),
            feat_dim: f.get("feat_dim")?.usize()?,
            sfeat_dim: f.get("sfeat_dim")?.usize()?,
            n_logits: f.get("n_logits")?.usize()?,
            batch: f.get("batch")?.usize()?,
        };
        Ok(Manifest { dir: dir.to_path_buf(), models, features })
    }

    pub fn model(&self, name: &str) -> Result<&ModelInfo> {
        self.models.get(name).ok_or_else(|| {
            anyhow!("unknown model '{name}' (have: {:?})", self.models.keys().collect::<Vec<_>>())
        })
    }

    pub fn artifact_path(&self, file: &str) -> PathBuf {
        self.dir.join(file)
    }
}

fn parse_model(name: &str, m: &Json) -> Result<ModelInfo> {
    let cfg = m.get("cfg")?;
    let cfg = ModelCfg {
        img_hw: cfg.get("img_hw")?.usize()?,
        in_ch: cfg.get("in_ch")?.usize()?,
        temb_dim: cfg.get("temb_dim")?.usize()?,
        n_classes: cfg.get("n_classes")?.usize()?,
        lora_rank: cfg.get("lora_rank")?.usize()?,
        lora_hub: cfg.get("lora_hub")?.usize()?,
    };
    let param_specs = m
        .get("param_specs")?
        .arr()?
        .iter()
        .map(|p| {
            Ok(ParamSpec {
                name: p.get("name")?.str()?.to_string(),
                shape: p.get("shape")?.usize_vec()?,
                offset: p.get("offset")?.usize()?,
            })
        })
        .collect::<Result<Vec<_>>>()?;
    let layer_specs = m
        .get("layer_specs")?
        .arr()?
        .iter()
        .map(|l| {
            Ok(LayerSpec {
                name: l.get("name")?.str()?.to_string(),
                kind: l.get("kind")?.str()?.to_string(),
                fan_in: l.get("fan_in")?.usize()?,
                fan_out: l.get("fan_out")?.usize()?,
                k: l.get("k")?.usize()?,
                stride: l.get("stride")?.usize()?,
                aal_hint: l.get("aal")?.bool()?,
                param: l.get("param")?.str()?.to_string(),
                lora_offset: l.get("lora_offset")?.usize()?,
            })
        })
        .collect::<Result<Vec<_>>>()?;
    let artifacts = m
        .get("artifacts")?
        .obj()?
        .iter()
        .map(|(k, v)| Ok((k.clone(), v.str()?.to_string())))
        .collect::<Result<BTreeMap<_, _>>>()?;
    let info = ModelInfo {
        name: name.to_string(),
        cfg,
        n_params: m.get("n_params")?.usize()?,
        n_layers: m.get("n_layers")?.usize()?,
        lora_size: m.get("lora_size")?.usize()?,
        router_size: m.get("router_size")?.usize()?,
        act_samples: m.get("act_samples")?.usize()?,
        param_specs,
        layer_specs,
        init_params: m.get("init_params")?.str()?.to_string(),
        artifacts,
        batches_fp: m.get("batches_fp")?.usize_vec()?,
        batches_q: m.get("batches_q")?.usize_vec()?,
        train_b: m.get("train_b")?.usize()?,
        calib_b: m.get("calib_b")?.usize()?,
    };
    // consistency checks — catch drift between aot.py and this parser early
    if info.layer_specs.len() != info.n_layers {
        bail!("model {name}: layer_specs len != n_layers");
    }
    let psum: usize = info.param_specs.iter().map(|p| p.size()).sum();
    if psum != info.n_params {
        bail!("model {name}: param sizes sum {psum} != n_params {}", info.n_params);
    }
    Ok(info)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts_dir() -> Option<PathBuf> {
        let d = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        d.join("manifest.json").exists().then_some(d)
    }

    #[test]
    fn loads_real_manifest() {
        let Some(dir) = artifacts_dir() else {
            crate::log_warn!("skipping: artifacts not built");
            return;
        };
        let m = Manifest::load(&dir).unwrap();
        assert!(m.models.contains_key("ddim16"));
        assert!(m.models.contains_key("ldm8"));
        assert!(m.models.contains_key("ldm8c"));
        let d = m.model("ddim16").unwrap();
        assert_eq!(d.cfg.img_hw, 16);
        assert_eq!(d.cfg.in_ch, 3);
        assert!(d.n_layers > 10);
        assert!(!d.io_layer_indices().is_empty());
        assert!(d.artifact("fp_b8").is_ok());
        assert!(d.artifact("q_b1").is_ok());
        assert!(d.artifact("finetune_b8").is_ok());
        assert!(d.artifact("nope").is_err());
    }

    #[test]
    fn ldm8c_is_conditional() {
        let Some(dir) = artifacts_dir() else {
            return;
        };
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.model("ldm8c").unwrap().cfg.n_classes, 10);
        assert_eq!(m.model("ldm8").unwrap().cfg.n_classes, 0);
    }

    #[test]
    fn missing_dir_errors_helpfully() {
        let err = Manifest::load(Path::new("/nonexistent")).unwrap_err();
        assert!(format!("{err:#}").contains("make artifacts"));
    }
}
