//! Model-side plumbing: the artifact manifest (the Rust↔JAX ABI), the flat
//! parameter store, the sinusoidal timestep embedding mirror, and model
//! variant metadata.

pub mod manifest;
pub mod params;
pub mod temb;

pub use manifest::{LayerSpec, Manifest, ModelInfo, ParamSpec};
pub use params::ParamStore;
