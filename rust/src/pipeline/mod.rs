//! End-to-end pipeline: pretrain → calibrate → quantize (MSFP/baseline) →
//! fine-tune (TALoRA+DFA) → generate → evaluate. Every experiment runner
//! and the CLI drive this; pretrained checkpoints are cached per corpus in
//! the runs directory.

use std::path::PathBuf;
use std::sync::Arc;

use anyhow::Result;

use crate::config::{MethodSpec, Scale};
use crate::data::{Corpus, PatchAutoencoder};
use crate::eval::{
    evaluate, generate_images, reference_stats, EvalResult, FeatureExtractor, GenerateCfg,
    ModelMode,
};
use crate::eval::generate::SamplerKind;
use crate::log_info;
use crate::lora::{LoraHub, Router};
use crate::model::manifest::{Manifest, ModelInfo};
use crate::model::ParamStore;
use crate::quant::msfp::{LayerCalib, QuantOpts, QuantScheme, StateDir};
use crate::quant::session::QuantSession;
use crate::runtime::{Denoiser, Engine, QuantState};
use crate::schedule::{timestep_subsequence, Schedule};
use crate::train::{
    collect_calibration, finetune, finetune_recal, pretrain, FinetuneRecal, FinetuneStats,
    PretrainCfg, TrajectoryBuffer,
};
use crate::util::io::Store;
use crate::util::rng::Rng;

pub const T_TOTAL: usize = 100;

pub struct Pipeline {
    pub engine: Arc<Engine>,
    pub manifest: Manifest,
    pub sched: Schedule,
    pub runs_dir: PathBuf,
    pub scale: Scale,
}

/// A pretrained model ready for quantization experiments.
pub struct Prepared {
    pub corpus: Corpus,
    pub info: ModelInfo,
    pub den: Denoiser,
    pub params: Vec<f32>,
    pub pretrain_losses: Vec<f32>,
}

/// A quantized (and possibly fine-tuned) model.
pub struct Quantized {
    pub scheme: QuantScheme,
    pub state: QuantState,
    pub ft_stats: Option<FinetuneStats>,
}

impl Pipeline {
    pub fn new(artifacts_dir: &std::path::Path, scale: Scale) -> Result<Pipeline> {
        let engine = Arc::new(Engine::new(artifacts_dir)?);
        let manifest = Manifest::load(artifacts_dir)?;
        let runs_dir = std::env::var("MSFP_RUNS")
            .map(PathBuf::from)
            .unwrap_or_else(|_| artifacts_dir.parent().unwrap().join("runs"));
        std::fs::create_dir_all(&runs_dir)?;
        Ok(Pipeline { engine, manifest, sched: Schedule::linear(T_TOTAL), runs_dir, scale })
    }

    pub fn default_artifacts_dir() -> PathBuf {
        std::env::var("MSFP_ARTIFACTS")
            .map(PathBuf::from)
            .unwrap_or_else(|_| PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts"))
    }

    /// Pretrain (or load the cached checkpoint for) a corpus.
    pub fn prepare(&self, corpus: Corpus) -> Result<Prepared> {
        let info = self.manifest.model(corpus.model_name())?.clone();
        let den = Denoiser::new(Arc::clone(&self.engine), &info)?;
        let ckpt = self.runs_dir.join(format!(
            "pretrain_{}_{}steps.mts",
            corpus.name(),
            self.scale.pretrain_steps
        ));
        if ckpt.exists() {
            let store = Store::load(&ckpt)?;
            log_info!("loaded pretrained {} from {}", corpus.name(), ckpt.display());
            return Ok(Prepared {
                corpus,
                params: store.get("params")?.to_vec(),
                pretrain_losses: store.get("losses")?.to_vec(),
                info,
                den,
            });
        }
        let init = ParamStore::load_init(&info, &self.manifest.dir)?;
        let cfg = PretrainCfg {
            steps: self.scale.pretrain_steps,
            seed: 7 ^ corpus.name().len() as u64,
            ..Default::default()
        };
        let (params, losses) =
            pretrain(&self.engine, &info, &self.sched, corpus, init.flat, &cfg)?;
        let mut store = Store::new();
        store.put("params", params.clone());
        store.put("losses", losses.clone());
        store.save(&ckpt)?;
        Ok(Prepared { corpus, params, pretrain_losses: losses, info, den })
    }

    /// Collect calibration data for a prepared model (x0 pool from the
    /// corpus itself, per Q-Diffusion's calibration-set construction).
    pub fn calibrate(&self, p: &Prepared) -> Result<Vec<LayerCalib>> {
        let mut rng = Rng::new(11);
        let ae = PatchAutoencoder::default();
        let n = 16;
        let (x0, _) = crate::train::pretrain::corpus_batch(p.corpus, &p.info, &ae, &mut rng, n);
        collect_calibration(
            &p.den,
            &p.info,
            &self.sched,
            &p.params,
            &x0,
            self.scale.calib_rounds,
            p.info.cfg.n_classes,
            &mut rng,
        )
    }

    /// State directory for a named serving deployment under the runs dir
    /// (`StateDir` layout: `quant.mts` + `sketches.msk`). Save the served
    /// `QuantState` to `dir.quant_path()` and hand the dir to
    /// `ServeRecal::with_state_dir`, and a restarted coordinator resumes
    /// both the last hot-swapped qparams and its drift window.
    pub fn serving_state_dir(&self, tag: &str) -> StateDir {
        StateDir::new(self.runs_dir.join(format!("serve_{tag}")))
    }

    /// Build a reusable quantization search session for a prepared model:
    /// calibration data plus the per-tensor grid engines, so every
    /// method spec and sweep point re-scores against one preprocessing
    /// pass (`quant::session`).
    pub fn build_session(&self, p: &Prepared) -> Result<QuantSession<'static>> {
        let calib = self.calibrate(p)?;
        let store = ParamStore::from_vec(&p.info, p.params.clone())?;
        let weights = store.layer_weights(&p.info)?;
        Ok(QuantSession::from_owned(weights, calib))
    }

    /// Quantize per a method spec (and optionally fine-tune). One-shot
    /// compatibility wrapper over [`Pipeline::quantize_with_session`];
    /// callers evaluating several specs should share a session instead.
    pub fn quantize(
        &self,
        p: &Prepared,
        spec: &MethodSpec,
        calib: &[LayerCalib],
    ) -> Result<Quantized> {
        let store = ParamStore::from_vec(&p.info, p.params.clone())?;
        let weights = store.layer_weights(&p.info)?;
        let session = QuantSession::new(&weights, calib);
        self.quantize_with_session(p, &session, spec)
    }

    /// The PTQ half of a method spec: resolve the search knobs, run (or
    /// replay) the initialization against the session, and assemble the
    /// pre-fine-tune `QuantState`.
    fn search_spec(
        &self,
        p: &Prepared,
        session: &QuantSession<'_>,
        spec: &MethodSpec,
    ) -> Result<(QuantOpts, QuantScheme, QuantState)> {
        let method = spec.method.expect("quantize() requires a quantization method");
        let info = &p.info;
        let mut opts = QuantOpts::new(method, info.n_layers, spec.wbits, spec.abits)
            .with_io_8bit(&info.io_layer_indices());
        if spec.partial {
            // Table 11 "partial quantization": skip/up/down layers at 8-bit
            let skip = info.skip_layer_indices();
            opts = opts.with_io_8bit(&skip);
        }
        let scheme = session.quantize(&opts);
        log_info!(
            "quantized {} [{}] w{}a{}: {} AALs, unsigned on {:.0}%",
            p.corpus.name(),
            spec.label,
            spec.wbits,
            spec.abits,
            scheme.n_aal(),
            scheme.unsigned_fraction_on_aals() * 100.0
        );

        let mut rng = Rng::new(23);
        let lora = LoraHub::init(info, &mut rng);
        let router_flat = rng.normal_vec(info.router_size, 0.05);
        let state = QuantState {
            qparams: scheme.qparams_rows(),
            lora: lora.flat,
            router: Router::new(info, router_flat)?,
            hub_mask: spec.alloc.hub_mask(
                info.cfg.lora_hub,
                spec.finetune.as_ref().map(|f| f.h).unwrap_or(1),
            ),
            strategy: spec.alloc,
            t_total: self.sched.t_total,
        };
        Ok((opts, scheme, state))
    }

    /// The FP-rollout trajectory buffer the fine-tune loop trains on.
    fn collect_traj(&self, p: &Prepared) -> Result<TrajectoryBuffer> {
        let tau = timestep_subsequence(self.sched.t_total, self.scale.steps);
        let mut rng = Rng::new(31);
        TrajectoryBuffer::collect(
            &p.den,
            &p.info,
            &self.sched,
            &tau,
            &p.params,
            self.scale.traj_samples,
            p.info.cfg.n_classes,
            &mut rng,
        )
    }

    /// Quantize per a method spec against a pre-built session (and
    /// optionally fine-tune). The session is shared read-only; for the
    /// recalibrate-while-tuning variant see [`Pipeline::quantize_recal`].
    pub fn quantize_with_session(
        &self,
        p: &Prepared,
        session: &QuantSession<'_>,
        spec: &MethodSpec,
    ) -> Result<Quantized> {
        let info = &p.info;
        let (_opts, scheme, mut state) = self.search_spec(p, session, spec)?;
        let ft_stats = if let Some(ft) = &spec.finetune {
            let traj = self.collect_traj(p)?;
            let mut lora_flat = state.lora.clone();
            let mut router_flat = state.router.flat.clone();
            let mut cfg = ft.clone();
            cfg.epochs = cfg.epochs.max(1);
            let stats = finetune(
                &self.engine,
                info,
                &self.sched,
                &traj,
                &p.params,
                &state.qparams,
                &mut lora_flat,
                &mut router_flat,
                &cfg,
            )?;
            state.lora = lora_flat;
            state.router = Router::new(info, router_flat)?;
            Some(stats)
        } else {
            None
        };
        Ok(Quantized { scheme, state, ft_stats })
    }

    /// [`Pipeline::quantize_with_session`] with the online-recalibration
    /// cadence: when the spec's `FinetuneCfg::recal_every > 0`, the
    /// fine-tune loop probes for activation drift every `recal_every`
    /// epochs, applies `QuantSession::update_layer_calib` to drifted
    /// layers and continues training on the re-searched qparams
    /// (`recal` module; EfficientDM-style recalibrate-while-tuning).
    /// Takes the session mutably because applied updates advance its
    /// calibration baseline; don't share one session between a recal run
    /// and unrelated sweep points afterwards.
    pub fn quantize_recal(
        &self,
        p: &Prepared,
        session: &mut QuantSession<'static>,
        spec: &MethodSpec,
    ) -> Result<Quantized> {
        let info = &p.info;
        let (opts, mut scheme, mut state) = self.search_spec(p, &*session, spec)?;
        let ft_stats = if let Some(ft) = &spec.finetune {
            let traj = self.collect_traj(p)?;
            let mut lora_flat = state.lora.clone();
            let mut router_flat = state.router.flat.clone();
            let mut qparams = state.qparams.clone();
            let mut cfg = ft.clone();
            cfg.epochs = cfg.epochs.max(1);
            let recal_ctx = if cfg.recal_every > 0 {
                Some(FinetuneRecal::new(&p.den, &mut *session, opts.clone()))
            } else {
                None
            };
            let stats = finetune_recal(
                &self.engine,
                info,
                &self.sched,
                &traj,
                &p.params,
                &mut qparams,
                &mut lora_flat,
                &mut router_flat,
                &cfg,
                recal_ctx,
            )?;
            if !stats.recal_events.is_empty() {
                // replay (memoized) so the returned scheme matches the
                // recalibrated qparams the state now carries
                scheme = session.quantize(&opts);
            }
            state.qparams = qparams;
            state.lora = lora_flat;
            state.router = Router::new(info, router_flat)?;
            Some(stats)
        } else {
            None
        };
        Ok(Quantized { scheme, state, ft_stats })
    }

    /// Generate + evaluate a method spec end to end; FP spec short-circuits
    /// the quantization stages. Builds a one-shot session for quantized
    /// specs — table runners evaluating several specs share one via
    /// [`Pipeline::evaluate_spec_with_session`].
    pub fn evaluate_spec(
        &self,
        p: &Prepared,
        spec: &MethodSpec,
        sampler: SamplerKind,
        eta: f32,
        seed: u64,
    ) -> Result<(EvalResult, Option<Quantized>)> {
        self.eval_spec_inner(p, None, spec, sampler, eta, seed)
    }

    /// [`Pipeline::evaluate_spec`] against a pre-built session (FP specs
    /// ignore it).
    pub fn evaluate_spec_with_session(
        &self,
        p: &Prepared,
        session: &QuantSession<'_>,
        spec: &MethodSpec,
        sampler: SamplerKind,
        eta: f32,
        seed: u64,
    ) -> Result<(EvalResult, Option<Quantized>)> {
        self.eval_spec_inner(p, Some(session), spec, sampler, eta, seed)
    }

    fn eval_spec_inner(
        &self,
        p: &Prepared,
        session: Option<&QuantSession<'_>>,
        spec: &MethodSpec,
        sampler: SamplerKind,
        eta: f32,
        seed: u64,
    ) -> Result<(EvalResult, Option<Quantized>)> {
        let fx = FeatureExtractor::new(&self.engine, &self.manifest.features, p.corpus.hw())?;
        let refs = reference_stats(&fx, p.corpus, self.scale.ref_n, 17)?;
        let gen_cfg = GenerateCfg {
            n: self.scale.eval_n,
            steps: self.scale.steps,
            eta,
            sampler,
            seed,
        };
        let (q, mode_images) = if spec.method.is_none() {
            let (px, _) = generate_images(
                &p.den, &p.info, &self.sched, p.corpus, &p.params, ModelMode::Fp, &gen_cfg,
            )?;
            (None, px)
        } else {
            let built;
            let session = match session {
                Some(s) => s,
                None => {
                    built = self.build_session(p)?;
                    &built
                }
            };
            let q = self.quantize_with_session(p, session, spec)?;
            let (px, _) = generate_images(
                &p.den,
                &p.info,
                &self.sched,
                p.corpus,
                &p.params,
                ModelMode::Quant(&q.state),
                &gen_cfg,
            )?;
            (Some(q), px)
        };
        let result = evaluate(&fx, &refs, &mode_images, gen_cfg.n)?;
        log_info!("eval {} [{}]: {}", p.corpus.name(), spec.label, result.row());
        Ok((result, q))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn full_fast_pipeline_ddim16() {
        let dir = Pipeline::default_artifacts_dir();
        if !dir.join("manifest.json").exists() {
            crate::log_warn!("skipping: artifacts not built");
            return;
        }
        let mut scale = Scale::fast();
        scale.pretrain_steps = 25;
        scale.eval_n = 40;
        scale.ref_n = 64;
        scale.steps = 5;
        scale.traj_samples = 4;
        scale.ft_epochs = 1;
        scale.calib_rounds = 2;
        // isolated runs dir so the cached checkpoint doesn't leak between
        // test configurations
        std::env::set_var("MSFP_RUNS", std::env::temp_dir().join("msfp_test_runs"));
        let pl = Pipeline::new(&dir, scale).unwrap();
        let p = pl.prepare(Corpus::CelebaSyn).unwrap();
        assert!(!p.pretrain_losses.is_empty());

        // FP eval
        let (fp, _) = pl
            .evaluate_spec(&p, &MethodSpec::fp(), SamplerKind::Ddim, 0.0, 1)
            .unwrap();
        // ours 4-bit with 1-epoch finetune
        let (ours, q) = pl
            .evaluate_spec(&p, &MethodSpec::ours(4, 2, 1), SamplerKind::Ddim, 0.0, 1)
            .unwrap();
        assert!(fp.fid.is_finite() && ours.fid.is_finite());
        let q = q.unwrap();
        assert!(q.scheme.n_aal() > 0, "UNet must expose AALs");
        assert!(q.ft_stats.is_some());

        // recalibrate-while-tuning entry point: same spec with the drift
        // cadence enabled, driven against a mutable session
        let mut session = pl.build_session(&p).unwrap();
        let mut spec = MethodSpec::ours(4, 2, 2);
        spec.finetune.as_mut().unwrap().recal_every = 1;
        let qr = pl.quantize_recal(&p, &mut session, &spec).unwrap();
        let stats = qr.ft_stats.unwrap();
        assert!(stats.losses.iter().all(|l| l.is_finite()));
        assert_eq!(qr.state.qparams.len(), p.info.n_layers * 8);
        // scheme and served qparams stay consistent whether or not any
        // layer actually crossed the drift threshold on this tiny budget
        assert_eq!(qr.scheme.qparams_rows(), qr.state.qparams);
        std::env::remove_var("MSFP_RUNS");
    }
}
