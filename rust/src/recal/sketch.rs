//! Streaming per-layer activation sketches — the *producer* stage of the
//! online recalibration pipeline (sketch → drift → plan → swap).
//!
//! A [`SketchSet`] maintains one [`LayerSketch`] per (layer, timestep
//! bucket): a fixed-capacity reservoir sample plus running min/max and
//! first/second moments. Feeding is O(samples) with no allocation past the
//! reservoir capacity, so producers (the TALoRA fine-tune loop's probe
//! batches, a serving-side monitor) can push from every
//! `Denoiser::calib_forward` without budget concerns.
//!
//! Timestep buckets keep the retained sample balanced across the denoising
//! process: a reservoir over the raw stream would be dominated by whatever
//! timesteps the producer visited most recently, while per-bucket
//! reservoirs give every phase of the process a fixed share of the
//! retained samples (the timestep-aware angle of the paper carried into
//! calibration maintenance). Drift scoring and plan construction merge the
//! buckets back into one per-layer view ([`SketchSet::layer_merged`]).
//!
//! Sketches are mergeable ([`LayerSketch::merge`]): min/max/moments
//! combine exactly; the merged reservoir is re-drawn from the two inputs
//! with probability proportional to their observed counts (sampling with
//! replacement — an approximation of a true distributed reservoir that is
//! ample for drift detection). Everything is deterministic from the
//! construction seed.
//!
//! Sketches are also *persistent*: [`SketchSet::save`]/[`SketchSet::load`]
//! write a versioned binary snapshot (exact min/max, f64 moments, the full
//! reservoir contents *and the reservoir rng cursor*), so a restarted
//! server resumes its drift window bit-exactly — the loaded set feeds,
//! merges and plans exactly like the one that was saved, including
//! widen-only buckets that carry extrema but no samples.

use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::util::rng::Rng;

/// Magic + version of the sketch snapshot format. Bump the trailing two
/// digits on any layout change; `load` rejects both foreign files and
/// newer/older versions with distinct errors.
const SKETCH_MAGIC: &[u8; 8] = b"MSFPSK01";

/// Streaming summary of one (layer, timestep-bucket) activation stream.
#[derive(Debug, Clone, PartialEq)]
pub struct LayerSketch {
    /// reservoir sample of the stream (≤ capacity values)
    res: Vec<f32>,
    cap: usize,
    /// values observed (not retained) so far
    count: usize,
    pub min: f32,
    pub max: f32,
    sum: f64,
    sumsq: f64,
    rng: Rng,
}

impl LayerSketch {
    pub fn new(cap: usize, seed: u64) -> LayerSketch {
        LayerSketch {
            res: Vec::with_capacity(cap.min(1024)),
            cap: cap.max(1),
            count: 0,
            min: f32::INFINITY,
            max: f32::NEG_INFINITY,
            sum: 0.0,
            sumsq: 0.0,
            rng: Rng::new(seed ^ 0x736b6574),
        }
    }

    /// Observed stream length (reservoir holds `min(count, cap)` of them).
    pub fn count(&self) -> usize {
        self.count
    }

    /// Reservoir capacity (the most samples this sketch retains).
    pub fn cap(&self) -> usize {
        self.cap
    }

    /// True while the reservoir still holds *every* observed value — the
    /// stream has not outgrown the capacity, so the retained sample is
    /// exact, not a subsample. The canonical fleet merge
    /// ([`SketchSet::merge_canonical`]) relies on this to rebuild
    /// partition-invariant reservoirs.
    pub fn is_lossless(&self) -> bool {
        self.count == self.res.len()
    }

    /// The retained reservoir sample.
    pub fn samples(&self) -> &[f32] {
        &self.res
    }

    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        self.sum / self.count as f64
    }

    pub fn var(&self) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        let m = self.mean();
        (self.sumsq / self.count as f64 - m * m).max(0.0)
    }

    /// Feed one value (Algorithm R reservoir update + running stats).
    pub fn push(&mut self, x: f32) {
        self.count += 1;
        self.min = self.min.min(x);
        self.max = self.max.max(x);
        self.sum += x as f64;
        self.sumsq += (x as f64) * (x as f64);
        if self.res.len() < self.cap {
            self.res.push(x);
        } else {
            let j = self.rng.below(self.count);
            if j < self.cap {
                self.res[j] = x;
            }
        }
    }

    /// Widen min/max without adding samples (exact per-batch extrema from
    /// `calib_forward`'s `[L, 2]` output cover values the subsampled
    /// activation capture missed).
    pub fn widen(&mut self, min: f32, max: f32) {
        if min <= max {
            self.min = self.min.min(min);
            self.max = self.max.max(max);
        }
    }

    /// Merge `other` into `self`. Counts, extrema and moments combine
    /// exactly; the merged reservoir re-draws from both inputs with
    /// probability proportional to their counts (see module docs).
    pub fn merge(&mut self, other: &LayerSketch) {
        // extrema merge first and unconditionally: a widen-only sketch
        // (count 0 but min/max set) still carries exact bounds that must
        // survive the cross-bucket merge
        self.widen(other.min, other.max);
        if other.count == 0 {
            return;
        }
        if self.count == 0 {
            // adopt other's data but keep this sketch's capacity and rng
            // stream (layer_merged builds wide empty sketches and folds
            // narrower per-bucket ones in)
            self.res = other.res.clone();
            self.res.truncate(self.cap);
            self.count = other.count;
            self.sum = other.sum;
            self.sumsq = other.sumsq;
            return;
        }
        let total = self.count + other.count;
        let k = self.cap.min(self.res.len() + other.res.len());
        let mut merged = Vec::with_capacity(k);
        for _ in 0..k {
            let from_self = self.rng.below(total) < self.count;
            let v = if from_self {
                self.res[self.rng.below(self.res.len())]
            } else {
                other.res[self.rng.below(other.res.len())]
            };
            merged.push(v);
        }
        self.res = merged;
        self.count = total;
        self.sum += other.sum;
        self.sumsq += other.sumsq;
    }

    /// Append this sketch's exact binary image (see [`SketchSet::save`]).
    fn write_to(&self, out: &mut Vec<u8>) {
        out.extend_from_slice(&(self.cap as u64).to_le_bytes());
        out.extend_from_slice(&(self.count as u64).to_le_bytes());
        out.extend_from_slice(&self.min.to_bits().to_le_bytes());
        out.extend_from_slice(&self.max.to_bits().to_le_bytes());
        out.extend_from_slice(&self.sum.to_bits().to_le_bytes());
        out.extend_from_slice(&self.sumsq.to_bits().to_le_bytes());
        for w in self.rng.snapshot() {
            out.extend_from_slice(&w.to_le_bytes());
        }
        out.extend_from_slice(&(self.res.len() as u64).to_le_bytes());
        for v in &self.res {
            out.extend_from_slice(&v.to_bits().to_le_bytes());
        }
    }

    fn read_from(r: &mut ByteReader<'_>) -> Result<LayerSketch> {
        let cap = r.u64()? as usize;
        let count = r.u64()? as usize;
        let min = f32::from_bits(r.u32()?);
        let max = f32::from_bits(r.u32()?);
        let sum = f64::from_bits(r.u64()?);
        let sumsq = f64::from_bits(r.u64()?);
        let rng = Rng::restore([r.u64()?, r.u64()?, r.u64()?, r.u64()?]);
        let res_len = r.u64()? as usize;
        if cap == 0 || res_len > cap || res_len > count || res_len > r.remaining() / 4 {
            bail!("corrupt sketch snapshot: cap {cap}, reservoir {res_len}, count {count}");
        }
        let mut res = Vec::with_capacity(res_len);
        for _ in 0..res_len {
            res.push(f32::from_bits(r.u32()?));
        }
        Ok(LayerSketch { res, cap, count, min, max, sum, sumsq, rng })
    }
}

/// Minimal bounds-checked little-endian cursor over a snapshot buffer.
struct ByteReader<'a> {
    bytes: &'a [u8],
    off: usize,
}

impl<'a> ByteReader<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.off + n > self.bytes.len() {
            bail!("truncated sketch snapshot at byte {}", self.off);
        }
        let s = &self.bytes[self.off..self.off + n];
        self.off += n;
        Ok(s)
    }

    fn u32(&mut self) -> Result<u32> {
        let b = self.take(4)?;
        Ok(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }

    fn u64(&mut self) -> Result<u64> {
        let b = self.take(8)?;
        Ok(u64::from_le_bytes([b[0], b[1], b[2], b[3], b[4], b[5], b[6], b[7]]))
    }

    fn remaining(&self) -> usize {
        self.bytes.len() - self.off
    }
}

/// Result of [`SketchSet::merge_canonical`]: the fleet-merged window plus
/// how many (layer, bucket) positions fell back to the order-sensitive
/// sequential merge because an input reservoir had already truncated.
#[derive(Debug, Clone)]
pub struct FleetMerged {
    pub window: SketchSet,
    pub lossy_positions: usize,
}

/// Whole-model sketch store: `n_layers × n_buckets` layer sketches, keyed
/// by layer index and the timestep bucket `floor(t / t_total · n_buckets)`.
#[derive(Debug, Clone, PartialEq)]
pub struct SketchSet {
    sketches: Vec<LayerSketch>,
    n_layers: usize,
    n_buckets: usize,
    t_total: usize,
}

impl SketchSet {
    /// `cap` is the per-(layer, bucket) reservoir capacity; the retained
    /// per-layer sample used for drift/recalibration is up to
    /// `cap · n_buckets` values.
    pub fn new(
        n_layers: usize,
        n_buckets: usize,
        cap: usize,
        t_total: usize,
        seed: u64,
    ) -> SketchSet {
        let n_buckets = n_buckets.max(1);
        let sketches = (0..n_layers * n_buckets)
            .map(|i| LayerSketch::new(cap, seed.wrapping_add(0x9E37 * i as u64 + 1)))
            .collect();
        SketchSet { sketches, n_layers, n_buckets, t_total: t_total.max(1) }
    }

    pub fn n_layers(&self) -> usize {
        self.n_layers
    }

    pub fn n_buckets(&self) -> usize {
        self.n_buckets
    }

    /// Timestep horizon the bucket index is computed against.
    pub fn t_total(&self) -> usize {
        self.t_total
    }

    fn bucket_of(&self, t: f32) -> usize {
        let frac = (t / self.t_total as f32).clamp(0.0, 1.0);
        ((frac * self.n_buckets as f32) as usize).min(self.n_buckets - 1)
    }

    pub fn sketch(&self, layer: usize, bucket: usize) -> &LayerSketch {
        &self.sketches[layer * self.n_buckets + bucket]
    }

    /// Feed one layer's activation samples observed at timestep `t`.
    pub fn observe(&mut self, layer: usize, t: f32, samples: &[f32]) {
        let b = self.bucket_of(t);
        let sk = &mut self.sketches[layer * self.n_buckets + b];
        for &x in samples {
            sk.push(x);
        }
    }

    /// Feed a whole `Denoiser::calib_forward` output captured at (uniform)
    /// timestep `t`: `acts` is the `[L, S]` per-layer activation capture,
    /// `mm` the `[L, 2]` exact per-layer min/max.
    pub fn observe_calib(&mut self, t: f32, acts: &[f32], mm: &[f32], act_samples: usize) {
        debug_assert_eq!(acts.len(), self.n_layers * act_samples);
        debug_assert_eq!(mm.len(), self.n_layers * 2);
        let b = self.bucket_of(t);
        for l in 0..self.n_layers {
            let sk = &mut self.sketches[l * self.n_buckets + b];
            for &x in &acts[l * act_samples..(l + 1) * act_samples] {
                sk.push(x);
            }
            sk.widen(mm[l * 2], mm[l * 2 + 1]);
        }
    }

    /// Widen layer `l`'s extrema at timestep `t` without adding samples
    /// (exact per-batch min/max from a producer whose sample capture is
    /// subsampled — see [`LayerSketch::widen`]).
    pub fn widen_layer(&mut self, l: usize, t: f32, min: f32, max: f32) {
        let b = self.bucket_of(t);
        self.sketches[l * self.n_buckets + b].widen(min, max);
    }

    /// Total observed samples for layer `l` across buckets.
    pub fn layer_count(&self, l: usize) -> usize {
        (0..self.n_buckets).map(|b| self.sketch(l, b).count()).sum()
    }

    /// One cross-bucket view of layer `l` (for drift scoring and plan
    /// construction). The merged reservoir holds up to `cap · n_buckets`
    /// values, each bucket contributing in proportion to its share of the
    /// observed stream.
    pub fn layer_merged(&self, l: usize) -> LayerSketch {
        let total_cap: usize = (0..self.n_buckets).map(|b| self.sketch(l, b).cap).sum();
        let mut out = LayerSketch::new(total_cap, 0xACC + l as u64);
        for b in 0..self.n_buckets {
            out.merge(self.sketch(l, b));
        }
        out
    }

    /// Verify `other` has this set's (layer, bucket) layout. Distinct
    /// errors per axis so a fleet aggregator can report exactly how a
    /// stale or foreign shard window disagrees.
    pub fn check_layout(&self, other: &SketchSet) -> Result<()> {
        if self.n_layers != other.n_layers {
            bail!(
                "sketch-set layer-layout mismatch: {} vs {} layers",
                self.n_layers,
                other.n_layers
            );
        }
        if self.n_buckets != other.n_buckets {
            bail!(
                "sketch-set bucket-layout mismatch: {} vs {} buckets",
                self.n_buckets,
                other.n_buckets
            );
        }
        Ok(())
    }

    /// Merge another producer's observations into this set, sketch by
    /// sketch. Extrema, counts and moments combine exactly; reservoirs
    /// re-draw per [`LayerSketch::merge`], driven by *this* set's rng
    /// cursors — so merging into a loaded snapshot draws identically to
    /// merging into the original. A (layer, bucket) layout mismatch is an
    /// error (`check_layout`), not a panic: a malformed peer snapshot
    /// must never take down the consumer.
    pub fn merge(&mut self, other: &SketchSet) -> Result<()> {
        self.check_layout(other)?;
        for (a, b) in self.sketches.iter_mut().zip(&other.sketches) {
            a.merge(b);
        }
        Ok(())
    }

    /// Canonical *partition-invariant* merge of per-shard windows — the
    /// fleet aggregator's primitive. The sequential [`SketchSet::merge`]
    /// is order-sensitive twice over (reservoir redraw consumes the rng;
    /// f64 moment sums group differently per partition), so a 2-shard
    /// and a 4-shard split of the same traffic would disagree bitwise.
    /// This merge instead rebuilds each (layer, bucket) position from the
    /// *sorted union* of every input's retained samples: counts, extrema
    /// and moments accumulate in canonical sorted order, and the rebuilt
    /// reservoir is either the union itself (when it fits the capacity)
    /// or a fresh deterministic Algorithm-R pass over the sorted stream —
    /// in both cases a pure function of the union multiset, not of how
    /// traffic was sharded.
    ///
    /// The invariance contract holds while every contributing sketch is
    /// still lossless ([`LayerSketch::is_lossless`] — count ≤ capacity,
    /// the drift-window regime the prober's budget keeps us in). A
    /// position where some input already truncated its reservoir falls
    /// back to the sequential redraw (still deterministic in input order)
    /// and is counted in [`FleetMerged::lossy_positions`].
    ///
    /// Layouts must agree with `windows[0]`; a mismatch is an error so
    /// the aggregator can skip the offending shard. Empty input is an
    /// error (there is no layout to adopt).
    pub fn merge_canonical(windows: &[&SketchSet]) -> Result<FleetMerged> {
        let first = *windows.first().ok_or_else(|| anyhow::anyhow!("no windows to merge"))?;
        for w in &windows[1..] {
            first.check_layout(w)?;
        }
        let cap = first.sketches.iter().map(|s| s.cap).max().unwrap_or(1);
        let mut out =
            SketchSet::new(first.n_layers, first.n_buckets, cap, first.t_total, 0xF1EE7);
        let mut lossy_positions = 0usize;
        let mut union: Vec<f32> = Vec::new();
        for (i, sk) in out.sketches.iter_mut().enumerate() {
            let inputs: Vec<&LayerSketch> = windows.iter().map(|w| &w.sketches[i]).collect();
            if inputs.iter().all(|s| s.is_lossless()) {
                union.clear();
                for s in &inputs {
                    union.extend_from_slice(s.samples());
                }
                union.sort_unstable_by(|a, b| a.total_cmp(b));
                for &x in &union {
                    sk.push(x);
                }
            } else {
                lossy_positions += 1;
                for s in &inputs {
                    sk.merge(s);
                }
            }
            // exact extrema always transfer — they cover widen-only
            // inputs and values a truncated reservoir dropped
            for s in &inputs {
                sk.widen(s.min, s.max);
            }
        }
        Ok(FleetMerged { window: out, lossy_positions })
    }

    /// Drop all observed data (fresh drift window), keeping the layout.
    pub fn reset(&mut self) {
        for sk in &mut self.sketches {
            let fresh = LayerSketch::new(sk.cap, 0);
            let rng = sk.rng.clone();
            *sk = fresh;
            sk.rng = rng;
        }
    }

    /// Exact binary snapshot of the whole set: layout, per-sketch min/max
    /// bits, f64 moment bits, reservoir contents and the reservoir rng
    /// cursor. `from_bytes(to_bytes(s)) == s` bit-for-bit, so a restored
    /// set continues feeding/merging exactly where the saved one stopped.
    pub fn to_bytes(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(64 + self.sketches.len() * 64);
        out.extend_from_slice(SKETCH_MAGIC);
        out.extend_from_slice(&(self.n_layers as u32).to_le_bytes());
        out.extend_from_slice(&(self.n_buckets as u32).to_le_bytes());
        out.extend_from_slice(&(self.t_total as u64).to_le_bytes());
        for sk in &self.sketches {
            sk.write_to(&mut out);
        }
        out
    }

    /// Parse a [`SketchSet::to_bytes`] snapshot. Foreign files and other
    /// format versions are rejected with distinct errors.
    pub fn from_bytes(bytes: &[u8]) -> Result<SketchSet> {
        let mut r = ByteReader { bytes, off: 0 };
        let magic = r.take(8)?;
        if magic != SKETCH_MAGIC {
            if magic[..6] == SKETCH_MAGIC[..6] {
                bail!(
                    "unsupported sketch snapshot version {:?} (this build reads {:?})",
                    String::from_utf8_lossy(&magic[6..]),
                    String::from_utf8_lossy(&SKETCH_MAGIC[6..]),
                );
            }
            bail!("not a sketch snapshot (bad magic)");
        }
        let n_layers = r.u32()? as usize;
        let n_buckets = r.u32()? as usize;
        let t_total = r.u64()? as usize;
        let n = n_layers
            .checked_mul(n_buckets)
            .filter(|&n| n <= 1 << 20)
            .ok_or_else(|| anyhow::anyhow!("corrupt sketch snapshot: {n_layers}x{n_buckets}"))?;
        if n_buckets == 0 || t_total == 0 {
            bail!("corrupt sketch snapshot: zero buckets or t_total");
        }
        let mut sketches = Vec::with_capacity(n);
        for _ in 0..n {
            sketches.push(LayerSketch::read_from(&mut r)?);
        }
        if r.off != bytes.len() {
            bail!("trailing bytes in sketch snapshot ({} past end)", bytes.len() - r.off);
        }
        Ok(SketchSet { sketches, n_layers, n_buckets, t_total })
    }

    /// Persist the drift window next to the serving `QuantState` (see
    /// `quant::msfp::StateDir`); [`SketchSet::load`] restores it on server
    /// start. Atomic (temp + rename), so a kill mid-checkpoint can never
    /// leave a torn snapshot — the restart-resume guarantee depends on it.
    pub fn save(&self, path: &Path) -> Result<()> {
        crate::util::io::atomic_write(path, &self.to_bytes())
            .with_context(|| format!("writing sketch snapshot {}", path.display()))
    }

    /// Restore a persisted window. Routed through the fault-aware reader
    /// (`util::io::read_file_retry`) so an installed `FaultFs` can inject
    /// transient restore failures; real transient errors retry under the
    /// same cap.
    pub fn load(path: &Path) -> Result<SketchSet> {
        let bytes = crate::util::io::read_file_retry(path, crate::util::io::RESTORE_ATTEMPTS)
            .with_context(|| format!("reading sketch snapshot {}", path.display()))?;
        SketchSet::from_bytes(&bytes).with_context(|| format!("parsing {}", path.display()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reservoir_caps_and_counts() {
        let mut sk = LayerSketch::new(32, 7);
        for i in 0..1000 {
            sk.push(i as f32);
        }
        assert_eq!(sk.count(), 1000);
        assert_eq!(sk.samples().len(), 32);
        assert_eq!(sk.min, 0.0);
        assert_eq!(sk.max, 999.0);
        assert!((sk.mean() - 499.5).abs() < 1e-6);
        // retained values are a plausible spread, not just the head
        assert!(sk.samples().iter().any(|&v| v > 500.0));
    }

    #[test]
    fn widen_extends_extrema_only() {
        let mut sk = LayerSketch::new(8, 1);
        sk.push(0.5);
        sk.widen(-2.0, 3.0);
        assert_eq!(sk.min, -2.0);
        assert_eq!(sk.max, 3.0);
        assert_eq!(sk.count(), 1);
        sk.widen(5.0, 4.0); // inverted pair ignored
        assert_eq!(sk.max, 3.0);
    }

    #[test]
    fn merge_combines_counts_extrema_moments() {
        let mut a = LayerSketch::new(16, 2);
        let mut b = LayerSketch::new(16, 3);
        for i in 0..100 {
            a.push(i as f32 * 0.01);
            b.push(1.0 + i as f32 * 0.01);
        }
        let (sa, sb) = (a.sum, b.sum);
        a.merge(&b);
        assert_eq!(a.count(), 200);
        assert_eq!(a.min, 0.0);
        assert!((a.max - 1.99).abs() < 1e-6);
        assert!((a.sum - (sa + sb)).abs() < 1e-9);
        assert_eq!(a.samples().len(), 16);
        // merged reservoir draws from both sides
        assert!(a.samples().iter().any(|&v| v >= 1.0));
        assert!(a.samples().iter().any(|&v| v < 1.0));
    }

    #[test]
    fn merge_into_empty_adopts_other() {
        let mut a = LayerSketch::new(8, 4);
        let mut b = LayerSketch::new(8, 5);
        for i in 0..20 {
            b.push(i as f32);
        }
        a.merge(&b);
        assert_eq!(a.count(), 20);
        assert_eq!(a.samples().len(), 8);
        let empty = LayerSketch::new(8, 6);
        let before = a.count();
        a.merge(&empty); // no-op
        assert_eq!(a.count(), before);
    }

    #[test]
    fn buckets_split_by_timestep() {
        let mut set = SketchSet::new(2, 4, 64, 100, 9);
        set.observe(0, 10.0, &[1.0, 2.0]); // bucket 0
        set.observe(0, 90.0, &[5.0]); // bucket 3
        set.observe(1, 55.0, &[7.0]); // bucket 2
        assert_eq!(set.sketch(0, 0).count(), 2);
        assert_eq!(set.sketch(0, 3).count(), 1);
        assert_eq!(set.sketch(1, 2).count(), 1);
        assert_eq!(set.layer_count(0), 3);
        let merged = set.layer_merged(0);
        assert_eq!(merged.count(), 3);
        assert_eq!(merged.min, 1.0);
        assert_eq!(merged.max, 5.0);
    }

    #[test]
    fn observe_calib_layout_and_widen() {
        let mut set = SketchSet::new(3, 2, 16, 100, 1);
        let acts = vec![0.1, 0.2, 1.1, 1.2, 2.1, 2.2]; // [3, 2]
        let mm = vec![-1.0, 1.0, -2.0, 2.0, -3.0, 3.0]; // [3, 2]
        set.observe_calib(20.0, &acts, &mm, 2);
        for l in 0..3 {
            let sk = set.sketch(l, 0);
            assert_eq!(sk.count(), 2);
            assert_eq!(sk.min, -(l as f32 + 1.0));
            assert_eq!(sk.max, l as f32 + 1.0);
        }
    }

    #[test]
    fn widen_only_bucket_survives_layer_merge() {
        // a bucket that only ever saw exact extrema (no samples) must still
        // contribute them to the merged per-layer view
        let mut set = SketchSet::new(1, 4, 8, 100, 2);
        set.observe(0, 80.0, &[0.1, 0.2]); // bucket 3
        set.widen_layer(0, 5.0, -7.0, 9.0); // bucket 0, extrema only
        let merged = set.layer_merged(0);
        assert_eq!(merged.count(), 2);
        assert_eq!(merged.min, -7.0);
        assert_eq!(merged.max, 9.0);
    }

    #[test]
    fn reset_clears_data_keeps_layout() {
        let mut set = SketchSet::new(2, 2, 8, 100, 3);
        set.observe(0, 5.0, &[1.0; 20]);
        set.reset();
        assert_eq!(set.layer_count(0), 0);
        assert_eq!(set.n_layers(), 2);
        set.observe(0, 5.0, &[2.0; 4]);
        assert_eq!(set.layer_count(0), 4);
    }

    #[test]
    fn snapshot_roundtrip_is_bit_exact_and_resumes() {
        let mut set = SketchSet::new(3, 4, 16, 100, 21);
        let mut rng = Rng::new(9);
        for _ in 0..400 {
            let l = rng.below(3);
            let t = rng.range(0.0, 100.0);
            set.observe(l, t, &[rng.normal(), rng.normal()]);
        }
        set.widen_layer(2, 3.0, -50.0, 50.0); // widen-only bucket
        let bytes = set.to_bytes();
        let loaded = SketchSet::from_bytes(&bytes).unwrap();
        assert_eq!(loaded, set);
        assert_eq!(loaded.to_bytes(), bytes, "re-serialization must be stable");
        // the rng cursor survived: both continue with identical reservoir
        // replacement decisions from here on
        let mut a = set;
        let mut b = loaded;
        for i in 0..200 {
            let v = [i as f32 * 0.3 - 20.0];
            a.observe(0, 42.0, &v);
            b.observe(0, 42.0, &v);
        }
        assert_eq!(a.to_bytes(), b.to_bytes());
    }

    #[test]
    fn snapshot_file_roundtrip() {
        let mut set = SketchSet::new(2, 2, 8, 50, 5);
        set.observe(1, 30.0, &[1.0, -2.0, 0.5]);
        let path = std::env::temp_dir().join("msfp_sketch_roundtrip.msk");
        set.save(&path).unwrap();
        assert_eq!(SketchSet::load(&path).unwrap(), set);
    }

    #[test]
    fn snapshot_rejects_foreign_and_versioned_files() {
        let set = SketchSet::new(1, 1, 4, 10, 1);
        let bytes = set.to_bytes();
        // foreign magic
        let mut junk = bytes.clone();
        junk[..8].copy_from_slice(b"NOTMAGIC");
        let err = SketchSet::from_bytes(&junk).unwrap_err();
        assert!(err.to_string().contains("bad magic"), "{err}");
        // same family, different version digits
        let mut v99 = bytes.clone();
        v99[6..8].copy_from_slice(b"99");
        let err = SketchSet::from_bytes(&v99).unwrap_err();
        assert!(err.to_string().contains("version"), "{err}");
        // truncation
        assert!(SketchSet::from_bytes(&bytes[..bytes.len() - 3]).is_err());
        // trailing garbage
        let mut long = bytes;
        long.push(0);
        assert!(SketchSet::from_bytes(&long).is_err());
    }

    #[test]
    fn merge_layout_mismatch_is_an_error_not_a_panic() {
        let mut a = SketchSet::new(2, 4, 8, 100, 1);
        let b = SketchSet::new(3, 4, 8, 100, 1);
        let err = a.merge(&b).unwrap_err();
        assert!(err.to_string().contains("layer-layout mismatch"), "{err}");
        let c = SketchSet::new(2, 2, 8, 100, 1);
        let err = a.merge(&c).unwrap_err();
        assert!(err.to_string().contains("bucket-layout mismatch"), "{err}");
        // a matching layout still merges
        let d = SketchSet::new(2, 4, 8, 100, 9);
        a.merge(&d).unwrap();
        // canonical merge rejects the same mismatches
        assert!(SketchSet::merge_canonical(&[&a, &b]).is_err());
        assert!(SketchSet::merge_canonical(&[&a, &c]).is_err());
        assert!(SketchSet::merge_canonical(&[]).is_err());
    }

    #[test]
    fn self_merge_doubles_moments_keeps_extrema_matches_roundtrip() {
        // merging a sketch with a byte-identical clone of itself is the
        // aliasing edge of the fleet path: moments and counts double
        // exactly, extrema are unchanged, and the reservoir redraw (which
        // advances the rng cursor) is identical whether `other` is a
        // clone or a persistence roundtrip of the same sketch
        let mut a = LayerSketch::new(16, 11);
        for i in 0..100 {
            a.push((i as f32 * 0.37).sin() * 3.0);
        }
        let (count, min, max, sum, sumsq) = (a.count(), a.min, a.max, a.sum, a.sumsq);
        let mut via_clone = a.clone();
        via_clone.merge(&a.clone());
        let mut bytes = Vec::new();
        a.write_to(&mut bytes);
        let restored = LayerSketch::read_from(&mut ByteReader { bytes: &bytes, off: 0 }).unwrap();
        let mut via_roundtrip = a.clone();
        via_roundtrip.merge(&restored);
        assert_eq!(via_clone, via_roundtrip);
        assert_eq!(via_clone.count(), 2 * count);
        assert_eq!(via_clone.min.to_bits(), min.to_bits());
        assert_eq!(via_clone.max.to_bits(), max.to_bits());
        assert_eq!(via_clone.sum.to_bits(), (sum + sum).to_bits());
        assert_eq!(via_clone.sumsq.to_bits(), (sumsq + sumsq).to_bits());
        // the reservoir still holds only values the stream produced
        assert!(via_clone.samples().iter().all(|v| *v >= min && *v <= max));
    }

    #[test]
    fn canonical_merge_is_partition_invariant_for_lossless_windows() {
        // the fleet contract: any sharding of the same observation stream
        // merges to the same window, bit for bit, as long as no reservoir
        // truncated. Build one stream, split it 2-way and 4-way by a
        // routing hash, and compare the canonical merges.
        let t_total = 100usize;
        let obs: Vec<(usize, f32, f32)> = {
            let mut rng = Rng::new(77);
            (0..300)
                .map(|_| (rng.below(3), rng.range(0.0, 100.0), rng.normal()))
                .collect()
        };
        let feed_split = |n_shards: usize| -> Vec<SketchSet> {
            let mut shards: Vec<SketchSet> = (0..n_shards)
                .map(|s| SketchSet::new(3, 4, 256, t_total, 1000 + s as u64))
                .collect();
            for (i, &(l, t, v)) in obs.iter().enumerate() {
                let shard = crate::util::rng::mix64(i as u64) as usize % n_shards;
                shards[shard].observe(l, t, &[v]);
            }
            shards
        };
        let two = feed_split(2);
        let four = feed_split(4);
        let m2 = SketchSet::merge_canonical(&two.iter().collect::<Vec<_>>()).unwrap();
        let m4 = SketchSet::merge_canonical(&four.iter().collect::<Vec<_>>()).unwrap();
        assert_eq!(m2.lossy_positions, 0);
        assert_eq!(m4.lossy_positions, 0);
        assert_eq!(m2.window.to_bytes(), m4.window.to_bytes());
        // and both agree with the single-producer feed merged alone
        let one = feed_split(1);
        let m1 = SketchSet::merge_canonical(&[&one[0]]).unwrap();
        assert_eq!(m1.window.to_bytes(), m2.window.to_bytes());
        // exact stats survive: total count per layer matches the stream
        for l in 0..3 {
            let n = obs.iter().filter(|o| o.0 == l).count();
            assert_eq!(m2.window.layer_count(l), n);
        }
    }

    #[test]
    fn canonical_merge_truncates_deterministically_past_capacity() {
        // tiny caps force the Algorithm-R pass over the sorted union; the
        // inputs are still lossless (cap 256 holds everything), so the
        // 2-way and 4-way merges must still agree bitwise
        let obs: Vec<(usize, f32, f32)> = {
            let mut rng = Rng::new(5);
            (0..200).map(|_| (0usize, rng.range(0.0, 100.0), rng.normal())).collect()
        };
        let feed = |n_shards: usize, cap: usize| -> Vec<SketchSet> {
            let mut shards: Vec<SketchSet> =
                (0..n_shards).map(|s| SketchSet::new(1, 1, cap, 100, 7 + s as u64)).collect();
            for (i, &(l, t, v)) in obs.iter().enumerate() {
                let shard = crate::util::rng::mix64(i as u64) as usize % n_shards;
                shards[shard].observe(l, t, &[v]);
            }
            shards
        };
        // per-shard slices (~40-50 obs) fit cap 64 losslessly, but their
        // 200-sample union overflows the merged cap — the output runs the
        // deterministic Algorithm-R pass over the sorted union, which is
        // still a pure function of the union multiset, so different shard
        // counts keep agreeing bitwise
        let a = SketchSet::merge_canonical(&feed(4, 64).iter().collect::<Vec<_>>()).unwrap();
        let b = SketchSet::merge_canonical(&feed(5, 64).iter().collect::<Vec<_>>()).unwrap();
        assert_eq!(a.lossy_positions, 0);
        assert_eq!(b.lossy_positions, 0);
        assert_eq!(a.window.to_bytes(), b.window.to_bytes());
        assert_eq!(a.window.sketch(0, 0).count(), 200);
        assert_eq!(a.window.sketch(0, 0).samples().len(), 64);
        // a truncated *input* flips the lossy fallback counter instead
        let lossy_in = feed(1, 16); // 200 obs into cap 16 → truncated
        let c = SketchSet::merge_canonical(&lossy_in.iter().collect::<Vec<_>>()).unwrap();
        assert_eq!(c.lossy_positions, 1);
        assert_eq!(c.window.sketch(0, 0).count(), 200);
    }

    #[test]
    fn deterministic_from_seed() {
        let feed = |seed| {
            let mut set = SketchSet::new(1, 2, 8, 100, seed);
            let mut rng = Rng::new(42);
            for _ in 0..500 {
                let t = rng.range(0.0, 100.0);
                set.observe(0, t, &[rng.normal()]);
            }
            set.layer_merged(0).samples().to_vec()
        };
        assert_eq!(feed(11), feed(11));
    }
}
