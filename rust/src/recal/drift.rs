//! Drift scoring — the *detector* stage of the online recalibration
//! pipeline: compare a layer's live activation sketch against the
//! `LayerCalib` baseline its current quantizer was searched on.
//!
//! The score is scale-normalized so one threshold works across layers of
//! very different amplitudes:
//!
//!  * **quantile term** — mean absolute displacement of the inner
//!    quantiles (deciles by default) between the baseline samples and the
//!    sketch reservoir, divided by the baseline amplitude. Catches shape
//!    and location changes (the SiLU-trough vs gaussian switch that flips
//!    AAL/NAL classification shows up here immediately);
//!  * **range term** — displacement of the observed min/max relative to
//!    the baseline amplitude. Catches tail growth that quantile averages
//!    smooth over — exactly the failure mode of a stale `maxval` search
//!    space (clipped outliers dominate 4-bit MSE).
//!
//! The final score is the max of the two terms: 0 for an identical
//! distribution, ~1 when the distribution moved by about one baseline
//! amplitude. Typical thresholds sit at 0.05–0.15 (see
//! `recal::planner::RecalPlanner`).

use crate::quant::msfp::LayerCalib;

use super::sketch::LayerSketch;

/// Drift verdict for one layer.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct DriftScore {
    pub layer: usize,
    /// scale-normalized drift (see module docs); 0 = no drift
    pub score: f32,
    /// samples the sketch had observed when scored
    pub samples: usize,
}

/// `n` inner quantile points of an ascending-sorted slice (e.g. `n = 9`
/// gives the deciles q10..q90). Empty input yields an empty vector.
pub fn quantiles_sorted(sorted: &[f32], n: usize) -> Vec<f32> {
    if sorted.is_empty() || n == 0 {
        return Vec::new();
    }
    (1..=n)
        .map(|i| {
            let q = i as f64 / (n + 1) as f64;
            sorted[((sorted.len() - 1) as f64 * q).round() as usize]
        })
        .collect()
}

/// Baseline amplitude used to normalize displacement (the larger of
/// |min| and |max|, floored so all-zero layers cannot divide by zero).
pub fn baseline_scale(base: &LayerCalib) -> f32 {
    base.min.abs().max(base.max.abs()).max(1e-6)
}

/// Score a layer's live sketch against its calibration baseline.
/// `n_quantiles` controls the resolution of the quantile term.
pub fn drift_score(
    layer: usize,
    base: &LayerCalib,
    live: &LayerSketch,
    n_quantiles: usize,
) -> DriftScore {
    let samples = live.count();
    if samples == 0 || base.acts.is_empty() {
        return DriftScore { layer, score: 0.0, samples };
    }
    let scale = baseline_scale(base);

    let mut bs = base.acts.clone();
    bs.sort_unstable_by(f32::total_cmp);
    let mut ls = live.samples().to_vec();
    ls.sort_unstable_by(f32::total_cmp);
    let bq = quantiles_sorted(&bs, n_quantiles);
    let lq = quantiles_sorted(&ls, n_quantiles);
    let qterm = if bq.is_empty() {
        0.0
    } else {
        bq.iter().zip(&lq).map(|(a, b)| (a - b).abs()).sum::<f32>() / bq.len() as f32 / scale
    };

    let rterm = ((live.min - base.min).abs().max((live.max - base.max).abs())) / scale;

    DriftScore { layer, score: qterm.max(rterm), samples }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn calib_of(acts: Vec<f32>) -> LayerCalib {
        LayerCalib::from_samples("l", acts, false)
    }

    fn sketch_of(vals: &[f32]) -> LayerSketch {
        let mut sk = LayerSketch::new(vals.len().max(1), 3);
        for &v in vals {
            sk.push(v);
        }
        sk
    }

    #[test]
    fn quantiles_of_known_sequence() {
        let xs: Vec<f32> = (0..=100).map(|i| i as f32).collect();
        let q = quantiles_sorted(&xs, 9);
        assert_eq!(q.len(), 9);
        assert!((q[0] - 10.0).abs() <= 1.0);
        assert!((q[4] - 50.0).abs() <= 1.0);
        assert!((q[8] - 90.0).abs() <= 1.0);
        assert!(quantiles_sorted(&[], 9).is_empty());
    }

    #[test]
    fn identical_distribution_scores_near_zero() {
        let mut rng = Rng::new(5);
        let vals: Vec<f32> = (0..2000).map(|_| rng.normal()).collect();
        let base = calib_of(vals.clone());
        let live = sketch_of(&vals);
        let d = drift_score(0, &base, &live, 9);
        assert_eq!(d.samples, 2000);
        assert!(d.score < 1e-6, "score={}", d.score);
    }

    #[test]
    fn shift_scores_proportionally() {
        let mut rng = Rng::new(6);
        let vals: Vec<f32> = (0..4000).map(|_| rng.normal()).collect();
        let base = calib_of(vals.clone());
        let shifted: Vec<f32> = vals.iter().map(|v| v + 1.0).collect();
        let d = drift_score(0, &base, &sketch_of(&shifted), 9);
        // amplitude ~3.5σ, shift 1σ -> score around 0.28
        assert!(d.score > 0.15 && d.score < 0.6, "score={}", d.score);

        let small: Vec<f32> = vals.iter().map(|v| v + 0.02).collect();
        let d_small = drift_score(0, &base, &sketch_of(&small), 9);
        assert!(d_small.score < d.score / 3.0, "{} vs {}", d_small.score, d.score);
    }

    #[test]
    fn tail_growth_caught_by_range_term() {
        let mut rng = Rng::new(7);
        let vals: Vec<f32> = (0..2000).map(|_| rng.normal() * 0.5).collect();
        let base = calib_of(vals.clone());
        // same bulk, one 4x outlier: quantiles barely move, range does
        let mut tail = vals.clone();
        let amp = baseline_scale(&base);
        tail.push(amp * 4.0);
        let d = drift_score(0, &base, &sketch_of(&tail), 9);
        assert!(d.score > 1.0, "range term must dominate: {}", d.score);
    }

    #[test]
    fn empty_sketch_scores_zero() {
        let base = calib_of(vec![0.1, 0.2, 0.3]);
        let live = LayerSketch::new(8, 1);
        let d = drift_score(3, &base, &live, 9);
        assert_eq!(d.layer, 3);
        assert_eq!(d.score, 0.0);
        assert_eq!(d.samples, 0);
    }
}
