//! Online recalibration: drift-tracked activation sketches with
//! incremental `QuantSession` rebuilds — the EfficientDM-style
//! recalibrate-while-tuning loop, plus a serving-side hot-swap.
//!
//! The MSFP search ranges are frozen at the initial calibration pass, but
//! TALoRA fine-tuning (and any distribution shift in serving traffic)
//! moves per-layer activation distributions out from under them. This
//! subsystem closes that loop as a producer → detector → planner →
//! applier pipeline:
//!
//!  1. **sketch** ([`sketch`]) — producers feed cheap streaming per-layer
//!     activation sketches (reservoir + min/max/moments, keyed by layer
//!     and timestep bucket) from `Denoiser::calib_forward` outputs;
//!  2. **drift** ([`drift`]) — each layer's live sketch is scored against
//!     the `LayerCalib` baseline its current quantizer was searched on;
//!  3. **plan** ([`planner`]) — layers whose drift crosses the threshold
//!     get a replacement calibration built from the sketch;
//!  4. **apply** — `quant::session::QuantSession::update_layer_calib`
//!     rebuilds exactly one activation grid engine and invalidates only
//!     that layer's memoized activation sub-searches; the resulting
//!     scheme is bit-identical to a cold full-session rebuild on the same
//!     calibration (pinned by session unit tests and `tests/props.rs`).
//!
//! Consumers: `train::finetune` recalibrates drifted layers mid-run on a
//! `recal_every` epoch cadence, and the serving coordinator
//! (`coordinator::server`) runs the same loop as a background job on its
//! worker pool, atomically hot-swapping the updated qparams between
//! scheduling rounds (never mid-round). Serving also *produces* its own
//! sketches — `coordinator::prober::ShadowProber` recycles a budgeted
//! fraction of each round's request latents through the calibration graph
//! — and persists the window (`SketchSet::save`/`load`, exact reservoir +
//! rng cursor) so a restarted server resumes drift tracking bit-exactly.

pub mod drift;
pub mod planner;
pub mod sketch;

pub use drift::{drift_score, DriftScore};
pub use planner::{RecalLayer, RecalPlan, RecalPlanner};
pub use sketch::{FleetMerged, LayerSketch, SketchSet};
