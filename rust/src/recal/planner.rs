//! Recalibration planning — the *planner* stage of the online
//! recalibration pipeline: turn per-layer drift scores into the minimal
//! set of `QuantSession::update_layer_calib` applications.
//!
//! A plan contains one [`RecalLayer`] per layer whose drift crossed the
//! threshold *and* whose sketch has observed enough samples to trust: the
//! replacement `LayerCalib` is built from the sketch's merged reservoir
//! (acts) and exact running extrema (min/max), with the baseline's name
//! and architecture hint carried over. Layers below threshold are left
//! alone — their engines, memoized sub-searches and quantizers survive
//! untouched, which is what makes the incremental rebuild cheap.

use crate::quant::msfp::LayerCalib;

use super::drift::{drift_score, DriftScore};
use super::sketch::SketchSet;

/// Thresholds for when a layer is worth recalibrating.
#[derive(Debug, Clone)]
pub struct RecalPlanner {
    /// scale-normalized drift above which a layer is recalibrated
    /// (see `recal::drift` for the score's semantics)
    pub threshold: f32,
    /// minimum observed samples before a layer's sketch is trusted
    pub min_samples: usize,
    /// quantile resolution of the drift score
    pub n_quantiles: usize,
}

impl Default for RecalPlanner {
    fn default() -> Self {
        RecalPlanner { threshold: 0.08, min_samples: 64, n_quantiles: 9 }
    }
}

/// One planned layer update.
#[derive(Debug, Clone)]
pub struct RecalLayer {
    pub layer: usize,
    pub score: f32,
    /// replacement calibration built from the live sketch
    pub calib: LayerCalib,
}

/// The planner's output: drifted layers (with their replacement calib)
/// plus every layer's score for observability.
#[derive(Debug, Clone, Default)]
pub struct RecalPlan {
    pub layers: Vec<RecalLayer>,
    pub scores: Vec<DriftScore>,
}

impl RecalPlan {
    pub fn is_empty(&self) -> bool {
        self.layers.is_empty()
    }
}

impl RecalPlanner {
    /// Score every layer's sketch against its baseline and plan updates
    /// for the ones that crossed the threshold. `baseline[l]` must be the
    /// calibration the layer's current quantizer was searched on (a
    /// `QuantSession::calib()` slice keeps itself current across applied
    /// updates, so drift is always measured since the *last*
    /// recalibration, not since cold start).
    pub fn plan(&self, baseline: &[LayerCalib], sketches: &SketchSet) -> RecalPlan {
        let mut plan = RecalPlan::default();
        let n = baseline.len().min(sketches.n_layers());
        for l in 0..n {
            // under-sampled layers skip the merge + sort entirely, so an
            // idle producer makes checks nearly free; a trusted layer pays
            // one baseline sort + one reservoir sort per check (small at
            // calibration sizes — revisit with a per-baseline quantile
            // cache if L·N grows)
            let count = sketches.layer_count(l);
            if count < self.min_samples.max(1) {
                plan.scores.push(DriftScore { layer: l, score: 0.0, samples: count });
                continue;
            }
            let live = sketches.layer_merged(l);
            let d = drift_score(l, &baseline[l], &live, self.n_quantiles);
            plan.scores.push(d);
            if d.samples >= self.min_samples.max(1) && d.score > self.threshold {
                let base = &baseline[l];
                plan.layers.push(RecalLayer {
                    layer: l,
                    score: d.score,
                    calib: LayerCalib {
                        name: base.name.clone(),
                        acts: live.samples().to_vec(),
                        min: live.min,
                        max: live.max,
                        aal_hint: base.aal_hint,
                    },
                });
            }
        }
        plan
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    /// 3-layer fixture: layer 1's live stream is its baseline shifted by
    /// +1.5, layers 0 and 2 replay their baselines exactly (so only
    /// reservoir-subsampling noise separates them — deterministically far
    /// below any reasonable threshold).
    fn fixture() -> (Vec<LayerCalib>, SketchSet) {
        let mut rng = Rng::new(11);
        let base: Vec<LayerCalib> = (0..3)
            .map(|l| {
                LayerCalib::from_samples(
                    format!("l{l}"),
                    (0..1500).map(|_| rng.normal()).collect(),
                    l == 0,
                )
            })
            .collect();
        let mut set = SketchSet::new(3, 4, 256, 100, 5);
        let mut feed_rng = Rng::new(12);
        for (l, c) in base.iter().enumerate() {
            let shift = if l == 1 { 1.5 } else { 0.0 };
            for chunk in c.acts.chunks(50) {
                let t = feed_rng.range(0.0, 100.0);
                let vals: Vec<f32> = chunk.iter().map(|v| v + shift).collect();
                set.observe(l, t, &vals);
            }
        }
        (base, set)
    }

    #[test]
    fn plans_only_drifted_layers() {
        let (base, set) = fixture();
        let plan = RecalPlanner::default().plan(&base, &set);
        assert_eq!(plan.scores.len(), 3);
        assert_eq!(plan.layers.len(), 1, "scores: {:?}", plan.scores);
        let rl = &plan.layers[0];
        assert_eq!(rl.layer, 1);
        assert!(rl.score > 0.08);
        assert_eq!(rl.calib.name, "l1");
        assert!(!rl.calib.acts.is_empty());
        assert!(rl.calib.min <= rl.calib.max);
        // the replacement calib reflects the shifted stream
        let mean: f32 = rl.calib.acts.iter().sum::<f32>() / rl.calib.acts.len() as f32;
        assert!(mean > 1.0, "mean={mean}");
    }

    #[test]
    fn hint_and_name_carry_over() {
        let (base, mut set) = fixture();
        // shift layer 0 (the AAL-hinted one) too
        let mut rng = Rng::new(13);
        for _ in 0..1500 {
            set.observe(0, rng.range(0.0, 100.0), &[rng.normal() * 3.0]);
        }
        let plan = RecalPlanner::default().plan(&base, &set);
        let l0 = plan.layers.iter().find(|r| r.layer == 0).expect("layer 0 drifted");
        assert!(l0.calib.aal_hint);
    }

    #[test]
    fn min_samples_gates_thin_sketches() {
        let (base, _) = fixture();
        let mut set = SketchSet::new(3, 4, 256, 100, 5);
        // heavy drift but only a handful of samples
        set.observe(1, 50.0, &[10.0; 8]);
        let planner = RecalPlanner { min_samples: 64, ..Default::default() };
        assert!(planner.plan(&base, &set).is_empty());
        let eager = RecalPlanner { min_samples: 1, ..Default::default() };
        assert_eq!(eager.plan(&base, &set).layers.len(), 1);
    }

    #[test]
    fn plan_from_loaded_snapshot_matches_original() {
        // the restart guarantee at the planner level: a persisted-and-
        // restored sketch window produces bit-identical drift scores and
        // replacement calibrations, so a restarted server makes the same
        // hot-swap decisions as one that never went down
        let (base, set) = fixture();
        let loaded = SketchSet::from_bytes(&set.to_bytes()).unwrap();
        let planner = RecalPlanner::default();
        let a = planner.plan(&base, &set);
        let b = planner.plan(&base, &loaded);
        assert!(!a.is_empty(), "fixture must drift");
        assert_eq!(a.scores, b.scores);
        assert_eq!(a.layers.len(), b.layers.len());
        for (x, y) in a.layers.iter().zip(&b.layers) {
            assert_eq!(x.layer, y.layer);
            assert_eq!(x.score.to_bits(), y.score.to_bits());
            assert!(x.calib.acts.iter().zip(&y.calib.acts).all(|(p, q)| p.to_bits() == q.to_bits()));
            assert_eq!(x.calib.min.to_bits(), y.calib.min.to_bits());
            assert_eq!(x.calib.max.to_bits(), y.calib.max.to_bits());
        }
    }

    #[test]
    fn empty_sketches_plan_nothing() {
        let (base, _) = fixture();
        let set = SketchSet::new(3, 4, 256, 100, 5);
        let plan = RecalPlanner::default().plan(&base, &set);
        assert!(plan.is_empty());
        assert!(plan.scores.iter().all(|d| d.score == 0.0));
    }
}
