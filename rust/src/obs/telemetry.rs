//! Per-round telemetry: time-series samples + per-phase latency
//! histograms, exported as `metrics.jsonl`.
//!
//! A [`RoundSample`] is one row of the serving time series — queue state,
//! the active ladder rung, cumulative decision counters, per-class wait
//! percentiles, the latest drift-check score and the round's plan/exec
//! wall times. [`Telemetry`] keeps a bounded ring of rows plus one
//! [`PhaseTimers`] set of power-of-two-bucket [`Hist`]ograms over the
//! scheduler's five phases (plan / exec / offload / probe / recal).
//!
//! Everything numeric rides through `util::json`, whose integer-exact
//! float printing makes `RoundSample::from_json(to_json(r)) == r` hold
//! bit-for-bit — the same roundtrip contract `MetricsSnapshot` pins.
//! Wall-clock fields live *only* here: the telemetry file is the timing
//! side-channel, the flight recorder's logical trace stays clock-free.

use std::collections::VecDeque;

use anyhow::Result;

use crate::util::json::{arr, num, obj, Json};

/// Power-of-two-bucket latency histogram: bucket `i > 0` counts samples
/// in `[2^(i-1), 2^i)` microseconds, bucket 0 counts zeros. 32 buckets
/// cover past an hour; mean is exact via `sum_us`.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct Hist {
    pub buckets: [u64; 32],
    pub count: u64,
    pub sum_us: u64,
}

impl Hist {
    pub fn record_us(&mut self, us: u64) {
        let b = if us == 0 { 0 } else { (64 - us.leading_zeros() as usize).min(31) };
        self.buckets[b] += 1;
        self.count += 1;
        self.sum_us += us;
    }

    pub fn mean_us(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum_us as f64 / self.count as f64
        }
    }

    /// Fold another histogram in: bucketwise integer sums, so the merge
    /// is exactly commutative and associative — the property the fleet
    /// telemetry aggregation leans on.
    pub fn merge(&mut self, other: &Hist) {
        for (mine, theirs) in self.buckets.iter_mut().zip(&other.buckets) {
            *mine += *theirs;
        }
        self.count += other.count;
        self.sum_us += other.sum_us;
    }

    pub fn to_json(&self) -> Json {
        obj(vec![
            ("count", num(self.count as f64)),
            ("sum_us", num(self.sum_us as f64)),
            ("buckets", arr(self.buckets.iter().map(|&b| num(b as f64)))),
        ])
    }

    pub fn from_json(j: &Json) -> Result<Hist> {
        let mut h = Hist {
            count: j.get("count")?.usize()? as u64,
            sum_us: j.get("sum_us")?.usize()? as u64,
            ..Hist::default()
        };
        let buckets = j.get("buckets")?.arr()?;
        anyhow::ensure!(buckets.len() == 32, "histogram needs 32 buckets, got {}", buckets.len());
        for (slot, b) in h.buckets.iter_mut().zip(buckets) {
            *slot = b.usize()? as u64;
        }
        Ok(h)
    }
}

/// One histogram per scheduler phase. `offload` covers the scatter +
/// completion lane (decode/send handoff), `recal` the round-boundary
/// swap/bookkeeping span — the in-flight background check itself runs
/// off-thread and is *not* a scheduler phase.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct PhaseTimers {
    pub plan: Hist,
    pub exec: Hist,
    pub offload: Hist,
    pub probe: Hist,
    pub recal: Hist,
}

impl PhaseTimers {
    /// Merge another shard's phase histograms ([`Hist::merge`] per phase).
    pub fn merge(&mut self, other: &PhaseTimers) {
        self.plan.merge(&other.plan);
        self.exec.merge(&other.exec);
        self.offload.merge(&other.offload);
        self.probe.merge(&other.probe);
        self.recal.merge(&other.recal);
    }

    pub fn to_json(&self) -> Json {
        obj(vec![
            ("plan", self.plan.to_json()),
            ("exec", self.exec.to_json()),
            ("offload", self.offload.to_json()),
            ("probe", self.probe.to_json()),
            ("recal", self.recal.to_json()),
        ])
    }

    pub fn from_json(j: &Json) -> Result<PhaseTimers> {
        Ok(PhaseTimers {
            plan: Hist::from_json(j.get("plan")?)?,
            exec: Hist::from_json(j.get("exec")?)?,
            offload: Hist::from_json(j.get("offload")?)?,
            probe: Hist::from_json(j.get("probe")?)?,
            recal: Hist::from_json(j.get("recal")?)?,
        })
    }
}

/// One row of the per-round time series. Counter fields are *cumulative*
/// (totals as of this round), so a truncated ring still yields correct
/// rates by differencing adjacent rows.
#[derive(Debug, Clone, PartialEq)]
pub struct RoundSample {
    pub round: u64,
    /// active requests at plan time (in-flight working set)
    pub depth: u32,
    /// admission candidates this round (ready, not backed off)
    pub backlog: u32,
    pub admitted: u32,
    pub deferred: u32,
    pub batches: u32,
    /// ladder rung index the backlog selected (-1 = full quality)
    pub rung: i32,
    pub shed: u64,
    pub retries: u64,
    pub faults: u64,
    pub evals: u64,
    pub probes: u64,
    pub recal_checks: u64,
    pub recal_swaps: u64,
    pub ckpt_retries: u64,
    /// max drift score of the latest completed recal check (0 = none yet)
    pub drift_max: f32,
    /// cumulative per-class queue-wait p50 (rounds), `SloClass::ALL` order
    pub wait_p50: [u64; 3],
    pub wait_p99: [u64; 3],
    pub plan_us: u64,
    pub exec_us: u64,
}

impl RoundSample {
    pub fn to_json(&self) -> Json {
        obj(vec![
            ("round", num(self.round as f64)),
            ("depth", num(self.depth as f64)),
            ("backlog", num(self.backlog as f64)),
            ("admitted", num(self.admitted as f64)),
            ("deferred", num(self.deferred as f64)),
            ("batches", num(self.batches as f64)),
            ("rung", num(self.rung as f64)),
            ("shed", num(self.shed as f64)),
            ("retries", num(self.retries as f64)),
            ("faults", num(self.faults as f64)),
            ("evals", num(self.evals as f64)),
            ("probes", num(self.probes as f64)),
            ("recal_checks", num(self.recal_checks as f64)),
            ("recal_swaps", num(self.recal_swaps as f64)),
            ("ckpt_retries", num(self.ckpt_retries as f64)),
            ("drift_max", num(self.drift_max as f64)),
            ("wait_p50", arr(self.wait_p50.iter().map(|&w| num(w as f64)))),
            ("wait_p99", arr(self.wait_p99.iter().map(|&w| num(w as f64)))),
            ("plan_us", num(self.plan_us as f64)),
            ("exec_us", num(self.exec_us as f64)),
        ])
    }

    pub fn from_json(j: &Json) -> Result<RoundSample> {
        let triple = |key: &str| -> Result<[u64; 3]> {
            let v = j.get(key)?.arr()?;
            anyhow::ensure!(v.len() == 3, "{key} needs 3 classes, got {}", v.len());
            Ok([v[0].usize()? as u64, v[1].usize()? as u64, v[2].usize()? as u64])
        };
        Ok(RoundSample {
            round: j.get("round")?.usize()? as u64,
            depth: j.get("depth")?.usize()? as u32,
            backlog: j.get("backlog")?.usize()? as u32,
            admitted: j.get("admitted")?.usize()? as u32,
            deferred: j.get("deferred")?.usize()? as u32,
            batches: j.get("batches")?.usize()? as u32,
            rung: j.get("rung")?.i64()? as i32,
            shed: j.get("shed")?.usize()? as u64,
            retries: j.get("retries")?.usize()? as u64,
            faults: j.get("faults")?.usize()? as u64,
            evals: j.get("evals")?.usize()? as u64,
            probes: j.get("probes")?.usize()? as u64,
            recal_checks: j.get("recal_checks")?.usize()? as u64,
            recal_swaps: j.get("recal_swaps")?.usize()? as u64,
            ckpt_retries: j.get("ckpt_retries")?.usize()? as u64,
            drift_max: j.get("drift_max")?.f32()?,
            wait_p50: triple("wait_p50")?,
            wait_p99: triple("wait_p99")?,
            plan_us: j.get("plan_us")?.usize()? as u64,
            exec_us: j.get("exec_us")?.usize()? as u64,
        })
    }
}

/// Bounded per-round time series + phase histograms. `cap` rows are
/// retained (oldest evicted, counted in `rows_dropped`); cumulative
/// counters in each row keep a truncated series differentiable.
#[derive(Debug, Default)]
pub struct Telemetry {
    cap: usize,
    rows: VecDeque<RoundSample>,
    pub timers: PhaseTimers,
    rows_dropped: u64,
    rows_total: u64,
}

impl Telemetry {
    /// `cap` = retained rows; 0 disables row retention (timers still
    /// accumulate — they are O(1) regardless).
    pub fn new(cap: usize) -> Telemetry {
        Telemetry { cap, ..Telemetry::default() }
    }

    pub fn push(&mut self, row: RoundSample) {
        self.rows_total += 1;
        if self.cap == 0 {
            self.rows_dropped += 1;
            return;
        }
        if self.rows.len() == self.cap {
            self.rows.pop_front();
            self.rows_dropped += 1;
        }
        self.rows.push_back(row);
    }

    pub fn rows(&self) -> impl Iterator<Item = &RoundSample> {
        self.rows.iter()
    }

    /// The `metrics.jsonl` image: one JSON object per retained round,
    /// oldest first, then one trailer object carrying the phase
    /// histograms and the ring accounting.
    pub fn to_jsonl(&self) -> String {
        let mut out = String::new();
        for row in &self.rows {
            out.push_str(&row.to_json().to_string());
            out.push('\n');
        }
        let trailer = obj(vec![
            ("phase_timers", self.timers.to_json()),
            ("rows_total", num(self.rows_total as f64)),
            ("rows_dropped", num(self.rows_dropped as f64)),
        ]);
        out.push_str(&trailer.to_string());
        out.push('\n');
        out
    }
}

/// One shard's contribution to the fleet telemetry export: its id, its
/// retained rows, and its phase timers (harvested from the shard's
/// scheduler at shutdown or an aggregation boundary).
#[derive(Debug, Clone, Default)]
pub struct ShardSeries {
    pub shard: u64,
    pub rows: Vec<RoundSample>,
    pub timers: PhaseTimers,
}

/// The fleet-wide `metrics.jsonl` image: every shard's retained rows,
/// each tagged with a `"shard"` key (shards in the given order, rows
/// oldest-first within a shard — per-shard series stay differentiable),
/// then one trailer object carrying the fleet-merged phase timers and
/// the shard count.
pub fn fleet_jsonl(shards: &[ShardSeries]) -> String {
    let mut out = String::new();
    let mut timers = PhaseTimers::default();
    let mut rows_total = 0u64;
    for s in shards {
        timers.merge(&s.timers);
        for row in &s.rows {
            rows_total += 1;
            let mut j = row.to_json();
            if let Json::Obj(map) = &mut j {
                map.insert("shard".to_string(), num(s.shard as f64));
            }
            out.push_str(&j.to_string());
            out.push('\n');
        }
    }
    let trailer = obj(vec![
        ("phase_timers", timers.to_json()),
        ("shards", num(shards.len() as f64)),
        ("rows_total", num(rows_total as f64)),
    ]);
    out.push_str(&trailer.to_string());
    out.push('\n');
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample(round: u64) -> RoundSample {
        RoundSample {
            round,
            depth: 5,
            backlog: 3,
            admitted: 3,
            deferred: 0,
            batches: 2,
            rung: -1,
            shed: 1,
            retries: 2,
            faults: 1,
            evals: 40,
            probes: 4,
            recal_checks: 2,
            recal_swaps: 1,
            ckpt_retries: 0,
            drift_max: 0.62,
            wait_p50: [0, 1, 3],
            wait_p99: [1, 2, 7],
            plan_us: 130,
            exec_us: 5400,
        }
    }

    #[test]
    fn hist_buckets_by_power_of_two() {
        let mut h = Hist::default();
        h.record_us(0); // bucket 0
        h.record_us(1); // [1,2) -> bucket 1
        h.record_us(2); // [2,4) -> bucket 2
        h.record_us(3);
        h.record_us(1000); // [512,1024) -> bucket 10
        h.record_us(u64::MAX); // clamps to bucket 31
        assert_eq!(h.count, 6);
        assert_eq!(h.buckets[0], 1);
        assert_eq!(h.buckets[1], 1);
        assert_eq!(h.buckets[2], 2);
        assert_eq!(h.buckets[10], 1);
        assert_eq!(h.buckets[31], 1);
        assert_eq!(h.buckets.iter().sum::<u64>(), h.count);
    }

    #[test]
    fn hist_mean_and_json_roundtrip() {
        let mut h = Hist::default();
        for us in [10, 20, 60] {
            h.record_us(us);
        }
        assert!((h.mean_us() - 30.0).abs() < 1e-12);
        let back = Hist::from_json(&h.to_json()).unwrap();
        assert_eq!(back, h);
        assert_eq!(Hist::from_json(&Json::parse(&h.to_json().to_string()).unwrap()).unwrap(), h);
    }

    #[test]
    fn round_sample_json_roundtrip_is_exact() {
        let r = sample(17);
        let back = RoundSample::from_json(&r.to_json()).unwrap();
        assert_eq!(back, r);
        // through the actual text form (what metrics.jsonl holds)
        let text = r.to_json().to_string();
        let back = RoundSample::from_json(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(back, r);
    }

    #[test]
    fn telemetry_ring_caps_and_jsonl_shape() {
        let mut t = Telemetry::new(3);
        for round in 0..5 {
            t.push(sample(round));
        }
        t.timers.plan.record_us(100);
        t.timers.exec.record_us(9000);
        assert_eq!(t.rows().count(), 3);
        assert_eq!(t.rows().next().unwrap().round, 2, "oldest rows evicted");
        let jsonl = t.to_jsonl();
        let lines: Vec<&str> = jsonl.lines().collect();
        assert_eq!(lines.len(), 4, "3 rows + trailer");
        for (i, line) in lines[..3].iter().enumerate() {
            let row = RoundSample::from_json(&Json::parse(line).unwrap()).unwrap();
            assert_eq!(row.round, i as u64 + 2);
        }
        let trailer = Json::parse(lines[3]).unwrap();
        assert_eq!(trailer.get("rows_total").unwrap().usize().unwrap(), 5);
        assert_eq!(trailer.get("rows_dropped").unwrap().usize().unwrap(), 2);
        let timers = PhaseTimers::from_json(trailer.get("phase_timers").unwrap()).unwrap();
        assert_eq!(timers, t.timers);
    }

    #[test]
    fn hist_merge_is_bucketwise_sum() {
        let mut a = Hist::default();
        let mut b = Hist::default();
        for us in [0u64, 3, 1000] {
            a.record_us(us);
        }
        for us in [3u64, 7] {
            b.record_us(us);
        }
        let mut ab = a.clone();
        ab.merge(&b);
        let mut ba = b.clone();
        ba.merge(&a);
        assert_eq!(ab, ba, "merge is commutative");
        assert_eq!(ab.count, 5);
        assert_eq!(ab.sum_us, 1013);
        assert_eq!(ab.buckets[2], 2); // both 3s
        assert_eq!(ab.buckets.iter().sum::<u64>(), ab.count);
        // a merged sequentially vs pairwise agrees (associativity)
        let mut c = Hist::default();
        c.record_us(42);
        let mut ab_c = ab.clone();
        ab_c.merge(&c);
        let mut bc = b.clone();
        bc.merge(&c);
        let mut a_bc = a.clone();
        a_bc.merge(&bc);
        assert_eq!(ab_c, a_bc);
    }

    #[test]
    fn fleet_jsonl_tags_rows_by_shard_and_merges_timers() {
        let mut t0 = PhaseTimers::default();
        t0.plan.record_us(10);
        let mut t1 = PhaseTimers::default();
        t1.plan.record_us(30);
        t1.exec.record_us(500);
        let shards = vec![
            ShardSeries { shard: 0, rows: vec![sample(1), sample(2)], timers: t0.clone() },
            ShardSeries { shard: 1, rows: vec![sample(1)], timers: t1.clone() },
        ];
        let jsonl = fleet_jsonl(&shards);
        let lines: Vec<&str> = jsonl.lines().collect();
        assert_eq!(lines.len(), 4, "3 rows + trailer");
        for (line, want_shard) in lines[..3].iter().zip([0u64, 0, 1]) {
            let j = Json::parse(line).unwrap();
            assert_eq!(j.get("shard").unwrap().usize().unwrap() as u64, want_shard);
            // the row body still roundtrips (extra key is ignored)
            let _ = RoundSample::from_json(&j).unwrap();
        }
        let trailer = Json::parse(lines[3]).unwrap();
        assert_eq!(trailer.get("shards").unwrap().usize().unwrap(), 2);
        assert_eq!(trailer.get("rows_total").unwrap().usize().unwrap(), 3);
        let merged = PhaseTimers::from_json(trailer.get("phase_timers").unwrap()).unwrap();
        let mut want = t0;
        want.merge(&t1);
        assert_eq!(merged, want);
    }

    #[test]
    fn zero_capacity_disables_rows_not_timers() {
        let mut t = Telemetry::new(0);
        t.push(sample(0));
        t.timers.recal.record_us(5);
        assert_eq!(t.rows().count(), 0);
        let jsonl = t.to_jsonl();
        assert_eq!(jsonl.lines().count(), 1, "trailer only");
        let trailer = Json::parse(jsonl.lines().next().unwrap()).unwrap();
        assert_eq!(trailer.get("rows_dropped").unwrap().usize().unwrap(), 1);
    }
}
