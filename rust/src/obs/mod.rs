//! Observability for the serving coordinator: deterministic flight
//! recorder, per-round telemetry export, structured metrics snapshots,
//! and the recal hot-swap audit trail.
//!
//! Three artifacts come out of a serve:
//!
//!  * **`trace.mtr`** — a versioned postmortem of the flight recorder's
//!    bounded event ring ([`FlightRecorder`], [`Trace`]). Events are
//!    `(round, seq, kind)` plus a wall-clock annotation; the *logical*
//!    trace (wall-clock stripped, [`Trace::logical_bytes`]) is
//!    bit-identical for any worker count on the same workload — the
//!    1-vs-N parity discipline extended to the decision log. Dumped on
//!    shed storms, injected faults, recal-check panics and shutdown.
//!  * **`metrics.jsonl`** — a per-round time series ([`RoundSample`])
//!    plus per-phase plan/exec/offload/probe/recal latency histograms
//!    ([`PhaseTimers`]), written at shutdown and on postmortems.
//!  * **[`MetricsSnapshot`]** — the structured, exactly-JSON-roundtrip
//!    form of `coordinator::Metrics` (with a Prometheus-style text
//!    exposition); the classic `report()` string is a renderer over it.
//!
//! Both files land in the serve's `StateDir` via `util::io::atomic_write`,
//! so `FaultFs` chaos drills cover the dump paths and a crash mid-dump
//! can never tear an existing postmortem.

pub mod event;
pub mod recorder;
pub mod snapshot;
pub mod telemetry;

pub use event::{Event, EventKind};
pub use recorder::{FlightRecorder, SwapAudit, Trace};
pub use snapshot::{FleetSnapshot, MetricsSnapshot, CLASS_NAMES};
pub use telemetry::{fleet_jsonl, Hist, PhaseTimers, RoundSample, ShardSeries, Telemetry};

/// Observability configuration for one serving coordinator.
///
/// The recorder defaults to **on**: emission is a few mutex-guarded ring
/// pushes per round (the `perf_serving` `trace_overhead` row pins it
/// under 2% of mean round time), and every pre-existing 1-vs-N
/// bit-identity test runs with it enabled — the logical trace is part of
/// the determinism surface, not an optional extra.
#[derive(Debug, Clone)]
pub struct ObsCfg {
    /// flight-recorder ring capacity in events; 0 disables the recorder
    pub events: usize,
    /// telemetry rows retained (per-round samples); 0 disables rows
    /// (phase timers still accumulate)
    pub rounds: usize,
    /// where postmortems land; `None` falls back to the serve's recal
    /// `StateDir` (if any), else dumps are skipped
    pub dir: Option<crate::quant::msfp::StateDir>,
}

impl Default for ObsCfg {
    fn default() -> ObsCfg {
        ObsCfg { events: 1024, rounds: 1024, dir: None }
    }
}

impl ObsCfg {
    /// Recorder fully off (the `trace_overhead` baseline).
    pub fn off() -> ObsCfg {
        ObsCfg { events: 0, rounds: 0, dir: None }
    }

    pub fn enabled(&self) -> bool {
        self.events > 0
    }
}
