//! Structured, serializable serving metrics — `Metrics::snapshot()`
//! returns one of these, and the human-oriented `Metrics::report()`
//! string is now just [`MetricsSnapshot::render`] over it.
//!
//! The snapshot is the machine-facing contract: exact JSON roundtrip
//! (`from_json(to_json(s)) == s`, bit-for-bit — `util::json` prints
//! integers exactly and other floats shortest-roundtrip) plus a one-shot
//! Prometheus-style text exposition for scraping. The renderer reproduces
//! the pre-snapshot `report()`/`slo_report()` strings byte-for-byte; the
//! string-pinning tests in `coordinator::metrics` hold across the
//! refactor.

use anyhow::Result;

use crate::util::json::{arr, num, obj, s, Json};

/// Per-class label names in `SloClass::ALL` / `rank()` order (the
/// coordinator's Debug names, duplicated here so `obs` stays free of a
/// coordinator dependency; pinned against drift by a metrics test).
pub const CLASS_NAMES: [&str; 3] = ["Interactive", "Batch", "BestEffort"];

/// One structured snapshot of a serve lifetime. All derived quantities
/// (throughput, percentiles, fractions) are precomputed so a consumer —
/// or [`MetricsSnapshot::render`] — never needs the raw sample series.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct MetricsSnapshot {
    pub requests: u64,
    pub images: u64,
    pub evals: u64,
    pub rounds: u64,
    /// resolved backend tag ("graph" | "packed")
    pub backend: String,
    pub packed_bytes: u64,
    pub wall_s: f64,
    pub throughput: f64,
    pub latency_p50_ms: f64,
    pub latency_p95_ms: f64,
    pub mean_batch: f64,
    /// mean batch fill as a fraction in [0, 1]
    pub mean_fill: f64,
    pub round_exec_ms: f64,
    pub round_sched_ms: f64,
    pub exec_fraction: f64,
    pub sel_hits: u64,
    pub sel_misses: u64,
    pub sel_hit_rate: f64,
    pub recal_checks: u64,
    pub recal_swaps: u64,
    pub recal_layers: u64,
    pub first_swap_round: Option<u64>,
    pub probes: u64,
    pub probes_skipped: u64,
    pub probes_failed: u64,
    /// per-class queue-wait percentiles in rounds ([`CLASS_NAMES`] order)
    pub wait_p50: [u64; 3],
    pub wait_p99: [u64; 3],
    /// per-class queue-wait maxima — `wait_max == [0, 0, 0]` is exactly
    /// the "every wait sample was zero" half of the quiet condition
    pub wait_max: [u64; 3],
    pub shed: [u64; 3],
    pub downgraded_rounds: u64,
    pub downgraded_steps: u64,
    pub cancelled: u64,
    pub retries: u64,
    pub faults_injected: u64,
    pub compile_attempts: u64,
    pub compile_exhausted: u64,
    pub ckpt_fails: u64,
    pub ckpt_retries: u64,
    pub reconfigures: u64,
    pub rung_rounds: Vec<u64>,
    /// flight-recorder events emitted over the serve lifetime
    pub trace_events: u64,
    /// events the recorder ring evicted
    pub trace_dropped: u64,
    /// postmortem trace/telemetry dumps written
    pub postmortems: u64,
}

impl MetricsSnapshot {
    /// The classic one-line serving report (exactly the pre-snapshot
    /// `Metrics::report()` string — recorder counters intentionally do
    /// not appear, so recorder-on and recorder-off runs render the same).
    pub fn render(&self) -> String {
        let packed = if self.packed_bytes > 0 {
            format!(" ({:.1} KiB packed)", self.packed_bytes as f64 / 1024.0)
        } else {
            String::new()
        };
        format!(
            "requests {:4}  images {:5}  evals {:6}  rounds {:5}  backend {}{}  thpt {:7.2} img/s  p50 {:6.1} ms  p95 {:6.1} ms  mean-batch {:4.1}  fill {:4.0}%  exec {:6.1} ms / sched {:6.1} ms ({:3.0}% exec)  sel-hit {:3.0}%  recal {}/{} swaps ({} layers)  probes {} ({} skipped, {} failed){}",
            self.requests,
            self.images,
            self.evals,
            self.rounds,
            self.backend,
            packed,
            self.throughput,
            self.latency_p50_ms,
            self.latency_p95_ms,
            self.mean_batch,
            self.mean_fill * 100.0,
            self.round_exec_ms,
            self.round_sched_ms,
            self.exec_fraction * 100.0,
            self.sel_hit_rate * 100.0,
            self.recal_swaps,
            self.recal_checks,
            self.recal_layers,
            self.probes,
            self.probes_skipped,
            self.probes_failed,
            self.render_slo()
        )
    }

    /// SLO / robustness suffix of [`MetricsSnapshot::render`]: empty when
    /// nothing SLO-related happened (the common quiet path), one line of
    /// per-class queue waits and shed/downgrade/retry/fault counters
    /// otherwise.
    pub fn render_slo(&self) -> String {
        let quiet = self.wait_max.iter().all(|&m| m == 0)
            && self.shed.iter().all(|&n| n == 0)
            && self.downgraded_rounds == 0
            && self.downgraded_steps == 0
            && self.cancelled == 0
            && self.retries == 0
            && self.faults_injected == 0
            && self.compile_exhausted == 0
            && self.ckpt_fails == 0
            && self.ckpt_retries == 0
            && self.reconfigures == 0
            && self.rung_rounds.iter().all(|&r| r == 0);
        if quiet {
            return String::new();
        }
        let mut out = String::from("\n  slo:");
        for (i, name) in CLASS_NAMES.iter().enumerate() {
            out.push_str(&format!(
                " {} wait p50/p99 {}/{} rounds shed {};",
                name, self.wait_p50[i], self.wait_p99[i], self.shed[i],
            ));
        }
        out.push_str(&format!(
            "  downgraded {} rounds / {} step-cuts  cancelled {}  retries {}  faults {}  compile {} attempts ({} exhausted)",
            self.downgraded_rounds,
            self.downgraded_steps,
            self.cancelled,
            self.retries,
            self.faults_injected,
            self.compile_attempts,
            self.compile_exhausted
        ));
        if !self.rung_rounds.is_empty() {
            out.push_str(&format!("  ladder rounds {:?}", self.rung_rounds));
        }
        if self.ckpt_fails > 0 || self.ckpt_retries > 0 || self.reconfigures > 0 {
            out.push_str(&format!(
                "  ckpt {} fails / {} retries  reconfigures {}",
                self.ckpt_fails, self.ckpt_retries, self.reconfigures
            ));
        }
        out
    }

    pub fn to_json(&self) -> Json {
        let triple = |v: &[u64; 3]| arr(v.iter().map(|&n| num(n as f64)));
        obj(vec![
            ("requests", num(self.requests as f64)),
            ("images", num(self.images as f64)),
            ("evals", num(self.evals as f64)),
            ("rounds", num(self.rounds as f64)),
            ("backend", s(&self.backend)),
            ("packed_bytes", num(self.packed_bytes as f64)),
            ("wall_s", num(self.wall_s)),
            ("throughput", num(self.throughput)),
            ("latency_p50_ms", num(self.latency_p50_ms)),
            ("latency_p95_ms", num(self.latency_p95_ms)),
            ("mean_batch", num(self.mean_batch)),
            ("mean_fill", num(self.mean_fill)),
            ("round_exec_ms", num(self.round_exec_ms)),
            ("round_sched_ms", num(self.round_sched_ms)),
            ("exec_fraction", num(self.exec_fraction)),
            ("sel_hits", num(self.sel_hits as f64)),
            ("sel_misses", num(self.sel_misses as f64)),
            ("sel_hit_rate", num(self.sel_hit_rate)),
            ("recal_checks", num(self.recal_checks as f64)),
            ("recal_swaps", num(self.recal_swaps as f64)),
            ("recal_layers", num(self.recal_layers as f64)),
            (
                "first_swap_round",
                match self.first_swap_round {
                    Some(r) => num(r as f64),
                    None => Json::Null,
                },
            ),
            ("probes", num(self.probes as f64)),
            ("probes_skipped", num(self.probes_skipped as f64)),
            ("probes_failed", num(self.probes_failed as f64)),
            ("wait_p50", triple(&self.wait_p50)),
            ("wait_p99", triple(&self.wait_p99)),
            ("wait_max", triple(&self.wait_max)),
            ("shed", triple(&self.shed)),
            ("downgraded_rounds", num(self.downgraded_rounds as f64)),
            ("downgraded_steps", num(self.downgraded_steps as f64)),
            ("cancelled", num(self.cancelled as f64)),
            ("retries", num(self.retries as f64)),
            ("faults_injected", num(self.faults_injected as f64)),
            ("compile_attempts", num(self.compile_attempts as f64)),
            ("compile_exhausted", num(self.compile_exhausted as f64)),
            ("ckpt_fails", num(self.ckpt_fails as f64)),
            ("ckpt_retries", num(self.ckpt_retries as f64)),
            ("reconfigures", num(self.reconfigures as f64)),
            ("rung_rounds", arr(self.rung_rounds.iter().map(|&r| num(r as f64)))),
            ("trace_events", num(self.trace_events as f64)),
            ("trace_dropped", num(self.trace_dropped as f64)),
            ("postmortems", num(self.postmortems as f64)),
        ])
    }

    pub fn from_json(j: &Json) -> Result<MetricsSnapshot> {
        let triple = |key: &str| -> Result<[u64; 3]> {
            let v = j.get(key)?.arr()?;
            anyhow::ensure!(v.len() == 3, "{key} needs 3 classes, got {}", v.len());
            Ok([v[0].usize()? as u64, v[1].usize()? as u64, v[2].usize()? as u64])
        };
        let count = |key: &str| -> Result<u64> { Ok(j.get(key)?.usize()? as u64) };
        Ok(MetricsSnapshot {
            requests: count("requests")?,
            images: count("images")?,
            evals: count("evals")?,
            rounds: count("rounds")?,
            backend: j.get("backend")?.str()?.to_string(),
            packed_bytes: count("packed_bytes")?,
            wall_s: j.get("wall_s")?.f64()?,
            throughput: j.get("throughput")?.f64()?,
            latency_p50_ms: j.get("latency_p50_ms")?.f64()?,
            latency_p95_ms: j.get("latency_p95_ms")?.f64()?,
            mean_batch: j.get("mean_batch")?.f64()?,
            mean_fill: j.get("mean_fill")?.f64()?,
            round_exec_ms: j.get("round_exec_ms")?.f64()?,
            round_sched_ms: j.get("round_sched_ms")?.f64()?,
            exec_fraction: j.get("exec_fraction")?.f64()?,
            sel_hits: count("sel_hits")?,
            sel_misses: count("sel_misses")?,
            sel_hit_rate: j.get("sel_hit_rate")?.f64()?,
            recal_checks: count("recal_checks")?,
            recal_swaps: count("recal_swaps")?,
            recal_layers: count("recal_layers")?,
            first_swap_round: match j.get("first_swap_round")? {
                Json::Null => None,
                v => Some(v.usize()? as u64),
            },
            probes: count("probes")?,
            probes_skipped: count("probes_skipped")?,
            probes_failed: count("probes_failed")?,
            wait_p50: triple("wait_p50")?,
            wait_p99: triple("wait_p99")?,
            wait_max: triple("wait_max")?,
            shed: triple("shed")?,
            downgraded_rounds: count("downgraded_rounds")?,
            downgraded_steps: count("downgraded_steps")?,
            cancelled: count("cancelled")?,
            retries: count("retries")?,
            faults_injected: count("faults_injected")?,
            compile_attempts: count("compile_attempts")?,
            compile_exhausted: count("compile_exhausted")?,
            ckpt_fails: count("ckpt_fails")?,
            ckpt_retries: count("ckpt_retries")?,
            reconfigures: count("reconfigures")?,
            rung_rounds: j
                .get("rung_rounds")?
                .arr()?
                .iter()
                .map(|r| Ok(r.usize()? as u64))
                .collect::<Result<Vec<u64>>>()?,
            trace_events: count("trace_events")?,
            trace_dropped: count("trace_dropped")?,
            postmortems: count("postmortems")?,
        })
    }

    /// One-shot Prometheus-style text exposition (the `# TYPE`d subset a
    /// scraper needs; counters suffixed `_total`, everything else gauges).
    pub fn prometheus(&self) -> String {
        use std::fmt::Write;
        let mut out = String::new();
        macro_rules! head {
            ($name:literal, $kind:literal, $help:literal) => {{
                let _ = writeln!(out, concat!("# HELP ", $name, " ", $help));
                let _ = writeln!(out, concat!("# TYPE ", $name, " ", $kind));
            }};
        }
        macro_rules! put {
            ($($t:tt)*) => {{ let _ = writeln!(out, $($t)*); }};
        }
        head!("msfp_requests_total", "counter", "requests retired (done)");
        put!("msfp_requests_total {}", self.requests);
        head!("msfp_images_total", "counter", "images generated");
        put!("msfp_images_total {}", self.images);
        head!("msfp_evals_total", "counter", "denoiser evaluations");
        put!("msfp_evals_total {}", self.evals);
        head!("msfp_rounds_total", "counter", "scheduling rounds executed");
        put!("msfp_rounds_total {}", self.rounds);
        head!("msfp_throughput_img_per_s", "gauge", "images per second over the serve wall time");
        put!("msfp_throughput_img_per_s {}", self.throughput);
        head!("msfp_latency_ms", "gauge", "request latency percentiles");
        put!("msfp_latency_ms{{q=\"p50\"}} {}", self.latency_p50_ms);
        put!("msfp_latency_ms{{q=\"p95\"}} {}", self.latency_p95_ms);
        head!("msfp_round_phase_ms", "gauge", "cumulative round time by phase");
        put!("msfp_round_phase_ms{{phase=\"exec\"}} {}", self.round_exec_ms);
        put!("msfp_round_phase_ms{{phase=\"sched\"}} {}", self.round_sched_ms);
        head!("msfp_queue_wait_rounds", "gauge", "per-class queue-wait percentiles in rounds");
        for (i, class) in CLASS_NAMES.iter().enumerate() {
            let class = class.to_ascii_lowercase();
            put!("msfp_queue_wait_rounds{{class=\"{class}\",q=\"p50\"}} {}", self.wait_p50[i]);
            put!("msfp_queue_wait_rounds{{class=\"{class}\",q=\"p99\"}} {}", self.wait_p99[i]);
            put!("msfp_queue_wait_rounds{{class=\"{class}\",q=\"max\"}} {}", self.wait_max[i]);
        }
        head!("msfp_shed_total", "counter", "requests shed per class");
        for (i, class) in CLASS_NAMES.iter().enumerate() {
            put!("msfp_shed_total{{class=\"{}\"}} {}", class.to_ascii_lowercase(), self.shed[i]);
        }
        head!("msfp_rung_rounds_total", "counter", "degraded rounds per ladder rung");
        for (rung, n) in self.rung_rounds.iter().enumerate() {
            put!("msfp_rung_rounds_total{{rung=\"{rung}\"}} {n}");
        }
        head!("msfp_recal_checks_total", "counter", "background drift checks launched");
        put!("msfp_recal_checks_total {}", self.recal_checks);
        head!("msfp_recal_swaps_total", "counter", "qparams hot-swaps applied");
        put!("msfp_recal_swaps_total {}", self.recal_swaps);
        head!("msfp_probes_total", "counter", "shadow calibration probes submitted");
        put!("msfp_probes_total {}", self.probes);
        head!("msfp_retries_total", "counter", "failed-round retry attempts");
        put!("msfp_retries_total {}", self.retries);
        head!("msfp_faults_injected_total", "counter", "batch faults injected by the FaultPlan");
        put!("msfp_faults_injected_total {}", self.faults_injected);
        head!("msfp_ckpt_retries_total", "counter", "checkpoint write retries that landed");
        put!("msfp_ckpt_retries_total {}", self.ckpt_retries);
        head!("msfp_ckpt_fails_total", "counter", "checkpoint writes that exhausted retries");
        put!("msfp_ckpt_fails_total {}", self.ckpt_fails);
        head!("msfp_trace_events_total", "counter", "flight-recorder events emitted");
        put!("msfp_trace_events_total {}", self.trace_events);
        head!("msfp_trace_dropped_total", "counter", "flight-recorder events evicted by the ring");
        put!("msfp_trace_dropped_total {}", self.trace_dropped);
        head!("msfp_postmortems_total", "counter", "postmortem trace dumps written");
        put!("msfp_postmortems_total {}", self.postmortems);
        out
    }
}

/// Fleet-scope observability snapshot: every shard's
/// [`MetricsSnapshot`] keyed by shard id, the fleet-merged view
/// (counters summed, series canonically re-sorted — built by
/// `coordinator::Metrics::merge`), and the aggregator's own accounting.
/// Written next to the merged sketch window on fleet shutdown.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct FleetSnapshot {
    /// per-shard snapshots in shard-id order
    pub shards: Vec<(u64, MetricsSnapshot)>,
    /// the fleet-wide merged snapshot
    pub merged: MetricsSnapshot,
    /// fleet aggregation epochs completed (merge + drift-score + plan)
    pub merges: u64,
    /// shard windows the aggregator skipped for layout mismatch instead
    /// of dying (the hardened `SketchSet::merge` error path)
    pub skipped_windows: u64,
    /// (layer, bucket) positions that lost the partition-invariance
    /// guarantee to a truncated input reservoir, summed over epochs
    pub lossy_positions: u64,
    /// layers broadcast recalibration plans rebuilt, over every epoch
    pub plan_layers: Vec<u64>,
    /// fleet epoch the first broadcast swap applied at (None = no swap)
    pub swap_epoch: Option<u64>,
}

impl FleetSnapshot {
    pub fn to_json(&self) -> Json {
        obj(vec![
            (
                "shards",
                arr(self.shards.iter().map(|(id, snap)| {
                    obj(vec![("shard", num(*id as f64)), ("snapshot", snap.to_json())])
                })),
            ),
            ("merged", self.merged.to_json()),
            ("merges", num(self.merges as f64)),
            ("skipped_windows", num(self.skipped_windows as f64)),
            ("lossy_positions", num(self.lossy_positions as f64)),
            ("plan_layers", arr(self.plan_layers.iter().map(|&l| num(l as f64)))),
            (
                "swap_epoch",
                match self.swap_epoch {
                    Some(e) => num(e as f64),
                    None => Json::Null,
                },
            ),
        ])
    }

    pub fn from_json(j: &Json) -> Result<FleetSnapshot> {
        let mut shards = Vec::new();
        for entry in j.get("shards")?.arr()? {
            shards.push((
                entry.get("shard")?.usize()? as u64,
                MetricsSnapshot::from_json(entry.get("snapshot")?)?,
            ));
        }
        Ok(FleetSnapshot {
            shards,
            merged: MetricsSnapshot::from_json(j.get("merged")?)?,
            merges: j.get("merges")?.usize()? as u64,
            skipped_windows: j.get("skipped_windows")?.usize()? as u64,
            lossy_positions: j.get("lossy_positions")?.usize()? as u64,
            plan_layers: j
                .get("plan_layers")?
                .arr()?
                .iter()
                .map(|l| Ok(l.usize()? as u64))
                .collect::<Result<Vec<u64>>>()?,
            swap_epoch: match j.get("swap_epoch")? {
                Json::Null => None,
                v => Some(v.usize()? as u64),
            },
        })
    }

    /// Fleet Prometheus page: the merged snapshot's exposition plus the
    /// fleet-only series (`msfp_fleet_*`), including per-shard image
    /// counters so a scraper sees routing balance.
    pub fn prometheus(&self) -> String {
        use std::fmt::Write;
        let mut out = self.merged.prometheus();
        let mut head = |name: &str, kind: &str, help: &str| {
            let _ = writeln!(out, "# HELP {name} {help}");
            let _ = writeln!(out, "# TYPE {name} {kind}");
        };
        head("msfp_fleet_shards", "gauge", "coordinator shards in the fleet");
        let _ = writeln!(out, "msfp_fleet_shards {}", self.shards.len());
        head("msfp_fleet_merges_total", "counter", "fleet aggregation epochs completed");
        let _ = writeln!(out, "msfp_fleet_merges_total {}", self.merges);
        head(
            "msfp_fleet_skipped_windows_total",
            "counter",
            "shard windows skipped for layout mismatch",
        );
        let _ = writeln!(out, "msfp_fleet_skipped_windows_total {}", self.skipped_windows);
        head(
            "msfp_fleet_lossy_positions_total",
            "counter",
            "sketch positions merged via the lossy fallback",
        );
        let _ = writeln!(out, "msfp_fleet_lossy_positions_total {}", self.lossy_positions);
        head("msfp_fleet_shard_images_total", "counter", "images generated per shard");
        for (id, snap) in &self.shards {
            let _ =
                writeln!(out, "msfp_fleet_shard_images_total{{shard=\"{id}\"}} {}", snap.images);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn busy() -> MetricsSnapshot {
        MetricsSnapshot {
            requests: 16,
            images: 32,
            evals: 236,
            rounds: 11,
            backend: "packed".to_string(),
            packed_bytes: 2048,
            wall_s: 0.8212345,
            throughput: 38.973214,
            latency_p50_ms: 412.25,
            latency_p95_ms: 701.5,
            mean_batch: 5.8181818,
            mean_fill: 0.9090909,
            round_exec_ms: 630.125,
            round_sched_ms: 92.0625,
            exec_fraction: 0.87253,
            sel_hits: 200,
            sel_misses: 36,
            sel_hit_rate: 0.8474576,
            recal_checks: 5,
            recal_swaps: 2,
            recal_layers: 7,
            first_swap_round: Some(4),
            probes: 12,
            probes_skipped: 3,
            probes_failed: 1,
            wait_p50: [0, 1, 3],
            wait_p99: [1, 2, 7],
            wait_max: [1, 2, 9],
            shed: [0, 0, 2],
            downgraded_rounds: 4,
            downgraded_steps: 1,
            cancelled: 1,
            retries: 3,
            faults_injected: 2,
            compile_attempts: 5,
            compile_exhausted: 1,
            ckpt_fails: 1,
            ckpt_retries: 3,
            reconfigures: 2,
            rung_rounds: vec![4, 1],
            trace_events: 120,
            trace_dropped: 8,
            postmortems: 1,
        }
    }

    #[test]
    fn json_roundtrip_is_exact() {
        for snap in [busy(), MetricsSnapshot::default()] {
            let back = MetricsSnapshot::from_json(&snap.to_json()).unwrap();
            assert_eq!(back, snap);
            // through the actual serialized text, bit-for-bit — including
            // the non-integer f64 fields
            let text = snap.to_json().to_string();
            let back = MetricsSnapshot::from_json(&Json::parse(&text).unwrap()).unwrap();
            assert_eq!(back, snap);
            assert_eq!(back.to_json().to_string(), text, "re-serialization must be stable");
        }
    }

    #[test]
    fn first_swap_round_roundtrips_none_as_null() {
        let snap = MetricsSnapshot { first_swap_round: None, ..busy() };
        let text = snap.to_json().to_string();
        assert!(text.contains("\"first_swap_round\":null"), "{text}");
        assert_eq!(MetricsSnapshot::from_json(&Json::parse(&text).unwrap()).unwrap(), snap);
    }

    #[test]
    fn render_busy_shows_slo_line_and_packed_suffix() {
        let r = busy().render();
        assert!(r.contains("backend packed (2.0 KiB packed)"), "{r}");
        assert!(r.contains("recal 2/5 swaps (7 layers)"), "{r}");
        assert!(r.contains("slo:"), "{r}");
        assert!(r.contains("BestEffort wait p50/p99 3/7 rounds shed 2;"), "{r}");
        assert!(r.contains("ladder rounds [4, 1]"), "{r}");
        assert!(r.contains("ckpt 1 fails / 3 retries  reconfigures 2"), "{r}");
        // recorder counters live in the snapshot, never in the report line
        assert!(!r.contains("trace"), "{r}");
        assert!(!r.contains("postmortem"), "{r}");
    }

    #[test]
    fn render_slo_quiet_ignores_trace_counters() {
        let snap = MetricsSnapshot {
            backend: "graph".to_string(),
            trace_events: 500,
            trace_dropped: 100,
            postmortems: 2,
            ..MetricsSnapshot::default()
        };
        assert_eq!(snap.render_slo(), "");
        // zero-valued waits with samples present stay quiet (wait_max==0)
        let snap = MetricsSnapshot { wait_max: [0; 3], ..snap };
        assert_eq!(snap.render_slo(), "");
        // but any nonzero wait sample unquiets
        let snap = MetricsSnapshot { wait_max: [0, 1, 0], ..snap };
        assert!(snap.render_slo().contains("slo:"));
    }

    #[test]
    fn fleet_snapshot_roundtrips_and_exposes_fleet_series() {
        let fleet = FleetSnapshot {
            shards: vec![(0, busy()), (1, MetricsSnapshot::default())],
            merged: busy(),
            merges: 3,
            skipped_windows: 1,
            lossy_positions: 2,
            plan_layers: vec![0, 4, 7],
            swap_epoch: Some(2),
        };
        let text = fleet.to_json().to_string();
        let back = FleetSnapshot::from_json(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(back, fleet);
        assert_eq!(back.to_json().to_string(), text, "re-serialization must be stable");
        // swap_epoch None rides as null
        let none = FleetSnapshot { swap_epoch: None, ..fleet.clone() };
        let text = none.to_json().to_string();
        assert!(text.contains("\"swap_epoch\":null"), "{text}");
        assert_eq!(FleetSnapshot::from_json(&Json::parse(&text).unwrap()).unwrap(), none);

        let prom = fleet.prometheus();
        assert!(prom.contains("msfp_fleet_shards 2"), "{prom}");
        assert!(prom.contains("msfp_fleet_merges_total 3"), "{prom}");
        assert!(prom.contains("msfp_fleet_skipped_windows_total 1"), "{prom}");
        assert!(prom.contains("msfp_fleet_shard_images_total{shard=\"0\"} 32"), "{prom}");
        assert!(prom.contains("msfp_fleet_shard_images_total{shard=\"1\"} 0"), "{prom}");
        // the merged exposition rides along untouched
        assert!(prom.contains("msfp_requests_total 16"), "{prom}");
    }

    #[test]
    fn prometheus_exposition_shape() {
        let text = busy().prometheus();
        assert!(text.contains("# TYPE msfp_requests_total counter"), "{text}");
        assert!(text.contains("msfp_requests_total 16"), "{text}");
        assert!(text.contains("msfp_latency_ms{q=\"p50\"} 412.25"), "{text}");
        assert!(
            text.contains("msfp_queue_wait_rounds{class=\"besteffort\",q=\"p99\"} 7"),
            "{text}"
        );
        assert!(text.contains("msfp_shed_total{class=\"besteffort\"} 2"), "{text}");
        assert!(text.contains("msfp_rung_rounds_total{rung=\"1\"} 1"), "{text}");
        assert!(text.contains("msfp_trace_events_total 120"), "{text}");
        // every non-comment line is "name{labels} value"
        for line in text.lines().filter(|l| !l.starts_with('#')) {
            let mut parts = line.rsplitn(2, ' ');
            let value = parts.next().unwrap();
            assert!(value.parse::<f64>().is_ok(), "unparseable value in {line:?}");
            assert!(parts.next().unwrap().starts_with("msfp_"), "{line:?}");
        }
    }
}
