//! Structured flight-recorder events — one variant per coordinator
//! decision kind, each with a fixed-width binary image.
//!
//! An [`Event`] is `(round, seq, wall_us, kind)`. The `(round, seq)` pair
//! totally orders the *logical* trace: every event is emitted from the
//! scheduler thread (or drained back onto it in deterministic order), so
//! the sequence of `(round, seq, kind)` triples is a pure function of the
//! workload + seed and bit-identical for any worker count — the same
//! discipline the 1-vs-N parity suite pins for images and metrics.
//! `wall_us` is a wall-clock annotation only: it rides along for humans
//! reading a postmortem and is zeroed out by
//! [`Trace::logical_bytes`](super::recorder::Trace::logical_bytes) before
//! any determinism comparison.
//!
//! Encoding is little-endian, tag byte first, then the common header,
//! then a fixed per-variant payload — the same hand-rolled versioned
//! binary style as `recal::sketch` (no serde in this crate).

use anyhow::{bail, Result};

/// What happened. Payloads carry the decision inputs that make the event
/// replayable: ids, classes, rungs, fingerprints — never wall-clock.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum EventKind {
    /// One scheduling round was planned: queue state at plan time plus
    /// the ladder rung the backlog selected.
    Round { backlog: u32, admitted: u32, deferred: u32, batches: u32, rung: i32 },
    /// A request entered this round's working set (EDF admission).
    Admit { id: u64, class: u8, deadline: u64, steps: u32, images: u32, step_cut: bool },
    /// A request was shed (`reason` is the coordinator's `ShedReason`
    /// wire tag: 0 = deadline missed, 1 = retries exhausted).
    Shed { id: u64, class: u8, reason: u8 },
    /// The backlog-selected ladder rung changed between rounds.
    RungChange { from: i32, to: i32, backlog: u32 },
    /// A recal hot-swap landed: qparams fingerprints before/after plus
    /// how many layers drifted (full per-layer detail in the swap audit).
    HotSwap { swap: u64, drifted: u32, old_fp: u64, new_fp: u64 },
    /// A seeded `FaultPlan` fault fired on batch `batch` (`kind` =
    /// `exec::Fault::tag`).
    Fault { batch: u32, kind: u8 },
    /// A failed request re-queued with capped backoff.
    Retry { id: u64, attempt: u32, backoff_rounds: u64 },
    /// A checkpoint write attempt concluded (`ok` false = gave up after
    /// the retry budget; skipped writes are not events).
    Ckpt { what: u8, ok: bool },
    /// Shadow probes recycled from this round's served latents.
    Probe { sent: u32, skipped: u32 },
    /// `ServerHandle::reconfigure` applied a new `SloCfg` at a round
    /// boundary.
    Reconfigure { queue_budget: u32, step_cut: u32, ladder_depth: u32 },
    /// A client cancellation sweep retired a request.
    Cancel { id: u64 },
    /// A request completed and its response was handed to the offload
    /// lane.
    Done { id: u64, evals: u32, degraded: bool },
    /// A background recalibration check was kicked off (`fault` =
    /// injected `exec::Fault::tag`, 0 when clean).
    RecalCheck { check: u64, fault: u8 },
    /// A recalibration check panicked and was contained (the in-flight
    /// flag cleared; serving continued on the old qparams).
    RecalPanic { check: u64 },
    /// The scheduler exited its loop after `rounds` rounds.
    Shutdown { rounds: u64 },
}

/// Stable wire tag for the checkpoint kinds named in `Ckpt` events.
pub const CKPT_SKETCH: u8 = 0;
/// See [`CKPT_SKETCH`].
pub const CKPT_QPARAMS: u8 = 1;
/// See [`CKPT_SKETCH`] — postmortem trace/telemetry dumps count too.
pub const CKPT_TRACE: u8 = 2;

impl EventKind {
    /// Stable wire tag of this variant (also the postmortem sort key for
    /// events sharing a `(round, seq)` — which cannot happen, seq is
    /// globally monotone; the tag is purely the encoding discriminant).
    pub fn tag(&self) -> u8 {
        match self {
            EventKind::Round { .. } => 0,
            EventKind::Admit { .. } => 1,
            EventKind::Shed { .. } => 2,
            EventKind::RungChange { .. } => 3,
            EventKind::HotSwap { .. } => 4,
            EventKind::Fault { .. } => 5,
            EventKind::Retry { .. } => 6,
            EventKind::Ckpt { .. } => 7,
            EventKind::Probe { .. } => 8,
            EventKind::Reconfigure { .. } => 9,
            EventKind::Cancel { .. } => 10,
            EventKind::Done { .. } => 11,
            EventKind::RecalCheck { .. } => 12,
            EventKind::RecalPanic { .. } => 13,
            EventKind::Shutdown { .. } => 14,
        }
    }

    /// Short lowercase name (Prometheus label / postmortem rendering).
    pub fn name(&self) -> &'static str {
        match self {
            EventKind::Round { .. } => "round",
            EventKind::Admit { .. } => "admit",
            EventKind::Shed { .. } => "shed",
            EventKind::RungChange { .. } => "rung-change",
            EventKind::HotSwap { .. } => "hot-swap",
            EventKind::Fault { .. } => "fault",
            EventKind::Retry { .. } => "retry",
            EventKind::Ckpt { .. } => "ckpt",
            EventKind::Probe { .. } => "probe",
            EventKind::Reconfigure { .. } => "reconfigure",
            EventKind::Cancel { .. } => "cancel",
            EventKind::Done { .. } => "done",
            EventKind::RecalCheck { .. } => "recal-check",
            EventKind::RecalPanic { .. } => "recal-panic",
            EventKind::Shutdown { .. } => "shutdown",
        }
    }

    fn write_payload(&self, out: &mut Vec<u8>) {
        match *self {
            EventKind::Round { backlog, admitted, deferred, batches, rung } => {
                w32(out, backlog);
                w32(out, admitted);
                w32(out, deferred);
                w32(out, batches);
                wi32(out, rung);
            }
            EventKind::Admit { id, class, deadline, steps, images, step_cut } => {
                w64(out, id);
                out.push(class);
                w64(out, deadline);
                w32(out, steps);
                w32(out, images);
                out.push(step_cut as u8);
            }
            EventKind::Shed { id, class, reason } => {
                w64(out, id);
                out.push(class);
                out.push(reason);
            }
            EventKind::RungChange { from, to, backlog } => {
                wi32(out, from);
                wi32(out, to);
                w32(out, backlog);
            }
            EventKind::HotSwap { swap, drifted, old_fp, new_fp } => {
                w64(out, swap);
                w32(out, drifted);
                w64(out, old_fp);
                w64(out, new_fp);
            }
            EventKind::Fault { batch, kind } => {
                w32(out, batch);
                out.push(kind);
            }
            EventKind::Retry { id, attempt, backoff_rounds } => {
                w64(out, id);
                w32(out, attempt);
                w64(out, backoff_rounds);
            }
            EventKind::Ckpt { what, ok } => {
                out.push(what);
                out.push(ok as u8);
            }
            EventKind::Probe { sent, skipped } => {
                w32(out, sent);
                w32(out, skipped);
            }
            EventKind::Reconfigure { queue_budget, step_cut, ladder_depth } => {
                w32(out, queue_budget);
                w32(out, step_cut);
                w32(out, ladder_depth);
            }
            EventKind::Cancel { id } => w64(out, id),
            EventKind::Done { id, evals, degraded } => {
                w64(out, id);
                w32(out, evals);
                out.push(degraded as u8);
            }
            EventKind::RecalCheck { check, fault } => {
                w64(out, check);
                out.push(fault);
            }
            EventKind::RecalPanic { check } => w64(out, check),
            EventKind::Shutdown { rounds } => w64(out, rounds),
        }
    }

    fn read_payload(tag: u8, r: &mut super::recorder::TraceReader<'_>) -> Result<EventKind> {
        Ok(match tag {
            0 => EventKind::Round {
                backlog: r.u32()?,
                admitted: r.u32()?,
                deferred: r.u32()?,
                batches: r.u32()?,
                rung: r.u32()? as i32,
            },
            1 => EventKind::Admit {
                id: r.u64()?,
                class: r.u8()?,
                deadline: r.u64()?,
                steps: r.u32()?,
                images: r.u32()?,
                step_cut: r.u8()? != 0,
            },
            2 => EventKind::Shed { id: r.u64()?, class: r.u8()?, reason: r.u8()? },
            3 => EventKind::RungChange {
                from: r.u32()? as i32,
                to: r.u32()? as i32,
                backlog: r.u32()?,
            },
            4 => EventKind::HotSwap {
                swap: r.u64()?,
                drifted: r.u32()?,
                old_fp: r.u64()?,
                new_fp: r.u64()?,
            },
            5 => EventKind::Fault { batch: r.u32()?, kind: r.u8()? },
            6 => EventKind::Retry { id: r.u64()?, attempt: r.u32()?, backoff_rounds: r.u64()? },
            7 => EventKind::Ckpt { what: r.u8()?, ok: r.u8()? != 0 },
            8 => EventKind::Probe { sent: r.u32()?, skipped: r.u32()? },
            9 => EventKind::Reconfigure {
                queue_budget: r.u32()?,
                step_cut: r.u32()?,
                ladder_depth: r.u32()?,
            },
            10 => EventKind::Cancel { id: r.u64()? },
            11 => EventKind::Done { id: r.u64()?, evals: r.u32()?, degraded: r.u8()? != 0 },
            12 => EventKind::RecalCheck { check: r.u64()?, fault: r.u8()? },
            13 => EventKind::RecalPanic { check: r.u64()? },
            14 => EventKind::Shutdown { rounds: r.u64()? },
            t => bail!("corrupt trace: unknown event tag {t}"),
        })
    }
}

/// One recorded coordinator decision. Ordering (and the logical
/// determinism contract) is `(round, seq)`; `wall_us` is annotation only.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Event {
    /// Scheduler round the event belongs to (`Metrics::rounds` at emit).
    pub round: u64,
    /// Globally monotone sequence number within the recorder.
    pub seq: u64,
    /// Microseconds since recorder construction — excluded from logical
    /// trace comparisons.
    pub wall_us: u64,
    pub kind: EventKind,
}

impl Event {
    /// Append this event's binary image. `wall` false writes a zero
    /// wall-clock field — the *logical* image used for determinism
    /// comparisons.
    pub(super) fn write_to(&self, out: &mut Vec<u8>, wall: bool) {
        out.push(self.kind.tag());
        w64(out, self.round);
        w64(out, self.seq);
        w64(out, if wall { self.wall_us } else { 0 });
        self.kind.write_payload(out);
    }

    pub(super) fn read_from(r: &mut super::recorder::TraceReader<'_>) -> Result<Event> {
        let tag = r.u8()?;
        let round = r.u64()?;
        let seq = r.u64()?;
        let wall_us = r.u64()?;
        let kind = EventKind::read_payload(tag, r)?;
        Ok(Event { round, seq, wall_us, kind })
    }
}

fn w32(out: &mut Vec<u8>, v: u32) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn wi32(out: &mut Vec<u8>, v: i32) {
    out.extend_from_slice(&(v as u32).to_le_bytes());
}

fn w64(out: &mut Vec<u8>, v: u64) {
    out.extend_from_slice(&v.to_le_bytes());
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::recorder::TraceReader;

    fn roundtrip(kind: EventKind) {
        let ev = Event { round: 7, seq: 42, wall_us: 123_456, kind };
        let mut buf = Vec::new();
        ev.write_to(&mut buf, true);
        let mut r = TraceReader::new(&buf);
        let back = Event::read_from(&mut r).unwrap();
        assert_eq!(back, ev);
        assert_eq!(r.remaining(), 0, "payload width mismatch for {:?}", ev.kind);
    }

    #[test]
    fn every_variant_roundtrips() {
        roundtrip(EventKind::Round { backlog: 9, admitted: 4, deferred: 5, batches: 2, rung: 1 });
        roundtrip(EventKind::Admit {
            id: 3,
            class: 0,
            deadline: 12,
            steps: 6,
            images: 2,
            step_cut: true,
        });
        roundtrip(EventKind::Shed { id: 5, class: 2, reason: 1 });
        roundtrip(EventKind::RungChange { from: 0, to: 2, backlog: 14 });
        roundtrip(EventKind::HotSwap { swap: 1, drifted: 3, old_fp: 0xAB, new_fp: 0xCD });
        roundtrip(EventKind::Fault { batch: 1, kind: 2 });
        roundtrip(EventKind::Retry { id: 8, attempt: 2, backoff_rounds: 4 });
        roundtrip(EventKind::Ckpt { what: CKPT_TRACE, ok: false });
        roundtrip(EventKind::Probe { sent: 2, skipped: 1 });
        roundtrip(EventKind::Reconfigure { queue_budget: 32, step_cut: 2, ladder_depth: 3 });
        roundtrip(EventKind::Cancel { id: 11 });
        roundtrip(EventKind::Done { id: 1, evals: 18, degraded: true });
        roundtrip(EventKind::RecalCheck { check: 4, fault: 0 });
        roundtrip(EventKind::RecalPanic { check: 4 });
        roundtrip(EventKind::Shutdown { rounds: 40 });
    }

    #[test]
    fn logical_image_zeroes_wall_clock_only() {
        let ev = Event {
            round: 3,
            seq: 9,
            wall_us: 999,
            kind: EventKind::Probe { sent: 1, skipped: 0 },
        };
        let (mut with, mut without) = (Vec::new(), Vec::new());
        ev.write_to(&mut with, true);
        ev.write_to(&mut without, false);
        assert_eq!(with.len(), without.len());
        assert_ne!(with, without);
        let mut r = TraceReader::new(&without);
        let logical = Event::read_from(&mut r).unwrap();
        assert_eq!(logical.wall_us, 0);
        assert_eq!(logical.kind, ev.kind);
        assert_eq!((logical.round, logical.seq), (ev.round, ev.seq));
    }

    #[test]
    fn negative_rungs_survive_the_wire() {
        roundtrip(EventKind::RungChange { from: -1, to: -3, backlog: 0 });
        roundtrip(EventKind::Round { backlog: 0, admitted: 0, deferred: 0, batches: 0, rung: -2 });
    }

    #[test]
    fn unknown_tag_is_rejected() {
        let mut buf = Vec::new();
        Event {
            round: 0,
            seq: 0,
            wall_us: 0,
            kind: EventKind::Shutdown { rounds: 1 },
        }
        .write_to(&mut buf, true);
        buf[0] = 200;
        let mut r = TraceReader::new(&buf);
        let err = Event::read_from(&mut r).unwrap_err();
        assert!(err.to_string().contains("unknown event tag"), "{err}");
    }

    #[test]
    fn names_and_tags_are_distinct() {
        let kinds = [
            EventKind::Round { backlog: 0, admitted: 0, deferred: 0, batches: 0, rung: 0 },
            EventKind::Admit { id: 0, class: 0, deadline: 0, steps: 0, images: 0, step_cut: false },
            EventKind::Shed { id: 0, class: 0, reason: 0 },
            EventKind::RungChange { from: 0, to: 0, backlog: 0 },
            EventKind::HotSwap { swap: 0, drifted: 0, old_fp: 0, new_fp: 0 },
            EventKind::Fault { batch: 0, kind: 0 },
            EventKind::Retry { id: 0, attempt: 0, backoff_rounds: 0 },
            EventKind::Ckpt { what: 0, ok: true },
            EventKind::Probe { sent: 0, skipped: 0 },
            EventKind::Reconfigure { queue_budget: 0, step_cut: 0, ladder_depth: 0 },
            EventKind::Cancel { id: 0 },
            EventKind::Done { id: 0, evals: 0, degraded: false },
            EventKind::RecalCheck { check: 0, fault: 0 },
            EventKind::RecalPanic { check: 0 },
            EventKind::Shutdown { rounds: 0 },
        ];
        let mut tags: Vec<u8> = kinds.iter().map(|k| k.tag()).collect();
        let mut names: Vec<&str> = kinds.iter().map(|k| k.name()).collect();
        tags.sort_unstable();
        tags.dedup();
        names.sort_unstable();
        names.dedup();
        assert_eq!(tags.len(), kinds.len());
        assert_eq!(names.len(), kinds.len());
        assert_eq!(tags, (0..kinds.len() as u8).collect::<Vec<_>>());
    }
}
