//! Bounded flight recorder + versioned `trace.mtr` postmortems.
//!
//! The [`FlightRecorder`] is a fixed-capacity ring of [`Event`]s plus a
//! capped list of [`SwapAudit`] records. Emission is cheap (one mutex, no
//! allocation past the ring) and happens only on the scheduler thread, so
//! the retained *logical* trace — `(round, seq, kind)` with wall-clock
//! zeroed — is a pure function of (workload, seed, recorder capacity) and
//! bit-identical for any worker count. When the ring overflows, the oldest
//! events drop and `dropped` counts them; the drop schedule is part of the
//! logical trace (same capacity ⇒ same retained window).
//!
//! A [`Trace`] is the serializable snapshot: magic `MSFPTR01`, little-
//! endian, with the same distinct-error discipline as the sketch snapshot
//! format — foreign files ("not an MSFP trace"), other format versions
//! ("unsupported trace version"), truncation ("truncated trace at byte N")
//! and trailing garbage each fail with their own message. Postmortem dumps
//! go through `atomic_write`, so an installed `util::io::FaultFs` chaos
//! plan exercises the dump path for free and a crash-before-rename kill
//! point can never tear an existing postmortem.

use std::collections::VecDeque;
use std::path::Path;
use std::sync::Mutex;
use std::time::Instant;

use anyhow::{bail, Context, Result};

use super::event::{Event, EventKind};

/// Magic + version of the trace postmortem format. Bump the trailing two
/// digits on any layout change; [`Trace::from_bytes`] rejects foreign
/// files and other versions with distinct errors.
const TRACE_MAGIC: &[u8; 8] = b"MSFPTR01";

/// Retained swap audits (one per recal hot-swap — far below this cap in
/// any real window; the ring exists so a pathological drift storm cannot
/// grow the recorder unboundedly).
const AUDIT_CAP: usize = 256;

/// Decode-time sanity bounds: a corrupt header cannot make us reserve
/// gigabytes before the bounds-checked reader catches the truncation.
const MAX_EVENTS: usize = 1 << 22;
const MAX_AUDITS: usize = 1 << 16;
const MAX_AUDIT_ROWS: usize = 1 << 16;

/// One recal hot-swap decision, fully attributed: which check fired,
/// which layers drifted and by how much, the qparams fingerprints before
/// and after the swap, and how each ladder rung's refresh went. The
/// audit trail is what the ROADMAP's recalibration-aware LoRA refresh
/// needs — it names exactly the layers worth re-tuning.
#[derive(Debug, Clone, PartialEq)]
pub struct SwapAudit {
    /// Scheduler round the swap landed on (not the round the background
    /// check started — with >1 worker those may differ).
    pub round: u64,
    /// Index of the recal check that produced the plan.
    pub check: u64,
    /// `qparams_fingerprint` of the serving matrix before the swap…
    pub old_fp: u64,
    /// …and after it.
    pub new_fp: u64,
    /// `(layer, drift score)` for every layer the plan rebuilt.
    pub drifted: Vec<(u32, f32)>,
    /// `(wbits, abits, refreshed)` per ladder rung after the swap.
    pub rungs: Vec<(i32, i32, bool)>,
}

impl SwapAudit {
    fn write_to(&self, out: &mut Vec<u8>) {
        out.extend_from_slice(&self.round.to_le_bytes());
        out.extend_from_slice(&self.check.to_le_bytes());
        out.extend_from_slice(&self.old_fp.to_le_bytes());
        out.extend_from_slice(&self.new_fp.to_le_bytes());
        out.extend_from_slice(&(self.drifted.len() as u32).to_le_bytes());
        for &(layer, score) in &self.drifted {
            out.extend_from_slice(&layer.to_le_bytes());
            out.extend_from_slice(&score.to_bits().to_le_bytes());
        }
        out.extend_from_slice(&(self.rungs.len() as u32).to_le_bytes());
        for &(w, a, refreshed) in &self.rungs {
            out.extend_from_slice(&(w as u32).to_le_bytes());
            out.extend_from_slice(&(a as u32).to_le_bytes());
            out.push(refreshed as u8);
        }
    }

    fn read_from(r: &mut TraceReader<'_>) -> Result<SwapAudit> {
        let round = r.u64()?;
        let check = r.u64()?;
        let old_fp = r.u64()?;
        let new_fp = r.u64()?;
        let n_drifted = r.u32()? as usize;
        if n_drifted > MAX_AUDIT_ROWS {
            bail!("corrupt trace: audit names {n_drifted} drifted layers");
        }
        let mut drifted = Vec::with_capacity(n_drifted);
        for _ in 0..n_drifted {
            let layer = r.u32()?;
            let score = f32::from_bits(r.u32()?);
            drifted.push((layer, score));
        }
        let n_rungs = r.u32()? as usize;
        if n_rungs > MAX_AUDIT_ROWS {
            bail!("corrupt trace: audit names {n_rungs} ladder rungs");
        }
        let mut rungs = Vec::with_capacity(n_rungs);
        for _ in 0..n_rungs {
            let w = r.u32()? as i32;
            let a = r.u32()? as i32;
            rungs.push((w, a, r.u8()? != 0));
        }
        Ok(SwapAudit { round, check, old_fp, new_fp, drifted, rungs })
    }
}

/// A serializable snapshot of the recorder: the retained event window,
/// the swap audit trail, and the drop accounting.
#[derive(Debug, Clone, PartialEq)]
pub struct Trace {
    /// Retained events in `(round, seq)` order (the ring's oldest first).
    pub events: Vec<Event>,
    /// Hot-swap audit trail, oldest first.
    pub audits: Vec<SwapAudit>,
    /// Events evicted by the ring (emitted − retained).
    pub dropped: u64,
    /// Events emitted over the recorder's lifetime.
    pub total: u64,
}

impl Trace {
    fn bytes(&self, wall: bool) -> Vec<u8> {
        let mut out = Vec::with_capacity(32 + self.events.len() * 40 + self.audits.len() * 64);
        out.extend_from_slice(TRACE_MAGIC);
        out.extend_from_slice(&self.dropped.to_le_bytes());
        out.extend_from_slice(&self.total.to_le_bytes());
        out.extend_from_slice(&(self.events.len() as u32).to_le_bytes());
        out.extend_from_slice(&(self.audits.len() as u32).to_le_bytes());
        for ev in &self.events {
            ev.write_to(&mut out, wall);
        }
        for audit in &self.audits {
            audit.write_to(&mut out);
        }
        out
    }

    /// Full binary image, wall-clock annotations included — what a
    /// `trace.mtr` postmortem holds.
    pub fn to_bytes(&self) -> Vec<u8> {
        self.bytes(true)
    }

    /// The *logical* image: identical layout with every `wall_us` written
    /// as zero. This is the determinism contract — logical images from
    /// runs of the same workload at any worker count are byte-identical.
    pub fn logical_bytes(&self) -> Vec<u8> {
        self.bytes(false)
    }

    /// Parse a [`Trace::to_bytes`] image. Foreign files, other format
    /// versions, truncation and trailing bytes all fail with distinct
    /// errors (same discipline as `recal::SketchSet::from_bytes`).
    pub fn from_bytes(bytes: &[u8]) -> Result<Trace> {
        let mut r = TraceReader::new(bytes);
        let magic = r.take(8)?;
        if magic != TRACE_MAGIC {
            if magic[..6] == TRACE_MAGIC[..6] {
                bail!(
                    "unsupported trace version {:?} (this build reads {:?})",
                    String::from_utf8_lossy(&magic[6..]),
                    String::from_utf8_lossy(&TRACE_MAGIC[6..]),
                );
            }
            bail!("not an MSFP trace (bad magic)");
        }
        let dropped = r.u64()?;
        let total = r.u64()?;
        let n_events = r.u32()? as usize;
        let n_audits = r.u32()? as usize;
        if n_events > MAX_EVENTS || n_audits > MAX_AUDITS {
            bail!("corrupt trace: {n_events} events / {n_audits} audits exceed sanity bounds");
        }
        let mut events = Vec::with_capacity(n_events);
        for _ in 0..n_events {
            events.push(Event::read_from(&mut r)?);
        }
        let mut audits = Vec::with_capacity(n_audits);
        for _ in 0..n_audits {
            audits.push(SwapAudit::read_from(&mut r)?);
        }
        if r.remaining() != 0 {
            bail!("trailing bytes in trace ({} past end)", r.remaining());
        }
        Ok(Trace { events, audits, dropped, total })
    }

    /// Write a postmortem atomically (temp + rename + fsync): a reader —
    /// or a `FaultFs` crash-before-rename kill point — never observes a
    /// torn trace.
    pub fn save(&self, path: &Path) -> Result<()> {
        crate::util::io::atomic_write(path, &self.to_bytes())
            .with_context(|| format!("writing trace postmortem {}", path.display()))
    }

    /// Load a postmortem through the fault-aware retrying reader.
    pub fn load(path: &Path) -> Result<Trace> {
        let bytes = crate::util::io::read_file_retry(path, crate::util::io::RESTORE_ATTEMPTS)
            .with_context(|| format!("reading trace postmortem {}", path.display()))?;
        Trace::from_bytes(&bytes).with_context(|| format!("parsing {}", path.display()))
    }

    /// Human-oriented rendering for reading a postmortem: one line per
    /// event (`[round/seq +wall] kind payload`), then the audit trail.
    pub fn render(&self) -> String {
        use std::fmt::Write;
        let mut out = String::new();
        let _ = writeln!(
            out,
            "trace: {} events retained ({} emitted, {} dropped), {} swap audits",
            self.events.len(),
            self.total,
            self.dropped,
            self.audits.len()
        );
        for ev in &self.events {
            let _ = writeln!(
                out,
                "  [r{:5} #{:6} +{:9}us] {:11} {:?}",
                ev.round,
                ev.seq,
                ev.wall_us,
                ev.kind.name(),
                ev.kind
            );
        }
        for a in &self.audits {
            let _ = writeln!(
                out,
                "  audit: check {} landed round {}; qparams {:016x} -> {:016x}; \
                 drifted {:?}; rungs {:?}",
                a.check, a.round, a.old_fp, a.new_fp, a.drifted, a.rungs
            );
        }
        out
    }
}

/// Minimal bounds-checked little-endian cursor over a trace image.
pub(crate) struct TraceReader<'a> {
    bytes: &'a [u8],
    off: usize,
}

impl<'a> TraceReader<'a> {
    pub(crate) fn new(bytes: &'a [u8]) -> TraceReader<'a> {
        TraceReader { bytes, off: 0 }
    }

    pub(crate) fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if n > self.bytes.len() - self.off {
            bail!("truncated trace at byte {}", self.off);
        }
        let s = &self.bytes[self.off..self.off + n];
        self.off += n;
        Ok(s)
    }

    pub(crate) fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    pub(crate) fn u32(&mut self) -> Result<u32> {
        let b = self.take(4)?;
        Ok(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }

    pub(crate) fn u64(&mut self) -> Result<u64> {
        let b = self.take(8)?;
        Ok(u64::from_le_bytes([b[0], b[1], b[2], b[3], b[4], b[5], b[6], b[7]]))
    }

    pub(crate) fn remaining(&self) -> usize {
        self.bytes.len() - self.off
    }
}

struct RecorderInner {
    cap: usize,
    events: VecDeque<Event>,
    audits: VecDeque<SwapAudit>,
    seq: u64,
    dropped: u64,
}

/// Bounded in-memory event ring (see module docs). All methods take
/// `&self`; emission serializes on one internal mutex, which is
/// uncontended in practice — every emitter runs on the scheduler thread.
pub struct FlightRecorder {
    start: Instant,
    inner: Mutex<RecorderInner>,
}

impl FlightRecorder {
    /// `cap` is the retained-event window (≥ 1 enforced).
    pub fn new(cap: usize) -> FlightRecorder {
        let cap = cap.max(1);
        FlightRecorder {
            start: Instant::now(),
            inner: Mutex::new(RecorderInner {
                cap,
                events: VecDeque::with_capacity(cap.min(4096)),
                audits: VecDeque::new(),
                seq: 0,
                dropped: 0,
            }),
        }
    }

    /// Record one event at `round`. The sequence number is assigned here
    /// (globally monotone); the wall-clock annotation is microseconds
    /// since recorder construction.
    pub fn emit(&self, round: u64, kind: EventKind) {
        let wall_us = self.start.elapsed().as_micros() as u64;
        let mut inner = self.inner.lock().unwrap();
        let seq = inner.seq;
        inner.seq += 1;
        if inner.events.len() == inner.cap {
            inner.events.pop_front();
            inner.dropped += 1;
        }
        inner.events.push_back(Event { round, seq, wall_us, kind });
    }

    /// Append one hot-swap audit record (ring-capped at [`AUDIT_CAP`]).
    pub fn audit(&self, audit: SwapAudit) {
        let mut inner = self.inner.lock().unwrap();
        if inner.audits.len() == AUDIT_CAP {
            inner.audits.pop_front();
        }
        inner.audits.push_back(audit);
    }

    /// Events emitted over the recorder's lifetime (retained + dropped).
    pub fn total(&self) -> u64 {
        self.inner.lock().unwrap().seq
    }

    /// Events evicted by the ring so far.
    pub fn dropped(&self) -> u64 {
        self.inner.lock().unwrap().dropped
    }

    /// Snapshot the current window as a serializable [`Trace`].
    pub fn trace(&self) -> Trace {
        let inner = self.inner.lock().unwrap();
        Trace {
            events: inner.events.iter().cloned().collect(),
            audits: inner.audits.iter().cloned().collect(),
            dropped: inner.dropped,
            total: inner.seq,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::io::{read_file, FaultFs};

    fn probe(sent: u32) -> EventKind {
        EventKind::Probe { sent, skipped: 0 }
    }

    fn sample_audit() -> SwapAudit {
        SwapAudit {
            round: 12,
            check: 3,
            old_fp: 0xDEAD_BEEF,
            new_fp: 0xFEED_FACE,
            drifted: vec![(0, 1.5), (4, -0.25)],
            rungs: vec![(4, 4, true), (3, 4, true), (2, 3, false)],
        }
    }

    #[test]
    fn ring_caps_drops_oldest_and_counts() {
        let rec = FlightRecorder::new(4);
        for i in 0..10u32 {
            rec.emit(i as u64, probe(i));
        }
        assert_eq!(rec.total(), 10);
        assert_eq!(rec.dropped(), 6);
        let tr = rec.trace();
        assert_eq!(tr.events.len(), 4);
        assert_eq!(tr.dropped, 6);
        assert_eq!(tr.total, 10);
        // oldest evicted first; seq stays globally monotone
        let seqs: Vec<u64> = tr.events.iter().map(|e| e.seq).collect();
        assert_eq!(seqs, vec![6, 7, 8, 9]);
        assert_eq!(tr.events[0].kind, probe(6));
    }

    #[test]
    fn audits_are_capped() {
        let rec = FlightRecorder::new(8);
        for i in 0..(AUDIT_CAP as u64 + 10) {
            rec.audit(SwapAudit { round: i, ..sample_audit() });
        }
        let tr = rec.trace();
        assert_eq!(tr.audits.len(), AUDIT_CAP);
        assert_eq!(tr.audits[0].round, 10, "oldest audits evicted first");
    }

    #[test]
    fn trace_roundtrip_is_bit_exact() {
        let rec = FlightRecorder::new(16);
        rec.emit(0, EventKind::Round { backlog: 3, admitted: 3, deferred: 0, batches: 2, rung: 0 });
        rec.emit(
            0,
            EventKind::Admit { id: 1, class: 0, deadline: 8, steps: 6, images: 2, step_cut: false },
        );
        rec.emit(1, EventKind::Shed { id: 2, class: 2, reason: 0 });
        rec.emit(2, EventKind::Shutdown { rounds: 3 });
        rec.audit(sample_audit());
        let tr = rec.trace();
        let bytes = tr.to_bytes();
        let back = Trace::from_bytes(&bytes).unwrap();
        assert_eq!(back, tr);
        assert_eq!(back.to_bytes(), bytes, "re-serialization must be stable");
    }

    #[test]
    fn logical_bytes_strip_wall_clock_only() {
        // two recorders emit the same logical events at different wall
        // times; the logical images match while the full images may not
        let mk = || {
            let rec = FlightRecorder::new(8);
            rec.emit(0, probe(1));
            rec.emit(1, EventKind::Cancel { id: 5 });
            rec
        };
        let a = mk();
        std::thread::sleep(std::time::Duration::from_millis(2));
        let b = mk();
        assert_eq!(a.trace().logical_bytes(), b.trace().logical_bytes());
        let logical = Trace::from_bytes(&a.trace().logical_bytes()).unwrap();
        assert!(logical.events.iter().all(|e| e.wall_us == 0));
        assert_eq!(
            logical.events.iter().map(|e| &e.kind).collect::<Vec<_>>(),
            a.trace().events.iter().map(|e| &e.kind).collect::<Vec<_>>(),
        );
    }

    #[test]
    fn rejects_foreign_versioned_truncated_and_trailing() {
        let rec = FlightRecorder::new(4);
        rec.emit(0, probe(1));
        rec.audit(sample_audit());
        let bytes = rec.trace().to_bytes();
        // foreign magic → its own error
        let mut junk = bytes.clone();
        junk[..8].copy_from_slice(b"NOTMAGIC");
        let err = Trace::from_bytes(&junk).unwrap_err();
        assert!(err.to_string().contains("not an MSFP trace"), "{err}");
        // same family, different version digits → distinct error
        let mut v99 = bytes.clone();
        v99[6..8].copy_from_slice(b"99");
        let err = Trace::from_bytes(&v99).unwrap_err();
        assert!(err.to_string().contains("unsupported trace version"), "{err}");
        // every truncation point fails loudly with the byte offset
        for cut in [0, 5, 8, 20, bytes.len() / 2, bytes.len() - 1] {
            let err = Trace::from_bytes(&bytes[..cut]).unwrap_err();
            assert!(err.to_string().contains("truncated trace"), "cut {cut}: {err}");
        }
        // trailing garbage
        let mut long = bytes;
        long.push(7);
        let err = Trace::from_bytes(&long).unwrap_err();
        assert!(err.to_string().contains("trailing bytes"), "{err}");
    }

    #[test]
    fn corrupt_counts_are_bounded_not_allocated() {
        let rec = FlightRecorder::new(2);
        rec.emit(0, probe(1));
        let mut bytes = rec.trace().to_bytes();
        // claim 2^31 events: must fail on the sanity bound, not OOM
        bytes[24..28].copy_from_slice(&(1u32 << 31).to_le_bytes());
        let err = Trace::from_bytes(&bytes).unwrap_err();
        assert!(err.to_string().contains("sanity bounds"), "{err}");
    }

    #[test]
    fn postmortem_file_roundtrip_and_render() {
        let dir = std::env::temp_dir().join("msfp_obs_postmortem");
        let _ = std::fs::remove_dir_all(&dir);
        let rec = FlightRecorder::new(8);
        rec.emit(0, EventKind::Fault { batch: 1, kind: 2 });
        rec.emit(1, EventKind::RecalPanic { check: 0 });
        rec.audit(sample_audit());
        let tr = rec.trace();
        let path = dir.join("trace.mtr");
        tr.save(&path).unwrap();
        assert_eq!(Trace::load(&path).unwrap(), tr);
        let text = tr.render();
        assert!(text.contains("fault"), "{text}");
        assert!(text.contains("recal-panic"), "{text}");
        assert!(text.contains("audit: check 3"), "{text}");
        assert!(text.contains("2 events retained"), "{text}");
    }

    #[test]
    fn postmortem_survives_crash_before_rename() {
        // chaos drill: a postmortem landed before the kill point must
        // survive a crash-before-rename on the overwrite attempt intact —
        // atomic_write renames whole files only
        let dir = std::env::temp_dir().join("msfp_obs_crash_drill");
        let _ = std::fs::remove_dir_all(&dir);
        let path = dir.join("trace.mtr");
        let rec = FlightRecorder::new(8);
        rec.emit(0, probe(1));
        let first = rec.trace();
        first.save(&path).unwrap();
        rec.emit(1, probe(2));
        let guard = FaultFs { crash_per_mille: 1000, ..FaultFs::new(11) }.install(&dir);
        let err = rec.trace().save(&path).unwrap_err();
        assert!(format!("{err:#}").contains("crash before renaming"), "{err:#}");
        // the surviving postmortem is the complete first dump, not a tear
        assert_eq!(Trace::load(&path).unwrap(), first);
        drop(guard);
        // clean retry lands the newer window
        rec.trace().save(&path).unwrap();
        assert_eq!(Trace::load(&path).unwrap().events.len(), 2);
        // no staged temp strays survive the injected crash
        let stray: Vec<_> = std::fs::read_dir(&dir)
            .unwrap()
            .map(|e| e.unwrap().file_name().into_string().unwrap())
            .filter(|n| n != "trace.mtr")
            .collect();
        assert!(stray.is_empty(), "stray files: {stray:?}");
        let _ = read_file(&path).unwrap();
    }
}
