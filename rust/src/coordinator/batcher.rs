//! Step-level continuous batching policy (pure logic, unit-tested).
//!
//! Quantized serving constraint: one model evaluation shares a single
//! timestep t (TALoRA routes per timestep), so only same-t evals can share
//! a batch. Each scheduling round takes every pending evaluation ticket,
//! groups by t, packs FIFO-greedily into the compiled batch-size classes,
//! and returns the execution plan.
//!
//! The FP graph has no such constraint — it takes per-sample t — so FP
//! rounds may plan *mixed-t* batches ([`PlanMode::MixedT`]): tickets pack
//! FIFO across timesteps, cutting the number of (padded) evaluations per
//! round when concurrent requests sit at different denoising phases.
//! Per-sample results are unchanged — a batch slot computes the same
//! function of its own (x, t, cond) regardless of batchmates — and the
//! executor-level parity test (`coordinator::exec`) plus the FP serving
//! integration test pin the mixed-t scatter bitwise against same-t plans.

use super::request::SloClass;

/// One pending model evaluation: request `req` needs its `n` samples
/// evaluated at timestep `t`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Ticket {
    pub req: usize,
    pub t: f32,
    pub n: usize,
}

/// A ticket annotated with its request's SLO metadata, the input of
/// [`admit_edf`]. `deadline` is absolute (admission round + deadline
/// budget); `id` is the request id, the stable tie-break that keeps the
/// admission order deterministic when class and deadline agree.
#[derive(Debug, Clone, Copy)]
pub struct SloTicket {
    pub ticket: Ticket,
    pub class: SloClass,
    pub deadline: u64,
    pub id: u64,
}

/// Earliest-deadline-first admission within class priority: candidates
/// are ordered by (class rank, deadline, id) and admitted whole-ticket
/// greedily until `budget` samples are planned (0 = unlimited). The first
/// candidate always admits — a ticket larger than the whole budget must
/// not stall the round — and later, smaller tickets may still fit after a
/// larger one was deferred (work-conserving). Returns the admitted
/// tickets in EDF order plus the indices (into `cands`) of the deferred
/// ones.
///
/// Pure in (cands, budget): the scheduler's shed/downgrade/queue-wait
/// decisions built on top of this stay bit-identical for any worker
/// count.
pub fn admit_edf(cands: &[SloTicket], budget: usize) -> (Vec<Ticket>, Vec<usize>) {
    let mut order: Vec<usize> = (0..cands.len()).collect();
    order.sort_by_key(|&i| (cands[i].class.rank(), cands[i].deadline, cands[i].id));
    let mut admitted = Vec::with_capacity(cands.len());
    let mut deferred = Vec::new();
    let mut used = 0usize;
    for i in order {
        let n = cands[i].ticket.n;
        if budget == 0 || admitted.is_empty() || used + n <= budget {
            used += n;
            admitted.push(cands[i].ticket);
        } else {
            deferred.push(i);
        }
    }
    (admitted, deferred)
}

/// Whether a round's batches must share a timestep (quantized serving:
/// TALoRA routes per timestep) or may mix them (FP serving: the graph
/// takes per-sample t).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PlanMode {
    SameT,
    MixedT,
}

/// A planned batch: tickets packed to `class` slots. Under
/// [`PlanMode::SameT`] all tickets share `t`; under [`PlanMode::MixedT`]
/// `t` is the first ticket's timestep (a label only — consumers needing
/// per-sample timesteps read them off the tickets).
#[derive(Debug, Clone, PartialEq)]
pub struct Batch {
    pub t: f32,
    pub class: usize,
    pub tickets: Vec<Ticket>,
}

impl Batch {
    pub fn used(&self) -> usize {
        self.tickets.iter().map(|tk| tk.n).sum()
    }

    /// fill ratio = used slots / class size (batching efficiency metric)
    pub fn fill(&self) -> f32 {
        self.used() as f32 / self.class as f32
    }
}

/// Pack tickets into same-t batches (the quantized-serving constraint).
/// `classes` must be the ascending compiled batch sizes. Tickets larger
/// than the max class are split. Equivalent to
/// `plan_mode(.., PlanMode::SameT)`.
pub fn plan(tickets: &[Ticket], classes: &[usize]) -> Vec<Batch> {
    plan_mode(tickets, classes, PlanMode::SameT)
}

/// Mode-aware packing: [`PlanMode::SameT`] groups by exact t bits before
/// packing (samplers produce identical t for identical phases);
/// [`PlanMode::MixedT`] packs all tickets FIFO into one stream regardless
/// of timestep. Ticket order within a request is preserved in both modes,
/// so [`ticket_offsets`] assigns identical per-request sample ranges.
pub fn plan_mode(tickets: &[Ticket], classes: &[usize], mode: PlanMode) -> Vec<Batch> {
    assert!(!classes.is_empty());
    let max = *classes.last().unwrap();
    // split oversized tickets
    let mut items: Vec<Ticket> = Vec::with_capacity(tickets.len());
    for &tk in tickets {
        let mut left = tk.n;
        while left > 0 {
            let take = left.min(max);
            items.push(Ticket { req: tk.req, t: tk.t, n: take });
            left -= take;
        }
    }
    let groups: Vec<Vec<Ticket>> = match mode {
        PlanMode::MixedT => vec![items],
        PlanMode::SameT => {
            let mut groups: Vec<(u32, Vec<Ticket>)> = Vec::new();
            for tk in items {
                let key = tk.t.to_bits();
                match groups.iter_mut().find(|(k, _)| *k == key) {
                    Some((_, v)) => v.push(tk),
                    None => groups.push((key, vec![tk])),
                }
            }
            groups.into_iter().map(|(_, v)| v).collect()
        }
    };
    let mut out = Vec::new();
    for group in groups {
        let mut current: Vec<Ticket> = Vec::new();
        let mut used = 0usize;
        for tk in group {
            if used + tk.n > max && used > 0 {
                out.push(close_batch(std::mem::take(&mut current), classes));
                used = 0;
            }
            used += tk.n;
            current.push(tk);
        }
        if !current.is_empty() {
            out.push(close_batch(current, classes));
        }
    }
    out
}

/// Per-batch, per-ticket start offsets (in samples) into each request's
/// sample array, assigned in plan order.
///
/// Split tickets of one request keep sample order across batches, so a
/// request's k-th planned sample always lands at offset k. Fixing every
/// offset *before* execution is what lets the round executor run batches
/// in parallel with a bit-identical scatter, and what keeps a failing
/// batch from shifting the slices of its neighbors (each surviving batch
/// still writes to its own pre-assigned range).
pub fn ticket_offsets(batches: &[Batch], n_reqs: usize) -> Vec<Vec<usize>> {
    let mut next = vec![0usize; n_reqs];
    batches
        .iter()
        .map(|b| {
            b.tickets
                .iter()
                .map(|tk| {
                    let off = next[tk.req];
                    next[tk.req] += tk.n;
                    off
                })
                .collect()
        })
        .collect()
}

fn close_batch(tickets: Vec<Ticket>, classes: &[usize]) -> Batch {
    let used: usize = tickets.iter().map(|t| t.n).sum();
    let class = *classes.iter().find(|&&c| c >= used).unwrap_or(classes.last().unwrap());
    Batch { t: tickets[0].t, class, tickets }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;
    use crate::util::rng::Rng;

    const CLASSES: &[usize] = &[1, 2, 4, 8];

    #[test]
    fn same_t_merges() {
        let tickets =
            vec![Ticket { req: 0, t: 5.0, n: 2 }, Ticket { req: 1, t: 5.0, n: 3 }];
        let plan = plan(&tickets, CLASSES);
        assert_eq!(plan.len(), 1);
        assert_eq!(plan[0].class, 8);
        assert_eq!(plan[0].used(), 5);
    }

    #[test]
    fn different_t_never_merge() {
        let tickets =
            vec![Ticket { req: 0, t: 5.0, n: 1 }, Ticket { req: 1, t: 6.0, n: 1 }];
        let plan = plan(&tickets, CLASSES);
        assert_eq!(plan.len(), 2);
        assert_eq!(plan[0].class, 1);
    }

    #[test]
    fn oversized_request_splits() {
        let tickets = vec![Ticket { req: 0, t: 2.0, n: 19 }];
        let plan = plan(&tickets, CLASSES);
        let total: usize = plan.iter().map(|b| b.used()).sum();
        assert_eq!(total, 19);
        assert!(plan.iter().all(|b| b.used() <= 8));
        assert_eq!(plan.len(), 3); // 8 + 8 + 3
    }

    #[test]
    fn class_is_smallest_fitting() {
        let p3 = plan(&[Ticket { req: 0, t: 1.0, n: 3 }], CLASSES);
        assert_eq!(p3[0].class, 4);
        let p1 = plan(&[Ticket { req: 0, t: 1.0, n: 1 }], CLASSES);
        assert_eq!(p1[0].class, 1);
    }

    #[test]
    fn fifo_order_within_group() {
        let tickets = vec![
            Ticket { req: 7, t: 3.0, n: 4 },
            Ticket { req: 8, t: 3.0, n: 4 },
            Ticket { req: 9, t: 3.0, n: 4 },
        ];
        let plan = plan(&tickets, CLASSES);
        assert_eq!(plan.len(), 2);
        assert_eq!(plan[0].tickets[0].req, 7);
        assert_eq!(plan[0].tickets[1].req, 8);
        assert_eq!(plan[1].tickets[0].req, 9); // no starvation / reorder
    }

    #[test]
    fn ticket_offsets_follow_plan_order() {
        // one oversized request split across three batches, interleaved
        // with a small same-t request
        let tickets = vec![
            Ticket { req: 0, t: 2.0, n: 19 },
            Ticket { req: 1, t: 2.0, n: 3 },
        ];
        let batches = plan(&tickets, CLASSES);
        let offs = ticket_offsets(&batches, 2);
        assert_eq!(offs.len(), batches.len());
        // request 0's chunks cover [0,8), [8,16), [16,19) in plan order
        let mut seen0 = Vec::new();
        let mut seen1 = Vec::new();
        for (b, off) in batches.iter().zip(&offs) {
            for (tk, &start) in b.tickets.iter().zip(off) {
                if tk.req == 0 {
                    seen0.push((start, tk.n));
                } else {
                    seen1.push((start, tk.n));
                }
            }
        }
        let mut expect = 0;
        for (start, n) in seen0 {
            assert_eq!(start, expect);
            expect += n;
        }
        assert_eq!(expect, 19);
        assert_eq!(seen1, vec![(0, 3)]);
    }

    #[test]
    fn prop_ticket_offsets_are_contiguous_per_request() {
        prop::check(
            "ticket-offsets-contiguous",
            200,
            |rng: &mut Rng| {
                let n = 1 + rng.below(12);
                (0..n)
                    .map(|i| Ticket {
                        req: i,
                        t: rng.below(4) as f32,
                        n: 1 + rng.below(20),
                    })
                    .collect::<Vec<_>>()
            },
            |tickets| {
                let batches = plan(tickets, CLASSES);
                let offs = ticket_offsets(&batches, tickets.len());
                // per request, collected (start, n) chunks tile [0, n_req)
                let mut chunks: Vec<Vec<(usize, usize)>> = vec![Vec::new(); tickets.len()];
                for (b, off) in batches.iter().zip(&offs) {
                    for (tk, &start) in b.tickets.iter().zip(off) {
                        chunks[tk.req].push((start, tk.n));
                    }
                }
                tickets.iter().all(|tk| {
                    let mut expect = 0;
                    for &(start, n) in &chunks[tk.req] {
                        if start != expect {
                            return false;
                        }
                        expect += n;
                    }
                    expect == tk.n
                })
            },
        );
    }

    #[test]
    fn prop_no_ticket_lost_and_caps_respected() {
        prop::check(
            "batcher-conservation",
            200,
            |rng: &mut Rng| {
                let n = 1 + rng.below(20);
                (0..n)
                    .map(|i| Ticket {
                        req: i,
                        t: rng.below(5) as f32,
                        n: 1 + rng.below(12),
                    })
                    .collect::<Vec<_>>()
            },
            |tickets| {
                let batches = plan(tickets, CLASSES);
                let total_in: usize = tickets.iter().map(|t| t.n).sum();
                let total_out: usize = batches.iter().map(|b| b.used()).sum();
                total_in == total_out
                    && batches.iter().all(|b| b.used() <= b.class && b.class <= 8)
                    && batches
                        .iter()
                        .all(|b| b.tickets.iter().all(|tk| tk.t == b.t))
            },
        );
    }

    #[test]
    fn mixed_t_merges_across_timesteps() {
        let tickets =
            vec![Ticket { req: 0, t: 5.0, n: 2 }, Ticket { req: 1, t: 6.0, n: 3 }];
        // same-t: two batches; mixed-t: one class-8 batch
        assert_eq!(plan(&tickets, CLASSES).len(), 2);
        let mixed = plan_mode(&tickets, CLASSES, PlanMode::MixedT);
        assert_eq!(mixed.len(), 1);
        assert_eq!(mixed[0].used(), 5);
        assert_eq!(mixed[0].class, 8);
        // per-ticket timesteps survive in the plan
        assert_eq!(mixed[0].tickets[0].t, 5.0);
        assert_eq!(mixed[0].tickets[1].t, 6.0);
    }

    #[test]
    fn mixed_t_equals_same_t_on_uniform_timesteps() {
        let tickets: Vec<Ticket> =
            (0..7).map(|i| Ticket { req: i, t: 3.0, n: 1 + i % 4 }).collect();
        assert_eq!(
            plan(&tickets, CLASSES),
            plan_mode(&tickets, CLASSES, PlanMode::MixedT)
        );
    }

    #[test]
    fn prop_mixed_t_conservation_and_offsets() {
        prop::check(
            "mixed-t-conservation",
            200,
            |rng: &mut Rng| {
                let n = 1 + rng.below(16);
                (0..n)
                    .map(|i| Ticket {
                        req: i,
                        t: rng.below(6) as f32,
                        n: 1 + rng.below(14),
                    })
                    .collect::<Vec<_>>()
            },
            |tickets| {
                let batches = plan_mode(tickets, CLASSES, PlanMode::MixedT);
                let total_in: usize = tickets.iter().map(|t| t.n).sum();
                let total_out: usize = batches.iter().map(|b| b.used()).sum();
                if total_in != total_out || batches.iter().any(|b| b.used() > b.class) {
                    return false;
                }
                // offsets tile each request's samples contiguously, exactly
                // as under same-t planning
                let offs = ticket_offsets(&batches, tickets.len());
                let mut chunks: Vec<Vec<(usize, usize)>> = vec![Vec::new(); tickets.len()];
                for (b, off) in batches.iter().zip(&offs) {
                    for (tk, &start) in b.tickets.iter().zip(off) {
                        chunks[tk.req].push((start, tk.n));
                    }
                }
                tickets.iter().all(|tk| {
                    let mut expect = 0;
                    for &(start, n) in &chunks[tk.req] {
                        if start != expect {
                            return false;
                        }
                        expect += n;
                    }
                    expect == tk.n
                })
            },
        );
    }

    #[test]
    fn mixed_t_cuts_batches_on_scattered_singletons() {
        // the serving shape: one small ticket per request, timesteps spread
        // across denoising phases — same-t planning yields one tiny batch
        // per distinct t, mixed-t packs them into full classes
        let tickets: Vec<Ticket> =
            (0..12).map(|i| Ticket { req: i, t: i as f32, n: 1 }).collect();
        assert_eq!(plan(&tickets, CLASSES).len(), 12);
        let mixed = plan_mode(&tickets, CLASSES, PlanMode::MixedT);
        assert_eq!(mixed.len(), 2); // 8 + 4
        assert!(mixed.iter().all(|b| b.fill() >= 0.99));
    }

    #[test]
    fn empty_round_plans_nothing_in_both_modes() {
        for mode in [PlanMode::SameT, PlanMode::MixedT] {
            let plan = plan_mode(&[], CLASSES, mode);
            assert!(plan.is_empty(), "{mode:?}");
            assert!(ticket_offsets(&plan, 0).is_empty());
        }
    }

    #[test]
    fn single_ticket_mixed_t_matches_same_t() {
        // one ticket (the single-request server): both modes must produce
        // the identical plan, including the oversized-split path
        for n in [1usize, 3, 8, 19] {
            let tickets = vec![Ticket { req: 0, t: 4.5, n }];
            let same = plan(&tickets, CLASSES);
            let mixed = plan_mode(&tickets, CLASSES, PlanMode::MixedT);
            assert_eq!(same, mixed, "n={n}");
            assert_eq!(ticket_offsets(&same, 1), ticket_offsets(&mixed, 1));
            let total: usize = mixed.iter().map(|b| b.used()).sum();
            assert_eq!(total, n);
        }
    }

    #[test]
    fn prop_mixed_t_degenerates_to_same_t_on_uniform_input() {
        // all-same-t input: MixedT must degenerate to SameT batching
        // EXACTLY — same batches, same classes, same ticket order
        prop::check(
            "mixed-t-uniform-degenerate",
            200,
            |rng: &mut Rng| {
                let t = rng.below(7) as f32 * 1.5;
                let n = 1 + rng.below(14);
                (0..n)
                    .map(|i| Ticket { req: i, t, n: 1 + rng.below(11) })
                    .collect::<Vec<_>>()
            },
            |tickets| {
                let same = plan(tickets, CLASSES);
                let mixed = plan_mode(tickets, CLASSES, PlanMode::MixedT);
                same == mixed
                    && ticket_offsets(&same, tickets.len())
                        == ticket_offsets(&mixed, tickets.len())
            },
        );
    }

    fn slo(req: usize, n: usize, class: SloClass, deadline: u64, id: u64) -> SloTicket {
        SloTicket { ticket: Ticket { req, t: 1.0, n }, class, deadline, id }
    }

    #[test]
    fn edf_orders_by_class_then_deadline_then_id() {
        let cands = vec![
            slo(0, 1, SloClass::BestEffort, 2, 10),
            slo(1, 1, SloClass::Interactive, 9, 11),
            slo(2, 1, SloClass::Interactive, 4, 12),
            slo(3, 1, SloClass::Batch, 1, 13),
            slo(4, 1, SloClass::Interactive, 4, 9),
        ];
        let (admitted, deferred) = admit_edf(&cands, 0);
        assert!(deferred.is_empty());
        let reqs: Vec<usize> = admitted.iter().map(|tk| tk.req).collect();
        // interactive by (deadline, id), then batch, then best-effort
        assert_eq!(reqs, vec![4, 2, 1, 3, 0]);
    }

    #[test]
    fn edf_budget_defers_lowest_priority_latest_deadline() {
        let cands = vec![
            slo(0, 2, SloClass::BestEffort, 5, 1),
            slo(1, 2, SloClass::Interactive, 8, 2),
            slo(2, 2, SloClass::Batch, 3, 3),
        ];
        let (admitted, deferred) = admit_edf(&cands, 4);
        let reqs: Vec<usize> = admitted.iter().map(|tk| tk.req).collect();
        assert_eq!(reqs, vec![1, 2]);
        assert_eq!(deferred, vec![0]);
    }

    #[test]
    fn edf_oversized_first_ticket_always_admits() {
        let cands = vec![
            slo(0, 12, SloClass::Interactive, 1, 1),
            slo(1, 1, SloClass::Interactive, 2, 2),
        ];
        let (admitted, deferred) = admit_edf(&cands, 4);
        // the head-of-line ticket admits even though it alone exceeds the
        // budget (otherwise the round would stall forever)
        assert_eq!(admitted.len(), 1);
        assert_eq!(admitted[0].req, 0);
        assert_eq!(deferred, vec![1]);
    }

    #[test]
    fn edf_is_work_conserving_after_a_deferral() {
        let cands = vec![
            slo(0, 3, SloClass::Interactive, 1, 1),
            slo(1, 3, SloClass::Interactive, 2, 2), // deferred (3+3 > 4)
            slo(2, 1, SloClass::Batch, 9, 3),       // still fits (3+1 <= 4)
        ];
        let (admitted, deferred) = admit_edf(&cands, 4);
        let reqs: Vec<usize> = admitted.iter().map(|tk| tk.req).collect();
        assert_eq!(reqs, vec![0, 2]);
        assert_eq!(deferred, vec![1]);
    }

    #[test]
    fn edf_unlimited_budget_same_class_is_deadline_stable() {
        // all-batch candidates with equal deadlines keep id order — the
        // pre-SLO coordinator's arrival order, so a budget-less server
        // plans exactly as before
        let cands: Vec<SloTicket> =
            (0..6).map(|i| slo(i, 1 + i % 3, SloClass::Batch, 10, i as u64)).collect();
        let (admitted, deferred) = admit_edf(&cands, 0);
        assert!(deferred.is_empty());
        assert_eq!(admitted.iter().map(|tk| tk.req).collect::<Vec<_>>(), vec![0, 1, 2, 3, 4, 5]);
    }

    #[test]
    fn prop_edf_conserves_tickets_and_respects_budget() {
        prop::check(
            "edf-conservation",
            200,
            |rng: &mut Rng| {
                let n = 1 + rng.below(16);
                let budget = rng.below(20);
                let cands: Vec<SloTicket> = (0..n)
                    .map(|i| {
                        slo(
                            i,
                            1 + rng.below(6),
                            SloClass::ALL[rng.below(3)],
                            rng.below(30) as u64,
                            i as u64,
                        )
                    })
                    .collect();
                (cands, budget)
            },
            |(cands, budget)| {
                let (admitted, deferred) = admit_edf(cands, *budget);
                if admitted.len() + deferred.len() != cands.len() {
                    return false;
                }
                // beyond the head-of-line exception, admitted samples
                // never exceed the budget
                let used: usize = admitted.iter().map(|tk| tk.n).sum();
                if *budget > 0 && admitted.len() > 1 && used > *budget {
                    return false;
                }
                // admitted tickets come out in (class, deadline, id) order
                // (req == candidate index in this generator)
                let keys: Vec<_> = admitted
                    .iter()
                    .map(|tk| {
                        let c = &cands[tk.req];
                        (c.class.rank(), c.deadline, c.id)
                    })
                    .collect();
                keys.windows(2).all(|w| w[0] <= w[1])
            },
        );
    }

    #[test]
    fn prop_fill_ratio_reasonable() {
        // with many same-t single-sample tickets the packer should reach
        // high fill on all but the last batch
        prop::check(
            "batcher-fill",
            50,
            |rng: &mut Rng| 9 + rng.below(40),
            |&n| {
                let tickets: Vec<Ticket> =
                    (0..n).map(|i| Ticket { req: i, t: 1.0, n: 1 }).collect();
                let batches = plan(&tickets, CLASSES);
                batches[..batches.len() - 1].iter().all(|b| b.fill() >= 0.99)
            },
        );
    }
}
