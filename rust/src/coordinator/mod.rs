//! L3 serving coordinator: request router + step-level continuous batcher
//! over the quantized diffusion model (the deployment story of a 4-bit
//! diffusion model — paper §1's edge-serving motivation), plus the
//! fleet layer: N coordinator shards behind a consistent-hash router
//! with fleet-consistent drift detection and recalibration.

pub mod request;
pub mod batcher;
pub mod exec;
pub mod fleet;
pub mod metrics;
pub mod prober;
pub mod server;

pub use crate::obs::ObsCfg;
pub use batcher::{admit_edf, SloTicket};
pub use exec::{Backend, Fault, FaultPlan, RoundExecutor};
pub use fleet::{route, Fleet, FleetAggregate, FleetCfg, FleetReport};
pub use metrics::Metrics;
pub use prober::ShadowProber;
pub use request::{Completion, Request, Response, ResponseRx, ShedReason, SloClass};
pub use server::{
    degradation_ladder, degraded_state, spawn, FleetSwap, LadderRung, ServeMode, ServeRecal,
    ServerCfg, ServerHandle, ShardHarvest, SloCfg,
};
