//! Serving request/response types, SLO classes and round-denominated
//! deadlines.
//!
//! Deadlines are *virtual*: measured in scheduling rounds, not wall
//! clocks, so every admission/shed/downgrade decision the scheduler makes
//! from them is a pure function of (queue snapshot, round index) — and
//! therefore bit-identical for any worker count.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc};

use crate::eval::generate::SamplerKind;

/// Service class of a request, in descending scheduling priority.
/// Within a class, requests are planned earliest-deadline-first.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum SloClass {
    /// user-facing: never shed; under overload it is *downgraded* instead
    /// (fewer sampler steps at admission and/or a lower-bit variant)
    Interactive,
    /// bulk work: neither shed nor downgraded, just deprioritized
    Batch,
    /// opportunistic: shed (channel closed with [`Response::Shed`]) once
    /// its deadline passes while the server is over its queue budget
    BestEffort,
}

impl SloClass {
    pub const ALL: [SloClass; 3] = [SloClass::Interactive, SloClass::Batch, SloClass::BestEffort];

    /// Scheduling priority index (0 = highest). Doubles as the index of
    /// this class's slot in per-class metric arrays.
    pub fn rank(self) -> usize {
        match self {
            SloClass::Interactive => 0,
            SloClass::Batch => 1,
            SloClass::BestEffort => 2,
        }
    }

    /// Default deadline slack in rounds beyond the request's step count
    /// (used when `Request::deadline_rounds` is 0 = auto).
    pub fn slack_rounds(self) -> usize {
        match self {
            SloClass::Interactive => 2,
            SloClass::Batch => 8,
            SloClass::BestEffort => 16,
        }
    }
}

/// A generation request: n images from a (possibly quantized) diffusion
/// model. Submitted to the coordinator, which co-schedules the denoising
//  steps of concurrent requests into shared model evaluations.
#[derive(Debug, Clone)]
pub struct Request {
    pub id: u64,
    /// number of images
    pub n: usize,
    pub steps: usize,
    pub eta: f32,
    pub sampler: SamplerKind,
    pub seed: u64,
    /// class label for conditional models (None = unconditional / random)
    pub class: Option<usize>,
    /// SLO class (default [`SloClass::Batch`]: never shed, never
    /// downgraded — the pre-SLO coordinator's behavior)
    pub slo: SloClass,
    /// virtual deadline in scheduling rounds from admission;
    /// 0 = auto (`steps + slo.slack_rounds()`)
    pub deadline_rounds: usize,
}

impl Request {
    pub fn new(id: u64, n: usize, steps: usize) -> Request {
        Request {
            id,
            n,
            steps,
            eta: 0.0,
            sampler: SamplerKind::Ddim,
            seed: id,
            class: None,
            slo: SloClass::Batch,
            deadline_rounds: 0,
        }
    }

    pub fn with_slo(mut self, slo: SloClass) -> Request {
        self.slo = slo;
        self
    }

    /// Effective relative deadline in rounds: the explicit
    /// `deadline_rounds` when set, otherwise the minimum rounds the
    /// request needs (its step count) plus the class slack.
    pub fn deadline_budget(&self) -> usize {
        if self.deadline_rounds > 0 {
            self.deadline_rounds
        } else {
            self.steps + self.slo.slack_rounds()
        }
    }
}

/// Why the scheduler retired a request without serving it.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ShedReason {
    /// best-effort request past its round deadline while the admitted
    /// backlog exceeded the queue budget
    DeadlineMissed,
    /// failed-round retries exhausted (capped exponential backoff)
    RetriesExhausted,
}

impl std::fmt::Display for ShedReason {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ShedReason::DeadlineMissed => write!(f, "deadline missed under overload"),
            ShedReason::RetriesExhausted => write!(f, "failed-round retries exhausted"),
        }
    }
}

/// Completed generation.
#[derive(Debug)]
pub struct Completion {
    pub id: u64,
    /// pixels (decoded for LDM variants), n * hw*hw*3
    pub images: Vec<f32>,
    pub n: usize,
    /// wall time from submit to completion
    pub latency: std::time::Duration,
    /// total model evaluations consumed
    pub evals: usize,
    /// served degraded at least once (step cut at admission and/or
    /// lower-bit variant rounds under overload)
    pub degraded: bool,
}

/// Outcome of a request: either a [`Completion`] or an explicit shed
/// notice — after sending either, the scheduler drops its sender, so the
/// channel closes and a second `recv()` errors instead of hanging.
#[derive(Debug)]
pub enum Response {
    Done(Completion),
    Shed { id: u64, class: SloClass, reason: ShedReason },
}

impl Response {
    pub fn id(&self) -> u64 {
        match self {
            Response::Done(c) => c.id,
            Response::Shed { id, .. } => *id,
        }
    }

    pub fn done(self) -> Option<Completion> {
        match self {
            Response::Done(c) => Some(c),
            Response::Shed { .. } => None,
        }
    }

    pub fn shed_reason(&self) -> Option<ShedReason> {
        match self {
            Response::Done(_) => None,
            Response::Shed { reason, .. } => Some(*reason),
        }
    }

    /// The completion, panicking with the shed reason otherwise — the
    /// ergonomic accessor for callers that never configure a queue budget
    /// (shedding needs one to be possible).
    pub fn unwrap_done(self) -> Completion {
        match self {
            Response::Done(c) => c,
            Response::Shed { id, class, reason } => {
                panic!("request {id} ({class:?}) was shed: {reason}")
            }
        }
    }
}

/// The client's end of a response channel. Dropping it (with the request
/// still in flight) is a *cancellation*: the scheduler observes the
/// raised flag at plan time, stops executing the request's remaining
/// rounds, and counts it as `cancelled` in `Metrics`.
pub struct ResponseRx {
    rx: mpsc::Receiver<Response>,
    gone: Arc<AtomicBool>,
}

impl ResponseRx {
    /// A response channel plus the scheduler-side cancellation flag.
    pub fn channel() -> (mpsc::Sender<Response>, Arc<AtomicBool>, ResponseRx) {
        let (tx, rx) = mpsc::channel();
        let gone = Arc::new(AtomicBool::new(false));
        (tx, Arc::clone(&gone), ResponseRx { rx, gone })
    }

    pub fn recv(&self) -> Result<Response, mpsc::RecvError> {
        self.rx.recv()
    }

    pub fn try_recv(&self) -> Result<Response, mpsc::TryRecvError> {
        self.rx.try_recv()
    }

    pub fn recv_timeout(
        &self,
        timeout: std::time::Duration,
    ) -> Result<Response, mpsc::RecvTimeoutError> {
        self.rx.recv_timeout(timeout)
    }
}

impl Drop for ResponseRx {
    fn drop(&mut self) {
        self.gone.store(true, Ordering::SeqCst);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn request_defaults() {
        let r = Request::new(3, 4, 10);
        assert_eq!(r.id, 3);
        assert_eq!(r.n, 4);
        assert_eq!(r.sampler, SamplerKind::Ddim);
        assert!(r.class.is_none());
        assert_eq!(r.slo, SloClass::Batch);
        assert_eq!(r.deadline_rounds, 0);
    }

    #[test]
    fn deadline_budget_auto_and_explicit() {
        let r = Request::new(0, 1, 10);
        assert_eq!(r.deadline_budget(), 10 + SloClass::Batch.slack_rounds());
        let r = Request::new(0, 1, 10).with_slo(SloClass::Interactive);
        assert_eq!(r.deadline_budget(), 12);
        let mut r = Request::new(0, 1, 10).with_slo(SloClass::BestEffort);
        r.deadline_rounds = 3;
        assert_eq!(r.deadline_budget(), 3);
    }

    #[test]
    fn class_ranks_are_priority_ordered_and_distinct() {
        assert_eq!(SloClass::Interactive.rank(), 0);
        assert_eq!(SloClass::Batch.rank(), 1);
        assert_eq!(SloClass::BestEffort.rank(), 2);
        for c in SloClass::ALL {
            assert!(c.slack_rounds() > 0);
        }
        // slack grows with laxity: lower priority tolerates later deadlines
        assert!(SloClass::Interactive.slack_rounds() < SloClass::BestEffort.slack_rounds());
    }

    #[test]
    fn response_accessors() {
        let done = Response::Done(Completion {
            id: 7,
            images: vec![0.0],
            n: 1,
            latency: std::time::Duration::ZERO,
            evals: 4,
            degraded: false,
        });
        assert_eq!(done.id(), 7);
        assert_eq!(done.shed_reason(), None);
        assert_eq!(done.unwrap_done().n, 1);

        let shed = Response::Shed {
            id: 9,
            class: SloClass::BestEffort,
            reason: ShedReason::DeadlineMissed,
        };
        assert_eq!(shed.id(), 9);
        assert_eq!(shed.shed_reason(), Some(ShedReason::DeadlineMissed));
        assert!(shed.done().is_none());
    }

    #[test]
    fn dropping_response_rx_raises_the_cancel_flag() {
        let (tx, gone, rx) = ResponseRx::channel();
        assert!(!gone.load(Ordering::SeqCst));
        drop(rx);
        assert!(gone.load(Ordering::SeqCst));
        // the channel is closed too: sends fail instead of leaking
        assert!(tx
            .send(Response::Shed {
                id: 0,
                class: SloClass::BestEffort,
                reason: ShedReason::DeadlineMissed
            })
            .is_err());
    }
}
