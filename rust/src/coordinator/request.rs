//! Serving request/response types.

use crate::eval::generate::SamplerKind;

/// A generation request: n images from a (possibly quantized) diffusion
/// model. Submitted to the coordinator, which co-schedules the denoising
//  steps of concurrent requests into shared model evaluations.
#[derive(Debug, Clone)]
pub struct Request {
    pub id: u64,
    /// number of images
    pub n: usize,
    pub steps: usize,
    pub eta: f32,
    pub sampler: SamplerKind,
    pub seed: u64,
    /// class label for conditional models (None = unconditional / random)
    pub class: Option<usize>,
}

impl Request {
    pub fn new(id: u64, n: usize, steps: usize) -> Request {
        Request { id, n, steps, eta: 0.0, sampler: SamplerKind::Ddim, seed: id, class: None }
    }
}

/// Completed generation.
#[derive(Debug)]
pub struct Response {
    pub id: u64,
    /// pixels (decoded for LDM variants), n * hw*hw*3
    pub images: Vec<f32>,
    pub n: usize,
    /// wall time from submit to completion
    pub latency: std::time::Duration,
    /// total model evaluations consumed
    pub evals: usize,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn request_defaults() {
        let r = Request::new(3, 4, 10);
        assert_eq!(r.id, 3);
        assert_eq!(r.n, 4);
        assert_eq!(r.sampler, SamplerKind::Ddim);
        assert!(r.class.is_none());
    }
}
