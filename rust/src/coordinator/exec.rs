//! The parallel round executor: fans one scheduling round's planned
//! batches out across a persistent worker pool and scatters the results
//! back **by batch index**, so the merged round is bit-identical and
//! order-deterministic regardless of worker count.
//!
//! Design invariants:
//!  * every input (x slice, cond slice, t, selection) is gathered on the
//!    scheduler thread *before* fan-out, at offsets fixed by
//!    [`super::batcher::ticket_offsets`] — worker timing cannot change
//!    what any batch computes;
//!  * results are collected into a slot array indexed by batch position,
//!    then consumed in plan order — worker timing cannot change the order
//!    anything is observed in;
//!  * a failing (or panicking) batch yields an `Err` slot and nothing
//!    else: neighbors' slots and buffer ranges are untouched.
//!
//! The same pool doubles as the completion offload lane
//! ([`RoundExecutor::offload`]): latent decode and response sends run here
//! so the scheduler can start planning the next round immediately.
//!
//! Marshalling buffers (gather x/ts/cond, pad scratch, eps outputs) are
//! recycled through a shared store, so steady-state rounds allocate O(1)
//! regardless of batch count.

use std::panic::AssertUnwindSafe;
use std::sync::{mpsc, Arc, Mutex};
use std::time::Duration;

use anyhow::{anyhow, Result};

use crate::runtime::{Denoiser, EpsScratch, QuantState};
use crate::util::rng::mix64;
use crate::util::threadpool::{resolve_threads, Pool};

/// A fault forced onto one batch evaluation by a [`FaultPlan`].
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum Fault {
    #[default]
    None,
    /// the eval returns `Err` (an isolated `Err` slot, neighbors untouched)
    Fail,
    /// the eval panics (contained by the executor's catch_unwind — the
    /// worker-crash drill)
    Panic,
    /// the eval stalls for the given milliseconds first (straggler drill;
    /// results are still bit-identical, only wall time moves)
    Slow(u64),
}

impl Fault {
    /// Stable wire tag for `obs` event payloads (`EventKind::Fault` /
    /// `EventKind::RecalCheck`): 0 = none, 1 = fail, 2 = panic, 3 = slow.
    pub fn tag(&self) -> u8 {
        match self {
            Fault::None => 0,
            Fault::Fail => 1,
            Fault::Panic => 2,
            Fault::Slow(_) => 3,
        }
    }
}

/// Deterministic fault-injection schedule for the serving coordinator.
///
/// Faults are decided per (scheduling round, batch index) by hashing with
/// the plan seed — a pure function, so a 1-worker server and an N-worker
/// server inject the *same* faults into the *same* batches and every
/// downstream retry/backoff/shed decision stays bit-identical. Rates are
/// per-mille of batches.
#[derive(Debug, Clone, Copy, Default)]
pub struct FaultPlan {
    pub seed: u64,
    /// ‰ of batches that fail ([`Fault::Fail`])
    pub fail_per_mille: u32,
    /// ‰ of batches whose worker panics ([`Fault::Panic`])
    pub panic_per_mille: u32,
    /// ‰ of batches stalled by `slow_ms` ([`Fault::Slow`])
    pub slow_per_mille: u32,
    /// stall applied to slow batches, in milliseconds
    pub slow_ms: u64,
    /// fail the first N engine compiles after server start
    /// (`Engine::inject_compile_failures` — exercises the compile retry
    /// budget)
    pub compile_fail_first: usize,
    /// ‰ of background recal checks that panic mid-application (contained
    /// by the recal job's catch_unwind — the half-applied plan is
    /// discarded, nothing is parked, the swap stays round-atomic)
    pub recal_panic_per_mille: u32,
    /// ‰ of background recal checks stalled by `slow_ms` first (the slow
    /// drift-check drill; decisions are unchanged, only wall time moves)
    pub recal_slow_per_mille: u32,
}

impl FaultPlan {
    pub fn new(seed: u64) -> FaultPlan {
        FaultPlan { seed, ..Default::default() }
    }

    /// The fault (if any) for batch `batch` of round `round` — pure in
    /// (self, round, batch).
    pub fn decide(&self, round: u64, batch: u64) -> Fault {
        let total = self.fail_per_mille + self.panic_per_mille + self.slow_per_mille;
        if total == 0 {
            return Fault::None;
        }
        let h = mix64(self.seed ^ mix64(round.wrapping_mul(0x9E3779B97F4A7C15) ^ batch));
        let d = (h % 1000) as u32;
        if d < self.fail_per_mille {
            Fault::Fail
        } else if d < self.fail_per_mille + self.panic_per_mille {
            Fault::Panic
        } else if d < total {
            Fault::Slow(self.slow_ms)
        } else {
            Fault::None
        }
    }

    /// The fault (if any) for the `check`-th background recal check —
    /// pure in (self, check), drawn from a stream independent of the
    /// per-batch [`FaultPlan::decide`] draws.
    pub fn decide_recal(&self, check: u64) -> Fault {
        let total = self.recal_panic_per_mille + self.recal_slow_per_mille;
        if total == 0 {
            return Fault::None;
        }
        let h =
            mix64(self.seed ^ mix64(check.wrapping_mul(0x9E3779B97F4A7C15) ^ 0x7265_6361_6c));
        let d = (h % 1000) as u32;
        if d < self.recal_panic_per_mille {
            Fault::Panic
        } else if d < total {
            Fault::Slow(self.slow_ms)
        } else {
            Fault::None
        }
    }
}

/// Everything a worker needs to evaluate a batch. The model flavor rides
/// on each [`BatchJob`] (`qs`), not here: the scheduler pins the
/// `QuantState` per round when it builds the jobs, which is what lets a
/// background recalibration hot-swap the state *between* rounds without
/// any worker observing a mid-round change.
pub struct EvalCtx {
    pub den: Arc<Denoiser>,
    pub params: Arc<Vec<f32>>,
    /// execution backend for quantized batches (FP batches always run
    /// the compiled graph)
    pub backend: Backend,
}

/// How quantized batches execute: through the compiled fake-qdq XLA
/// graph (the oracle), or through the native packed-weight path
/// (`runtime::native`) that streams bit-packed 4-bit code indices into
/// the fused dequantize-matmul kernel.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum Backend {
    #[default]
    Graph,
    Packed,
}

impl Backend {
    /// Short tag for metrics/reports.
    pub fn tag(&self) -> &'static str {
        match self {
            Backend::Graph => "graph",
            Backend::Packed => "packed",
        }
    }
}

/// One gathered batch, ready to evaluate: `idx` is its position in the
/// round plan (and the slot its result scatters back into).
pub struct BatchJob {
    pub idx: usize,
    /// the batch's (first ticket's) timestep — the quantized path's
    /// uniform t, and a display label for FP mixed-t batches
    pub t: f32,
    pub x: Vec<f32>,
    /// per-sample timesteps (len == sample count); uniform under same-t
    /// planning, mixed for FP `PlanMode::MixedT` batches
    pub ts: Vec<f32>,
    pub cond: Vec<f32>,
    /// precomputed `[L, H]` selection (quant mode; None for FP)
    pub sel: Option<Arc<Vec<f32>>>,
    /// quantized state pinned for this round (None => FP path)
    pub qs: Option<Arc<QuantState>>,
    /// fault forced onto this batch (assigned at plan time from the
    /// server's [`FaultPlan`]; `Fault::None` in production)
    pub fault: Fault,
}

/// A batch's outcome, returned in plan order. The job rides along so its
/// gather buffers can be recycled.
pub struct BatchResult {
    pub idx: usize,
    pub eps: Result<Vec<f32>>,
    pub job: BatchJob,
}

/// Batch evaluation function: fills `out` with the eps for the job, using
/// `pad` as marshalling scratch. `Arc`'d so the pool's `'static` jobs can
/// share it; the production closure is built by [`eval_closure`].
pub type EvalFn = dyn Fn(&BatchJob, &mut EpsScratch, &mut Vec<f32>) -> Result<()> + Send + Sync;

/// The production eval closure over a [`EvalCtx`]: FP batches go through
/// the per-sample-t marshalling path (`eps_fp_into`; bit-identical to the
/// old uniform-t path when all ts agree — pinned by the Denoiser
/// `into_variants` test — and required for mixed-t batches), quantized
/// batches through the configured [`Backend`] — `eps_q_with_sel_into`
/// (compiled fake-qdq graph) or `eps_q_packed_into` (native packed
/// weights) — with the job's pinned state and precomputed (cached)
/// selection.
pub fn eval_closure(ctx: EvalCtx) -> Arc<EvalFn> {
    Arc::new(move |job: &BatchJob, pad: &mut EpsScratch, out: &mut Vec<f32>| match &job.qs {
        None => ctx.den.eps_fp_into(&ctx.params, &job.x, &job.ts, &job.cond, pad, out),
        Some(qs) => {
            let sel = job.sel.as_ref().expect("quant batch without selection");
            match ctx.backend {
                Backend::Graph => ctx
                    .den
                    .eps_q_with_sel_into(&ctx.params, qs, sel, &job.x, job.t, &job.cond, pad, out),
                Backend::Packed => ctx
                    .den
                    .eps_q_packed_into(&ctx.params, qs, sel, &job.x, job.t, &job.cond, pad, out),
            }
        }
    })
}

/// Recycled marshalling storage shared between the scheduler thread
/// (gather buffers) and the workers (output buffers).
#[derive(Default)]
struct BufStore {
    gathers: Vec<(Vec<f32>, Vec<f32>, Vec<f32>)>,
    outs: Vec<Vec<f32>>,
}

/// Shared pool of pad-to-batch-class staging scratch ([`EpsScratch`]).
/// Batch evals and the shadow prober's `calib_forward` jobs draw from the
/// same pool, so probing reuses the allocations the eval path already
/// warmed instead of growing its own set.
pub type PadPool = Arc<Mutex<Vec<EpsScratch>>>;

pub struct RoundExecutor {
    /// None ⇒ single-worker mode: batches run in-line on the caller's
    /// thread, in plan order (the sequential reference path).
    pool: Option<Pool>,
    bufs: Arc<Mutex<BufStore>>,
    pads: PadPool,
    res_tx: mpsc::Sender<BatchResult>,
    res_rx: mpsc::Receiver<BatchResult>,
}

impl RoundExecutor {
    /// `workers == 0` ⇒ available parallelism; `workers == 1` ⇒ in-line
    /// sequential execution (no pool threads at all).
    pub fn new(workers: usize) -> RoundExecutor {
        let workers = resolve_threads(workers);
        let pool = (workers > 1).then(|| Pool::new(workers));
        let (res_tx, res_rx) = mpsc::channel();
        RoundExecutor {
            pool,
            bufs: Arc::new(Mutex::new(BufStore::default())),
            pads: Arc::new(Mutex::new(Vec::new())),
            res_tx,
            res_rx,
        }
    }

    /// The shared pad-scratch pool (cloned into offloaded jobs that need
    /// marshalling scratch — the shadow prober's calib forwards).
    pub fn pad_pool(&self) -> PadPool {
        Arc::clone(&self.pads)
    }

    /// A cleared (x, ts, cond) gather-buffer triple, recycled when
    /// available.
    pub fn gather_bufs(&self) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
        self.bufs.lock().unwrap().gathers.pop().unwrap_or_default()
    }

    /// Return a consumed job's buffers (and its scattered eps vector) to
    /// the store for the next round.
    pub fn recycle(&self, mut job: BatchJob, eps: Option<Vec<f32>>) {
        job.x.clear();
        job.ts.clear();
        job.cond.clear();
        let mut bufs = self.bufs.lock().unwrap();
        bufs.gathers.push((job.x, job.ts, job.cond));
        if let Some(mut e) = eps {
            e.clear();
            bufs.outs.push(e);
        }
    }

    /// Execute a round. `jobs[i].idx` must equal `i` (plan position).
    /// Returns one [`BatchResult`] per job, **in plan order**, regardless
    /// of which worker finished first. A failing batch becomes an `Err`
    /// slot; the other slots are unaffected.
    pub fn run_with(&self, eval: &Arc<EvalFn>, jobs: Vec<BatchJob>) -> Vec<BatchResult> {
        let n = jobs.len();
        if n == 0 {
            return Vec::new();
        }
        debug_assert!(jobs.iter().enumerate().all(|(i, j)| j.idx == i));
        match &self.pool {
            None => jobs
                .into_iter()
                .map(|job| eval_one(&self.bufs, &self.pads, eval.as_ref(), job))
                .collect(),
            Some(pool) => {
                for job in jobs {
                    let eval = Arc::clone(eval);
                    let bufs = Arc::clone(&self.bufs);
                    let pads = Arc::clone(&self.pads);
                    let tx = self.res_tx.clone();
                    pool.submit(move || {
                        let _ = tx.send(eval_one(&bufs, &pads, eval.as_ref(), job));
                    });
                }
                let mut slots: Vec<Option<BatchResult>> = (0..n).map(|_| None).collect();
                for _ in 0..n {
                    let r = self.res_rx.recv().expect("round executor pool died");
                    let idx = r.idx;
                    slots[idx] = Some(r);
                }
                slots.into_iter().map(|s| s.expect("missing batch result")).collect()
            }
        }
    }

    /// Run `f` off the scheduler thread (in-line in single-worker mode).
    /// Used for completion work: latent decode + response send. Panics are
    /// contained (by the pool's worker guard, or by catch_unwind on the
    /// in-line path) so one poisoned completion can't kill the scheduler.
    pub fn offload(&self, f: impl FnOnce() + Send + 'static) {
        match &self.pool {
            Some(pool) => pool.submit(f),
            None => {
                if std::panic::catch_unwind(AssertUnwindSafe(f)).is_err() {
                    crate::log_warn!("offloaded completion job panicked");
                }
            }
        }
    }

    /// Block until every submitted job — batch evals and offloaded
    /// completions — has finished.
    pub fn join(&self) {
        if let Some(pool) = &self.pool {
            pool.join();
        }
    }
}

/// Evaluate one batch with recycled scratch. Panics inside `eval` are
/// contained to an `Err` result so one poisoned batch can neither deadlock
/// the round collection nor kill a pool worker.
fn eval_one(
    bufs: &Mutex<BufStore>,
    pads: &Mutex<Vec<EpsScratch>>,
    eval: &EvalFn,
    job: BatchJob,
) -> BatchResult {
    let mut pad = pads.lock().unwrap().pop().unwrap_or_default();
    let mut out = bufs.lock().unwrap().outs.pop().unwrap_or_default();
    // injected faults run *inside* the containment boundary, so a forced
    // panic exercises exactly the path a real worker crash takes
    let res = std::panic::catch_unwind(AssertUnwindSafe(|| match job.fault {
        Fault::Fail => Err(anyhow!("injected fault: forced batch failure (t={})", job.t)),
        Fault::Panic => panic!("injected fault: forced worker panic (t={})", job.t),
        Fault::Slow(ms) => {
            std::thread::sleep(Duration::from_millis(ms));
            eval(&job, &mut pad, &mut out)
        }
        Fault::None => eval(&job, &mut pad, &mut out),
    }));
    let eps = match res {
        Ok(Ok(())) => Ok(std::mem::take(&mut out)),
        Ok(Err(e)) => Err(e),
        Err(_) => Err(anyhow!(
            "batch eval panicked (t={}, n={})",
            job.t,
            job.cond.len()
        )),
    };
    pads.lock().unwrap().push(pad);
    if eps.is_err() {
        out.clear();
        bufs.lock().unwrap().outs.push(out);
    }
    BatchResult { idx: job.idx, eps, job }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::batcher::{plan_mode, ticket_offsets, PlanMode, Ticket};
    use std::sync::atomic::{AtomicUsize, Ordering};

    /// Deterministic *per-sample* synthetic eval: eps for sample j is a
    /// pure function of (x_j, ts_j, cond_j) — the same batch-composition
    /// independence the FP graph has — failing or panicking on request.
    fn fake_eval(fail_t: Option<f32>, panic_t: Option<f32>) -> Arc<EvalFn> {
        Arc::new(move |job: &BatchJob, _pad: &mut EpsScratch, out: &mut Vec<f32>| {
            if Some(job.t) == fail_t {
                anyhow::bail!("injected failure at t={}", job.t);
            }
            if Some(job.t) == panic_t {
                panic!("injected panic at t={}", job.t);
            }
            out.clear();
            let per = job.x.len() / job.cond.len().max(1);
            for (i, &v) in job.x.iter().enumerate() {
                let j = i / per.max(1);
                out.push(2.0 * v + job.ts[j] + job.cond[j]);
            }
            Ok(())
        })
    }

    fn mixed_jobs() -> Vec<BatchJob> {
        // uneven sizes so worker finish order scrambles under parallelism
        (0..24)
            .map(|i| {
                let n = 1 + (i * 7) % 5;
                let per = 3;
                let t = (i % 6) as f32 * 1.25;
                BatchJob {
                    idx: i,
                    t,
                    x: (0..n * per).map(|k| (i * 31 + k) as f32 * 0.125).collect(),
                    ts: vec![t; n],
                    cond: (0..n).map(|k| k as f32).collect(),
                    sel: None,
                    qs: None,
                    fault: Fault::None,
                }
            })
            .collect()
    }

    /// Gather jobs from a plan the way the scheduler does: request `req`'s
    /// sample `k` has x = req·16 + k (3 values per sample) and cond = req.
    fn jobs_from_plan(
        batches: &[crate::coordinator::batcher::Batch],
        offsets: &[Vec<usize>],
        per: usize,
    ) -> Vec<BatchJob> {
        batches
            .iter()
            .enumerate()
            .map(|(bi, b)| {
                let mut x = Vec::new();
                let mut ts = Vec::new();
                let mut cond = Vec::new();
                for (tk, &start) in b.tickets.iter().zip(&offsets[bi]) {
                    for k in start..start + tk.n {
                        for d in 0..per {
                            x.push((tk.req * 16 + k) as f32 + d as f32 * 0.25);
                        }
                        ts.push(tk.t);
                        cond.push(tk.req as f32);
                    }
                }
                BatchJob { idx: bi, t: b.t, x, ts, cond, sel: None, qs: None, fault: Fault::None }
            })
            .collect()
    }

    /// The FP mixed-t satellite's bitwise pin at the executor level: the
    /// same tickets planned same-t vs mixed-t, evaluated by a per-sample
    /// function and scattered at ticket_offsets, produce bit-identical
    /// per-request results — batch composition does not leak into any
    /// sample.
    #[test]
    fn mixed_t_plan_scatters_bit_identical_to_same_t() {
        let per = 3;
        let tickets: Vec<Ticket> = (0..9)
            .map(|i| Ticket { req: i, t: (i % 4) as f32 * 2.5, n: 1 + i % 3 })
            .collect();
        let classes = &[1usize, 2, 4, 8];
        let eval = fake_eval(None, None);

        let run = |mode: PlanMode, workers: usize| -> Vec<Vec<u32>> {
            let batches = plan_mode(&tickets, classes, mode);
            let offsets = ticket_offsets(&batches, tickets.len());
            let exec = RoundExecutor::new(workers);
            let results = exec.run_with(&eval, jobs_from_plan(&batches, &offsets, per));
            // scatter into per-request sample ranges, exactly like the
            // scheduler loop
            let mut out: Vec<Vec<u32>> =
                tickets.iter().map(|tk| vec![0u32; tk.n * per]).collect();
            for r in results {
                let eps = r.eps.unwrap();
                let batch = &batches[r.idx];
                let mut off = 0;
                for (tk, &start) in batch.tickets.iter().zip(&offsets[r.idx]) {
                    for (slot, &v) in out[tk.req][start * per..(start + tk.n) * per]
                        .iter_mut()
                        .zip(&eps[off * per..(off + tk.n) * per])
                    {
                        *slot = v.to_bits();
                    }
                    off += tk.n;
                }
            }
            out
        };

        let same = run(PlanMode::SameT, 1);
        for workers in [1usize, 4] {
            assert_eq!(
                same,
                run(PlanMode::MixedT, workers),
                "mixed-t scatter diverged (workers={workers})"
            );
        }
        // sanity: the plans actually differed (the pin is not vacuous)
        assert_ne!(
            plan_mode(&tickets, classes, PlanMode::SameT).len(),
            plan_mode(&tickets, classes, PlanMode::MixedT).len()
        );
    }

    fn run_round(workers: usize, eval: &Arc<EvalFn>) -> Vec<Result<Vec<f32>>> {
        let exec = RoundExecutor::new(workers);
        exec.run_with(eval, mixed_jobs()).into_iter().map(|r| r.eps).collect()
    }

    #[test]
    fn parallel_is_bit_identical_to_sequential() {
        let eval = fake_eval(None, None);
        let seq = run_round(1, &eval);
        for workers in [2, 4, 8] {
            let par = run_round(workers, &eval);
            assert_eq!(seq.len(), par.len());
            for (a, b) in seq.iter().zip(&par) {
                let (a, b) = (a.as_ref().unwrap(), b.as_ref().unwrap());
                assert_eq!(a.len(), b.len());
                assert!(
                    a.iter().zip(b.iter()).all(|(x, y)| x.to_bits() == y.to_bits()),
                    "workers={workers} changed bits"
                );
            }
        }
    }

    #[test]
    fn failing_batch_isolated_from_neighbors() {
        let clean: Vec<_> = run_round(4, &fake_eval(None, None));
        let t_fail = 2.5; // hits several of the mixed jobs
        let with_fail = run_round(4, &fake_eval(Some(t_fail), None));
        let mut failed = 0;
        for (i, (c, f)) in clean.iter().zip(&with_fail).enumerate() {
            let job_t = (i % 6) as f32 * 1.25;
            if job_t == t_fail {
                assert!(f.is_err(), "job {i} at fail t must error");
                failed += 1;
            } else {
                assert_eq!(c.as_ref().unwrap(), f.as_ref().unwrap(), "neighbor {i} corrupted");
            }
        }
        assert!(failed > 0, "fail t never hit — test is vacuous");
    }

    #[test]
    fn panicking_batch_contained_and_executor_reusable() {
        let exec = RoundExecutor::new(4);
        let eval = fake_eval(None, Some(0.0));
        let results = exec.run_with(&eval, mixed_jobs());
        assert_eq!(results.len(), 24);
        for r in &results {
            let job_t = (r.idx % 6) as f32 * 1.25;
            if job_t == 0.0 {
                let msg = format!("{:#}", r.eps.as_ref().unwrap_err());
                assert!(msg.contains("panicked"), "{msg}");
            } else {
                assert!(r.eps.is_ok());
            }
        }
        // the pool survived: a clean round still works afterwards
        let ok = exec.run_with(&fake_eval(None, None), mixed_jobs());
        assert!(ok.iter().all(|r| r.eps.is_ok()));
    }

    #[test]
    fn results_arrive_in_plan_order() {
        let exec = RoundExecutor::new(8);
        let results = exec.run_with(&fake_eval(None, None), mixed_jobs());
        for (i, r) in results.iter().enumerate() {
            assert_eq!(r.idx, i);
        }
    }

    #[test]
    fn buffers_recycle_across_rounds() {
        let exec = RoundExecutor::new(1);
        let eval = fake_eval(None, None);
        let results = exec.run_with(&eval, mixed_jobs());
        for r in results {
            let eps = r.eps.ok();
            exec.recycle(r.job, eps);
        }
        // next round's gather bufs come from the store, already allocated
        let (x, ts, cond) = exec.gather_bufs();
        assert!(x.capacity() > 0 && x.is_empty());
        assert!(ts.capacity() > 0 && ts.is_empty());
        assert!(cond.capacity() > 0 && cond.is_empty());
    }

    #[test]
    fn pad_pool_is_shared_and_recycled() {
        let exec = RoundExecutor::new(1);
        let results = exec.run_with(&fake_eval(None, None), mixed_jobs());
        for r in results {
            let eps = r.eps.ok();
            exec.recycle(r.job, eps);
        }
        // the eval path returned its scratch to the shared pool, where an
        // offloaded probe-style job can draw it
        let pads = exec.pad_pool();
        let drawn = pads.lock().unwrap().pop();
        assert!(drawn.is_some(), "eval path must seed the shared pad pool");
        pads.lock().unwrap().push(drawn.unwrap());
    }

    #[test]
    fn offload_runs_and_join_waits() {
        for workers in [1usize, 4] {
            let exec = RoundExecutor::new(workers);
            let counter = Arc::new(AtomicUsize::new(0));
            for _ in 0..20 {
                let c = Arc::clone(&counter);
                exec.offload(move || {
                    c.fetch_add(1, Ordering::SeqCst);
                });
            }
            exec.join();
            assert_eq!(counter.load(Ordering::SeqCst), 20);
        }
    }

    #[test]
    fn empty_round_is_a_noop() {
        let exec = RoundExecutor::new(4);
        assert!(exec.run_with(&fake_eval(None, None), Vec::new()).is_empty());
    }

    #[test]
    fn fault_plan_is_pure_and_rate_bounded() {
        let fp = FaultPlan {
            fail_per_mille: 150,
            panic_per_mille: 50,
            slow_per_mille: 100,
            slow_ms: 1,
            ..FaultPlan::new(42)
        };
        let mut counts = [0usize; 4];
        for round in 0..50u64 {
            for batch in 0..20u64 {
                let f = fp.decide(round, batch);
                // pure: the same (round, batch) always decides the same
                assert_eq!(f, fp.decide(round, batch));
                counts[match f {
                    Fault::None => 0,
                    Fault::Fail => 1,
                    Fault::Panic => 2,
                    Fault::Slow(ms) => {
                        assert_eq!(ms, 1);
                        3
                    }
                }] += 1;
            }
        }
        let total = 50 * 20;
        // ~30% of batches faulted; allow generous slack on the hash draw
        let faulted = counts[1] + counts[2] + counts[3];
        assert!(faulted > total / 5 && faulted < total / 2, "{counts:?}");
        assert!(counts[1] > counts[2], "fail rate 3x panic rate: {counts:?}");
        // a different seed reshuffles the schedule
        let other = FaultPlan { seed: 43, ..fp };
        assert!(
            (0..50u64).any(|r| (0..20u64).any(|b| fp.decide(r, b) != other.decide(r, b))),
            "seed did not move the schedule"
        );
    }

    #[test]
    fn recal_fault_draws_are_pure_rate_bounded_and_independent() {
        let fp = FaultPlan {
            recal_panic_per_mille: 400,
            recal_slow_per_mille: 300,
            slow_ms: 2,
            ..FaultPlan::new(3)
        };
        let mut counts = [0usize; 3];
        for check in 0..1000u64 {
            let f = fp.decide_recal(check);
            assert_eq!(f, fp.decide_recal(check), "decide_recal must be pure");
            counts[match f {
                Fault::None => 0,
                Fault::Panic => 1,
                Fault::Slow(ms) => {
                    assert_eq!(ms, 2);
                    2
                }
                Fault::Fail => unreachable!("recal draws never yield Fail"),
            }] += 1;
        }
        for (label, count, rate) in
            [("none", counts[0], 300), ("panic", counts[1], 400), ("slow", counts[2], 300)]
        {
            assert!(count.abs_diff(rate) < 100, "{label}: {count} vs ~{rate}‰");
        }
        // recal rates never leak into the per-batch stream and vice versa
        assert_eq!(fp.decide(0, 0), Fault::None);
        let batch_only = FaultPlan { fail_per_mille: 1000, ..FaultPlan::new(3) };
        assert_eq!(batch_only.decide_recal(0), Fault::None);
    }

    #[test]
    fn zero_rate_plan_never_faults() {
        let fp = FaultPlan::new(7);
        for round in 0..20u64 {
            for batch in 0..8u64 {
                assert_eq!(fp.decide(round, batch), Fault::None);
            }
        }
    }

    #[test]
    fn injected_faults_fail_panic_and_slow_on_schedule() {
        let eval = fake_eval(None, None);
        let clean: Vec<_> = run_round(1, &eval);
        for workers in [1usize, 4] {
            let exec = RoundExecutor::new(workers);
            let mut jobs = mixed_jobs();
            jobs[3].fault = Fault::Fail;
            jobs[5].fault = Fault::Panic;
            jobs[7].fault = Fault::Slow(1);
            let results = exec.run_with(&eval, jobs);
            for (i, r) in results.iter().enumerate() {
                match i {
                    3 => {
                        let msg = format!("{:#}", r.eps.as_ref().unwrap_err());
                        assert!(msg.contains("forced batch failure"), "{msg}");
                    }
                    5 => {
                        let msg = format!("{:#}", r.eps.as_ref().unwrap_err());
                        assert!(msg.contains("panicked"), "{msg}");
                    }
                    _ => {
                        // slow and clean batches are bit-identical to the
                        // no-fault round — faults never corrupt neighbors
                        let (a, b) = (clean[i].as_ref().unwrap(), r.eps.as_ref().unwrap());
                        assert!(
                            a.iter().zip(b.iter()).all(|(x, y)| x.to_bits() == y.to_bits()),
                            "job {i} bits moved (workers={workers})"
                        );
                    }
                }
            }
        }
    }
}
