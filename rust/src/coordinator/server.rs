//! The serving coordinator: step-level continuous batching over the
//! quantized (or FP) denoiser — the vLLM-router-shaped L3 of this repo.
//!
//! Architecture (std threads; tokio unavailable offline — DESIGN.md §1):
//!   * clients `submit()` requests over an MPSC channel and get a
//!     per-request response receiver;
//!   * the scheduler thread owns all request state (sampler state machines,
//!     latents) and loops: drain arrivals → collect each active request's
//!     next evaluation ticket → `batcher::plan` → gather batch inputs at
//!     offsets fixed by `batcher::ticket_offsets` → fan the batches out
//!     across the `exec::RoundExecutor` worker pool → scatter eps back in
//!     plan order → `observe` results into the samplers;
//!   * completed requests are decoded and answered *on the pool*
//!     (`RoundExecutor::offload`), so the next scheduling round starts
//!     while decode/send of the previous one is still in flight;
//!   * quantized selections are memoized per timestep in a
//!     `lora::SelectionCache` — every batch eval goes through
//!     `eps_q_with_sel` with an `Arc`'d cached selection;
//!   * FP rounds plan *mixed-t* batches by default (the FP graph takes
//!     per-sample t; only the quantized TALoRA path is same-t
//!     constrained), so scattered denoising phases still pack full
//!     batches;
//!   * a quantized server may carry a [`ServeRecal`] config: drift checks
//!     against the live activation sketches run as background jobs on the
//!     worker pool, and re-searched qparams hot-swap atomically at round
//!     boundaries (never mid-round — each round's batches pin the
//!     `QuantState` they were planned with). Sketches are fed externally
//!     through the shared handle and/or by the in-process shadow prober
//!     (`ServerCfg::probe_budget` recycled-latent calib forwards per
//!     round, deterministic for any worker count); with a
//!     `ServeRecal::state_dir` the drift window is persisted and restored
//!     across restarts bit-exactly;
//!   * new requests join at the next round (continuous batching): a long
//!     request never blocks a short one, same-t requests share compute;
//!   * requests carry an SLO class and a virtual (round-denominated)
//!     deadline; with a [`SloCfg`] queue budget the scheduler admits
//!     earliest-deadline-first within class priority, sheds overdue
//!     best-effort requests under overload, and degrades interactive ones
//!     (step cut at admission, multi-rung lower-bit ladder per round —
//!     the deeper the backlog, the coarser the rung) instead of dropping
//!     them. The SLO config is *live*: `ServerHandle::reconfigure` swaps
//!     budget/step-cut/ladder between rounds without a restart. Failed
//!     rounds retry with capped exponential backoff in rounds; a
//!     [`FaultPlan`] injects deterministic batch failures/panics/stalls,
//!     compile failures, storage faults (via `util::io::FaultFs`) and
//!     recal-check panics/slowdowns for chaos drills. State-dir
//!     checkpoint writes retry transient faults and count
//!     fails/retries into `Metrics`.
//!
//! Determinism: batch composition is fixed by the plan before execution
//! and results scatter by batch index, so a server with N workers produces
//! bit-identical images to a server with 1 worker given the same rounds
//! (pinned by `rust/tests/integration.rs`). Admission, shedding,
//! downgrade, backoff and fault decisions are pure functions of (queue
//! snapshot, round index, seed) — no wall clocks — so they inherit the
//! same guarantee.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::thread;
use std::time::{Duration, Instant};

use anyhow::{anyhow, Result};

use crate::data::PatchAutoencoder;
use crate::lora::SelectionCache;
use crate::model::manifest::ModelInfo;
use crate::obs::event::{CKPT_QPARAMS, CKPT_SKETCH, CKPT_TRACE};
use crate::obs::{
    EventKind, FlightRecorder, MetricsSnapshot, ObsCfg, PhaseTimers, RoundSample, SwapAudit,
    Telemetry,
};
use crate::quant::msfp::{QuantOpts, StateDir};
use crate::quant::session::QuantSession;
use crate::recal::{RecalPlanner, SketchSet};
use crate::runtime::{Denoiser, QuantState};
use crate::schedule::{timestep_subsequence, DdimSampler, DpmSolver2, PlmsSampler, Sampler, Schedule};
use crate::util::rng::Rng;

use super::batcher::{admit_edf, plan_mode, ticket_offsets, PlanMode, SloTicket, Ticket};
use super::exec::{eval_closure, Backend, BatchJob, EvalCtx, Fault, FaultPlan, RoundExecutor};
use super::metrics::Metrics;
use super::prober::{ProbeCandidate, ShadowProber};
use super::request::{Completion, Request, Response, ResponseRx, ShedReason, SloClass};

use crate::eval::generate::SamplerKind;

enum Msg {
    Submit(Vec<(Request, mpsc::Sender<Response>, Arc<AtomicBool>)>),
    /// swap the live SLO config (queue budget, step cut, degradation
    /// ladder) at the next round boundary
    Reconfigure(SloCfg),
    /// harvest the shard's drift window + observability state for fleet
    /// aggregation (see `coordinator::fleet`): joins in-flight work and
    /// drains the prober so the reply reflects a round boundary
    Harvest(mpsc::Sender<ShardHarvest>),
    /// apply a fleet-broadcast recalibration plan at the next round
    /// boundary (round-atomic, exactly like a locally landed recal
    /// outcome — channel-ordered with submissions like `Reconfigure`)
    ApplyQparams(Box<FleetSwap>),
    Shutdown(mpsc::Sender<Metrics>),
}

/// One shard's round-boundary harvest, collected by the fleet aggregator:
/// the serialized live drift window plus a structured metrics snapshot
/// and the shard's telemetry series. Harvesting does not reset anything —
/// the window keeps accumulating and the shard keeps serving.
pub struct ShardHarvest {
    /// the shard's round counter at the harvest boundary
    pub round: u64,
    /// `SketchSet::to_bytes` of the live window; empty when the shard has
    /// no sketch sink (no recal and no `probe_sketches`)
    pub window: Vec<u8>,
    pub snapshot: MetricsSnapshot,
    /// retained per-round telemetry rows, oldest first
    pub rows: Vec<RoundSample>,
    pub timers: PhaseTimers,
}

/// A fleet-broadcast recalibration plan: qparams re-searched once on the
/// fleet-merged window, applied by every shard at its next round boundary
/// so the whole fleet hot-swaps to the same state at the same logical
/// point. Mirrors the private `RecalOutcome` a local check parks.
#[derive(Debug, Clone)]
pub struct FleetSwap {
    /// index of the fleet drift check that produced this plan
    pub check: u64,
    /// re-searched base qparams
    pub qparams: Vec<f32>,
    /// per-ladder-rung qparams, tagged with their (wbits, abits) targets
    pub rung_qparams: Vec<(i32, i32, Vec<f32>)>,
    /// `(layer, drift score)` of every rebuilt layer (audit attribution)
    pub layers: Vec<(u32, f32)>,
}

/// Failed-round attempts before a request is retired with
/// [`ShedReason::RetriesExhausted`] (its channel gets an explicit
/// [`Response::Shed`], then closes). Bounds both the retry load and
/// `shutdown()` when a batch fails deterministically (e.g. a
/// missing/corrupt artifact for one class).
const MAX_RETRY_ATTEMPTS: usize = 4;

/// Cap on the exponential retry backoff, in scheduling rounds. After the
/// k-th consecutive failed round a request sits out `min(2^k, this)`
/// rounds before it is planned again.
const MAX_BACKOFF_ROUNDS: u64 = 8;

struct Active {
    req: Request,
    sampler: Box<dyn Sampler>,
    x: Vec<f32>,
    cond: Vec<f32>,
    /// round-scoped eps landing zone (x.len()); persists across rounds so
    /// scatter never allocates
    eps_buf: Vec<f32>,
    /// consecutive failed-round retry attempts (reset on any served round)
    attempts: usize,
    /// retry backoff: not planned again before this round index
    backoff_until: u64,
    /// absolute round deadline (admission round + `deadline_budget()`)
    deadline: u64,
    /// rounds spent admitted but unscheduled (deferred past the queue
    /// budget or parked by backoff) — the per-class queue-wait sample
    waited: u64,
    /// served degraded at least once (step cut at admission and/or
    /// lower-bit variant rounds)
    degraded: bool,
    /// raised by the client dropping its [`ResponseRx`]
    cancelled: Arc<AtomicBool>,
    rng: Rng,
    tx: mpsc::Sender<Response>,
    submitted: Instant,
    evals: usize,
}

pub struct ServerHandle {
    tx: mpsc::Sender<Msg>,
    join: Option<thread::JoinHandle<()>>,
    next_id: std::sync::atomic::AtomicU64,
}

impl ServerHandle {
    /// Submit one request. Errors if the scheduler thread has exited
    /// (e.g. after a panic) instead of panicking in the caller. If the
    /// request is later shed (overload, exhausted retries), the receiver
    /// gets an explicit [`Response::Shed`] before the channel closes —
    /// `recv()` never blocks forever. Dropping the receiver cancels the
    /// request at the next planning round.
    pub fn submit(&self, req: Request) -> Result<ResponseRx> {
        Ok(self.submit_many(vec![req])?.pop().expect("one receiver per request"))
    }

    /// Submit a group of requests atomically: all of them join the same
    /// scheduling round, so round composition (and therefore output bits)
    /// does not depend on the race between arrivals and round execution.
    pub fn submit_many(&self, reqs: Vec<Request>) -> Result<Vec<ResponseRx>> {
        let mut rxs = Vec::with_capacity(reqs.len());
        let mut batch = Vec::with_capacity(reqs.len());
        for mut req in reqs {
            req.id = self.next_id.fetch_add(1, std::sync::atomic::Ordering::SeqCst);
            let (tx, gone, rx) = ResponseRx::channel();
            batch.push((req, tx, gone));
            rxs.push(rx);
        }
        self.tx
            .send(Msg::Submit(batch))
            .map_err(|_| anyhow!("serving coordinator is down (scheduler thread exited)"))?;
        Ok(rxs)
    }

    /// Swap the live SLO configuration (queue budget, step cut,
    /// degradation ladder) without restarting the server. Channel-ordered
    /// with submissions and applied strictly between rounds, so every
    /// round runs under exactly one config and a 1-worker server makes
    /// the same admission/degradation decisions as an N-worker one.
    pub fn reconfigure(&self, slo: SloCfg) -> Result<()> {
        self.tx
            .send(Msg::Reconfigure(slo))
            .map_err(|_| anyhow!("serving coordinator is down (scheduler thread exited)"))
    }

    /// Round-boundary harvest for fleet aggregation: the scheduler joins
    /// in-flight work, drains the shadow prober (in submission order, so
    /// the window state is worker-count independent), and replies with
    /// the serialized drift window plus a metrics snapshot and telemetry
    /// series. The server keeps running; nothing is reset.
    pub fn harvest(&self) -> Result<ShardHarvest> {
        let (tx, rx) = mpsc::channel();
        self.tx
            .send(Msg::Harvest(tx))
            .map_err(|_| anyhow!("serving coordinator is down (scheduler thread exited)"))?;
        rx.recv()
            .map_err(|_| anyhow!("serving coordinator exited before answering the harvest"))
    }

    /// Apply a fleet-broadcast recalibration plan. Channel-ordered with
    /// submissions and applied strictly between rounds (the `Reconfigure`
    /// discipline), so the hot-swap is round-atomic on every shard.
    pub fn apply_qparams(&self, swap: FleetSwap) -> Result<()> {
        self.tx
            .send(Msg::ApplyQparams(Box::new(swap)))
            .map_err(|_| anyhow!("serving coordinator is down (scheduler thread exited)"))
    }

    /// Stop the scheduler (after finishing in-flight requests) and collect
    /// the serving metrics.
    pub fn shutdown(mut self) -> Metrics {
        let (tx, rx) = mpsc::channel();
        let _ = self.tx.send(Msg::Shutdown(tx));
        let m = rx.recv().unwrap_or_default();
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
        m
    }
}

impl Drop for ServerHandle {
    fn drop(&mut self) {
        if let Some(j) = self.join.take() {
            let (tx, _rx) = mpsc::channel();
            let _ = self.tx.send(Msg::Shutdown(tx));
            let _ = j.join();
        }
    }
}

/// Serving mode: FP or quantized model.
pub enum ServeMode {
    Fp,
    Quant(QuantState),
}

/// Online-recalibration configuration for a quantized server (the serving
/// consumer of `crate::recal`). External producers — a fine-tune loop, a
/// shadow calibration prober, a monitoring sidecar — feed activation
/// sketches through the shared `sketches` handle; every `every_rounds`
/// scheduling rounds the coordinator runs the drift check → plan →
/// incremental re-search as a background job on its worker pool, and the
/// scheduler atomically swaps the re-searched qparams in **between**
/// rounds (a round's batches pin the `QuantState` they were planned with,
/// so no evaluation ever observes a mid-round change). TALoRA selections
/// depend only on the router/hub-mask/strategy, none of which a qparams
/// swap touches, so the per-timestep selection cache stays valid across
/// swaps.
pub struct ServeRecal {
    /// the session the serving qparams were searched on — owns the drift
    /// baseline, which advances as updates are applied
    pub session: QuantSession<'static>,
    /// knobs matching the original search (untouched layers replay their
    /// memoized winners)
    pub opts: QuantOpts,
    pub planner: RecalPlanner,
    /// live activation sketches (shared with the producers)
    pub sketches: Arc<Mutex<SketchSet>>,
    /// drift-check cadence in scheduling rounds
    pub every_rounds: usize,
    /// serving state directory: when set, the sketch window is restored
    /// from `sketches.msk` on server start (if present) and persisted
    /// there on shutdown and after every hot-swap — along with the
    /// swapped `QuantState` in `quant.mts` — so a restarted server
    /// resumes its drift window instead of starting blind
    pub state_dir: Option<StateDir>,
}

impl ServeRecal {
    pub fn new(
        session: QuantSession<'static>,
        opts: QuantOpts,
        sketches: Arc<Mutex<SketchSet>>,
    ) -> ServeRecal {
        ServeRecal {
            session,
            opts,
            planner: RecalPlanner::default(),
            sketches,
            every_rounds: 8,
            state_dir: None,
        }
    }

    /// Enable sketch/state persistence under `dir` (see
    /// [`ServeRecal::state_dir`]).
    pub fn with_state_dir(mut self, dir: StateDir) -> ServeRecal {
        self.state_dir = Some(dir);
        self
    }
}

/// A completed drift check's product, parked for the next round boundary.
struct RecalOutcome {
    /// re-searched base qparams
    qparams: Vec<f32>,
    /// per-ladder-rung qparams re-searched on the same updated
    /// calibration, tagged with the (wbits, abits) they were searched at
    rung_qparams: Vec<(i32, i32, Vec<f32>)>,
    /// drifted-layer count (for metrics)
    drifted: usize,
    /// `(layer, drift score)` of every rebuilt layer — the swap audit's
    /// attribution payload
    layers: Vec<(u32, f32)>,
    /// index of the drift check that produced this plan
    check: u64,
}

/// Shared state of the background recalibration job (scheduler thread +
/// pool workers).
struct RecalShared {
    session: Mutex<QuantSession<'static>>,
    sketches: Arc<Mutex<SketchSet>>,
    planner: RecalPlanner,
    opts: QuantOpts,
    every_rounds: usize,
    /// (wbits, abits) of each live degradation-ladder rung, in ladder
    /// order; kept in sync by `Msg::Reconfigure` so checks re-search the
    /// rungs the scheduler is actually serving
    rung_bits: Mutex<Vec<(i32, i32)>>,
    /// the fault plan's recal dials (injected panics/slowdowns)
    faults: FaultPlan,
    /// re-searched qparams awaiting the next round boundary
    outcome: Mutex<Option<RecalOutcome>>,
    inflight: AtomicBool,
    /// check indices whose job panicked (injected or real), drained by
    /// the scheduler at round boundaries into `recal-panic` trace events
    /// and a postmortem dump
    panicked: Mutex<Vec<u64>>,
}

impl RecalShared {
    /// The background job: snapshot the sketches, score drift against the
    /// session's current calibration, and on any drifted layer apply the
    /// incremental updates + re-search — base and every ladder rung on
    /// the same updated calibration — and park the result for the
    /// scheduler. `inflight` is cleared on every exit path (guard) so a
    /// panic inside the search can't wedge the cadence. Injected faults
    /// ([`FaultPlan::decide_recal`], keyed by the check index) and real
    /// panics alike are contained by the `catch_unwind`: a panic
    /// mid-application discards the whole product — nothing is parked, so
    /// a half-applied plan can never reach a round and hot-swaps stay
    /// round-atomic. The session mutex is locked *outside* the unwind
    /// boundary (the guard drops on the normal path after the panic is
    /// caught), so it is never poisoned and the next check proceeds.
    fn run_check(&self, check: u64) {
        struct Clear<'a>(&'a AtomicBool);
        impl Drop for Clear<'_> {
            fn drop(&mut self) {
                self.0.store(false, Ordering::SeqCst);
            }
        }
        let _clear = Clear(&self.inflight);
        let fault = self.faults.decide_recal(check);
        if let Fault::Slow(ms) = fault {
            thread::sleep(Duration::from_millis(ms));
        }
        let snapshot = self.sketches.lock().unwrap().clone();
        let rung_bits = self.rung_bits.lock().unwrap().clone();
        let mut session = self.session.lock().unwrap();
        let outcome = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let plan = self.planner.plan(session.calib(), &snapshot);
            if plan.is_empty() {
                return None;
            }
            let drifted = plan.layers.len();
            let layers: Vec<(u32, f32)> =
                plan.layers.iter().map(|rl| (rl.layer as u32, rl.score)).collect();
            for rl in plan.layers {
                session.update_layer_calib(rl.layer, rl.calib);
            }
            if fault == Fault::Panic {
                panic!("injected fault: recal check {check} panics mid-application");
            }
            let qparams = session.quantize(&self.opts).qparams_rows();
            let rung_qparams = rung_bits
                .iter()
                .map(|&(w, a)| (w, a, session.degraded_qparams(&self.opts, w, a)))
                .collect();
            Some(RecalOutcome { qparams, rung_qparams, drifted, layers, check })
        }));
        match outcome {
            Ok(Some(out)) => *self.outcome.lock().unwrap() = Some(out),
            Ok(None) => {}
            Err(_) => {
                self.panicked.lock().unwrap().push(check);
                crate::log_warn!(
                    "recal check {check} panicked; half-applied plan discarded (no swap parked)"
                );
            }
        }
    }
}

/// Overload policy: admission budget + graceful-degradation levers. All
/// decisions derived from it are pure functions of (queue snapshot, round
/// index), so they are bit-identical for any worker count.
///
/// The default (`queue_budget == 0`) disables admission control entirely
/// — the pre-SLO coordinator's behavior.
#[derive(Clone, Default)]
pub struct SloCfg {
    /// max samples planned per scheduling round; 0 = unlimited. The
    /// server is *overloaded* whenever the admitted backlog exceeds this,
    /// which arms best-effort shedding and interactive downgrades.
    pub queue_budget: usize,
    /// sampler steps cut from an interactive request admitted while the
    /// backlog is over budget (0 = no step cut; never cuts below 1 step)
    pub step_cut: usize,
    /// multi-rung degradation ladder, mildest rung first (e.g. W3 then
    /// W2): interactive tickets of an overloaded round are served on the
    /// rung picked by backlog depth (see `ladder_rung`), and recal
    /// hot-swaps refresh every rung's qparams alongside the base.
    /// Quantized serving only; ignored (with a warning) on an FP server.
    /// Empty = no degraded variants (step cuts still apply). Build with
    /// [`degradation_ladder`] or push [`LadderRung`]s by hand.
    pub ladder: Vec<LadderRung>,
}

/// One rung of the degradation ladder: a pre-built lower-bit variant plus
/// the (wbits, abits) target it was searched at, so recalibration
/// hot-swaps can re-search the same target against the updated
/// calibration and refresh the rung's qparams alongside the base.
#[derive(Clone)]
pub struct LadderRung {
    pub wbits: i32,
    pub abits: i32,
    pub state: QuantState,
}

/// Build a degradation ladder from one sweep over the serving session:
/// each `(wbits, abits)` target re-searches only the layers the bit cut
/// touches (`QuantSession::degraded_qparams` replays memoized winners
/// elsewhere), and every rung shares router/LoRA/hub-mask with `base`
/// ([`degraded_state`]), so TALoRA selections — and the scheduler's
/// selection cache — stay valid across all rungs. Order targets mildest
/// first (e.g. `&[(3, 4), (2, 4)]` for a W3 → W2 ladder).
pub fn degradation_ladder(
    session: &QuantSession<'_>,
    opts: &QuantOpts,
    base: &QuantState,
    bits: &[(i32, i32)],
) -> Vec<LadderRung> {
    bits.iter()
        .map(|&(wbits, abits)| LadderRung {
            wbits,
            abits,
            state: degraded_state(base, session.degraded_qparams(opts, wbits, abits)),
        })
        .collect()
}

/// Degradation rung for one round: `None` while the backlog is within
/// budget (or with no budget/ladder), otherwise a rung index scaling with
/// how many budget multiples the backlog exceeds — backlog in (B, 2B] →
/// rung 0, (2B, 3B] → rung 1, …, clamped to the deepest rung. Pure in
/// (backlog, budget, depth), so every worker count picks the same rung
/// for the same queue snapshot.
fn ladder_rung(backlog: usize, budget: usize, depth: usize) -> Option<usize> {
    if budget == 0 || depth == 0 || backlog <= budget {
        return None;
    }
    Some(((backlog - budget - 1) / budget).min(depth - 1))
}

/// The graceful-degradation variant: the serving `QuantState` with its
/// qparams swapped for a cheaper (lower-bit) search result. Router, LoRA,
/// hub mask and strategy are shared with the base state, so per-timestep
/// TALoRA selections — and the scheduler's selection cache — stay valid
/// across base/degraded rounds.
pub fn degraded_state(base: &QuantState, qparams: Vec<f32>) -> QuantState {
    let mut v = base.clone();
    v.qparams = qparams;
    v
}

/// The round-boundary qparams hot-swap, shared by the local recal landing
/// and the fleet `ApplyQparams` broadcast: swap the base state, refresh
/// every ladder rung whose (wbits, abits) still matches its re-searched
/// target, and write the full audit trail (HotSwap event + [`SwapAudit`]
/// + swap counters). Returns the plan's max drift score — the telemetry
/// `drift_max` signal — or `None` on an FP server (nothing to swap).
/// Checkpointing is *not* part of the swap: the local recal path persists
/// to its shard state dir afterwards, while fleet swaps leave durability
/// to the fleet aggregator.
#[allow(clippy::too_many_arguments)]
fn apply_qparams_swap(
    qs_cur: &mut Option<Arc<QuantState>>,
    ladder: &mut [(i32, i32, Arc<QuantState>)],
    metrics: &mut Metrics,
    rec: &Option<Arc<FlightRecorder>>,
    round: u64,
    check: u64,
    qparams: Vec<f32>,
    rung_qparams: Vec<(i32, i32, Vec<f32>)>,
    layers: Vec<(u32, f32)>,
) -> Option<f32> {
    let qs = qs_cur.as_mut()?;
    let old_fp = crate::runtime::native::qparams_fingerprint(&qs.qparams);
    let mut swapped = (**qs).clone();
    swapped.qparams = qparams;
    *qs = Arc::new(swapped);
    let new_fp = crate::runtime::native::qparams_fingerprint(&qs.qparams);
    // refresh every ladder rung re-searched on the same updated
    // calibration. Positions must still agree on (wbits, abits) — a
    // reconfigure that landed while the check ran leaves mismatched rungs
    // on their old qparams until the next check refreshes them.
    let mut rung_status = Vec::with_capacity(rung_qparams.len());
    for (i, (w, a, qp)) in rung_qparams.into_iter().enumerate() {
        let refreshed = match ladder.get_mut(i) {
            Some(entry) if entry.0 == w && entry.1 == a => {
                entry.2 = Arc::new(degraded_state(&entry.2, qp));
                true
            }
            _ => false,
        };
        rung_status.push((w, a, refreshed));
    }
    let drifted = layers.len();
    let drift_max = layers.iter().fold(0.0f32, |m, &(_, s)| m.max(s));
    // the audit trail attributes the swap end to end: which check, which
    // layers (with scores), what the qparams fingerprints were
    // before/after, and how each rung's refresh went
    let audit = SwapAudit { round, check, old_fp, new_fp, drifted: layers, rungs: rung_status };
    if let Some(r) = rec {
        r.emit(
            round,
            EventKind::HotSwap {
                swap: metrics.recal_swaps as u64,
                drifted: drifted as u32,
                old_fp,
                new_fp,
            },
        );
        r.audit(audit.clone());
    }
    metrics.swap_audits.push(audit);
    metrics.recal_swaps += 1;
    metrics.recal_layers += drifted;
    if metrics.first_swap_round.is_none() {
        metrics.first_swap_round = Some(metrics.rounds);
    }
    crate::log_info!("recalibration hot-swap: {drifted} drifted layer(s) at round {round}");
    Some(drift_max)
}

pub struct ServerCfg {
    pub mode: ServeMode,
    /// decode latents to pixels before responding (LDM variants)
    pub decode_latents: bool,
    pub seed: u64,
    /// round-executor worker threads: 0 = available parallelism,
    /// 1 = sequential in-line execution on the scheduler thread
    pub workers: usize,
    /// FP rounds batch mixed-t tickets (the FP graph takes per-sample t;
    /// quantized planning is always same-t). On by default; turn off to
    /// reproduce same-t FP plans (the mixed-t parity test pins both modes
    /// bit-identical per request)
    pub fp_mixed_t: bool,
    /// background drift-tracked recalibration (quantized serving only)
    pub recal: Option<ServeRecal>,
    /// shadow-prober budget: max recycled-latent `calib_forward` probes
    /// per scheduling round (0 = probing off). Requires `recal` — the
    /// probes feed its sketches. Selection and feeding are deterministic
    /// for any worker count; candidates beyond the budget count as
    /// skipped in `Metrics`
    pub probe_budget: usize,
    /// external sketch sink for the shadow prober when no `recal` is
    /// configured: a fleet shard probes into its own window while the
    /// fleet aggregator owns drift scoring and planning (the shard never
    /// runs local checks). Ignored when `recal` is set — probes feed the
    /// recal sketches, which take precedence
    pub probe_sketches: Option<Arc<Mutex<SketchSet>>>,
    /// admission control + graceful degradation (default: off)
    pub slo: SloCfg,
    /// deterministic fault injection (default: no faults). Production
    /// servers leave this zeroed; tests and chaos drills schedule batch
    /// failures/panics/stalls and compile failures from a seed
    pub faults: FaultPlan,
    /// quantized-batch execution backend: `Graph` (compiled fake-qdq XLA
    /// graph, the oracle) or `Packed` (native bit-packed weights through
    /// the fused dequantize-matmul kernel). FP batches always use the
    /// graph
    pub backend: Backend,
    /// observability: flight-recorder ring size, telemetry row retention
    /// and the postmortem directory. Defaults to **on** (`ObsCfg::off()`
    /// disables everything); the logical trace is part of the 1-vs-N
    /// determinism surface
    pub obs: ObsCfg,
}

impl ServerCfg {
    /// Defaults: no latent decode, seed 0, auto workers, FP mixed-t
    /// batching on, no recalibration, probing off, no admission control,
    /// no fault injection.
    pub fn new(mode: ServeMode) -> ServerCfg {
        ServerCfg {
            mode,
            decode_latents: false,
            seed: 0,
            workers: 0,
            fp_mixed_t: true,
            recal: None,
            probe_budget: 0,
            probe_sketches: None,
            slo: SloCfg::default(),
            faults: FaultPlan::default(),
            backend: Backend::Graph,
            obs: ObsCfg::default(),
        }
    }
}

/// Spawn the coordinator. `den`/`params` are shared with the scheduler
/// thread; everything it needs is moved in.
pub fn spawn(
    den: Arc<Denoiser>,
    info: ModelInfo,
    sched: Schedule,
    params: Arc<Vec<f32>>,
    cfg: ServerCfg,
) -> ServerHandle {
    let (tx, rx) = mpsc::channel::<Msg>();
    let join = thread::spawn(move || scheduler_loop(rx, den, info, sched, params, cfg));
    ServerHandle { tx, join: Some(join), next_id: std::sync::atomic::AtomicU64::new(1) }
}

fn make_sampler(req: &Request, sched: &Schedule) -> Box<dyn Sampler> {
    let tau = timestep_subsequence(sched.t_total, req.steps);
    let s = Arc::new(sched.clone());
    match req.sampler {
        SamplerKind::Ddim => Box::new(DdimSampler::new(s, tau, req.eta)),
        SamplerKind::Plms => Box::new(PlmsSampler::new(s, tau)),
        SamplerKind::DpmSolver2 => Box::new(DpmSolver2::new(s, tau)),
    }
}

/// Clears the checkpoint-inflight flag when its job finishes (or panics),
/// so a poisoned write can't wedge checkpointing for the server lifetime.
struct ClearFlag(Arc<AtomicBool>);

impl Drop for ClearFlag {
    fn drop(&mut self) {
        self.0.store(false, Ordering::SeqCst);
    }
}

/// Retries per checkpoint-blob write before the write is counted failed
/// (transient storage faults — injected or real — usually clear well
/// within this).
const CKPT_WRITE_ATTEMPTS: u64 = 3;

/// Checkpoint durability counters, shared between the scheduler thread
/// and its offloaded checkpoint jobs and harvested into [`Metrics`] at
/// shutdown (`ckpt_fails` / `ckpt_retries`).
#[derive(Default)]
struct CkptCounters {
    fails: std::sync::atomic::AtomicUsize,
    retries: std::sync::atomic::AtomicUsize,
}

/// One checkpoint blob write with capped retries, feeding the shared
/// durability counters. Best-effort by design: serving never fails
/// because a checkpoint write did — atomic_write's tmp+rename discipline
/// guarantees the previous complete snapshot stays on disk whatever
/// happens here.
fn ckpt_write(path: &std::path::Path, bytes: &[u8], ckpt: &CkptCounters, what: &str) -> bool {
    match crate::util::io::atomic_write_retry(path, bytes, CKPT_WRITE_ATTEMPTS) {
        Ok(retries) => {
            if retries > 0 {
                ckpt.retries.fetch_add(retries as usize, Ordering::SeqCst);
                crate::log_warn!(
                    "persisted {what} to {} after {retries} retried write fault(s)",
                    path.display()
                );
            }
            true
        }
        Err(err) => {
            ckpt.fails.fetch_add(1, Ordering::SeqCst);
            crate::log_warn!("could not persist {what}: {err:#}");
            false
        }
    }
}

/// Persist the live drift window into the state dir (best-effort: serving
/// never fails because a checkpoint write did).
fn persist_window(
    recal: &Option<Arc<RecalShared>>,
    state_dir: &Option<StateDir>,
    ckpt: &CkptCounters,
    rec: &Option<Arc<FlightRecorder>>,
    round: u64,
) {
    if let (Some(rs), Some(sd)) = (recal, state_dir) {
        let snap = rs.sketches.lock().unwrap().clone();
        let ok = ckpt_write(&sd.sketch_path(), &snap.to_bytes(), ckpt, "sketch window");
        if let Some(r) = rec {
            r.emit(round, EventKind::Ckpt { what: CKPT_SKETCH, ok });
        }
    }
}

/// Stable wire tag of a [`ShedReason`] in `EventKind::Shed` payloads.
fn shed_reason_tag(reason: ShedReason) -> u8 {
    match reason {
        ShedReason::DeadlineMissed => 0,
        ShedReason::RetriesExhausted => 1,
    }
}

/// Retire a request without serving it: send the explicit shed notice
/// (then close the channel by dropping `tx`), and account the per-class
/// shed counter + queue-wait sample.
fn shed_request(
    a: Active,
    reason: ShedReason,
    metrics: &mut Metrics,
    rec: &Option<Arc<FlightRecorder>>,
    round: u64,
) {
    let rank = a.req.slo.rank();
    metrics.shed[rank] += 1;
    metrics.queue_waits[rank].push(a.waited);
    if let Some(r) = rec {
        r.emit(
            round,
            EventKind::Shed {
                id: a.req.id,
                class: rank as u8,
                reason: shed_reason_tag(reason),
            },
        );
    }
    crate::log_warn!("shedding request {} ({:?}): {reason}", a.req.id, a.req.slo);
    let _ = a.tx.send(Response::Shed { id: a.req.id, class: a.req.slo, reason });
}

/// Sheds in a single round at or above this count are a *shed storm* —
/// one of the postmortem-dump triggers.
const SHED_STORM_THRESHOLD: usize = 3;

/// Rounds between non-shutdown postmortem dumps, so a sustained overload
/// doesn't turn every round into a disk write.
const PM_COOLDOWN_ROUNDS: u64 = 8;

/// Dump the flight recorder (`trace.mtr`) and the telemetry series
/// (`metrics.jsonl`) into the postmortem directory (`ObsCfg::dir`,
/// falling back to the recal state dir). Best-effort like every
/// checkpoint write — both go through `ckpt_write`'s retried
/// `atomic_write`, so `FaultFs` chaos drills cover the dump path and a
/// crash mid-dump can never tear an existing postmortem. The caller
/// passes the *observability* counter pair, kept separate from the
/// serving checkpoint counters: `Metrics::ckpt_fails == 0` remains a
/// meaningful durability assertion for state checkpoints even when a
/// storm dump loses its own race with injected faults. Returns whether
/// a dump was attempted (recorder + directory both present).
fn dump_postmortem(
    rec: &Option<Arc<FlightRecorder>>,
    tel: &Telemetry,
    dir: &Option<StateDir>,
    ckpt: &CkptCounters,
    round: u64,
    why: &str,
) -> bool {
    let (Some(r), Some(sd)) = (rec, dir) else {
        return false;
    };
    crate::log_info!("postmortem ({why}) at round {round}: dumping trace + telemetry");
    let ok_trace =
        ckpt_write(&sd.trace_path(), &r.trace().to_bytes(), ckpt, "trace postmortem");
    let ok_tel =
        ckpt_write(&sd.telemetry_path(), tel.to_jsonl().as_bytes(), ckpt, "telemetry series");
    r.emit(round, EventKind::Ckpt { what: CKPT_TRACE, ok: ok_trace && ok_tel });
    true
}

fn scheduler_loop(
    rx: mpsc::Receiver<Msg>,
    den: Arc<Denoiser>,
    info: ModelInfo,
    sched: Schedule,
    params: Arc<Vec<f32>>,
    cfg: ServerCfg,
) {
    let ServerCfg {
        mode,
        decode_latents,
        seed,
        workers,
        fp_mixed_t,
        recal,
        probe_budget,
        probe_sketches,
        slo,
        faults,
        backend,
        obs,
    } = cfg;
    // compile-fault injection (chaos drills): arm the engine before any
    // graph loads so the retry budget is what gets exercised
    if faults.compile_fail_first > 0 {
        den.engine().inject_compile_failures(faults.compile_fail_first);
    }
    // flight recorder + telemetry: constructed before the first checkpoint
    // write so every ckpt attempt is an event. Emission happens on the
    // scheduler thread — plus the recal checkpoint offload lane, which is
    // timing-dependent exactly where recal already is (the no-recal
    // logical trace stays bit-identical for any worker count)
    let ObsCfg { events: obs_events, rounds: obs_rounds, dir: obs_dir } = obs;
    let rec: Option<Arc<FlightRecorder>> =
        (obs_events > 0).then(|| Arc::new(FlightRecorder::new(obs_events)));
    let mut tel = Telemetry::new(obs_rounds);
    let obs_on = rec.is_some() || obs_rounds > 0;
    let mut postmortems = 0usize;
    let mut pm_cooldown_until = 0u64;
    let mut fault_dumped = false;
    // previous round's ladder rung (-1 = full quality), for rung-change
    // events; max drift score of the latest landed recal plan
    let mut last_rung: i32 = -1;
    let mut last_drift_max = 0.0f32;
    let mut active: Vec<Active> = Vec::new();
    // samples received per active request in the current round
    let mut got: Vec<usize> = Vec::new();
    let mut metrics = Metrics::default();
    let mut shutdown: Option<mpsc::Sender<Metrics>> = None;
    let classes = den.batch_classes_q();
    let ae = Arc::new(PatchAutoencoder::default());
    let t0 = Instant::now();
    let xs = info.x_size(1);

    let exec = RoundExecutor::new(workers);
    let mut sel_cache = SelectionCache::new();
    // completion stats flow back from offloaded decode/send jobs
    let (done_tx, done_rx) = mpsc::channel::<Duration>();
    // the scheduler owns the current quantized state; batches pin the Arc
    // they were planned with, so recalibration swaps are round-atomic
    let mut qs_cur: Option<Arc<QuantState>> = match mode {
        ServeMode::Fp => None,
        ServeMode::Quant(qs) => Some(Arc::new(qs)),
    };
    // SLO knobs are *live* state: `Msg::Reconfigure` swaps them strictly
    // between rounds, so every derived decision changes for whole rounds
    // only and stays a pure function of (queue snapshot, round, config)
    let SloCfg { mut queue_budget, mut step_cut, ladder } = slo;
    // the degradation-ladder rungs served to interactive tickets during
    // overloaded rounds, mildest first; recalibration hot-swaps refresh
    // every rung's qparams alongside the base
    let arm_ladder = |rungs: Vec<LadderRung>, quant: bool| -> Vec<(i32, i32, Arc<QuantState>)> {
        if !rungs.is_empty() && !quant {
            crate::log_warn!("degradation ladder configured on an FP server: ignored");
            return Vec::new();
        }
        rungs.into_iter().map(|r| (r.wbits, r.abits, Arc::new(r.state))).collect()
    };
    let mut ladder = arm_ladder(ladder, qs_cur.is_some());
    metrics.rung_rounds = vec![0; ladder.len()];
    let mut state_dir: Option<StateDir> = None;
    let recal: Option<Arc<RecalShared>> = match (recal, qs_cur.is_some()) {
        (Some(r), true) => {
            state_dir = r.state_dir;
            Some(Arc::new(RecalShared {
                session: Mutex::new(r.session),
                sketches: r.sketches,
                planner: r.planner,
                opts: r.opts,
                every_rounds: r.every_rounds.max(1),
                rung_bits: Mutex::new(ladder.iter().map(|&(w, a, _)| (w, a)).collect()),
                faults,
                outcome: Mutex::new(None),
                inflight: AtomicBool::new(false),
                panicked: Mutex::new(Vec::new()),
            }))
        }
        (Some(_), false) => {
            crate::log_warn!("recalibration configured on an FP server: ignored");
            None
        }
        (None, _) => None,
    };
    // postmortems land in the obs dir, falling back to the recal state
    // dir — with neither, dumps are skipped (the in-memory ring and
    // telemetry still serve `Metrics`)
    let obs_dir = obs_dir.or_else(|| state_dir.clone());
    // crash hygiene: tmp files stranded by a previous kill mid-write are
    // never read as state (loads only see committed renames), but sweep
    // them so the state dir holds only complete checkpoints
    if let Some(sd) = &state_dir {
        let swept = sd.sweep_stale_tmp();
        if swept > 0 {
            crate::log_info!("swept {swept} stale tmp file(s) from the state dir");
        }
    }
    let ckpt_counters = Arc::new(CkptCounters::default());
    // postmortem-dump durability is accounted separately: a storm dump
    // losing its retry race with injected storage faults must not perturb
    // the serving checkpoint counters chaos tests pin (`ckpt_fails == 0`
    // under transient faults)
    let obs_ckpt = CkptCounters::default();
    // resume the drift window persisted by a previous run of this state
    // dir: the restored sketches are bit-identical to the saved ones
    // (reservoir contents + rng cursor), so drift accumulates as if the
    // restart never happened
    if let (Some(rs), Some(sd)) = (&recal, &state_dir) {
        let path = sd.sketch_path();
        if path.exists() {
            match SketchSet::load(&path) {
                Ok(loaded) => {
                    crate::log_info!("restored sketch window from {}", path.display());
                    *rs.sketches.lock().unwrap() = loaded;
                }
                Err(err) => {
                    crate::log_warn!("could not restore sketch window: {err:#}");
                }
            }
        }
    }
    // packed-blob lifecycle (packed backend + state dir): restore the
    // persisted nibble-packed weights so serving starts without
    // re-packing. A corrupt/truncated/stale blob surfaces as a distinct
    // parse or validation error and falls back to the normal rebuild from
    // the f32 store; the rebuilt blob is re-persisted so the *next* start
    // restores cleanly. Hot-swaps re-persist it again (see the swap path).
    if backend == Backend::Packed {
        if let (Some(sd), Some(qs)) = (&state_dir, &qs_cur) {
            let path = sd.packed_path();
            let mut restored = false;
            if path.exists() {
                match crate::quant::PackedModel::load(&path)
                    .and_then(|pm| den.seed_packed(qs, pm))
                {
                    Ok(()) => {
                        crate::log_info!("restored packed weights from {}", path.display());
                        restored = true;
                    }
                    Err(err) => crate::log_warn!(
                        "could not restore packed blob: {err:#}; rebuilding from the f32 store"
                    ),
                }
            }
            if !restored {
                match den.packed_blob(&params, qs) {
                    Ok(bytes) => {
                        let ok = ckpt_write(&path, &bytes, &ckpt_counters, "packed blob");
                        if let Some(r) = &rec {
                            r.emit(0, EventKind::Ckpt { what: CKPT_QPARAMS, ok });
                        }
                    }
                    Err(err) => crate::log_warn!("could not build packed blob: {err:#}"),
                }
            }
        }
    }
    // the live sketch window the prober feeds and `Msg::Harvest` reads:
    // the recal sketches when local recalibration owns the window, else
    // the externally supplied `probe_sketches` (a fleet shard's window —
    // the fleet aggregator scores drift and plans on the merged set)
    let live_sketches: Option<Arc<Mutex<SketchSet>>> = match (&recal, probe_sketches) {
        (Some(rs), external) => {
            if external.is_some() {
                crate::log_warn!("probe_sketches set alongside recal: recal sketches win");
            }
            Some(Arc::clone(&rs.sketches))
        }
        (None, external) => external,
    };
    let mut prober: Option<ShadowProber> = match (probe_budget, &live_sketches) {
        (0, _) => None,
        (k, Some(sink)) => Some(ShadowProber::new(
            k,
            Arc::clone(sink),
            Arc::clone(&den),
            Arc::clone(&params),
            exec.pad_pool(),
            rec.clone(),
        )),
        (_, None) => {
            crate::log_warn!("probe budget set without a sketch sink (recal or probe_sketches): ignored");
            None
        }
    };
    let mut last_check_round = 0usize;
    // at most one state-dir checkpoint job in flight (see the swap path)
    let ckpt_inflight = Arc::new(AtomicBool::new(false));
    // FP graphs take per-sample t, so FP rounds may batch mixed-t tickets;
    // the quantized TALoRA path stays same-t constrained
    let pmode =
        if qs_cur.is_none() && fp_mixed_t { PlanMode::MixedT } else { PlanMode::SameT };
    let evalf = eval_closure(EvalCtx {
        den: Arc::clone(&den),
        params: Arc::clone(&params),
        backend,
    });
    metrics.backend = backend.tag();

    loop {
        // drain arrivals; block only when idle and not shutting down
        loop {
            let msg = if active.is_empty() && shutdown.is_none() {
                match rx.recv() {
                    Ok(m) => m,
                    Err(_) => {
                        exec.join(); // flush offloaded completions
                        if let Some(p) = &mut prober {
                            p.drain();
                        }
                        let round = metrics.rounds as u64;
                        persist_window(&recal, &state_dir, &ckpt_counters, &rec, round);
                        if let Some(r) = &rec {
                            r.emit(round, EventKind::Shutdown { rounds: round });
                        }
                        dump_postmortem(
                            &rec,
                            &tel,
                            &obs_dir,
                            &obs_ckpt,
                            round,
                            "clients gone",
                        );
                        return;
                    }
                }
            } else {
                match rx.try_recv() {
                    Ok(m) => m,
                    Err(mpsc::TryRecvError::Empty) => break,
                    Err(mpsc::TryRecvError::Disconnected) => {
                        if active.is_empty() {
                            exec.join();
                            if let Some(p) = &mut prober {
                                p.drain();
                            }
                            let round = metrics.rounds as u64;
                            persist_window(&recal, &state_dir, &ckpt_counters, &rec, round);
                            if let Some(r) = &rec {
                                r.emit(round, EventKind::Shutdown { rounds: round });
                            }
                            dump_postmortem(
                                &rec,
                                &tel,
                                &obs_dir,
                                &obs_ckpt,
                                round,
                                "clients gone",
                            );
                            return;
                        }
                        break;
                    }
                }
            };
            match msg {
                Msg::Submit(reqs) => {
                    let admit_round = metrics.rounds as u64;
                    let mut backlog: usize = active.iter().map(|a| a.req.n).sum();
                    for (mut req, tx, gone) in reqs {
                        // admission-time degradation: an interactive
                        // request joining an over-budget backlog gets its
                        // step count cut (a pure function of the queue
                        // snapshot at admission)
                        let mut degraded = false;
                        if queue_budget > 0
                            && backlog + req.n > queue_budget
                            && req.slo == SloClass::Interactive
                            && step_cut > 0
                        {
                            let cut = req.steps.saturating_sub(step_cut).max(1);
                            if cut < req.steps {
                                crate::log_info!(
                                    "request {}: overloaded admission, steps {} -> {cut}",
                                    req.id,
                                    req.steps
                                );
                                req.steps = cut;
                                degraded = true;
                                metrics.downgraded_steps += 1;
                            }
                        }
                        let deadline = admit_round + req.deadline_budget() as u64;
                        if let Some(r) = &rec {
                            r.emit(
                                admit_round,
                                EventKind::Admit {
                                    id: req.id,
                                    class: req.slo.rank() as u8,
                                    deadline,
                                    steps: req.steps as u32,
                                    images: req.n as u32,
                                    step_cut: degraded,
                                },
                            );
                        }
                        backlog += req.n;
                        let mut rng = Rng::new(req.seed ^ 0x73657276);
                        let x: Vec<f32> = (0..req.n * xs).map(|_| rng.normal()).collect();
                        let cond: Vec<f32> = (0..req.n)
                            .map(|_| match req.class {
                                Some(c) => c as f32,
                                None if info.cfg.n_classes > 0 => {
                                    rng.below(info.cfg.n_classes) as f32
                                }
                                None => 0.0,
                            })
                            .collect();
                        active.push(Active {
                            sampler: make_sampler(&req, &sched),
                            eps_buf: vec![0.0; x.len()],
                            x,
                            cond,
                            attempts: 0,
                            backoff_until: 0,
                            deadline,
                            waited: 0,
                            degraded,
                            cancelled: gone,
                            rng,
                            tx,
                            submitted: Instant::now(),
                            evals: 0,
                            req,
                        });
                    }
                }
                Msg::Reconfigure(new) => {
                    // applied here, in the arrival drain — strictly
                    // between rounds — so admission, step cuts and rung
                    // choice change for whole rounds only and a 1-worker
                    // server replays an N-worker server's decisions
                    queue_budget = new.queue_budget;
                    step_cut = new.step_cut;
                    ladder = arm_ladder(new.ladder, qs_cur.is_some());
                    if metrics.rung_rounds.len() < ladder.len() {
                        metrics.rung_rounds.resize(ladder.len(), 0);
                    }
                    if let Some(rs) = &recal {
                        *rs.rung_bits.lock().unwrap() =
                            ladder.iter().map(|&(w, a, _)| (w, a)).collect();
                    }
                    metrics.reconfigures += 1;
                    if let Some(r) = &rec {
                        r.emit(
                            metrics.rounds as u64,
                            EventKind::Reconfigure {
                                queue_budget: queue_budget as u32,
                                step_cut: step_cut as u32,
                                ladder_depth: ladder.len() as u32,
                            },
                        );
                    }
                    crate::log_info!(
                        "reconfigured SLOs at round {}: queue budget {queue_budget}, step cut {step_cut}, ladder depth {}",
                        metrics.rounds,
                        ladder.len()
                    );
                }
                Msg::Harvest(tx) => {
                    // fleet aggregation boundary: flush everything the
                    // window could still absorb. After join() every
                    // offloaded probe has posted, so the in-order drain
                    // leaves the sketch state identical for any worker
                    // count — the harvested window is deterministic.
                    exec.join();
                    while let Ok(latency) = done_rx.try_recv() {
                        metrics.latencies.push(latency);
                    }
                    if let Some(p) = &mut prober {
                        p.drain();
                        metrics.probes = p.sent;
                        metrics.probes_skipped = p.skipped;
                        metrics.probes_failed = p.failed;
                    }
                    let round = metrics.rounds as u64;
                    let window = live_sketches
                        .as_ref()
                        .map(|s| s.lock().unwrap().to_bytes())
                        .unwrap_or_default();
                    // stamp the late-bound counters the shutdown path
                    // stamps, so a harvest snapshot is self-consistent
                    let mut m = metrics.clone();
                    if let Some(r) = &rec {
                        m.trace_events = r.total() as usize;
                        m.trace_dropped = r.dropped() as usize;
                    }
                    m.ckpt_fails = ckpt_counters.fails.load(Ordering::SeqCst);
                    m.ckpt_retries = ckpt_counters.retries.load(Ordering::SeqCst);
                    m.sel_hits = sel_cache.hits;
                    m.sel_misses = sel_cache.misses;
                    m.compile_attempts = den.engine().compile_attempts();
                    m.compile_exhausted = den.engine().compile_exhausted_count();
                    m.packed_bytes = den.packed_bytes();
                    m.postmortems = postmortems;
                    m.wall = t0.elapsed();
                    let _ = tx.send(ShardHarvest {
                        round,
                        window,
                        snapshot: m.snapshot(),
                        rows: tel.rows().cloned().collect(),
                        timers: tel.timers.clone(),
                    });
                }
                Msg::ApplyQparams(swap) => {
                    // fleet-broadcast swap, applied here in the arrival
                    // drain — strictly between rounds, like Reconfigure —
                    // so no evaluation ever observes a mid-round change
                    // and every shard swaps at a round boundary. The
                    // fleet owns planning and durability; the shard skips
                    // its local checkpoint.
                    let round = metrics.rounds as u64;
                    let FleetSwap { check, qparams, rung_qparams, layers } = *swap;
                    match apply_qparams_swap(
                        &mut qs_cur,
                        &mut ladder,
                        &mut metrics,
                        &rec,
                        round,
                        check,
                        qparams,
                        rung_qparams,
                        layers,
                    ) {
                        Some(dm) => last_drift_max = dm,
                        None => {
                            crate::log_warn!("fleet qparams swap on an FP server: ignored")
                        }
                    }
                }
                Msg::Shutdown(tx) => shutdown = Some(tx),
            }
        }

        // absorb stats from completions that finished since last round
        while let Ok(latency) = done_rx.try_recv() {
            metrics.latencies.push(latency);
        }

        let round = metrics.rounds as u64;
        // round-scoped postmortem signals: sheds this round (storm
        // trigger) and whether a seeded fault fired (first-hit trigger)
        let mut round_sheds = 0usize;
        let mut round_fault_hit = false;

        // retire cancellations at plan time: the client dropped its
        // receiver, so its remaining rounds would be wasted compute
        let mut i = 0;
        while i < active.len() {
            if active[i].cancelled.load(Ordering::SeqCst) {
                let a = active.swap_remove(i);
                metrics.cancelled += 1;
                metrics.queue_waits[a.req.slo.rank()].push(a.waited);
                if let Some(r) = &rec {
                    r.emit(round, EventKind::Cancel { id: a.req.id });
                }
                crate::log_info!("request {} cancelled by client", a.req.id);
            } else {
                i += 1;
            }
        }

        // overload check + best-effort shedding: both decided from this
        // round's queue snapshot alone, so 1-vs-N workers agree bit-wise
        let backlog: usize = active.iter().map(|a| a.req.n).sum();
        let overloaded = queue_budget > 0 && backlog > queue_budget;
        if overloaded {
            let mut i = 0;
            while i < active.len() {
                if active[i].req.slo == SloClass::BestEffort && round >= active[i].deadline {
                    let a = active.swap_remove(i);
                    shed_request(a, ShedReason::DeadlineMissed, &mut metrics, &rec, round);
                    round_sheds += 1;
                } else {
                    i += 1;
                }
            }
        }
        // shed-storm postmortem, checked here as well as at round end so a
        // sweep that empties the whole queue still leaves a dump behind
        if round_sheds >= SHED_STORM_THRESHOLD
            && round >= pm_cooldown_until
            && dump_postmortem(&rec, &tel, &obs_dir, &obs_ckpt, round, "shed storm")
        {
            postmortems += 1;
            pm_cooldown_until = round + PM_COOLDOWN_ROUNDS;
        }

        if active.is_empty() {
            if let Some(tx) = shutdown.take() {
                exec.join(); // flush in-flight decode/send jobs + probes
                while let Ok(latency) = done_rx.try_recv() {
                    metrics.latencies.push(latency);
                }
                if let Some(p) = &mut prober {
                    // every probe has posted (join() above), so this final
                    // in-order drain leaves the sketch window in the same
                    // state for any worker count
                    p.drain();
                    metrics.probes = p.sent;
                    metrics.probes_skipped = p.skipped;
                    metrics.probes_failed = p.failed;
                }
                persist_window(&recal, &state_dir, &ckpt_counters, &rec, round);
                // final trace + telemetry dump, then stamp the recorder's
                // accounting into the metrics the caller collects
                if let Some(r) = &rec {
                    r.emit(round, EventKind::Shutdown { rounds: round });
                }
                if dump_postmortem(&rec, &tel, &obs_dir, &obs_ckpt, round, "shutdown") {
                    postmortems += 1;
                }
                if let Some(r) = &rec {
                    metrics.trace_events = r.total() as usize;
                    metrics.trace_dropped = r.dropped() as usize;
                }
                metrics.postmortems = postmortems;
                // offloaded checkpoint jobs all finished (join() above),
                // so the durability counters are final
                metrics.ckpt_fails = ckpt_counters.fails.load(Ordering::SeqCst);
                metrics.ckpt_retries = ckpt_counters.retries.load(Ordering::SeqCst);
                metrics.sel_hits = sel_cache.hits;
                metrics.sel_misses = sel_cache.misses;
                metrics.compile_attempts = den.engine().compile_attempts();
                metrics.compile_exhausted = den.engine().compile_exhausted_count();
                // real memory footprint of the packed backend's weights
                // (0 on the graph backend or before the first packed eval)
                metrics.packed_bytes = den.packed_bytes();
                metrics.wall = t0.elapsed();
                let _ = tx.send(metrics.clone());
                return;
            }
            continue;
        }

        // between rounds: feed completed shadow probes into the sketches
        // (in submission order), land a finished recalibration (atomic
        // hot-swap — the new state only affects batches planned from here
        // on) and kick off the next drift check on the pool when due
        if let Some(p) = &mut prober {
            p.drain();
        }
        let mut recal_panicked: Vec<u64> = Vec::new();
        if let Some(rs) = &recal {
            let recal_t0 = Instant::now();
            // surface contained recal-check panics as trace events (and a
            // postmortem trigger at the end of this round)
            recal_panicked = std::mem::take(&mut *rs.panicked.lock().unwrap());
            if let Some(r) = &rec {
                for &check in &recal_panicked {
                    r.emit(round, EventKind::RecalPanic { check });
                }
            }
            if let Some(out) = rs.outcome.lock().unwrap().take() {
                let landed = apply_qparams_swap(
                    &mut qs_cur,
                    &mut ladder,
                    &mut metrics,
                    &rec,
                    round,
                    out.check,
                    out.qparams,
                    out.rung_qparams,
                    out.layers,
                );
                if let (Some(dm), Some(qs)) = (landed, &qs_cur) {
                    last_drift_max = dm;
                    // checkpoint the swapped model + the window it came
                    // from, off the scheduler thread: a crash after this
                    // point restarts on the recalibrated params. At most
                    // one checkpoint job runs at a time (a swap landing
                    // while one is in flight skips its checkpoint — the
                    // next swap or the shutdown persist catches up), so
                    // two jobs never race on the same files and the files
                    // on disk always reflect the newest completed write.
                    // Writes go through ckpt_write: capped retries over
                    // transient storage faults, fails/retries counted.
                    if let Some(sd) = &state_dir {
                        if !ckpt_inflight.swap(true, Ordering::SeqCst) {
                            let qs_snap = Arc::clone(qs);
                            let sk_snap = rs.sketches.lock().unwrap().clone();
                            let sd = sd.clone();
                            let clear = ClearFlag(Arc::clone(&ckpt_inflight));
                            let ckpt = Arc::clone(&ckpt_counters);
                            let den = Arc::clone(&den);
                            let params = Arc::clone(&params);
                            let packed = backend == Backend::Packed;
                            let rec = rec.clone();
                            exec.offload(move || {
                                let _clear = clear;
                                let ok = ckpt_write(
                                    &sd.quant_path(),
                                    &qs_snap.to_bytes(),
                                    &ckpt,
                                    "quant state",
                                );
                                if let Some(r) = &rec {
                                    r.emit(round, EventKind::Ckpt { what: CKPT_QPARAMS, ok });
                                }
                                let ok = ckpt_write(
                                    &sd.sketch_path(),
                                    &sk_snap.to_bytes(),
                                    &ckpt,
                                    "sketch window",
                                );
                                if let Some(r) = &rec {
                                    r.emit(round, EventKind::Ckpt { what: CKPT_SKETCH, ok });
                                }
                                if packed {
                                    // re-pack under the swapped qparams so a
                                    // restart seeds the packed cache without
                                    // rebuilding (a stale blob would be
                                    // rejected at load and rebuilt anyway)
                                    match den.packed_blob(&params, &qs_snap) {
                                        Ok(bytes) => {
                                            let ok = ckpt_write(
                                                &sd.packed_path(),
                                                &bytes,
                                                &ckpt,
                                                "packed blob",
                                            );
                                            if let Some(r) = &rec {
                                                r.emit(
                                                    round,
                                                    EventKind::Ckpt {
                                                        what: CKPT_QPARAMS,
                                                        ok,
                                                    },
                                                );
                                            }
                                        }
                                        Err(err) => crate::log_warn!(
                                            "could not re-pack swapped weights: {err:#}"
                                        ),
                                    }
                                }
                            });
                        }
                    }
                }
            }
            if metrics.rounds >= last_check_round + rs.every_rounds
                && !rs.inflight.swap(true, Ordering::SeqCst)
            {
                last_check_round = metrics.rounds;
                let check = metrics.recal_checks as u64;
                metrics.recal_checks += 1;
                // recal faults draw from the same pure schedule the job
                // will see, so the injected count is worker-independent
                let rfault = faults.decide_recal(check);
                if rfault != Fault::None {
                    metrics.faults_injected += 1;
                    round_fault_hit = true;
                }
                if let Some(r) = &rec {
                    r.emit(round, EventKind::RecalCheck { check, fault: rfault.tag() });
                }
                let rs = Arc::clone(rs);
                exec.offload(move || rs.run_check(check));
            }
            tel.timers.recal.record_us(recal_t0.elapsed().as_micros() as u64);
        }

        // one scheduling round: earliest-deadline-first admission within
        // class priority over every schedulable (not backed-off) request,
        // then batch planning (same-t for quant, mixed-t for FP when
        // enabled) and gather at pre-assigned offsets
        let sched_t0 = Instant::now();
        let cands: Vec<SloTicket> = active
            .iter()
            .enumerate()
            .filter(|&(_, a)| round >= a.backoff_until)
            .map(|(i, a)| SloTicket {
                ticket: Ticket { req: i, t: a.sampler.current_t(), n: a.req.n },
                class: a.req.slo,
                deadline: a.deadline,
                id: a.req.id,
            })
            .collect();
        let n_cands = cands.len();
        let (admitted, deferred) = admit_edf(&cands, queue_budget);
        let n_admitted = admitted.len();
        let n_deferred = deferred.len();
        let mut scheduled = vec![false; active.len()];
        for tk in &admitted {
            scheduled[tk.req] = true;
        }
        for (i, a) in active.iter_mut().enumerate() {
            if !scheduled[i] {
                // deferred past the budget or parked by retry backoff:
                // a queue-wait round for this request's class
                a.waited += 1;
            }
        }
        // graceful degradation: during overloaded rounds, interactive
        // tickets are split off and served on a degradation-ladder rung —
        // the deeper the backlog, the coarser the rung (`ladder_rung` is
        // pure in the queue snapshot, so every worker count agrees).
        // Normal batches plan first, degraded batches second, so batch
        // indices (and the fault schedule over them) stay stable.
        let rung = ladder_rung(backlog, queue_budget, ladder.len());
        let rung_qs: Option<Arc<QuantState>> = rung.map(|r| Arc::clone(&ladder[r].2));
        let degrade_round = rung_qs.is_some();
        let (norm_tk, deg_tk): (Vec<Ticket>, Vec<Ticket>) = if degrade_round {
            admitted
                .into_iter()
                .partition(|tk| active[tk.req].req.slo != SloClass::Interactive)
        } else {
            (admitted, Vec::new())
        };
        if !deg_tk.is_empty() {
            metrics.downgraded_rounds += 1;
            if let Some(r) = rung {
                metrics.rung_rounds[r] += 1;
            }
            for tk in &deg_tk {
                active[tk.req].degraded = true;
            }
        }
        let mut batches = plan_mode(&norm_tk, &classes, pmode);
        let n_norm = batches.len();
        if !deg_tk.is_empty() {
            // the degraded path is quantized, hence same-t constrained
            batches.extend(plan_mode(&deg_tk, &classes, PlanMode::SameT));
        }
        // the round summary event, emitted once the plan is fixed; a
        // rung-change event precedes it whenever the backlog moved the
        // ladder between rounds
        let rung_i = rung.map(|r| r as i32).unwrap_or(-1);
        if let Some(r) = &rec {
            if rung_i != last_rung {
                r.emit(
                    round,
                    EventKind::RungChange {
                        from: last_rung,
                        to: rung_i,
                        backlog: backlog as u32,
                    },
                );
            }
            r.emit(
                round,
                EventKind::Round {
                    backlog: backlog as u32,
                    admitted: n_admitted as u32,
                    deferred: n_deferred as u32,
                    batches: batches.len() as u32,
                    rung: rung_i,
                },
            );
        }
        last_rung = rung_i;
        // each request's tickets live in exactly one partition, so
        // offsets over the concatenated plan tile its samples as usual
        let offsets = ticket_offsets(&batches, active.len());
        let mut jobs = Vec::with_capacity(batches.len());
        for (bi, batch) in batches.iter().enumerate() {
            let (mut x, mut ts, mut cond) = exec.gather_bufs();
            for (tk, &start) in batch.tickets.iter().zip(&offsets[bi]) {
                let a = &active[tk.req];
                x.extend_from_slice(&a.x[start * xs..(start + tk.n) * xs]);
                ts.resize(ts.len() + tk.n, tk.t);
                cond.extend_from_slice(&a.cond[start..start + tk.n]);
            }
            let qs_batch = if bi >= n_norm { &rung_qs } else { &qs_cur };
            let sel = match qs_batch {
                None => None,
                Some(qs) => Some(sel_cache.get_or_compute(batch.t, || {
                    // fixed strategies draw from a per-t seeded rng, so
                    // even DualRandom selections are a pure function of
                    // (seed, t) and cache exactly. The cache is shared
                    // between base and degraded batches: selections
                    // depend only on router/hub-mask/strategy, which the
                    // degraded variant shares (only qparams differ)
                    let mut rng = Rng::new(seed ^ batch.t.to_bits() as u64);
                    qs.selection(batch.t, &mut rng)
                })),
            };
            let fault = faults.decide(round, bi as u64);
            if fault != Fault::None {
                metrics.faults_injected += 1;
                round_fault_hit = true;
                if let Some(r) = &rec {
                    r.emit(round, EventKind::Fault { batch: bi as u32, kind: fault.tag() });
                }
            }
            jobs.push(BatchJob {
                idx: bi,
                t: batch.t,
                x,
                ts,
                cond,
                sel,
                qs: qs_batch.clone(),
                fault,
            });
        }
        let plan_dt = sched_t0.elapsed();
        metrics.round_sched += plan_dt;
        tel.timers.plan.record_us(plan_dt.as_micros() as u64);

        // fan out; results come back in plan order regardless of workers
        let exec_t0 = Instant::now();
        let results = exec.run_with(&evalf, jobs);
        let exec_dt = exec_t0.elapsed();
        metrics.round_exec += exec_dt;
        tel.timers.exec.record_us(exec_dt.as_micros() as u64);

        // scatter eps into each request's pre-assigned range
        let scatter_t0 = Instant::now();
        got.clear();
        got.resize(active.len(), 0);
        for r in results {
            let batch = &batches[r.idx];
            match r.eps {
                Ok(eps) => {
                    metrics.evals += 1;
                    metrics.batch_sizes.push(batch.used());
                    metrics.batch_fills.push(batch.fill());
                    let mut off = 0;
                    for (tk, &start) in batch.tickets.iter().zip(&offsets[r.idx]) {
                        let a = &mut active[tk.req];
                        a.eps_buf[start * xs..(start + tk.n) * xs]
                            .copy_from_slice(&eps[off * xs..(off + tk.n) * xs]);
                        got[tk.req] += tk.n;
                        off += tk.n;
                    }
                    exec.recycle(r.job, Some(eps));
                }
                Err(err) => {
                    // the failed batch's requests simply miss this round
                    // (retried next round); every other batch already
                    // scattered into its own pre-assigned ranges
                    crate::log_warn!("batch eval failed: {err:#}");
                    exec.recycle(r.job, None);
                }
            }
        }

        // shadow probing: recycle a budgeted, deterministically selected
        // subset of this round's fully served latents into calib forwards
        // on the pool — post-scatter (the exact (x, t) the round's eval
        // consumed), before the sampler advances x below
        if let Some(p) = &mut prober {
            let probe_t0 = Instant::now();
            let cands: Vec<ProbeCandidate> = active
                .iter()
                .enumerate()
                .filter(|&(i, a)| got[i] == a.req.n)
                .map(|(i, a)| ProbeCandidate { id: a.req.id, idx: i })
                .collect();
            p.round_probes(&exec, metrics.rounds as u64, &cands, |idx| {
                let a = &active[idx];
                // the sampler has not advanced yet, so current_t() is the
                // exact t this round's eval consumed for the request
                (&a.x[..], a.sampler.current_t(), &a.cond[..])
            });
            tel.timers.probe.record_us(probe_t0.elapsed().as_micros() as u64);
        }

        // observe + complete (completions run on the pool)
        let mut i = 0;
        while i < active.len() {
            if scheduled[i] && got[i] == active[i].req.n {
                let a = &mut active[i];
                let eps = std::mem::take(&mut a.eps_buf);
                a.sampler.observe(&mut a.x, &eps, &mut a.rng);
                a.eps_buf = eps;
                a.evals += 1;
                a.attempts = 0;
            } else if scheduled[i] {
                // a scheduled request came up short: one of its batches
                // failed. Retry with capped exponential backoff in rounds;
                // a persistent failure retires the request with an
                // explicit shed notice instead of spinning the scheduler
                // or hanging shutdown
                active[i].attempts += 1;
                metrics.retries += 1;
                if active[i].attempts >= MAX_RETRY_ATTEMPTS {
                    let a = active.swap_remove(i);
                    got.swap_remove(i);
                    scheduled.swap_remove(i);
                    shed_request(a, ShedReason::RetriesExhausted, &mut metrics, &rec, round);
                    round_sheds += 1;
                    continue;
                }
                let a = &mut active[i];
                a.backoff_until = round + 1 + (1u64 << a.attempts).min(MAX_BACKOFF_ROUNDS);
                if let Some(r) = &rec {
                    r.emit(
                        round,
                        EventKind::Retry {
                            id: a.req.id,
                            attempt: a.attempts as u32,
                            backoff_rounds: a.backoff_until - round - 1,
                        },
                    );
                }
                crate::log_warn!(
                    "request {} failed round {round} (attempt {}/{MAX_RETRY_ATTEMPTS}); backing off {} round(s)",
                    a.req.id,
                    a.attempts,
                    a.backoff_until - round - 1
                );
            }
            if active[i].sampler.done() {
                let a = active.swap_remove(i);
                got.swap_remove(i);
                scheduled.swap_remove(i);
                metrics.images_done += a.req.n;
                metrics.queue_waits[a.req.slo.rank()].push(a.waited);
                if let Some(r) = &rec {
                    r.emit(
                        round,
                        EventKind::Done {
                            id: a.req.id,
                            evals: a.evals as u32,
                            degraded: a.degraded,
                        },
                    );
                }
                let ae = Arc::clone(&ae);
                let done_tx = done_tx.clone();
                exec.offload(move || {
                    let images =
                        if decode_latents { ae.decode_batch(&a.x, a.req.n) } else { a.x };
                    let latency = a.submitted.elapsed();
                    let _ = done_tx.send(latency);
                    let _ = a.tx.send(Response::Done(Completion {
                        id: a.req.id,
                        images,
                        n: a.req.n,
                        latency,
                        evals: a.evals,
                        degraded: a.degraded,
                    }));
                });
            } else {
                i += 1;
            }
        }
        let offload_dt = scatter_t0.elapsed();
        metrics.round_sched += offload_dt;
        tel.timers.offload.record_us(offload_dt.as_micros() as u64);

        // per-round telemetry sample: counters are cumulative (see
        // `RoundSample`), so a truncated ring still differentiates into
        // correct rates. Skipped entirely when observability is off — the
        // `trace_overhead` bench baseline pays nothing here.
        if obs_on {
            let wp = |i: usize, q: f64| super::metrics::percentile_u64(&metrics.queue_waits[i], q);
            tel.push(RoundSample {
                round,
                depth: active.len() as u32,
                backlog: n_cands as u32,
                admitted: n_admitted as u32,
                deferred: n_deferred as u32,
                batches: batches.len() as u32,
                rung: rung_i,
                shed: metrics.shed.iter().map(|&s| s as u64).sum(),
                retries: metrics.retries as u64,
                faults: metrics.faults_injected as u64,
                evals: metrics.evals as u64,
                probes: prober.as_ref().map_or(0, |p| p.sent as u64),
                recal_checks: metrics.recal_checks as u64,
                recal_swaps: metrics.recal_swaps as u64,
                ckpt_retries: ckpt_counters.retries.load(Ordering::SeqCst) as u64,
                drift_max: last_drift_max,
                wait_p50: [wp(0, 0.50), wp(1, 0.50), wp(2, 0.50)],
                wait_p99: [wp(0, 0.99), wp(1, 0.99), wp(2, 0.99)],
                plan_us: metrics.round_sched.as_micros() as u64,
                exec_us: metrics.round_exec.as_micros() as u64,
            });
        }
        // remaining postmortem triggers: a shed storm that built up after
        // the sweep-time check, a contained recal-check panic, or the
        // first seeded fault of the serve (once — later hits are ordinary)
        let storm = round_sheds >= SHED_STORM_THRESHOLD;
        let fresh_fault = round_fault_hit && !fault_dumped;
        if (storm || !recal_panicked.is_empty() || fresh_fault) && round >= pm_cooldown_until {
            let why = if storm {
                "shed storm"
            } else if !recal_panicked.is_empty() {
                "recal-check panic"
            } else {
                "injected fault"
            };
            if dump_postmortem(&rec, &tel, &obs_dir, &obs_ckpt, round, why) {
                postmortems += 1;
                pm_cooldown_until = round + PM_COOLDOWN_ROUNDS;
                if fresh_fault {
                    fault_dumped = true;
                }
            }
        }
        metrics.rounds += 1;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::manifest::Manifest;
    use crate::model::ParamStore;
    use crate::runtime::Engine;
    use std::path::PathBuf;

    fn setup() -> Option<(Arc<Denoiser>, ModelInfo, Arc<Vec<f32>>)> {
        let d = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if !d.join("manifest.json").exists() {
            crate::log_warn!("skipping: artifacts not built");
            return None;
        }
        let m = Manifest::load(&d).unwrap();
        let info = m.model("ddim16").unwrap().clone();
        let engine = Arc::new(Engine::new(&d).unwrap());
        let den = Arc::new(Denoiser::new(engine, &info).unwrap());
        let params = Arc::new(ParamStore::load_init(&info, &d).unwrap().flat);
        Some((den, info, params))
    }

    #[test]
    fn ladder_rung_scales_with_backlog_and_clamps() {
        // within budget (or at it): no degradation
        assert_eq!(ladder_rung(0, 4, 2), None);
        assert_eq!(ladder_rung(4, 4, 2), None);
        // one budget multiple over: mildest rung
        assert_eq!(ladder_rung(5, 4, 2), Some(0));
        assert_eq!(ladder_rung(8, 4, 2), Some(0));
        // next multiple: next rung; deep backlog clamps to the deepest
        assert_eq!(ladder_rung(9, 4, 2), Some(1));
        assert_eq!(ladder_rung(100, 4, 2), Some(1));
        assert_eq!(ladder_rung(13, 4, 3), Some(2));
        // no budget = no overload signal; no ladder = nothing to pick
        assert_eq!(ladder_rung(5, 0, 2), None);
        assert_eq!(ladder_rung(5, 4, 0), None);
    }

    #[test]
    fn serves_concurrent_fp_requests() {
        let Some((den, info, params)) = setup() else { return };
        let sched = Schedule::linear(100);
        let handle = spawn(
            den,
            info,
            sched,
            params,
            ServerCfg { seed: 1, ..ServerCfg::new(ServeMode::Fp) },
        );
        let rx1 = handle.submit(Request::new(0, 3, 4)).unwrap();
        let rx2 = handle.submit(Request::new(0, 2, 4)).unwrap();
        let rx3 = handle.submit(Request::new(0, 1, 6)).unwrap(); // different step count
        let r1 = rx1.recv().unwrap().unwrap_done();
        let r2 = rx2.recv().unwrap().unwrap_done();
        let r3 = rx3.recv().unwrap().unwrap_done();
        assert_eq!(r1.n, 3);
        assert_eq!(r2.images.len(), 2 * 16 * 16 * 3);
        assert_eq!(r3.evals, 6);
        assert!(r1.images.iter().all(|v| v.is_finite()));
        let m = handle.shutdown();
        assert_eq!(m.images_done, 6);
        assert!(m.evals > 0);
        assert!(m.rounds > 0);
        assert_eq!(m.latencies.len(), 3, "every completion must report back");
        // same-steps requests must have shared batches at least once
        assert!(m.mean_batch() > 1.0, "no batching happened: {}", m.report());
    }

    #[test]
    fn submit_after_shutdown_errors_instead_of_panicking() {
        let Some((den, info, params)) = setup() else { return };
        let sched = Schedule::linear(100);
        let handle = spawn(
            den,
            info,
            sched,
            params,
            ServerCfg { seed: 1, workers: 1, ..ServerCfg::new(ServeMode::Fp) },
        );
        // steal the sender's counterpart by shutting the scheduler down
        // out from under a clone of the handle's channel
        let tx = handle.tx.clone();
        let m = handle.shutdown();
        assert_eq!(m.images_done, 0);
        // the scheduler thread is gone; a late submit must surface an Err
        let stale = ServerHandle {
            tx,
            join: None,
            next_id: std::sync::atomic::AtomicU64::new(1),
        };
        assert!(stale.submit(Request::new(0, 1, 2)).is_err());
    }

    #[test]
    fn submit_many_joins_one_round() {
        let Some((den, info, params)) = setup() else { return };
        let sched = Schedule::linear(100);
        let handle = spawn(
            den,
            info,
            sched,
            params,
            ServerCfg { seed: 1, ..ServerCfg::new(ServeMode::Fp) },
        );
        let reqs: Vec<Request> = (0..4)
            .map(|i| {
                let mut r = Request::new(0, 1, 4);
                r.seed = i;
                r
            })
            .collect();
        let rxs = handle.submit_many(reqs).unwrap();
        for rx in rxs {
            let c = rx.recv().unwrap().unwrap_done();
            assert!(c.images.iter().all(|v| v.is_finite()));
        }
        let m = handle.shutdown();
        assert_eq!(m.images_done, 4);
        // all four single-sample requests shared batches from round one
        assert!(m.mean_batch() > 3.0, "bulk submit did not share rounds: {}", m.report());
    }
}
