//! The serving coordinator: step-level continuous batching over the
//! quantized (or FP) denoiser — the vLLM-router-shaped L3 of this repo.
//!
//! Architecture (std threads; tokio unavailable offline — DESIGN.md §1):
//!   * clients `submit()` requests over an MPSC channel and get a
//!     per-request response receiver;
//!   * the scheduler thread owns all request state (sampler state machines,
//!     latents) and loops: drain arrivals → collect each active request's
//!     next evaluation ticket → `batcher::plan` → execute batches (model
//!     eval) → `observe` results into the samplers → emit completions;
//!   * new requests join at the next round (continuous batching): a long
//!     request never blocks a short one, same-t requests share compute.

use std::sync::mpsc;
use std::sync::Arc;
use std::thread;
use std::time::Instant;


use crate::data::PatchAutoencoder;
use crate::model::manifest::ModelInfo;
use crate::runtime::{Denoiser, QuantState};
use crate::schedule::{timestep_subsequence, DdimSampler, DpmSolver2, PlmsSampler, Sampler, Schedule};
use crate::util::rng::Rng;

use super::batcher::{plan, Ticket};
use super::metrics::Metrics;
use super::request::{Request, Response};

use crate::eval::generate::SamplerKind;

enum Msg {
    Submit(Request, mpsc::Sender<Response>),
    Shutdown(mpsc::Sender<Metrics>),
}

struct Active {
    req: Request,
    sampler: Box<dyn Sampler>,
    x: Vec<f32>,
    cond: Vec<f32>,
    rng: Rng,
    tx: mpsc::Sender<Response>,
    submitted: Instant,
    evals: usize,
}

pub struct ServerHandle {
    tx: mpsc::Sender<Msg>,
    join: Option<thread::JoinHandle<()>>,
    next_id: std::sync::atomic::AtomicU64,
}

impl ServerHandle {
    pub fn submit(&self, mut req: Request) -> mpsc::Receiver<Response> {
        req.id = self.next_id.fetch_add(1, std::sync::atomic::Ordering::SeqCst);
        let (tx, rx) = mpsc::channel();
        self.tx.send(Msg::Submit(req, tx)).expect("server down");
        rx
    }

    /// Stop the scheduler (after finishing in-flight requests) and collect
    /// the serving metrics.
    pub fn shutdown(mut self) -> Metrics {
        let (tx, rx) = mpsc::channel();
        let _ = self.tx.send(Msg::Shutdown(tx));
        let m = rx.recv().unwrap_or_default();
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
        m
    }
}

impl Drop for ServerHandle {
    fn drop(&mut self) {
        if let Some(j) = self.join.take() {
            let (tx, _rx) = mpsc::channel();
            let _ = self.tx.send(Msg::Shutdown(tx));
            let _ = j.join();
        }
    }
}

/// Serving mode: FP or quantized model.
pub enum ServeMode {
    Fp,
    Quant(QuantState),
}

pub struct ServerCfg {
    pub mode: ServeMode,
    /// decode latents to pixels before responding (LDM variants)
    pub decode_latents: bool,
    pub seed: u64,
}

/// Spawn the coordinator. `den`/`params` are shared with the scheduler
/// thread; everything it needs is moved in.
pub fn spawn(
    den: Arc<Denoiser>,
    info: ModelInfo,
    sched: Schedule,
    params: Arc<Vec<f32>>,
    cfg: ServerCfg,
) -> ServerHandle {
    let (tx, rx) = mpsc::channel::<Msg>();
    let join = thread::spawn(move || scheduler_loop(rx, den, info, sched, params, cfg));
    ServerHandle { tx, join: Some(join), next_id: std::sync::atomic::AtomicU64::new(1) }
}

fn make_sampler(req: &Request, sched: &Schedule) -> Box<dyn Sampler> {
    let tau = timestep_subsequence(sched.t_total, req.steps);
    let s = Arc::new(sched.clone());
    match req.sampler {
        SamplerKind::Ddim => Box::new(DdimSampler::new(s, tau, req.eta)),
        SamplerKind::Plms => Box::new(PlmsSampler::new(s, tau)),
        SamplerKind::DpmSolver2 => Box::new(DpmSolver2::new(s, tau)),
    }
}

fn scheduler_loop(
    rx: mpsc::Receiver<Msg>,
    den: Arc<Denoiser>,
    info: ModelInfo,
    sched: Schedule,
    params: Arc<Vec<f32>>,
    cfg: ServerCfg,
) {
    let mut active: Vec<Active> = Vec::new();
    let mut metrics = Metrics::default();
    let mut shutdown: Option<mpsc::Sender<Metrics>> = None;
    let classes = den.batch_classes_q();
    let ae = PatchAutoencoder::default();
    let t0 = Instant::now();
    let xs = info.x_size(1);

    loop {
        // drain arrivals; block only when idle and not shutting down
        loop {
            let msg = if active.is_empty() && shutdown.is_none() {
                match rx.recv() {
                    Ok(m) => m,
                    Err(_) => return,
                }
            } else {
                match rx.try_recv() {
                    Ok(m) => m,
                    Err(mpsc::TryRecvError::Empty) => break,
                    Err(mpsc::TryRecvError::Disconnected) => {
                        if active.is_empty() {
                            return;
                        }
                        break;
                    }
                }
            };
            match msg {
                Msg::Submit(req, tx) => {
                    let mut rng = Rng::new(req.seed ^ 0x73657276);
                    let x: Vec<f32> = (0..req.n * xs).map(|_| rng.normal()).collect();
                    let cond: Vec<f32> = (0..req.n)
                        .map(|_| match req.class {
                            Some(c) => c as f32,
                            None if info.cfg.n_classes > 0 => {
                                rng.below(info.cfg.n_classes) as f32
                            }
                            None => 0.0,
                        })
                        .collect();
                    active.push(Active {
                        sampler: make_sampler(&req, &sched),
                        x,
                        cond,
                        rng,
                        tx,
                        submitted: Instant::now(),
                        evals: 0,
                        req,
                    });
                }
                Msg::Shutdown(tx) => shutdown = Some(tx),
            }
        }

        if active.is_empty() {
            if let Some(tx) = shutdown.take() {
                metrics.wall = t0.elapsed();
                let _ = tx.send(metrics.clone());
                return;
            }
            continue;
        }

        // one scheduling round: plan same-t batches over all active requests
        let tickets: Vec<Ticket> = active
            .iter()
            .enumerate()
            .map(|(i, a)| Ticket { req: i, t: a.sampler.current_t(), n: a.req.n })
            .collect();
        let batches = plan(&tickets, &classes);

        // execute each batch and scatter eps back per request
        let mut eps_per_req: Vec<Vec<f32>> = active.iter().map(|_| Vec::new()).collect();
        for batch in &batches {
            let mut x = Vec::with_capacity(batch.used() * xs);
            let mut cond = Vec::with_capacity(batch.used());
            for tk in &batch.tickets {
                // NOTE: split tickets (n > max class) keep sample order, so
                // offsets reconstruct by arrival order per request
                let a = &active[tk.req];
                let done = eps_per_req[tk.req].len() / xs;
                x.extend_from_slice(&a.x[done * xs..(done + tk.n) * xs]);
                cond.extend_from_slice(&a.cond[done..done + tk.n]);
            }
            let eps = match &cfg.mode {
                ServeMode::Fp => {
                    let t = vec![batch.t; cond.len()];
                    den.eps_fp(&params, &x, &t, &cond)
                }
                ServeMode::Quant(qs) => {
                    // selection computed once per batch (one t): serving
                    // hot path shares it across the whole batch
                    let mut rng = Rng::new(cfg.seed ^ batch.t.to_bits() as u64);
                    den.eps_q(&params, qs, &x, batch.t, &cond, &mut rng)
                }
            };
            let eps = match eps {
                Ok(e) => e,
                Err(err) => {
                    crate::log_warn!("batch eval failed: {err:#}");
                    continue;
                }
            };
            metrics.evals += 1;
            metrics.batch_sizes.push(batch.used());
            metrics.batch_fills.push(batch.fill());
            let mut off = 0;
            for tk in &batch.tickets {
                eps_per_req[tk.req].extend_from_slice(&eps[off * xs..(off + tk.n) * xs]);
                off += tk.n;
            }
        }

        // observe + complete
        let mut i = 0;
        while i < active.len() {
            let eps = std::mem::take(&mut eps_per_req[i]);
            if eps.len() == active[i].x.len() {
                let a = &mut active[i];
                a.sampler.observe(&mut a.x, &eps, &mut a.rng);
                a.evals += 1;
            }
            if active[i].sampler.done() {
                let a = active.swap_remove(i);
                eps_per_req.swap_remove(i);
                let images = if cfg.decode_latents {
                    ae.decode_batch(&a.x, a.req.n)
                } else {
                    a.x
                };
                metrics.images_done += a.req.n;
                metrics.latencies.push(a.submitted.elapsed());
                let _ = a.tx.send(Response {
                    id: a.req.id,
                    images,
                    n: a.req.n,
                    latency: a.submitted.elapsed(),
                    evals: a.evals,
                });
            } else {
                i += 1;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::manifest::Manifest;
    use crate::model::ParamStore;
    use crate::runtime::Engine;
    use std::path::PathBuf;

    fn setup() -> Option<(Arc<Denoiser>, ModelInfo, Arc<Vec<f32>>)> {
        let d = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if !d.join("manifest.json").exists() {
            eprintln!("skipping: artifacts not built");
            return None;
        }
        let m = Manifest::load(&d).unwrap();
        let info = m.model("ddim16").unwrap().clone();
        let engine = Arc::new(Engine::new(&d).unwrap());
        let den = Arc::new(Denoiser::new(engine, &info).unwrap());
        let params = Arc::new(ParamStore::load_init(&info, &d).unwrap().flat);
        Some((den, info, params))
    }

    #[test]
    fn serves_concurrent_fp_requests() {
        let Some((den, info, params)) = setup() else { return };
        let sched = Schedule::linear(100);
        let handle = spawn(
            den,
            info,
            sched,
            params,
            ServerCfg { mode: ServeMode::Fp, decode_latents: false, seed: 1 },
        );
        let rx1 = handle.submit(Request::new(0, 3, 4));
        let rx2 = handle.submit(Request::new(0, 2, 4));
        let rx3 = handle.submit(Request::new(0, 1, 6)); // different step count
        let r1 = rx1.recv().unwrap();
        let r2 = rx2.recv().unwrap();
        let r3 = rx3.recv().unwrap();
        assert_eq!(r1.n, 3);
        assert_eq!(r2.images.len(), 2 * 16 * 16 * 3);
        assert_eq!(r3.evals, 6);
        assert!(r1.images.iter().all(|v| v.is_finite()));
        let m = handle.shutdown();
        assert_eq!(m.images_done, 6);
        assert!(m.evals > 0);
        // same-steps requests must have shared batches at least once
        assert!(m.mean_batch() > 1.0, "no batching happened: {}", m.report());
    }
}
