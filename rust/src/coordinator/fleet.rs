//! Fleet-scale sharded serving: N coordinator shards behind a
//! deterministic consistent-hash router, with fleet-consistent drift
//! detection and recalibration.
//!
//! The [`Fleet`] owns:
//!
//!  * **Routing** — requests (and externally fed calibration
//!    observations) are assigned to shards by [`route`]: a pure
//!    `mix64(id ^ salt) % shards` over fleet-assigned global ids. No
//!    shared state, no rebalancing races — the same id lands on the same
//!    shard for the fleet's lifetime, and a request's output bits depend
//!    only on its own seed/steps, never on which shard served it.
//!  * **Window aggregation** — each shard probes/observes into its own
//!    [`SketchSet`] window (`ServerCfg::probe_sketches`). At an
//!    aggregation boundary the fleet harvests every shard at a round
//!    boundary (`ServerHandle::harvest` joins in-flight work and drains
//!    the prober first), layout-validates each window (a bad shard is
//!    skipped, warned about and counted — never fatal, the
//!    `SketchSet::merge` hardening), and merges them with
//!    [`SketchSet::merge_canonical`] — the partition-invariant merge, so
//!    a 2-shard and a 4-shard fleet over the same observation multiset
//!    produce byte-identical merged windows.
//!  * **Fleet-consistent recalibration** — drift scoring + planning run
//!    **once** on the merged window against the fleet-owned
//!    [`QuantSession`] baseline. A non-empty plan is materialized into
//!    one [`FleetSwap`] (base qparams + every ladder rung re-searched on
//!    the same updated calibration) and broadcast to every shard, which
//!    applies it in its arrival drain strictly between rounds — the
//!    `Msg::Reconfigure` delivery discipline — so the whole fleet
//!    hot-swaps to the same qparams at the same logical (epoch) boundary.
//!  * **Fleet observability** — per-shard [`Metrics`] merge into one
//!    fleet-wide view (`Metrics::merge`), telemetry series export as one
//!    shard-tagged `metrics.jsonl` (`obs::fleet_jsonl`), and the
//!    [`FleetSnapshot`] (per-shard + merged snapshots, aggregation
//!    counters, the broadcast plan's layers and swap epoch) lands next to
//!    the merged sketch window in the fleet state dir, with a
//!    Prometheus-style exposition.
//!
//! Why merging beats per-shard detection: a drifted layer's evidence is
//! split across shards, so any single shard may sit below the planner's
//! `min_samples` trust gate while the fleet-merged window clears it. The
//! integration suite pins exactly this — no solo shard window plans a
//! swap, the merged window does — alongside the headline invariant that
//! 2-shard and 4-shard fleets produce identical merged windows, drift
//! scores, broadcast plans and per-request image bits.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use anyhow::{ensure, Result};

use crate::model::manifest::ModelInfo;
use crate::obs::{fleet_jsonl, FleetSnapshot, ObsCfg, ShardSeries};
use crate::quant::msfp::{QuantOpts, StateDir};
use crate::quant::session::QuantSession;
use crate::recal::{DriftScore, RecalPlanner, SketchSet};
use crate::runtime::{Denoiser, QuantState};
use crate::schedule::Schedule;
use crate::util::rng::mix64;

use super::exec::{Backend, FaultPlan};
use super::metrics::Metrics;
use super::request::{Request, ResponseRx};
use super::server::{spawn, FleetSwap, ServeMode, ServerCfg, ServerHandle, SloCfg};

/// The consistent-hash router: shard index for an id, pure in
/// `(id, salt, shards)`. The splitmix64 finalizer ([`mix64`]) whitens
/// sequential ids into a uniform 64-bit space before the modulo, so
/// contiguous id ranges spread evenly across shards.
pub fn route(id: u64, salt: u64, shards: usize) -> usize {
    (mix64(id ^ salt) % shards.max(1) as u64) as usize
}

/// Fleet configuration: the shared model/quant state every shard serves,
/// the fleet-owned recalibration session, and the per-shard serving
/// knobs. Every shard gets the same `seed` — per-timestep selections are
/// derived from `(seed, t)`, and image bits from per-request seeds, so
/// identical seeds are what make a request's output independent of its
/// shard assignment.
pub struct FleetCfg {
    /// shard count (min 1)
    pub shards: usize,
    /// router salt mixed into every id hash ([`route`])
    pub salt: u64,
    /// the quantized state every shard starts serving
    pub state: QuantState,
    /// the session the serving qparams were searched on — the fleet owns
    /// the drift baseline; shards never run local checks
    pub session: QuantSession<'static>,
    /// knobs matching the original search
    pub opts: QuantOpts,
    /// drift thresholds, applied once per aggregation to the merged window
    pub planner: RecalPlanner,
    /// per-shard sketch window shape: timestep buckets per layer
    pub n_buckets: usize,
    /// per-shard sketch window shape: reservoir capacity per
    /// (layer, bucket). Size it to hold a full aggregation window's worth
    /// of samples per shard — lossless shard windows are what make the
    /// canonical merge partition-invariant
    pub sketch_cap: usize,
    /// per-shard scheduler seed (identical across shards by design)
    pub seed: u64,
    /// worker threads per shard (0 = available parallelism)
    pub workers: usize,
    /// shadow-prober budget per shard per round (0 = external feeding only)
    pub probe_budget: usize,
    /// admission control + degradation, replicated to every shard; the
    /// ladder's (wbits, abits) targets are also what fleet swaps re-search
    pub slo: SloCfg,
    /// decode latents to pixels before responding
    pub decode_latents: bool,
    /// quantized-batch execution backend, replicated to every shard
    pub backend: Backend,
    /// per-shard observability (replicated); fleet-scope artifacts are
    /// governed by `state_dir` below
    pub obs: ObsCfg,
    /// fleet state dir: on shutdown the merged sketch window, the
    /// [`FleetSnapshot`] (JSON + Prometheus exposition) and the
    /// shard-tagged telemetry `metrics.jsonl` land here
    pub state_dir: Option<StateDir>,
}

impl FleetCfg {
    /// Defaults mirroring `ServerCfg::new`: salt 0, seed 0, auto workers,
    /// probing off, 4 timestep buckets with a 1024-sample reservoir per
    /// (layer, bucket), default planner, no SLO policy, no persistence.
    pub fn new(
        shards: usize,
        state: QuantState,
        session: QuantSession<'static>,
        opts: QuantOpts,
    ) -> FleetCfg {
        FleetCfg {
            shards: shards.max(1),
            salt: 0,
            state,
            session,
            opts,
            planner: RecalPlanner::default(),
            n_buckets: 4,
            sketch_cap: 1024,
            seed: 0,
            workers: 0,
            probe_budget: 0,
            slo: SloCfg::default(),
            decode_latents: false,
            backend: Backend::Graph,
            obs: ObsCfg::default(),
            state_dir: None,
        }
    }
}

/// One aggregation boundary's product: the fleet-merged window, the
/// drift scores computed on it, and the broadcast plan (if any layer
/// crossed the threshold).
#[derive(Debug, Clone)]
pub struct FleetAggregate {
    /// aggregation epoch index (0-based)
    pub epoch: u64,
    /// the canonical fleet-merged window the scores were computed on
    pub window: SketchSet,
    /// (layer, bucket) positions merged through the order-dependent
    /// fallback because an input sketch had already overflowed its
    /// reservoir (0 = fully partition-invariant merge)
    pub lossy_positions: usize,
    /// shard windows skipped this epoch (harvest failure, decode failure
    /// or sketch-layout mismatch) — aggregation proceeds without them
    pub skipped_windows: usize,
    /// every layer's drift score against the fleet baseline
    pub scores: Vec<DriftScore>,
    /// the plan broadcast to every shard, when drift crossed the
    /// threshold (`None` = nothing drifted, nothing swapped)
    pub swap: Option<FleetSwap>,
}

/// What `Fleet::shutdown` returns: per-shard metrics, the fleet-merged
/// metrics, and the structured fleet snapshot (also persisted to the
/// fleet state dir when one is configured).
#[derive(Debug)]
pub struct FleetReport {
    /// per-shard serving metrics, indexed by shard id
    pub per_shard: Vec<Metrics>,
    /// the fleet-wide merge: summed counters, canonically merged series
    pub merged: Metrics,
    pub snapshot: FleetSnapshot,
}

/// N coordinator shards behind the consistent-hash router (see the
/// module docs for the full contract).
pub struct Fleet {
    shards: Vec<ServerHandle>,
    /// each shard's live sketch window (shared with its shadow prober)
    windows: Vec<Arc<Mutex<SketchSet>>>,
    session: QuantSession<'static>,
    opts: QuantOpts,
    planner: RecalPlanner,
    /// (wbits, abits) of each ladder rung, in ladder order — what fleet
    /// swaps re-search alongside the base
    rung_bits: Vec<(i32, i32)>,
    salt: u64,
    /// fleet-global request/observation id source. Shard-local ids are
    /// reassigned at submission; routing happens on *these* ids, before
    /// any shard sees the request
    next_id: AtomicU64,
    /// zero-sample reference carrying the fleet's expected window layout,
    /// so one bad shard can never poison the layout check for the rest
    layout: SketchSet,
    epochs: u64,
    checks: u64,
    merges: u64,
    skipped_windows: u64,
    lossy_positions: u64,
    swap_epoch: Option<u64>,
    plan_layers: Vec<u64>,
    last_window: Option<SketchSet>,
    series: Vec<ShardSeries>,
    state_dir: Option<StateDir>,
}

impl Fleet {
    /// Spawn `cfg.shards` coordinator shards. Every shard serves a clone
    /// of the same quantized state with the same scheduler seed and
    /// probes into its own sketch window; the fleet keeps the session,
    /// planner and router state.
    pub fn spawn(
        den: Arc<Denoiser>,
        info: ModelInfo,
        sched: Schedule,
        params: Arc<Vec<f32>>,
        cfg: FleetCfg,
    ) -> Fleet {
        let FleetCfg {
            shards,
            salt,
            state,
            session,
            opts,
            planner,
            n_buckets,
            sketch_cap,
            seed,
            workers,
            probe_budget,
            slo,
            decode_latents,
            backend,
            obs,
            state_dir,
        } = cfg;
        let n_layers = session.calib().len();
        let t_total = sched.t_total;
        let layout = SketchSet::new(n_layers, n_buckets, 1, t_total, 0);
        let rung_bits: Vec<(i32, i32)> =
            slo.ladder.iter().map(|r| (r.wbits, r.abits)).collect();
        let mut handles = Vec::with_capacity(shards.max(1));
        let mut windows = Vec::with_capacity(shards.max(1));
        for shard in 0..shards.max(1) {
            // per-shard reservoir seeds may differ freely: the canonical
            // merge rebuilds lossless positions from the sample union with
            // its own fixed seed, so shard seeds never reach the merged
            // window's bytes
            let window = Arc::new(Mutex::new(SketchSet::new(
                n_layers,
                n_buckets,
                sketch_cap,
                t_total,
                0x5EED ^ shard as u64,
            )));
            windows.push(Arc::clone(&window));
            handles.push(spawn(
                Arc::clone(&den),
                info.clone(),
                sched.clone(),
                Arc::clone(&params),
                ServerCfg {
                    mode: ServeMode::Quant(state.clone()),
                    decode_latents,
                    seed,
                    workers,
                    fp_mixed_t: true,
                    recal: None,
                    probe_budget,
                    probe_sketches: Some(window),
                    slo: slo.clone(),
                    faults: FaultPlan::default(),
                    backend,
                    obs: obs.clone(),
                },
            ));
        }
        Fleet {
            shards: handles,
            windows,
            session,
            opts,
            planner,
            rung_bits,
            salt,
            next_id: AtomicU64::new(0),
            layout,
            epochs: 0,
            checks: 0,
            merges: 0,
            skipped_windows: 0,
            lossy_positions: 0,
            swap_epoch: None,
            plan_layers: Vec::new(),
            last_window: None,
            series: Vec::new(),
            state_dir,
        }
    }

    pub fn n_shards(&self) -> usize {
        self.shards.len()
    }

    /// Shard index for a fleet-global id ([`route`] with this fleet's
    /// salt and shard count).
    pub fn route_id(&self, id: u64) -> usize {
        route(id, self.salt, self.shards.len())
    }

    /// A shard's live sketch window. External producers (a fine-tune
    /// loop, a monitoring sidecar) feed through this exactly as they
    /// would feed a single server's `ServeRecal::sketches` handle.
    pub fn shard_window(&self, shard: usize) -> Arc<Mutex<SketchSet>> {
        Arc::clone(&self.windows[shard])
    }

    /// Submit a group of requests atomically per shard: the fleet assigns
    /// each request a global id, routes it, and forwards each shard's
    /// group in one `submit_many` (so co-routed requests join the same
    /// scheduling round). Receivers come back in the input order.
    pub fn submit_many(&self, reqs: Vec<Request>) -> Result<Vec<ResponseRx>> {
        let n = self.shards.len();
        let mut groups: Vec<Vec<Request>> = vec![Vec::new(); n];
        // (shard, index within the shard's group) per input position
        let mut slots = Vec::with_capacity(reqs.len());
        for req in reqs {
            let id = self.next_id.fetch_add(1, Ordering::SeqCst);
            let shard = route(id, self.salt, n);
            slots.push((shard, groups[shard].len()));
            groups[shard].push(req);
        }
        let mut per_shard: Vec<Vec<Option<ResponseRx>>> = Vec::with_capacity(n);
        for (shard, group) in groups.into_iter().enumerate() {
            if group.is_empty() {
                per_shard.push(Vec::new());
                continue;
            }
            let rxs = self.shards[shard].submit_many(group)?;
            per_shard.push(rxs.into_iter().map(Some).collect());
        }
        Ok(slots
            .into_iter()
            .map(|(shard, i)| per_shard[shard][i].take().expect("one receiver per slot"))
            .collect())
    }

    /// Feed one calibration observation into the window of the shard the
    /// router assigns `id` — the same consistent hash requests take, so a
    /// deterministic observation stream partitions deterministically for
    /// any shard count (and, being a partition of the same multiset,
    /// merges back canonically at the next aggregation).
    pub fn observe(&self, id: u64, layer: usize, t: f32, samples: &[f32]) {
        let shard = route(id, self.salt, self.shards.len());
        self.windows[shard].lock().unwrap().observe(layer, t, samples);
    }

    /// Widen a layer's exact extrema on the window `id` routes to (the
    /// full-tensor min/max companion to subsampled [`Fleet::observe`]
    /// feeds). Extrema widening is idempotent and merge-exact, so feeding
    /// it to one routed shard is enough.
    pub fn widen_layer(&self, id: u64, layer: usize, t: f32, min: f32, max: f32) {
        let shard = route(id, self.salt, self.shards.len());
        self.windows[shard].lock().unwrap().widen_layer(layer, t, min, max);
    }

    /// One aggregation boundary: harvest every shard at a round boundary,
    /// canonically merge the usable windows, score drift + plan **once**
    /// on the merged window, and broadcast a non-empty plan to every
    /// shard for a round-atomic hot-swap. A shard whose window fails to
    /// decode or whose layout mismatches is skipped (warned + counted) —
    /// the fleet keeps aggregating the shards that agree. Errors only
    /// when *no* shard produced a usable window.
    pub fn aggregate(&mut self) -> Result<FleetAggregate> {
        let epoch = self.epochs;
        self.epochs += 1;
        let mut windows: Vec<SketchSet> = Vec::new();
        let mut series: Vec<ShardSeries> = Vec::new();
        let mut skipped = 0usize;
        for (i, h) in self.shards.iter().enumerate() {
            match h.harvest() {
                Ok(hv) => {
                    series.push(ShardSeries {
                        shard: i as u64,
                        rows: hv.rows,
                        timers: hv.timers,
                    });
                    let decoded = SketchSet::from_bytes(&hv.window)
                        .and_then(|w| self.layout.check_layout(&w).map(|()| w));
                    match decoded {
                        Ok(w) => windows.push(w),
                        Err(err) => {
                            skipped += 1;
                            crate::log_warn!(
                                "fleet epoch {epoch}: skipping shard {i}'s window: {err:#}"
                            );
                        }
                    }
                }
                Err(err) => {
                    skipped += 1;
                    crate::log_warn!("fleet epoch {epoch}: shard {i} harvest failed: {err:#}");
                }
            }
        }
        self.skipped_windows += skipped as u64;
        ensure!(
            !windows.is_empty(),
            "fleet epoch {epoch}: no usable shard window to aggregate \
             ({skipped} skipped of {} shards)",
            self.shards.len()
        );
        let refs: Vec<&SketchSet> = windows.iter().collect();
        let merged = SketchSet::merge_canonical(&refs)?;
        self.merges += 1;
        self.lossy_positions += merged.lossy_positions as u64;
        if merged.lossy_positions > 0 {
            crate::log_warn!(
                "fleet epoch {epoch}: {} sketch position(s) merged lossily — shard \
                 windows overflowed their reservoirs; merged bytes are still \
                 deterministic but no longer partition-invariant",
                merged.lossy_positions
            );
        }
        // drift scoring + planning run exactly once, on the merged window
        // against the fleet-owned baseline
        let check = self.checks;
        self.checks += 1;
        let plan = self.planner.plan(self.session.calib(), &merged.window);
        let scores = plan.scores;
        let swap = if plan.layers.is_empty() {
            None
        } else {
            let layers: Vec<(u32, f32)> =
                plan.layers.iter().map(|rl| (rl.layer as u32, rl.score)).collect();
            for rl in plan.layers {
                self.session.update_layer_calib(rl.layer, rl.calib);
            }
            let qparams = self.session.quantize(&self.opts).qparams_rows();
            let rung_qparams = self
                .rung_bits
                .iter()
                .map(|&(w, a)| (w, a, self.session.degraded_qparams(&self.opts, w, a)))
                .collect();
            Some(FleetSwap { check, qparams, rung_qparams, layers })
        };
        if let Some(sw) = &swap {
            // one plan, every shard: delivery is channel-ordered with
            // submissions, so each shard applies it strictly between
            // rounds and before anything submitted after this call
            for (i, h) in self.shards.iter().enumerate() {
                if let Err(err) = h.apply_qparams(sw.clone()) {
                    crate::log_warn!("fleet epoch {epoch}: shard {i} missed the swap: {err:#}");
                }
            }
            if self.swap_epoch.is_none() {
                self.swap_epoch = Some(epoch);
            }
            for &(l, _) in &sw.layers {
                self.plan_layers.push(l as u64);
            }
            crate::log_info!(
                "fleet epoch {epoch}: broadcast recal plan ({} layer(s)) to {} shard(s)",
                sw.layers.len(),
                self.shards.len()
            );
        }
        self.series = series;
        self.last_window = Some(merged.window.clone());
        Ok(FleetAggregate {
            epoch,
            window: merged.window,
            lossy_positions: merged.lossy_positions,
            skipped_windows: skipped,
            scores,
            swap,
        })
    }

    /// Stop every shard (after their in-flight requests finish), merge
    /// the per-shard metrics into the fleet view, and persist the fleet
    /// artifacts (merged window, snapshot JSON, Prometheus exposition,
    /// shard-tagged telemetry) into the fleet state dir when configured.
    pub fn shutdown(mut self) -> FleetReport {
        // refresh each shard's telemetry series at a final round boundary
        // (best-effort: a dead shard keeps its last harvested series)
        let mut series = std::mem::take(&mut self.series);
        for (i, h) in self.shards.iter().enumerate() {
            if let Ok(hv) = h.harvest() {
                let s = ShardSeries { shard: i as u64, rows: hv.rows, timers: hv.timers };
                match series.iter_mut().find(|e| e.shard == i as u64) {
                    Some(slot) => *slot = s,
                    None => series.push(s),
                }
            }
        }
        series.sort_by_key(|s| s.shard);
        let per_shard: Vec<Metrics> =
            std::mem::take(&mut self.shards).into_iter().map(|h| h.shutdown()).collect();
        let mut merged = Metrics::default();
        for m in &per_shard {
            merged.merge(m);
        }
        let snapshot = FleetSnapshot {
            shards: per_shard
                .iter()
                .enumerate()
                .map(|(i, m)| (i as u64, m.snapshot()))
                .collect(),
            merged: merged.snapshot(),
            merges: self.merges,
            skipped_windows: self.skipped_windows,
            lossy_positions: self.lossy_positions,
            plan_layers: self.plan_layers.clone(),
            swap_epoch: self.swap_epoch,
        };
        if let Some(sd) = &self.state_dir {
            use crate::util::io::atomic_write;
            let write = |path: std::path::PathBuf, bytes: &[u8], what: &str| {
                if let Err(err) = atomic_write(&path, bytes) {
                    crate::log_warn!("could not persist fleet {what}: {err:#}");
                }
            };
            if let Some(w) = &self.last_window {
                write(sd.sketch_path(), &w.to_bytes(), "merged window");
            }
            write(
                sd.telemetry_path(),
                fleet_jsonl(&series).as_bytes(),
                "telemetry series",
            );
            write(
                sd.root().join("fleet.json"),
                snapshot.to_json().to_string().as_bytes(),
                "snapshot",
            );
            write(
                sd.root().join("fleet.prom"),
                snapshot.prometheus().as_bytes(),
                "prometheus exposition",
            );
        }
        FleetReport { per_shard, merged, snapshot }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn router_is_pure_covers_all_shards_and_balances() {
        // purity + full coverage for every shard count up to 8
        for n in 1..=8usize {
            let mut hit = vec![0usize; n];
            for id in 0..256u64 {
                let s = route(id, 7, n);
                assert_eq!(s, route(id, 7, n), "router must be pure");
                assert!(s < n);
                hit[s] += 1;
            }
            assert!(
                hit.iter().all(|&c| c > 0),
                "some shard of {n} never hit: {hit:?}"
            );
        }
        // the salt actually perturbs the assignment
        let moved = (0..256u64).filter(|&id| route(id, 0, 4) != route(id, 99, 4)).count();
        assert!(moved > 64, "salt barely moved the routing: {moved}/256");
        // single-shard fleets route everything to shard 0
        assert!((0..64).all(|id| route(id, 3, 1) == 0));
    }

    #[test]
    fn routed_observation_slices_stay_disjoint_and_complete() {
        // the property the canonical merge leans on: routing partitions
        // an id range — every id lands on exactly one shard, and the
        // union of the slices is the full range
        let ids: Vec<u64> = (0..300).collect();
        for n in [2usize, 4] {
            let mut seen = vec![Vec::new(); n];
            for &id in &ids {
                seen[route(id, 0, n)].push(id);
            }
            let mut all: Vec<u64> = seen.concat();
            all.sort_unstable();
            assert_eq!(all, ids);
        }
    }
}
