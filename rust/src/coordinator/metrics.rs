//! Serving metrics: latency percentiles, throughput, batching efficiency,
//! and the round-execution vs scheduling-overhead split of the parallel
//! round executor.

use std::time::Duration;

#[derive(Debug, Default, Clone)]
pub struct Metrics {
    pub latencies: Vec<Duration>,
    pub images_done: usize,
    pub evals: usize,
    pub batch_sizes: Vec<usize>,
    pub batch_fills: Vec<f32>,
    pub wall: Duration,
    /// scheduling rounds executed
    pub rounds: usize,
    /// time inside the round executor (model evals, fan-out to scatter)
    pub round_exec: Duration,
    /// scheduler-side overhead: planning, gather, scatter, observe
    pub round_sched: Duration,
    /// per-timestep selection cache outcomes (quant serving)
    pub sel_hits: u64,
    pub sel_misses: u64,
    /// background drift checks launched (online recalibration)
    pub recal_checks: usize,
    /// qparams hot-swaps applied at round boundaries
    pub recal_swaps: usize,
    /// drifted layers recalibrated across all swaps
    pub recal_layers: usize,
    /// scheduling round at which the first hot-swap landed (None = never)
    pub first_swap_round: Option<usize>,
    /// shadow-prober calib forwards submitted (self-calibrating serving)
    pub probes: usize,
    /// probe candidates dropped by the per-round budget gate
    pub probes_skipped: usize,
    /// probe forwards that failed or panicked (their slot is skipped, the
    /// feed order is preserved)
    pub probes_failed: usize,
}

impl Metrics {
    /// Lower (floor-index) latency percentile, q in [0, 1]: the sorted
    /// element at index `floor((len-1) * q)`. For p95 over 10 samples this
    /// is the 9th element, one below the nearest-rank definition.
    pub fn latency_p(&self, q: f64) -> Duration {
        if self.latencies.is_empty() {
            return Duration::ZERO;
        }
        let mut v = self.latencies.clone();
        v.sort();
        v[((v.len() - 1) as f64 * q) as usize]
    }

    /// images per second over the measured wall time
    pub fn throughput(&self) -> f64 {
        if self.wall.is_zero() {
            return 0.0;
        }
        self.images_done as f64 / self.wall.as_secs_f64()
    }

    pub fn mean_batch(&self) -> f64 {
        if self.batch_sizes.is_empty() {
            return 0.0;
        }
        self.batch_sizes.iter().sum::<usize>() as f64 / self.batch_sizes.len() as f64
    }

    pub fn mean_fill(&self) -> f64 {
        if self.batch_fills.is_empty() {
            return 0.0;
        }
        self.batch_fills.iter().map(|f| *f as f64).sum::<f64>() / self.batch_fills.len() as f64
    }

    /// Fraction of round wall time spent executing batches (vs scheduler
    /// overhead). 0.0 when nothing has been measured.
    pub fn exec_fraction(&self) -> f64 {
        let total = self.round_exec + self.round_sched;
        if total.is_zero() {
            return 0.0;
        }
        self.round_exec.as_secs_f64() / total.as_secs_f64()
    }

    /// Selection-cache hit rate over the serve lifetime (quant mode).
    pub fn sel_hit_rate(&self) -> f64 {
        let total = self.sel_hits + self.sel_misses;
        if total == 0 {
            return 0.0;
        }
        self.sel_hits as f64 / total as f64
    }

    pub fn report(&self) -> String {
        format!(
            "requests {:4}  images {:5}  evals {:6}  rounds {:5}  thpt {:7.2} img/s  p50 {:6.1} ms  p95 {:6.1} ms  mean-batch {:4.1}  fill {:4.0}%  exec {:6.1} ms / sched {:6.1} ms ({:3.0}% exec)  sel-hit {:3.0}%  recal {}/{} swaps ({} layers)  probes {} ({} skipped, {} failed)",
            self.latencies.len(),
            self.images_done,
            self.evals,
            self.rounds,
            self.throughput(),
            self.latency_p(0.5).as_secs_f64() * 1e3,
            self.latency_p(0.95).as_secs_f64() * 1e3,
            self.mean_batch(),
            self.mean_fill() * 100.0,
            self.round_exec.as_secs_f64() * 1e3,
            self.round_sched.as_secs_f64() * 1e3,
            self.exec_fraction() * 100.0,
            self.sel_hit_rate() * 100.0,
            self.recal_swaps,
            self.recal_checks,
            self.recal_layers,
            self.probes,
            self.probes_skipped,
            self.probes_failed
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentiles() {
        let mut m = Metrics::default();
        for ms in [10u64, 20, 30, 40, 50, 60, 70, 80, 90, 100] {
            m.latencies.push(Duration::from_millis(ms));
        }
        assert_eq!(m.latency_p(0.5), Duration::from_millis(50));
        assert_eq!(m.latency_p(0.0), Duration::from_millis(10));
        assert_eq!(m.latency_p(1.0), Duration::from_millis(100));
        assert_eq!(m.latency_p(0.95), Duration::from_millis(90));
    }

    #[test]
    fn percentiles_odd_count_and_unsorted_input() {
        let mut m = Metrics::default();
        // insertion order must not matter
        for ms in [70u64, 10, 50, 90, 30] {
            m.latencies.push(Duration::from_millis(ms));
        }
        assert_eq!(m.latency_p(0.5), Duration::from_millis(50));
        assert_eq!(m.latency_p(0.25), Duration::from_millis(30));
        assert_eq!(m.latency_p(0.95), Duration::from_millis(70));
        assert_eq!(m.latency_p(1.0), Duration::from_millis(90));
    }

    #[test]
    fn percentiles_single_element() {
        let m = Metrics {
            latencies: vec![Duration::from_millis(42)],
            ..Default::default()
        };
        for q in [0.0, 0.5, 0.95, 1.0] {
            assert_eq!(m.latency_p(q), Duration::from_millis(42));
        }
    }

    #[test]
    fn throughput_math() {
        let m = Metrics { images_done: 50, wall: Duration::from_secs(5), ..Default::default() };
        assert!((m.throughput() - 10.0).abs() < 1e-9);
    }

    #[test]
    fn exec_sched_split() {
        let m = Metrics {
            round_exec: Duration::from_millis(300),
            round_sched: Duration::from_millis(100),
            ..Default::default()
        };
        assert!((m.exec_fraction() - 0.75).abs() < 1e-9);
    }

    #[test]
    fn sel_hit_rate_math() {
        let m = Metrics { sel_hits: 9, sel_misses: 1, ..Default::default() };
        assert!((m.sel_hit_rate() - 0.9).abs() < 1e-12);
    }

    #[test]
    fn mean_fill_math() {
        let m = Metrics { batch_fills: vec![1.0, 0.5, 0.75], ..Default::default() };
        assert!((m.mean_fill() - 0.75).abs() < 1e-9);
    }

    #[test]
    fn empty_metrics_safe() {
        let m = Metrics::default();
        assert_eq!(m.latency_p(0.5), Duration::ZERO);
        assert_eq!(m.throughput(), 0.0);
        assert_eq!(m.mean_batch(), 0.0);
        assert_eq!(m.exec_fraction(), 0.0);
        assert_eq!(m.sel_hit_rate(), 0.0);
        assert_eq!((m.recal_checks, m.recal_swaps, m.recal_layers), (0, 0, 0));
        let _ = m.report();
    }

    #[test]
    fn recal_counters_render_in_report() {
        let m = Metrics {
            recal_checks: 5,
            recal_swaps: 2,
            recal_layers: 7,
            ..Default::default()
        };
        let r = m.report();
        assert!(r.contains("recal 2/5 swaps (7 layers)"), "{r}");
    }

    #[test]
    fn probe_counters_render_and_default_clean() {
        let m = Metrics::default();
        assert_eq!((m.probes, m.probes_skipped, m.probes_failed), (0, 0, 0));
        assert_eq!(m.first_swap_round, None);
        let m = Metrics {
            probes: 12,
            probes_skipped: 3,
            probes_failed: 1,
            first_swap_round: Some(4),
            ..Default::default()
        };
        let r = m.report();
        assert!(r.contains("probes 12 (3 skipped, 1 failed)"), "{r}");
    }
}
