//! Serving metrics: latency percentiles, throughput, batching efficiency,
//! and the round-execution vs scheduling-overhead split of the parallel
//! round executor.
//!
//! The raw sample series live here; [`Metrics::snapshot`] condenses them
//! into the structured, serializable `obs::MetricsSnapshot`, and the
//! human-oriented [`Metrics::report`] string is a renderer over that
//! snapshot (the exact pre-snapshot format, pinned by the tests below).

use std::time::Duration;

use super::request::SloClass;
use crate::obs::{MetricsSnapshot, SwapAudit};

/// Clamp a requested percentile into [0, 1]: NaN maps to 0 (the lowest
/// sample), anything outside the range saturates to the nearest end.
/// Percentile requests reach here from user-facing report knobs, so an
/// out-of-range q must degrade to an end sample, never index out of
/// bounds.
fn clamp_q(q: f64) -> f64 {
    if q.is_nan() {
        0.0
    } else {
        q.clamp(0.0, 1.0)
    }
}

/// Floor-index percentile over an unsorted series, q clamped to [0, 1]
/// (NaN → 0): the sorted element at `floor((len-1) * q)`; 0 on an empty
/// series. The one percentile definition every series in [`Metrics`]
/// uses.
pub(crate) fn percentile_u64(series: &[u64], q: f64) -> u64 {
    if series.is_empty() {
        return 0;
    }
    let mut v = series.to_vec();
    v.sort_unstable();
    v[((v.len() - 1) as f64 * clamp_q(q)) as usize]
}

#[derive(Debug, Default, Clone)]
pub struct Metrics {
    pub latencies: Vec<Duration>,
    pub images_done: usize,
    pub evals: usize,
    pub batch_sizes: Vec<usize>,
    pub batch_fills: Vec<f32>,
    pub wall: Duration,
    /// scheduling rounds executed
    pub rounds: usize,
    /// time inside the round executor (model evals, fan-out to scatter)
    pub round_exec: Duration,
    /// scheduler-side overhead: planning, gather, scatter, observe
    pub round_sched: Duration,
    /// per-timestep selection cache outcomes (quant serving)
    pub sel_hits: u64,
    pub sel_misses: u64,
    /// background drift checks launched (online recalibration)
    pub recal_checks: usize,
    /// qparams hot-swaps applied at round boundaries
    pub recal_swaps: usize,
    /// drifted layers recalibrated across all swaps
    pub recal_layers: usize,
    /// scheduling round at which the first hot-swap landed (None = never)
    pub first_swap_round: Option<usize>,
    /// shadow-prober calib forwards submitted (self-calibrating serving)
    pub probes: usize,
    /// probe candidates dropped by the per-round budget gate
    pub probes_skipped: usize,
    /// probe forwards that failed or panicked (their slot is skipped, the
    /// feed order is preserved)
    pub probes_failed: usize,
    /// per-class queue-wait samples in *rounds* (indexed by
    /// `SloClass::rank()`): rounds a request spent admitted but
    /// unscheduled — deferred past the queue budget or parked by retry
    /// backoff. One sample per retired request (done, shed or cancelled).
    pub queue_waits: [Vec<u64>; 3],
    /// requests shed per class (deadline misses under overload, exhausted
    /// retries), indexed by `SloClass::rank()`
    pub shed: [usize; 3],
    /// overloaded rounds whose interactive tickets served the pre-built
    /// lower-bit variant
    pub downgraded_rounds: usize,
    /// interactive requests admitted with a cut step count under overload
    pub downgraded_steps: usize,
    /// requests retired because the client dropped its receiver
    pub cancelled: usize,
    /// failed-round retry attempts (each backs off exponentially, capped)
    pub retries: usize,
    /// batch faults injected by the server's `FaultPlan`
    pub faults_injected: usize,
    /// engine compile attempts over the serve lifetime (includes retries
    /// of Failed slots, excludes cache hits)
    pub compile_attempts: usize,
    /// loads refused because a Failed slot's retry budget was exhausted
    pub compile_exhausted: usize,
    /// quantized-batch execution backend tag (`Backend::tag()`: "graph" |
    /// "packed"); empty until the scheduler stamps it (reads as "graph")
    pub backend: &'static str,
    /// resident packed weight bytes for the packed backend — the real
    /// memory footprint of the served model's quantized layers (0 on the
    /// graph backend, which keeps f32 weights)
    pub packed_bytes: usize,
    /// checkpoint writes that failed even after the capped retries (the
    /// previous complete snapshot stays on disk — durability degrades,
    /// serving never does)
    pub ckpt_fails: usize,
    /// checkpoint write retries that eventually landed
    pub ckpt_retries: usize,
    /// `Msg::Reconfigure` messages applied at round boundaries
    pub reconfigures: usize,
    /// overloaded rounds served per degradation-ladder rung (index 0 =
    /// mildest); empty when no ladder is configured or no round degraded
    pub rung_rounds: Vec<usize>,
    /// flight-recorder events emitted over the serve lifetime (0 when the
    /// recorder is disabled)
    pub trace_events: usize,
    /// recorder events evicted by the bounded ring
    pub trace_dropped: usize,
    /// postmortem trace/telemetry dumps written (shed storms, injected
    /// faults, recal panics, shutdown)
    pub postmortems: usize,
    /// full audit record of every recal hot-swap, in landing order (also
    /// carried in the trace postmortem)
    pub swap_audits: Vec<SwapAudit>,
}

impl Metrics {
    /// Lower (floor-index) latency percentile, q clamped to [0, 1]
    /// (NaN → 0): the sorted element at index `floor((len-1) * q)`. For
    /// p95 over 10 samples this is the 9th element, one below the
    /// nearest-rank definition.
    pub fn latency_p(&self, q: f64) -> Duration {
        if self.latencies.is_empty() {
            return Duration::ZERO;
        }
        let mut v = self.latencies.clone();
        v.sort();
        v[((v.len() - 1) as f64 * clamp_q(q)) as usize]
    }

    /// Queue-wait percentile in rounds for one SLO class (floor-index,
    /// same definition as [`Metrics::latency_p`]); 0 when the class has
    /// retired no requests.
    pub fn queue_wait_p(&self, class: SloClass, q: f64) -> u64 {
        percentile_u64(&self.queue_waits[class.rank()], q)
    }

    /// total requests shed across all classes
    pub fn shed_total(&self) -> usize {
        self.shed.iter().sum()
    }

    /// images per second over the measured wall time
    pub fn throughput(&self) -> f64 {
        if self.wall.is_zero() {
            return 0.0;
        }
        self.images_done as f64 / self.wall.as_secs_f64()
    }

    pub fn mean_batch(&self) -> f64 {
        if self.batch_sizes.is_empty() {
            return 0.0;
        }
        self.batch_sizes.iter().sum::<usize>() as f64 / self.batch_sizes.len() as f64
    }

    pub fn mean_fill(&self) -> f64 {
        if self.batch_fills.is_empty() {
            return 0.0;
        }
        self.batch_fills.iter().map(|f| *f as f64).sum::<f64>() / self.batch_fills.len() as f64
    }

    /// Fraction of round wall time spent executing batches (vs scheduler
    /// overhead). 0.0 when nothing has been measured.
    pub fn exec_fraction(&self) -> f64 {
        let total = self.round_exec + self.round_sched;
        if total.is_zero() {
            return 0.0;
        }
        self.round_exec.as_secs_f64() / total.as_secs_f64()
    }

    /// Selection-cache hit rate over the serve lifetime (quant mode).
    pub fn sel_hit_rate(&self) -> f64 {
        let total = self.sel_hits + self.sel_misses;
        if total == 0 {
            return 0.0;
        }
        self.sel_hits as f64 / total as f64
    }

    /// Backend tag for display: "graph" until a scheduler stamps it.
    pub fn backend_tag(&self) -> &'static str {
        if self.backend.is_empty() {
            "graph"
        } else {
            self.backend
        }
    }

    /// Condense the raw series into the structured, serializable
    /// `obs::MetricsSnapshot`: every derived quantity (throughput,
    /// percentiles, fractions) precomputed, per-class wait percentiles
    /// and maxima materialized, counters widened to u64. The snapshot —
    /// not this struct — is the export surface: exact JSON roundtrip and
    /// a Prometheus-style exposition live on it.
    pub fn snapshot(&self) -> MetricsSnapshot {
        let wp = |i: usize, q: f64| percentile_u64(&self.queue_waits[i], q);
        let wmax = |i: usize| self.queue_waits[i].iter().copied().max().unwrap_or(0);
        MetricsSnapshot {
            requests: self.latencies.len() as u64,
            images: self.images_done as u64,
            evals: self.evals as u64,
            rounds: self.rounds as u64,
            backend: self.backend_tag().to_string(),
            packed_bytes: self.packed_bytes as u64,
            wall_s: self.wall.as_secs_f64(),
            throughput: self.throughput(),
            latency_p50_ms: self.latency_p(0.5).as_secs_f64() * 1e3,
            latency_p95_ms: self.latency_p(0.95).as_secs_f64() * 1e3,
            mean_batch: self.mean_batch(),
            mean_fill: self.mean_fill(),
            round_exec_ms: self.round_exec.as_secs_f64() * 1e3,
            round_sched_ms: self.round_sched.as_secs_f64() * 1e3,
            exec_fraction: self.exec_fraction(),
            sel_hits: self.sel_hits,
            sel_misses: self.sel_misses,
            sel_hit_rate: self.sel_hit_rate(),
            recal_checks: self.recal_checks as u64,
            recal_swaps: self.recal_swaps as u64,
            recal_layers: self.recal_layers as u64,
            first_swap_round: self.first_swap_round.map(|r| r as u64),
            probes: self.probes as u64,
            probes_skipped: self.probes_skipped as u64,
            probes_failed: self.probes_failed as u64,
            wait_p50: [wp(0, 0.5), wp(1, 0.5), wp(2, 0.5)],
            wait_p99: [wp(0, 0.99), wp(1, 0.99), wp(2, 0.99)],
            wait_max: [wmax(0), wmax(1), wmax(2)],
            shed: [self.shed[0] as u64, self.shed[1] as u64, self.shed[2] as u64],
            downgraded_rounds: self.downgraded_rounds as u64,
            downgraded_steps: self.downgraded_steps as u64,
            cancelled: self.cancelled as u64,
            retries: self.retries as u64,
            faults_injected: self.faults_injected as u64,
            compile_attempts: self.compile_attempts as u64,
            compile_exhausted: self.compile_exhausted as u64,
            ckpt_fails: self.ckpt_fails as u64,
            ckpt_retries: self.ckpt_retries as u64,
            reconfigures: self.reconfigures as u64,
            rung_rounds: self.rung_rounds.iter().map(|&r| r as u64).collect(),
            trace_events: self.trace_events as u64,
            trace_dropped: self.trace_dropped as u64,
            postmortems: self.postmortems as u64,
        }
    }

    /// Fold another shard's metrics into this one, producing the fleet
    /// view. Counters sum; sample series concatenate and re-sort into a
    /// canonical sorted-multiset form, so the merge is bitwise
    /// commutative *and* associative over any shard grouping (the
    /// fleet-merge laws pinned in props.rs). Wall clock and round count
    /// take the max (shards run concurrently — the fleet is as old as
    /// its oldest shard), `first_swap_round` the earliest Some, and
    /// per-rung round counts add element-wise after widening to the
    /// longer ladder.
    pub fn merge(&mut self, other: &Metrics) {
        self.latencies.extend_from_slice(&other.latencies);
        self.latencies.sort_unstable();
        self.images_done += other.images_done;
        self.evals += other.evals;
        self.batch_sizes.extend_from_slice(&other.batch_sizes);
        self.batch_sizes.sort_unstable();
        self.batch_fills.extend_from_slice(&other.batch_fills);
        self.batch_fills.sort_unstable_by(|a, b| a.total_cmp(b));
        self.wall = self.wall.max(other.wall);
        self.rounds = self.rounds.max(other.rounds);
        self.round_exec += other.round_exec;
        self.round_sched += other.round_sched;
        self.sel_hits += other.sel_hits;
        self.sel_misses += other.sel_misses;
        self.recal_checks += other.recal_checks;
        self.recal_swaps += other.recal_swaps;
        self.recal_layers += other.recal_layers;
        self.first_swap_round = match (self.first_swap_round, other.first_swap_round) {
            (Some(a), Some(b)) => Some(a.min(b)),
            (a, b) => a.or(b),
        };
        self.probes += other.probes;
        self.probes_skipped += other.probes_skipped;
        self.probes_failed += other.probes_failed;
        for (mine, theirs) in self.queue_waits.iter_mut().zip(&other.queue_waits) {
            mine.extend_from_slice(theirs);
            mine.sort_unstable();
        }
        for (mine, theirs) in self.shed.iter_mut().zip(&other.shed) {
            *mine += *theirs;
        }
        self.downgraded_rounds += other.downgraded_rounds;
        self.downgraded_steps += other.downgraded_steps;
        self.cancelled += other.cancelled;
        self.retries += other.retries;
        self.faults_injected += other.faults_injected;
        self.compile_attempts += other.compile_attempts;
        self.compile_exhausted += other.compile_exhausted;
        if self.backend.is_empty() {
            self.backend = other.backend;
        }
        self.packed_bytes += other.packed_bytes;
        self.ckpt_fails += other.ckpt_fails;
        self.ckpt_retries += other.ckpt_retries;
        self.reconfigures += other.reconfigures;
        if self.rung_rounds.len() < other.rung_rounds.len() {
            self.rung_rounds.resize(other.rung_rounds.len(), 0);
        }
        for (mine, theirs) in self.rung_rounds.iter_mut().zip(&other.rung_rounds) {
            *mine += *theirs;
        }
        self.trace_events += other.trace_events;
        self.trace_dropped += other.trace_dropped;
        self.postmortems += other.postmortems;
        self.swap_audits.extend(other.swap_audits.iter().cloned());
        self.swap_audits.sort_by_key(|a| (a.round, a.check, a.old_fp, a.new_fp));
    }

    /// The classic one-line serving report — now a renderer over
    /// [`Metrics::snapshot`] (byte-identical to the pre-snapshot format).
    pub fn report(&self) -> String {
        self.snapshot().render()
    }

    /// SLO / robustness suffix of [`Metrics::report`]: empty when nothing
    /// SLO-related happened (the common quiet path), one line of per-class
    /// queue waits and shed/downgrade/retry/fault counters otherwise.
    pub fn slo_report(&self) -> String {
        self.snapshot().render_slo()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentiles() {
        let mut m = Metrics::default();
        for ms in [10u64, 20, 30, 40, 50, 60, 70, 80, 90, 100] {
            m.latencies.push(Duration::from_millis(ms));
        }
        assert_eq!(m.latency_p(0.5), Duration::from_millis(50));
        assert_eq!(m.latency_p(0.0), Duration::from_millis(10));
        assert_eq!(m.latency_p(1.0), Duration::from_millis(100));
        assert_eq!(m.latency_p(0.95), Duration::from_millis(90));
    }

    #[test]
    fn percentiles_odd_count_and_unsorted_input() {
        let mut m = Metrics::default();
        // insertion order must not matter
        for ms in [70u64, 10, 50, 90, 30] {
            m.latencies.push(Duration::from_millis(ms));
        }
        assert_eq!(m.latency_p(0.5), Duration::from_millis(50));
        assert_eq!(m.latency_p(0.25), Duration::from_millis(30));
        assert_eq!(m.latency_p(0.95), Duration::from_millis(70));
        assert_eq!(m.latency_p(1.0), Duration::from_millis(90));
    }

    #[test]
    fn percentiles_single_element() {
        let m = Metrics {
            latencies: vec![Duration::from_millis(42)],
            ..Default::default()
        };
        for q in [0.0, 0.5, 0.95, 1.0] {
            assert_eq!(m.latency_p(q), Duration::from_millis(42));
        }
    }

    #[test]
    fn percentile_q_out_of_range_clamps_instead_of_panicking() {
        // q > 1 used to index past the end of the sorted series; NaN and
        // negative q now degrade to the lowest sample
        let mut m = Metrics::default();
        for ms in [10u64, 20, 30, 40] {
            m.latencies.push(Duration::from_millis(ms));
        }
        m.queue_waits[SloClass::Batch.rank()].extend([1u64, 2, 3, 4]);
        assert_eq!(m.latency_p(1.5), Duration::from_millis(40));
        assert_eq!(m.latency_p(-0.1), Duration::from_millis(10));
        assert_eq!(m.latency_p(f64::NAN), Duration::from_millis(10));
        assert_eq!(m.queue_wait_p(SloClass::Batch, 1.5), 4);
        assert_eq!(m.queue_wait_p(SloClass::Batch, -0.1), 1);
        assert_eq!(m.queue_wait_p(SloClass::Batch, f64::NAN), 1);
        assert_eq!(percentile_u64(&[], 1.5), 0);
        assert_eq!(percentile_u64(&[7], f64::NAN), 7);
    }

    #[test]
    fn merge_sums_counters_and_canonicalizes_series() {
        let mut a = Metrics {
            images_done: 4,
            evals: 10,
            rounds: 7,
            wall: Duration::from_millis(500),
            sel_hits: 3,
            first_swap_round: Some(5),
            rung_rounds: vec![2],
            backend: "packed",
            packed_bytes: 100,
            ..Default::default()
        };
        a.latencies.push(Duration::from_millis(30));
        a.queue_waits[0].push(4);
        let mut b = Metrics {
            images_done: 6,
            evals: 20,
            rounds: 9,
            wall: Duration::from_millis(400),
            sel_hits: 2,
            first_swap_round: Some(3),
            rung_rounds: vec![1, 5],
            ..Default::default()
        };
        b.latencies.push(Duration::from_millis(10));
        b.queue_waits[0].push(1);

        // commutative: a⊕b == b⊕a field for field
        let mut ab = a.clone();
        ab.merge(&b);
        let mut ba = b.clone();
        ba.merge(&a);
        assert_eq!(ab.images_done, 10);
        assert_eq!(ab.evals, 30);
        assert_eq!(ab.rounds, 9);
        assert_eq!(ab.wall, Duration::from_millis(500));
        assert_eq!(ab.first_swap_round, Some(3));
        assert_eq!(ab.rung_rounds, vec![3, 5]);
        assert_eq!(ab.latencies, vec![Duration::from_millis(10), Duration::from_millis(30)]);
        assert_eq!(ab.queue_waits[0], vec![1, 4]);
        assert_eq!(ab.backend_tag(), "packed");
        assert_eq!(ba.backend_tag(), "packed");
        assert_eq!(ab.packed_bytes, 100);
        assert_eq!(ab.latencies, ba.latencies);
        assert_eq!(ab.images_done, ba.images_done);
        assert_eq!(ab.rung_rounds, ba.rung_rounds);
        assert_eq!(ab.first_swap_round, ba.first_swap_round);
        // the merged snapshot is identical either way
        assert_eq!(ab.snapshot(), ba.snapshot());
    }

    #[test]
    fn throughput_math() {
        let m = Metrics { images_done: 50, wall: Duration::from_secs(5), ..Default::default() };
        assert!((m.throughput() - 10.0).abs() < 1e-9);
    }

    #[test]
    fn exec_sched_split() {
        let m = Metrics {
            round_exec: Duration::from_millis(300),
            round_sched: Duration::from_millis(100),
            ..Default::default()
        };
        assert!((m.exec_fraction() - 0.75).abs() < 1e-9);
    }

    #[test]
    fn sel_hit_rate_math() {
        let m = Metrics { sel_hits: 9, sel_misses: 1, ..Default::default() };
        assert!((m.sel_hit_rate() - 0.9).abs() < 1e-12);
    }

    #[test]
    fn mean_fill_math() {
        let m = Metrics { batch_fills: vec![1.0, 0.5, 0.75], ..Default::default() };
        assert!((m.mean_fill() - 0.75).abs() < 1e-9);
    }

    #[test]
    fn empty_metrics_safe() {
        let m = Metrics::default();
        assert_eq!(m.latency_p(0.5), Duration::ZERO);
        assert_eq!(m.throughput(), 0.0);
        assert_eq!(m.mean_batch(), 0.0);
        assert_eq!(m.exec_fraction(), 0.0);
        assert_eq!(m.sel_hit_rate(), 0.0);
        assert_eq!((m.recal_checks, m.recal_swaps, m.recal_layers), (0, 0, 0));
        let _ = m.report();
    }

    #[test]
    fn recal_counters_render_in_report() {
        let m = Metrics {
            recal_checks: 5,
            recal_swaps: 2,
            recal_layers: 7,
            ..Default::default()
        };
        let r = m.report();
        assert!(r.contains("recal 2/5 swaps (7 layers)"), "{r}");
    }

    #[test]
    fn backend_and_packed_bytes_render_in_report() {
        // default: no scheduler has stamped a backend yet → reads "graph",
        // no packed suffix
        let m = Metrics::default();
        assert_eq!(m.backend_tag(), "graph");
        let r = m.report();
        assert!(r.contains("backend graph"), "{r}");
        assert!(!r.contains("packed"), "{r}");

        let m = Metrics { backend: "packed", packed_bytes: 2048, ..Default::default() };
        assert_eq!(m.backend_tag(), "packed");
        let r = m.report();
        assert!(r.contains("backend packed (2.0 KiB packed)"), "{r}");
    }

    #[test]
    fn queue_wait_percentile_edges() {
        // empty series: every percentile is 0, for every class
        let m = Metrics::default();
        for c in SloClass::ALL {
            for q in [0.0, 0.5, 0.99, 1.0] {
                assert_eq!(m.queue_wait_p(c, q), 0);
            }
        }
        // single sample: every percentile is that sample
        let mut m = Metrics::default();
        m.queue_waits[SloClass::Interactive.rank()].push(7);
        for q in [0.0, 0.5, 0.99, 1.0] {
            assert_eq!(m.queue_wait_p(SloClass::Interactive, q), 7);
        }
        // all-equal samples: percentiles collapse to the common value
        let mut m = Metrics::default();
        m.queue_waits[SloClass::Batch.rank()].extend([4u64; 10]);
        for q in [0.0, 0.5, 0.99, 1.0] {
            assert_eq!(m.queue_wait_p(SloClass::Batch, q), 4);
        }
    }

    #[test]
    fn queue_waits_split_per_class() {
        let mut m = Metrics::default();
        // interactive waits little, best-effort waits long; the splits
        // must not bleed into each other
        m.queue_waits[SloClass::Interactive.rank()].extend([0, 1, 0, 2]);
        m.queue_waits[SloClass::BestEffort.rank()].extend([10, 30, 20]);
        assert_eq!(m.queue_wait_p(SloClass::Interactive, 0.5), 0);
        assert_eq!(m.queue_wait_p(SloClass::Interactive, 1.0), 2);
        assert_eq!(m.queue_wait_p(SloClass::BestEffort, 0.5), 20);
        assert_eq!(m.queue_wait_p(SloClass::BestEffort, 0.99), 30);
        assert_eq!(m.queue_wait_p(SloClass::Batch, 0.5), 0);
    }

    #[test]
    fn slo_report_quiet_by_default_and_renders_counters() {
        let m = Metrics::default();
        assert_eq!(m.slo_report(), "");
        assert!(!m.report().contains("slo:"));

        let mut m = Metrics::default();
        m.queue_waits[SloClass::BestEffort.rank()].extend([3, 5]);
        m.shed[SloClass::BestEffort.rank()] = 2;
        m.downgraded_rounds = 4;
        m.downgraded_steps = 1;
        m.cancelled = 1;
        m.retries = 3;
        m.faults_injected = 2;
        m.compile_attempts = 5;
        m.compile_exhausted = 1;
        assert_eq!(m.shed_total(), 2);
        let r = m.report();
        assert!(r.contains("slo:"), "{r}");
        assert!(r.contains("BestEffort wait p50/p99 3/5 rounds shed 2;"), "{r}");
        assert!(r.contains("downgraded 4 rounds / 1 step-cuts"), "{r}");
        assert!(r.contains("cancelled 1  retries 3  faults 2"), "{r}");
        assert!(r.contains("compile 5 attempts (1 exhausted)"), "{r}");
    }

    #[test]
    fn durability_counters_render_and_stay_quiet_by_default() {
        // a ladder with zero degraded rounds is still the quiet path
        let m = Metrics { rung_rounds: vec![0, 0], ..Default::default() };
        assert_eq!(m.slo_report(), "");

        let m = Metrics {
            ckpt_fails: 1,
            ckpt_retries: 3,
            reconfigures: 2,
            rung_rounds: vec![4, 1],
            ..Default::default()
        };
        let r = m.report();
        assert!(r.contains("ckpt 1 fails / 3 retries"), "{r}");
        assert!(r.contains("reconfigures 2"), "{r}");
        assert!(r.contains("ladder rounds [4, 1]"), "{r}");
    }

    #[test]
    fn snapshot_class_names_match_slo_class_debug() {
        // obs::CLASS_NAMES duplicates the SloClass Debug names so obs has
        // no coordinator dependency; pin them against drift
        for (c, name) in SloClass::ALL.iter().zip(crate::obs::CLASS_NAMES) {
            assert_eq!(format!("{c:?}"), name);
            assert_eq!(c.rank(), crate::obs::CLASS_NAMES.iter().position(|&n| n == name).unwrap());
        }
    }

    #[test]
    fn snapshot_condenses_series_and_roundtrips() {
        let mut m = Metrics {
            images_done: 24,
            evals: 300,
            rounds: 9,
            wall: Duration::from_millis(1500),
            round_exec: Duration::from_millis(300),
            round_sched: Duration::from_millis(100),
            sel_hits: 9,
            sel_misses: 1,
            backend: "packed",
            packed_bytes: 4096,
            first_swap_round: Some(3),
            rung_rounds: vec![2, 1],
            trace_events: 88,
            trace_dropped: 4,
            postmortems: 1,
            ..Default::default()
        };
        for ms in [10u64, 20, 30, 40] {
            m.latencies.push(Duration::from_millis(ms));
        }
        m.queue_waits[SloClass::Batch.rank()].extend([0, 2, 4]);
        m.shed[SloClass::BestEffort.rank()] = 1;
        let snap = m.snapshot();
        assert_eq!(snap.requests, 4);
        assert_eq!(snap.backend, "packed");
        assert!((snap.throughput - m.throughput()).abs() < 1e-12);
        assert!((snap.exec_fraction - 0.75).abs() < 1e-9);
        assert_eq!(snap.wait_p50[SloClass::Batch.rank()], 2);
        assert_eq!(snap.wait_max[SloClass::Batch.rank()], 4);
        assert_eq!(snap.shed, [0, 0, 1]);
        assert_eq!(snap.first_swap_round, Some(3));
        assert_eq!(snap.rung_rounds, vec![2, 1]);
        assert_eq!((snap.trace_events, snap.trace_dropped, snap.postmortems), (88, 4, 1));
        // report stays a renderer over the snapshot
        assert_eq!(m.report(), snap.render());
        assert_eq!(m.slo_report(), snap.render_slo());
        // and the snapshot survives its JSON form exactly
        let text = snap.to_json().to_string();
        let back =
            crate::obs::MetricsSnapshot::from_json(&crate::util::json::Json::parse(&text).unwrap())
                .unwrap();
        assert_eq!(back, snap);
    }

    #[test]
    fn probe_counters_render_and_default_clean() {
        let m = Metrics::default();
        assert_eq!((m.probes, m.probes_skipped, m.probes_failed), (0, 0, 0));
        assert_eq!(m.first_swap_round, None);
        let m = Metrics {
            probes: 12,
            probes_skipped: 3,
            probes_failed: 1,
            first_swap_round: Some(4),
            ..Default::default()
        };
        let r = m.report();
        assert!(r.contains("probes 12 (3 skipped, 1 failed)"), "{r}");
    }
}
