//! Serving metrics: latency percentiles, throughput, batching efficiency.

use std::time::Duration;

#[derive(Debug, Default, Clone)]
pub struct Metrics {
    pub latencies: Vec<Duration>,
    pub images_done: usize,
    pub evals: usize,
    pub batch_sizes: Vec<usize>,
    pub batch_fills: Vec<f32>,
    pub wall: Duration,
}

impl Metrics {
    pub fn latency_p(&self, q: f64) -> Duration {
        if self.latencies.is_empty() {
            return Duration::ZERO;
        }
        let mut v = self.latencies.clone();
        v.sort();
        v[((v.len() - 1) as f64 * q) as usize]
    }

    /// images per second over the measured wall time
    pub fn throughput(&self) -> f64 {
        if self.wall.is_zero() {
            return 0.0;
        }
        self.images_done as f64 / self.wall.as_secs_f64()
    }

    pub fn mean_batch(&self) -> f64 {
        if self.batch_sizes.is_empty() {
            return 0.0;
        }
        self.batch_sizes.iter().sum::<usize>() as f64 / self.batch_sizes.len() as f64
    }

    pub fn mean_fill(&self) -> f64 {
        if self.batch_fills.is_empty() {
            return 0.0;
        }
        self.batch_fills.iter().map(|f| *f as f64).sum::<f64>() / self.batch_fills.len() as f64
    }

    pub fn report(&self) -> String {
        format!(
            "requests {:4}  images {:5}  evals {:6}  thpt {:7.2} img/s  p50 {:6.1} ms  p95 {:6.1} ms  mean-batch {:4.1}  fill {:4.0}%",
            self.latencies.len(),
            self.images_done,
            self.evals,
            self.throughput(),
            self.latency_p(0.5).as_secs_f64() * 1e3,
            self.latency_p(0.95).as_secs_f64() * 1e3,
            self.mean_batch(),
            self.mean_fill() * 100.0
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentiles() {
        let mut m = Metrics::default();
        for ms in [10u64, 20, 30, 40, 50, 60, 70, 80, 90, 100] {
            m.latencies.push(Duration::from_millis(ms));
        }
        assert_eq!(m.latency_p(0.5), Duration::from_millis(50));
        assert_eq!(m.latency_p(0.0), Duration::from_millis(10));
        assert_eq!(m.latency_p(1.0), Duration::from_millis(100));
    }

    #[test]
    fn throughput_math() {
        let m = Metrics { images_done: 50, wall: Duration::from_secs(5), ..Default::default() };
        assert!((m.throughput() - 10.0).abs() < 1e-9);
    }

    #[test]
    fn empty_metrics_safe() {
        let m = Metrics::default();
        assert_eq!(m.latency_p(0.5), Duration::ZERO);
        assert_eq!(m.throughput(), 0.0);
        assert_eq!(m.mean_batch(), 0.0);
        let _ = m.report();
    }
}
