//! The shadow calibration prober: the serving-side sketch *producer*.
//!
//! PR 4's recalibration loop consumed externally fed activation sketches —
//! nothing in-process observed live traffic. The [`ShadowProber`] closes
//! that gap by recycling a budgeted fraction of each scheduling round's
//! request latents (snapshotted post-scatter, before the sampler advances
//! them) into `Denoiser::calib_forward_probe` jobs on the round executor's
//! worker pool, each at its ticket's own timestep, and feeding the
//! resulting per-(layer, timestep-bucket) samples into the round-pinned
//! recalibration sketches. Quantized serving thereby detects its own
//! drift: the activations the denoiser actually sees, per timestep bucket,
//! are exactly what the MSFP search ranges must track.
//!
//! Determinism contract (pinned by `tests/integration.rs`):
//!  * **selection** is a pure function of `(request id, round index)` — a
//!    deterministic per-candidate score, ranked with the id as tie-break —
//!    so neither arrival order nor worker timing changes which latents are
//!    probed;
//!  * **feeding** happens in probe *sequence* order: every probe job posts
//!    its result (or failure) back tagged with its submission sequence
//!    number, and the scheduler drains completions into the sketches
//!    strictly in-order, buffering early arrivals. The reservoir rng thus
//!    sees the same update stream for any worker count, and the final
//!    sketch state is bit-identical between a 1-worker and an N-worker
//!    server.
//!
//! Budgeting: at most `ServerCfg::probe_budget` probe forwards are
//! submitted per round (0 disables probing); candidates beyond the budget
//! are counted as skipped in `Metrics`, so probing never grows faster than
//! one bounded tranche per round and cannot starve round execution.

use std::collections::BTreeMap;
use std::sync::{mpsc, Arc, Mutex};

use crate::obs::{EventKind, FlightRecorder};
use crate::recal::SketchSet;
use crate::runtime::Denoiser;

use super::exec::{PadPool, RoundExecutor};

/// One probe candidate: a request whose latents fully scattered this
/// round. `idx` is its position in the scheduler's active list (used only
/// to fetch the data after selection — never for ranking).
#[derive(Debug, Clone, Copy)]
pub struct ProbeCandidate {
    pub id: u64,
    pub idx: usize,
}

/// Deterministic per-candidate priority: the splitmix64 finalizer
/// ([`crate::util::rng::mix64`]) over the request id xor the rotated round
/// index. Pure, so the ranking is identical for any arrival order or
/// worker count.
pub fn probe_score(id: u64, round: u64) -> u64 {
    crate::util::rng::mix64(id ^ round.rotate_left(32) ^ 0x9E3779B97F4A7C15)
}

/// Rank candidates by [`probe_score`] (id as tie-break) and keep the first
/// `budget`. Returns the selected candidates in rank order. Request ids
/// are server-assigned and unique (`ServerHandle::submit_many` overwrites
/// `Request::id` from a monotonic counter), so the (score, id) key is
/// total and the sort order cannot fall back to input position.
pub fn select_probes(
    cands: &[ProbeCandidate],
    round: u64,
    budget: usize,
) -> Vec<ProbeCandidate> {
    let mut ranked: Vec<(u64, ProbeCandidate)> =
        cands.iter().map(|&c| (probe_score(c.id, round), c)).collect();
    ranked.sort_unstable_by_key(|&(score, c)| (score, c.id));
    ranked.truncate(budget);
    ranked.into_iter().map(|(_, c)| c).collect()
}

/// A completed probe forward, tagged with its submission sequence number.
struct ProbeDone {
    seq: u64,
    t: f32,
    /// None ⇒ the forward failed (still posted so in-order feeding never
    /// stalls behind a lost sequence number)
    capture: Option<(Vec<f32>, Vec<f32>)>,
}

/// Serving-side sketch producer state (owned by the scheduler thread; the
/// probe forwards themselves run on the worker pool).
pub struct ShadowProber {
    budget: usize,
    act_samples: usize,
    sketches: Arc<Mutex<SketchSet>>,
    den: Arc<Denoiser>,
    params: Arc<Vec<f32>>,
    pads: PadPool,
    /// recycled (x, cond) snapshot buffers for probe jobs
    snaps: Arc<Mutex<Vec<(Vec<f32>, Vec<f32>)>>>,
    done_tx: mpsc::Sender<ProbeDone>,
    done_rx: mpsc::Receiver<ProbeDone>,
    /// completions that arrived ahead of their feed turn
    pending: BTreeMap<u64, ProbeDone>,
    next_seq: u64,
    next_feed: u64,
    pub sent: usize,
    pub skipped: usize,
    pub failed: usize,
    /// the coordinator's flight recorder: each probing round emits one
    /// `probe` event (sent/skipped) from the scheduler thread, so the
    /// event is as deterministic as the selection itself
    rec: Option<Arc<FlightRecorder>>,
}

impl ShadowProber {
    pub fn new(
        budget: usize,
        sketches: Arc<Mutex<SketchSet>>,
        den: Arc<Denoiser>,
        params: Arc<Vec<f32>>,
        pads: PadPool,
        rec: Option<Arc<FlightRecorder>>,
    ) -> ShadowProber {
        let act_samples = den.info.act_samples;
        let (done_tx, done_rx) = mpsc::channel();
        ShadowProber {
            budget,
            act_samples,
            sketches,
            den,
            params,
            pads,
            rec,
            snaps: Arc::new(Mutex::new(Vec::new())),
            done_tx,
            done_rx,
            pending: BTreeMap::new(),
            next_seq: 0,
            next_feed: 0,
            sent: 0,
            skipped: 0,
            failed: 0,
        }
    }

    /// Select this round's probes and submit them to the pool. The caller
    /// passes an accessor from candidate index to `(x, t, cond)` — the
    /// request's latents *before* the sampler observes this round's eps,
    /// its current ticket timestep, and its condition vector.
    pub fn round_probes<'d>(
        &mut self,
        exec: &RoundExecutor,
        round: u64,
        cands: &[ProbeCandidate],
        data: impl Fn(usize) -> (&'d [f32], f32, &'d [f32]),
    ) {
        if self.budget == 0 || cands.is_empty() {
            return;
        }
        let picks = select_probes(cands, round, self.budget);
        self.skipped += cands.len() - picks.len();
        if let Some(r) = &self.rec {
            r.emit(
                round,
                EventKind::Probe {
                    sent: picks.len() as u32,
                    skipped: (cands.len() - picks.len()) as u32,
                },
            );
        }
        for c in picks {
            let (x, t, cond) = data(c.idx);
            let (mut xs, mut cs) = self.snaps.lock().unwrap().pop().unwrap_or_default();
            xs.clear();
            xs.extend_from_slice(x);
            cs.clear();
            cs.extend_from_slice(cond);
            let seq = self.next_seq;
            self.next_seq += 1;
            self.sent += 1;
            let den = Arc::clone(&self.den);
            let params = Arc::clone(&self.params);
            let pads = Arc::clone(&self.pads);
            let snaps = Arc::clone(&self.snaps);
            let tx = self.done_tx.clone();
            exec.offload(move || {
                let mut pad = pads.lock().unwrap().pop().unwrap_or_default();
                let n = cs.len();
                let res = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                    den.calib_forward_probe(&params, &xs, n, t, &cs, &mut pad)
                }));
                pads.lock().unwrap().push(pad);
                snaps.lock().unwrap().push((xs, cs));
                let capture = match res {
                    Ok(Ok(c)) => Some(c),
                    Ok(Err(err)) => {
                        crate::log_warn!("shadow probe failed (t={t}): {err:#}");
                        None
                    }
                    Err(_) => {
                        crate::log_warn!("shadow probe panicked (t={t})");
                        None
                    }
                };
                // always post the seq — a lost number would stall feeding
                let _ = tx.send(ProbeDone { seq, t, capture });
            });
        }
    }

    /// Drain completed probes into the sketches, strictly in submission
    /// order (early arrivals are buffered until their turn). Called at
    /// round boundaries and after the final `exec.join()`, which
    /// guarantees every outstanding probe has posted — so the post-drain
    /// sketch state is a pure function of the probe sequence.
    pub fn drain(&mut self) {
        while let Ok(done) = self.done_rx.try_recv() {
            self.pending.insert(done.seq, done);
        }
        while let Some(done) = self.pending.remove(&self.next_feed) {
            self.next_feed += 1;
            match done.capture {
                Some((acts, mm)) => {
                    let mut set = self.sketches.lock().unwrap();
                    set.observe_calib(done.t, &acts, &mm, self.act_samples);
                }
                None => self.failed += 1,
            }
        }
    }

    /// Probes submitted but not yet fed (for tests/metrics sanity).
    pub fn outstanding(&self) -> u64 {
        self.next_seq - self.next_feed
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cands(ids: &[u64]) -> Vec<ProbeCandidate> {
        ids.iter().enumerate().map(|(idx, &id)| ProbeCandidate { id, idx }).collect()
    }

    #[test]
    fn selection_is_arrival_order_invariant() {
        let a = cands(&[3, 9, 4, 11, 7]);
        let mut shuffled = a.clone();
        shuffled.reverse();
        for round in 0..32u64 {
            for budget in 1..=5 {
                let pa: Vec<u64> =
                    select_probes(&a, round, budget).iter().map(|c| c.id).collect();
                let pb: Vec<u64> =
                    select_probes(&shuffled, round, budget).iter().map(|c| c.id).collect();
                assert_eq!(pa, pb, "round {round} budget {budget}");
                assert_eq!(pa.len(), budget.min(a.len()));
            }
        }
    }

    #[test]
    fn selection_rotates_across_rounds() {
        // the score mixes the round in, so a budget-1 prober does not pin
        // the same request forever
        let c = cands(&[1, 2, 3, 4, 5, 6, 7, 8]);
        let picked: std::collections::BTreeSet<u64> =
            (0..64u64).map(|r| select_probes(&c, r, 1)[0].id).collect();
        assert!(picked.len() >= 4, "probe selection stuck on {picked:?}");
    }

    #[test]
    fn selection_budget_zero_and_empty() {
        assert!(select_probes(&cands(&[1, 2]), 0, 0).is_empty());
        assert!(select_probes(&[], 5, 3).is_empty());
    }

    #[test]
    fn probe_score_is_pure_and_spread() {
        assert_eq!(probe_score(42, 7), probe_score(42, 7));
        assert_ne!(probe_score(42, 7), probe_score(42, 8));
        assert_ne!(probe_score(42, 7), probe_score(43, 7));
    }
}
