//! Procedural synthetic corpora.
//!
//! Each corpus is a deterministic generative program with enough structural
//! variation that Frechet-style metrics rank models meaningfully, and
//! distinct low-order statistics per corpus (a quantized model fine-tuned
//! on celeba-syn scores differently than on church-syn). Pixel range is
//! [-1, 1], NHWC.

use crate::util::rng::Rng;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Corpus {
    /// colored Gaussian blobs (CIFAR-10 stand-in, 16x16)
    CifarSyn,
    /// face-like ovals with eyes (CelebA stand-in, 16x16)
    CelebaSyn,
    /// room-like interior: wall/floor split + box (LSUN-Bedroom, 32x32)
    BedroomSyn,
    /// arch/spire vertical structure (LSUN-Church, 32x32)
    ChurchSyn,
    /// 10-class shapes x palettes (ImageNet stand-in, 32x32)
    ImagenetSyn,
}

impl Corpus {
    pub fn parse(name: &str) -> Option<Corpus> {
        Some(match name {
            "cifar-syn" => Corpus::CifarSyn,
            "celeba-syn" => Corpus::CelebaSyn,
            "bedroom-syn" => Corpus::BedroomSyn,
            "church-syn" => Corpus::ChurchSyn,
            "imagenet-syn" => Corpus::ImagenetSyn,
            _ => return None,
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            Corpus::CifarSyn => "cifar-syn",
            Corpus::CelebaSyn => "celeba-syn",
            Corpus::BedroomSyn => "bedroom-syn",
            Corpus::ChurchSyn => "church-syn",
            Corpus::ImagenetSyn => "imagenet-syn",
        }
    }

    pub fn hw(&self) -> usize {
        match self {
            Corpus::CifarSyn | Corpus::CelebaSyn => 16,
            _ => 32,
        }
    }

    pub fn n_classes(&self) -> usize {
        match self {
            Corpus::ImagenetSyn => 10,
            _ => 0,
        }
    }

    /// Which model variant trains on this corpus.
    pub fn model_name(&self) -> &'static str {
        match self {
            Corpus::CifarSyn | Corpus::CelebaSyn => "ddim16",
            Corpus::BedroomSyn | Corpus::ChurchSyn => "ldm8",
            Corpus::ImagenetSyn => "ldm8c",
        }
    }

    pub fn sample(&self, rng: &mut Rng) -> Sample {
        match self {
            Corpus::CifarSyn => cifar_syn(rng),
            Corpus::CelebaSyn => celeba_syn(rng),
            Corpus::BedroomSyn => bedroom_syn(rng),
            Corpus::ChurchSyn => church_syn(rng),
            Corpus::ImagenetSyn => imagenet_syn(rng),
        }
    }

    /// Batch of n samples as stacked NHWC pixels + class labels.
    pub fn batch(&self, rng: &mut Rng, n: usize) -> (Vec<f32>, Vec<f32>) {
        let mut px = Vec::with_capacity(n * self.hw() * self.hw() * 3);
        let mut cls = Vec::with_capacity(n);
        for _ in 0..n {
            let s = self.sample(rng);
            px.extend_from_slice(&s.pixels);
            cls.push(s.class as f32);
        }
        (px, cls)
    }
}

#[derive(Debug, Clone)]
pub struct Sample {
    /// hw*hw*3 NHWC pixels in [-1, 1]
    pub pixels: Vec<f32>,
    pub class: usize,
}

struct Canvas {
    hw: usize,
    px: Vec<f32>,
}

impl Canvas {
    fn new(hw: usize) -> Canvas {
        Canvas { hw, px: vec![0.0; hw * hw * 3] }
    }

    fn fill_gradient(&mut self, top: [f32; 3], bottom: [f32; 3]) {
        let hw = self.hw;
        for y in 0..hw {
            let t = y as f32 / (hw - 1) as f32;
            for x in 0..hw {
                for c in 0..3 {
                    self.px[(y * hw + x) * 3 + c] = top[c] * (1.0 - t) + bottom[c] * t;
                }
            }
        }
    }

    fn blob(&mut self, cx: f32, cy: f32, r: f32, color: [f32; 3], soft: f32) {
        let hw = self.hw;
        for y in 0..hw {
            for x in 0..hw {
                let d2 = ((x as f32 - cx).powi(2) + (y as f32 - cy).powi(2)) / (r * r);
                let w = (-d2 * soft).exp();
                if w > 0.01 {
                    for c in 0..3 {
                        let p = &mut self.px[(y * hw + x) * 3 + c];
                        *p = *p * (1.0 - w) + color[c] * w;
                    }
                }
            }
        }
    }

    fn rect(&mut self, x0: usize, y0: usize, x1: usize, y1: usize, color: [f32; 3]) {
        for y in y0..y1.min(self.hw) {
            for x in x0..x1.min(self.hw) {
                for c in 0..3 {
                    self.px[(y * self.hw + x) * 3 + c] = color[c];
                }
            }
        }
    }

    fn triangle_up(&mut self, cx: f32, base_y: usize, half_w: f32, top_y: usize, color: [f32; 3]) {
        for y in top_y..base_y.min(self.hw) {
            let frac = (y - top_y) as f32 / (base_y - top_y).max(1) as f32;
            let w = half_w * frac;
            let x0 = (cx - w).max(0.0) as usize;
            let x1 = ((cx + w) as usize + 1).min(self.hw);
            for x in x0..x1 {
                for c in 0..3 {
                    self.px[(y * self.hw + x) * 3 + c] = color[c];
                }
            }
        }
    }

    fn noise(&mut self, rng: &mut Rng, amp: f32) {
        for p in &mut self.px {
            *p += rng.normal() * amp;
        }
    }

    fn finish(mut self) -> Vec<f32> {
        for p in &mut self.px {
            *p = p.clamp(-1.0, 1.0);
        }
        self.px
    }
}

fn rand_color(rng: &mut Rng) -> [f32; 3] {
    [rng.range(-0.9, 0.9), rng.range(-0.9, 0.9), rng.range(-0.9, 0.9)]
}

fn cifar_syn(rng: &mut Rng) -> Sample {
    let mut c = Canvas::new(16);
    c.fill_gradient(rand_color(rng), rand_color(rng));
    let n = 2 + rng.below(3);
    for _ in 0..n {
        c.blob(rng.range(2.0, 14.0), rng.range(2.0, 14.0), rng.range(2.0, 5.0), rand_color(rng), 1.0);
    }
    c.noise(rng, 0.08);
    Sample { pixels: c.finish(), class: 0 }
}

fn celeba_syn(rng: &mut Rng) -> Sample {
    let mut c = Canvas::new(16);
    c.fill_gradient(rand_color(rng), rand_color(rng));
    // skin-tone face oval
    let skin = [rng.range(0.3, 0.8), rng.range(0.0, 0.4), rng.range(-0.3, 0.1)];
    let cx = rng.range(6.5, 9.5);
    let cy = rng.range(6.5, 9.5);
    c.blob(cx, cy, rng.range(4.5, 6.0), skin, 1.2);
    // eyes
    let dy = rng.range(-1.5, -0.5);
    let dx = rng.range(1.5, 2.5);
    let eye = [-0.8, -0.8, rng.range(-0.8, 0.0)];
    c.blob(cx - dx, cy + dy, 0.9, eye, 3.0);
    c.blob(cx + dx, cy + dy, 0.9, eye, 3.0);
    // mouth
    c.blob(cx, cy + rng.range(2.0, 3.0), 1.1, [-0.5, -0.7, -0.7], 2.5);
    c.noise(rng, 0.05);
    Sample { pixels: c.finish(), class: 0 }
}

fn bedroom_syn(rng: &mut Rng) -> Sample {
    let mut c = Canvas::new(32);
    let wall = rand_color(rng);
    let floor = [wall[0] * 0.5 - 0.2, wall[1] * 0.5 - 0.2, wall[2] * 0.5 - 0.2];
    c.fill_gradient(wall, wall);
    let horizon = 16 + rng.below(8);
    c.rect(0, horizon, 32, 32, floor);
    // bed: box with headboard
    let bx = rng.below(12);
    let bw = 12 + rng.below(10);
    let by = horizon - 2 - rng.below(4);
    let bed = rand_color(rng);
    c.rect(bx, by, bx + bw, (by + 10).min(32), bed);
    c.rect(bx, by.saturating_sub(4), bx + 2, by, [bed[0] * 0.6, bed[1] * 0.6, bed[2] * 0.6]);
    // window
    let wx = rng.below(20);
    c.rect(wx, 2, wx + 6, 8, [0.7, 0.8, 0.9]);
    c.noise(rng, 0.06);
    Sample { pixels: c.finish(), class: 0 }
}

fn church_syn(rng: &mut Rng) -> Sample {
    let mut c = Canvas::new(32);
    // sky gradient
    c.fill_gradient([rng.range(-0.2, 0.4), rng.range(0.2, 0.7), rng.range(0.6, 0.95)],
                    [rng.range(0.3, 0.7); 3]);
    let stone = [rng.range(-0.3, 0.3); 3];
    // main body
    let bx = 8 + rng.below(6);
    let bw = 10 + rng.below(8);
    c.rect(bx, 16, bx + bw, 32, stone);
    // spire
    let scx = (bx + bw / 2) as f32 + rng.range(-2.0, 2.0);
    c.triangle_up(scx, 17, rng.range(3.0, 5.0), 2 + rng.below(5), stone);
    // arch door
    let dx = bx + bw / 2;
    c.rect(dx.saturating_sub(2), 25, dx + 2, 32, [-0.7, -0.7, -0.6]);
    c.noise(rng, 0.06);
    Sample { pixels: c.finish(), class: 0 }
}

/// 10 classes: shape family (blob / rect / triangle / ring / stripes) x 2
/// palettes — class-conditional structure the IS-syn metric can detect.
fn imagenet_syn(rng: &mut Rng) -> Sample {
    let class = rng.below(10);
    let shape = class % 5;
    let warm = class / 5 == 0;
    let mut c = Canvas::new(32);
    let bg = if warm { [0.3, 0.0, -0.3] } else { [-0.3, 0.0, 0.3] };
    c.fill_gradient([bg[0] + rng.range(-0.2, 0.2), bg[1], bg[2]], bg);
    let fg = if warm {
        [rng.range(0.5, 0.95), rng.range(0.0, 0.5), rng.range(-0.8, -0.3)]
    } else {
        [rng.range(-0.8, -0.3), rng.range(0.0, 0.5), rng.range(0.5, 0.95)]
    };
    match shape {
        0 => c.blob(rng.range(12.0, 20.0), rng.range(12.0, 20.0), rng.range(6.0, 9.0), fg, 1.2),
        1 => {
            let x0 = 6 + rng.below(8);
            let y0 = 6 + rng.below(8);
            c.rect(x0, y0, x0 + 12, y0 + 12, fg);
        }
        2 => c.triangle_up(16.0 + rng.range(-3.0, 3.0), 28, 10.0, 4 + rng.below(6), fg),
        3 => {
            // ring: blob minus inner blob
            let cx = rng.range(13.0, 19.0);
            let cy = rng.range(13.0, 19.0);
            c.blob(cx, cy, 8.0, fg, 1.5);
            c.blob(cx, cy, 4.0, bg, 2.0);
        }
        _ => {
            for i in 0..4 {
                c.rect(0, 4 + i * 8, 32, 8 + i * 8, if i % 2 == 0 { fg } else { bg });
            }
        }
    }
    c.noise(rng, 0.05);
    Sample { pixels: c.finish(), class }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_corpora_generate_valid_samples() {
        let mut rng = Rng::new(1);
        for corpus in [Corpus::CifarSyn, Corpus::CelebaSyn, Corpus::BedroomSyn,
                       Corpus::ChurchSyn, Corpus::ImagenetSyn] {
            let s = corpus.sample(&mut rng);
            assert_eq!(s.pixels.len(), corpus.hw() * corpus.hw() * 3);
            assert!(s.pixels.iter().all(|v| (-1.0..=1.0).contains(v)));
            assert!(s.class < corpus.n_classes().max(1));
        }
    }

    #[test]
    fn deterministic_given_seed() {
        let a = Corpus::CelebaSyn.sample(&mut Rng::new(7)).pixels;
        let b = Corpus::CelebaSyn.sample(&mut Rng::new(7)).pixels;
        assert_eq!(a, b);
    }

    #[test]
    fn samples_vary() {
        let mut rng = Rng::new(2);
        let a = Corpus::ChurchSyn.sample(&mut rng).pixels;
        let b = Corpus::ChurchSyn.sample(&mut rng).pixels;
        assert_ne!(a, b);
    }

    #[test]
    fn batch_shapes() {
        let mut rng = Rng::new(3);
        let (px, cls) = Corpus::ImagenetSyn.batch(&mut rng, 5);
        assert_eq!(px.len(), 5 * 32 * 32 * 3);
        assert_eq!(cls.len(), 5);
        assert!(cls.iter().all(|&c| c >= 0.0 && c < 10.0));
    }

    #[test]
    fn imagenet_classes_cover() {
        let mut rng = Rng::new(4);
        let mut seen = [false; 10];
        for _ in 0..300 {
            seen[Corpus::ImagenetSyn.sample(&mut rng).class] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn corpora_statistically_distinct() {
        // mean pixel stats must differ across corpora (FID-syn relies on it)
        let mut rng = Rng::new(5);
        let mut mean = |c: Corpus| {
            let (px, _) = c.batch(&mut rng, 64);
            px.iter().sum::<f32>() / px.len() as f32
        };
        let mc = mean(Corpus::ChurchSyn);
        let mb = mean(Corpus::BedroomSyn);
        assert!((mc - mb).abs() > 0.01, "church={mc} bedroom={mb}");
    }

    #[test]
    fn parse_roundtrip() {
        for c in [Corpus::CifarSyn, Corpus::CelebaSyn, Corpus::BedroomSyn,
                  Corpus::ChurchSyn, Corpus::ImagenetSyn] {
            assert_eq!(Corpus::parse(c.name()), Some(c));
        }
        assert_eq!(Corpus::parse("nope"), None);
    }
}
