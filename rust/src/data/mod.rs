//! Synthetic data substrate (DESIGN.md §2): procedural stand-ins for
//! CIFAR-10 / CelebA / LSUN-Bedroom / LSUN-Church / ImageNet, plus the
//! fixed orthogonal patch autoencoder that provides the latent space for
//! the LDM variants.

pub mod synth;
pub mod latent;

pub use latent::PatchAutoencoder;
pub use synth::{Corpus, Sample};
