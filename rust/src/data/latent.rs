//! Fixed orthogonal patch autoencoder — the LDM latent-space stand-in.
//!
//! 32x32x3 images are split into 4x4 patches (48 dims) and projected onto 4
//! fixed orthonormal directions (seeded Gram-Schmidt), giving an 8x8x4
//! latent. Orthonormality makes decode(encode(x)) the best rank-4
//! projection of each patch — deterministic, invertible-on-range, and
//! training-free, which keeps the substitution honest: all learning happens
//! in the latent UNet, as in LDM.

use crate::util::rng::Rng;

pub const PATCH: usize = 4;
pub const IMG_HW: usize = 32;
pub const LAT_HW: usize = IMG_HW / PATCH; // 8
pub const PATCH_DIM: usize = PATCH * PATCH * 3; // 48
pub const LAT_CH: usize = 4;
/// latent scale: patch energy concentrates in few directions; scale to
/// roughly unit variance for the diffusion prior.
const SCALE: f32 = 0.55;

#[derive(Debug, Clone)]
pub struct PatchAutoencoder {
    /// [PATCH_DIM, LAT_CH] orthonormal columns
    basis: Vec<f32>,
}

impl Default for PatchAutoencoder {
    fn default() -> Self {
        Self::new(911)
    }
}

impl PatchAutoencoder {
    pub fn new(seed: u64) -> PatchAutoencoder {
        let mut rng = Rng::new(seed);
        // Structured low-frequency basis (a 4-component DCT-like dictionary:
        // luminance DC, horizontal + vertical luminance gradients, chroma
        // R-B DC), orthonormalized by Gram-Schmidt with a whisper of seeded
        // noise to break exact ties. Rank-4 random projections lose most
        // image structure; these four carry the bulk of smooth-image energy.
        let mut cols: Vec<Vec<f32>> = Vec::new();
        let comp = |f: &dyn Fn(usize, usize, usize) -> f32| -> Vec<f32> {
            let mut v = vec![0.0f32; PATCH_DIM];
            for dy in 0..PATCH {
                for dx in 0..PATCH {
                    for ch in 0..3 {
                        v[(dy * PATCH + dx) * 3 + ch] = f(dy, dx, ch);
                    }
                }
            }
            v
        };
        cols.push(comp(&|_, _, _| 1.0)); // luminance DC
        cols.push(comp(&|_, dx, _| dx as f32 - (PATCH - 1) as f32 / 2.0)); // horiz grad
        cols.push(comp(&|dy, _, _| dy as f32 - (PATCH - 1) as f32 / 2.0)); // vert grad
        cols.push(comp(&|_, _, ch| match ch {
            0 => 1.0,
            2 => -1.0,
            _ => 0.0,
        })); // chroma R-B
        for col in &mut cols {
            for v in col.iter_mut() {
                *v += rng.normal() * 1e-3;
            }
        }
        for i in 0..LAT_CH {
            for j in 0..i {
                let d: f32 = cols[i].iter().zip(&cols[j]).map(|(a, b)| a * b).sum();
                let cj = cols[j].clone();
                for (a, b) in cols[i].iter_mut().zip(cj) {
                    *a -= d * b;
                }
            }
            let n: f32 = cols[i].iter().map(|v| v * v).sum::<f32>().sqrt();
            for v in &mut cols[i] {
                *v /= n;
            }
        }
        let mut basis = vec![0.0f32; PATCH_DIM * LAT_CH];
        for (j, col) in cols.iter().enumerate() {
            for (i, &v) in col.iter().enumerate() {
                basis[i * LAT_CH + j] = v;
            }
        }
        PatchAutoencoder { basis }
    }

    /// 32x32x3 NHWC pixels -> 8x8x4 latent.
    pub fn encode(&self, img: &[f32]) -> Vec<f32> {
        assert_eq!(img.len(), IMG_HW * IMG_HW * 3);
        let mut z = vec![0.0f32; LAT_HW * LAT_HW * LAT_CH];
        for py in 0..LAT_HW {
            for px in 0..LAT_HW {
                for c in 0..LAT_CH {
                    let mut acc = 0.0f32;
                    for dy in 0..PATCH {
                        for dx in 0..PATCH {
                            let y = py * PATCH + dy;
                            let x = px * PATCH + dx;
                            for ch in 0..3 {
                                let pi = (dy * PATCH + dx) * 3 + ch;
                                acc += img[(y * IMG_HW + x) * 3 + ch]
                                    * self.basis[pi * LAT_CH + c];
                            }
                        }
                    }
                    z[(py * LAT_HW + px) * LAT_CH + c] = acc * SCALE;
                }
            }
        }
        z
    }

    /// 8x8x4 latent -> 32x32x3 pixels (transpose projection).
    pub fn decode(&self, z: &[f32]) -> Vec<f32> {
        assert_eq!(z.len(), LAT_HW * LAT_HW * LAT_CH);
        let mut img = vec![0.0f32; IMG_HW * IMG_HW * 3];
        for py in 0..LAT_HW {
            for px in 0..LAT_HW {
                for dy in 0..PATCH {
                    for dx in 0..PATCH {
                        let y = py * PATCH + dy;
                        let x = px * PATCH + dx;
                        for ch in 0..3 {
                            let pi = (dy * PATCH + dx) * 3 + ch;
                            let mut acc = 0.0f32;
                            for c in 0..LAT_CH {
                                acc += z[(py * LAT_HW + px) * LAT_CH + c]
                                    * self.basis[pi * LAT_CH + c];
                            }
                            img[(y * IMG_HW + x) * 3 + ch] = (acc / SCALE).clamp(-1.0, 1.0);
                        }
                    }
                }
            }
        }
        img
    }

    pub fn encode_batch(&self, imgs: &[f32], n: usize) -> Vec<f32> {
        let per = IMG_HW * IMG_HW * 3;
        let mut out = Vec::with_capacity(n * LAT_HW * LAT_HW * LAT_CH);
        for i in 0..n {
            out.extend(self.encode(&imgs[i * per..(i + 1) * per]));
        }
        out
    }

    pub fn decode_batch(&self, zs: &[f32], n: usize) -> Vec<f32> {
        let per = LAT_HW * LAT_HW * LAT_CH;
        let mut out = Vec::with_capacity(n * IMG_HW * IMG_HW * 3);
        for i in 0..n {
            out.extend(self.decode(&zs[i * per..(i + 1) * per]));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::Corpus;

    #[test]
    fn basis_is_orthonormal() {
        let ae = PatchAutoencoder::default();
        for a in 0..LAT_CH {
            for b in 0..LAT_CH {
                let dot: f32 = (0..PATCH_DIM)
                    .map(|i| ae.basis[i * LAT_CH + a] * ae.basis[i * LAT_CH + b])
                    .sum();
                let want = if a == b { 1.0 } else { 0.0 };
                assert!((dot - want).abs() < 1e-4, "({a},{b}) dot={dot}");
            }
        }
    }

    #[test]
    fn decode_encode_is_projection() {
        // encode∘decode must be identity on the latent space
        let ae = PatchAutoencoder::default();
        let mut rng = Rng::new(1);
        let z: Vec<f32> = (0..LAT_HW * LAT_HW * LAT_CH).map(|_| rng.normal() * 0.3).collect();
        let z2 = ae.encode(&ae.decode(&z));
        for (a, b) in z.iter().zip(&z2) {
            assert!((a - b).abs() < 0.05, "{a} vs {b}"); // clamp can nibble
        }
    }

    #[test]
    fn encode_decode_preserves_structure() {
        // the low-frequency content of real corpus images must survive
        let ae = PatchAutoencoder::default();
        let mut rng = Rng::new(2);
        let s = Corpus::BedroomSyn.sample(&mut rng);
        let rec = ae.decode(&ae.encode(&s.pixels));
        // correlation between original and reconstruction
        let mx = s.pixels.iter().sum::<f32>() / s.pixels.len() as f32;
        let my = rec.iter().sum::<f32>() / rec.len() as f32;
        let mut num = 0.0;
        let mut dx = 0.0;
        let mut dy = 0.0;
        for (a, b) in s.pixels.iter().zip(&rec) {
            num += (a - mx) * (b - my);
            dx += (a - mx).powi(2);
            dy += (b - my).powi(2);
        }
        let corr = num / (dx.sqrt() * dy.sqrt()).max(1e-9);
        assert!(corr > 0.7, "reconstruction correlation {corr}");
    }

    #[test]
    fn latent_roughly_unit_scale() {
        let ae = PatchAutoencoder::default();
        let mut rng = Rng::new(3);
        let (px, _) = Corpus::ChurchSyn.batch(&mut rng, 32);
        let z = ae.encode_batch(&px, 32);
        let var = z.iter().map(|v| v * v).sum::<f32>() / z.len() as f32;
        assert!(var > 0.05 && var < 5.0, "latent var {var}");
    }

    #[test]
    fn batch_roundtrip_shapes() {
        let ae = PatchAutoencoder::default();
        let mut rng = Rng::new(4);
        let (px, _) = Corpus::ImagenetSyn.batch(&mut rng, 3);
        let z = ae.encode_batch(&px, 3);
        assert_eq!(z.len(), 3 * 8 * 8 * 4);
        let rec = ae.decode_batch(&z, 3);
        assert_eq!(rec.len(), px.len());
    }
}
