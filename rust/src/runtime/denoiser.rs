//! High-level denoiser façade over the compiled artifacts.
//!
//! Handles batch-size-class selection (artifacts are compiled for fixed
//! batch sizes; requests are padded up to the smallest fitting class and
//! outputs truncated), input marshalling per the manifest ABI, and the
//! quantized path's router-driven LoRA selection.

use std::sync::{Arc, Mutex};

use anyhow::{bail, Result};

use crate::lora::hub::AllocStrategy;
use crate::lora::Router;
use crate::model::manifest::ModelInfo;
use crate::util::rng::Rng;
use crate::util::threadpool::resolve_threads;

use super::client::{Engine, Executable};
use super::native::{qparams_fingerprint, PackedForward};

/// Everything the quantized graphs need beyond the FP params.
#[derive(Debug, Clone)]
pub struct QuantState {
    /// qparams[L, 8] rows (from quant::msfp::QuantScheme::qparams_rows)
    pub qparams: Vec<f32>,
    /// flat LoRA hub
    pub lora: Vec<f32>,
    /// trained router weights (selection mirror)
    pub router: Router,
    /// active-hub mask (h=2 masks slots 2,3 of the H=4 hub)
    pub hub_mask: Vec<f32>,
    /// allocation strategy (Learned = use the router)
    pub strategy: AllocStrategy,
    /// total schedule steps (for the fixed strategies' t split)
    pub t_total: usize,
}

impl QuantState {
    /// Selection matrix [L, H] for timestep t.
    pub fn selection(&self, t: f32, rng: &mut Rng) -> Vec<f32> {
        match self.strategy.fixed_slot(t as usize, self.t_total, rng) {
            Some(slot) => {
                crate::lora::hub::uniform_selection(self.router.n_layers, self.router.h, slot)
                    .expect("slot in range")
            }
            None => self.router.selection_onehot(t, &self.hub_mask),
        }
    }

    /// Serialized checkpoint bytes (exactly what [`QuantState::save`]
    /// writes) — the serving checkpoint path writes these through the
    /// fault-aware capped-retry writer instead of a one-shot save.
    pub fn to_bytes(&self) -> Vec<u8> {
        let mut s = crate::util::io::Store::new();
        s.put("qparams", self.qparams.clone());
        s.put("lora", self.lora.clone());
        s.put("router", self.router.flat.clone());
        s.put("hub_mask", self.hub_mask.clone());
        s.put("t_total", vec![self.t_total as f32]);
        s.to_bytes()
    }

    /// Persist a quantized model (qparams + LoRA hub + router + mask) so
    /// serving can start without re-running the search/fine-tune.
    pub fn save(&self, path: &std::path::Path) -> Result<()> {
        crate::util::io::atomic_write(path, &self.to_bytes())
    }

    /// Load a quantized model saved by [`QuantState::save`]. The allocation
    /// strategy is Learned (fixed strategies are experiment-only).
    pub fn load(info: &ModelInfo, path: &std::path::Path) -> Result<QuantState> {
        let s = crate::util::io::Store::load(path)?;
        let router = Router::new(info, s.get("router")?.to_vec())?;
        let qparams = s.get("qparams")?.to_vec();
        if qparams.len() != info.n_layers * 8 {
            bail!("qparams len {} != L*8", qparams.len());
        }
        let lora = s.get("lora")?.to_vec();
        if lora.len() != info.lora_size {
            bail!("lora len {} != lora_size {}", lora.len(), info.lora_size);
        }
        let hub_mask = s.get("hub_mask")?.to_vec();
        if hub_mask.len() != info.cfg.lora_hub {
            // a truncated mask would silently corrupt router selections at
            // serve time (selection_onehot indexes mask[0..H])
            bail!("hub_mask len {} != lora_hub {}", hub_mask.len(), info.cfg.lora_hub);
        }
        Ok(QuantState {
            qparams,
            lora,
            router,
            hub_mask,
            strategy: AllocStrategy::Learned,
            t_total: s.get("t_total")?[0] as usize,
        })
    }
}

/// Caller-owned marshalling scratch for the `eps_*_into` entry points: the
/// pad-to-batch-class staging buffers. The serving round executor keeps one
/// per worker so per-round allocations stop scaling with batch count.
#[derive(Debug, Default)]
pub struct EpsScratch {
    xp: Vec<f32>,
    tp: Vec<f32>,
    cp: Vec<f32>,
}

/// Pad `n` stacked samples up to batch class `b` into `buf` by repeating
/// the last sample (capacity is reused across calls).
fn pad_into(buf: &mut Vec<f32>, src: &[f32], n: usize, b: usize) {
    debug_assert!(n >= 1, "pad_into requires a non-empty batch");
    let per = src.len() / n;
    buf.clear();
    buf.reserve(b * per);
    buf.extend_from_slice(src);
    for _ in n..b {
        buf.extend_from_within((n - 1) * per..n * per); // repeat last
    }
}

pub struct Denoiser {
    pub info: ModelInfo,
    engine: Arc<Engine>,
    /// (batch class, artifact file) — compiled lazily through the engine
    /// cache (XLA-compiling an unused batch class costs ~30 s, so eager
    /// loading is a tax on every pipeline stage)
    fp_files: Vec<(usize, String)>,
    q_files: Vec<(usize, String)>,
    calib_file: String,
    /// Packed-backend cache: the native forward built for the current
    /// qparams (recal hot-swaps change the fingerprint and force a
    /// rebuild on the next packed eval).
    packed: Mutex<Option<Arc<PackedForward>>>,
}

impl Denoiser {
    pub fn new(engine: Arc<Engine>, info: &ModelInfo) -> Result<Denoiser> {
        let mut fp_files = Vec::new();
        for &b in &info.batches_fp {
            fp_files.push((b, info.artifact(&format!("fp_b{b}"))?.to_string()));
        }
        let mut q_files = Vec::new();
        for &b in &info.batches_q {
            q_files.push((b, info.artifact(&format!("q_b{b}"))?.to_string()));
        }
        let calib_file = info.artifact(&format!("calib_b{}", info.calib_b))?.to_string();
        Ok(Denoiser {
            info: info.clone(),
            engine,
            fp_files,
            q_files,
            calib_file,
            packed: Mutex::new(None),
        })
    }

    pub fn engine(&self) -> &Arc<Engine> {
        &self.engine
    }

    /// Largest compiled quantized batch class.
    pub fn max_batch_q(&self) -> usize {
        self.q_files.iter().map(|(b, _)| *b).max().unwrap_or(1)
    }

    /// Compiled quantized batch classes (ascending).
    pub fn batch_classes_q(&self) -> Vec<usize> {
        let mut v: Vec<usize> = self.q_files.iter().map(|(b, _)| *b).collect();
        v.sort_unstable();
        v
    }

    fn pick(&self, classes: &[(usize, String)], n: usize) -> Result<(usize, Arc<Executable>)> {
        let (b, file) = classes
            .iter()
            .filter(|(b, _)| *b >= n)
            .min_by_key(|(b, _)| *b)
            .ok_or_else(|| anyhow::anyhow!("no compiled batch class >= {n}"))?;
        Ok((*b, self.engine.load(file)?))
    }

    fn x_dims(&self, b: usize) -> [i64; 4] {
        let hw = self.info.cfg.img_hw as i64;
        [b as i64, hw, hw, self.info.cfg.in_ch as i64]
    }

    /// Full-precision eps_theta. x is n stacked samples; t/cond length n.
    pub fn eps_fp(&self, params: &[f32], x: &[f32], t: &[f32], cond: &[f32]) -> Result<Vec<f32>> {
        let mut s = EpsScratch::default();
        let mut out = Vec::new();
        self.eps_fp_into(params, x, t, cond, &mut s, &mut out)?;
        Ok(out)
    }

    /// [`Denoiser::eps_fp`] with caller-owned pad scratch and output buffer
    /// (the serving round executor reuses both across rounds).
    pub fn eps_fp_into(
        &self,
        params: &[f32],
        x: &[f32],
        t: &[f32],
        cond: &[f32],
        s: &mut EpsScratch,
        out: &mut Vec<f32>,
    ) -> Result<()> {
        let n = t.len();
        if n == 0 {
            bail!("eps_fp called with an empty batch (t is empty)");
        }
        if x.len() != self.info.x_size(n) {
            bail!("x len {} != expected {}", x.len(), self.info.x_size(n));
        }
        let (b, exe) = self.pick(&self.fp_files, n)?;
        pad_into(&mut s.xp, x, n, b);
        pad_into(&mut s.tp, t, n, b);
        pad_into(&mut s.cp, cond, n, b);
        self.run_fp(params, n, b, &exe, s, out)
    }

    /// [`Denoiser::eps_fp_into`] for a same-t batch: t is marshalled
    /// straight into the pad scratch. Convenience API only — the serving
    /// executor routes every FP batch (same-t or mixed-t) through
    /// [`Denoiser::eps_fp_into`]; the `into_variants` test pins both
    /// marshalling paths bit-identical on uniform-t inputs.
    pub fn eps_fp_uniform_into(
        &self,
        params: &[f32],
        x: &[f32],
        t: f32,
        cond: &[f32],
        s: &mut EpsScratch,
        out: &mut Vec<f32>,
    ) -> Result<()> {
        let n = cond.len();
        if n == 0 {
            bail!("eps_fp called with an empty batch (cond is empty)");
        }
        if x.len() != self.info.x_size(n) {
            bail!("x len {} != expected {}", x.len(), self.info.x_size(n));
        }
        let (b, exe) = self.pick(&self.fp_files, n)?;
        pad_into(&mut s.xp, x, n, b);
        s.tp.clear();
        s.tp.resize(b, t);
        pad_into(&mut s.cp, cond, n, b);
        self.run_fp(params, n, b, &exe, s, out)
    }

    /// Shared tail of the FP eps paths: execute on the padded scratch and
    /// truncate the result into `out`.
    fn run_fp(
        &self,
        params: &[f32],
        n: usize,
        b: usize,
        exe: &Executable,
        s: &EpsScratch,
        out: &mut Vec<f32>,
    ) -> Result<()> {
        let dims = self.x_dims(b);
        let res = exe.run(&[
            (params, &[params.len() as i64]),
            (&s.xp, &dims),
            (&s.tp, &[b as i64]),
            (&s.cp, &[b as i64]),
        ])?;
        let eps = res.into_iter().next().unwrap();
        out.clear();
        out.extend_from_slice(&eps[..self.info.x_size(n)]);
        Ok(())
    }

    /// Quantized eps_theta. The whole batch shares timestep `t` (the
    /// TALoRA router picks one adapter per layer per timestep).
    pub fn eps_q(
        &self,
        params: &[f32],
        qs: &QuantState,
        x: &[f32],
        t: f32,
        cond: &[f32],
        rng: &mut Rng,
    ) -> Result<Vec<f32>> {
        let sel = qs.selection(t, rng);
        self.eps_q_with_sel(params, qs, &sel, x, t, cond)
    }

    /// Quantized eps with an explicit selection matrix (serving hot path
    /// precomputes selections per step).
    pub fn eps_q_with_sel(
        &self,
        params: &[f32],
        qs: &QuantState,
        sel: &[f32],
        x: &[f32],
        t: f32,
        cond: &[f32],
    ) -> Result<Vec<f32>> {
        let mut s = EpsScratch::default();
        let mut out = Vec::new();
        self.eps_q_with_sel_into(params, qs, sel, x, t, cond, &mut s, &mut out)?;
        Ok(out)
    }

    /// [`Denoiser::eps_q_with_sel`] with caller-owned pad scratch and output
    /// buffer (the serving round executor reuses both across rounds).
    #[allow(clippy::too_many_arguments)]
    pub fn eps_q_with_sel_into(
        &self,
        params: &[f32],
        qs: &QuantState,
        sel: &[f32],
        x: &[f32],
        t: f32,
        cond: &[f32],
        s: &mut EpsScratch,
        out: &mut Vec<f32>,
    ) -> Result<()> {
        let n = cond.len();
        if n == 0 {
            bail!("eps_q/eps_q_with_sel called with an empty batch (cond is empty)");
        }
        if x.len() != self.info.x_size(n) {
            bail!("x len {} != expected {}", x.len(), self.info.x_size(n));
        }
        let (b, exe) = self.pick(&self.q_files, n)?;
        pad_into(&mut s.xp, x, n, b);
        s.tp.clear();
        s.tp.resize(b, t);
        pad_into(&mut s.cp, cond, n, b);
        let dims = self.x_dims(b);
        let l = self.info.n_layers as i64;
        let h = self.info.cfg.lora_hub as i64;
        let res = exe.run(&[
            (params, &[params.len() as i64]),
            (&qs.qparams, &[l, 8]),
            (&qs.lora, &[qs.lora.len() as i64]),
            (sel, &[l, h]),
            (&s.xp, &dims),
            (&s.tp, &[b as i64]),
            (&s.cp, &[b as i64]),
        ])?;
        let eps = res.into_iter().next().unwrap();
        out.clear();
        out.extend_from_slice(&eps[..self.info.x_size(n)]);
        Ok(())
    }

    /// Quantized eps through the native packed backend: bit-packed code
    /// indices streamed through the fused dequantize-matmul kernel
    /// (`runtime::native`) instead of the compiled fake-qdq graph. Same
    /// signature and quantization contract as [`Self::eps_q_with_sel_into`]
    /// (the graph stays the oracle; outputs agree within f32
    /// re-association tolerance, pinned by the packed-parity integration
    /// test). Needs no batch-class padding — the native path runs the
    /// exact batch.
    #[allow(clippy::too_many_arguments)]
    pub fn eps_q_packed_into(
        &self,
        params: &[f32],
        qs: &QuantState,
        sel: &[f32],
        x: &[f32],
        t: f32,
        cond: &[f32],
        _s: &mut EpsScratch,
        out: &mut Vec<f32>,
    ) -> Result<()> {
        let n = cond.len();
        if n == 0 {
            bail!("eps_q_packed called with an empty batch (cond is empty)");
        }
        if x.len() != self.info.x_size(n) {
            bail!("x len {} != expected {}", x.len(), self.info.x_size(n));
        }
        let pf = self.packed_forward(params, qs)?;
        pf.forward(&self.info, params, &qs.lora, sel, x, t, cond, resolve_threads(0), out)
    }

    /// The cached packed model for `qs.qparams`, building (packing every
    /// layer) on first use or after a qparams hot-swap.
    fn packed_forward(&self, params: &[f32], qs: &QuantState) -> Result<Arc<PackedForward>> {
        let want = qparams_fingerprint(&qs.qparams);
        let mut cache = self.packed.lock().unwrap();
        if let Some(pf) = cache.as_ref() {
            if pf.qparams_hash() == want {
                return Ok(Arc::clone(pf));
            }
        }
        let pf = Arc::new(PackedForward::build(&self.info, params, &qs.qparams)?);
        *cache = Some(Arc::clone(&pf));
        Ok(pf)
    }

    /// Packed weight bytes of the cached packed model (0 before the first
    /// packed eval) — the serving `Metrics::packed_bytes` gauge.
    pub fn packed_bytes(&self) -> usize {
        self.packed.lock().unwrap().as_ref().map(|pf| pf.bytes()).unwrap_or(0)
    }

    /// Seed the packed cache from a persisted blob so serving starts
    /// without re-packing the f32 weights. The blob is validated against
    /// the manifest and `qs.qparams` (`PackedForward::from_model`); a
    /// corrupt or stale blob is rejected and the caller falls back to the
    /// normal lazy rebuild.
    pub fn seed_packed(&self, qs: &QuantState, model: crate::quant::PackedModel) -> Result<()> {
        let pf = PackedForward::from_model(&self.info, model, &qs.qparams)?;
        *self.packed.lock().unwrap() = Some(Arc::new(pf));
        Ok(())
    }

    /// Serialized packed blob for `qs`, building (or reusing) the cached
    /// packed model — what the serving checkpoint path persists to
    /// `StateDir::packed_path` so the next start can [`Self::seed_packed`].
    pub fn packed_blob(&self, params: &[f32], qs: &QuantState) -> Result<Vec<u8>> {
        Ok(self.packed_forward(params, qs)?.model().to_bytes())
    }

    /// Calibration forward for the serving shadow prober: `n` stacked
    /// samples at uniform timestep `t`, padded up to the compiled calib
    /// batch class by repeating the last sample (oversized probes are
    /// truncated to the class). Padding duplicates add no new extrema to
    /// the exact `[L, 2]` capture; the `[L, S]` activation capture
    /// subsamples the padded batch, which slightly over-weights the
    /// repeated sample — acceptable for drift sketching, where the batch
    /// is recycled serving traffic to begin with. Uses caller-owned
    /// [`EpsScratch`] so steady-state probing allocates nothing beyond the
    /// graph outputs. Returns (acts `[L, S]`, mm `[L, 2]`); the probe
    /// discards eps (the real round already computed it).
    pub fn calib_forward_probe(
        &self,
        params: &[f32],
        x: &[f32],
        n: usize,
        t: f32,
        cond: &[f32],
        s: &mut EpsScratch,
    ) -> Result<(Vec<f32>, Vec<f32>)> {
        if n == 0 {
            bail!("calib_forward_probe called with an empty batch");
        }
        if x.len() != self.info.x_size(n) || cond.len() != n {
            bail!("probe shapes: x {} cond {} for n {}", x.len(), cond.len(), n);
        }
        let b = self.info.calib_b;
        let n_used = n.min(b);
        pad_into(&mut s.xp, &x[..self.info.x_size(n_used)], n_used, b);
        s.tp.clear();
        s.tp.resize(b, t);
        pad_into(&mut s.cp, &cond[..n_used], n_used, b);
        let dims = self.x_dims(b);
        let out = self.engine.load(&self.calib_file)?.run(&[
            (params, &[params.len() as i64]),
            (&s.xp, &dims),
            (&s.tp, &[b as i64]),
            (&s.cp, &[b as i64]),
        ])?;
        let mut it = out.into_iter();
        let _eps = it.next();
        Ok((it.next().unwrap(), it.next().unwrap()))
    }

    /// Calibration forward: (eps, per-layer activation samples [L, S],
    /// per-layer min/max [L, 2]). Batch must equal the compiled calib_b.
    pub fn calib_forward(
        &self,
        params: &[f32],
        x: &[f32],
        t: &[f32],
        cond: &[f32],
    ) -> Result<(Vec<f32>, Vec<f32>, Vec<f32>)> {
        let b = self.info.calib_b;
        if t.len() != b {
            bail!("calib batch must be {b}, got {}", t.len());
        }
        let dims = self.x_dims(b);
        let out = self.engine.load(&self.calib_file)?.run(&[
            (params, &[params.len() as i64]),
            (x, &dims),
            (t, &[b as i64]),
            (cond, &[b as i64]),
        ])?;
        let mut it = out.into_iter();
        Ok((it.next().unwrap(), it.next().unwrap(), it.next().unwrap()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::manifest::Manifest;
    use crate::model::ParamStore;
    use std::path::PathBuf;

    #[test]
    fn pad_into_repeats_last_sample_and_reuses_capacity() {
        let mut buf = Vec::new();
        pad_into(&mut buf, &[1.0, 2.0, 3.0, 4.0], 2, 4); // 2 samples of 2
        assert_eq!(buf, vec![1.0, 2.0, 3.0, 4.0, 3.0, 4.0, 3.0, 4.0]);
        let cap = buf.capacity();
        pad_into(&mut buf, &[5.0, 6.0], 1, 3);
        assert_eq!(buf, vec![5.0, 6.0, 5.0, 6.0, 5.0, 6.0]);
        assert_eq!(buf.capacity(), cap, "pad_into must reuse the allocation");
        // exact-fit batch: no padding appended
        pad_into(&mut buf, &[7.0, 8.0], 2, 2);
        assert_eq!(buf, vec![7.0, 8.0]);
    }

    fn setup() -> Option<(Arc<Engine>, Manifest)> {
        let d = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if !d.join("manifest.json").exists() {
            crate::log_warn!("skipping: artifacts not built");
            return None;
        }
        Some((Arc::new(Engine::new(&d).unwrap()), Manifest::load(&d).unwrap()))
    }

    #[test]
    fn fp_forward_all_batch_classes() {
        let Some((engine, m)) = setup() else { return };
        let info = m.model("ddim16").unwrap();
        let den = Denoiser::new(engine, info).unwrap();
        let params = ParamStore::load_init(info, &m.dir).unwrap();
        for n in [1usize, 3, 8] {
            let x = vec![0.2f32; info.x_size(n)];
            let t = vec![5.0; n];
            let cond = vec![0.0; n];
            let eps = den.eps_fp(&params.flat, &x, &t, &cond).unwrap();
            assert_eq!(eps.len(), info.x_size(n));
            assert!(eps.iter().all(|v| v.is_finite()));
        }
    }

    #[test]
    fn into_variants_match_allocating_paths_bitwise() {
        let Some((engine, m)) = setup() else { return };
        let info = m.model("ddim16").unwrap();
        let den = Denoiser::new(engine, info).unwrap();
        let params = ParamStore::load_init(info, &m.dir).unwrap();
        let n = 3;
        let x = vec![0.2f32; info.x_size(n)];
        let t = vec![5.0; n];
        let cond = vec![0.0; n];
        let base = den.eps_fp(&params.flat, &x, &t, &cond).unwrap();
        let mut s = EpsScratch::default();
        let mut out = Vec::new();
        den.eps_fp_into(&params.flat, &x, &t, &cond, &mut s, &mut out).unwrap();
        assert_eq!(base, out);
        den.eps_fp_uniform_into(&params.flat, &x, 5.0, &cond, &mut s, &mut out).unwrap();
        assert!(
            base.iter().zip(&out).all(|(a, b)| a.to_bits() == b.to_bits()),
            "uniform-t marshalling must be bit-identical to the t-slice path"
        );
        // a second call reuses the pad scratch allocations
        let cap = s.xp.capacity();
        den.eps_fp_uniform_into(&params.flat, &x, 5.0, &cond, &mut s, &mut out).unwrap();
        assert_eq!(s.xp.capacity(), cap);
    }

    #[test]
    fn quantized_forward_runs() {
        let Some((engine, m)) = setup() else { return };
        let info = m.model("ddim16").unwrap();
        let den = Denoiser::new(engine, info).unwrap();
        let params = ParamStore::load_init(info, &m.dir).unwrap();
        let mut rng = Rng::new(1);
        let l = info.n_layers;
        // simple 8-bit-ish qparams: signed FP E2M5-ish everywhere
        let mut qp = Vec::new();
        for _ in 0..l {
            qp.extend_from_slice(&[1.0, 2.0, 5.0, 1.0, 6.0, 2.0, 5.0, 0.0]);
        }
        let qs = QuantState {
            qparams: qp,
            lora: vec![0.0; info.lora_size],
            router: Router::init(info, &mut rng),
            hub_mask: vec![1.0; info.cfg.lora_hub],
            strategy: AllocStrategy::Learned,
            t_total: 100,
        };
        let n = 2;
        let x = vec![0.3f32; info.x_size(n)];
        let cond = vec![0.0; n];
        let eps = den.eps_q(&params.flat, &qs, &x, 7.0, &cond, &mut rng).unwrap();
        assert_eq!(eps.len(), info.x_size(n));
        assert!(eps.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn quant_state_roundtrip() {
        let Some((_, m)) = setup() else { return };
        let info = m.model("ddim16").unwrap();
        let mut rng = Rng::new(3);
        let mut qp = Vec::new();
        for _ in 0..info.n_layers {
            qp.extend_from_slice(&[1.0, 2.0, 1.0, 0.0, 4.0, 2.0, 2.0, -0.25]);
        }
        let qs = QuantState {
            qparams: qp,
            lora: rng.normal_vec(info.lora_size, 0.01),
            router: Router::init(info, &mut rng),
            hub_mask: vec![1.0, 1.0, 0.0, 0.0],
            strategy: AllocStrategy::Learned,
            t_total: 100,
        };
        let path = std::env::temp_dir().join("msfp_qs_roundtrip.mts");
        qs.save(&path).unwrap();
        let qs2 = QuantState::load(info, &path).unwrap();
        assert_eq!(qs.qparams, qs2.qparams);
        assert_eq!(qs.lora, qs2.lora);
        assert_eq!(qs.router.flat, qs2.router.flat);
        assert_eq!(qs.hub_mask, qs2.hub_mask);
        assert_eq!(qs2.t_total, 100);
        // selections agree
        let a = qs.selection(13.0, &mut Rng::new(1));
        let b = qs2.selection(13.0, &mut Rng::new(1));
        assert_eq!(a, b);
    }

    #[test]
    fn load_rejects_truncated_hub_mask() {
        let Some((_, m)) = setup() else { return };
        let info = m.model("ddim16").unwrap();
        let mut rng = Rng::new(4);
        let mut qp = Vec::new();
        for _ in 0..info.n_layers {
            qp.extend_from_slice(&[1.0, 2.0, 1.0, 0.0, 4.0, 2.0, 2.0, -0.25]);
        }
        let qs = QuantState {
            qparams: qp,
            lora: rng.normal_vec(info.lora_size, 0.01),
            router: Router::init(info, &mut rng),
            // truncated mask (one slot short of the compiled hub width)
            hub_mask: vec![1.0; info.cfg.lora_hub - 1],
            strategy: AllocStrategy::Learned,
            t_total: 100,
        };
        let path = std::env::temp_dir().join("msfp_qs_truncated.mts");
        qs.save(&path).unwrap();
        let err = QuantState::load(info, &path).unwrap_err();
        assert!(err.to_string().contains("hub_mask"), "{err}");
    }

    #[test]
    fn calib_forward_probe_pads_and_matches_full_batch() {
        let Some((engine, m)) = setup() else { return };
        let info = m.model("ddim16").unwrap();
        let den = Denoiser::new(engine, info).unwrap();
        let params = ParamStore::load_init(info, &m.dir).unwrap();
        let b = info.calib_b;
        let mut s = EpsScratch::default();

        // a full uniform-t probe batch is bit-identical to calib_forward
        let x = vec![0.15f32; info.x_size(b)];
        let t = vec![7.0f32; b];
        let cond = vec![0.0f32; b];
        let (_, acts, mm) = den.calib_forward(&params.flat, &x, &t, &cond).unwrap();
        let (pacts, pmm) =
            den.calib_forward_probe(&params.flat, &x, b, 7.0, &cond, &mut s).unwrap();
        assert!(acts.iter().zip(&pacts).all(|(a, p)| a.to_bits() == p.to_bits()));
        assert!(mm.iter().zip(&pmm).all(|(a, p)| a.to_bits() == p.to_bits()));

        // a short probe pads up: shapes hold, extrema finite & ordered
        let n = 1.max(b / 2);
        let x = vec![0.3f32; info.x_size(n)];
        let cond = vec![0.0f32; n];
        let (acts, mm) =
            den.calib_forward_probe(&params.flat, &x, n, 3.0, &cond, &mut s).unwrap();
        assert_eq!(acts.len(), info.n_layers * info.act_samples);
        assert_eq!(mm.len(), info.n_layers * 2);
        for l in 0..info.n_layers {
            assert!(mm[l * 2] <= mm[l * 2 + 1]);
        }
        // scratch is reused, not regrown, on a repeat probe
        let cap = s.xp.capacity();
        den.calib_forward_probe(&params.flat, &x, n, 3.0, &cond, &mut s).unwrap();
        assert_eq!(s.xp.capacity(), cap);
        // empty probe errors
        assert!(den.calib_forward_probe(&params.flat, &[], 0, 3.0, &[], &mut s).is_err());
    }

    #[test]
    fn calib_forward_shapes() {
        let Some((engine, m)) = setup() else { return };
        let info = m.model("ddim16").unwrap();
        let den = Denoiser::new(engine, info).unwrap();
        let params = ParamStore::load_init(info, &m.dir).unwrap();
        let b = info.calib_b;
        let x = vec![0.1f32; info.x_size(b)];
        let t: Vec<f32> = (0..b).map(|i| i as f32 * 10.0).collect();
        let cond = vec![0.0; b];
        let (eps, acts, mm) = den.calib_forward(&params.flat, &x, &t, &cond).unwrap();
        assert_eq!(eps.len(), info.x_size(b));
        assert_eq!(acts.len(), info.n_layers * info.act_samples);
        assert_eq!(mm.len(), info.n_layers * 2);
        for l in 0..info.n_layers {
            assert!(mm[l * 2] <= mm[l * 2 + 1], "layer {l} min > max");
        }
    }
}
