//! PJRT runtime: load AOT-lowered HLO text, compile once, execute many.
//!
//! This is the only boundary between the Rust coordinator and the JAX/Pallas
//! compute. Pattern follows /opt/xla-example/load_hlo: HLO *text* →
//! `HloModuleProto::from_text_file` → `XlaComputation` → `client.compile`.

pub mod client;
pub mod denoiser;
pub mod native;

pub use client::{Engine, Executable};
pub use denoiser::{Denoiser, EpsScratch, QuantState};
pub use native::PackedForward;
