//! Native packed-weight quantized forward for the manifest UNet denoiser.
//!
//! This is the serving backend that makes 4-bit real: every quantized
//! conv/linear streams bit-packed code indices through the fused
//! dequantize-matmul kernel (`quant::packed`) instead of materializing f32
//! weights, with the LoRA hub correction `(1/r)·B@(A@x)` fused into the
//! same pass. The compiled fake-qdq XLA graph (`Denoiser::eps_q_with_sel_into`)
//! stays the oracle: both paths quantize weights and activations with the
//! identical qdq contract (the packed code tables reproduce fake-qdq bits
//! exactly), so outputs agree within f32 re-association tolerance — pinned
//! end-to-end by the packed-parity integration test.
//!
//! The architecture mirrors `python/compile/model.py` `unet()` exactly:
//! sinusoidal temb → 2 temb linears (+ class embedding) → conv_in → res1 →
//! strided down conv → res2 → mid res → attention → concat skip → res3 →
//! nearest 2× upsample → up conv → concat skip → res4 → out groupnorm →
//! conv_out, NHWC activations, HWIO conv weights, SAME padding, silu
//! nonlinearity, group_norm(groups=8, eps=1e-5) kept full precision.
//! Quantized layers (conv + linear) are resolved by manifest layer name;
//! each applies its activation quantizer to the layer input first, exactly
//! like the graph.

use std::collections::HashMap;

use anyhow::{bail, Context, Result};

use crate::model::manifest::{LayerSpec, ModelInfo};
use crate::model::temb::sinusoidal;
use crate::quant::packed::{
    decode_qparams_row, LoraTerm, PackedLayer, PackedMat, PackedModel, QPARAMS_COLS,
};
use crate::quant::search::Quantizer;
use crate::util::rng::mix64;

/// group_norm group count — fixed in python/compile/model.py `ModelCfg`.
pub const GROUPS: usize = 8;
const GN_EPS: f32 = 1e-5;

/// A manifest model with every quantized layer packed into matmul layout
/// (`[fan_out, fan_in]` code indices) plus the decoded per-layer
/// activation quantizers. Built once per (params, qparams) pair and
/// cached by the denoiser; recalibration hot-swaps change the qparams
/// hash and force a rebuild.
pub struct PackedForward {
    packed: PackedModel,
    acts: Vec<Quantizer>,
    qparams_hash: u64,
}

/// Order-dependent 64-bit hash over the exact f32 bits of a qparams
/// matrix — the packed cache key (recal hot-swaps produce a new matrix).
pub fn qparams_fingerprint(qparams: &[f32]) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for v in qparams {
        h = mix64(h ^ v.to_bits() as u64);
    }
    h
}

impl PackedForward {
    /// Pack every quantized layer of `info` from the flat `params` under
    /// the per-layer weight quantizers encoded in `qparams` (`[L, 8]`
    /// rows). Both conv (HWIO `(k,k,cin,cout)`) and linear (`(cin,cout)`)
    /// weights flatten to `[fan_in, fan_out]` row-major, so one transpose
    /// yields the kernel's `[fan_out, fan_in]` layout.
    pub fn build(info: &ModelInfo, params: &[f32], qparams: &[f32]) -> Result<PackedForward> {
        let l = info.layer_specs.len();
        if qparams.len() != l * QPARAMS_COLS {
            bail!("qparams len {} != {l} layers x {QPARAMS_COLS}", qparams.len());
        }
        let mut layers = Vec::with_capacity(l);
        let mut acts = Vec::with_capacity(l);
        for (i, spec) in info.layer_specs.iter().enumerate() {
            let row = &qparams[i * QPARAMS_COLS..(i + 1) * QPARAMS_COLS];
            let (wq, aq) = decode_qparams_row(row);
            let ps = info.param_spec(&spec.param)?;
            let w = &params[ps.offset..ps.offset + ps.size()];
            let (kk, n) = (spec.fan_in, spec.fan_out);
            if w.len() != kk * n {
                bail!("layer {}: weight len {} != {kk}x{n}", spec.name, w.len());
            }
            let mut wt = vec![0.0f32; n * kk];
            for j in 0..kk {
                for nn in 0..n {
                    wt[nn * kk + j] = w[j * n + nn];
                }
            }
            layers.push(PackedLayer {
                name: spec.name.clone(),
                mat: PackedMat::pack(&wt, n, kk, &wq)
                    .with_context(|| format!("packing layer {}", spec.name))?,
            });
            acts.push(aq);
        }
        Ok(PackedForward {
            packed: PackedModel { layers },
            acts,
            qparams_hash: qparams_fingerprint(qparams),
        })
    }

    /// Rebuild a forward from a persisted [`PackedModel`] blob without
    /// re-packing the f32 weights. The blob must structurally match what
    /// a fresh pack under `qparams` would produce: layer count, order,
    /// names and matmul shapes per the manifest, and — the staleness
    /// check — each layer's code table must equal the grid of the weight
    /// quantizer decoded from `qparams`. A qparams hot-swap changes the
    /// table, so a blob persisted under older qparams is rejected here
    /// and the caller falls back to [`PackedForward::build`].
    pub fn from_model(
        info: &ModelInfo,
        packed: PackedModel,
        qparams: &[f32],
    ) -> Result<PackedForward> {
        let l = info.layer_specs.len();
        if qparams.len() != l * QPARAMS_COLS {
            bail!("qparams len {} != {l} layers x {QPARAMS_COLS}", qparams.len());
        }
        if packed.layers.len() != l {
            bail!("packed blob has {} layers, manifest has {l}", packed.layers.len());
        }
        let mut acts = Vec::with_capacity(l);
        for (i, (layer, spec)) in packed.layers.iter().zip(&info.layer_specs).enumerate() {
            if layer.name != spec.name {
                bail!("packed layer {i} is '{}', manifest expects '{}'", layer.name, spec.name);
            }
            if layer.mat.rows != spec.fan_out || layer.mat.cols != spec.fan_in {
                bail!(
                    "packed layer '{}' is {}x{}, manifest expects {}x{}",
                    layer.name,
                    layer.mat.rows,
                    layer.mat.cols,
                    spec.fan_out,
                    spec.fan_in
                );
            }
            let row = &qparams[i * QPARAMS_COLS..(i + 1) * QPARAMS_COLS];
            let (wq, aq) = decode_qparams_row(row);
            if layer.mat.t.table != crate::quant::grid::quantizer_grid(&wq) {
                bail!(
                    "packed layer '{}': code table does not match the current qparams (stale blob)",
                    layer.name
                );
            }
            acts.push(aq);
        }
        Ok(PackedForward { packed, acts, qparams_hash: qparams_fingerprint(qparams) })
    }

    /// Total packed weight bytes (the `Metrics::packed_bytes` gauge).
    pub fn bytes(&self) -> usize {
        self.packed.bytes()
    }

    pub fn qparams_hash(&self) -> u64 {
        self.qparams_hash
    }

    pub fn model(&self) -> &PackedModel {
        &self.packed
    }

    /// Quantized UNet forward: predicts eps for a batch defined by
    /// `cond` (`b = cond.len()`), uniform timestep `t`, NHWC latents `x`
    /// of `info.x_size(b)`. `sel` is the `[L, H]` router one-hot,
    /// `lora` the flat hub. `threads` parallelizes the fused kernels
    /// (bit-identical for any count). Output replaces `out`.
    #[allow(clippy::too_many_arguments)]
    pub fn forward(
        &self,
        info: &ModelInfo,
        params: &[f32],
        lora: &[f32],
        sel: &[f32],
        x: &[f32],
        t: f32,
        cond: &[f32],
        threads: usize,
        out: &mut Vec<f32>,
    ) -> Result<()> {
        let b = cond.len();
        if x.len() != info.x_size(b) {
            bail!("x len {} != x_size({b}) = {}", x.len(), info.x_size(b));
        }
        let cfg = &info.cfg;
        let td = cfg.temb_dim;
        let h = cfg.lora_hub;
        if sel.len() != info.layer_specs.len() * h {
            bail!("sel len {} != {} layers x {h} hubs", sel.len(), info.layer_specs.len());
        }
        let fw = Fwd {
            pf: self,
            info,
            params,
            lora,
            sel,
            threads,
            idx: info
                .layer_specs
                .iter()
                .enumerate()
                .map(|(i, s)| (s.name.as_str(), i))
                .collect(),
        };

        // Timestep embedding: identical for every sample at uniform t.
        let base = sinusoidal(t, td);
        let mut temb = Vec::with_capacity(b * td);
        for _ in 0..b {
            temb.extend_from_slice(&base);
        }
        let mut temb = fw.linear_q("temb.lin1", &temb, b)?;
        silu_slice(&mut temb);
        let mut temb = fw.linear_q("temb.lin2", &temb, b)?;
        if cfg.n_classes > 0 {
            let emb = fw.tensor("cls.emb")?;
            for (bi, &c) in cond.iter().enumerate() {
                let ci = (c.max(0.0) as usize).min(cfg.n_classes - 1);
                for j in 0..td {
                    temb[bi * td + j] += emb[ci * td + j];
                }
            }
        }

        let x0 = T4 { b, h: cfg.img_hw, w: cfg.img_hw, c: cfg.in_ch, d: x.to_vec() };
        let h0 = fw.conv_q("conv_in", &x0)?;
        let h1 = fw.resblock("res1", &h0, &temb)?;
        let d1 = fw.conv_q("down", &silu_t4(&h1))?;
        let h2 = fw.resblock("res2", &d1, &temb)?;
        let m = fw.resblock("mid", &h2, &temb)?;
        let m = fw.attention("attn", &m)?;
        let u = concat_c(&m, &h2);
        let u = fw.resblock("res3", &u, &temb)?;
        let u = upsample2x(&u);
        let u = fw.conv_q("up", &silu_t4(&u))?;
        let u2 = concat_c(&u, &h1);
        let u2 = fw.resblock("res4", &u2, &temb)?;
        let o = fw.group_norm(&u2, "out.gn")?;
        let o = fw.conv_q("conv_out", &silu_t4(&o))?;

        out.clear();
        out.extend_from_slice(&o.d);
        Ok(())
    }
}

/// NHWC activation tensor.
#[derive(Clone)]
struct T4 {
    b: usize,
    h: usize,
    w: usize,
    c: usize,
    d: Vec<f32>,
}

fn silu(v: f32) -> f32 {
    v * (1.0 / (1.0 + (-v).exp()))
}

fn silu_slice(xs: &mut [f32]) {
    for v in xs.iter_mut() {
        *v = silu(*v);
    }
}

fn silu_t4(x: &T4) -> T4 {
    let mut y = x.clone();
    silu_slice(&mut y.d);
    y
}

/// Channel concat `[a | b]` (python `jnp.concatenate([a, b], axis=-1)`).
fn concat_c(a: &T4, b: &T4) -> T4 {
    assert_eq!((a.b, a.h, a.w), (b.b, b.h, b.w), "concat on mismatched spatial dims");
    let c = a.c + b.c;
    let mut d = Vec::with_capacity(a.b * a.h * a.w * c);
    for p in 0..a.b * a.h * a.w {
        d.extend_from_slice(&a.d[p * a.c..(p + 1) * a.c]);
        d.extend_from_slice(&b.d[p * b.c..(p + 1) * b.c]);
    }
    T4 { b: a.b, h: a.h, w: a.w, c, d }
}

/// Nearest-neighbor 2x upsample (python `jnp.repeat` on both spatial
/// axes).
fn upsample2x(x: &T4) -> T4 {
    let (oh, ow) = (x.h * 2, x.w * 2);
    let mut d = vec![0.0f32; x.b * oh * ow * x.c];
    for bi in 0..x.b {
        for oy in 0..oh {
            for ox in 0..ow {
                let src = ((bi * x.h + oy / 2) * x.w + ox / 2) * x.c;
                let dst = ((bi * oh + oy) * ow + ox) * x.c;
                d[dst..dst + x.c].copy_from_slice(&x.d[src..src + x.c]);
            }
        }
    }
    T4 { b: x.b, h: oh, w: ow, c: x.c, d }
}

/// One forward pass's borrowed context.
struct Fwd<'a> {
    pf: &'a PackedForward,
    info: &'a ModelInfo,
    params: &'a [f32],
    lora: &'a [f32],
    sel: &'a [f32],
    threads: usize,
    idx: HashMap<&'a str, usize>,
}

impl Fwd<'_> {
    fn tensor(&self, name: &str) -> Result<&[f32]> {
        let ps = self.info.param_spec(name)?;
        Ok(&self.params[ps.offset..ps.offset + ps.size()])
    }

    fn layer(&self, name: &str) -> Result<(usize, &LayerSpec, &PackedMat)> {
        let &li = self
            .idx
            .get(name)
            .with_context(|| format!("no quantized layer '{name}' in manifest"))?;
        Ok((li, &self.info.layer_specs[li], &self.pf.packed.layers[li].mat))
    }

    /// Router-selected LoRA factors for layer `li`:
    /// `a_sel: [rank, fan_in]`, `b_sel: [fan_out, rank]` — the einsum
    /// `('h,hrk->rk')` / `('h,hnr->nr')` contractions from model.py.
    fn sel_slices(&self, li: usize, spec: &LayerSpec) -> (Vec<f32>, Vec<f32>) {
        let cfg = &self.info.cfg;
        let (r, hubs) = (cfg.lora_rank, cfg.lora_hub);
        let (kk, n) = (spec.fan_in, spec.fan_out);
        let o = spec.lora_offset;
        let a_all = &self.lora[o..o + hubs * r * kk];
        let b_all = &self.lora[o + hubs * r * kk..o + hubs * r * kk + hubs * n * r];
        let s = &self.sel[li * hubs..(li + 1) * hubs];
        let mut a_sel = vec![0.0f32; r * kk];
        let mut b_sel = vec![0.0f32; n * r];
        for (hi, &sv) in s.iter().enumerate() {
            if sv == 0.0 {
                continue;
            }
            let ah = &a_all[hi * r * kk..(hi + 1) * r * kk];
            for (acc, &v) in a_sel.iter_mut().zip(ah) {
                *acc += sv * v;
            }
            let bh = &b_all[hi * n * r..(hi + 1) * n * r];
            for (acc, &v) in b_sel.iter_mut().zip(bh) {
                *acc += sv * v;
            }
        }
        (a_sel, b_sel)
    }

    /// Quantized linear on `[rows, cin]` input: activation qdq, then the
    /// fused packed matmul with the selected LoRA term and bias.
    fn linear_q(&self, name: &str, x: &[f32], rows: usize) -> Result<Vec<f32>> {
        let (li, spec, mat) = self.layer(name)?;
        let cin = spec.fan_in;
        let cout = spec.fan_out;
        if x.len() != rows * cin {
            bail!("linear {name}: input len {} != {rows}x{cin}", x.len());
        }
        let aq = &self.pf.acts[li];
        // transpose to [cin, rows] for the kernel while quantizing
        let mut xt = vec![0.0f32; cin * rows];
        for p in 0..rows {
            for kk in 0..cin {
                xt[kk * rows + p] = aq.qdq(x[p * cin + kk]);
            }
        }
        let (a_sel, b_sel) = self.sel_slices(li, spec);
        let rank = self.info.cfg.lora_rank;
        let lt = LoraTerm { a: &a_sel, b: &b_sel, rank, scale: 1.0 / rank as f32 };
        let bias = self.tensor(&format!("{name}.b"))?;
        let mut y = Vec::new();
        mat.fused_matmul_into(&xt, rows, Some(&lt), Some(bias), self.threads, &mut y);
        // back to [rows, cout]
        let mut outv = vec![0.0f32; rows * cout];
        for nn in 0..cout {
            for p in 0..rows {
                outv[p * cout + nn] = y[nn * rows + p];
            }
        }
        Ok(outv)
    }

    /// Quantized SAME conv: activation qdq, im2col (pad zeros added
    /// *after* quantization, matching the graph), fused packed matmul.
    fn conv_q(&self, name: &str, x: &T4) -> Result<T4> {
        let (li, spec, mat) = self.layer(name)?;
        let (k, s) = (spec.k, spec.stride.max(1));
        let (cin, cout) = (x.c, spec.fan_out);
        if spec.fan_in != k * k * cin {
            bail!("conv {name}: fan_in {} != {k}x{k}x{cin}", spec.fan_in);
        }
        let aq = &self.pf.acts[li];
        let mut xq = x.d.clone();
        for v in xq.iter_mut() {
            *v = aq.qdq(*v);
        }
        // SAME output dims + padding (jax convention)
        let oh = x.h.div_ceil(s);
        let ow = x.w.div_ceil(s);
        let pad_h = ((oh - 1) * s + k).saturating_sub(x.h);
        let pad_w = ((ow - 1) * s + k).saturating_sub(x.w);
        let (ph_lo, pw_lo) = (pad_h / 2, pad_w / 2);
        // im2col: X [fan_in, P], row index (kh, kw, ci) matching the HWIO
        // weight flattening, P = b*oh*ow
        let p_total = x.b * oh * ow;
        let mut xcol = vec![0.0f32; spec.fan_in * p_total];
        for kh in 0..k {
            for kw in 0..k {
                for bi in 0..x.b {
                    for oy in 0..oh {
                        let iy = (oy * s + kh) as isize - ph_lo as isize;
                        if iy < 0 || iy >= x.h as isize {
                            continue;
                        }
                        for ox in 0..ow {
                            let ix = (ox * s + kw) as isize - pw_lo as isize;
                            if ix < 0 || ix >= x.w as isize {
                                continue;
                            }
                            let src = ((bi * x.h + iy as usize) * x.w + ix as usize) * cin;
                            let p = (bi * oh + oy) * ow + ox;
                            let row0 = (kh * k + kw) * cin;
                            for ci in 0..cin {
                                xcol[(row0 + ci) * p_total + p] = xq[src + ci];
                            }
                        }
                    }
                }
            }
        }
        let (a_sel, b_sel) = self.sel_slices(li, spec);
        let rank = self.info.cfg.lora_rank;
        let lt = LoraTerm { a: &a_sel, b: &b_sel, rank, scale: 1.0 / rank as f32 };
        let bias = self.tensor(&format!("{name}.b"))?;
        let mut y = Vec::new();
        mat.fused_matmul_into(&xcol, p_total, Some(&lt), Some(bias), self.threads, &mut y);
        // scatter [cout, P] -> NHWC
        let mut d = vec![0.0f32; p_total * cout];
        for nn in 0..cout {
            for p in 0..p_total {
                d[p * cout + nn] = y[nn * p_total + p];
            }
        }
        Ok(T4 { b: x.b, h: oh, w: ow, c: cout, d })
    }

    /// Full-precision group_norm (groups=8, eps=1e-5), scale `{name}.g`,
    /// bias `{name}.b`.
    fn group_norm(&self, x: &T4, name: &str) -> Result<T4> {
        let g = self.tensor(&format!("{name}.g"))?;
        let bta = self.tensor(&format!("{name}.b"))?;
        if x.c % GROUPS != 0 {
            bail!("group_norm {name}: {} channels not divisible by {GROUPS}", x.c);
        }
        let cpg = x.c / GROUPS;
        let hw = x.h * x.w;
        let count = (hw * cpg) as f32;
        let mut y = x.clone();
        for bi in 0..x.b {
            for gi in 0..GROUPS {
                let mut mean = 0.0f32;
                for p in 0..hw {
                    let base = (bi * hw + p) * x.c + gi * cpg;
                    for ci in 0..cpg {
                        mean += x.d[base + ci];
                    }
                }
                mean /= count;
                let mut var = 0.0f32;
                for p in 0..hw {
                    let base = (bi * hw + p) * x.c + gi * cpg;
                    for ci in 0..cpg {
                        let dv = x.d[base + ci] - mean;
                        var += dv * dv;
                    }
                }
                var /= count;
                let inv = 1.0 / (var + GN_EPS).sqrt();
                for p in 0..hw {
                    let base = (bi * hw + p) * x.c + gi * cpg;
                    for ci in 0..cpg {
                        let cc = gi * cpg + ci;
                        y.d[base + ci] = (x.d[base + ci] - mean) * inv * g[cc] + bta[cc];
                    }
                }
            }
        }
        Ok(y)
    }

    /// Residual block: gn1 → silu → conv1 → +temb projection → gn2 → silu
    /// → conv2, with a 1x1 skip conv when channel counts change.
    fn resblock(&self, name: &str, x: &T4, temb: &[f32]) -> Result<T4> {
        let conv1 = format!("{name}.conv1");
        let (_, spec1, _) = self.layer(&conv1)?;
        let cout = spec1.fan_out;
        let h1 = self.group_norm(x, &format!("{name}.gn1"))?;
        let mut h1 = silu_t4(&h1);
        let mut h = self.conv_q(&conv1, &h1)?;
        // temb projection: linear over silu(temb), broadcast over (h, w)
        let b = x.b;
        let mut st = temb.to_vec();
        silu_slice(&mut st);
        let tp = self.linear_q(&format!("{name}.temb"), &st, b)?;
        let hw = h.h * h.w;
        for bi in 0..b {
            for p in 0..hw {
                let base = (bi * hw + p) * cout;
                for cc in 0..cout {
                    h.d[base + cc] += tp[bi * cout + cc];
                }
            }
        }
        let h2 = self.group_norm(&h, &format!("{name}.gn2"))?;
        h1 = silu_t4(&h2);
        let h = self.conv_q(&format!("{name}.conv2"), &h1)?;
        let skip = if x.c != cout {
            self.conv_q(&format!("{name}.skip"), x)?
        } else {
            x.clone()
        };
        let mut outv = skip;
        for (o, &hv) in outv.d.iter_mut().zip(&h.d) {
            *o += hv;
        }
        Ok(outv)
    }

    /// Self-attention over flattened spatial positions (per sample):
    /// gn → qkv linear → softmax(q·kᵀ/√c) → ·v → proj linear → residual.
    fn attention(&self, name: &str, x: &T4) -> Result<T4> {
        let c = x.c;
        let hw = x.h * x.w;
        let y = self.group_norm(x, &format!("{name}.gn"))?;
        let qkv = self.linear_q(&format!("{name}.qkv"), &y.d, x.b * hw)?;
        let scale = 1.0 / (c as f32).sqrt();
        let mut att_out = vec![0.0f32; x.b * hw * c];
        let mut logits = vec![0.0f32; hw];
        for bi in 0..x.b {
            let base = bi * hw;
            for i in 0..hw {
                let qrow = &qkv[(base + i) * 3 * c..(base + i) * 3 * c + c];
                for (j, l) in logits.iter_mut().enumerate() {
                    let krow = &qkv[(base + j) * 3 * c + c..(base + j) * 3 * c + 2 * c];
                    let mut dot = 0.0f32;
                    for (qv, kv) in qrow.iter().zip(krow) {
                        dot += qv * kv;
                    }
                    *l = dot * scale;
                }
                // stable softmax (jax.nn.softmax subtracts the row max)
                let mx = logits.iter().fold(f32::NEG_INFINITY, |a, &v| a.max(v));
                let mut denom = 0.0f32;
                for l in logits.iter_mut() {
                    *l = (*l - mx).exp();
                    denom += *l;
                }
                let orow = &mut att_out[(base + i) * c..(base + i + 1) * c];
                for (j, &a) in logits.iter().enumerate() {
                    let w = a / denom;
                    let vrow = &qkv[(base + j) * 3 * c + 2 * c..(base + j + 1) * 3 * c];
                    for (o, &vv) in orow.iter_mut().zip(vrow) {
                        *o += w * vv;
                    }
                }
            }
        }
        let proj = self.linear_q(&format!("{name}.proj"), &att_out, x.b * hw)?;
        let mut outv = x.clone();
        for (o, &pv) in outv.d.iter_mut().zip(&proj) {
            *o += pv;
        }
        Ok(outv)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::manifest::{ModelCfg, ParamSpec};
    use crate::quant::search::Quantizer;
    use crate::quant::FpFormat;
    use crate::util::rng::Rng;

    /// Manifest builder for the miniature UNet fixture below.
    struct B {
        offset: usize,
        lora_offset: usize,
        specs: Vec<ParamSpec>,
        layers: Vec<LayerSpec>,
        rank: usize,
        hubs: usize,
        td: usize,
    }

    impl B {
        fn param(&mut self, name: &str, shape: Vec<usize>) {
            let size: usize = shape.iter().product();
            self.specs.push(ParamSpec { name: name.into(), shape, offset: self.offset });
            self.offset += size;
        }

        fn layer(&mut self, name: &str, kind: &str, fan_in: usize, fan_out: usize, k: usize, stride: usize) {
            let shape = if kind == "conv" {
                vec![k, k, fan_in / (k * k), fan_out]
            } else {
                vec![fan_in, fan_out]
            };
            self.param(&format!("{name}.w"), shape);
            self.param(&format!("{name}.b"), vec![fan_out]);
            self.layers.push(LayerSpec {
                name: name.into(),
                kind: kind.into(),
                fan_in,
                fan_out,
                k,
                stride,
                aal_hint: false,
                param: format!("{name}.w"),
                lora_offset: self.lora_offset,
            });
            self.lora_offset += self.hubs * self.rank * fan_in + self.hubs * fan_out * self.rank;
        }

        fn gn(&mut self, name: &str, c: usize) {
            self.param(&format!("{name}.g"), vec![c]);
            self.param(&format!("{name}.b"), vec![c]);
        }

        fn resblock(&mut self, name: &str, cin: usize, cout: usize) {
            self.gn(&format!("{name}.gn1"), cin);
            self.layer(&format!("{name}.conv1"), "conv", 9 * cin, cout, 3, 1);
            let td = self.td;
            self.layer(&format!("{name}.temb"), "linear", td, cout, 0, 0);
            self.gn(&format!("{name}.gn2"), cout);
            self.layer(&format!("{name}.conv2"), "conv", 9 * cout, cout, 3, 1);
            if cin != cout {
                self.layer(&format!("{name}.skip"), "conv", cin, cout, 1, 1);
            }
        }
    }

    /// Hand-built miniature UNet manifest exercising every native op:
    /// c0=8, c1=16, temb 16, 4x4 latents, 2 classes, rank 2, 2 hubs.
    fn synthetic_info() -> ModelInfo {
        let (c0, c1, td, hw, in_ch, n_classes, rank, hubs) = (8usize, 16usize, 16, 4, 1, 2, 2, 2);
        let mut b = B {
            offset: 0,
            lora_offset: 0,
            specs: Vec::new(),
            layers: Vec::new(),
            rank,
            hubs,
            td,
        };
        b.layer("temb.lin1", "linear", td, td * 2, 0, 0);
        b.layer("temb.lin2", "linear", td * 2, td, 0, 0);
        b.param("cls.emb", vec![n_classes, td]);
        b.layer("conv_in", "conv", 9 * in_ch, c0, 3, 1);
        b.resblock("res1", c0, c0);
        b.layer("down", "conv", 9 * c0, c1, 3, 2);
        b.resblock("res2", c1, c1);
        b.resblock("mid", c1, c1);
        b.gn("attn.gn", c1);
        b.layer("attn.qkv", "linear", c1, 3 * c1, 0, 0);
        b.layer("attn.proj", "linear", c1, c1, 0, 0);
        b.resblock("res3", 2 * c1, c1);
        b.layer("up", "conv", 9 * c1, c0, 3, 1);
        b.resblock("res4", 2 * c0, c0);
        b.gn("out.gn", c0);
        b.layer("conv_out", "conv", 9 * c0, in_ch, 3, 1);

        let n_layers = b.layers.len();
        ModelInfo {
            name: "native-test".into(),
            cfg: ModelCfg {
                img_hw: hw,
                in_ch,
                temb_dim: td,
                n_classes,
                lora_rank: rank,
                lora_hub: hubs,
            },
            n_params: b.offset,
            n_layers,
            lora_size: b.lora_offset,
            router_size: td * n_layers * hubs + n_layers * hubs,
            act_samples: 0,
            param_specs: b.specs,
            layer_specs: b.layers,
            init_params: String::new(),
            artifacts: Default::default(),
            batches_fp: vec![],
            batches_q: vec![],
            train_b: 1,
            calib_b: 1,
        }
    }

    fn fixture() -> (ModelInfo, Vec<f32>, Vec<f32>, Vec<f32>, Vec<f32>) {
        let info = synthetic_info();
        let mut r = Rng::new(42);
        let params: Vec<f32> = (0..info.n_params).map(|_| r.normal() * 0.1).collect();
        let lora: Vec<f32> = (0..info.lora_size).map(|_| r.normal() * 0.02).collect();
        let h = info.cfg.lora_hub;
        let mut sel = vec![0.0f32; info.n_layers * h];
        for li in 0..info.n_layers {
            sel[li * h + li % h] = 1.0;
        }
        let wq = Quantizer::SignedFp { fmt: FpFormat::new(2, 1), maxval: 0.35 };
        let aq = Quantizer::SignedFp { fmt: FpFormat::new(2, 1), maxval: 6.0 };
        let mut qparams = Vec::new();
        for _ in 0..info.n_layers {
            qparams.extend_from_slice(&wq.encode_weight());
            qparams.extend_from_slice(&aq.encode_act());
        }
        (info, params, lora, sel, qparams)
    }

    #[test]
    fn forward_shape_and_finiteness() {
        let (info, params, lora, sel, qparams) = fixture();
        let pf = PackedForward::build(&info, &params, &qparams).unwrap();
        let b = 2;
        let mut r = Rng::new(7);
        let x: Vec<f32> = (0..info.x_size(b)).map(|_| r.normal()).collect();
        let mut out = Vec::new();
        pf.forward(&info, &params, &lora, &sel, &x, 3.0, &[0.0, 1.0], 2, &mut out).unwrap();
        assert_eq!(out.len(), info.x_size(b));
        assert!(out.iter().all(|v| v.is_finite()), "non-finite output");
        // not trivially zero: conv_out bias is random here
        assert!(out.iter().any(|v| v.abs() > 1e-12));
    }

    #[test]
    fn forward_is_bit_identical_for_any_thread_count() {
        let (info, params, lora, sel, qparams) = fixture();
        let pf = PackedForward::build(&info, &params, &qparams).unwrap();
        let b = 3;
        let mut r = Rng::new(8);
        let x: Vec<f32> = (0..info.x_size(b)).map(|_| r.normal()).collect();
        let cond = [1.0, 0.0, 1.0];
        let run = |threads: usize| {
            let mut out = Vec::new();
            pf.forward(&info, &params, &lora, &sel, &x, 5.0, &cond, threads, &mut out).unwrap();
            out.iter().map(|v| v.to_bits()).collect::<Vec<u32>>()
        };
        let one = run(1);
        for threads in [2, 4, 7] {
            assert_eq!(one, run(threads), "threads={threads} diverged");
        }
    }

    #[test]
    fn packed_model_is_smaller_than_f32_weights() {
        let (info, params, _, _, qparams) = fixture();
        let pf = PackedForward::build(&info, &params, &qparams).unwrap();
        let f32_bytes: usize =
            info.layer_specs.iter().map(|s| s.fan_in * s.fan_out * 4).sum();
        assert!(
            pf.bytes() < f32_bytes / 4,
            "packed {} vs f32 {} bytes",
            pf.bytes(),
            f32_bytes
        );
    }

    #[test]
    fn qparams_fingerprint_tracks_content() {
        let (_, _, _, _, qparams) = fixture();
        let h1 = qparams_fingerprint(&qparams);
        let mut q2 = qparams.clone();
        q2[0] += 0.125;
        assert_ne!(h1, qparams_fingerprint(&q2));
        assert_eq!(h1, qparams_fingerprint(&qparams));
    }

    #[test]
    fn per_sample_independence_padding_rows_do_not_leak() {
        // Serving never pads the native path, but the property that makes
        // that safe is per-sample independence: batch [x0] must equal the
        // first sample of batch [x0, x1].
        let (info, params, lora, sel, qparams) = fixture();
        let pf = PackedForward::build(&info, &params, &qparams).unwrap();
        let mut r = Rng::new(9);
        let x1: Vec<f32> = (0..info.x_size(1)).map(|_| r.normal()).collect();
        let x2: Vec<f32> = {
            let mut v = x1.clone();
            v.extend((0..info.x_size(1)).map(|_| r.normal()));
            v
        };
        let mut o1 = Vec::new();
        pf.forward(&info, &params, &lora, &sel, &x1, 2.0, &[1.0], 1, &mut o1).unwrap();
        let mut o2 = Vec::new();
        pf.forward(&info, &params, &lora, &sel, &x2, 2.0, &[1.0, 0.0], 1, &mut o2).unwrap();
        assert_eq!(
            o1.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
            o2[..o1.len()].iter().map(|v| v.to_bits()).collect::<Vec<_>>()
        );
    }
}
