//! PJRT client + compiled-executable cache.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};

use anyhow::{anyhow, Context, Result};

use crate::log_info;

/// A compiled artifact, shareable across threads.
///
/// SAFETY of the Send/Sync impls: `PjRtLoadedExecutable` wraps a PJRT
/// executable handle plus a refcounted client handle. The PJRT C API
/// guarantees `Execute` is thread-safe on immutable loaded executables, and
/// the CPU client is internally synchronized; the Rust wrapper is !Send only
/// because it holds raw pointers. We never mutate the executable after
/// compilation and never destroy it while workers hold an Arc.
pub struct Executable {
    exe: xla::PjRtLoadedExecutable,
    pub name: String,
}

unsafe impl Send for Executable {}
unsafe impl Sync for Executable {}

impl Executable {
    /// Execute on f32 input vectors shaped per `dims` (row-major). Returns
    /// the flattened f32 outputs of the tuple result, in order.
    pub fn run(&self, inputs: &[(&[f32], &[i64])]) -> Result<Vec<Vec<f32>>> {
        let lits = inputs
            .iter()
            .map(|(data, dims)| {
                let l = xla::Literal::vec1(data);
                if dims.len() == 1 {
                    Ok(l)
                } else {
                    l.reshape(dims).map_err(anyhow::Error::from)
                }
            })
            .collect::<Result<Vec<_>>>()?;
        let result = self
            .exe
            .execute::<xla::Literal>(&lits)
            .with_context(|| format!("executing {}", self.name))?;
        let lit = result[0][0].to_literal_sync()?;
        let parts = lit.to_tuple()?;
        parts
            .into_iter()
            .map(|p| p.to_vec::<f32>().map_err(anyhow::Error::from))
            .collect()
    }
}

/// Max compile attempts per artifact. Once a slot has failed this many
/// times, further loads serve the cached error immediately instead of
/// hammering the compiler (and count as `compile_exhausted`).
pub const COMPILE_RETRY_BUDGET: usize = 3;

/// Per-artifact compile slot: the first thread to miss the cache becomes
/// the builder; concurrent loaders of the same key wait on the condvar
/// instead of compiling the same ~30 s artifact a second time. Failed
/// attempts park the slot back at `Pending` with their count — the next
/// loader becomes the retry builder until the budget is spent.
enum Slot {
    /// never attempted, or a failed attempt awaiting an in-budget retry
    Pending { last_err: Option<String>, attempts: usize },
    Building,
    Ready(Arc<Executable>),
}

struct SlotCell {
    state: Mutex<Slot>,
    cv: Condvar,
}

/// The engine owns the PJRT client and a by-path cache of compiled
/// executables (compile once per process; execution is hot-path).
pub struct Engine {
    client: xla::PjRtClient,
    artifacts_dir: PathBuf,
    cache: Mutex<BTreeMap<String, Arc<SlotCell>>>,
    /// number of actual compilations (cache-hit / wait paths excluded) —
    /// observable so tests can pin the single-flight guarantee
    compiles: AtomicUsize,
    /// compile attempts including failures and injected faults
    attempts: AtomicUsize,
    /// loads refused because a slot's retry budget was exhausted
    exhausted: AtomicUsize,
    /// countdown of forced compile failures (fault injection)
    fault_compiles: AtomicUsize,
}

unsafe impl Send for Engine {}
unsafe impl Sync for Engine {}

impl Engine {
    pub fn new(artifacts_dir: &Path) -> Result<Engine> {
        let client = xla::PjRtClient::cpu()?;
        log_info!("PJRT client up: platform={}", client.platform_name());
        Ok(Engine {
            client,
            artifacts_dir: artifacts_dir.to_path_buf(),
            cache: Mutex::new(BTreeMap::new()),
            compiles: AtomicUsize::new(0),
            attempts: AtomicUsize::new(0),
            exhausted: AtomicUsize::new(0),
            fault_compiles: AtomicUsize::new(0),
        })
    }

    pub fn artifacts_dir(&self) -> &Path {
        &self.artifacts_dir
    }

    /// How many artifacts this engine actually compiled (as opposed to
    /// served from cache or waited on another thread for).
    pub fn compiled_count(&self) -> usize {
        self.compiles.load(Ordering::SeqCst)
    }

    /// Compile attempts over the engine lifetime, including failed and
    /// fault-injected ones (cache hits and waits excluded).
    pub fn compile_attempts(&self) -> usize {
        self.attempts.load(Ordering::SeqCst)
    }

    /// Loads refused because the artifact's retry budget
    /// ([`COMPILE_RETRY_BUDGET`]) was already spent.
    pub fn compile_exhausted_count(&self) -> usize {
        self.exhausted.load(Ordering::SeqCst)
    }

    /// Fault injection: force the next `n` compile attempts (across all
    /// artifacts) to fail. Used by the serving coordinator's `FaultPlan`
    /// and the chaos tests to exercise the retry budget.
    pub fn inject_compile_failures(&self, n: usize) {
        self.fault_compiles.fetch_add(n, Ordering::SeqCst);
    }

    /// Load + compile (or fetch from cache) an artifact by file name.
    ///
    /// Concurrent loads of the same file are single-flight: the first
    /// caller compiles, the rest block until it finishes and share the
    /// result. A failed compile parks the slot with its attempt count;
    /// the next loader retries (becoming the builder) until
    /// [`COMPILE_RETRY_BUDGET`] attempts are spent, after which every
    /// load serves the cached error immediately.
    pub fn load(&self, file: &str) -> Result<Arc<Executable>> {
        let cell = {
            let mut map = self.cache.lock().unwrap();
            Arc::clone(map.entry(file.to_string()).or_insert_with(|| {
                Arc::new(SlotCell {
                    state: Mutex::new(Slot::Pending { last_err: None, attempts: 0 }),
                    cv: Condvar::new(),
                })
            }))
        };
        // claim the builder role (first load, or in-budget retry of a
        // failed slot), wait out a concurrent build, or serve the cached
        // outcome
        let prev_attempts = {
            let mut st = cell.state.lock().unwrap();
            loop {
                match &*st {
                    Slot::Ready(e) => return Ok(Arc::clone(e)),
                    Slot::Building => st = cell.cv.wait(st).unwrap(),
                    Slot::Pending { last_err, attempts } => {
                        if *attempts >= COMPILE_RETRY_BUDGET {
                            self.exhausted.fetch_add(1, Ordering::SeqCst);
                            return Err(anyhow!(
                                "compiling {file}: retry budget exhausted after {attempts} failed attempts (last: {})",
                                last_err.as_deref().unwrap_or("never attempted")
                            ));
                        }
                        let prev = *attempts;
                        *st = Slot::Building;
                        break prev;
                    }
                }
            }
        };
        // unwind guard: if compile() panics (e.g. inside the xla FFI),
        // park the slot back at Pending with the attempt counted and wake
        // every waiter — a slot stuck at Building would hang all current
        // and future loaders
        struct BuildGuard<'a> {
            cell: &'a SlotCell,
            attempts: usize,
            armed: bool,
        }
        impl Drop for BuildGuard<'_> {
            fn drop(&mut self) {
                if !self.armed {
                    return;
                }
                *self.cell.state.lock().unwrap() = Slot::Pending {
                    last_err: Some("compile panicked".to_string()),
                    attempts: self.attempts + 1,
                };
                self.cell.cv.notify_all();
            }
        }
        let mut guard = BuildGuard { cell: &cell, attempts: prev_attempts, armed: true };
        let res = self.compile(file);
        guard.armed = false;
        drop(guard);
        {
            let mut st = cell.state.lock().unwrap();
            match &res {
                Ok(e) => *st = Slot::Ready(Arc::clone(e)),
                Err(e) => {
                    *st = Slot::Pending {
                        last_err: Some(format!("{e:#}")),
                        attempts: prev_attempts + 1,
                    }
                }
            }
        }
        cell.cv.notify_all();
        res
    }

    fn compile(&self, file: &str) -> Result<Arc<Executable>> {
        self.attempts.fetch_add(1, Ordering::SeqCst);
        // injected compile faults consume the countdown before any real
        // compiler work — the forced failure takes the exact path a real
        // one does (Pending slot, attempt counted, budget spent)
        loop {
            let left = self.fault_compiles.load(Ordering::SeqCst);
            if left == 0 {
                break;
            }
            if self
                .fault_compiles
                .compare_exchange(left, left - 1, Ordering::SeqCst, Ordering::SeqCst)
                .is_ok()
            {
                return Err(anyhow!("injected fault: forced compile failure for {file}"));
            }
        }
        let path = self.artifacts_dir.join(file);
        let t0 = std::time::Instant::now();
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("non-utf8 path")?,
        )
        .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp).with_context(|| format!("compiling {file}"))?;
        self.compiles.fetch_add(1, Ordering::SeqCst);
        log_info!("compiled {file} in {:.2}s", t0.elapsed().as_secs_f64());
        Ok(Arc::new(Executable { exe, name: file.to_string() }))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts_dir() -> Option<PathBuf> {
        let d = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        d.join("manifest.json").exists().then_some(d)
    }

    #[test]
    fn loads_and_runs_features_artifact() {
        let Some(dir) = artifacts_dir() else {
            crate::log_warn!("skipping: artifacts not built");
            return;
        };
        let engine = Engine::new(&dir).unwrap();
        let exe = engine.load("features16.hlo.txt").unwrap();
        let img = vec![0.1f32; 32 * 16 * 16 * 3];
        let out = exe.run(&[(&img, &[32, 16, 16, 3])]).unwrap();
        assert_eq!(out.len(), 3); // feat, sfeat, logits
        assert_eq!(out[0].len(), 32 * 64);
        assert_eq!(out[1].len(), 32 * 256);
        assert_eq!(out[2].len(), 32 * 10);
        assert!(out[0].iter().all(|v| v.is_finite()));
    }

    #[test]
    fn cache_returns_same_arc() {
        let Some(dir) = artifacts_dir() else {
            return;
        };
        let engine = Engine::new(&dir).unwrap();
        let a = engine.load("features16.hlo.txt").unwrap();
        let b = engine.load("features16.hlo.txt").unwrap();
        assert!(Arc::ptr_eq(&a, &b));
    }

    #[test]
    fn missing_artifact_errors() {
        let Some(dir) = artifacts_dir() else {
            return;
        };
        let engine = Engine::new(&dir).unwrap();
        assert!(engine.load("nope.hlo.txt").is_err());
        // an in-budget failed slot is retried: the second load takes the
        // builder path again instead of seeing a stale Ready/hung slot
        assert!(engine.load("nope.hlo.txt").is_err());
        assert_eq!(engine.compiled_count(), 0);
        assert_eq!(engine.compile_attempts(), 2);
    }

    #[test]
    fn failed_compile_retry_budget_caps_attempts() {
        let Some(dir) = artifacts_dir() else {
            return;
        };
        let engine = Engine::new(&dir).unwrap();
        for i in 0..5 {
            let err = engine.load("nope.hlo.txt").unwrap_err();
            let msg = format!("{err:#}");
            if i >= COMPILE_RETRY_BUDGET {
                assert!(msg.contains("retry budget exhausted"), "load {i}: {msg}");
            } else {
                assert!(!msg.contains("retry budget exhausted"), "load {i}: {msg}");
            }
        }
        // only the first BUDGET loads actually hit the compiler; the rest
        // were refused from the cached error
        assert_eq!(engine.compile_attempts(), COMPILE_RETRY_BUDGET);
        assert_eq!(engine.compile_exhausted_count(), 2);
        assert_eq!(engine.compiled_count(), 0);
    }

    #[test]
    fn injected_compile_faults_consume_retries_then_succeed() {
        let Some(dir) = artifacts_dir() else {
            return;
        };
        let engine = Engine::new(&dir).unwrap();
        engine.inject_compile_failures(2);
        for _ in 0..2 {
            let err = engine.load("features16.hlo.txt").unwrap_err();
            assert!(format!("{err:#}").contains("injected fault"), "{err:#}");
        }
        // the third attempt is within budget and the fault countdown is
        // spent, so it compiles for real and the slot turns Ready
        let exe = engine.load("features16.hlo.txt").unwrap();
        assert_eq!(engine.compiled_count(), 1);
        assert_eq!(engine.compile_attempts(), 3);
        assert_eq!(engine.compile_exhausted_count(), 0);
        // cached thereafter
        let again = engine.load("features16.hlo.txt").unwrap();
        assert!(Arc::ptr_eq(&exe, &again));
    }

    #[test]
    fn concurrent_loads_compile_once() {
        let Some(dir) = artifacts_dir() else {
            return;
        };
        let engine = Arc::new(Engine::new(&dir).unwrap());
        let exes: Vec<Arc<Executable>> = std::thread::scope(|s| {
            let handles: Vec<_> = (0..8)
                .map(|_| {
                    let engine = Arc::clone(&engine);
                    s.spawn(move || engine.load("features16.hlo.txt").unwrap())
                })
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        });
        assert_eq!(engine.compiled_count(), 1, "exactly one thread must compile");
        assert!(exes.iter().all(|e| Arc::ptr_eq(e, &exes[0])));
    }

    #[test]
    fn concurrent_missing_loads_all_error() {
        let Some(dir) = artifacts_dir() else {
            return;
        };
        let engine = Arc::new(Engine::new(&dir).unwrap());
        std::thread::scope(|s| {
            let handles: Vec<_> = (0..4)
                .map(|_| {
                    let engine = Arc::clone(&engine);
                    s.spawn(move || engine.load("nope.hlo.txt").is_err())
                })
                .collect();
            assert!(handles.into_iter().all(|h| h.join().unwrap()));
        });
    }
}
