//! PJRT client + compiled-executable cache.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};

use anyhow::{anyhow, Context, Result};

use crate::log_info;

/// A compiled artifact, shareable across threads.
///
/// SAFETY of the Send/Sync impls: `PjRtLoadedExecutable` wraps a PJRT
/// executable handle plus a refcounted client handle. The PJRT C API
/// guarantees `Execute` is thread-safe on immutable loaded executables, and
/// the CPU client is internally synchronized; the Rust wrapper is !Send only
/// because it holds raw pointers. We never mutate the executable after
/// compilation and never destroy it while workers hold an Arc.
pub struct Executable {
    exe: xla::PjRtLoadedExecutable,
    pub name: String,
}

unsafe impl Send for Executable {}
unsafe impl Sync for Executable {}

impl Executable {
    /// Execute on f32 input vectors shaped per `dims` (row-major). Returns
    /// the flattened f32 outputs of the tuple result, in order.
    pub fn run(&self, inputs: &[(&[f32], &[i64])]) -> Result<Vec<Vec<f32>>> {
        let lits = inputs
            .iter()
            .map(|(data, dims)| {
                let l = xla::Literal::vec1(data);
                if dims.len() == 1 {
                    Ok(l)
                } else {
                    l.reshape(dims).map_err(anyhow::Error::from)
                }
            })
            .collect::<Result<Vec<_>>>()?;
        let result = self
            .exe
            .execute::<xla::Literal>(&lits)
            .with_context(|| format!("executing {}", self.name))?;
        let lit = result[0][0].to_literal_sync()?;
        let parts = lit.to_tuple()?;
        parts
            .into_iter()
            .map(|p| p.to_vec::<f32>().map_err(anyhow::Error::from))
            .collect()
    }
}

/// Per-artifact compile slot: the first thread to miss the cache becomes
/// the builder; concurrent loaders of the same key wait on the condvar
/// instead of compiling the same ~30 s artifact a second time.
enum Slot {
    Building,
    Ready(Arc<Executable>),
    Failed(String),
}

struct SlotCell {
    state: Mutex<Slot>,
    cv: Condvar,
}

/// The engine owns the PJRT client and a by-path cache of compiled
/// executables (compile once per process; execution is hot-path).
pub struct Engine {
    client: xla::PjRtClient,
    artifacts_dir: PathBuf,
    cache: Mutex<BTreeMap<String, Arc<SlotCell>>>,
    /// number of actual compilations (cache-hit / wait paths excluded) —
    /// observable so tests can pin the single-flight guarantee
    compiles: AtomicUsize,
}

unsafe impl Send for Engine {}
unsafe impl Sync for Engine {}

impl Engine {
    pub fn new(artifacts_dir: &Path) -> Result<Engine> {
        let client = xla::PjRtClient::cpu()?;
        log_info!("PJRT client up: platform={}", client.platform_name());
        Ok(Engine {
            client,
            artifacts_dir: artifacts_dir.to_path_buf(),
            cache: Mutex::new(BTreeMap::new()),
            compiles: AtomicUsize::new(0),
        })
    }

    pub fn artifacts_dir(&self) -> &Path {
        &self.artifacts_dir
    }

    /// How many artifacts this engine actually compiled (as opposed to
    /// served from cache or waited on another thread for).
    pub fn compiled_count(&self) -> usize {
        self.compiles.load(Ordering::SeqCst)
    }

    /// Load + compile (or fetch from cache) an artifact by file name.
    ///
    /// Concurrent loads of the same file are single-flight: the first
    /// caller compiles, the rest block until it finishes and share the
    /// result. A failed compile is reported to every waiter and then
    /// evicted, so a later load retries instead of caching the error.
    pub fn load(&self, file: &str) -> Result<Arc<Executable>> {
        let (cell, builder) = {
            let mut map = self.cache.lock().unwrap();
            match map.get(file) {
                Some(c) => (Arc::clone(c), false),
                None => {
                    let c = Arc::new(SlotCell {
                        state: Mutex::new(Slot::Building),
                        cv: Condvar::new(),
                    });
                    map.insert(file.to_string(), Arc::clone(&c));
                    (c, true)
                }
            }
        };
        if builder {
            // unwind guard: if compile() panics (e.g. inside the xla FFI),
            // mark the slot Failed, evict it and wake every waiter — a slot
            // stuck at Building would hang all current and future loaders
            struct BuildGuard<'a> {
                cell: &'a SlotCell,
                cache: &'a Mutex<BTreeMap<String, Arc<SlotCell>>>,
                file: &'a str,
                armed: bool,
            }
            impl Drop for BuildGuard<'_> {
                fn drop(&mut self) {
                    if !self.armed {
                        return;
                    }
                    *self.cell.state.lock().unwrap() =
                        Slot::Failed("compile panicked".to_string());
                    self.cache.lock().unwrap().remove(self.file);
                    self.cell.cv.notify_all();
                }
            }
            let mut guard = BuildGuard { cell: &cell, cache: &self.cache, file, armed: true };
            let res = self.compile(file);
            guard.armed = false;
            drop(guard);
            {
                let mut st = cell.state.lock().unwrap();
                match &res {
                    Ok(e) => *st = Slot::Ready(Arc::clone(e)),
                    Err(e) => {
                        *st = Slot::Failed(format!("{e:#}"));
                        self.cache.lock().unwrap().remove(file);
                    }
                }
            }
            cell.cv.notify_all();
            res
        } else {
            let mut st = cell.state.lock().unwrap();
            while matches!(*st, Slot::Building) {
                st = cell.cv.wait(st).unwrap();
            }
            match &*st {
                Slot::Ready(e) => Ok(Arc::clone(e)),
                Slot::Failed(msg) => {
                    Err(anyhow!("compiling {file} failed in another thread: {msg}"))
                }
                Slot::Building => unreachable!("condvar wait ended while Building"),
            }
        }
    }

    fn compile(&self, file: &str) -> Result<Arc<Executable>> {
        let path = self.artifacts_dir.join(file);
        let t0 = std::time::Instant::now();
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("non-utf8 path")?,
        )
        .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp).with_context(|| format!("compiling {file}"))?;
        self.compiles.fetch_add(1, Ordering::SeqCst);
        log_info!("compiled {file} in {:.2}s", t0.elapsed().as_secs_f64());
        Ok(Arc::new(Executable { exe, name: file.to_string() }))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts_dir() -> Option<PathBuf> {
        let d = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        d.join("manifest.json").exists().then_some(d)
    }

    #[test]
    fn loads_and_runs_features_artifact() {
        let Some(dir) = artifacts_dir() else {
            eprintln!("skipping: artifacts not built");
            return;
        };
        let engine = Engine::new(&dir).unwrap();
        let exe = engine.load("features16.hlo.txt").unwrap();
        let img = vec![0.1f32; 32 * 16 * 16 * 3];
        let out = exe.run(&[(&img, &[32, 16, 16, 3])]).unwrap();
        assert_eq!(out.len(), 3); // feat, sfeat, logits
        assert_eq!(out[0].len(), 32 * 64);
        assert_eq!(out[1].len(), 32 * 256);
        assert_eq!(out[2].len(), 32 * 10);
        assert!(out[0].iter().all(|v| v.is_finite()));
    }

    #[test]
    fn cache_returns_same_arc() {
        let Some(dir) = artifacts_dir() else {
            return;
        };
        let engine = Engine::new(&dir).unwrap();
        let a = engine.load("features16.hlo.txt").unwrap();
        let b = engine.load("features16.hlo.txt").unwrap();
        assert!(Arc::ptr_eq(&a, &b));
    }

    #[test]
    fn missing_artifact_errors() {
        let Some(dir) = artifacts_dir() else {
            return;
        };
        let engine = Engine::new(&dir).unwrap();
        assert!(engine.load("nope.hlo.txt").is_err());
        // a failed compile must not be cached: the retry takes the builder
        // path again (and fails again, rather than seeing a stale slot)
        assert!(engine.load("nope.hlo.txt").is_err());
        assert_eq!(engine.compiled_count(), 0);
    }

    #[test]
    fn concurrent_loads_compile_once() {
        let Some(dir) = artifacts_dir() else {
            return;
        };
        let engine = Arc::new(Engine::new(&dir).unwrap());
        let exes: Vec<Arc<Executable>> = std::thread::scope(|s| {
            let handles: Vec<_> = (0..8)
                .map(|_| {
                    let engine = Arc::clone(&engine);
                    s.spawn(move || engine.load("features16.hlo.txt").unwrap())
                })
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        });
        assert_eq!(engine.compiled_count(), 1, "exactly one thread must compile");
        assert!(exes.iter().all(|e| Arc::ptr_eq(e, &exes[0])));
    }

    #[test]
    fn concurrent_missing_loads_all_error() {
        let Some(dir) = artifacts_dir() else {
            return;
        };
        let engine = Arc::new(Engine::new(&dir).unwrap());
        std::thread::scope(|s| {
            let handles: Vec<_> = (0..4)
                .map(|_| {
                    let engine = Arc::clone(&engine);
                    s.spawn(move || engine.load("nope.hlo.txt").is_err())
                })
                .collect();
            assert!(handles.into_iter().all(|h| h.join().unwrap()));
        });
    }
}
