//! PJRT client + compiled-executable cache.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex};

use anyhow::{Context, Result};

use crate::log_info;

/// A compiled artifact, shareable across threads.
///
/// SAFETY of the Send/Sync impls: `PjRtLoadedExecutable` wraps a PJRT
/// executable handle plus a refcounted client handle. The PJRT C API
/// guarantees `Execute` is thread-safe on immutable loaded executables, and
/// the CPU client is internally synchronized; the Rust wrapper is !Send only
/// because it holds raw pointers. We never mutate the executable after
/// compilation and never destroy it while workers hold an Arc.
pub struct Executable {
    exe: xla::PjRtLoadedExecutable,
    pub name: String,
}

unsafe impl Send for Executable {}
unsafe impl Sync for Executable {}

impl Executable {
    /// Execute on f32 input vectors shaped per `dims` (row-major). Returns
    /// the flattened f32 outputs of the tuple result, in order.
    pub fn run(&self, inputs: &[(&[f32], &[i64])]) -> Result<Vec<Vec<f32>>> {
        let lits = inputs
            .iter()
            .map(|(data, dims)| {
                let l = xla::Literal::vec1(data);
                if dims.len() == 1 {
                    Ok(l)
                } else {
                    l.reshape(dims).map_err(anyhow::Error::from)
                }
            })
            .collect::<Result<Vec<_>>>()?;
        let result = self
            .exe
            .execute::<xla::Literal>(&lits)
            .with_context(|| format!("executing {}", self.name))?;
        let lit = result[0][0].to_literal_sync()?;
        let parts = lit.to_tuple()?;
        parts
            .into_iter()
            .map(|p| p.to_vec::<f32>().map_err(anyhow::Error::from))
            .collect()
    }
}

/// The engine owns the PJRT client and a by-path cache of compiled
/// executables (compile once per process; execution is hot-path).
pub struct Engine {
    client: xla::PjRtClient,
    artifacts_dir: PathBuf,
    cache: Mutex<BTreeMap<String, Arc<Executable>>>,
}

unsafe impl Send for Engine {}
unsafe impl Sync for Engine {}

impl Engine {
    pub fn new(artifacts_dir: &Path) -> Result<Engine> {
        let client = xla::PjRtClient::cpu()?;
        log_info!("PJRT client up: platform={}", client.platform_name());
        Ok(Engine {
            client,
            artifacts_dir: artifacts_dir.to_path_buf(),
            cache: Mutex::new(BTreeMap::new()),
        })
    }

    pub fn artifacts_dir(&self) -> &Path {
        &self.artifacts_dir
    }

    /// Load + compile (or fetch from cache) an artifact by file name.
    pub fn load(&self, file: &str) -> Result<Arc<Executable>> {
        if let Some(e) = self.cache.lock().unwrap().get(file) {
            return Ok(Arc::clone(e));
        }
        let path = self.artifacts_dir.join(file);
        let t0 = std::time::Instant::now();
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("non-utf8 path")?,
        )
        .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp).with_context(|| format!("compiling {file}"))?;
        log_info!("compiled {file} in {:.2}s", t0.elapsed().as_secs_f64());
        let exe = Arc::new(Executable { exe, name: file.to_string() });
        self.cache.lock().unwrap().insert(file.to_string(), Arc::clone(&exe));
        Ok(exe)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts_dir() -> Option<PathBuf> {
        let d = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        d.join("manifest.json").exists().then_some(d)
    }

    #[test]
    fn loads_and_runs_features_artifact() {
        let Some(dir) = artifacts_dir() else {
            eprintln!("skipping: artifacts not built");
            return;
        };
        let engine = Engine::new(&dir).unwrap();
        let exe = engine.load("features16.hlo.txt").unwrap();
        let img = vec![0.1f32; 32 * 16 * 16 * 3];
        let out = exe.run(&[(&img, &[32, 16, 16, 3])]).unwrap();
        assert_eq!(out.len(), 3); // feat, sfeat, logits
        assert_eq!(out[0].len(), 32 * 64);
        assert_eq!(out[1].len(), 32 * 256);
        assert_eq!(out[2].len(), 32 * 10);
        assert!(out[0].iter().all(|v| v.is_finite()));
    }

    #[test]
    fn cache_returns_same_arc() {
        let Some(dir) = artifacts_dir() else {
            return;
        };
        let engine = Engine::new(&dir).unwrap();
        let a = engine.load("features16.hlo.txt").unwrap();
        let b = engine.load("features16.hlo.txt").unwrap();
        assert!(Arc::ptr_eq(&a, &b));
    }

    #[test]
    fn missing_artifact_errors() {
        let Some(dir) = artifacts_dir() else {
            return;
        };
        let engine = Engine::new(&dir).unwrap();
        assert!(engine.load("nope.hlo.txt").is_err());
    }
}
