//! msfp — CLI for the MSFP 4-bit FP diffusion quantization system.
//!
//! Subcommands:
//!   pretrain  --corpus <name> [--steps N]           train the FP model
//!   quantize  --corpus <name> --bits 4 [--method msfp|signed|int-mse|int-minmax]
//!   sample    --corpus <name> [--bits N] [--n N] [--steps N] [--out grid.ppm]
//!   eval      --corpus <name> [--bits N] [--method ...]     FID/sFID/IS proxy
//!   serve     --corpus <name> [--requests N] [--n N] [--workers N]  serving demo/load
//!   repro     --exp t1..t11,f1..f9|all                      paper tables/figures
//!
//! Scale: MSFP_SCALE=fast|full (default fast). Artifacts dir: MSFP_ARTIFACTS
//! (default ./artifacts, built by `make artifacts`).

use std::sync::Arc;

use anyhow::{bail, Context, Result};

use msfp::config::{MethodSpec, Scale};
use msfp::coordinator::{self, Request, ServeMode, ServerCfg};
use msfp::data::Corpus;
use msfp::eval::generate::SamplerKind;
use msfp::eval::image::write_grid_ppm;
use msfp::eval::{generate_images, GenerateCfg, ModelMode};
use msfp::exp::{figures, tables, Report};
use msfp::pipeline::Pipeline;
use msfp::quant::msfp::Method;
use msfp::util::cli::Args;

fn main() {
    if let Err(e) = run() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn corpus_arg(args: &Args) -> Result<Corpus> {
    let name = args.str("corpus", "celeba-syn");
    Corpus::parse(&name).with_context(|| format!("unknown corpus '{name}'"))
}

fn spec_arg(args: &Args, scale: &Scale) -> Result<MethodSpec> {
    let bits = args.usize("bits", 4)? as i32;
    if bits == 32 {
        return Ok(MethodSpec::fp());
    }
    let h = args.usize("h", 2)?;
    let method = args.str("method", "msfp");
    Ok(match method.as_str() {
        "msfp" => MethodSpec::ours(bits, h, scale.ft_epochs),
        "msfp-ptq" => MethodSpec { finetune: None, ..MethodSpec::ours(bits, h, scale.ft_epochs) },
        "signed" => MethodSpec {
            label: "signed-FP".into(),
            method: Some(Method::SignedFp),
            ..MethodSpec::ours(bits, h, scale.ft_epochs)
        },
        "int-mse" => MethodSpec::qdiffusion_like(bits),
        "int-minmax" => MethodSpec::eda_dm_like(bits),
        "efficientdm" => MethodSpec::efficientdm_like(bits, scale.ft_epochs),
        other => bail!("unknown method '{other}'"),
    })
}

fn run() -> Result<()> {
    let args = Args::parse_env()?;
    let scale = Scale::from_env();
    let artifacts = Pipeline::default_artifacts_dir();

    match args.subcommand.as_deref() {
        Some("pretrain") => {
            let mut scale = scale;
            if let Some(steps) = args.opt_str("steps") {
                scale.pretrain_steps = steps.parse()?;
            }
            let corpus = corpus_arg(&args)?;
            args.finish()?;
            let pl = Pipeline::new(&artifacts, scale)?;
            let p = pl.prepare(corpus)?;
            println!(
                "pretrained {}: {} steps, loss {:.4} -> {:.4}",
                corpus.name(),
                p.pretrain_losses.len(),
                p.pretrain_losses.first().unwrap_or(&0.0),
                p.pretrain_losses.last().unwrap_or(&0.0)
            );
        }
        Some("quantize") => {
            let corpus = corpus_arg(&args)?;
            let spec = spec_arg(&args, &scale)?;
            args.finish()?;
            let pl = Pipeline::new(&artifacts, scale)?;
            let p = pl.prepare(corpus)?;
            let calib = pl.calibrate(&p)?;
            let q = pl.quantize(&p, &spec, &calib)?;
            println!(
                "quantized {} [{}]: {} layers, {} AALs, unsigned on {:.0}% of AALs",
                corpus.name(),
                spec.label,
                q.scheme.layers.len(),
                q.scheme.n_aal(),
                q.scheme.unsigned_fraction_on_aals() * 100.0
            );
            for l in q.scheme.layers.iter().take(8) {
                println!(
                    "  {:<14} {:?} | w {:?} (mse {:.2e}) | a {:?} (mse {:.2e})",
                    l.name, l.class, l.weight, l.w_mse, l.act, l.a_mse
                );
            }
            let out = pl.runs_dir.join(format!("quant_{}_w{}.mts", corpus.name(), spec.wbits));
            q.state.save(&out)?;
            println!("saved quantized state to {} (serve --load {})", out.display(), out.display());
        }
        Some("sample") => {
            let corpus = corpus_arg(&args)?;
            let spec = spec_arg(&args, &scale)?;
            let n = args.usize("n", 16)?;
            let steps = args.usize("steps", scale.steps)?;
            let out = args.str("out", "samples.ppm");
            let seed = args.u64("seed", 11)?;
            args.finish()?;
            let pl = Pipeline::new(&artifacts, scale)?;
            let p = pl.prepare(corpus)?;
            let cfg = GenerateCfg { n, steps, eta: 0.0, sampler: SamplerKind::Ddim, seed };
            let px = if spec.method.is_none() {
                generate_images(&p.den, &p.info, &pl.sched, corpus, &p.params, ModelMode::Fp, &cfg)?
                    .0
            } else {
                let calib = pl.calibrate(&p)?;
                let q = pl.quantize(&p, &spec, &calib)?;
                generate_images(
                    &p.den,
                    &p.info,
                    &pl.sched,
                    corpus,
                    &p.params,
                    ModelMode::Quant(&q.state),
                    &cfg,
                )?
                .0
            };
            write_grid_ppm(std::path::Path::new(&out), &px, n, corpus.hw(), 4)?;
            println!("wrote {n} samples to {out}");
        }
        Some("eval") => {
            let corpus = corpus_arg(&args)?;
            let spec = spec_arg(&args, &scale)?;
            args.finish()?;
            let pl = Pipeline::new(&artifacts, scale)?;
            let p = pl.prepare(corpus)?;
            let (r, _) = pl.evaluate_spec(&p, &spec, SamplerKind::Ddim, 0.0, 42)?;
            println!("{} [{}]: {}", corpus.name(), spec.label, r.row());
        }
        Some("serve") => {
            let corpus = corpus_arg(&args)?;
            let spec = spec_arg(&args, &scale)?;
            let requests = args.usize("requests", 12)?;
            let per = args.usize("n", 2)?;
            let steps = args.usize("steps", scale.steps)?;
            let workers = args.usize("workers", 0)?;
            args.finish()?;
            let pl = Pipeline::new(&artifacts, scale)?;
            let p = pl.prepare(corpus)?;
            let mode = if let Some(path) = args.opt_str("load") {
                ServeMode::Quant(msfp::runtime::QuantState::load(
                    &p.info,
                    std::path::Path::new(&path),
                )?)
            } else if spec.method.is_none() {
                ServeMode::Fp
            } else {
                let calib = pl.calibrate(&p)?;
                let q = pl.quantize(&p, &spec, &calib)?;
                ServeMode::Quant(q.state)
            };
            let decode = corpus.hw() != p.info.cfg.img_hw;
            let den = Arc::new(msfp::runtime::Denoiser::new(Arc::clone(&pl.engine), &p.info)?);
            let handle = coordinator::spawn(
                den,
                p.info.clone(),
                pl.sched.clone(),
                Arc::new(p.params.clone()),
                ServerCfg { decode_latents: decode, seed: 3, workers, ..ServerCfg::new(mode) },
            );
            let rxs = handle
                .submit_many((0..requests).map(|i| Request::new(i as u64, per, steps)).collect())?;
            for rx in rxs {
                let resp = rx.recv()?.unwrap_done();
                println!(
                    "request {} done: {} images in {:.1} ms ({} evals)",
                    resp.id,
                    resp.n,
                    resp.latency.as_secs_f64() * 1e3,
                    resp.evals
                );
            }
            let m = handle.shutdown();
            println!("serving summary: {}", m.report());
        }
        Some("repro") => {
            let exp = args.str("exp", "all");
            args.finish()?;
            let pl = Pipeline::new(&artifacts, scale)?;
            let report = Report::new(&pl.runs_dir)?;
            let ids: Vec<&str> = if exp == "all" {
                vec![
                    "t1", "t2", "t3", "t4", "t5", "t6", "t7", "t8", "t9", "t10", "t11", "f1",
                    "f2", "f3", "f4", "f6", "f7", "f8", "f9",
                ]
            } else {
                exp.split(',').collect()
            };
            for id in ids {
                println!("\n### running experiment {id} ###");
                let r = if id.starts_with('t') {
                    tables::run_table(&pl, &report, id)
                } else {
                    figures::run_figure(&pl, &report, id)
                };
                if let Err(e) = r {
                    eprintln!("experiment {id} failed: {e:#}");
                }
            }
        }
        Some(other) => {
            bail!("unknown subcommand '{other}' (try: pretrain quantize sample eval serve repro)")
        }
        None => {
            println!("msfp — 4-bit FP quantization for diffusion models (MSFP + TALoRA + DFA)");
            println!("usage: msfp <pretrain|quantize|sample|eval|serve|repro> [--flags]");
            println!("see README.md; artifacts must be built first: make artifacts");
        }
    }
    Ok(())
}
