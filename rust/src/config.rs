//! Experiment configuration: method specs (the rows of the paper's tables)
//! and scale presets (paper-scale vs CI-scale runs).

use crate::lora::hub::AllocStrategy;
use crate::quant::msfp::Method;
use crate::train::FinetuneCfg;

/// Scale knobs for a full experiment chain. `full` approximates the paper's
/// protocol at this model scale; `fast` keeps CI and benches snappy.
#[derive(Debug, Clone)]
pub struct Scale {
    pub pretrain_steps: usize,
    pub traj_samples: usize,
    pub ft_epochs: usize,
    pub eval_n: usize,
    pub ref_n: usize,
    pub steps: usize,
    pub calib_rounds: usize,
}

impl Scale {
    pub fn full() -> Scale {
        Scale {
            pretrain_steps: 600,
            traj_samples: 32,
            ft_epochs: 6,
            eval_n: 512,
            ref_n: 512,
            steps: 100,
            calib_rounds: 8,
        }
    }

    pub fn fast() -> Scale {
        Scale {
            pretrain_steps: 80,
            traj_samples: 8,
            ft_epochs: 2,
            eval_n: 96,
            ref_n: 192,
            steps: 10,
            calib_rounds: 3,
        }
    }

    /// Middle preset: enough budget for discriminative tables in minutes.
    pub fn mid() -> Scale {
        Scale {
            pretrain_steps: 400,
            traj_samples: 16,
            ft_epochs: 3,
            eval_n: 128,
            ref_n: 256,
            steps: 20,
            calib_rounds: 4,
        }
    }

    /// Resolve from the MSFP_SCALE env var (default fast — experiments that
    /// matter pass full/mid explicitly or set the env).
    pub fn from_env() -> Scale {
        match std::env::var("MSFP_SCALE").as_deref() {
            Ok("full") => Scale::full(),
            Ok("mid") => Scale::mid(),
            _ => Scale::fast(),
        }
    }
}

/// One table row: how to initialize and (optionally) fine-tune a model.
#[derive(Debug, Clone)]
pub struct MethodSpec {
    pub label: String,
    /// None = full precision (no quantization at all)
    pub method: Option<Method>,
    pub wbits: i32,
    pub abits: i32,
    /// None = PTQ only (no fine-tuning)
    pub finetune: Option<FinetuneCfg>,
    pub alloc: AllocStrategy,
    /// Table 11: keep skip-connection/up/down layers at high precision
    pub partial: bool,
}

impl MethodSpec {
    pub fn fp() -> MethodSpec {
        MethodSpec {
            label: "FP".into(),
            method: None,
            wbits: 32,
            abits: 32,
            finetune: None,
            alloc: AllocStrategy::Single,
            partial: false,
        }
    }

    /// Ours: MSFP + TALoRA(h) + DFA.
    pub fn ours(bits: i32, h: usize, epochs: usize) -> MethodSpec {
        MethodSpec {
            label: format!("Ours (h={h})"),
            method: Some(Method::Msfp),
            wbits: bits,
            abits: bits,
            finetune: Some(FinetuneCfg { epochs, h, dfa: true, ..Default::default() }),
            alloc: AllocStrategy::Learned,
            partial: false,
        }
    }

    /// Q-Diffusion-like: MSE-searched INT PTQ, no fine-tuning.
    pub fn qdiffusion_like(bits: i32) -> MethodSpec {
        MethodSpec {
            label: "Q-Diffusion-like".into(),
            method: Some(Method::IntMse),
            wbits: bits,
            abits: bits,
            finetune: None,
            alloc: AllocStrategy::Single,
            partial: false,
        }
    }

    /// EDA-DM-like: INT PTQ with min-max calibration-reconstruction flavor.
    pub fn eda_dm_like(bits: i32) -> MethodSpec {
        MethodSpec {
            label: "EDA-DM-like".into(),
            method: Some(Method::IntMinMax),
            wbits: bits,
            abits: bits,
            finetune: None,
            alloc: AllocStrategy::Single,
            partial: false,
        }
    }

    /// EfficientDM-like: INT PTQ + single-LoRA fine-tuning.
    pub fn efficientdm_like(bits: i32, epochs: usize) -> MethodSpec {
        MethodSpec {
            label: "EfficientDM-like".into(),
            method: Some(Method::IntMse),
            wbits: bits,
            abits: bits,
            finetune: Some(FinetuneCfg { epochs, h: 1, dfa: false, ..Default::default() }),
            alloc: AllocStrategy::Single,
            partial: false,
        }
    }

    /// QuEST-like: INT PTQ + single-LoRA with activation-aware (min-max)
    /// init.
    pub fn quest_like(bits: i32, epochs: usize) -> MethodSpec {
        MethodSpec {
            label: "QuEST-like".into(),
            method: Some(Method::IntMinMax),
            wbits: bits,
            abits: bits,
            finetune: Some(FinetuneCfg { epochs, h: 1, dfa: false, ..Default::default() }),
            alloc: AllocStrategy::Single,
            partial: false,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn presets_are_consistent() {
        let f = Scale::full();
        let q = Scale::fast();
        assert!(f.eval_n > q.eval_n);
        assert!(f.pretrain_steps > q.pretrain_steps);
    }

    #[test]
    fn ours_spec_wires_talora_dfa() {
        let s = MethodSpec::ours(4, 2, 3);
        assert_eq!(s.wbits, 4);
        let ft = s.finetune.unwrap();
        assert!(ft.dfa);
        assert_eq!(ft.h, 2);
        assert_eq!(s.alloc, AllocStrategy::Learned);
    }

    #[test]
    fn baselines_differ() {
        assert_ne!(MethodSpec::qdiffusion_like(4).method, MethodSpec::eda_dm_like(4).method);
        assert!(MethodSpec::efficientdm_like(4, 2).finetune.is_some());
        assert!(MethodSpec::qdiffusion_like(4).finetune.is_none());
    }
}
