//! Row-major f32 matrix with the handful of ops the eval stack needs.

use anyhow::{bail, Result};

#[derive(Debug, Clone, PartialEq)]
pub struct Mat {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f32>,
}

impl Mat {
    pub fn zeros(rows: usize, cols: usize) -> Mat {
        Mat { rows, cols, data: vec![0.0; rows * cols] }
    }

    pub fn eye(n: usize) -> Mat {
        let mut m = Mat::zeros(n, n);
        for i in 0..n {
            m[(i, i)] = 1.0;
        }
        m
    }

    pub fn from_rows(rows: &[Vec<f32>]) -> Result<Mat> {
        if rows.is_empty() {
            return Ok(Mat::zeros(0, 0));
        }
        let cols = rows[0].len();
        if rows.iter().any(|r| r.len() != cols) {
            bail!("ragged rows");
        }
        Ok(Mat { rows: rows.len(), cols, data: rows.concat() })
    }

    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Result<Mat> {
        if data.len() != rows * cols {
            bail!("shape {}x{} != data len {}", rows, cols, data.len());
        }
        Ok(Mat { rows, cols, data })
    }

    pub fn row(&self, i: usize) -> &[f32] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    pub fn transpose(&self) -> Mat {
        let mut t = Mat::zeros(self.cols, self.rows);
        for i in 0..self.rows {
            for j in 0..self.cols {
                t[(j, i)] = self[(i, j)];
            }
        }
        t
    }

    pub fn matmul(&self, other: &Mat) -> Result<Mat> {
        if self.cols != other.rows {
            bail!("matmul shape mismatch: {}x{} @ {}x{}", self.rows, self.cols, other.rows, other.cols);
        }
        let mut out = Mat::zeros(self.rows, other.cols);
        // The a == 0.0 fast path skips a whole `other` row, which would
        // also skip 0 * NaN / 0 * inf and silently launder non-finite
        // inputs into zeros. Only take it when `other` is entirely finite
        // (the common case), so IEEE propagation is preserved otherwise.
        let other_finite = other.data.iter().all(|v| v.is_finite());
        // ikj loop order: stream `other` rows, accumulate into out rows.
        for i in 0..self.rows {
            for k in 0..self.cols {
                let a = self[(i, k)];
                if a == 0.0 && other_finite {
                    continue;
                }
                let orow = &other.data[k * other.cols..(k + 1) * other.cols];
                let out_row = &mut out.data[i * other.cols..(i + 1) * other.cols];
                for (o, &b) in out_row.iter_mut().zip(orow) {
                    *o += a * b;
                }
            }
        }
        Ok(out)
    }

    pub fn add(&self, other: &Mat) -> Result<Mat> {
        if self.rows != other.rows || self.cols != other.cols {
            bail!("add shape mismatch");
        }
        let data = self.data.iter().zip(&other.data).map(|(a, b)| a + b).collect();
        Ok(Mat { rows: self.rows, cols: self.cols, data })
    }

    pub fn scale(&self, s: f32) -> Mat {
        Mat { rows: self.rows, cols: self.cols, data: self.data.iter().map(|v| v * s).collect() }
    }

    pub fn trace(&self) -> f32 {
        (0..self.rows.min(self.cols)).map(|i| self[(i, i)]).sum()
    }

    /// Frobenius norm of (self - other).
    pub fn dist(&self, other: &Mat) -> f32 {
        self.data.iter().zip(&other.data).map(|(a, b)| (a - b).powi(2)).sum::<f32>().sqrt()
    }

    pub fn is_symmetric(&self, tol: f32) -> bool {
        if self.rows != self.cols {
            return false;
        }
        for i in 0..self.rows {
            for j in 0..i {
                if (self[(i, j)] - self[(j, i)]).abs() > tol {
                    return false;
                }
            }
        }
        true
    }
}

impl std::ops::Index<(usize, usize)> for Mat {
    type Output = f32;
    fn index(&self, (i, j): (usize, usize)) -> &f32 {
        &self.data[i * self.cols + j]
    }
}

impl std::ops::IndexMut<(usize, usize)> for Mat {
    fn index_mut(&mut self, (i, j): (usize, usize)) -> &mut f32 {
        &mut self.data[i * self.cols + j]
    }
}

/// Dot product.
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    a.iter().zip(b).map(|(x, y)| x * y).sum()
}

/// y += alpha * x
pub fn axpy(alpha: f32, x: &[f32], y: &mut [f32]) {
    for (yi, xi) in y.iter_mut().zip(x) {
        *yi += alpha * xi;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matmul_known() {
        let a = Mat::from_vec(2, 3, vec![1., 2., 3., 4., 5., 6.]).unwrap();
        let b = Mat::from_vec(3, 2, vec![7., 8., 9., 10., 11., 12.]).unwrap();
        let c = a.matmul(&b).unwrap();
        assert_eq!(c.data, vec![58., 64., 139., 154.]);
    }

    #[test]
    fn matmul_identity() {
        let a = Mat::from_vec(3, 3, (0..9).map(|i| i as f32).collect()).unwrap();
        let c = a.matmul(&Mat::eye(3)).unwrap();
        assert_eq!(c, a);
    }

    #[test]
    fn matmul_propagates_nan_and_inf_through_zero_rows() {
        // 0 * NaN must stay NaN, 0 * inf must stay NaN — the zero-skip
        // fast path used to drop both and return 0.
        let a = Mat::from_vec(1, 2, vec![0.0, 0.0]).unwrap();
        let b = Mat::from_vec(2, 1, vec![f32::NAN, 1.0]).unwrap();
        assert!(a.matmul(&b).unwrap()[(0, 0)].is_nan());
        let b = Mat::from_vec(2, 1, vec![f32::INFINITY, 1.0]).unwrap();
        assert!(a.matmul(&b).unwrap()[(0, 0)].is_nan());
        // finite inputs keep the old exact behaviour
        let a = Mat::from_vec(1, 2, vec![0.0, 2.0]).unwrap();
        let b = Mat::from_vec(2, 1, vec![5.0, 3.0]).unwrap();
        assert_eq!(a.matmul(&b).unwrap()[(0, 0)], 6.0);
    }

    #[test]
    fn matmul_shape_error() {
        let a = Mat::zeros(2, 3);
        assert!(a.matmul(&Mat::zeros(2, 2)).is_err());
    }

    #[test]
    fn transpose_involution() {
        let a = Mat::from_vec(2, 3, vec![1., 2., 3., 4., 5., 6.]).unwrap();
        assert_eq!(a.transpose().transpose(), a);
    }

    #[test]
    fn trace_and_dist() {
        let a = Mat::eye(4);
        assert_eq!(a.trace(), 4.0);
        assert_eq!(a.dist(&Mat::eye(4)), 0.0);
        assert!(a.dist(&Mat::zeros(4, 4)) > 1.9);
    }

    #[test]
    fn symmetry_check() {
        let mut a = Mat::eye(3);
        assert!(a.is_symmetric(1e-9));
        a[(0, 1)] = 0.5;
        assert!(!a.is_symmetric(1e-9));
        a[(1, 0)] = 0.5;
        assert!(a.is_symmetric(1e-9));
    }
}
