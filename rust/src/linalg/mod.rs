//! Dense linear algebra substrate: just enough for the evaluation stack
//! (feature statistics, Frechet distance) and the autoencoder — built
//! in-repo since no BLAS/ndarray is available offline.

pub mod tensor;
pub mod eig;
pub mod stats;
