//! Feature statistics + Frechet distance (the FID-syn metric core).

use anyhow::{bail, Result};

use super::eig::sqrtm_psd;
use super::tensor::Mat;

/// Mean vector and covariance matrix of row-stacked feature vectors.
pub fn mean_cov(feats: &Mat) -> Result<(Vec<f32>, Mat)> {
    let (n, d) = (feats.rows, feats.cols);
    if n < 2 {
        bail!("mean_cov: need at least 2 samples, got {n}");
    }
    let mut mean = vec![0.0f32; d];
    for i in 0..n {
        for (m, &v) in mean.iter_mut().zip(feats.row(i)) {
            *m += v;
        }
    }
    for m in &mut mean {
        *m /= n as f32;
    }
    let mut cov = Mat::zeros(d, d);
    for i in 0..n {
        let row = feats.row(i);
        for a in 0..d {
            let da = row[a] - mean[a];
            if da == 0.0 {
                continue;
            }
            for b in 0..d {
                cov[(a, b)] += da * (row[b] - mean[b]);
            }
        }
    }
    let denom = (n - 1) as f32;
    for v in &mut cov.data {
        *v /= denom;
    }
    Ok((mean, cov))
}

/// Frechet distance between two Gaussians:
/// ||µ1-µ2||² + Tr(Σ1 + Σ2 − 2·sqrtm(Σ1 Σ2)).
///
/// Σ1Σ2 is not symmetric; we use the standard equivalent symmetric form
/// sqrtm(Σ1)·Σ2·sqrtm(Σ1), whose trace-sqrt equals Tr(sqrtm(Σ1 Σ2)).
pub fn frechet(mu1: &[f32], cov1: &Mat, mu2: &[f32], cov2: &Mat) -> Result<f32> {
    if mu1.len() != mu2.len() || cov1.rows != cov2.rows {
        bail!("frechet: dimension mismatch");
    }
    let dmu: f32 = mu1.iter().zip(mu2).map(|(a, b)| (a - b).powi(2)).sum();
    let s1 = sqrtm_psd(cov1)?;
    let inner = s1.matmul(cov2)?.matmul(&s1)?;
    // numerical symmetrization before the PSD sqrt
    let inner_sym = inner.add(&inner.transpose())?.scale(0.5);
    let covmean = sqrtm_psd(&inner_sym)?;
    let fid = dmu + cov1.trace() + cov2.trace() - 2.0 * covmean.trace();
    Ok(fid.max(0.0))
}

/// Inception-Score-style exp(E_x KL(p(y|x) || p(y))) from row-stacked
/// per-sample class probabilities.
pub fn inception_score(probs: &Mat) -> Result<f32> {
    let (n, k) = (probs.rows, probs.cols);
    if n == 0 {
        bail!("inception_score: no samples");
    }
    let mut marginal = vec![0.0f64; k];
    for i in 0..n {
        for (m, &p) in marginal.iter_mut().zip(probs.row(i)) {
            *m += p as f64;
        }
    }
    for m in &mut marginal {
        *m /= n as f64;
    }
    let mut kl_sum = 0.0f64;
    for i in 0..n {
        for (j, &p) in probs.row(i).iter().enumerate() {
            let p = p as f64;
            if p > 1e-12 {
                kl_sum += p * (p / marginal[j].max(1e-12)).ln();
            }
        }
    }
    Ok(((kl_sum / n as f64).exp()) as f32)
}

/// Softmax rows in place.
pub fn softmax_rows(m: &mut Mat) {
    for i in 0..m.rows {
        let row = &mut m.data[i * m.cols..(i + 1) * m.cols];
        let mx = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let mut sum = 0.0;
        for v in row.iter_mut() {
            *v = (*v - mx).exp();
            sum += *v;
        }
        for v in row.iter_mut() {
            *v /= sum;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn gaussian_feats(n: usize, d: usize, mean: f32, std: f32, seed: u64) -> Mat {
        let mut rng = Rng::new(seed);
        Mat::from_vec(n, d, (0..n * d).map(|_| mean + std * rng.normal()).collect()).unwrap()
    }

    #[test]
    fn mean_cov_of_known() {
        let f = Mat::from_vec(4, 2, vec![1., 0., -1., 0., 0., 1., 0., -1.]).unwrap();
        let (mu, cov) = mean_cov(&f).unwrap();
        assert!(mu.iter().all(|v| v.abs() < 1e-6));
        assert!((cov[(0, 0)] - 2.0 / 3.0).abs() < 1e-5);
        assert!((cov[(0, 1)]).abs() < 1e-6);
    }

    #[test]
    fn frechet_zero_for_same() {
        let f = gaussian_feats(500, 8, 0.0, 1.0, 1);
        let (mu, cov) = mean_cov(&f).unwrap();
        let d = frechet(&mu, &cov, &mu, &cov).unwrap();
        assert!(d < 1e-3, "d={d}");
    }

    #[test]
    fn frechet_detects_mean_shift() {
        let a = gaussian_feats(2000, 6, 0.0, 1.0, 2);
        let b = gaussian_feats(2000, 6, 1.0, 1.0, 3);
        let (m1, c1) = mean_cov(&a).unwrap();
        let (m2, c2) = mean_cov(&b).unwrap();
        let d = frechet(&m1, &c1, &m2, &c2).unwrap();
        // analytic: ||Δµ||² = 6
        assert!((d - 6.0).abs() < 1.0, "d={d}");
    }

    #[test]
    fn frechet_detects_scale_change() {
        let a = gaussian_feats(3000, 4, 0.0, 1.0, 4);
        let b = gaussian_feats(3000, 4, 0.0, 2.0, 5);
        let (m1, c1) = mean_cov(&a).unwrap();
        let (m2, c2) = mean_cov(&b).unwrap();
        // analytic: Tr(1 + 4 − 2·2) per dim = 1 per dim = 4
        let d = frechet(&m1, &c1, &m2, &c2).unwrap();
        assert!((d - 4.0).abs() < 1.0, "d={d}");
    }

    #[test]
    fn frechet_monotone_in_shift() {
        let a = gaussian_feats(1000, 4, 0.0, 1.0, 6);
        let (m1, c1) = mean_cov(&a).unwrap();
        let mut prev = -1.0;
        for shift in [0.0f32, 0.5, 1.0, 2.0] {
            let b = gaussian_feats(1000, 4, shift, 1.0, 7);
            let (m2, c2) = mean_cov(&b).unwrap();
            let d = frechet(&m1, &c1, &m2, &c2).unwrap();
            assert!(d > prev, "shift={shift} d={d} prev={prev}");
            prev = d;
        }
    }

    #[test]
    fn is_uniform_vs_peaked() {
        // peaked & diverse predictions -> high IS; uniform -> IS = 1
        let n = 100;
        let k = 10;
        let mut peaked = Mat::zeros(n, k);
        for i in 0..n {
            peaked[(i, i % k)] = 1.0;
        }
        let uniform = Mat::from_vec(n, k, vec![0.1; n * k]).unwrap();
        let is_peaked = inception_score(&peaked).unwrap();
        let is_uniform = inception_score(&uniform).unwrap();
        assert!((is_uniform - 1.0).abs() < 1e-4);
        assert!((is_peaked - k as f32).abs() < 0.5, "{is_peaked}");
    }

    #[test]
    fn softmax_rows_normalizes() {
        let mut m = Mat::from_vec(2, 3, vec![1., 2., 3., -1., 0., 1.]).unwrap();
        softmax_rows(&mut m);
        for i in 0..2 {
            let s: f32 = m.row(i).iter().sum();
            assert!((s - 1.0).abs() < 1e-5);
        }
    }
}
