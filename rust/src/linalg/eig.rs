//! Cyclic Jacobi eigendecomposition for symmetric matrices.
//!
//! Used by the Frechet-distance (FID-syn) computation: the matrix square
//! root of a symmetric PSD covariance product is V·sqrt(Λ)·Vᵀ. Dimensions
//! are small (feature dim 64 / spatial 256), where Jacobi is robust and
//! plenty fast.

use anyhow::{bail, Result};

use super::tensor::Mat;

/// Returns (eigenvalues, eigenvectors-as-columns) of a symmetric matrix.
pub fn eigh(a: &Mat) -> Result<(Vec<f32>, Mat)> {
    if a.rows != a.cols {
        bail!("eigh: matrix not square");
    }
    let n = a.rows;
    // f64 working copy: Jacobi accumulates many rotations.
    let mut m: Vec<f64> = a.data.iter().map(|&v| v as f64).collect();
    let mut v = vec![0.0f64; n * n];
    for i in 0..n {
        v[i * n + i] = 1.0;
    }
    let idx = |i: usize, j: usize| i * n + j;

    for sweep in 0..100 {
        let mut off = 0.0f64;
        for i in 0..n {
            for j in (i + 1)..n {
                off += m[idx(i, j)] * m[idx(i, j)];
            }
        }
        if off.sqrt() < 1e-11 {
            break;
        }
        if sweep == 99 {
            // fall through with whatever precision we reached
        }
        for p in 0..n {
            for q in (p + 1)..n {
                let apq = m[idx(p, q)];
                if apq.abs() < 1e-300 {
                    continue;
                }
                let app = m[idx(p, p)];
                let aqq = m[idx(q, q)];
                let theta = (aqq - app) / (2.0 * apq);
                let t = theta.signum() / (theta.abs() + (theta * theta + 1.0).sqrt());
                let c = 1.0 / (t * t + 1.0).sqrt();
                let s = t * c;
                // rotate rows/cols p and q
                for k in 0..n {
                    let mkp = m[idx(k, p)];
                    let mkq = m[idx(k, q)];
                    m[idx(k, p)] = c * mkp - s * mkq;
                    m[idx(k, q)] = s * mkp + c * mkq;
                }
                for k in 0..n {
                    let mpk = m[idx(p, k)];
                    let mqk = m[idx(q, k)];
                    m[idx(p, k)] = c * mpk - s * mqk;
                    m[idx(q, k)] = s * mpk + c * mqk;
                }
                for k in 0..n {
                    let vkp = v[idx(k, p)];
                    let vkq = v[idx(k, q)];
                    v[idx(k, p)] = c * vkp - s * vkq;
                    v[idx(k, q)] = s * vkp + c * vkq;
                }
            }
        }
    }

    let eigvals: Vec<f32> = (0..n).map(|i| m[idx(i, i)] as f32).collect();
    let eigvecs = Mat::from_vec(n, n, v.into_iter().map(|x| x as f32).collect())?;
    Ok((eigvals, eigvecs))
}

/// Symmetric PSD matrix square root via eigh; negative eigenvalues (noise)
/// are clamped to zero.
pub fn sqrtm_psd(a: &Mat) -> Result<Mat> {
    let (vals, vecs) = eigh(a)?;
    let n = a.rows;
    let mut out = Mat::zeros(n, n);
    // V diag(sqrt(max(λ,0))) Vᵀ
    for k in 0..n {
        let s = vals[k].max(0.0).sqrt();
        if s == 0.0 {
            continue;
        }
        for i in 0..n {
            let vik = vecs[(i, k)] * s;
            if vik == 0.0 {
                continue;
            }
            for j in 0..n {
                out[(i, j)] += vik * vecs[(j, k)];
            }
        }
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn random_sym(n: usize, seed: u64) -> Mat {
        let mut rng = Rng::new(seed);
        let mut a = Mat::zeros(n, n);
        for i in 0..n {
            for j in 0..=i {
                let v = rng.normal();
                a[(i, j)] = v;
                a[(j, i)] = v;
            }
        }
        a
    }

    #[test]
    fn eig_of_diagonal() {
        let mut a = Mat::zeros(3, 3);
        a[(0, 0)] = 3.0;
        a[(1, 1)] = -1.0;
        a[(2, 2)] = 7.0;
        let (mut vals, _) = eigh(&a).unwrap();
        vals.sort_by(|x, y| x.partial_cmp(y).unwrap());
        assert!((vals[0] + 1.0).abs() < 1e-5);
        assert!((vals[1] - 3.0).abs() < 1e-5);
        assert!((vals[2] - 7.0).abs() < 1e-5);
    }

    #[test]
    fn reconstruction() {
        let a = random_sym(12, 4);
        let (vals, vecs) = eigh(&a).unwrap();
        // A ≈ V diag(vals) Vᵀ
        let mut d = Mat::zeros(12, 12);
        for i in 0..12 {
            d[(i, i)] = vals[i];
        }
        let rec = vecs.matmul(&d).unwrap().matmul(&vecs.transpose()).unwrap();
        assert!(rec.dist(&a) < 1e-3, "dist={}", rec.dist(&a));
    }

    #[test]
    fn eigenvectors_orthonormal() {
        let a = random_sym(16, 9);
        let (_, vecs) = eigh(&a).unwrap();
        let vtv = vecs.transpose().matmul(&vecs).unwrap();
        assert!(vtv.dist(&Mat::eye(16)) < 1e-4);
    }

    #[test]
    fn sqrtm_squares_back() {
        // build PSD: B Bᵀ
        let b = random_sym(10, 17);
        let psd = b.matmul(&b.transpose()).unwrap();
        let r = sqrtm_psd(&psd).unwrap();
        let r2 = r.matmul(&r).unwrap();
        assert!(r2.dist(&psd) < 1e-2 * (1.0 + psd.trace().abs()), "dist={}", r2.dist(&psd));
    }

    #[test]
    fn sqrtm_of_identity() {
        let r = sqrtm_psd(&Mat::eye(8)).unwrap();
        assert!(r.dist(&Mat::eye(8)) < 1e-5);
    }

    #[test]
    fn rejects_nonsquare() {
        assert!(eigh(&Mat::zeros(2, 3)).is_err());
    }
}
