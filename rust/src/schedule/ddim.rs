//! DDIM sampler (Song et al., the paper's primary solver; Eq. 3).

use std::sync::Arc;

use crate::util::rng::Rng;

use super::ddpm::Schedule;
use super::Sampler;

/// DDIM over a timestep subsequence tau with stochasticity eta
/// (eta = 1 -> DDPM-like, eta = 0 -> deterministic; paper uses both).
pub struct DdimSampler {
    sched: Arc<Schedule>,
    tau: Vec<usize>,
    i: usize,
    eta: f32,
}

impl DdimSampler {
    pub fn new(sched: Arc<Schedule>, tau: Vec<usize>, eta: f32) -> DdimSampler {
        assert!(!tau.is_empty());
        DdimSampler { sched, tau, i: 0, eta }
    }
}

impl Sampler for DdimSampler {
    fn current_t(&self) -> f32 {
        self.tau[self.i] as f32
    }

    fn observe(&mut self, x: &mut [f32], eps: &[f32], rng: &mut Rng) {
        let t = self.tau[self.i];
        let abar_t = self.sched.abar[t];
        let abar_prev = self.sched.abar_prev(&self.tau, self.i);
        let sigma = self.eta
            * ((1.0 - abar_prev) / (1.0 - abar_t)).sqrt()
            * (1.0 - abar_t / abar_prev).sqrt();
        let c_x0 = abar_prev.sqrt();
        let dir = (1.0 - abar_prev - sigma * sigma).max(0.0).sqrt();
        let sa = abar_t.sqrt();
        let sb = (1.0 - abar_t).sqrt();
        let last = self.i + 1 == self.tau.len();
        for (xi, &ei) in x.iter_mut().zip(eps) {
            let x0 = (*xi - sb * ei) / sa;
            let mut v = c_x0 * x0 + dir * ei;
            if sigma > 0.0 && !last {
                v += sigma * rng.normal();
            }
            *xi = v;
        }
        self.i += 1;
    }

    fn done(&self) -> bool {
        self.i >= self.tau.len()
    }

    fn total_evals(&self) -> usize {
        self.tau.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::schedule::timestep_subsequence;

    /// A "model" that exactly predicts the noise of a known x0: sampling
    /// from x_T built by the forward process must recover x0 (eta = 0).
    #[test]
    fn recovers_x0_with_oracle_eps() {
        let sched = Arc::new(Schedule::linear(100));
        let tau = timestep_subsequence(100, 100);
        let mut rng = Rng::new(1);
        let x0: Vec<f32> = (0..16).map(|_| rng.normal()).collect();
        let noise: Vec<f32> = (0..16).map(|_| rng.normal()).collect();
        let t_start = tau[0];
        let (a, b) = sched.forward_coeffs(t_start);
        let mut x: Vec<f32> = x0.iter().zip(&noise).map(|(x0, n)| a * x0 + b * n).collect();

        let mut s = DdimSampler::new(Arc::clone(&sched), tau, 0.0);
        while !s.done() {
            let t = s.current_t() as usize;
            // oracle eps: the exact noise content of x at step t
            let (at, bt) = sched.forward_coeffs(t);
            let eps: Vec<f32> = x.iter().zip(&x0).map(|(xt, x0)| (xt - at * x0) / bt).collect();
            s.observe(&mut x, &eps, &mut rng);
        }
        for (a, b) in x.iter().zip(&x0) {
            assert!((a - b).abs() < 1e-2, "{a} vs {b}");
        }
    }

    #[test]
    fn deterministic_when_eta_zero() {
        let sched = Arc::new(Schedule::linear(50));
        let tau = timestep_subsequence(50, 10);
        let run = |seed: u64| {
            let mut rng = Rng::new(seed);
            let mut x: Vec<f32> = (0..8).map(|i| (i as f32 * 0.37).sin()).collect();
            let mut s = DdimSampler::new(Arc::clone(&sched), tau.clone(), 0.0);
            while !s.done() {
                let eps: Vec<f32> = x.iter().map(|v| v * 0.1).collect();
                s.observe(&mut x, &eps, &mut rng);
            }
            x
        };
        assert_eq!(run(1), run(2)); // rng must not matter at eta=0
    }

    #[test]
    fn eta_one_is_stochastic() {
        let sched = Arc::new(Schedule::linear(50));
        let tau = timestep_subsequence(50, 10);
        let run = |seed: u64| {
            let mut rng = Rng::new(seed);
            let mut x: Vec<f32> = vec![0.5; 8];
            let mut s = DdimSampler::new(Arc::clone(&sched), tau.clone(), 1.0);
            while !s.done() {
                let eps: Vec<f32> = x.iter().map(|v| v * 0.1).collect();
                s.observe(&mut x, &eps, &mut rng);
            }
            x
        };
        assert_ne!(run(1), run(2));
    }

    #[test]
    fn eval_count() {
        let sched = Arc::new(Schedule::linear(100));
        let s = DdimSampler::new(sched, timestep_subsequence(100, 20), 0.0);
        assert_eq!(s.total_evals(), 20);
    }
}
