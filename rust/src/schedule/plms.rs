//! PLMS (pseudo linear multistep) sampler — Liu et al., used by the paper's
//! Table 10. Adams-Bashforth style extrapolation over the eps history with
//! Runge-Kutta-flavored warmup replaced by lower-order multistep (the
//! common practical variant), then a deterministic DDIM-style transfer.

use std::sync::Arc;

use crate::util::rng::Rng;

use super::ddpm::Schedule;
use super::Sampler;

pub struct PlmsSampler {
    sched: Arc<Schedule>,
    tau: Vec<usize>,
    i: usize,
    hist: Vec<Vec<f32>>, // most recent last
}

impl PlmsSampler {
    pub fn new(sched: Arc<Schedule>, tau: Vec<usize>) -> PlmsSampler {
        assert!(!tau.is_empty());
        PlmsSampler { sched, tau, i: 0, hist: Vec::new() }
    }

    /// Adams-Bashforth blend of the eps history (orders 1..4).
    fn blended_eps(&self, eps: &[f32]) -> Vec<f32> {
        let h = &self.hist;
        match h.len() {
            0 => eps.to_vec(),
            1 => eps.iter().zip(&h[0]).map(|(e, p)| (3.0 * e - p) / 2.0).collect(),
            2 => eps
                .iter()
                .enumerate()
                .map(|(k, e)| (23.0 * e - 16.0 * h[1][k] + 5.0 * h[0][k]) / 12.0)
                .collect(),
            _ => {
                let n = h.len();
                eps.iter()
                    .enumerate()
                    .map(|(k, e)| {
                        (55.0 * e - 59.0 * h[n - 1][k] + 37.0 * h[n - 2][k] - 9.0 * h[n - 3][k])
                            / 24.0
                    })
                    .collect()
            }
        }
    }
}

impl Sampler for PlmsSampler {
    fn current_t(&self) -> f32 {
        self.tau[self.i] as f32
    }

    fn observe(&mut self, x: &mut [f32], eps: &[f32], _rng: &mut Rng) {
        let blended = self.blended_eps(eps);
        let t = self.tau[self.i];
        let abar_t = self.sched.abar[t];
        let abar_prev = self.sched.abar_prev(&self.tau, self.i);
        let sa = abar_t.sqrt();
        let sb = (1.0 - abar_t).sqrt();
        let c_x0 = abar_prev.sqrt();
        let dir = (1.0 - abar_prev).sqrt();
        for (xi, &bi) in x.iter_mut().zip(&blended) {
            let x0 = (*xi - sb * bi) / sa;
            *xi = c_x0 * x0 + dir * bi;
        }
        self.hist.push(eps.to_vec());
        if self.hist.len() > 3 {
            self.hist.remove(0);
        }
        self.i += 1;
    }

    fn done(&self) -> bool {
        self.i >= self.tau.len()
    }

    fn total_evals(&self) -> usize {
        self.tau.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::schedule::timestep_subsequence;

    #[test]
    fn recovers_x0_with_oracle_eps() {
        let sched = Arc::new(Schedule::linear(100));
        let tau = timestep_subsequence(100, 50);
        let mut rng = Rng::new(2);
        let x0: Vec<f32> = (0..16).map(|_| rng.normal()).collect();
        let noise: Vec<f32> = (0..16).map(|_| rng.normal()).collect();
        let (a, b) = sched.forward_coeffs(tau[0]);
        let mut x: Vec<f32> = x0.iter().zip(&noise).map(|(x0, n)| a * x0 + b * n).collect();
        let mut s = PlmsSampler::new(Arc::clone(&sched), tau);
        while !s.done() {
            let t = s.current_t() as usize;
            let (at, bt) = sched.forward_coeffs(t);
            let eps: Vec<f32> = x.iter().zip(&x0).map(|(xt, x0)| (xt - at * x0) / bt).collect();
            s.observe(&mut x, &eps, &mut rng);
        }
        for (a, b) in x.iter().zip(&x0) {
            assert!((a - b).abs() < 5e-2, "{a} vs {b}");
        }
    }

    #[test]
    fn history_capped() {
        let sched = Arc::new(Schedule::linear(100));
        let tau = timestep_subsequence(100, 20);
        let mut s = PlmsSampler::new(sched, tau);
        let mut rng = Rng::new(3);
        let mut x = vec![0.3f32; 4];
        for _ in 0..10 {
            let eps = vec![0.1f32; 4];
            s.observe(&mut x, &eps, &mut rng);
        }
        assert!(s.hist.len() <= 3);
    }

    #[test]
    fn multistep_blend_weights_sum_to_one() {
        // each AB order must be an affine combination (weights sum to 1) —
        // feeding a constant eps history must return that constant.
        let sched = Arc::new(Schedule::linear(100));
        let mut s = PlmsSampler::new(sched, vec![99, 50, 25, 12, 6, 0]);
        let eps = vec![0.7f32; 4];
        for _ in 0..5 {
            let blended = s.blended_eps(&eps);
            for b in &blended {
                assert!((b - 0.7).abs() < 1e-6);
            }
            s.hist.push(eps.clone());
            if s.hist.len() > 3 {
                s.hist.remove(0);
            }
        }
    }
}
