//! DPM-Solver-2 (Lu et al.) — the second fast solver of the paper's
//! Table 10. Second-order midpoint method in log-SNR (λ) space; two model
//! evaluations per step, expressed as a state machine so the serving
//! coordinator can batch each evaluation independently.

use std::sync::Arc;

use crate::util::rng::Rng;

use super::ddpm::Schedule;
use super::Sampler;

/// Continuous-time helpers: α̂ = sqrt(ᾱ), σ̂ = sqrt(1-ᾱ), λ = ln(α̂/σ̂).
fn lambda_of(abar: f32) -> f32 {
    let a = abar.sqrt();
    let s = (1.0 - abar).sqrt().max(1e-12);
    (a / s).ln()
}

enum Phase {
    /// waiting for eps at t_i (start of step i)
    First,
    /// waiting for eps at the λ-midpoint; carries x_i and eps(t_i)
    Mid { x_prev: Vec<f32> },
}

pub struct DpmSolver2 {
    sched: Arc<Schedule>,
    tau: Vec<usize>,
    /// interpolated ᾱ at the midpoint of each (tau[i], tau[i+1]) pair
    mid_abar: Vec<f32>,
    i: usize,
    phase: Phase,
}

impl DpmSolver2 {
    pub fn new(sched: Arc<Schedule>, tau: Vec<usize>) -> DpmSolver2 {
        assert!(tau.len() >= 2, "DPM-Solver-2 needs >= 2 timesteps");
        // midpoint in λ-space between consecutive tau entries, realized as
        // the ᾱ whose λ is the average.
        let mid_abar = (0..tau.len() - 1)
            .map(|i| {
                let l0 = lambda_of(sched.abar[tau[i]]);
                let l1 = lambda_of(sched.abar[tau[i + 1]]);
                let lm = 0.5 * (l0 + l1);
                // invert λ: ᾱ = sigmoid(2λ)
                1.0 / (1.0 + (-2.0 * lm).exp())
            })
            .collect();
        DpmSolver2 { sched, tau, mid_abar, i: 0, phase: Phase::First }
    }

    /// the ᾱ the *next requested evaluation* sees
    fn eval_abar(&self) -> f32 {
        match self.phase {
            Phase::First => self.sched.abar[self.tau[self.i]],
            Phase::Mid { .. } => self.mid_abar[self.i],
        }
    }

    /// map an ᾱ to a (possibly fractional) model timestep by inverting the
    /// discrete schedule with linear interpolation.
    fn t_of_abar(&self, abar: f32) -> f32 {
        let ab = &self.sched.abar;
        if abar >= ab[0] {
            return 0.0;
        }
        for t in 1..ab.len() {
            if ab[t] <= abar {
                let hi = ab[t - 1];
                let lo = ab[t];
                let frac = if hi > lo { (hi - abar) / (hi - lo) } else { 0.0 };
                return (t - 1) as f32 + frac;
            }
        }
        (ab.len() - 1) as f32
    }
}

impl Sampler for DpmSolver2 {
    fn current_t(&self) -> f32 {
        self.t_of_abar(self.eval_abar())
    }

    fn observe(&mut self, x: &mut [f32], eps: &[f32], _rng: &mut Rng) {
        let abar_i = self.sched.abar[self.tau[self.i]];
        let abar_next = self.sched.abar[self.tau[self.i + 1]];
        let (li, ln_) = (lambda_of(abar_i), lambda_of(abar_next));
        let h = ln_ - li;
        match std::mem::replace(&mut self.phase, Phase::First) {
            Phase::First => {
                // half step to the midpoint
                let abar_m = self.mid_abar[self.i];
                let (am, sm) = (abar_m.sqrt(), (1.0 - abar_m).sqrt());
                let (ai, _si) = (abar_i.sqrt(), (1.0 - abar_i).sqrt());
                let x_prev = x.to_vec();
                let phi_half = ((h / 2.0).exp() - 1.0) as f32;
                for (xm, (&xi, &ei)) in x.iter_mut().zip(x_prev.iter().zip(eps)) {
                    *xm = (am / ai) * xi - sm * phi_half * ei;
                }
                self.phase = Phase::Mid { x_prev };
            }
            Phase::Mid { x_prev } => {
                // full step using the midpoint slope
                let (an, sn) = (abar_next.sqrt(), (1.0 - abar_next).sqrt());
                let ai = abar_i.sqrt();
                let phi = (h.exp() - 1.0) as f32;
                for (xo, (&xi, &em)) in x.iter_mut().zip(x_prev.iter().zip(eps)) {
                    *xo = (an / ai) * xi - sn * phi * em;
                }
                self.i += 1;
                self.phase = Phase::First;
            }
        }
    }

    fn done(&self) -> bool {
        self.i >= self.tau.len() - 1
    }

    fn total_evals(&self) -> usize {
        2 * (self.tau.len() - 1)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::schedule::timestep_subsequence;

    #[test]
    fn lambda_monotone_in_abar() {
        let mut prev = f32::NEG_INFINITY;
        for i in 1..20 {
            let l = lambda_of(i as f32 / 20.0);
            assert!(l > prev);
            prev = l;
        }
    }

    fn oracle_run(steps: usize) -> f32 {
        let sched = Arc::new(Schedule::linear(100));
        let tau = timestep_subsequence(100, steps);
        let mut rng = Rng::new(4);
        let x0: Vec<f32> = (0..16).map(|_| rng.normal()).collect();
        let noise: Vec<f32> = (0..16).map(|_| rng.normal()).collect();
        let (a, b) = sched.forward_coeffs(tau[0]);
        let mut x: Vec<f32> = x0.iter().zip(&noise).map(|(x0, n)| a * x0 + b * n).collect();
        let mut s = DpmSolver2::new(Arc::clone(&sched), tau);
        while !s.done() {
            // oracle eps at the (fractional) requested abar
            let abar = s.eval_abar();
            let (at, bt) = (abar.sqrt(), (1.0 - abar).sqrt());
            let eps: Vec<f32> = x.iter().zip(&x0).map(|(xt, x0)| (xt - at * x0) / bt).collect();
            s.observe(&mut x, &eps, &mut rng);
        }
        x.iter().zip(&x0).map(|(a, b)| (a - b).abs()).fold(0.0f32, f32::max)
    }

    #[test]
    fn recovers_x0_with_oracle_eps() {
        // second-order solver: moderate error at 20 steps over a coarse
        // 100-step schedule, and the error must shrink with more steps.
        let e20 = oracle_run(20);
        let e40 = oracle_run(40);
        assert!(e20 < 0.15, "e20={e20}");
        assert!(e40 < e20, "e40={e40} e20={e20}");
    }

    #[test]
    fn eval_count_is_double() {
        let sched = Arc::new(Schedule::linear(100));
        let s = DpmSolver2::new(sched, timestep_subsequence(100, 20));
        assert_eq!(s.total_evals(), 38);
    }

    #[test]
    fn t_of_abar_inverts_schedule() {
        let sched = Arc::new(Schedule::linear(100));
        let s = DpmSolver2::new(Arc::clone(&sched), vec![99, 50, 0]);
        for t in [0usize, 30, 70, 99] {
            let back = s.t_of_abar(sched.abar[t]);
            assert!((back - t as f32).abs() < 0.51, "t={t} back={back}");
        }
    }

    #[test]
    fn runs_to_completion() {
        let sched = Arc::new(Schedule::linear(100));
        let mut s = DpmSolver2::new(Arc::clone(&sched), timestep_subsequence(100, 10));
        let mut rng = Rng::new(5);
        let mut x = vec![0.2f32; 8];
        let mut evals = 0;
        while !s.done() {
            let eps = vec![0.05f32; 8];
            s.observe(&mut x, &eps, &mut rng);
            evals += 1;
            assert!(evals <= 100, "runaway sampler");
        }
        assert_eq!(evals, s.total_evals());
        assert!(x.iter().all(|v| v.is_finite()));
    }
}
