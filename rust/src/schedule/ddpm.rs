//! The DDPM noise schedule (paper Eq. 1-4).

/// Linear-beta DDPM schedule over T steps.
#[derive(Debug, Clone)]
pub struct Schedule {
    pub t_total: usize,
    pub betas: Vec<f32>,
    pub alphas: Vec<f32>,
    /// ᾱ_t = Π α_i (paper Eq. 2)
    pub abar: Vec<f32>,
}

impl Schedule {
    /// Linear schedule; defaults follow DDPM's (1e-4, 0.02) scaled for T.
    pub fn linear(t_total: usize) -> Schedule {
        // Scale the 1000-step endpoints so total noise injected is similar:
        // beta_end scaled by 1000/T keeps ᾱ_T small for short schedules.
        let scale = (1000.0 / t_total as f32).min(10.0);
        Self::linear_with(t_total, 1e-4 * scale, 0.02 * scale)
    }

    pub fn linear_with(t_total: usize, beta_start: f32, beta_end: f32) -> Schedule {
        assert!(t_total >= 1);
        let betas: Vec<f32> = (0..t_total)
            .map(|i| {
                if t_total == 1 {
                    beta_start
                } else {
                    beta_start + (beta_end - beta_start) * i as f32 / (t_total - 1) as f32
                }
            })
            .collect();
        let alphas: Vec<f32> = betas.iter().map(|b| 1.0 - b).collect();
        let mut abar = Vec::with_capacity(t_total);
        let mut acc = 1.0f32;
        for &a in &alphas {
            acc *= a;
            abar.push(acc);
        }
        Schedule { t_total, betas, alphas, abar }
    }

    /// The paper's denoising factor γ_t (Eq. 4): the weight of the
    /// predicted noise in the reverse update — the DFA loss multiplier.
    pub fn gamma(&self, t: usize) -> f32 {
        let a = self.alphas[t];
        (1.0 / a.sqrt()) * (1.0 - a) / (1.0 - self.abar[t]).sqrt()
    }

    /// ᾱ for the step *before* tau index i (ᾱ_{-1} := 1).
    pub fn abar_prev(&self, tau: &[usize], i: usize) -> f32 {
        if i + 1 < tau.len() {
            self.abar[tau[i + 1]]
        } else {
            1.0
        }
    }

    /// Forward process q(x_t | x_0) coefficients (Eq. 1).
    pub fn forward_coeffs(&self, t: usize) -> (f32, f32) {
        (self.abar[t].sqrt(), (1.0 - self.abar[t]).sqrt())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn abar_monotone_decreasing() {
        let s = Schedule::linear(100);
        assert!(s.abar.windows(2).all(|w| w[1] < w[0]));
        assert!(s.abar[0] < 1.0 && s.abar[0] > 0.99);
        assert!(s.abar[99] < 0.05, "abar_T={}", s.abar[99]);
    }

    #[test]
    fn gamma_positive_and_growing() {
        // γ_t grows toward the end of the forward process (large t):
        // the paper's Fig. 3 argument that eps matters most early in
        // denoising (t near T).
        let s = Schedule::linear(100);
        for t in 0..100 {
            assert!(s.gamma(t) > 0.0);
        }
        assert!(s.gamma(99) > s.gamma(0));
    }

    #[test]
    fn forward_coeffs_norm() {
        // a² + s² = 1 would hold for variance-preserving; check consistency
        let s = Schedule::linear(100);
        for t in [0, 50, 99] {
            let (a, b) = s.forward_coeffs(t);
            assert!((a * a + b * b - 1.0).abs() < 1e-5);
        }
    }

    #[test]
    fn abar_prev_boundary() {
        let s = Schedule::linear(10);
        let tau = vec![9, 5, 0];
        assert_eq!(s.abar_prev(&tau, 0), s.abar[5]);
        assert_eq!(s.abar_prev(&tau, 2), 1.0);
    }

    #[test]
    fn short_schedule_still_noisy() {
        let s = Schedule::linear(20);
        assert!(s.abar[19] < 0.2, "abar_T={}", s.abar[19]);
    }
}
