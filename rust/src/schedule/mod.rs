//! Diffusion schedule + samplers.
//!
//! The schedule owns the noise process (betas, ᾱ_t, the paper's denoising
//! factor γ_t — Eq. 4) and the samplers consume per-step eps predictions as
//! *state machines*: the serving coordinator batches model evaluations
//! across requests, so a sampler never calls the model itself — it exposes
//! the timestep it needs next and `observe()`s the prediction.

pub mod ddpm;
pub mod ddim;
pub mod plms;
pub mod dpm_solver;

pub use ddim::DdimSampler;
pub use ddpm::Schedule;
pub use dpm_solver::DpmSolver2;
pub use plms::PlmsSampler;

use crate::util::rng::Rng;

/// A sampler drives one request's latent through the reverse process.
/// Contract: while `!done()`, the coordinator evaluates eps_theta(x, t)
/// with `t = current_t()` and calls `observe(x, eps, rng)`, which mutates
/// x in place (one eval may or may not complete a "step" — DPM-Solver-2
/// uses two evals per step).
pub trait Sampler: Send {
    fn current_t(&self) -> f32;
    fn observe(&mut self, x: &mut [f32], eps: &[f32], rng: &mut Rng);
    fn done(&self) -> bool;
    /// Total model evaluations this sampler will request.
    fn total_evals(&self) -> usize;
}

/// Build the evenly spaced timestep subsequence tau (descending), e.g.
/// T=100, steps=20 -> [95, 90, ..., 0].
pub fn timestep_subsequence(t_total: usize, steps: usize) -> Vec<usize> {
    assert!(steps >= 1 && steps <= t_total);
    let stride = t_total as f64 / steps as f64;
    let mut tau: Vec<usize> = (0..steps).map(|i| (i as f64 * stride) as usize).collect();
    tau.dedup();
    tau.reverse();
    tau
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn subsequence_full() {
        let tau = timestep_subsequence(100, 100);
        assert_eq!(tau.len(), 100);
        assert_eq!(tau[0], 99);
        assert_eq!(tau[99], 0);
    }

    #[test]
    fn subsequence_strided() {
        let tau = timestep_subsequence(100, 20);
        assert_eq!(tau.len(), 20);
        assert_eq!(*tau.last().unwrap(), 0);
        assert!(tau.windows(2).all(|w| w[0] > w[1]));
    }

    #[test]
    fn subsequence_single() {
        assert_eq!(timestep_subsequence(100, 1), vec![0]);
    }
}
