//! Search-based quantizer initialization — the paper's Algorithm 1.
//!
//! Every candidate (format × maxval × zp) is scored by the MSE between the
//! calibration samples and their fake-quantized image, computed with the
//! *deployed* numerics (quant::fp / quant::int). Stage 1 searches signed FP
//! for all layers; stage 2 additionally searches unsigned FP + zero-point
//! for AALs and keeps the winner (the mixup).

use super::fp::{fp_qdq_signed, fp_qdq_signed_zp, fp_qdq_unsigned};
use super::format::{self, FpFormat};
use super::int::{int_qdq_asym, int_qdq_sym};

/// A fully specified quantizer, encodable into a qparams row half
/// (see manifest "qparams_row").
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Quantizer {
    SignedFp { fmt: FpFormat, maxval: f32 },
    UnsignedFp { fmt: FpFormat, maxval: f32, zp: f32 },
    IntSym { n_bits: i32, maxval: f32 },
    IntAsym { n_bits: i32, lo: f32, hi: f32 },
}

impl Quantizer {
    #[inline]
    pub fn qdq(&self, x: f32) -> f32 {
        match *self {
            Quantizer::SignedFp { fmt, maxval } => fp_qdq_signed(x, maxval, fmt.e_bits, fmt.m_bits),
            Quantizer::UnsignedFp { fmt, maxval, zp } => {
                fp_qdq_unsigned(x, maxval, fmt.e_bits, fmt.m_bits, zp)
            }
            Quantizer::IntSym { n_bits, maxval } => int_qdq_sym(x, maxval, n_bits),
            Quantizer::IntAsym { n_bits, lo, hi } => int_qdq_asym(x, lo, hi, n_bits),
        }
    }

    /// MSE against samples under this quantizer.
    pub fn mse(&self, xs: &[f32]) -> f64 {
        let mut acc = 0.0f64;
        for &x in xs {
            let d = (self.qdq(x) - x) as f64;
            acc += d * d;
        }
        acc / xs.len().max(1) as f64
    }

    /// Encode as the activation half of a qparams row:
    /// [a_sign, a_maxval, a_ebits, a_mbits, a_zp].
    pub fn encode_act(&self) -> [f32; 5] {
        match *self {
            Quantizer::SignedFp { fmt, maxval } => {
                [1.0, maxval, fmt.e_bits as f32, fmt.m_bits as f32, 0.0]
            }
            Quantizer::UnsignedFp { fmt, maxval, zp } => {
                [0.0, maxval, fmt.e_bits as f32, fmt.m_bits as f32, zp]
            }
            Quantizer::IntSym { n_bits, maxval } => [1.0, maxval, -1.0, n_bits as f32, 0.0],
            Quantizer::IntAsym { n_bits, lo, hi } => [0.0, hi, -1.0, n_bits as f32, lo],
        }
    }

    /// Encode as the weight half of a qparams row:
    /// [w_maxval, w_ebits, w_mbits].
    pub fn encode_weight(&self) -> [f32; 3] {
        match *self {
            Quantizer::SignedFp { fmt, maxval } => [maxval, fmt.e_bits as f32, fmt.m_bits as f32],
            Quantizer::IntSym { n_bits, maxval } => [maxval, -1.0, n_bits as f32],
            _ => panic!("weight quantizer must be signed ({self:?})"),
        }
    }
}

/// Result of a search: the winner and its calibration MSE.
#[derive(Debug, Clone, Copy)]
pub struct SearchResult {
    pub quantizer: Quantizer,
    pub mse: f64,
}

fn argmin(cands: impl Iterator<Item = (Quantizer, f64)>) -> SearchResult {
    let mut best = SearchResult {
        quantizer: Quantizer::SignedFp { fmt: FpFormat::new(1, 1), maxval: 1.0 },
        mse: f64::INFINITY,
    };
    for (q, mse) in cands {
        if mse < best.mse {
            best = SearchResult { quantizer: q, mse };
        }
    }
    best
}

/// linspace with `n` points from lo to hi inclusive.
pub fn linspace(lo: f32, hi: f32, n: usize) -> Vec<f32> {
    if n == 1 {
        return vec![lo];
    }
    (0..n).map(|i| lo + (hi - lo) * i as f32 / (n - 1) as f32).collect()
}

/// Stage-1 signed FP search (Algorithm 1 lines 6-16).
pub fn search_signed(xs: &[f32], formats: &[FpFormat], maxvals: &[f32]) -> SearchResult {
    argmin(formats.iter().flat_map(|&fmt| {
        maxvals.iter().filter(|m| **m > 0.0).map(move |&maxval| {
            let q = Quantizer::SignedFp { fmt, maxval };
            (q, q.mse(xs))
        })
    }))
}

/// Stage-2 unsigned FP + zero-point search (Algorithm 1 lines 20-32).
pub fn search_unsigned(
    xs: &[f32],
    formats: &[FpFormat],
    maxvals: &[f32],
    zps: &[f32],
) -> SearchResult {
    argmin(formats.iter().flat_map(|&fmt| {
        maxvals.iter().filter(|m| **m > 0.0).flat_map(move |&maxval| {
            zps.iter().map(move |&zp| {
                let q = Quantizer::UnsignedFp { fmt, maxval, zp };
                (q, q.mse(xs))
            })
        })
    }))
}

/// Weight search: signed FP over the Table-6 spaces. `maxval0` is the
/// absolute max of the tensor; `space` overrides the (lo,hi) fractions for
/// the Table-5 sweep. `maxval_points` controls grid resolution.
pub fn search_weight_fp(
    w: &[f32],
    bits: i32,
    space: Option<(f32, f32)>,
    maxval_points: usize,
) -> SearchResult {
    let maxval0 = w.iter().fold(0.0f32, |a, &b| a.max(b.abs())).max(1e-8);
    let (lo, hi) = space.unwrap_or_else(|| format::weight_maxval_space(bits));
    let maxvals = linspace(lo * maxval0, hi * maxval0, maxval_points);
    search_signed(w, &format::weight_formats(bits), &maxvals)
}

/// Activation MSFP search. `maxval0` comes from the random-forward capture
/// (Appendix C); AALs run both stages and keep the winner.
pub fn search_act_msfp(
    xs: &[f32],
    bits: i32,
    maxval0: f32,
    is_aal: bool,
    maxval_points: usize,
) -> SearchResult {
    let maxvals = linspace(maxval0 / maxval_points as f32, maxval0, maxval_points);
    let mut best = search_signed(xs, &format::act_signed_formats(bits), &maxvals);
    if is_aal {
        let u = search_unsigned(xs, &format::act_unsigned_formats(bits), &maxvals, &format::zp_space());
        if u.mse < best.mse {
            best = u;
        }
    }
    best
}

/// INT baseline searches -------------------------------------------------

/// MinMax INT weight quantizer (Q-Diffusion-style start).
pub fn int_weight_minmax(w: &[f32], bits: i32) -> Quantizer {
    let maxval = w.iter().fold(0.0f32, |a, &b| a.max(b.abs())).max(1e-8);
    Quantizer::IntSym { n_bits: bits, maxval }
}

/// MSE-searched symmetric INT (Q-Diffusion/EDA-DM-style reconstruction).
pub fn search_weight_int(w: &[f32], bits: i32, maxval_points: usize) -> SearchResult {
    let maxval0 = w.iter().fold(0.0f32, |a, &b| a.max(b.abs())).max(1e-8);
    argmin(linspace(0.3 * maxval0, maxval0, maxval_points).into_iter().map(|m| {
        let q = Quantizer::IntSym { n_bits: bits, maxval: m };
        (q, q.mse(w))
    }))
}

/// MSE-searched asymmetric INT for activations.
pub fn search_act_int(xs: &[f32], bits: i32, min: f32, max: f32, points: usize) -> SearchResult {
    let lo0 = min.min(0.0);
    let hi0 = max.max(1e-8);
    argmin(linspace(0.3, 1.0, points).into_iter().flat_map(|s| {
        linspace(0.5, 1.0, (points / 2).max(1)).into_iter().map(move |sl| {
            let q = Quantizer::IntAsym { n_bits: bits, lo: lo0 * sl, hi: hi0 * s };
            (q, q.mse(xs))
        })
    }))
}

/// The four Figure-4 strategies evaluated on one AAL's samples, returning
/// MSEs normalized against plain signed FP (strategy 1): signed, signed+zp,
/// unsigned (no zp), unsigned+zp.
pub fn fig4_strategies(xs: &[f32], bits: i32, maxval0: f32, points: usize) -> [f64; 4] {
    let maxvals = linspace(maxval0 / points as f32, maxval0, points);
    let zps = format::zp_space();
    let signed = search_signed(xs, &format::act_signed_formats(bits), &maxvals).mse;

    // signed + zp: offline-only variant (fp_qdq_signed_zp)
    let mut signed_zp = f64::INFINITY;
    for fmt in format::act_signed_formats(bits) {
        for &m in &maxvals {
            for &zp in &zps {
                let mse = xs
                    .iter()
                    .map(|&x| {
                        let d = (fp_qdq_signed_zp(x, m, fmt.e_bits, fmt.m_bits, zp) - x) as f64;
                        d * d
                    })
                    .sum::<f64>()
                    / xs.len().max(1) as f64;
                signed_zp = signed_zp.min(mse);
            }
        }
    }

    let unsigned_nozp =
        search_unsigned(xs, &format::act_unsigned_formats(bits), &maxvals, &[0.0]).mse;
    let unsigned_zp =
        search_unsigned(xs, &format::act_unsigned_formats(bits), &maxvals, &zps).mse;

    let base = signed.max(1e-18);
    [signed / base, signed_zp / base, unsigned_nozp / base, unsigned_zp / base]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn silu_samples(n: usize, seed: u64) -> Vec<f32> {
        let mut rng = Rng::new(seed);
        (0..n)
            .map(|_| {
                let x = rng.normal() * 2.0;
                x / (1.0 + (-x).exp())
            })
            .collect()
    }

    fn normal_samples(n: usize, seed: u64) -> Vec<f32> {
        let mut rng = Rng::new(seed);
        (0..n).map(|_| rng.normal()).collect()
    }

    #[test]
    fn search_finds_low_mse_signed() {
        let xs = normal_samples(2048, 1);
        let r = search_signed(&xs, &format::act_signed_formats(6), &linspace(0.5, 5.0, 40));
        assert!(r.mse < 1e-3, "mse={}", r.mse);
    }

    #[test]
    fn aal_search_prefers_unsigned_at_4bit() {
        // the paper's core claim (Fig. 4): unsigned+zp wins on > 95% of AALs
        let xs = silu_samples(4096, 2);
        let maxval0 = xs.iter().fold(0.0f32, |a, &b| a.max(b.abs()));
        let r = search_act_msfp(&xs, 4, maxval0 * 1.2, true, 40);
        assert!(matches!(r.quantizer, Quantizer::UnsignedFp { .. }), "{:?}", r);
    }

    #[test]
    fn nal_search_stays_signed() {
        let xs = normal_samples(4096, 3);
        let maxval0 = xs.iter().fold(0.0f32, |a, &b| a.max(b.abs()));
        let r = search_act_msfp(&xs, 4, maxval0, false, 40);
        assert!(matches!(r.quantizer, Quantizer::SignedFp { .. }));
    }

    #[test]
    fn mixup_never_worse_than_signed_only() {
        for seed in 0..5 {
            let xs = silu_samples(2048, 100 + seed);
            let maxval0 = xs.iter().fold(0.0f32, |a, &b| a.max(b.abs()));
            let signed = search_act_msfp(&xs, 4, maxval0, false, 30);
            let mixup = search_act_msfp(&xs, 4, maxval0, true, 30);
            assert!(mixup.mse <= signed.mse + 1e-12);
        }
    }

    #[test]
    fn weight_search_beats_minmax_int() {
        let w = normal_samples(4096, 5);
        let fp = search_weight_fp(&w, 4, None, 40);
        let int_mm = int_weight_minmax(&w, 4);
        assert!(fp.mse < int_mm.mse(&w), "{} vs {}", fp.mse, int_mm.mse(&w));
    }

    #[test]
    fn int_mse_search_beats_minmax() {
        let w = normal_samples(4096, 6);
        let s = search_weight_int(&w, 4, 40);
        assert!(s.mse <= int_weight_minmax(&w, 4).mse(&w));
    }

    #[test]
    fn fig4_unsigned_zp_wins_on_silu() {
        let xs = silu_samples(4096, 7);
        let maxval0 = xs.iter().fold(0.0f32, |a, &b| a.max(b.abs()));
        let [s, _szp, _u, uzp] = fig4_strategies(&xs, 4, maxval0 * 1.3, 25);
        assert!((s - 1.0).abs() < 1e-9);
        assert!(uzp < 1.0, "unsigned+zp should beat signed: {uzp}");
    }

    #[test]
    fn encode_roundtrip_semantics() {
        let q = Quantizer::UnsignedFp { fmt: FpFormat::new(2, 2), maxval: 1.5, zp: -0.18 };
        let e = q.encode_act();
        assert_eq!(e, [0.0, 1.5, 2.0, 2.0, -0.18]);
        let w = Quantizer::IntSym { n_bits: 4, maxval: 2.0 };
        assert_eq!(w.encode_weight(), [2.0, -1.0, 4.0]);
    }

    #[test]
    fn mse_higher_bits_monotone() {
        let xs = silu_samples(2048, 8);
        let maxval0 = xs.iter().fold(0.0f32, |a, &b| a.max(b.abs()));
        let m4 = search_act_msfp(&xs, 4, maxval0, true, 30).mse;
        let m6 = search_act_msfp(&xs, 6, maxval0, true, 30).mse;
        let m8 = search_act_msfp(&xs, 8, maxval0, true, 30).mse;
        assert!(m8 < m6 && m6 < m4, "{m8} {m6} {m4}");
    }

    #[test]
    fn linspace_endpoints() {
        let v = linspace(1.0, 2.0, 5);
        assert_eq!(v.len(), 5);
        assert_eq!(v[0], 1.0);
        assert_eq!(v[4], 2.0);
    }
}
