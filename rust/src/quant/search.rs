//! Search-based quantizer initialization — the paper's Algorithm 1.
//!
//! Every candidate (format × maxval × zp) is scored by the MSE between the
//! calibration samples and their fake-quantized image, computed with the
//! *deployed* numerics (quant::fp / quant::int). Stage 1 searches signed FP
//! for all layers; stage 2 additionally searches unsigned FP + zero-point
//! for AALs and keeps the winner (the mixup).
//!
//! Scoring runs on the closed-form grid-segment engine (quant::grid):
//! samples are sorted once per layer, each candidate costs O(G·log N)
//! instead of O(N), and candidates early-abandon against the best score so
//! far. The original O(C·N) per-element path is kept in [`scalar`] as the
//! reference oracle (property tests + the perf_quant oracle bench).

use super::format::{self, FpFormat};
use super::fp::{fp_qdq_signed, fp_qdq_signed_zp, fp_qdq_unsigned};
use super::grid::{self, quantizer_grid, GridEngine};
use super::int::{int_qdq_asym, int_qdq_sym};

/// A fully specified quantizer, encodable into a qparams row half
/// (see manifest "qparams_row").
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Quantizer {
    SignedFp { fmt: FpFormat, maxval: f32 },
    UnsignedFp { fmt: FpFormat, maxval: f32, zp: f32 },
    IntSym { n_bits: i32, maxval: f32 },
    IntAsym { n_bits: i32, lo: f32, hi: f32 },
}

impl Quantizer {
    #[inline]
    pub fn qdq(&self, x: f32) -> f32 {
        match *self {
            Quantizer::SignedFp { fmt, maxval } => fp_qdq_signed(x, maxval, fmt.e_bits, fmt.m_bits),
            Quantizer::UnsignedFp { fmt, maxval, zp } => {
                fp_qdq_unsigned(x, maxval, fmt.e_bits, fmt.m_bits, zp)
            }
            Quantizer::IntSym { n_bits, maxval } => int_qdq_sym(x, maxval, n_bits),
            Quantizer::IntAsym { n_bits, lo, hi } => int_qdq_asym(x, lo, hi, n_bits),
        }
    }

    /// MSE against samples under this quantizer (per-element reference;
    /// the search paths score via quant::grid instead). The difference is
    /// taken in f64 — an f32 subtraction loses up to 2^-24 relative on
    /// clamped outliers, which would swamp the engine's 1e-9 parity bound.
    pub fn mse(&self, xs: &[f32]) -> f64 {
        let mut acc = 0.0f64;
        for &x in xs {
            let d = self.qdq(x) as f64 - x as f64;
            acc += d * d;
        }
        acc / xs.len().max(1) as f64
    }

    /// Encode as the activation half of a qparams row:
    /// [a_sign, a_maxval, a_ebits, a_mbits, a_zp].
    pub fn encode_act(&self) -> [f32; 5] {
        match *self {
            Quantizer::SignedFp { fmt, maxval } => {
                [1.0, maxval, fmt.e_bits as f32, fmt.m_bits as f32, 0.0]
            }
            Quantizer::UnsignedFp { fmt, maxval, zp } => {
                [0.0, maxval, fmt.e_bits as f32, fmt.m_bits as f32, zp]
            }
            Quantizer::IntSym { n_bits, maxval } => [1.0, maxval, -1.0, n_bits as f32, 0.0],
            Quantizer::IntAsym { n_bits, lo, hi } => [0.0, hi, -1.0, n_bits as f32, lo],
        }
    }

    /// Encode as the weight half of a qparams row:
    /// [w_maxval, w_ebits, w_mbits].
    pub fn encode_weight(&self) -> [f32; 3] {
        match *self {
            Quantizer::SignedFp { fmt, maxval } => [maxval, fmt.e_bits as f32, fmt.m_bits as f32],
            Quantizer::IntSym { n_bits, maxval } => [maxval, -1.0, n_bits as f32],
            _ => panic!("weight quantizer must be signed ({self:?})"),
        }
    }
}

/// Result of a search: the winner and its calibration MSE.
#[derive(Debug, Clone, Copy)]
pub struct SearchResult {
    pub quantizer: Quantizer,
    pub mse: f64,
}

/// First-wins argmin over pre-scored candidates; None on an empty set (the
/// old behavior silently returned a dummy E1M1 quantizer with infinite MSE).
fn argmin(cands: impl Iterator<Item = (Quantizer, f64)>) -> Option<SearchResult> {
    let mut best: Option<SearchResult> = None;
    for (q, mse) in cands {
        // NaN-scored candidates (poisoned samples) are never selectable,
        // mirroring the old INF-initialized strict-< loop
        let better = match best {
            Some(b) => mse < b.mse,
            None => true,
        };
        if !mse.is_nan() && better {
            best = Some(SearchResult { quantizer: q, mse });
        }
    }
    best
}

/// linspace with `n` points from lo to hi inclusive.
pub fn linspace(lo: f32, hi: f32, n: usize) -> Vec<f32> {
    if n == 1 {
        return vec![lo];
    }
    (0..n).map(|i| lo + (hi - lo) * i as f32 / (n - 1) as f32).collect()
}

/// Candidate enumerations — shared verbatim by the grid engine and the
/// scalar oracle so both walk the same list in the same order (ties break
/// identically).
fn signed_cands(formats: &[FpFormat], maxvals: &[f32]) -> Vec<Quantizer> {
    formats
        .iter()
        .flat_map(|&fmt| {
            maxvals
                .iter()
                .filter(|m| **m > 0.0)
                .map(move |&maxval| Quantizer::SignedFp { fmt, maxval })
        })
        .collect()
}

/// Enumerate the unsigned candidate space in its canonical order
/// (format → positive maxval → zp). Both the scalar oracle's candidate
/// list and the engine path's shared-base-grid builder walk this exact
/// enumeration, so ties break identically everywhere.
fn for_each_unsigned(
    formats: &[FpFormat],
    maxvals: &[f32],
    mut f: impl FnMut(FpFormat, f32),
) {
    for &fmt in formats {
        for &maxval in maxvals.iter().filter(|m| **m > 0.0) {
            f(fmt, maxval);
        }
    }
}

fn unsigned_cands(formats: &[FpFormat], maxvals: &[f32], zps: &[f32]) -> Vec<Quantizer> {
    let mut out = Vec::new();
    for_each_unsigned(formats, maxvals, |fmt, maxval| {
        for &zp in zps {
            out.push(Quantizer::UnsignedFp { fmt, maxval, zp });
        }
    });
    out
}

fn weight_int_cands(bits: i32, maxval0: f32, maxval_points: usize) -> Vec<Quantizer> {
    linspace(0.3 * maxval0, maxval0, maxval_points)
        .into_iter()
        .map(|m| Quantizer::IntSym { n_bits: bits, maxval: m })
        .collect()
}

fn act_int_cands(bits: i32, min: f32, max: f32, points: usize) -> Vec<Quantizer> {
    let lo0 = min.min(0.0);
    let hi0 = max.max(1e-8);
    linspace(0.3, 1.0, points)
        .into_iter()
        .flat_map(|s| {
            linspace(0.5, 1.0, (points / 2).max(1)).into_iter().map(move |sl| {
                Quantizer::IntAsym { n_bits: bits, lo: lo0 * sl, hi: hi0 * s }
            })
        })
        .collect()
}

/// Stage-1 signed FP search (Algorithm 1 lines 6-16). None when the
/// candidate set is empty (no formats, or no positive maxvals).
pub fn search_signed(xs: &[f32], formats: &[FpFormat], maxvals: &[f32]) -> Option<SearchResult> {
    search_signed_on(&GridEngine::new(xs), formats, maxvals, 1)
}

/// Stage-1 search on a pre-built engine (shares the sort/prefix work
/// across stages; `threads` fans candidates out within the layer).
pub fn search_signed_on(
    eng: &GridEngine,
    formats: &[FpFormat],
    maxvals: &[f32],
    threads: usize,
) -> Option<SearchResult> {
    grid::search_min(eng, &signed_cands(formats, maxvals), threads)
}

/// Stage-2 unsigned FP + zero-point search (Algorithm 1 lines 20-32).
pub fn search_unsigned(
    xs: &[f32],
    formats: &[FpFormat],
    maxvals: &[f32],
    zps: &[f32],
) -> Option<SearchResult> {
    search_unsigned_on(&GridEngine::new(xs), formats, maxvals, zps, 1)
}

/// Stage-2 search on a pre-built engine. The base magnitude grid is
/// generated once per (format, maxval) pair and each zp candidate reuses
/// it through the exact f32 shift `+ zp` — the same add `quantizer_grid`
/// applies — instead of regenerating (and re-sorting) the grid per
/// candidate. Scores are bit-identical: the shift is monotone, and any
/// post-shift duplicate only yields an empty segment.
pub fn search_unsigned_on(
    eng: &GridEngine,
    formats: &[FpFormat],
    maxvals: &[f32],
    zps: &[f32],
    threads: usize,
) -> Option<SearchResult> {
    let mut cands: Vec<Quantizer> = Vec::new();
    let mut grids: Vec<Vec<f32>> = Vec::new();
    for_each_unsigned(formats, maxvals, |fmt, maxval| {
        let base = quantizer_grid(&Quantizer::UnsignedFp { fmt, maxval, zp: 0.0 });
        for &zp in zps {
            cands.push(Quantizer::UnsignedFp { fmt, maxval, zp });
            grids.push(base.iter().map(|&g| g + zp).collect());
        }
    });
    grid::search_min_pregrids(eng, &cands, &grids, threads)
}

/// Weight search: signed FP over the Table-6 spaces. `maxval0` is the
/// absolute max of the tensor; `space` overrides the (lo,hi) fractions for
/// the Table-5 sweep. `maxval_points` controls grid resolution.
pub fn search_weight_fp(
    w: &[f32],
    bits: i32,
    space: Option<(f32, f32)>,
    maxval_points: usize,
) -> SearchResult {
    search_weight_fp_t(w, bits, space, maxval_points, 1)
}

/// [`search_weight_fp`] with candidate-level parallelism.
pub fn search_weight_fp_t(
    w: &[f32],
    bits: i32,
    space: Option<(f32, f32)>,
    maxval_points: usize,
    threads: usize,
) -> SearchResult {
    let maxval0 = w.iter().fold(0.0f32, |a, &b| a.max(b.abs())).max(1e-8);
    search_weight_fp_on(&GridEngine::new(w), maxval0, bits, space, maxval_points, threads)
}

/// [`search_weight_fp`] on a pre-built engine. `maxval0` is the absolute
/// max of the tensor (cached alongside the engine by `quant::session`).
pub fn search_weight_fp_on(
    eng: &GridEngine,
    maxval0: f32,
    bits: i32,
    space: Option<(f32, f32)>,
    maxval_points: usize,
    threads: usize,
) -> SearchResult {
    let (lo, hi) = space.unwrap_or_else(|| format::weight_maxval_space(bits));
    let maxvals = linspace(lo * maxval0, hi * maxval0, maxval_points);
    search_signed_on(eng, &format::weight_formats(bits), &maxvals, threads)
        .expect("weight FP search failed: empty space (maxval_points == 0?) or NaN-poisoned weights")
}

/// Activation MSFP search. `maxval0` comes from the random-forward capture
/// (Appendix C); AALs run both stages and keep the winner.
pub fn search_act_msfp(
    xs: &[f32],
    bits: i32,
    maxval0: f32,
    is_aal: bool,
    maxval_points: usize,
) -> SearchResult {
    search_act_msfp_t(xs, bits, maxval0, is_aal, maxval_points, 1)
}

/// [`search_act_msfp`] with candidate-level parallelism. Both mixup stages
/// share one engine (one sort + prefix pass over the samples).
pub fn search_act_msfp_t(
    xs: &[f32],
    bits: i32,
    maxval0: f32,
    is_aal: bool,
    maxval_points: usize,
    threads: usize,
) -> SearchResult {
    search_act_msfp_on(&GridEngine::new(xs), bits, maxval0, is_aal, maxval_points, threads)
}

/// [`search_act_msfp`] on a pre-built engine (both mixup stages re-score
/// against the caller's sort/prefix pass).
pub fn search_act_msfp_on(
    eng: &GridEngine,
    bits: i32,
    maxval0: f32,
    is_aal: bool,
    maxval_points: usize,
    threads: usize,
) -> SearchResult {
    let maxvals = linspace(maxval0 / maxval_points as f32, maxval0, maxval_points);
    let mut best = search_signed_on(eng, &format::act_signed_formats(bits), &maxvals, threads)
        .expect("signed act search failed: empty space (maxval_points == 0?) or NaN-poisoned samples");
    if is_aal {
        let u = search_unsigned_on(
            eng,
            &format::act_unsigned_formats(bits),
            &maxvals,
            &format::zp_space(),
            threads,
        );
        if let Some(u) = u {
            if u.mse < best.mse {
                best = u;
            }
        }
    }
    best
}

/// INT baseline searches -------------------------------------------------

/// MinMax INT weight quantizer (Q-Diffusion-style start).
pub fn int_weight_minmax(w: &[f32], bits: i32) -> Quantizer {
    let maxval = w.iter().fold(0.0f32, |a, &b| a.max(b.abs())).max(1e-8);
    Quantizer::IntSym { n_bits: bits, maxval }
}

/// MSE-searched symmetric INT (Q-Diffusion/EDA-DM-style reconstruction).
/// None when `maxval_points == 0`.
pub fn search_weight_int(w: &[f32], bits: i32, maxval_points: usize) -> Option<SearchResult> {
    search_weight_int_t(w, bits, maxval_points, 1)
}

/// [`search_weight_int`] with candidate-level parallelism.
pub fn search_weight_int_t(
    w: &[f32],
    bits: i32,
    maxval_points: usize,
    threads: usize,
) -> Option<SearchResult> {
    let maxval0 = w.iter().fold(0.0f32, |a, &b| a.max(b.abs())).max(1e-8);
    search_weight_int_on(&GridEngine::new(w), maxval0, bits, maxval_points, threads)
}

/// [`search_weight_int`] on a pre-built engine; `maxval0` is the absolute
/// max of the tensor.
pub fn search_weight_int_on(
    eng: &GridEngine,
    maxval0: f32,
    bits: i32,
    maxval_points: usize,
    threads: usize,
) -> Option<SearchResult> {
    grid::search_min(eng, &weight_int_cands(bits, maxval0, maxval_points), threads)
}

/// MSE-searched asymmetric INT for activations. None when `points == 0`.
pub fn search_act_int(
    xs: &[f32],
    bits: i32,
    min: f32,
    max: f32,
    points: usize,
) -> Option<SearchResult> {
    search_act_int_t(xs, bits, min, max, points, 1)
}

/// [`search_act_int`] with candidate-level parallelism.
pub fn search_act_int_t(
    xs: &[f32],
    bits: i32,
    min: f32,
    max: f32,
    points: usize,
    threads: usize,
) -> Option<SearchResult> {
    search_act_int_on(&GridEngine::new(xs), bits, min, max, points, threads)
}

/// [`search_act_int`] on a pre-built engine (min/max come from the
/// calibration stats, not the engine).
pub fn search_act_int_on(
    eng: &GridEngine,
    bits: i32,
    min: f32,
    max: f32,
    points: usize,
    threads: usize,
) -> Option<SearchResult> {
    grid::search_min(eng, &act_int_cands(bits, min, max, points), threads)
}

/// The four Figure-4 strategies evaluated on one AAL's samples, returning
/// MSEs normalized against plain signed FP (strategy 1): signed, signed+zp,
/// unsigned (no zp), unsigned+zp.
pub fn fig4_strategies(xs: &[f32], bits: i32, maxval0: f32, points: usize) -> [f64; 4] {
    fig4_strategies_on(&GridEngine::new(xs), bits, maxval0, points)
}

/// [`fig4_strategies`] on a pre-built engine, so figure runners borrow a
/// `QuantSession`'s per-layer engine instead of re-sorting per strategy.
pub fn fig4_strategies_on(eng: &GridEngine, bits: i32, maxval0: f32, points: usize) -> [f64; 4] {
    let maxvals = linspace(maxval0 / points as f32, maxval0, points);
    let zps = format::zp_space();
    let n = eng.len().max(1) as f64;

    let signed = search_signed_on(eng, &format::act_signed_formats(bits), &maxvals, 1)
        .map_or(f64::INFINITY, |r| r.mse);

    // signed + zp: offline-only variant (fp_qdq_signed_zp, not a deployed
    // Quantizer). Scored on the engine too: its grid is the signed grid
    // shifted by the exact f32 add `+ zp` the scalar path applies.
    let mut best_sse = f64::INFINITY;
    for fmt in format::act_signed_formats(bits) {
        for &m in &maxvals {
            if m <= 0.0 {
                continue;
            }
            let base = quantizer_grid(&Quantizer::SignedFp { fmt, maxval: m });
            for &zp in &zps {
                let shifted: Vec<f32> = base.iter().map(|&g| g + zp).collect();
                if let Some(sse) = eng.sse_fn(
                    |x| fp_qdq_signed_zp(x, m, fmt.e_bits, fmt.m_bits, zp),
                    &shifted,
                    best_sse,
                ) {
                    best_sse = best_sse.min(sse);
                }
            }
        }
    }
    let signed_zp = best_sse / n;

    let unsigned_nozp =
        search_unsigned_on(eng, &format::act_unsigned_formats(bits), &maxvals, &[0.0], 1)
            .map_or(f64::INFINITY, |r| r.mse);
    let unsigned_zp =
        search_unsigned_on(eng, &format::act_unsigned_formats(bits), &maxvals, &zps, 1)
            .map_or(f64::INFINITY, |r| r.mse);

    let base = signed.max(1e-18);
    [signed / base, signed_zp / base, unsigned_nozp / base, unsigned_zp / base]
}

/// The original O(C·N) per-element scoring, retained as the reference
/// oracle for the grid-segment engine: property tests assert argmin and
/// MSE parity, and `benches/perf_quant.rs` keeps a before/after-comparable
/// `*_scalar` baseline. Not used on any hot path.
pub mod scalar {
    use super::*;

    pub fn search_signed(
        xs: &[f32],
        formats: &[FpFormat],
        maxvals: &[f32],
    ) -> Option<SearchResult> {
        argmin(signed_cands(formats, maxvals).into_iter().map(|q| (q, q.mse(xs))))
    }

    pub fn search_unsigned(
        xs: &[f32],
        formats: &[FpFormat],
        maxvals: &[f32],
        zps: &[f32],
    ) -> Option<SearchResult> {
        argmin(unsigned_cands(formats, maxvals, zps).into_iter().map(|q| (q, q.mse(xs))))
    }

    pub fn search_weight_int(w: &[f32], bits: i32, maxval_points: usize) -> Option<SearchResult> {
        let maxval0 = w.iter().fold(0.0f32, |a, &b| a.max(b.abs())).max(1e-8);
        argmin(weight_int_cands(bits, maxval0, maxval_points).into_iter().map(|q| (q, q.mse(w))))
    }

    pub fn search_act_int(
        xs: &[f32],
        bits: i32,
        min: f32,
        max: f32,
        points: usize,
    ) -> Option<SearchResult> {
        argmin(act_int_cands(bits, min, max, points).into_iter().map(|q| (q, q.mse(xs))))
    }

    /// Scalar mirror of [`super::search_act_msfp`] (both mixup stages).
    pub fn search_act_msfp(
        xs: &[f32],
        bits: i32,
        maxval0: f32,
        is_aal: bool,
        maxval_points: usize,
    ) -> SearchResult {
        let maxvals = linspace(maxval0 / maxval_points as f32, maxval0, maxval_points);
        let mut best = search_signed(xs, &format::act_signed_formats(bits), &maxvals)
            .expect("signed act search space is empty");
        if is_aal {
            let u = search_unsigned(
                xs,
                &format::act_unsigned_formats(bits),
                &maxvals,
                &format::zp_space(),
            );
            if let Some(u) = u {
                if u.mse < best.mse {
                    best = u;
                }
            }
        }
        best
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn silu_samples(n: usize, seed: u64) -> Vec<f32> {
        let mut rng = Rng::new(seed);
        (0..n)
            .map(|_| {
                let x = rng.normal() * 2.0;
                x / (1.0 + (-x).exp())
            })
            .collect()
    }

    fn normal_samples(n: usize, seed: u64) -> Vec<f32> {
        let mut rng = Rng::new(seed);
        (0..n).map(|_| rng.normal()).collect()
    }

    #[test]
    fn search_finds_low_mse_signed() {
        let xs = normal_samples(2048, 1);
        let r = search_signed(&xs, &format::act_signed_formats(6), &linspace(0.5, 5.0, 40))
            .unwrap();
        assert!(r.mse < 1e-3, "mse={}", r.mse);
    }

    #[test]
    fn empty_candidate_set_is_none() {
        let xs = normal_samples(64, 9);
        assert!(search_signed(&xs, &[], &linspace(0.5, 2.0, 5)).is_none());
        assert!(search_signed(&xs, &format::act_signed_formats(4), &[]).is_none());
        // all-nonpositive maxvals filter down to nothing
        assert!(search_signed(&xs, &format::act_signed_formats(4), &[-1.0, 0.0]).is_none());
        assert!(search_weight_int(&xs, 4, 0).is_none());
        assert!(search_act_int(&xs, 4, -1.0, 1.0, 0).is_none());
    }

    #[test]
    fn aal_search_prefers_unsigned_at_4bit() {
        // the paper's core claim (Fig. 4): unsigned+zp wins on > 95% of AALs
        let xs = silu_samples(4096, 2);
        let maxval0 = xs.iter().fold(0.0f32, |a, &b| a.max(b.abs()));
        let r = search_act_msfp(&xs, 4, maxval0 * 1.2, true, 40);
        assert!(matches!(r.quantizer, Quantizer::UnsignedFp { .. }), "{:?}", r);
    }

    #[test]
    fn nal_search_stays_signed() {
        let xs = normal_samples(4096, 3);
        let maxval0 = xs.iter().fold(0.0f32, |a, &b| a.max(b.abs()));
        let r = search_act_msfp(&xs, 4, maxval0, false, 40);
        assert!(matches!(r.quantizer, Quantizer::SignedFp { .. }));
    }

    #[test]
    fn mixup_never_worse_than_signed_only() {
        for seed in 0..5 {
            let xs = silu_samples(2048, 100 + seed);
            let maxval0 = xs.iter().fold(0.0f32, |a, &b| a.max(b.abs()));
            let signed = search_act_msfp(&xs, 4, maxval0, false, 30);
            let mixup = search_act_msfp(&xs, 4, maxval0, true, 30);
            assert!(mixup.mse <= signed.mse + 1e-12);
        }
    }

    #[test]
    fn weight_search_beats_minmax_int() {
        let w = normal_samples(4096, 5);
        let fp = search_weight_fp(&w, 4, None, 40);
        let int_mm = int_weight_minmax(&w, 4);
        assert!(fp.mse < int_mm.mse(&w), "{} vs {}", fp.mse, int_mm.mse(&w));
    }

    #[test]
    fn int_mse_search_beats_minmax() {
        let w = normal_samples(4096, 6);
        let s = search_weight_int(&w, 4, 40).unwrap();
        assert!(s.mse <= int_weight_minmax(&w, 4).mse(&w));
    }

    #[test]
    fn fig4_unsigned_zp_wins_on_silu() {
        let xs = silu_samples(4096, 7);
        let maxval0 = xs.iter().fold(0.0f32, |a, &b| a.max(b.abs()));
        let [s, _szp, _u, uzp] = fig4_strategies(&xs, 4, maxval0 * 1.3, 25);
        assert!((s - 1.0).abs() < 1e-9);
        assert!(uzp < 1.0, "unsigned+zp should beat signed: {uzp}");
    }

    #[test]
    fn encode_roundtrip_semantics() {
        let q = Quantizer::UnsignedFp { fmt: FpFormat::new(2, 2), maxval: 1.5, zp: -0.18 };
        let e = q.encode_act();
        assert_eq!(e, [0.0, 1.5, 2.0, 2.0, -0.18]);
        let w = Quantizer::IntSym { n_bits: 4, maxval: 2.0 };
        assert_eq!(w.encode_weight(), [2.0, -1.0, 4.0]);
    }

    #[test]
    fn mse_higher_bits_monotone() {
        let xs = silu_samples(2048, 8);
        let maxval0 = xs.iter().fold(0.0f32, |a, &b| a.max(b.abs()));
        let m4 = search_act_msfp(&xs, 4, maxval0, true, 30).mse;
        let m6 = search_act_msfp(&xs, 6, maxval0, true, 30).mse;
        let m8 = search_act_msfp(&xs, 8, maxval0, true, 30).mse;
        assert!(m8 < m6 && m6 < m4, "{m8} {m6} {m4}");
    }

    #[test]
    fn engine_matches_scalar_oracle_msfp() {
        // end-to-end mixup parity against the retained scalar path
        for seed in [21u64, 22, 23] {
            let xs = silu_samples(1536, seed);
            let maxval0 = xs.iter().fold(0.0f32, |a, &b| a.max(b.abs()));
            let fast = search_act_msfp(&xs, 4, maxval0, true, 25);
            let slow = scalar::search_act_msfp(&xs, 4, maxval0, true, 25);
            assert_eq!(fast.quantizer, slow.quantizer, "seed {seed}");
            assert!(
                (fast.mse - slow.mse).abs() <= 1e-9 * slow.mse.max(1e-18),
                "seed {seed}: {} vs {}",
                fast.mse,
                slow.mse
            );
        }
    }

    #[test]
    fn shared_zp_base_grid_matches_per_candidate_grids() {
        // the ROADMAP micro-opt: one base grid per (format, maxval),
        // shifted per zp candidate — must score bit-identically to the
        // per-candidate quantizer_grid path, same tie-breaking included
        let xs = silu_samples(2048, 77);
        let maxval0 = xs.iter().fold(0.0f32, |a, &b| a.max(b.abs()));
        let maxvals = linspace(maxval0 / 30.0, maxval0, 30);
        let zps = format::zp_space();
        let fmts = format::act_unsigned_formats(4);
        let eng = GridEngine::new(&xs);
        let shared = search_unsigned_on(&eng, &fmts, &maxvals, &zps, 1).unwrap();
        let per_cand =
            grid::search_min(&eng, &unsigned_cands(&fmts, &maxvals, &zps), 1).unwrap();
        assert_eq!(shared.quantizer, per_cand.quantizer);
        assert_eq!(shared.mse.to_bits(), per_cand.mse.to_bits());
    }

    #[test]
    fn threaded_search_matches_sequential() {
        let xs = silu_samples(2048, 31);
        let maxval0 = xs.iter().fold(0.0f32, |a, &b| a.max(b.abs()));
        let a = search_act_msfp_t(&xs, 4, maxval0, true, 40, 1);
        let b = search_act_msfp_t(&xs, 4, maxval0, true, 40, 4);
        assert_eq!(a.quantizer, b.quantizer);
        assert_eq!(a.mse, b.mse);
    }

    #[test]
    fn linspace_endpoints() {
        let v = linspace(1.0, 2.0, 5);
        assert_eq!(v.len(), 5);
        assert_eq!(v[0], 1.0);
        assert_eq!(v[4], 2.0);
    }
}
