//! Uniform INT fake quantize-dequantize — the baseline quantizers
//! (Q-Diffusion / EfficientDM / LSQ-like comparators run on these).
//! Bit-exact mirror of ref.int_qdq_{sym,asym}.

use super::fp::rnd;

/// Symmetric uniform INT fake-qdq: grid {-2^(n-1) .. 2^(n-1)-1} · s.
#[inline]
pub fn int_qdq_sym(x: f32, maxval: f32, n_bits: i32) -> f32 {
    let qmax = ((1i64 << (n_bits - 1)) - 1) as f32;
    let s = maxval / qmax;
    rnd(x / s).clamp(-qmax - 1.0, qmax) * s
}

/// Asymmetric uniform INT fake-qdq on [lo, hi].
#[inline]
pub fn int_qdq_asym(x: f32, lo: f32, hi: f32, n_bits: i32) -> f32 {
    let levels = ((1i64 << n_bits) - 1) as f32;
    let mut s = (hi - lo) / levels;
    if s <= 0.0 {
        s = 1.0;
    }
    let z = rnd(-lo / s);
    ((rnd(x / s) + z).clamp(0.0, levels) - z) * s
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sym_grid_points_preserved() {
        let n = 4;
        let maxval = 3.5f32;
        let s = maxval / 7.0;
        for q in -8..=7 {
            let x = q as f32 * s;
            assert!((int_qdq_sym(x, maxval, n) - x).abs() < 1e-6);
        }
    }

    #[test]
    fn sym_clamps() {
        assert!((int_qdq_sym(100.0, 3.5, 4) - 3.5).abs() < 1e-6);
        assert!((int_qdq_sym(-100.0, 3.5, 4) + 4.0).abs() < 1e-6); // -qmax-1 level
    }

    #[test]
    fn asym_range_respected() {
        for x in [-10.0f32, -0.3, 0.0, 1.0, 10.0] {
            let q = int_qdq_asym(x, -0.3, 2.0, 4);
            assert!(q >= -0.3 - 0.2 && q <= 2.0 + 0.2, "x={x} q={q}");
        }
    }

    #[test]
    fn asym_idempotent() {
        let mut rng = crate::util::rng::Rng::new(3);
        for _ in 0..1000 {
            let x = rng.normal() * 2.0;
            let q = int_qdq_asym(x, -0.5, 1.8, 4);
            let q2 = int_qdq_asym(q, -0.5, 1.8, 4);
            assert!((q - q2).abs() < 1e-6);
        }
    }

    #[test]
    fn degenerate_range_safe() {
        // lo == hi must not divide by zero
        let q = int_qdq_asym(0.7, 1.0, 1.0, 4);
        assert!(q.is_finite());
    }

    #[test]
    fn higher_bits_lower_error() {
        let mut rng = crate::util::rng::Rng::new(4);
        let xs: Vec<f32> = (0..5000).map(|_| rng.normal()).collect();
        let mse = |n: i32| {
            xs.iter().map(|&x| (int_qdq_sym(x, 3.0, n) - x).powi(2)).sum::<f32>() / xs.len() as f32
        };
        assert!(mse(8) < mse(6) && mse(6) < mse(4) && mse(4) < mse(2));
    }
}
