//! Packed sub-byte MSFP storage and the fused dequantize-matmul kernel.
//!
//! Everywhere else in the repo a quantized layer is *simulated*: fake-qdq
//! (`quant/fp.rs`, `quant/int.rs`) maps each f32 weight onto its quantized
//! value and the result is stored and multiplied as dense f32. This module
//! makes the 4-bit promise real at serving time:
//!
//! - Each searched layer gets a **code table**: the exact ascending,
//!   deduplicated qdq output grid of its weight quantizer
//!   ([`super::grid::quantizer_grid`] — same f32 expressions as the scalar
//!   qdq, so membership is bit-exact). For an ExMy format the table *is*
//!   the per-binade `k·2^(e−m)·a` magnitude set (± for signed, `+zp`
//!   shifted for the unsigned path); for the Int methods it is the
//!   `q·s` / `(q−z)·s` ladder.
//! - Weights are stored as **bit-packed table indices** (LSB-first
//!   little-endian bitstream, `ceil(log2(len))` bits per weight — nibble
//!   region for W4 Int, 5 bits for the W4 FP grids, and general sub-byte
//!   so the W3/W2 degraded variants pack too).
//! - `pack → dequantize` reproduces the fake-qdq values with the **same
//!   f32 bits** (property-pinned in `tests/props.rs`), so the packed path
//!   and the compiled fake-qdq graph share one numerical contract.
//!
//! The fused kernel streams the packed indices and gathers through the
//! code table instead of touching f32 weights. Its accumulation order is
//! fixed and documented (see [`PackedMat::fused_matmul_into`]): results
//! are bit-identical to the scalar dequantize-then-matmul reference for
//! any worker count and any cache-block size.

use std::collections::HashMap;
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::util::io::atomic_write;
use crate::util::threadpool::parallel_map;

use super::format::FpFormat;
use super::grid::quantizer_grid;
use super::search::Quantizer;

/// Widest supported index. Every searched format is far below this (a W8
/// E4M3 grid has 271 codes → 9 bits); the cap only bounds the bitstream
/// reader's window.
pub const MAX_INDEX_BITS: u32 = 16;

/// Number of f32 values in a qparams row per layer (mirrors the manifest
/// docstring: [w_maxval, w_ebits, w_mbits, a_sign, a_maxval, a_ebits,
/// a_mbits, a_zp]).
pub const QPARAMS_COLS: usize = 8;

// ---------------------------------------------------------------------------
// qparams row → Quantizer (inverse of Quantizer::encode_weight/encode_act)
// ---------------------------------------------------------------------------

/// Decode the weight half of a qparams row `[maxval, ebits, mbits]` into
/// the quantizer it encodes. Inverse of [`Quantizer::encode_weight`]:
/// `ebits >= 0` is an ExMy signed-FP format, `ebits < 0` marks symmetric
/// int with `mbits` carrying the bit width.
pub fn decode_weight_row(row: &[f32]) -> Quantizer {
    let (maxval, e, m) = (row[0], row[1], row[2]);
    if e >= 0.0 {
        Quantizer::SignedFp { fmt: FpFormat::new(e as i32, m as i32), maxval }
    } else {
        Quantizer::IntSym { n_bits: m as i32, maxval }
    }
}

/// Decode the activation half of a qparams row
/// `[sign, maxval, ebits, mbits, zp]`. Inverse of
/// [`Quantizer::encode_act`].
pub fn decode_act_row(row: &[f32]) -> Quantizer {
    let (sign, maxval, e, m, zp) = (row[0], row[1], row[2], row[3], row[4]);
    if e >= 0.0 {
        if sign >= 0.5 {
            Quantizer::SignedFp { fmt: FpFormat::new(e as i32, m as i32), maxval }
        } else {
            Quantizer::UnsignedFp { fmt: FpFormat::new(e as i32, m as i32), maxval, zp }
        }
    } else if sign >= 0.5 {
        Quantizer::IntSym { n_bits: m as i32, maxval }
    } else {
        Quantizer::IntAsym { n_bits: m as i32, lo: zp, hi: maxval }
    }
}

/// Split one full qparams row into (weight quantizer, activation
/// quantizer).
pub fn decode_qparams_row(row: &[f32]) -> (Quantizer, Quantizer) {
    (decode_weight_row(&row[0..3]), decode_act_row(&row[3..8]))
}

// ---------------------------------------------------------------------------
// bitstream
// ---------------------------------------------------------------------------

fn pack_bits(idx: &[u32], bits: u32) -> Vec<u8> {
    let total = idx.len() * bits as usize;
    let mut out = vec![0u8; total.div_ceil(8)];
    let mut pos = 0usize;
    for &c in idx {
        let byte = pos >> 3;
        let off = (pos & 7) as u32;
        // bits <= 16 and off <= 7, so the shifted value fits in 23 bits
        let v = c << off;
        out[byte] |= (v & 0xff) as u8;
        if off + bits > 8 {
            out[byte + 1] |= ((v >> 8) & 0xff) as u8;
        }
        if off + bits > 16 {
            out[byte + 2] |= ((v >> 16) & 0xff) as u8;
        }
        pos += bits as usize;
    }
    out
}

/// Sequential LSB-first reader over a packed index stream; can start at
/// any bit offset so row starts need no byte alignment or padding.
struct BitReader<'a> {
    data: &'a [u8],
    pos: usize,
}

impl<'a> BitReader<'a> {
    fn at(data: &'a [u8], bitpos: usize) -> BitReader<'a> {
        BitReader { data, pos: bitpos }
    }

    #[inline]
    fn next(&mut self, bits: u32) -> u32 {
        let byte = self.pos >> 3;
        let off = (self.pos & 7) as u32;
        let mut v = (self.data[byte] as u32) >> off;
        let mut got = 8 - off;
        let mut i = 1;
        while got < bits {
            v |= (self.data.get(byte + i).copied().unwrap_or(0) as u32) << got;
            got += 8;
            i += 1;
        }
        self.pos += bits as usize;
        v & ((1u32 << bits) - 1)
    }
}

// ---------------------------------------------------------------------------
// PackedTensor
// ---------------------------------------------------------------------------

/// A flat tensor stored as bit-packed indices into its quantizer's code
/// table. Layout:
///
/// ```text
/// table:  [v_0 < v_1 < ... < v_{T-1}]        T * 4 bytes (f32, ascending)
/// codes:  |idx_0|idx_1|...|idx_{n-1}|        ceil(n*bits/8) bytes,
///          LSB-first within each byte, element i at bits [i*bits, (i+1)*bits)
/// ```
#[derive(Debug, Clone, PartialEq)]
pub struct PackedTensor {
    /// Exact qdq output grid of the source quantizer, ascending.
    pub table: Vec<f32>,
    /// Index width in bits: `max(1, ceil(log2(table.len())))`.
    pub bits: u32,
    /// Element count.
    pub n: usize,
    /// Bit-packed indices.
    pub codes: Vec<u8>,
}

fn index_bits(table_len: usize) -> u32 {
    let len = table_len.max(2);
    (usize::BITS - (len - 1).leading_zeros()).max(1)
}

impl PackedTensor {
    /// Quantize `weights` under `q` and store the result as packed code
    /// indices. `dequantize` reproduces `q.qdq(w)` for every element with
    /// the same f32 bits (the table is built from the identical f32
    /// expressions the scalar qdq evaluates). Fails on non-finite qdq
    /// output (NaN/inf weights) rather than packing garbage.
    pub fn pack(weights: &[f32], q: &Quantizer) -> Result<PackedTensor> {
        let table = quantizer_grid(q);
        if table.is_empty() {
            bail!("empty code table for {q:?}");
        }
        let bits = index_bits(table.len());
        if bits > MAX_INDEX_BITS {
            bail!("code table of {} entries needs {} index bits (cap {MAX_INDEX_BITS})", table.len(), bits);
        }
        let mut idx = Vec::with_capacity(weights.len());
        for &w in weights {
            let qv = q.qdq(w);
            if !qv.is_finite() {
                bail!("non-finite qdq output {qv} for weight {w} under {q:?}");
            }
            // partition_point lands on the first table entry >= qv; scan the
            // (tiny) run of ==-equal entries for the bit-exact one. A
            // value-equal fallback only triggers in the ±0.0 collapse of a
            // fully underflowed grid.
            let i = table.partition_point(|v| *v < qv);
            let mut found = None;
            let mut j = i;
            while j < table.len() && table[j] == qv {
                if table[j].to_bits() == qv.to_bits() {
                    found = Some(j);
                    break;
                }
                j += 1;
            }
            let code = match found {
                Some(j) => j,
                None if i < table.len() && table[i] == qv => i,
                _ => bail!("qdq output {qv:?} missing from code table of {q:?}"),
            };
            idx.push(code as u32);
        }
        let codes = pack_bits(&idx, bits);
        Ok(PackedTensor { table, bits, n: weights.len(), codes })
    }

    /// Decode element `i` back to its table index.
    pub fn code(&self, i: usize) -> u32 {
        BitReader::at(&self.codes, i * self.bits as usize).next(self.bits)
    }

    /// Append all dequantized values to `out` (same f32 bits as the
    /// fake-qdq of the packed source).
    pub fn dequantize_into(&self, out: &mut Vec<f32>) {
        out.reserve(self.n);
        let mut rd = BitReader::at(&self.codes, 0);
        for _ in 0..self.n {
            out.push(self.table[rd.next(self.bits) as usize]);
        }
    }

    pub fn dequantize(&self) -> Vec<f32> {
        let mut out = Vec::new();
        self.dequantize_into(&mut out);
        out
    }

    /// Real storage footprint: index stream + code table + a fixed 24-byte
    /// per-tensor header (bits, count, table length, shape).
    pub fn bytes(&self) -> usize {
        self.codes.len() + self.table.len() * 4 + 24
    }
}

// ---------------------------------------------------------------------------
// PackedMat + fused dequantize-matmul
// ---------------------------------------------------------------------------

/// LoRA low-rank correction fused into the packed matmul:
/// `scale · B @ (A @ X)` with `A: [rank, cols]`, `B: [rows, rank]`.
pub struct LoraTerm<'a> {
    pub a: &'a [f32],
    pub b: &'a [f32],
    pub rank: usize,
    pub scale: f32,
}

/// A packed weight matrix in matmul layout: `rows = fan_out`,
/// `cols = fan_in`, indices row-major so the kernel streams each output
/// row's codes contiguously.
#[derive(Debug, Clone, PartialEq)]
pub struct PackedMat {
    pub rows: usize,
    pub cols: usize,
    pub t: PackedTensor,
}

/// Fan-in block width for the cache-blocked kernel: a 64×B f32 slab of
/// `x` stays L1-resident while every row of a chunk consumes it. Blocking
/// never reorders any output element's accumulation (k stays ascending).
const K_BLOCK: usize = 64;

/// Rows per parallel work item.
const ROW_CHUNK: usize = 32;

impl PackedMat {
    /// Pack `weights` laid out row-major `[rows, cols]` under `q`.
    pub fn pack(weights: &[f32], rows: usize, cols: usize, q: &Quantizer) -> Result<PackedMat> {
        if weights.len() != rows * cols {
            bail!("weight len {} != {rows}x{cols}", weights.len());
        }
        Ok(PackedMat { rows, cols, t: PackedTensor::pack(weights, q)? })
    }

    /// Fused dequantize-matmul: `out[n,b] = Σ_k wq[n,k]·x[k,b]
    /// (+ scale·(B@(A@X))[n,b]) (+ bias[n])` with `x: [cols, b_cols]`
    /// row-major and `out: [rows, b_cols]`.
    ///
    /// **Fixed accumulation order** (the bit-identity contract with
    /// [`Self::fused_matmul_ref`], for any worker count): each output
    /// element accumulates (1) the packed-weight products over `k`
    /// ascending, then (2) the LoRA products over `r` ascending against a
    /// single-threaded precomputed `T = A@X` (itself `k`-ascending), then
    /// (3) the bias. Cache blocking over `k` and row-parallelism never
    /// reorder these sums — rows are independent and blocks are consumed
    /// in ascending order.
    pub fn fused_matmul_into(
        &self,
        x: &[f32],
        b_cols: usize,
        lora: Option<&LoraTerm<'_>>,
        bias: Option<&[f32]>,
        threads: usize,
        out: &mut Vec<f32>,
    ) {
        assert_eq!(x.len(), self.cols * b_cols, "x must be [cols, b_cols]");
        if let Some(l) = lora {
            assert_eq!(l.a.len(), l.rank * self.cols, "lora A must be [rank, cols]");
            assert_eq!(l.b.len(), self.rows * l.rank, "lora B must be [rows, rank]");
        }
        if let Some(b) = bias {
            assert_eq!(b.len(), self.rows, "bias must be [rows]");
        }
        // T = A @ X, single-threaded so every worker count sees one value.
        let t_lora: Option<Vec<f32>> =
            lora.map(|l| small_matmul(l.a, l.rank, self.cols, x, b_cols));
        out.clear();
        out.resize(self.rows * b_cols, 0.0);
        let ranges: Vec<(usize, usize)> = (0..self.rows)
            .step_by(ROW_CHUNK)
            .map(|r0| (r0, (r0 + ROW_CHUNK).min(self.rows)))
            .collect();
        let chunks = parallel_map(&ranges, threads, |_, &(r0, r1)| {
            let mut acc = vec![0.0f32; (r1 - r0) * b_cols];
            self.rows_kernel(r0, r1, x, b_cols, lora, t_lora.as_deref(), bias, &mut acc);
            acc
        });
        for (&(r0, _), chunk) in ranges.iter().zip(chunks) {
            out[r0 * b_cols..r0 * b_cols + chunk.len()].copy_from_slice(&chunk);
        }
    }

    /// One row chunk of the fused kernel; `acc` covers rows `r0..r1`.
    #[allow(clippy::too_many_arguments)]
    fn rows_kernel(
        &self,
        r0: usize,
        r1: usize,
        x: &[f32],
        b_cols: usize,
        lora: Option<&LoraTerm<'_>>,
        t_lora: Option<&[f32]>,
        bias: Option<&[f32]>,
        acc: &mut [f32],
    ) {
        let bits = self.t.bits;
        let table = &self.t.table;
        let mut kb = 0;
        while kb < self.cols {
            let ke = (kb + K_BLOCK).min(self.cols);
            for n in r0..r1 {
                let arow = &mut acc[(n - r0) * b_cols..(n - r0 + 1) * b_cols];
                let mut rd = BitReader::at(&self.t.codes, (n * self.cols + kb) * bits as usize);
                for k in kb..ke {
                    let w = table[rd.next(bits) as usize];
                    let xr = &x[k * b_cols..(k + 1) * b_cols];
                    for (a, &xv) in arow.iter_mut().zip(xr) {
                        *a += w * xv;
                    }
                }
            }
            kb = ke;
        }
        for n in r0..r1 {
            let arow = &mut acc[(n - r0) * b_cols..(n - r0 + 1) * b_cols];
            if let (Some(l), Some(t)) = (lora, t_lora) {
                for rr in 0..l.rank {
                    let c = l.b[n * l.rank + rr] * l.scale;
                    let tr = &t[rr * b_cols..(rr + 1) * b_cols];
                    for (a, &tv) in arow.iter_mut().zip(tr) {
                        *a += c * tv;
                    }
                }
            }
            if let Some(b) = bias {
                for a in arow.iter_mut() {
                    *a += b[n];
                }
            }
        }
    }

    /// Scalar reference: dequantize the whole matrix to dense f32, then
    /// run the same accumulation order single-threaded. The fused kernel
    /// must match this bit-for-bit (pinned in unit + property tests).
    pub fn fused_matmul_ref(
        &self,
        x: &[f32],
        b_cols: usize,
        lora: Option<&LoraTerm<'_>>,
        bias: Option<&[f32]>,
        out: &mut Vec<f32>,
    ) {
        assert_eq!(x.len(), self.cols * b_cols, "x must be [cols, b_cols]");
        let w = self.t.dequantize();
        let t_lora: Option<Vec<f32>> =
            lora.map(|l| small_matmul(l.a, l.rank, self.cols, x, b_cols));
        out.clear();
        out.resize(self.rows * b_cols, 0.0);
        for n in 0..self.rows {
            let arow = &mut out[n * b_cols..(n + 1) * b_cols];
            for k in 0..self.cols {
                let wv = w[n * self.cols + k];
                let xr = &x[k * b_cols..(k + 1) * b_cols];
                for (a, &xv) in arow.iter_mut().zip(xr) {
                    *a += wv * xv;
                }
            }
            if let (Some(l), Some(t)) = (lora, t_lora.as_deref()) {
                for rr in 0..l.rank {
                    let c = l.b[n * l.rank + rr] * l.scale;
                    let tr = &t[rr * b_cols..(rr + 1) * b_cols];
                    for (a, &tv) in arow.iter_mut().zip(tr) {
                        *a += c * tv;
                    }
                }
            }
            if let Some(b) = bias {
                for a in arow.iter_mut() {
                    *a += b[n];
                }
            }
        }
    }

    pub fn bytes(&self) -> usize {
        self.t.bytes()
    }
}

/// Dense row-major `a[ar, ac] @ x[ac, b_cols]`, k-ascending, single
/// thread — the deterministic LoRA `A@X` stage.
fn small_matmul(a: &[f32], ar: usize, ac: usize, x: &[f32], b_cols: usize) -> Vec<f32> {
    let mut t = vec![0.0f32; ar * b_cols];
    for i in 0..ar {
        let trow = &mut t[i * b_cols..(i + 1) * b_cols];
        for k in 0..ac {
            let v = a[i * ac + k];
            let xr = &x[k * b_cols..(k + 1) * b_cols];
            for (o, &xv) in trow.iter_mut().zip(xr) {
                *o += v * xv;
            }
        }
    }
    t
}

// ---------------------------------------------------------------------------
// PackedModel + versioned blob
// ---------------------------------------------------------------------------

/// One packed layer: the weight matrix in matmul layout keyed by the
/// manifest layer name.
#[derive(Debug, Clone, PartialEq)]
pub struct PackedLayer {
    pub name: String,
    pub mat: PackedMat,
}

/// Every quantized layer of a model, packed. Saved next to `quant.mts`
/// in the `StateDir` (see `StateDir::packed_path`).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct PackedModel {
    pub layers: Vec<PackedLayer>,
}

/// Blob magic: "MSFPPK" + 2-digit version. Bump on any layout change.
pub const PACKED_MAGIC: &[u8; 8] = b"MSFPPK01";

impl PackedModel {
    /// Total packed bytes across all layers (index streams + code tables
    /// + per-tensor headers).
    pub fn bytes(&self) -> usize {
        self.layers.iter().map(|l| l.mat.bytes()).sum()
    }

    pub fn layer(&self, name: &str) -> Option<&PackedLayer> {
        self.layers.iter().find(|l| l.name == name)
    }

    /// Serialize to the versioned `MSFPPK01` blob.
    pub fn to_bytes(&self) -> Vec<u8> {
        let mut out = Vec::new();
        out.extend_from_slice(PACKED_MAGIC);
        out.extend_from_slice(&(self.layers.len() as u32).to_le_bytes());
        for l in &self.layers {
            let name = l.name.as_bytes();
            out.extend_from_slice(&(name.len() as u32).to_le_bytes());
            out.extend_from_slice(name);
            out.extend_from_slice(&(l.mat.rows as u32).to_le_bytes());
            out.extend_from_slice(&(l.mat.cols as u32).to_le_bytes());
            out.extend_from_slice(&l.mat.t.bits.to_le_bytes());
            out.extend_from_slice(&(l.mat.t.table.len() as u32).to_le_bytes());
            for v in &l.mat.t.table {
                out.extend_from_slice(&v.to_le_bytes());
            }
            out.extend_from_slice(&(l.mat.t.codes.len() as u64).to_le_bytes());
            out.extend_from_slice(&l.mat.t.codes);
        }
        out
    }

    pub fn from_bytes(data: &[u8]) -> Result<PackedModel> {
        let mut c = Cursor { data, pos: 0 };
        let magic = c.take(8)?;
        if magic != PACKED_MAGIC {
            bail!("bad packed-model magic {magic:?} (want {PACKED_MAGIC:?})");
        }
        let n_layers = c.u32()? as usize;
        let mut layers = Vec::with_capacity(n_layers);
        for _ in 0..n_layers {
            let name_len = c.u32()? as usize;
            let name = String::from_utf8(c.take(name_len)?.to_vec())
                .context("packed layer name is not utf-8")?;
            let rows = c.u32()? as usize;
            let cols = c.u32()? as usize;
            let bits = c.u32()?;
            if bits == 0 || bits > MAX_INDEX_BITS {
                bail!("layer {name}: bad index width {bits}");
            }
            let table_len = c.u32()? as usize;
            if table_len == 0 || table_len > (1usize << bits) {
                bail!("layer {name}: table of {table_len} entries does not fit {bits} bits");
            }
            let mut table = Vec::with_capacity(table_len);
            for _ in 0..table_len {
                let b = c.take(4)?;
                table.push(f32::from_le_bytes([b[0], b[1], b[2], b[3]]));
            }
            let codes_len = c.u64()? as usize;
            let n = rows * cols;
            if codes_len != (n * bits as usize).div_ceil(8) {
                bail!("layer {name}: {codes_len} code bytes for {n} x {bits}-bit elements");
            }
            let codes = c.take(codes_len)?.to_vec();
            layers.push(PackedLayer {
                name,
                mat: PackedMat { rows, cols, t: PackedTensor { table, bits, n, codes } },
            });
        }
        if c.pos != data.len() {
            bail!("{} trailing bytes after packed model", data.len() - c.pos);
        }
        Ok(PackedModel { layers })
    }

    /// Atomic write of the versioned blob.
    pub fn save(&self, path: &Path) -> Result<()> {
        atomic_write(path, &self.to_bytes())
    }

    /// Restore a persisted blob. Fault-aware (`util::io::read_file_retry`)
    /// like every state restore; parse failures carry the path so a
    /// corrupt blob at server start is a distinct, loggable error.
    pub fn load(path: &Path) -> Result<PackedModel> {
        let data = crate::util::io::read_file_retry(path, crate::util::io::RESTORE_ATTEMPTS)
            .with_context(|| format!("reading packed model {}", path.display()))?;
        PackedModel::from_bytes(&data).with_context(|| format!("parsing {}", path.display()))
    }

    /// Index layers by name for O(1) lookup during a forward pass.
    pub fn by_name(&self) -> HashMap<&str, &PackedLayer> {
        self.layers.iter().map(|l| (l.name.as_str(), l)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::fp::e_min_of;
    use crate::util::rng::Rng;

    fn edge_values(q: &Quantizer) -> Vec<f32> {
        // zeros, ±maxval, far-out clamps, and subnormal-binade boundaries
        let mut xs = vec![0.0, -0.0, 1e30, -1e30, 1e-30, -1e-30];
        match *q {
            Quantizer::SignedFp { fmt, maxval } | Quantizer::UnsignedFp { fmt, maxval, .. } => {
                xs.push(maxval);
                xs.push(-maxval);
                let full = 2.0 - crate::quant::fp::exp2_int(-fmt.m_bits);
                let a = maxval / full;
                let e_min = e_min_of(fmt.e_bits);
                let step = crate::quant::fp::exp2_int(e_min - fmt.m_bits);
                for k in 0..=(1i64 << (fmt.m_bits + 1)) {
                    xs.push(k as f32 * step * a);
                    xs.push(-(k as f32) * step * a);
                    xs.push((k as f32 + 0.49) * step * a);
                }
            }
            Quantizer::IntSym { maxval, .. } => {
                xs.push(maxval);
                xs.push(-maxval);
            }
            Quantizer::IntAsym { lo, hi, .. } => {
                xs.push(lo);
                xs.push(hi);
            }
        }
        xs
    }

    fn assert_roundtrip(q: &Quantizer, xs: &[f32]) {
        let p = PackedTensor::pack(xs, q).unwrap();
        let deq = p.dequantize();
        for (i, (&x, &d)) in xs.iter().zip(&deq).enumerate() {
            let want = q.qdq(x);
            assert_eq!(
                d.to_bits(),
                want.to_bits(),
                "elem {i}: x={x} deq={d} want={want} under {q:?}"
            );
        }
    }

    #[test]
    fn roundtrip_signed_fp_formats() {
        let mut r = Rng::new(11);
        for (e, m) in [(3, 0), (2, 1), (1, 2), (0, 3), (2, 0), (1, 1), (0, 2), (4, 3)] {
            let q = Quantizer::SignedFp { fmt: FpFormat::new(e, m), maxval: 1.5 };
            let mut xs = edge_values(&q);
            xs.extend((0..512).map(|_| r.normal() * 2.0));
            assert_roundtrip(&q, &xs);
        }
    }

    #[test]
    fn roundtrip_unsigned_fp_with_zp() {
        let mut r = Rng::new(12);
        for (e, m) in [(2, 2), (1, 3), (3, 1), (0, 4)] {
            for zp in [0.0, -0.18, -0.3] {
                let q = Quantizer::UnsignedFp { fmt: FpFormat::new(e, m), maxval: 6.0, zp };
                let mut xs = edge_values(&q);
                xs.extend((0..512).map(|_| r.normal().abs() * 3.0 + zp));
                assert_roundtrip(&q, &xs);
            }
        }
    }

    #[test]
    fn roundtrip_int_sym_and_asym() {
        let mut r = Rng::new(13);
        for n in [2, 3, 4, 8] {
            let q = Quantizer::IntSym { n_bits: n, maxval: 2.5 };
            let mut xs = edge_values(&q);
            xs.extend((0..512).map(|_| r.normal() * 3.0));
            assert_roundtrip(&q, &xs);

            let q = Quantizer::IntAsym { n_bits: n, lo: -0.2785, hi: 5.0 };
            let mut xs = edge_values(&q);
            xs.extend((0..512).map(|_| r.normal() * 2.0 + 1.0));
            assert_roundtrip(&q, &xs);
        }
    }

    #[test]
    fn index_widths_are_sub_byte_for_low_bit_formats() {
        // W4 int grid has exactly 16 codes -> nibble; W4 FP grids carry the
        // subnormal binade + sign, so they index in 5 bits; degraded W3/W2
        // pack below that.
        let cases = [
            (Quantizer::IntSym { n_bits: 4, maxval: 1.0 }, 4),
            (Quantizer::SignedFp { fmt: FpFormat::new(2, 1), maxval: 1.0 }, 5),
            (Quantizer::SignedFp { fmt: FpFormat::new(3, 0), maxval: 1.0 }, 5),
            (Quantizer::SignedFp { fmt: FpFormat::new(1, 1), maxval: 1.0 }, 4),
            (Quantizer::SignedFp { fmt: FpFormat::new(1, 0), maxval: 1.0 }, 3),
            (Quantizer::IntSym { n_bits: 2, maxval: 1.0 }, 2),
        ];
        for (q, want_bits) in cases {
            let p = PackedTensor::pack(&[0.0, 0.5, -0.5, 1.0], &q).unwrap();
            assert_eq!(p.bits, want_bits, "{q:?} table {} entries", p.table.len());
        }
    }

    #[test]
    fn packed_bytes_beat_one_sixth_of_f32_for_4bit_layers() {
        // A mid-UNet conv: 3*3*64*64 weights.
        let mut r = Rng::new(14);
        let n = 3 * 3 * 64 * 64;
        let w: Vec<f32> = (0..n).map(|_| r.normal() * 0.1).collect();
        for q in [
            Quantizer::SignedFp { fmt: FpFormat::new(2, 1), maxval: 0.4 },
            Quantizer::IntSym { n_bits: 4, maxval: 0.4 },
        ] {
            let p = PackedTensor::pack(&w, &q).unwrap();
            let f32_bytes = n * 4;
            assert!(
                p.bytes() * 6 <= f32_bytes,
                "{q:?}: packed {} vs f32 {} bytes",
                p.bytes(),
                f32_bytes
            );
        }
    }

    #[test]
    fn pack_rejects_nan_weights() {
        let q = Quantizer::SignedFp { fmt: FpFormat::new(2, 1), maxval: 1.0 };
        assert!(PackedTensor::pack(&[0.0, f32::NAN], &q).is_err());
    }

    #[test]
    fn decode_rows_invert_encode() {
        let cases = [
            Quantizer::SignedFp { fmt: FpFormat::new(2, 1), maxval: 0.75 },
            Quantizer::IntSym { n_bits: 4, maxval: 1.25 },
        ];
        for q in cases {
            assert_eq!(decode_weight_row(&q.encode_weight()), q);
        }
        let acts = [
            Quantizer::SignedFp { fmt: FpFormat::new(2, 1), maxval: 6.0 },
            Quantizer::UnsignedFp { fmt: FpFormat::new(2, 2), maxval: 6.0, zp: -0.2785 },
            Quantizer::IntSym { n_bits: 4, maxval: 6.0 },
            Quantizer::IntAsym { n_bits: 4, lo: -0.2785, hi: 6.0 },
        ];
        for q in acts {
            assert_eq!(decode_act_row(&q.encode_act()), q);
        }
    }

    fn random_fused_case(
        r: &mut Rng,
        rows: usize,
        cols: usize,
        b_cols: usize,
        rank: usize,
    ) -> (PackedMat, Vec<f32>, Vec<f32>, Vec<f32>, Vec<f32>) {
        let q = Quantizer::SignedFp { fmt: FpFormat::new(2, 1), maxval: 0.8 };
        let w: Vec<f32> = (0..rows * cols).map(|_| r.normal() * 0.3).collect();
        let m = PackedMat::pack(&w, rows, cols, &q).unwrap();
        let x: Vec<f32> = (0..cols * b_cols).map(|_| r.normal()).collect();
        let a: Vec<f32> = (0..rank * cols).map(|_| r.normal() * 0.02).collect();
        let b: Vec<f32> = (0..rows * rank).map(|_| r.normal() * 0.02).collect();
        let bias: Vec<f32> = (0..rows).map(|_| r.normal()).collect();
        (m, x, a, b, bias)
    }

    #[test]
    fn fused_kernel_is_bit_identical_to_scalar_reference_for_any_worker_count() {
        let mut r = Rng::new(15);
        for &(rows, cols, b_cols, rank) in
            &[(1, 1, 1, 1), (7, 5, 3, 2), (33, 70, 4, 4), (64, 129, 8, 4), (100, 64, 2, 4)]
        {
            let (m, x, a, b, bias) = random_fused_case(&mut r, rows, cols, b_cols, rank);
            let lora = LoraTerm { a: &a, b: &b, rank, scale: 1.0 / rank as f32 };
            let mut want = Vec::new();
            m.fused_matmul_ref(&x, b_cols, Some(&lora), Some(&bias), &mut want);
            for workers in [1, 2, 3, 8] {
                let mut got = Vec::new();
                m.fused_matmul_into(&x, b_cols, Some(&lora), Some(&bias), workers, &mut got);
                assert_eq!(got.len(), want.len());
                for (i, (g, w)) in got.iter().zip(&want).enumerate() {
                    assert_eq!(
                        g.to_bits(),
                        w.to_bits(),
                        "rows={rows} cols={cols} b={b_cols} workers={workers} elem {i}: {g} vs {w}"
                    );
                }
            }
        }
    }

    #[test]
    fn fused_kernel_without_lora_or_bias_matches_reference() {
        let mut r = Rng::new(16);
        let (m, x, _, _, _) = random_fused_case(&mut r, 48, 96, 5, 4);
        let mut want = Vec::new();
        m.fused_matmul_ref(&x, 5, None, None, &mut want);
        let mut got = Vec::new();
        m.fused_matmul_into(&x, 5, None, None, 4, &mut got);
        assert_eq!(
            want.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
            got.iter().map(|v| v.to_bits()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn model_blob_roundtrips_exactly() {
        let mut r = Rng::new(17);
        let q4 = Quantizer::SignedFp { fmt: FpFormat::new(2, 1), maxval: 0.5 };
        let q8 = Quantizer::IntSym { n_bits: 8, maxval: 0.5 };
        let mut model = PackedModel::default();
        for (i, (q, rows, cols)) in [(q4, 16, 36), (q8, 8, 16), (q4, 5, 7)].iter().enumerate() {
            let w: Vec<f32> = (0..rows * cols).map(|_| r.normal() * 0.2).collect();
            model.layers.push(PackedLayer {
                name: format!("layer{i}"),
                mat: PackedMat::pack(&w, *rows, *cols, q).unwrap(),
            });
        }
        let blob = model.to_bytes();
        let back = PackedModel::from_bytes(&blob).unwrap();
        assert_eq!(model, back);
        assert_eq!(model.bytes(), back.bytes());

        let dir = std::env::temp_dir().join(format!("msfp_packed_test_{}", std::process::id()));
        let path = dir.join("packed.mpk");
        model.save(&path).unwrap();
        let loaded = PackedModel::load(&path).unwrap();
        assert_eq!(model, loaded);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn model_blob_rejects_corruption() {
        let q = Quantizer::IntSym { n_bits: 4, maxval: 1.0 };
        let model = PackedModel {
            layers: vec![PackedLayer {
                name: "l".into(),
                mat: PackedMat::pack(&[0.5f32; 12], 3, 4, &q).unwrap(),
            }],
        };
        let mut blob = model.to_bytes();
        assert!(PackedModel::from_bytes(&blob[..blob.len() - 1]).is_err());
        blob[0] = b'X';
        assert!(PackedModel::from_bytes(&blob).is_err());
        assert!(PackedModel::from_bytes(b"MSFPPK99\0\0\0\0").is_err());
    }
}

/// Minimal byte cursor for blob parsing.
struct Cursor<'a> {
    data: &'a [u8],
    pos: usize,
}

impl<'a> Cursor<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.pos + n > self.data.len() {
            bail!("packed blob truncated at byte {} (need {n} more)", self.pos);
        }
        let s = &self.data[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    fn u32(&mut self) -> Result<u32> {
        let b = self.take(4)?;
        Ok(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }

    fn u64(&mut self) -> Result<u64> {
        let b = self.take(8)?;
        Ok(u64::from_le_bytes([
            b[0], b[1], b[2], b[3], b[4], b[5], b[6], b[7],
        ]))
    }
}
