//! Floating-point fake quantize-dequantize — bit-exact Rust mirror of the
//! deployed Pallas kernel (numerics contract in python/compile/kernels/ref.py).
//!
//! The MSFP search (Algorithm 1) evaluates millions of candidate-quantizer
//! MSEs against calibration samples; it MUST use the exact arithmetic the
//! serving kernel applies, or the search optimizes the wrong objective.
//! Agreement is pinned by tests/golden.rs against artifacts generated from
//! the Python reference.

/// Exact 2^k for k in [-126, 127], via bit assembly.
#[inline]
pub fn exp2_int(k: i32) -> f32 {
    debug_assert!((-126..=127).contains(&k));
    f32::from_bits(((k + 127) as u32) << 23)
}

/// floor(log2(x)) for x >= 0 via IEEE-754 exponent extraction (exact).
/// x == 0 returns the sentinel -200 (callers clamp).
#[inline]
pub fn floor_log2(x: f32) -> i32 {
    let bits = x.to_bits();
    let e = ((bits >> 23) & 0xFF) as i32;
    let m = bits & 0x007F_FFFF;
    if e == 0 {
        if m == 0 {
            -200
        } else {
            (31 - m.leading_zeros() as i32) - 149
        }
    } else {
        e - 127
    }
}

/// Deterministic half-up rounding: floor(v + 0.5).
#[inline]
pub fn rnd(v: f32) -> f32 {
    (v + 0.5).floor()
}

/// Smallest normal binade exponent for an e-bit exponent field, floored at
/// -100 so `step = 2^(e_min - m)` stays a normal f32 for any mantissa width
/// (part of the shared numerics contract — ref.py applies the same floor).
#[inline]
pub fn e_min_of(e_bits: i32) -> i32 {
    (-((1i64 << e_bits) - 1)).max(-100) as i32
}

/// Signed ExMy fake-qdq (paper Eq. 6), grid anchored at `maxval`.
#[inline]
pub fn fp_qdq_signed(x: f32, maxval: f32, e_bits: i32, m_bits: i32) -> f32 {
    let full = 2.0 - exp2_int(-m_bits);
    let a = maxval / full;
    let y = (x / a).clamp(-full, full);
    let e = floor_log2(y.abs()).clamp(e_min_of(e_bits), 0);
    let step = exp2_int(e - m_bits);
    rnd(y / step) * step * a
}

/// Unsigned ExMy fake-qdq with zero point (paper Eq. 8).
#[inline]
pub fn fp_qdq_unsigned(x: f32, maxval: f32, e_bits: i32, m_bits: i32, zp: f32) -> f32 {
    let full = 2.0 - exp2_int(-m_bits);
    let a = maxval / full;
    let y = ((x - zp) / a).clamp(0.0, full);
    let e = floor_log2(y).clamp(e_min_of(e_bits), 0);
    let step = exp2_int(e - m_bits);
    rnd(y / step) * step * a + zp
}

/// Signed grid with an added zero point — NOT part of the deployed kernel;
/// used only by the Figure-4 strategy analysis (the paper shows it brings
/// minimal benefit, motivating MSFP's zp-only-for-unsigned choice).
#[inline]
pub fn fp_qdq_signed_zp(x: f32, maxval: f32, e_bits: i32, m_bits: i32, zp: f32) -> f32 {
    fp_qdq_signed(x - zp, maxval, e_bits, m_bits) + zp
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exp2_exactness() {
        assert_eq!(exp2_int(0), 1.0);
        assert_eq!(exp2_int(3), 8.0);
        assert_eq!(exp2_int(-4), 0.0625);
        assert_eq!(exp2_int(-126), f32::MIN_POSITIVE);
    }

    #[test]
    fn floor_log2_cases() {
        assert_eq!(floor_log2(1.0), 0);
        assert_eq!(floor_log2(1.999), 0);
        assert_eq!(floor_log2(2.0), 1);
        assert_eq!(floor_log2(0.5), -1);
        assert_eq!(floor_log2(0.49), -2);
        assert_eq!(floor_log2(3e-39), -128); // subnormal
        assert_eq!(floor_log2(0.0), -200);
    }

    #[test]
    fn rnd_half_up() {
        assert_eq!(rnd(0.5), 1.0);
        assert_eq!(rnd(-0.5), 0.0);
        assert_eq!(rnd(1.49), 1.0);
        assert_eq!(rnd(-1.5), -1.0);
    }

    #[test]
    fn signed_idempotent_and_bounded() {
        let mut rng = crate::util::rng::Rng::new(1);
        for _ in 0..2000 {
            let x = rng.normal() * 4.0;
            let q = fp_qdq_signed(x, 2.5, 2, 1);
            assert!(q.abs() <= 2.5 * (1.0 + 1e-6));
            let q2 = fp_qdq_signed(q, 2.5, 2, 1);
            assert!((q - q2).abs() <= 1e-6, "x={x} q={q} q2={q2}");
        }
    }

    #[test]
    fn signed_odd_symmetry() {
        let mut rng = crate::util::rng::Rng::new(2);
        for _ in 0..2000 {
            let x = rng.normal() * 3.0;
            let q = fp_qdq_signed(x, 1.7, 3, 2);
            let qn = fp_qdq_signed(-x, 1.7, 3, 2);
            assert_eq!(q, -qn);
        }
    }

    #[test]
    fn signed_hits_maxval() {
        // the top grid point is exactly maxval
        let q = fp_qdq_signed(100.0, 2.5, 2, 1);
        assert!((q - 2.5).abs() < 1e-6);
    }

    #[test]
    fn unsigned_floor_at_zp() {
        let zp = -0.25;
        for x in [-5.0f32, -0.3, -0.25, -0.1, 0.0, 0.5, 10.0] {
            let q = fp_qdq_unsigned(x, 2.0, 2, 2, zp);
            assert!(q >= zp - 1e-6, "x={x} q={q}");
            assert!(q <= 2.0 + zp + 1e-5);
        }
    }

    #[test]
    fn unsigned_preserves_subzero_info() {
        // Paper's Observation 1 fix: with zp = -0.278, sub-zero SiLU values
        // retain resolution the signed grid lacks at 4 bits.
        let zp = -0.278f32;
        let xs: Vec<f32> = (0..100).map(|i| -0.278 + 0.00278 * i as f32).collect();
        let mse_unsigned: f32 = xs
            .iter()
            .map(|&x| (fp_qdq_unsigned(x, 3.0 - zp, 1, 3, zp) - x).powi(2))
            .sum::<f32>()
            / xs.len() as f32;
        let mse_signed: f32 = xs
            .iter()
            .map(|&x| (fp_qdq_signed(x, 3.0, 1, 2) - x).powi(2))
            .sum::<f32>()
            / xs.len() as f32;
        assert!(mse_unsigned < mse_signed, "{mse_unsigned} vs {mse_signed}");
    }

    #[test]
    fn e0_formats_are_uniform_grids() {
        // E0M3 signed: uniform step everywhere = INT-like
        let m = 3;
        let maxval = 1.75f32;
        let a = maxval / (2.0 - exp2_int(-m));
        let step = a * exp2_int(-m);
        for i in -14..=14 {
            let x = i as f32 * step;
            let q = fp_qdq_signed(x, maxval, 0, m);
            assert!((q - x).abs() < 1e-6, "grid point {x} not preserved -> {q}");
        }
    }

    #[test]
    fn error_bounded_by_half_step_top_binade() {
        let maxval = 1.0f32;
        let m = 2;
        let a = maxval / (2.0 - exp2_int(-m));
        let top_step = a * exp2_int(-m);
        for i in 0..100 {
            let x = 0.55 + 0.0045 * i as f32;
            let q = fp_qdq_signed(x, maxval, 2, m);
            assert!((q - x).abs() <= top_step / 2.0 + 1e-7);
        }
    }
}
