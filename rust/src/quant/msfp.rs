//! The MSFP framework: assemble a per-layer quantization scheme for a whole
//! model from calibration data (paper §4.1 + Appendix B/C).
//!
//! Per layer: classify AAL/NAL from calibration stats, search the weight
//! quantizer over the tensor itself, search the activation quantizer over
//! calibration samples (mixup stage-2 for AALs), and encode everything as
//! the qparams[L, 8] runtime input of the serving/fine-tune graphs.

use std::path::{Path, PathBuf};

use super::classify::LayerClass;
use super::search::Quantizer;
use super::session::QuantSession;

/// On-disk layout of a serving state directory: the quantized model
/// (`runtime::QuantState::save`) next to its recalibration drift window
/// (`recal::SketchSet::save`), so a restarted server resumes *both* — it
/// serves the last hot-swapped qparams and keeps scoring drift against the
/// partially filled sketch window instead of starting blind.
///
/// Layout under `root`:
///   * `quant.mts`     — the `QuantState` tensor store;
///   * `sketches.msk`  — the versioned `SketchSet` snapshot;
///   * `packed.mpk`    — the versioned nibble-packed weight blob
///     (`quant::packed::PackedModel::save`), the packed backend's
///     sub-byte code indices + per-layer code tables;
///   * `trace.mtr`     — the flight-recorder postmortem
///     (`obs::Trace::save`), dumped on shed storms, injected faults,
///     recal-check panics and shutdown;
///   * `metrics.jsonl` — the per-round telemetry time series
///     (`obs::Telemetry::to_jsonl`), written alongside the trace.
#[derive(Debug, Clone)]
pub struct StateDir {
    root: PathBuf,
}

impl StateDir {
    pub fn new(root: impl Into<PathBuf>) -> StateDir {
        StateDir { root: root.into() }
    }

    pub fn root(&self) -> &Path {
        &self.root
    }

    /// Path of the quantized-model store (`QuantState::save`/`load`).
    pub fn quant_path(&self) -> PathBuf {
        self.root.join("quant.mts")
    }

    /// Path of the sketch snapshot (`SketchSet::save`/`load`).
    pub fn sketch_path(&self) -> PathBuf {
        self.root.join("sketches.msk")
    }

    /// Path of the packed-weight blob (`PackedModel::save`/`load`).
    pub fn packed_path(&self) -> PathBuf {
        self.root.join("packed.mpk")
    }

    /// Path of the flight-recorder postmortem (`obs::Trace::save`/`load`).
    pub fn trace_path(&self) -> PathBuf {
        self.root.join("trace.mtr")
    }

    /// Path of the per-round telemetry export (`obs::Telemetry::to_jsonl`).
    pub fn telemetry_path(&self) -> PathBuf {
        self.root.join("metrics.jsonl")
    }

    /// Remove staged `*.tmp.<pid>.<seq>` files left by a process killed
    /// mid-`atomic_write`. The staging names are unique per (pid, seq) so
    /// a stray is never read as state, but sweeping at server start keeps
    /// the directory to exactly the committed checkpoints. Returns how
    /// many strays were removed; a missing or unreadable root sweeps
    /// nothing.
    pub fn sweep_stale_tmp(&self) -> usize {
        let Ok(entries) = std::fs::read_dir(&self.root) else {
            return 0;
        };
        let mut swept = 0;
        for entry in entries.flatten() {
            let name = entry.file_name();
            let name = name.to_string_lossy();
            if name.contains(".tmp.") && std::fs::remove_file(entry.path()).is_ok() {
                swept += 1;
            }
        }
        swept
    }
}

/// Calibration data for one quantized layer.
#[derive(Debug, Clone)]
pub struct LayerCalib {
    pub name: String,
    /// subsampled input activations (from the *_calib artifact)
    pub acts: Vec<f32>,
    pub min: f32,
    pub max: f32,
    /// architecture ground truth (layer follows SiLU); used for reporting,
    /// the scheme itself classifies from stats
    pub aal_hint: bool,
}

impl LayerCalib {
    /// Build a calibration layer from raw samples, deriving min/max.
    /// Used by synthetic-model tests/benches and by recalibration paths
    /// that only have a sample pool (callers with exact extrema — e.g.
    /// `recal::sketch` — construct the struct directly instead).
    pub fn from_samples(name: impl Into<String>, acts: Vec<f32>, aal_hint: bool) -> LayerCalib {
        let min = acts.iter().cloned().fold(f32::INFINITY, f32::min);
        let max = acts.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        LayerCalib { name: name.into(), acts, min, max, aal_hint }
    }
}

/// Quantization decision for one layer.
#[derive(Debug, Clone)]
pub struct LayerQuant {
    pub name: String,
    pub weight: Quantizer,
    pub act: Quantizer,
    pub w_mse: f64,
    pub a_mse: f64,
    pub class: LayerClass,
}

/// Whole-model scheme: one row per quantized layer, graph-encodable.
#[derive(Debug, Clone)]
pub struct QuantScheme {
    pub layers: Vec<LayerQuant>,
}

/// Which initialization to run (ours vs the baseline families).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Method {
    /// MSFP: signed FP everywhere + mixup unsigned+zp on AALs (ours).
    Msfp,
    /// Signed FP only (the paper's ablation baseline, Table 4 row 1).
    SignedFp,
    /// Symmetric min-max INT (LSQ-init / naive PTQ).
    IntMinMax,
    /// MSE-searched INT (Q-Diffusion / EDA-DM / EfficientDM-style PTQ).
    IntMse,
}

#[derive(Debug, Clone)]
pub struct QuantOpts {
    pub method: Method,
    /// per-layer weight bit-width (IO layers typically 8, rest 4/6)
    pub wbits: Vec<i32>,
    /// per-layer activation bit-width
    pub abits: Vec<i32>,
    /// Table-5 override of the weight maxval space (fractions of maxval0)
    pub weight_space: Option<(f32, f32)>,
    /// maxval grid resolution (activations use Appendix B's 100)
    pub maxval_points: usize,
    pub threads: usize,
}

impl QuantOpts {
    pub fn new(method: Method, n_layers: usize, wbits: i32, abits: i32) -> QuantOpts {
        QuantOpts {
            method,
            wbits: vec![wbits; n_layers],
            abits: vec![abits; n_layers],
            weight_space: None,
            maxval_points: 40,
            threads: 0,
        }
    }

    /// Paper's standard config: input & output layers at 8 bits.
    pub fn with_io_8bit(mut self, io_layers: &[usize]) -> QuantOpts {
        for &i in io_layers {
            if i < self.wbits.len() {
                self.wbits[i] = 8;
                self.abits[i] = 8;
            }
        }
        self
    }

    /// The graceful-degradation knob set: every non-IO layer drops to at
    /// most (`wbits`, `abits`). Layers already at or below the target keep
    /// their bits, and 8-bit (IO) layers are left untouched — they anchor
    /// the quality floor the serving coordinator downgrades onto under
    /// overload.
    pub fn with_degraded_bits(mut self, wbits: i32, abits: i32) -> QuantOpts {
        for w in &mut self.wbits {
            if *w < 8 {
                *w = (*w).min(wbits);
            }
        }
        for a in &mut self.abits {
            if *a < 8 {
                *a = (*a).min(abits);
            }
        }
        self
    }
}

/// Run the initialization over all layers. `weights[l]` is layer l's weight
/// tensor (sliced from the flat param store by the manifest).
///
/// Compatibility shim over a one-shot [`QuantSession`]: callers scoring
/// more than one knob setting on the same model (table sweeps, method
/// comparisons) should build the session themselves so the per-tensor
/// sort/prefix preprocessing and knob-invariant sub-searches are shared
/// across points.
pub fn quantize_model(weights: &[Vec<f32>], calib: &[LayerCalib], opts: &QuantOpts) -> QuantScheme {
    QuantSession::new(weights, calib).quantize(opts)
}

impl QuantScheme {
    /// Flatten into the qparams[L, 8] runtime input:
    /// [w_maxval, w_ebits, w_mbits, a_sign, a_maxval, a_ebits, a_mbits, a_zp]
    pub fn qparams_rows(&self) -> Vec<f32> {
        let mut rows = Vec::with_capacity(self.layers.len() * 8);
        for l in &self.layers {
            let w = l.weight.encode_weight();
            let a = l.act.encode_act();
            rows.extend_from_slice(&[w[0], w[1], w[2], a[0], a[1], a[2], a[3], a[4]]);
        }
        rows
    }

    pub fn n_aal(&self) -> usize {
        self.layers.iter().filter(|l| l.class == LayerClass::Aal).count()
    }

    /// Fraction of AALs where the mixup picked the unsigned quantizer
    /// (paper: > 95%).
    pub fn unsigned_fraction_on_aals(&self) -> f32 {
        let aals: Vec<_> =
            self.layers.iter().filter(|l| l.class == LayerClass::Aal).collect();
        if aals.is_empty() {
            return 0.0;
        }
        let unsigned = aals
            .iter()
            .filter(|l| matches!(l.act, Quantizer::UnsignedFp { .. }))
            .count();
        unsigned as f32 / aals.len() as f32
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn state_dir_sweeps_only_stale_tmp_files() {
        let root = std::env::temp_dir().join("msfp_state_sweep");
        let _ = std::fs::remove_dir_all(&root);
        std::fs::create_dir_all(&root).unwrap();
        let sd = StateDir::new(&root);
        std::fs::write(sd.quant_path(), b"committed").unwrap();
        std::fs::write(root.join("quant.tmp.12345.0"), b"stray").unwrap();
        std::fs::write(root.join("sketches.tmp.12345.7"), b"stray").unwrap();
        assert_eq!(sd.sweep_stale_tmp(), 2);
        assert!(sd.quant_path().exists());
        assert_eq!(std::fs::read_dir(&root).unwrap().count(), 1);
        // idempotent, and a missing root is a no-op
        assert_eq!(sd.sweep_stale_tmp(), 0);
        assert_eq!(StateDir::new(root.join("nope")).sweep_stale_tmp(), 0);
    }

    fn silu(x: f32) -> f32 {
        x / (1.0 + (-x).exp())
    }

    fn fake_model(n_layers: usize, seed: u64) -> (Vec<Vec<f32>>, Vec<LayerCalib>) {
        let mut rng = Rng::new(seed);
        let mut weights = Vec::new();
        let mut calib = Vec::new();
        for l in 0..n_layers {
            weights.push(rng.normal_vec(512, 0.1));
            let aal = l % 2 == 0;
            let acts: Vec<f32> = (0..1024)
                .map(|_| {
                    let x = rng.normal() * 2.0;
                    if aal {
                        silu(x)
                    } else {
                        x
                    }
                })
                .collect();
            let min = acts.iter().cloned().fold(f32::INFINITY, f32::min);
            let max = acts.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
            calib.push(LayerCalib { name: format!("l{l}"), acts, min, max, aal_hint: aal });
        }
        (weights, calib)
    }

    #[test]
    fn msfp_beats_signed_only_on_acts() {
        let (w, c) = fake_model(6, 1);
        let ours = quantize_model(&w, &c, &QuantOpts::new(Method::Msfp, 6, 4, 4));
        let signed = quantize_model(&w, &c, &QuantOpts::new(Method::SignedFp, 6, 4, 4));
        let ours_mse: f64 = ours.layers.iter().map(|l| l.a_mse).sum();
        let signed_mse: f64 = signed.layers.iter().map(|l| l.a_mse).sum();
        assert!(ours_mse < signed_mse, "{ours_mse} vs {signed_mse}");
    }

    #[test]
    fn classification_matches_hints() {
        let (w, c) = fake_model(8, 2);
        let scheme = quantize_model(&w, &c, &QuantOpts::new(Method::Msfp, 8, 4, 4));
        for (l, cal) in scheme.layers.iter().zip(&c) {
            let is_aal = l.class == LayerClass::Aal;
            assert_eq!(is_aal, cal.aal_hint, "layer {}", l.name);
        }
    }

    #[test]
    fn unsigned_dominates_on_aals() {
        let (w, c) = fake_model(10, 3);
        let scheme = quantize_model(&w, &c, &QuantOpts::new(Method::Msfp, 10, 4, 4));
        assert!(scheme.unsigned_fraction_on_aals() >= 0.8,
            "{}", scheme.unsigned_fraction_on_aals());
    }

    #[test]
    fn qparams_rows_layout() {
        let (w, c) = fake_model(3, 4);
        let scheme = quantize_model(&w, &c, &QuantOpts::new(Method::Msfp, 3, 4, 4));
        let rows = scheme.qparams_rows();
        assert_eq!(rows.len(), 3 * 8);
        for l in 0..3 {
            assert!(rows[l * 8] > 0.0); // w_maxval
            assert!(rows[l * 8 + 4] > 0.0); // a_maxval
        }
    }

    #[test]
    fn state_dir_layout() {
        let sd = StateDir::new("/tmp/serve_a");
        assert_eq!(sd.quant_path(), std::path::Path::new("/tmp/serve_a/quant.mts"));
        assert_eq!(sd.sketch_path(), std::path::Path::new("/tmp/serve_a/sketches.msk"));
        assert_eq!(sd.packed_path(), std::path::Path::new("/tmp/serve_a/packed.mpk"));
        assert_eq!(sd.root(), std::path::Path::new("/tmp/serve_a"));
    }

    #[test]
    fn io_8bit_override() {
        let opts = QuantOpts::new(Method::Msfp, 5, 4, 4).with_io_8bit(&[0, 4]);
        assert_eq!(opts.wbits, vec![8, 4, 4, 4, 8]);
        assert_eq!(opts.abits, vec![8, 4, 4, 4, 8]);
    }

    #[test]
    fn degraded_bits_lower_non_io_layers_only() {
        let opts = QuantOpts::new(Method::Msfp, 5, 4, 6).with_io_8bit(&[0, 4]);
        let d = opts.clone().with_degraded_bits(3, 3);
        // IO anchors stay at 8; everything else drops to the target
        assert_eq!(d.wbits, vec![8, 3, 3, 3, 8]);
        assert_eq!(d.abits, vec![8, 3, 3, 3, 8]);
        // a layer already below the target keeps its (lower) bits
        let mut low = opts;
        low.wbits[2] = 2;
        let d = low.with_degraded_bits(3, 3);
        assert_eq!(d.wbits, vec![8, 3, 2, 3, 8]);
        // degrading to the current bits is a no-op
        let opts = QuantOpts::new(Method::Msfp, 3, 4, 4);
        let d = opts.clone().with_degraded_bits(4, 4);
        assert_eq!(d.wbits, opts.wbits);
        assert_eq!(d.abits, opts.abits);
    }

    #[test]
    fn int_mse_beats_minmax() {
        let (w, c) = fake_model(4, 5);
        let mm = quantize_model(&w, &c, &QuantOpts::new(Method::IntMinMax, 4, 4, 4));
        let ms = quantize_model(&w, &c, &QuantOpts::new(Method::IntMse, 4, 4, 4));
        let mm_mse: f64 = mm.layers.iter().map(|l| l.w_mse + l.a_mse).sum();
        let ms_mse: f64 = ms.layers.iter().map(|l| l.w_mse + l.a_mse).sum();
        assert!(ms_mse <= mm_mse + 1e-12);
    }

    #[test]
    fn fp4_beats_int4_msfp_claim() {
        // Appendix D's headline: FP PTQ beats INT PTQ on diffusion-style data
        let (w, c) = fake_model(8, 6);
        let fp = quantize_model(&w, &c, &QuantOpts::new(Method::Msfp, 8, 6, 6));
        let int = quantize_model(&w, &c, &QuantOpts::new(Method::IntMse, 8, 6, 6));
        let fp_mse: f64 = fp.layers.iter().map(|l| l.a_mse).sum();
        let int_mse: f64 = int.layers.iter().map(|l| l.a_mse).sum();
        assert!(fp_mse < int_mse * 1.5, "fp={fp_mse} int={int_mse}");
    }
}
