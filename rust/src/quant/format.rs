//! ExMy format space and the paper's search spaces (Table 6, Appendix B).

use std::fmt;

/// A floating-point format: e exponent bits, m mantissa bits. The sign bit
/// is implied by how the format is used (signed: e+m = n-1; unsigned:
/// e+m = n).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct FpFormat {
    pub e_bits: i32,
    pub m_bits: i32,
}

impl FpFormat {
    pub fn new(e_bits: i32, m_bits: i32) -> FpFormat {
        FpFormat { e_bits, m_bits }
    }

    /// Total data bits when used signed (adds the sign bit).
    pub fn signed_bits(&self) -> i32 {
        self.e_bits + self.m_bits + 1
    }

    /// Total data bits when used unsigned.
    pub fn unsigned_bits(&self) -> i32 {
        self.e_bits + self.m_bits
    }
}

impl fmt::Display for FpFormat {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "E{}M{}", self.e_bits, self.m_bits)
    }
}

/// Weight-format search space per bit-width (paper Table 6: the four most
/// expressive signed formats per n).
pub fn weight_formats(bits: i32) -> Vec<FpFormat> {
    match bits {
        4 => vec![FpFormat::new(3, 0), FpFormat::new(2, 1), FpFormat::new(1, 2), FpFormat::new(0, 3)],
        6 => vec![FpFormat::new(4, 1), FpFormat::new(3, 2), FpFormat::new(2, 3), FpFormat::new(1, 4)],
        8 => vec![FpFormat::new(5, 2), FpFormat::new(4, 3), FpFormat::new(3, 4), FpFormat::new(2, 5)],
        n => {
            // general fallback: all signed splits
            (0..n).map(|e| FpFormat::new(e, n - 1 - e)).collect()
        }
    }
}

/// Activation signed-format space: ALL splits e+m = n-1 (Appendix B:
/// "we include all possible formats ... within the search space").
pub fn act_signed_formats(bits: i32) -> Vec<FpFormat> {
    (0..bits).map(|e| FpFormat::new(e, bits - 1 - e)).collect()
}

/// Activation unsigned-format space: all splits e+m = n with m >= 1
/// (the freed sign bit becomes exponent/mantissa width — paper §4.1).
pub fn act_unsigned_formats(bits: i32) -> Vec<FpFormat> {
    (0..bits).map(|e| FpFormat::new(e, bits - e)).collect()
}

/// The weight maxval search interval per bit-width, as fractions of
/// maxval_0 (Appendix B Table 6 / Table 5 exploration).
pub fn weight_maxval_space(bits: i32) -> (f32, f32) {
    match bits {
        4 => (0.8, 2.0),
        _ => (0.9, 2.0),
    }
}

/// Zero-point search space: linspace(-0.3, 0, 6) — the SiLU trough
/// min is -0.278 (paper Appendix B).
pub fn zp_space() -> Vec<f32> {
    (0..6).map(|i| -0.3 + 0.06 * i as f32).collect()
}

/// SiLU's global minimum value: min_x x·sigmoid(x) ≈ -0.2785.
pub const SILU_MIN: f32 = -0.2785;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table6_weight_formats() {
        assert_eq!(
            weight_formats(4).iter().map(|f| f.to_string()).collect::<Vec<_>>(),
            vec!["E3M0", "E2M1", "E1M2", "E0M3"]
        );
        assert_eq!(weight_formats(6)[0].to_string(), "E4M1");
        assert_eq!(weight_formats(8)[3].to_string(), "E2M5");
    }

    #[test]
    fn bit_budgets_hold() {
        for bits in [4, 6, 8] {
            for f in weight_formats(bits) {
                assert_eq!(f.signed_bits(), bits);
            }
            for f in act_signed_formats(bits) {
                assert_eq!(f.signed_bits(), bits);
            }
            for f in act_unsigned_formats(bits) {
                assert_eq!(f.unsigned_bits(), bits, "{f}");
            }
        }
    }

    #[test]
    fn unsigned_has_one_extra_bit_of_width() {
        // the paper's freed-sign-bit argument: for the same n, unsigned
        // formats carry one more exponent+mantissa bit than signed ones.
        let s: i32 = act_signed_formats(4).iter().map(|f| f.e_bits + f.m_bits).max().unwrap();
        let u: i32 = act_unsigned_formats(4).iter().map(|f| f.e_bits + f.m_bits).max().unwrap();
        assert_eq!(u, s + 1);
    }

    #[test]
    fn zp_space_covers_silu_trough() {
        let zs = zp_space();
        assert_eq!(zs.len(), 6);
        assert!((zs[0] + 0.3).abs() < 1e-6);
        assert!(zs[5].abs() < 1e-6);
        assert!(zs.iter().any(|&z| (z - SILU_MIN).abs() < 0.04));
    }
}
