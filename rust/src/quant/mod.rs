//! The paper's quantization stack: FP/INT fake-quant numerics (bit-exact
//! mirror of the Pallas kernel — see python/compile/kernels/ref.py for the
//! shared contract), the ExMy format space, AAL/NAL classification, the
//! search-based initialization (Algorithm 1) and the MSFP framework that
//! assigns a quantizer to every layer.

pub mod format;
pub mod fp;
pub mod int;
pub mod grid;
pub mod search;
pub mod classify;
pub mod msfp;
pub mod packed;
pub mod session;

pub use format::FpFormat;
pub use grid::GridEngine;
pub use msfp::{LayerQuant, QuantScheme, StateDir};
pub use packed::{PackedMat, PackedModel, PackedTensor};
pub use session::QuantSession;
