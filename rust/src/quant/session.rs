//! Model-level quantization search sessions.
//!
//! Every sweep in the paper's evaluation (Tables 4/5/7/8/11) re-runs the
//! MSFP initialization over the *same* weights and calibration samples
//! with different knobs — method, bit-width, weight maxval space. The
//! expensive part of each run is identical across points: sorting each
//! tensor's samples and building the prefix sums of the grid-segment
//! engine (quant::grid). A [`QuantSession`] owns that preprocessing:
//!
//!  * one [`GridEngine`] per weight tensor and one per layer's activation
//!    samples, built lazily on first use and shared by every subsequent
//!    [`QuantSession::quantize`] call;
//!  * the per-layer stats the searches need (`maxval0` of weights and
//!    activations, the AAL/NAL class);
//!  * a memo of finished sub-searches keyed by their exact knobs, so a
//!    sweep that only moves `weight_space` re-scores weights and reuses
//!    the (invariant) activation winners outright.
//!
//! Results are bit-identical to a cold [`quantize_model`] call for every
//! method: the engines are deterministic functions of the samples, the
//! searches are thread-count-invariant (see quant::grid's pruning rules),
//! and memoization only replays values the same call would recompute.
//! `quantize_model` itself is a compatibility shim over a one-shot
//! session, and tests/props.rs pins the reused-session parity.
//!
//! Sessions are also *incrementally updatable*: when online recalibration
//! (`crate::recal`) finds a drifted layer,
//! [`QuantSession::update_layer_calib`] swaps in that layer's fresh
//! calibration, rebuilding exactly one activation engine and invalidating
//! only that layer's memoized activation sub-searches — every other
//! layer's preprocessing and winners are reused, and the next `quantize`
//! call is bit-identical to a cold session on the updated calibration.
//!
//! [`quantize_model`]: super::msfp::quantize_model

use std::borrow::Cow;
use std::collections::HashMap;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Mutex, OnceLock};

use crate::util::threadpool::{parallel_map, resolve_threads};

use super::classify::{classify, LayerClass};
use super::grid::GridEngine;
use super::msfp::{LayerCalib, LayerQuant, Method, QuantOpts, QuantScheme};
use super::search::{
    int_weight_minmax, search_act_int_on, search_act_msfp_on, search_weight_fp_on,
    search_weight_int_on, Quantizer,
};

/// Memo key for a layer's weight-quantizer search. f32 knobs are keyed by
/// bit pattern so identical sweep points hit the cache exactly.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
enum WeightKey {
    Fp { bits: i32, space: Option<(u32, u32)>, points: usize },
    IntMinMax { bits: i32 },
    IntMse { bits: i32, points: usize },
}

/// Memo key for a layer's activation-quantizer search.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
enum ActKey {
    Fp { bits: i32, mixup: bool, points: usize },
    IntMinMax { bits: i32 },
    IntMse { bits: i32, points: usize },
}

type Memo<K> = Mutex<HashMap<K, (Quantizer, f64)>>;

struct LayerCache {
    /// engine over the layer's weight tensor (lazy: INT min-max never
    /// needs it)
    w_eng: OnceLock<GridEngine>,
    /// engine over the layer's calibration activations
    a_eng: OnceLock<GridEngine>,
    /// times each engine was actually constructed — observable so tests
    /// can pin that `update_layer_calib` rebuilds exactly one engine
    w_builds: AtomicUsize,
    a_builds: AtomicUsize,
    /// absolute max of the weight tensor, floored at 1e-8
    w_maxval0: f32,
    /// absolute max of the activation samples, floored at 1e-8
    a_maxval0: f32,
    class: LayerClass,
    w_results: Memo<WeightKey>,
    a_results: Memo<ActKey>,
}

impl LayerCache {
    fn new(w: &[f32], c: &LayerCalib) -> LayerCache {
        LayerCache {
            w_eng: OnceLock::new(),
            a_eng: OnceLock::new(),
            w_builds: AtomicUsize::new(0),
            a_builds: AtomicUsize::new(0),
            w_maxval0: w.iter().fold(0.0f32, |a, &b| a.max(b.abs())).max(1e-8),
            a_maxval0: c.acts.iter().fold(0.0f32, |a, &b| a.max(b.abs())).max(1e-8),
            class: classify(c.min, c.max),
            w_results: Mutex::new(HashMap::new()),
            a_results: Mutex::new(HashMap::new()),
        }
    }
}

/// A reusable model-level search session: per-tensor engines + stats built
/// once, re-scored by every `quantize` call (see module docs). Borrows the
/// model data when built with [`QuantSession::new`] (one-shot shims stay
/// zero-copy) and owns it with [`QuantSession::from_owned`] (pipeline
/// sharing without self-referential lifetimes).
pub struct QuantSession<'a> {
    weights: Cow<'a, [Vec<f32>]>,
    calib: Cow<'a, [LayerCalib]>,
    layers: Vec<LayerCache>,
}

/// Memo lookup; the search runs outside the lock (it can take
/// milliseconds, and a racing duplicate computes the identical
/// deterministic result, so last-insert-wins is safe).
fn cached<K: std::hash::Hash + Eq + Copy>(
    memo: &Memo<K>,
    key: K,
    compute: impl FnOnce() -> (Quantizer, f64),
) -> (Quantizer, f64) {
    if let Some(&hit) = memo.lock().unwrap().get(&key) {
        return hit;
    }
    let v = compute();
    memo.lock().unwrap().insert(key, v);
    v
}

impl<'a> QuantSession<'a> {
    /// Build a session borrowing the model's weights and calibration data.
    pub fn new(weights: &'a [Vec<f32>], calib: &'a [LayerCalib]) -> QuantSession<'a> {
        QuantSession::build(Cow::Borrowed(weights), Cow::Borrowed(calib))
    }

    /// Build a session that owns its data (no borrow to keep alive).
    pub fn from_owned(weights: Vec<Vec<f32>>, calib: Vec<LayerCalib>) -> QuantSession<'static> {
        QuantSession::build(Cow::Owned(weights), Cow::Owned(calib))
    }

    fn build(weights: Cow<'a, [Vec<f32>]>, calib: Cow<'a, [LayerCalib]>) -> QuantSession<'a> {
        assert_eq!(weights.len(), calib.len());
        let layers =
            weights.iter().zip(calib.iter()).map(|(w, c)| LayerCache::new(w, c)).collect();
        QuantSession { weights, calib, layers }
    }

    /// Replace layer `l`'s calibration data (the online-recalibration entry
    /// point, `recal`): the layer's activation engine is dropped (rebuilt
    /// lazily from the new samples on next use), its cached activation
    /// stats and AAL/NAL class are recomputed, and its memoized activation
    /// sub-searches are invalidated. Everything else — every other layer's
    /// engines and memos, and this layer's *weight* engine and memo (the
    /// tensor did not change) — survives untouched, so re-quantizing after
    /// an update re-scores exactly one layer's activation searches.
    ///
    /// The result is bit-identical to building a cold session from the
    /// updated calibration: engines are deterministic functions of the
    /// samples and surviving memo entries replay values an identical
    /// search would recompute (pinned by unit tests and tests/props.rs).
    ///
    /// A borrowed session (`QuantSession::new`) clones its calibration
    /// slice on first update (`Cow::to_mut`); sessions built with
    /// [`QuantSession::from_owned`] update in place.
    pub fn update_layer_calib(&mut self, l: usize, calib: LayerCalib) {
        assert!(l < self.layers.len(), "layer {l} out of range ({})", self.layers.len());
        let lc = &mut self.layers[l];
        lc.a_eng = OnceLock::new();
        lc.a_maxval0 = calib.acts.iter().fold(0.0f32, |a, &b| a.max(b.abs())).max(1e-8);
        lc.class = classify(calib.min, calib.max);
        lc.a_results.lock().unwrap().clear();
        self.calib.to_mut()[l] = calib;
    }

    pub fn n_layers(&self) -> usize {
        self.calib.len()
    }

    /// The session's calibration layers (names, samples, min/max stats).
    pub fn calib(&self) -> &[LayerCalib] {
        &self.calib
    }

    /// AAL/NAL class of layer `l` (from the calibration stats).
    pub fn class(&self, l: usize) -> LayerClass {
        self.layers[l].class
    }

    /// Absolute max of layer `l`'s weight tensor (floored at 1e-8).
    pub fn weight_maxval0(&self, l: usize) -> f32 {
        self.layers[l].w_maxval0
    }

    /// Absolute max of layer `l`'s activation samples (floored at 1e-8).
    pub fn act_maxval0(&self, l: usize) -> f32 {
        self.layers[l].a_maxval0
    }

    /// Grid engine over layer `l`'s weight tensor (built on first use).
    pub fn weight_engine(&self, l: usize) -> &GridEngine {
        let lc = &self.layers[l];
        lc.w_eng.get_or_init(|| {
            lc.w_builds.fetch_add(1, Ordering::Relaxed);
            GridEngine::new(&self.weights[l])
        })
    }

    /// Grid engine over layer `l`'s activation samples (built on first
    /// use).
    pub fn act_engine(&self, l: usize) -> &GridEngine {
        let lc = &self.layers[l];
        lc.a_eng.get_or_init(|| {
            lc.a_builds.fetch_add(1, Ordering::Relaxed);
            GridEngine::new(&self.calib[l].acts)
        })
    }

    /// How many times layer `l`'s weight engine has been constructed over
    /// the session's lifetime (stays put across calib updates).
    pub fn weight_engine_builds(&self, l: usize) -> usize {
        self.layers[l].w_builds.load(Ordering::Relaxed)
    }

    /// How many times layer `l`'s activation engine has been constructed
    /// (increments once per `update_layer_calib` + re-quantize cycle).
    pub fn act_engine_builds(&self, l: usize) -> usize {
        self.layers[l].a_builds.load(Ordering::Relaxed)
    }

    /// Memoized weight sub-search entries for layer `l`.
    pub fn weight_memo_len(&self, l: usize) -> usize {
        self.layers[l].w_results.lock().unwrap().len()
    }

    /// Memoized activation sub-search entries for layer `l` (drops to 0 on
    /// `update_layer_calib`).
    pub fn act_memo_len(&self, l: usize) -> usize {
        self.layers[l].a_results.lock().unwrap().len()
    }

    /// Run the initialization for one knob setting against the cached
    /// engines. Repeated calls with different `Method`/bits/`weight_space`
    /// never re-sort, and sub-searches whose knobs are unchanged replay
    /// their memoized winners.
    pub fn quantize(&self, opts: &QuantOpts) -> QuantScheme {
        let idx: Vec<usize> = (0..self.calib.len()).collect();
        // Nested parallelism: the outer parallel_map spreads layers across
        // cores; cores left over when the model has fewer layers than
        // cores go to candidate-level parallelism inside each layer's
        // grid search.
        let total = resolve_threads(opts.threads);
        let outer = total.min(self.calib.len().max(1));
        let inner = (total / outer).max(1); // outer·inner <= total: never oversubscribe
        let layers = parallel_map(&idx, outer, |_, &l| self.quantize_layer(l, opts, inner));
        QuantScheme { layers }
    }

    /// Qparams rows for the serving coordinator's graceful-degradation
    /// variant: the same search with every non-IO layer lowered to at
    /// most (`wbits`, `abits`) — see `QuantOpts::with_degraded_bits`.
    /// After the base `quantize(opts)` this is nearly free: the session
    /// memoizes per-(layer, knob) winners, so only layers whose bits
    /// actually dropped run a new grid search.
    pub fn degraded_qparams(&self, opts: &QuantOpts, wbits: i32, abits: i32) -> Vec<f32> {
        self.quantize(&opts.clone().with_degraded_bits(wbits, abits)).qparams_rows()
    }

    fn quantize_layer(&self, l: usize, opts: &QuantOpts, inner: usize) -> LayerQuant {
        let c = &self.calib[l];
        let lc = &self.layers[l];
        let wbits = opts.wbits[l];
        let abits = opts.abits[l];

        let (weight, w_mse, act, a_mse) = match opts.method {
            Method::Msfp | Method::SignedFp => {
                let space = opts.weight_space;
                let wkey = WeightKey::Fp {
                    bits: wbits,
                    space: space.map(|(lo, hi)| (lo.to_bits(), hi.to_bits())),
                    points: opts.maxval_points,
                };
                let (w, w_mse) = cached(&lc.w_results, wkey, || {
                    let r = search_weight_fp_on(
                        self.weight_engine(l),
                        lc.w_maxval0,
                        wbits,
                        space,
                        opts.maxval_points,
                        inner,
                    );
                    (r.quantizer, r.mse)
                });
                let mixup = opts.method == Method::Msfp && lc.class == LayerClass::Aal;
                let apoints = opts.maxval_points.max(50);
                let akey = ActKey::Fp { bits: abits, mixup, points: apoints };
                let (a, a_mse) = cached(&lc.a_results, akey, || {
                    let r = search_act_msfp_on(
                        self.act_engine(l),
                        abits,
                        lc.a_maxval0,
                        mixup,
                        apoints,
                        inner,
                    );
                    (r.quantizer, r.mse)
                });
                (w, w_mse, a, a_mse)
            }
            Method::IntMinMax => {
                let (w, w_mse) = cached(&lc.w_results, WeightKey::IntMinMax { bits: wbits }, || {
                    let w = int_weight_minmax(&self.weights[l], wbits);
                    let mse = w.mse(&self.weights[l]);
                    (w, mse)
                });
                let (a, a_mse) = cached(&lc.a_results, ActKey::IntMinMax { bits: abits }, || {
                    let a = Quantizer::IntAsym {
                        n_bits: abits,
                        lo: c.min.min(0.0),
                        hi: c.max.max(1e-8),
                    };
                    (a, a.mse(&c.acts))
                });
                (w, w_mse, a, a_mse)
            }
            Method::IntMse => {
                let wkey = WeightKey::IntMse { bits: wbits, points: opts.maxval_points };
                let (w, w_mse) = cached(&lc.w_results, wkey, || {
                    let r = search_weight_int_on(
                        self.weight_engine(l),
                        lc.w_maxval0,
                        wbits,
                        opts.maxval_points,
                        inner,
                    )
                    .expect("INT weight search failed: empty space (maxval_points == 0?) or NaN-poisoned weights");
                    (r.quantizer, r.mse)
                });
                let apoints = opts.maxval_points.max(20);
                let akey = ActKey::IntMse { bits: abits, points: apoints };
                let (a, a_mse) = cached(&lc.a_results, akey, || {
                    let r = search_act_int_on(self.act_engine(l), abits, c.min, c.max, apoints, inner)
                        .expect("INT act search failed: empty space or NaN-poisoned calibration samples");
                    (r.quantizer, r.mse)
                });
                (w, w_mse, a, a_mse)
            }
        };
        LayerQuant { name: c.name.clone(), weight, act, w_mse, a_mse, class: lc.class }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn silu(x: f32) -> f32 {
        x / (1.0 + (-x).exp())
    }

    fn fake_model(n_layers: usize, seed: u64) -> (Vec<Vec<f32>>, Vec<LayerCalib>) {
        let mut rng = Rng::new(seed);
        let mut weights = Vec::new();
        let mut calib = Vec::new();
        for l in 0..n_layers {
            weights.push(rng.normal_vec(384, 0.1));
            let aal = l % 2 == 0;
            let acts: Vec<f32> = (0..768)
                .map(|_| {
                    let x = rng.normal() * 2.0;
                    if aal {
                        silu(x)
                    } else {
                        x
                    }
                })
                .collect();
            let min = acts.iter().cloned().fold(f32::INFINITY, f32::min);
            let max = acts.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
            calib.push(LayerCalib { name: format!("l{l}"), acts, min, max, aal_hint: aal });
        }
        (weights, calib)
    }

    fn assert_identical(a: &QuantScheme, b: &QuantScheme, what: &str) {
        assert_eq!(a.layers.len(), b.layers.len(), "{what}: layer count");
        for (x, y) in a.layers.iter().zip(&b.layers) {
            assert_eq!(x.name, y.name, "{what}");
            assert_eq!(x.weight, y.weight, "{what}: weight of {}", x.name);
            assert_eq!(x.act, y.act, "{what}: act of {}", x.name);
            assert_eq!(x.w_mse.to_bits(), y.w_mse.to_bits(), "{what}: w_mse of {}", x.name);
            assert_eq!(x.a_mse.to_bits(), y.a_mse.to_bits(), "{what}: a_mse of {}", x.name);
            assert_eq!(x.class, y.class, "{what}: class of {}", x.name);
        }
    }

    #[test]
    fn session_sweep_matches_fresh_sessions() {
        // the Table-5 amortization contract: one session scored at every
        // sweep point returns exactly what a cold per-point run returns
        let (w, c) = fake_model(4, 11);
        let session = QuantSession::new(&w, &c);
        for space in [None, Some((0.0001f32, 1.0f32)), Some((0.8, 2.0)), Some((1.0, 2.0))] {
            let mut opts = QuantOpts::new(Method::Msfp, 4, 6, 8);
            opts.weight_space = space;
            let warm = session.quantize(&opts);
            let cold = QuantSession::new(&w, &c).quantize(&opts);
            assert_identical(&warm, &cold, &format!("space {space:?}"));
        }
    }

    #[test]
    fn memoized_replay_is_stable() {
        let (w, c) = fake_model(3, 12);
        let session = QuantSession::new(&w, &c);
        for method in [Method::Msfp, Method::SignedFp, Method::IntMinMax, Method::IntMse] {
            let opts = QuantOpts::new(method, 3, 4, 4);
            let first = session.quantize(&opts);
            let second = session.quantize(&opts);
            assert_identical(&first, &second, &format!("{method:?}"));
        }
    }

    /// Shifted + rescaled activations for one layer (enough drift to move
    /// the argmin and, with the sign flip of the silu trough, the class).
    fn shifted_layer_calib(seed: u64, name: &str) -> LayerCalib {
        let mut rng = Rng::new(seed);
        LayerCalib::from_samples(
            name,
            (0..768).map(|_| rng.normal() * 3.0 + 0.8).collect(),
            false,
        )
    }

    #[test]
    fn update_layer_calib_matches_cold_rebuild_bitwise() {
        let (w, c) = fake_model(5, 21);
        for method in [Method::Msfp, Method::SignedFp, Method::IntMinMax, Method::IntMse] {
            let opts = QuantOpts::new(method, 5, 4, 4);
            let mut session = QuantSession::new(&w, &c);
            let _ = session.quantize(&opts); // warm every memo
            let updated = shifted_layer_calib(77, "l2");
            session.update_layer_calib(2, updated.clone());
            let warm = session.quantize(&opts);
            let mut c2 = c.clone();
            c2[2] = updated;
            let cold = QuantSession::new(&w, &c2).quantize(&opts);
            assert_identical(&warm, &cold, &format!("incremental vs cold ({method:?})"));
        }
    }

    #[test]
    fn update_layer_calib_invalidates_only_that_layer() {
        let (w, c) = fake_model(4, 22);
        let mut session = QuantSession::new(&w, &c);
        let opts = QuantOpts::new(Method::Msfp, 4, 4, 4);
        let _ = session.quantize(&opts);
        for l in 0..4 {
            assert_eq!(session.act_engine_builds(l), 1, "layer {l}");
            assert_eq!(session.weight_engine_builds(l), 1, "layer {l}");
            assert_eq!(session.act_memo_len(l), 1, "layer {l}");
            assert_eq!(session.weight_memo_len(l), 1, "layer {l}");
        }

        let updated = shifted_layer_calib(78, "l1");
        session.update_layer_calib(1, updated.clone());
        // only layer 1's activation memo dropped; its weight memo and every
        // other layer's memos survive
        assert_eq!(session.act_memo_len(1), 0);
        assert_eq!(session.weight_memo_len(1), 1);
        for l in [0usize, 2, 3] {
            assert_eq!(session.act_memo_len(l), 1, "layer {l}");
        }
        // cached stats track the new calibration
        let a0 = updated.acts.iter().fold(0.0f32, |a, &b| a.max(b.abs())).max(1e-8);
        assert_eq!(session.act_maxval0(1), a0);
        assert_eq!(session.class(1), classify(updated.min, updated.max));
        assert_eq!(session.calib()[1].acts, updated.acts);

        let _ = session.quantize(&opts);
        // exactly one activation engine was rebuilt; weight engines and the
        // untouched layers' activation engines were reused as-is
        assert_eq!(session.act_engine_builds(1), 2);
        for l in [0usize, 2, 3] {
            assert_eq!(session.act_engine_builds(l), 1, "layer {l}");
        }
        for l in 0..4 {
            assert_eq!(session.weight_engine_builds(l), 1, "layer {l}");
        }
        assert_eq!(session.act_memo_len(1), 1); // re-scored fresh
    }

    #[test]
    fn update_layer_calib_on_owned_session() {
        let (w, c) = fake_model(3, 23);
        let opts = QuantOpts::new(Method::Msfp, 3, 4, 6);
        let mut session = QuantSession::from_owned(w.clone(), c.clone());
        let _ = session.quantize(&opts);
        let updated = shifted_layer_calib(79, "l0");
        session.update_layer_calib(0, updated.clone());
        let warm = session.quantize(&opts);
        let mut c2 = c;
        c2[0] = updated;
        let cold = QuantSession::new(&w, &c2).quantize(&opts);
        assert_identical(&warm, &cold, "owned incremental vs cold");
    }

    #[test]
    fn degraded_qparams_match_a_fresh_lower_bit_search() {
        let (w, c) = fake_model(4, 31);
        let session = QuantSession::new(&w, &c);
        let opts = QuantOpts::new(Method::Msfp, 4, 4, 4);
        let base = session.quantize(&opts).qparams_rows();
        let deg = session.degraded_qparams(&opts, 3, 3);
        assert_eq!(deg.len(), base.len());
        let bits = |v: &[f32]| v.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
        // bit-identical to quantizing the lowered knobs from scratch —
        // the memoized session takes no shortcuts that change results
        let cold = QuantSession::new(&w, &c)
            .quantize(&opts.clone().with_degraded_bits(3, 3))
            .qparams_rows();
        assert_eq!(bits(&deg), bits(&cold));
        // and the variant is a real change from the base search
        assert_ne!(bits(&deg), bits(&base));
    }

    #[test]
    fn classes_and_stats_match_calib() {
        let (w, c) = fake_model(6, 13);
        let session = QuantSession::new(&w, &c);
        assert_eq!(session.n_layers(), 6);
        for (l, cal) in c.iter().enumerate() {
            let expect = classify(cal.min, cal.max);
            assert_eq!(session.class(l), expect, "layer {l}");
            let a0 = cal.acts.iter().fold(0.0f32, |a, &b| a.max(b.abs())).max(1e-8);
            assert_eq!(session.act_maxval0(l), a0);
            let w0 = w[l].iter().fold(0.0f32, |a, &b| a.max(b.abs())).max(1e-8);
            assert_eq!(session.weight_maxval0(l), w0);
            assert_eq!(session.act_engine(l).len(), cal.acts.len());
            assert_eq!(session.weight_engine(l).len(), w[l].len());
        }
    }
}
