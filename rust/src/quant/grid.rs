//! Closed-form grid-segment search engine for the MSFP initialization.
//!
//! The scalar search (quant::search::scalar) scores every candidate
//! quantizer by re-running fake-qdq over all N calibration samples —
//! O(C·N) per layer per stage. This engine replaces the per-element pass
//! with a per-*grid-point* pass:
//!
//!  1. Sort the layer's samples once and build f64 prefix sums of Σx and
//!     Σx² (`GridEngine::new`, O(N·log N), shared by every candidate and
//!     both mixup stages).
//!  2. For each candidate, enumerate its ≤2^bits distinct qdq output
//!     values (its *grid*) from the same `quant::fp` / `quant::int`
//!     primitives the deployed kernel uses (`quantizer_grid`).
//!  3. Because every fake-qdq in the repo is monotone non-decreasing in x,
//!     the sorted samples split into one contiguous run per grid point.
//!     The run boundary for grid point g is located by binary search with
//!     the predicate `qdq(x) <= g`, evaluated with the *scalar* qdq itself
//!     — so clamping, the half-up tie rule (`rnd(v) = floor(v + 0.5)`
//!     sends an exact midpoint to the upper grid point), and every f32
//!     rounding in `x/a`, `y/step` etc. are honored bit-exactly instead of
//!     being re-derived analytically.
//!  4. Each run's squared error is closed-form from the prefix sums:
//!     Σ(x−g)² = Σx² − 2·g·Σx + g²·n. Total cost per candidate is
//!     O(G·log N) instead of O(N).
//!
//! ## Grid generation
//!
//! Grids are a (deduplicated) superset of the qdq image, computed with the
//! *same f32 expressions* the scalar path applies so membership is
//! bit-exact:
//!
//!  * `SignedFp`   — magnitudes k·2^(e−m)·a for every binade
//!    e ∈ [e_min, 0] (k spans [2^m, 2^{m+1}], the subnormal binade starts
//!    at 0, the top binade is clamped at full = 2 − 2^{−m}), evaluated as
//!    `(k as f32) * step * a`, plus exact negations. A value the rounding
//!    can never produce only yields an empty segment — it cannot corrupt
//!    the score — so the enumeration errs on the inclusive side.
//!  * `UnsignedFp` — the non-negative magnitudes, each shifted by the f32
//!    add `+ zp` (the zero-point shift of paper Eq. 8).
//!  * `IntSym`     — q·s for q ∈ [−qmax−1, qmax].
//!  * `IntAsym`    — (q − z)·s for q ∈ [0, levels], with s and z computed
//!    exactly as `int_qdq_asym` computes them (including the degenerate
//!    `s <= 0 → s = 1` guard).
//!
//! ## Pruning rules
//!
//! `search_min` keeps the best fully-scored SSE so far in an atomic and
//! hands it to each candidate as an abandon threshold: scoring stops as
//! soon as the partial SSE exceeds it. Per-segment SSE is clamped at 0
//! (the closed form can go a hair negative from f64 cancellation), which
//! makes partial sums monotone, so an abandoned candidate provably scores
//! strictly above the final minimum — the selected argmin (lowest index on
//! ties, matching the scalar first-wins rule) is deterministic regardless
//! of thread interleaving. Candidates within one layer are scored through
//! `util::threadpool::parallel_map`, composing with the per-layer
//! parallelism of `quant::msfp::quantize_model` (few-layer models hand
//! their spare cores to the candidate level).
//!
//! Parity with the scalar oracle (same argmin, MSE within 1e-9 relative)
//! is pinned by property tests here and in tests/props.rs.

use std::sync::atomic::{AtomicU64, Ordering};

use crate::util::threadpool::parallel_map;

use super::fp::{e_min_of, exp2_int, rnd};
use super::search::{Quantizer, SearchResult};

/// Sorted calibration samples plus f64 prefix sums — built once per layer
/// (O(N·log N)) and shared by every candidate and search stage.
pub struct GridEngine {
    /// samples, ascending
    xs: Vec<f32>,
    /// p1[i] = Σ xs[..i] in f64
    p1: Vec<f64>,
    /// p2[i] = Σ xs[..i]² in f64
    p2: Vec<f64>,
    /// poisoned-sample score matching the scalar oracle: Some(NAN) when any
    /// sample is NaN (scalar MSE is NaN → unselectable), Some(INF) when any
    /// is ±inf (scalar MSE is +inf for every candidate); the closed form
    /// would otherwise turn both into inf−inf = NaN
    poisoned: Option<f64>,
}

impl GridEngine {
    pub fn new(samples: &[f32]) -> GridEngine {
        let mut xs = samples.to_vec();
        xs.sort_unstable_by(f32::total_cmp);
        let mut p1 = Vec::with_capacity(xs.len() + 1);
        let mut p2 = Vec::with_capacity(xs.len() + 1);
        let (mut a1, mut a2) = (0.0f64, 0.0f64);
        p1.push(0.0);
        p2.push(0.0);
        let mut poisoned = None;
        for &x in &xs {
            if x.is_nan() {
                poisoned = Some(f64::NAN);
            } else if x.is_infinite() && poisoned.is_none() {
                poisoned = Some(f64::INFINITY);
            }
            let x = x as f64;
            a1 += x;
            a2 += x * x;
            p1.push(a1);
            p2.push(a2);
        }
        GridEngine { xs, p1, p2, poisoned }
    }

    pub fn len(&self) -> usize {
        self.xs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.xs.is_empty()
    }

    /// Sum of squared errors of the monotone quantizer `qdq` whose output
    /// grid (ascending, superset of the image) is `grid`. Returns None as
    /// soon as the partial sum exceeds `abandon_above` (early abandon);
    /// pass `f64::INFINITY` to force a full score.
    pub fn sse_fn(
        &self,
        qdq: impl Fn(f32) -> f32,
        grid: &[f32],
        abandon_above: f64,
    ) -> Option<f64> {
        let n = self.xs.len();
        if let Some(score) = self.poisoned {
            return Some(score);
        }
        let mut acc = 0.0f64;
        let mut lo = 0usize;
        for (i, &g) in grid.iter().enumerate() {
            if lo >= n {
                break;
            }
            // Samples in [lo, hi) all quantize to exactly g: the grid
            // covers the image and qdq is monotone, so the run boundary is
            // the partition point of `qdq(x) <= g` over the sorted tail.
            let hi = if i + 1 == grid.len() {
                n
            } else {
                lo + self.xs[lo..].partition_point(|&x| qdq(x) <= g)
            };
            if hi > lo {
                let cnt = (hi - lo) as f64;
                let g = g as f64;
                let s1 = self.p1[hi] - self.p1[lo];
                let s2 = self.p2[hi] - self.p2[lo];
                let seg = s2 - 2.0 * g * s1 + g * g * cnt;
                if seg.is_nan() {
                    // belt-and-braces: finite samples and grids cannot get
                    // here, but never let max(0.0) hide a poisoned segment
                    return Some(f64::NAN);
                }
                // clamp: the closed form can round a hair below zero, and
                // monotone partial sums are what make abandonment exact
                acc += seg.max(0.0);
                if acc > abandon_above {
                    return None;
                }
            }
            lo = hi;
        }
        Some(acc)
    }

    /// Full (never-abandoned) MSE of `q` against the samples — the
    /// engine-side equivalent of `Quantizer::mse`.
    pub fn mse(&self, q: &Quantizer) -> f64 {
        let grid = quantizer_grid(q);
        let sse = self
            .sse_fn(|x| q.qdq(x), &grid, f64::INFINITY)
            .expect("abandon threshold is +inf");
        sse / self.xs.len().max(1) as f64
    }
}

/// Non-negative FP magnitudes k·2^(e−m)·a per binade, evaluated with the
/// scalar path's exact expression `rnd * step * a`.
fn fp_mag_grid(e_bits: i32, m_bits: i32, a: f32, out: &mut Vec<f32>, negate_too: bool) {
    let e_min = e_min_of(e_bits);
    let m = m_bits;
    for e in e_min..=0 {
        let step = exp2_int(e - m);
        let kmin = if e == e_min { 0i64 } else { 1i64 << m };
        let kmax = if e == 0 { (1i64 << (m + 1)) - 1 } else { 1i64 << (m + 1) };
        for k in kmin..=kmax {
            out.push((k as f32) * step * a);
            if negate_too && k > 0 {
                // exact: k·step is exact (integer times power of two) and
                // IEEE multiplication rounds symmetrically in sign
                out.push(-(k as f32) * step * a);
            }
        }
    }
}

/// The exact qdq output grid of `q`, ascending and deduplicated. Values
/// are computed with the same f32 expressions the scalar qdq applies, so
/// membership is bit-exact. Candidates are expected to have positive
/// maxval (the search spaces guarantee it).
pub fn quantizer_grid(q: &Quantizer) -> Vec<f32> {
    let mut g = Vec::new();
    match *q {
        Quantizer::SignedFp { fmt, maxval } => {
            let full = 2.0 - exp2_int(-fmt.m_bits);
            let a = maxval / full;
            fp_mag_grid(fmt.e_bits, fmt.m_bits, a, &mut g, true);
        }
        Quantizer::UnsignedFp { fmt, maxval, zp } => {
            let full = 2.0 - exp2_int(-fmt.m_bits);
            let a = maxval / full;
            fp_mag_grid(fmt.e_bits, fmt.m_bits, a, &mut g, false);
            for v in &mut g {
                *v += zp;
            }
        }
        Quantizer::IntSym { n_bits, maxval } => {
            let qmax_i = (1i64 << (n_bits - 1)) - 1;
            let s = maxval / qmax_i as f32;
            for qv in -qmax_i - 1..=qmax_i {
                g.push(qv as f32 * s);
            }
        }
        Quantizer::IntAsym { n_bits, lo, hi } => {
            let levels_i = (1i64 << n_bits) - 1;
            let mut s = (hi - lo) / levels_i as f32;
            if s <= 0.0 {
                s = 1.0;
            }
            let z = rnd(-lo / s);
            for qv in 0..=levels_i {
                g.push((qv as f32 - z) * s);
            }
        }
    }
    g.sort_unstable_by(f32::total_cmp);
    g.dedup();
    g
}

/// Score `cands` against the engine and return the argmin (lowest index on
/// ties — the scalar first-wins rule) with its MSE, or None on an empty
/// candidate set. `threads > 1` fans the candidates out over
/// `parallel_map`; the result is identical for any thread count.
pub fn search_min(
    eng: &GridEngine,
    cands: &[Quantizer],
    threads: usize,
) -> Option<SearchResult> {
    search_min_impl(eng, cands, None, threads)
}

/// [`search_min`] over candidates whose output grids were precomputed by
/// the caller (`grids[i]` belongs to `cands[i]`). This is how
/// `search_unsigned_on` shares one base magnitude grid across all zp
/// candidates of a (format, maxval) pair: the shifted grids stay ascending
/// (an f32 `+ zp` is monotone) and may contain adjacent duplicates, which
/// only produce empty segments — scores are bit-identical to regenerating
/// each grid with [`quantizer_grid`].
pub fn search_min_pregrids(
    eng: &GridEngine,
    cands: &[Quantizer],
    grids: &[Vec<f32>],
    threads: usize,
) -> Option<SearchResult> {
    assert_eq!(cands.len(), grids.len(), "one grid per candidate");
    search_min_impl(eng, cands, Some(grids), threads)
}

fn search_min_impl(
    eng: &GridEngine,
    cands: &[Quantizer],
    grids: Option<&[Vec<f32>]>,
    threads: usize,
) -> Option<SearchResult> {
    if cands.is_empty() {
        return None;
    }
    // best fully-scored SSE so far, shared across workers as f64 bits
    let best = AtomicU64::new(f64::INFINITY.to_bits());
    let sses = parallel_map(cands, threads.max(1), |i, q| {
        let owned;
        let grid: &[f32] = match grids {
            Some(gs) => &gs[i],
            None => {
                owned = quantizer_grid(q);
                &owned
            }
        };
        let abandon = f64::from_bits(best.load(Ordering::Relaxed));
        let sse = eng.sse_fn(|x| q.qdq(x), grid, abandon)?;
        let mut cur = best.load(Ordering::Relaxed);
        while sse < f64::from_bits(cur) {
            match best.compare_exchange_weak(
                cur,
                sse.to_bits(),
                Ordering::Relaxed,
                Ordering::Relaxed,
            ) {
                Ok(_) => break,
                Err(c) => cur = c,
            }
        }
        Some(sse)
    });
    let mut win: Option<(usize, f64)> = None;
    for (i, sse) in sses.into_iter().enumerate() {
        if let Some(sse) = sse {
            // NaN scores (poisoned samples) are never selectable, matching
            // the scalar argmin; all-NaN yields None
            let better = match win {
                Some((_, b)) => sse < b,
                None => true,
            };
            if !sse.is_nan() && better {
                win = Some((i, sse));
            }
        }
    }
    win.map(|(i, sse)| SearchResult {
        quantizer: cands[i],
        mse: sse / eng.len().max(1) as f64,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::format::{self, FpFormat};
    use crate::util::rng::Rng;

    fn sample_set(rng: &mut Rng, n: usize, scale: f32) -> Vec<f32> {
        let mut xs: Vec<f32> = (0..n).map(|_| rng.normal() * scale).collect();
        // clamp-boundary coverage: exact maxval hits and far outliers
        xs.push(scale);
        xs.push(-scale);
        xs.push(scale * 3.5);
        xs.push(-scale * 3.5);
        xs.push(0.0);
        xs
    }

    fn random_quantizer(rng: &mut Rng, kind: usize, maxval: f32) -> Quantizer {
        match kind {
            0 => Quantizer::SignedFp {
                fmt: FpFormat::new(rng.below(4) as i32, rng.below(4) as i32),
                maxval,
            },
            1 => Quantizer::UnsignedFp {
                fmt: FpFormat::new(rng.below(4) as i32, 1 + rng.below(3) as i32),
                maxval,
                zp: -rng.range(0.0, 0.3),
            },
            2 => Quantizer::IntSym { n_bits: 2 + rng.below(7) as i32, maxval },
            _ => Quantizer::IntAsym {
                n_bits: 2 + rng.below(7) as i32,
                lo: -rng.range(0.0, 1.0),
                hi: rng.range(0.1, 3.0),
            },
        }
    }

    #[test]
    fn grid_covers_qdq_image_all_kinds() {
        // every scalar qdq output must be bit-present in the grid
        let mut rng = Rng::new(41);
        for case in 0..200 {
            let maxval = rng.range(0.2, 4.0);
            let q = random_quantizer(&mut rng, case % 4, maxval);
            let grid = quantizer_grid(&q);
            assert!(!grid.is_empty());
            assert!(grid.windows(2).all(|w| w[0] < w[1]), "grid not sorted: {q:?}");
            for _ in 0..64 {
                let x = rng.normal() * maxval * 2.0;
                let v = q.qdq(x);
                assert!(
                    grid.iter().any(|&g| g == v),
                    "qdq({x}) = {v} not in grid of {q:?}"
                );
            }
        }
    }

    #[test]
    fn sse_matches_per_element_sum() {
        let mut rng = Rng::new(42);
        for case in 0..120 {
            let maxval = rng.range(0.2, 4.0);
            let q = random_quantizer(&mut rng, case % 4, maxval);
            let xs = sample_set(&mut rng, 300, maxval);
            let eng = GridEngine::new(&xs);
            let fast = eng.mse(&q);
            let oracle = q.mse(&xs);
            let power: f64 = xs.iter().map(|&x| (x as f64) * (x as f64)).sum::<f64>()
                / xs.len() as f64;
            assert!(
                (fast - oracle).abs() <= 1e-9 * oracle + 1e-12 * power + 1e-30,
                "case {case}: engine {fast} vs scalar {oracle} for {q:?}"
            );
        }
    }

    #[test]
    fn search_min_is_true_argmin_and_thread_invariant() {
        let mut rng = Rng::new(43);
        let xs = sample_set(&mut rng, 600, 1.5);
        let eng = GridEngine::new(&xs);
        let maxvals: Vec<f32> = (1..=20).map(|i| 1.5 * i as f32 / 20.0).collect();
        let mut cands = Vec::new();
        for fmt in format::act_signed_formats(4) {
            for &m in &maxvals {
                cands.push(Quantizer::SignedFp { fmt, maxval: m });
            }
        }
        let seq = search_min(&eng, &cands, 1).unwrap();
        // pruning never changes the winner: exhaustive rescoring agrees
        let mut best = (0usize, f64::INFINITY);
        for (i, q) in cands.iter().enumerate() {
            let mse = eng.mse(q);
            if mse < best.1 {
                best = (i, mse);
            }
        }
        assert_eq!(seq.quantizer, cands[best.0]);
        assert!((seq.mse - best.1).abs() <= 1e-15 * best.1.max(1e-18));
        // deterministic under candidate-level parallelism
        for threads in [2usize, 4, 8] {
            let par = search_min(&eng, &cands, threads).unwrap();
            assert_eq!(par.quantizer, seq.quantizer, "threads={threads}");
            assert_eq!(par.mse, seq.mse, "threads={threads}");
        }
    }

    #[test]
    fn empty_samples_and_empty_candidates() {
        let eng = GridEngine::new(&[]);
        assert!(eng.is_empty());
        let q = Quantizer::SignedFp { fmt: FpFormat::new(2, 1), maxval: 1.0 };
        assert_eq!(eng.mse(&q), 0.0);
        let r = search_min(&eng, &[q], 1).unwrap();
        assert_eq!(r.quantizer, q);
        assert_eq!(r.mse, 0.0);
        assert!(search_min(&eng, &[], 4).is_none());
    }

    #[test]
    fn poisoned_samples_match_scalar_semantics() {
        let q = Quantizer::SignedFp { fmt: FpFormat::new(2, 1), maxval: 1.0 };
        // NaN sample: scalar MSE is NaN -> unselectable -> search yields None
        let nan_xs = [0.1f32, f32::NAN, -0.4];
        let eng = GridEngine::new(&nan_xs);
        assert!(eng.mse(&q).is_nan());
        assert!(search_min(&eng, &[q], 1).is_none());
        assert!(q.mse(&nan_xs).is_nan());
        // inf sample: scalar MSE is +inf for every candidate and the first
        // candidate wins; the engine must do the same, not turn it to NaN
        let inf_xs = [0.1f32, f32::INFINITY, -0.4];
        let eng = GridEngine::new(&inf_xs);
        assert_eq!(eng.mse(&q), f64::INFINITY);
        let r = search_min(&eng, &[q], 1).unwrap();
        assert_eq!(r.quantizer, q);
        assert_eq!(r.mse, f64::INFINITY);
        assert_eq!(q.mse(&inf_xs), f64::INFINITY);
    }

    #[test]
    fn pregrids_match_per_candidate_generation() {
        // shared-base-grid scoring (search_min_pregrids) is bit-identical
        // to regenerating every candidate's grid inside search_min, even
        // when the pre-shifted grids carry adjacent duplicates
        let mut rng = Rng::new(46);
        let xs = sample_set(&mut rng, 500, 1.2);
        let eng = GridEngine::new(&xs);
        for threads in [1usize, 4] {
            let mut cands = Vec::new();
            let mut grids = Vec::new();
            for e in 0..3 {
                for m in 1..3 {
                    let fmt = FpFormat::new(e, m);
                    for i in 1..=8 {
                        let maxval = 1.2 * i as f32 / 8.0;
                        let base =
                            quantizer_grid(&Quantizer::UnsignedFp { fmt, maxval, zp: 0.0 });
                        for z in 0..4 {
                            let zp = -0.09 * z as f32;
                            cands.push(Quantizer::UnsignedFp { fmt, maxval, zp });
                            grids.push(base.iter().map(|&g| g + zp).collect());
                        }
                    }
                }
            }
            let pre = search_min_pregrids(&eng, &cands, &grids, threads).unwrap();
            let per_cand = search_min(&eng, &cands, threads).unwrap();
            assert_eq!(pre.quantizer, per_cand.quantizer, "threads={threads}");
            assert_eq!(pre.mse.to_bits(), per_cand.mse.to_bits(), "threads={threads}");
        }
    }

    #[test]
    fn abandon_threshold_prunes() {
        let mut rng = Rng::new(44);
        let xs = sample_set(&mut rng, 400, 2.0);
        let eng = GridEngine::new(&xs);
        let q = Quantizer::IntSym { n_bits: 4, maxval: 0.01 }; // terrible fit
        let grid = quantizer_grid(&q);
        let full = eng.sse_fn(|x| q.qdq(x), &grid, f64::INFINITY).unwrap();
        assert!(full > 0.0);
        assert!(eng.sse_fn(|x| q.qdq(x), &grid, full / 2.0).is_none());
        // threshold exactly at the full SSE must NOT abandon (strict >)
        assert_eq!(eng.sse_fn(|x| q.qdq(x), &grid, full), Some(full));
    }

    #[test]
    fn zp_shift_is_bit_exact() {
        // unsigned grids are the signed magnitudes + zp as an f32 add;
        // every unsigned qdq output must round-trip through the grid
        let mut rng = Rng::new(45);
        for _ in 0..100 {
            let fmt = FpFormat::new(rng.below(4) as i32, 1 + rng.below(3) as i32);
            let maxval = rng.range(0.3, 3.0);
            let zp = -rng.range(0.0, 0.3);
            let q = Quantizer::UnsignedFp { fmt, maxval, zp };
            let grid = quantizer_grid(&q);
            for _ in 0..32 {
                let x = rng.normal() * maxval;
                let v = q.qdq(x);
                assert!(grid.iter().any(|&g| g == v), "{v} missing for {q:?}");
            }
        }
    }
}
