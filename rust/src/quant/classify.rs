//! AAL / NAL classification (paper Observation 1).
//!
//! Layers fed by SiLU have Anomalous Activation Distributions: every
//! negative value is compressed into the trough [SILU_MIN, 0) ≈ [-0.278, 0),
//! while the positive tail is long. The classifier detects that signature
//! from calibration statistics alone (min/max + samples), so it works on
//! models whose architecture we cannot introspect.

use super::format::SILU_MIN;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LayerClass {
    /// Anomalous-Activation-Distribution Layer: SiLU-shaped asymmetric input.
    Aal,
    /// Normal-Activation-Distribution Layer: roughly symmetric input.
    Nal,
}

/// Classify from calibration stats. The SiLU signature:
///  * the minimum sits inside the trough (> SILU_MIN - slack, < 0), and
///  * the positive tail extends well beyond the trough depth.
pub fn classify(min: f32, max: f32) -> LayerClass {
    let trough = min > SILU_MIN - 0.05 && min < -1e-4;
    let asymmetric = max > 2.0 * min.abs();
    if trough && asymmetric {
        LayerClass::Aal
    } else {
        LayerClass::Nal
    }
}

/// Asymmetry diagnostic used by the Figure-1 report: ratio of positive to
/// negative mass range. ~1 for symmetric distributions, >> 1 for AALs.
pub fn asymmetry_ratio(min: f32, max: f32) -> f32 {
    if min >= 0.0 {
        f32::INFINITY
    } else {
        max / min.abs()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn silu(x: f32) -> f32 {
        x / (1.0 + (-x).exp())
    }

    #[test]
    fn silu_outputs_classified_aal() {
        let mut rng = Rng::new(1);
        let vals: Vec<f32> = (0..10_000).map(|_| silu(rng.normal() * 2.0)).collect();
        let min = vals.iter().cloned().fold(f32::INFINITY, f32::min);
        let max = vals.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        assert_eq!(classify(min, max), LayerClass::Aal, "min={min} max={max}");
    }

    #[test]
    fn gaussian_classified_nal() {
        let mut rng = Rng::new(2);
        let vals: Vec<f32> = (0..10_000).map(|_| rng.normal()).collect();
        let min = vals.iter().cloned().fold(f32::INFINITY, f32::min);
        let max = vals.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        assert_eq!(classify(min, max), LayerClass::Nal);
    }

    #[test]
    fn silu_min_constant_is_right() {
        // numeric minimum of x*sigmoid(x)
        let min = (0..40_000).map(|i| silu(-4.0 + i as f32 * 1e-4)).fold(f32::INFINITY, f32::min);
        assert!((min - SILU_MIN).abs() < 1e-3, "min={min}");
    }

    #[test]
    fn positive_only_is_nal() {
        // e.g. post-softmax attention outputs: min >= 0 -> not AAL by our
        // trough rule (nothing below zero to recover).
        assert_eq!(classify(0.0, 5.0), LayerClass::Nal);
    }

    #[test]
    fn symmetric_wide_negative_is_nal() {
        assert_eq!(classify(-3.0, 3.0), LayerClass::Nal);
    }

    #[test]
    fn asymmetry_diagnostic() {
        assert!(asymmetry_ratio(-0.27, 6.0) > 20.0);
        assert!((asymmetry_ratio(-3.0, 3.0) - 1.0).abs() < 1e-6);
    }
}
