//! Cross-module property tests (the in-repo prop harness; no artifacts
//! needed): quantizer grid laws, search optimality relations, schedule and
//! sampler identities, serialization fuzz.

use msfp::linalg::stats::{frechet, mean_cov};
use msfp::linalg::tensor::Mat;
use msfp::quant::classify::{classify, LayerClass};
use msfp::quant::fp::{e_min_of, exp2_int, fp_qdq_signed, fp_qdq_unsigned};
use msfp::quant::grid::{quantizer_grid, GridEngine};
use msfp::quant::int::{int_qdq_asym, int_qdq_sym};
use msfp::quant::msfp::{quantize_model, LayerCalib, Method, QuantOpts};
use msfp::quant::packed::{LoraTerm, PackedMat, PackedTensor};
use msfp::quant::search::{
    linspace, scalar, search_act_int, search_signed, search_unsigned, search_weight_int,
    Quantizer, SearchResult,
};
use msfp::quant::format::{
    act_signed_formats, act_unsigned_formats, weight_formats, weight_maxval_space, zp_space,
    FpFormat,
};
use msfp::quant::{QuantScheme, QuantSession};
use msfp::schedule::{timestep_subsequence, Schedule};
use msfp::util::io::Store;
use msfp::util::json::Json;
use msfp::util::prop::{check, vec_f32};
use msfp::util::rng::Rng;

#[test]
fn prop_signed_qdq_grid_membership() {
    // every output is a fixed point of the quantizer (grid membership)
    check(
        "signed-grid-member",
        300,
        |r| {
            let e = r.below(4) as i32;
            let m = 1 + r.below(4) as i32;
            let maxval = r.range(0.05, 20.0);
            (vec_f32(r, 64, maxval), maxval, e, m)
        },
        |(xs, maxval, e, m)| {
            xs.iter().all(|&x| {
                let q = fp_qdq_signed(x, *maxval, *e, *m);
                let q2 = fp_qdq_signed(q, *maxval, *e, *m);
                (q - q2).abs() <= 1e-6 * maxval.max(1.0)
            })
        },
    );
}

#[test]
fn prop_unsigned_qdq_monotone() {
    // fake quantization is monotone non-decreasing
    check(
        "unsigned-monotone",
        200,
        |r| {
            let e = r.below(4) as i32;
            let m = 1 + r.below(4) as i32;
            let maxval = r.range(0.1, 8.0);
            let zp = -r.range(0.0, 0.3);
            let mut xs = vec_f32(r, 64, maxval);
            xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
            (xs, maxval, e, m, zp)
        },
        |(xs, maxval, e, m, zp)| {
            xs.windows(2).all(|w| {
                fp_qdq_unsigned(w[0], *maxval, *e, *m, *zp)
                    <= fp_qdq_unsigned(w[1], *maxval, *e, *m, *zp) + 1e-7
            })
        },
    );
}

#[test]
fn prop_int_qdq_error_bounded() {
    // uniform INT error <= step/2 inside the representable range
    check(
        "int-error-bound",
        300,
        |r| {
            let n = 2 + r.below(7) as i32;
            let maxval = r.range(0.1, 10.0);
            let x = r.range(-maxval * 0.99, maxval * 0.99);
            (x, maxval, n)
        },
        |(x, maxval, n)| {
            let qmax = ((1i64 << (n - 1)) - 1) as f32;
            let step = maxval / qmax;
            (int_qdq_sym(*x, *maxval, *n) - x).abs() <= step / 2.0 + 1e-6
        },
    );
}

#[test]
fn prop_asym_int_covers_range_ends() {
    check(
        "asym-ends",
        200,
        |r| {
            let lo = -r.range(0.0, 2.0);
            let hi = r.range(0.1, 5.0);
            let n = 2 + r.below(7) as i32;
            (lo, hi, n)
        },
        |(lo, hi, n)| {
            let levels = ((1i64 << n) - 1) as f32;
            let step = (hi - lo) / levels;
            // endpoints are representable to within one step
            (int_qdq_asym(*lo, *lo, *hi, *n) - lo).abs() <= step + 1e-5
                && (int_qdq_asym(*hi, *lo, *hi, *n) - hi).abs() <= step + 1e-5
        },
    );
}

#[test]
fn prop_search_result_is_argmin_over_resample() {
    // the searched quantizer's MSE is never beaten by a random candidate
    // from the same space
    check(
        "search-argmin",
        40,
        |r| {
            let xs = vec_f32(r, 512, 2.0);
            let seed = r.next_u64();
            (xs, seed)
        },
        |(xs, seed)| {
            let maxval0 = xs.iter().fold(0.0f32, |a, &b| a.max(b.abs())).max(1e-6);
            let maxvals = linspace(maxval0 / 20.0, maxval0, 20);
            let best = search_signed(xs, &act_signed_formats(4), &maxvals)
                .expect("non-empty search space");
            let mut rng = Rng::new(*seed);
            for _ in 0..30 {
                let fmt = act_signed_formats(4)[rng.below(4)];
                let maxval = maxvals[rng.below(20)];
                let q = Quantizer::SignedFp { fmt, maxval };
                if q.mse(xs) < best.mse - 1e-12 {
                    return false;
                }
            }
            true
        },
    );
}

#[test]
fn prop_exp2_emin_consistency() {
    for e_bits in 0..32 {
        let emin = e_min_of(e_bits);
        assert!(emin >= -100);
        assert!(exp2_int(emin - 23) > 0.0); // step stays normal for m <= 23
    }
}

#[test]
fn prop_schedule_identities() {
    check(
        "schedule-ids",
        50,
        |r| 2 + r.below(500),
        |&t_total| {
            let s = Schedule::linear(t_total);
            // abar strictly decreasing in (0,1); gamma positive
            s.abar.windows(2).all(|w| w[1] < w[0] && w[1] > 0.0 && w[0] < 1.0)
                && (0..t_total).all(|t| s.gamma(t) > 0.0 && s.gamma(t).is_finite())
        },
    );
}

#[test]
fn prop_tau_subsequence_laws() {
    check(
        "tau-laws",
        200,
        |r| {
            let t_total = 2 + r.below(500);
            let steps = 1 + r.below(t_total);
            (t_total, steps)
        },
        |&(t_total, steps)| {
            let tau = timestep_subsequence(t_total, steps);
            !tau.is_empty()
                && *tau.last().unwrap() == 0
                && tau[0] < t_total
                && tau.windows(2).all(|w| w[0] > w[1])
        },
    );
}

#[test]
fn prop_store_roundtrip_fuzz() {
    check(
        "store-fuzz",
        40,
        |r| {
            let n_sections = 1 + r.below(6);
            (0..n_sections)
                .map(|i| (format!("s{i}_{}", r.below(1000)), vec_f32(r, 200, 100.0)))
                .collect::<Vec<_>>()
        },
        |sections| {
            let mut s = Store::new();
            for (k, v) in sections {
                s.put(k, v.clone());
            }
            let path = std::env::temp_dir().join(format!(
                "msfp_prop_store_{}.mts",
                std::process::id()
            ));
            s.save(&path).unwrap();
            let s2 = Store::load(&path).unwrap();
            sections.iter().all(|(k, v)| s2.get(k).unwrap() == v.as_slice())
        },
    );
}

#[test]
fn prop_json_number_roundtrip() {
    check(
        "json-numbers",
        300,
        |r| (r.normal() * 10f32.powi(r.below(8) as i32 - 4)) as f64,
        |&x| {
            let j = Json::Num(x);
            match Json::parse(&j.to_string()) {
                Ok(Json::Num(y)) => (x - y).abs() <= 1e-9 * x.abs().max(1.0),
                _ => false,
            }
        },
    );
}

// Grid-segment engine vs scalar oracle --------------------------------

fn silu(x: f32) -> f32 {
    x / (1.0 + (-x).exp())
}

/// Random "layer": SiLU-shaped (AAL) or gaussian (NAL) samples, with exact
/// clamp-boundary hits and out-of-range outliers appended so the top grid
/// point's clamping segment is always exercised.
fn random_layer(rng: &mut Rng, n: usize, aal: bool) -> Vec<f32> {
    let mut xs: Vec<f32> = (0..n)
        .map(|_| {
            let v = rng.normal() * 2.0;
            if aal {
                silu(v)
            } else {
                v
            }
        })
        .collect();
    let maxval0 = xs.iter().fold(0.0f32, |a, &b| a.max(b.abs())).max(1e-6);
    xs.push(maxval0);
    xs.push(-maxval0);
    xs.push(maxval0 * 2.5);
    xs.push(-maxval0 * 2.5);
    xs
}

fn assert_same_result(fast: &SearchResult, slow: &SearchResult, what: &str) {
    assert_eq!(
        fast.quantizer, slow.quantizer,
        "{what}: engine picked {:?} (mse {}), scalar picked {:?} (mse {})",
        fast.quantizer, fast.mse, slow.quantizer, slow.mse
    );
    assert!(
        (fast.mse - slow.mse).abs() <= 1e-9 * slow.mse.max(1e-18),
        "{what}: engine mse {} vs scalar mse {}",
        fast.mse,
        slow.mse
    );
}

#[test]
fn prop_grid_segment_mse_matches_scalar() {
    // per-candidate closed-form MSE == per-element MSE within 1e-9
    // relative, for all four quantizer kinds incl. zp-shifted unsigned
    check(
        "grid-mse-oracle",
        120,
        |r| {
            let maxval = r.range(0.2, 4.0);
            let mut xs = vec_f32(r, 400, maxval);
            xs.push(maxval);
            xs.push(-maxval);
            xs.push(maxval * 3.0);
            xs.push(-maxval * 3.0);
            let q = match r.below(4) {
                0 => Quantizer::SignedFp {
                    fmt: FpFormat::new(r.below(4) as i32, r.below(4) as i32),
                    maxval,
                },
                1 => Quantizer::UnsignedFp {
                    fmt: FpFormat::new(r.below(4) as i32, 1 + r.below(3) as i32),
                    maxval,
                    zp: -r.range(0.0, 0.3),
                },
                2 => Quantizer::IntSym { n_bits: 2 + r.below(7) as i32, maxval },
                _ => Quantizer::IntAsym {
                    n_bits: 2 + r.below(7) as i32,
                    lo: -r.range(0.0, 1.0),
                    hi: r.range(0.1, 3.0),
                },
            };
            (xs, q)
        },
        |(xs, q)| {
            let eng = GridEngine::new(xs);
            let fast = eng.mse(q);
            let oracle = q.mse(xs);
            let power: f64 =
                xs.iter().map(|&x| (x as f64) * (x as f64)).sum::<f64>() / xs.len() as f64;
            (fast - oracle).abs() <= 1e-9 * oracle + 1e-12 * power + 1e-30
        },
    );
}

#[test]
fn grid_engine_argmin_matches_scalar_all_kinds() {
    // the satellite contract: identical argmin quantizer across >= 20
    // random layers for all four search entry points
    for seed in 0..24u64 {
        let mut rng = Rng::new(4000 + seed);
        let n = 512 + (seed as usize % 3) * 256;
        let xs = random_layer(&mut rng, n, seed % 2 == 0);
        let maxval0 = xs.iter().fold(0.0f32, |a, &b| a.max(b.abs())).max(1e-6);
        let maxvals = linspace(maxval0 / 25.0, maxval0, 25);
        let zps = zp_space();

        let fast = search_signed(&xs, &act_signed_formats(4), &maxvals).unwrap();
        let slow = scalar::search_signed(&xs, &act_signed_formats(4), &maxvals).unwrap();
        assert_same_result(&fast, &slow, &format!("signed seed {seed}"));

        let fast = search_unsigned(&xs, &act_unsigned_formats(4), &maxvals, &zps).unwrap();
        let slow =
            scalar::search_unsigned(&xs, &act_unsigned_formats(4), &maxvals, &zps).unwrap();
        assert_same_result(&fast, &slow, &format!("unsigned+zp seed {seed}"));

        let fast = search_weight_int(&xs, 4, 25).unwrap();
        let slow = scalar::search_weight_int(&xs, 4, 25).unwrap();
        assert_same_result(&fast, &slow, &format!("int-sym seed {seed}"));

        let (mn, mx) = xs
            .iter()
            .fold((f32::INFINITY, f32::NEG_INFINITY), |(a, b), &x| (a.min(x), b.max(x)));
        let fast = search_act_int(&xs, 4, mn, mx, 12).unwrap();
        let slow = scalar::search_act_int(&xs, 4, mn, mx, 12).unwrap();
        assert_same_result(&fast, &slow, &format!("int-asym seed {seed}"));
    }
}

#[test]
fn prop_grid_covers_image_under_fuzz() {
    // the engine's correctness hinges on grid ⊇ qdq image; fuzz it across
    // formats, maxvals and zero points
    check(
        "grid-image-cover",
        200,
        |r| {
            let e = r.below(4) as i32;
            let m = r.below(4) as i32;
            let maxval = r.range(0.05, 8.0);
            let zp = -r.range(0.0, 0.3);
            let signed = r.below(2) == 0;
            let x = r.normal() * maxval * 2.0;
            (e, m, maxval, zp, signed, x)
        },
        |&(e, m, maxval, zp, signed, x)| {
            let q = if signed {
                Quantizer::SignedFp { fmt: FpFormat::new(e, m), maxval }
            } else {
                Quantizer::UnsignedFp { fmt: FpFormat::new(e, m.max(1)), maxval, zp }
            };
            let v = q.qdq(x);
            quantizer_grid(&q).iter().any(|&g| g == v)
        },
    );
}

// QuantSession vs cold quantize_model vs scalar oracle -----------------

/// Random model for session parity checks: SiLU-shaped (AAL) activations
/// on even layers, gaussian (NAL) on odd ones.
fn session_model(seed: u64, n_layers: usize) -> (Vec<Vec<f32>>, Vec<LayerCalib>) {
    let mut rng = Rng::new(seed);
    let mut weights = Vec::new();
    let mut calib = Vec::new();
    for l in 0..n_layers {
        weights.push((0..384).map(|_| rng.normal() * 0.1).collect());
        let aal = l % 2 == 0;
        let acts: Vec<f32> = (0..768)
            .map(|_| {
                let v = rng.normal() * 2.0;
                if aal {
                    silu(v)
                } else {
                    v
                }
            })
            .collect();
        let min = acts.iter().cloned().fold(f32::INFINITY, f32::min);
        let max = acts.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        calib.push(LayerCalib { name: format!("l{l}"), acts, min, max, aal_hint: aal });
    }
    (weights, calib)
}

fn assert_schemes_bit_identical(a: &QuantScheme, b: &QuantScheme, what: &str) {
    assert_eq!(a.layers.len(), b.layers.len(), "{what}: layer count");
    for (x, y) in a.layers.iter().zip(&b.layers) {
        assert_eq!(x.weight, y.weight, "{what}: weight of {}", x.name);
        assert_eq!(x.act, y.act, "{what}: act of {}", x.name);
        assert_eq!(x.w_mse.to_bits(), y.w_mse.to_bits(), "{what}: w_mse of {}", x.name);
        assert_eq!(x.a_mse.to_bits(), y.a_mse.to_bits(), "{what}: a_mse of {}", x.name);
        assert_eq!(x.class, y.class, "{what}: class of {}", x.name);
    }
}

#[test]
fn session_reuse_matches_cold_quantize_model_all_methods() {
    // the satellite contract: a reused session is bit-identical to a cold
    // quantize_model across methods, random bit-widths, weight_space
    // overrides, and repeated (memoized) calls
    let n_layers = 5;
    let (weights, calib) = session_model(6001, n_layers);
    let session = QuantSession::new(&weights, &calib);
    let methods = [Method::Msfp, Method::SignedFp, Method::IntMinMax, Method::IntMse];
    let spaces = [None, Some((0.0001f32, 1.0f32)), Some((0.6, 2.0)), Some((1.0, 2.0))];
    let mut rng = Rng::new(6002);
    for round in 0..12 {
        let method = methods[round % methods.len()];
        let mut opts = QuantOpts::new(
            method,
            n_layers,
            3 + rng.below(6) as i32, // 3..=8
            3 + rng.below(6) as i32,
        );
        opts.weight_space = spaces[rng.below(spaces.len())];
        opts.maxval_points = 10 + rng.below(3) * 5;
        // per-layer IO-style overrides
        opts.wbits[rng.below(n_layers)] = 8;
        opts.abits[rng.below(n_layers)] = 8;
        let what = format!("round {round} {method:?}");
        let cold = quantize_model(&weights, &calib, &opts);
        let warm = session.quantize(&opts);
        assert_schemes_bit_identical(&cold, &warm, &what);
        let replay = session.quantize(&opts); // memo hit must replay exactly
        assert_schemes_bit_identical(&warm, &replay, &format!("{what} (memo)"));
    }
}

#[test]
fn session_msfp_matches_scalar_oracle() {
    // session results stay within the 1e-9 relative bound of the scalar
    // per-element oracle, including the shifted-zp unsigned grid path on
    // AAL layers (mixup stage 2)
    let (weights, calib) = session_model(6101, 6);
    let session = QuantSession::new(&weights, &calib);
    let mut opts = QuantOpts::new(Method::Msfp, 6, 4, 4);
    opts.weight_space = Some((0.7, 2.0));
    let scheme = session.quantize(&opts);
    let mut saw_unsigned = false;
    for (l, (c, lq)) in calib.iter().zip(&scheme.layers).enumerate() {
        let mixup = classify(c.min, c.max) == LayerClass::Aal;
        let maxval0 = c.acts.iter().fold(0.0f32, |a, &b| a.max(b.abs())).max(1e-8);
        let slow_a =
            scalar::search_act_msfp(&c.acts, 4, maxval0, mixup, opts.maxval_points.max(50));
        assert_eq!(lq.act, slow_a.quantizer, "act argmin, layer {l}");
        assert!(
            (lq.a_mse - slow_a.mse).abs() <= 1e-9 * slow_a.mse.max(1e-18),
            "act mse, layer {l}: {} vs {}",
            lq.a_mse,
            slow_a.mse
        );
        saw_unsigned |= matches!(lq.act, Quantizer::UnsignedFp { .. });

        let w0 = weights[l].iter().fold(0.0f32, |a, &b| a.max(b.abs())).max(1e-8);
        let maxvals = linspace(0.7 * w0, 2.0 * w0, opts.maxval_points);
        let slow_w = scalar::search_signed(&weights[l], &weight_formats(4), &maxvals).unwrap();
        assert_eq!(lq.weight, slow_w.quantizer, "weight argmin, layer {l}");
        assert!(
            (lq.w_mse - slow_w.mse).abs() <= 1e-9 * slow_w.mse.max(1e-18),
            "weight mse, layer {l}: {} vs {}",
            lq.w_mse,
            slow_w.mse
        );
    }
    assert!(saw_unsigned, "no AAL picked the unsigned+zp grid — mixup path not exercised");
}

#[test]
fn session_default_weight_space_matches_scalar_oracle() {
    // weight_space = None resolves to the Table-6 per-bit-width interval
    let (weights, calib) = session_model(6201, 2);
    let session = QuantSession::new(&weights, &calib);
    for bits in [4, 6, 8] {
        let opts = QuantOpts::new(Method::Msfp, 2, bits, bits);
        let scheme = session.quantize(&opts);
        let (lo, hi) = weight_maxval_space(bits);
        for (l, lq) in scheme.layers.iter().enumerate() {
            let w0 = weights[l].iter().fold(0.0f32, |a, &b| a.max(b.abs())).max(1e-8);
            let maxvals = linspace(lo * w0, hi * w0, opts.maxval_points);
            let slow =
                scalar::search_signed(&weights[l], &weight_formats(bits), &maxvals).unwrap();
            assert_eq!(lq.weight, slow.quantizer, "bits {bits}, layer {l}");
            assert!(
                (lq.w_mse - slow.mse).abs() <= 1e-9 * slow.mse.max(1e-18),
                "bits {bits}, layer {l}: {} vs {}",
                lq.w_mse,
                slow.mse
            );
        }
    }
}

#[test]
fn session_incremental_recalibration_matches_cold_rebuild() {
    // the online-recalibration contract: a session that absorbs per-layer
    // calib updates via update_layer_calib stays bit-identical to a cold
    // session built from the updated calibration, across methods and a
    // sequence of updates (each update invalidates only its own layer)
    let n_layers = 6;
    let (weights, calib) = session_model(7001, n_layers);
    let methods = [Method::Msfp, Method::SignedFp, Method::IntMinMax, Method::IntMse];
    let mut rng = Rng::new(7002);
    for (round, &method) in methods.iter().enumerate() {
        let opts = QuantOpts::new(method, n_layers, 4, 4);
        let mut session = QuantSession::new(&weights, &calib);
        let mut current = calib.clone();
        let _ = session.quantize(&opts); // warm every memo before updating
        for step in 0..3 {
            // shift a layer hard enough to move argmins (and classes: a
            // positive offset fills the silu trough on AAL layers)
            let l = rng.below(n_layers);
            let shift = 0.5 + rng.range(0.0, 1.0);
            let scale = 1.0 + rng.range(0.0, 2.0);
            let acts: Vec<f32> =
                current[l].acts.iter().map(|v| v * scale + shift).collect();
            let updated =
                LayerCalib::from_samples(current[l].name.clone(), acts, current[l].aal_hint);
            current[l] = updated.clone();
            session.update_layer_calib(l, updated);
            let warm = session.quantize(&opts);
            let cold = QuantSession::new(&weights, &current).quantize(&opts);
            assert_schemes_bit_identical(
                &warm,
                &cold,
                &format!("method round {round} update {step} (layer {l})"),
            );
        }
    }
}

#[test]
fn recal_planner_plus_session_roundtrip_is_stable() {
    // feeding a session's own calibration back through the sketch->drift->
    // plan pipeline must plan nothing (no false-positive recalibration),
    // while a genuinely shifted stream must plan that layer and the applied
    // update must match a cold rebuild
    use msfp::recal::{RecalPlanner, SketchSet};
    let n_layers = 4;
    let (weights, calib) = session_model(7101, n_layers);
    let mut sketches = SketchSet::new(n_layers, 4, 512, 100, 3);
    let mut rng = Rng::new(7102);
    // replay the baseline itself into the sketches
    for (l, c) in calib.iter().enumerate() {
        for chunk in c.acts.chunks(64) {
            sketches.observe(l, rng.range(0.0, 100.0), chunk);
        }
        let merged = sketches.layer_merged(l);
        assert!(merged.count() >= c.acts.len());
    }
    let planner = RecalPlanner::default();
    let plan = planner.plan(&calib, &sketches);
    assert!(plan.is_empty(), "baseline replay must not drift: {:?}", plan.scores);

    // now shift layer 1's live stream and re-plan
    for _ in 0..40 {
        let vals: Vec<f32> = (0..64).map(|_| rng.normal() * 2.0 + 1.5).collect();
        sketches.observe(1, rng.range(0.0, 100.0), &vals);
    }
    let plan = planner.plan(&calib, &sketches);
    assert_eq!(plan.layers.len(), 1, "scores: {:?}", plan.scores);
    assert_eq!(plan.layers[0].layer, 1);

    let opts = QuantOpts::new(Method::Msfp, n_layers, 4, 4);
    let mut session = QuantSession::new(&weights, &calib);
    let _ = session.quantize(&opts);
    session.update_layer_calib(1, plan.layers[0].calib.clone());
    let warm = session.quantize(&opts);
    let mut c2 = calib.clone();
    c2[1] = plan.layers[0].calib.clone();
    let cold = QuantSession::new(&weights, &c2).quantize(&opts);
    assert_schemes_bit_identical(&warm, &cold, "planned update vs cold");
}

// Sketch persistence + merge laws ------------------------------------

/// Random LayerSketch: `n` pushes (possibly past the reservoir cap, so the
/// rng cursor advances) plus an optional widen-only extrema extension.
fn random_sketch(rng: &mut Rng, seed: u64) -> msfp::recal::LayerSketch {
    let cap = 4 + rng.below(48);
    let n = rng.below(4 * cap);
    let mut sk = msfp::recal::LayerSketch::new(cap, seed);
    for _ in 0..n {
        sk.push(rng.normal() * rng.range(0.1, 4.0));
    }
    if rng.below(3) == 0 {
        let w = rng.range(0.5, 20.0);
        sk.widen(-w, w);
    }
    sk
}

/// Random SketchSet fed across layers/buckets, sometimes leaving
/// widen-only buckets and sometimes overflowing reservoirs.
fn random_sketch_set(rng: &mut Rng) -> msfp::recal::SketchSet {
    let n_layers = 1 + rng.below(4);
    let n_buckets = 1 + rng.below(4);
    let cap = 4 + rng.below(24);
    let mut set = msfp::recal::SketchSet::new(n_layers, n_buckets, cap, 100, rng.next_u64());
    for _ in 0..rng.below(60) {
        let l = rng.below(n_layers);
        let t = rng.range(0.0, 100.0);
        match rng.below(8) {
            0 => set.widen_layer(l, t, -rng.range(0.0, 9.0), rng.range(0.0, 9.0)),
            _ => {
                let vals: Vec<f32> = (0..1 + rng.below(3 * cap))
                    .map(|_| rng.normal() * 2.0)
                    .collect();
                set.observe(l, t, &vals);
            }
        }
    }
    set
}

#[test]
fn prop_sketch_set_roundtrip_bit_exact_and_rng_cursor_survives() {
    // the persistence contract: serialize -> load is bit-exact (including
    // widen-only buckets and half-advanced reservoir rng cursors), and the
    // loaded set CONTINUES bit-identically — further observes make the
    // same reservoir replacement decisions as the never-saved original
    check(
        "sketch-roundtrip",
        60,
        |r: &mut Rng| r.next_u64(),
        |&seed| {
            let mut rng = Rng::new(seed);
            let set = random_sketch_set(&mut rng);
            let bytes = set.to_bytes();
            let Ok(loaded) = msfp::recal::SketchSet::from_bytes(&bytes) else {
                return false;
            };
            if loaded != set || loaded.to_bytes() != bytes {
                return false;
            }
            let mut a = set;
            let mut b = loaded;
            for _ in 0..40 {
                let l = rng.below(a.n_layers());
                let t = rng.range(0.0, 100.0);
                let vals: Vec<f32> = (0..1 + rng.below(20)).map(|_| rng.normal()).collect();
                a.observe(l, t, &vals);
                b.observe(l, t, &vals);
            }
            a.to_bytes() == b.to_bytes()
        },
    );
}

#[test]
fn prop_sketch_merge_stats_commutative_and_associative() {
    // merge's exact half (counts, extrema, moments) obeys the algebra;
    // the reservoir half is policy (seed-dependent re-draws), so it is
    // deliberately excluded here and covered by the roundtrip law below
    check(
        "sketch-merge-algebra",
        80,
        |r: &mut Rng| r.next_u64(),
        |&seed| {
            let mut rng = Rng::new(seed);
            let a = random_sketch(&mut rng, seed ^ 1);
            let b = random_sketch(&mut rng, seed ^ 2);
            let c = random_sketch(&mut rng, seed ^ 3);
            let stats = |s: &msfp::recal::LayerSketch| {
                (s.count(), s.min.to_bits(), s.max.to_bits(), s.mean(), s.var())
            };
            let mut ab = a.clone();
            ab.merge(&b);
            let mut ba = b.clone();
            ba.merge(&a);
            let (ca, cb) = (stats(&ab), stats(&ba));
            // mean/var combine from f64 sums — commutativity is exact up
            // to the one addition reorder
            let comm = ca.0 == cb.0
                && ca.1 == cb.1
                && ca.2 == cb.2
                && (ca.3 - cb.3).abs() <= 1e-12 * ca.3.abs().max(1.0)
                && (ca.4 - cb.4).abs() <= 1e-9 * ca.4.abs().max(1.0);
            let mut ab_c = ab.clone();
            ab_c.merge(&c);
            let mut bc = b.clone();
            bc.merge(&c);
            let mut a_bc = a.clone();
            a_bc.merge(&bc);
            let (l, r2) = (stats(&ab_c), stats(&a_bc));
            let assoc = l.0 == r2.0
                && l.1 == r2.1
                && l.2 == r2.2
                && (l.3 - r2.3).abs() <= 1e-12 * l.3.abs().max(1.0)
                && (l.4 - r2.4).abs() <= 1e-9 * l.4.abs().max(1.0);
            comm && assoc
        },
    );
}

#[test]
fn prop_sketch_loaded_then_merged_equals_merged_then_loaded() {
    // the law that ties persistence to the merge policy: because load is a
    // bit-exact identity (reservoir + rng cursor), merging into a loaded
    // sketch draws the same reservoir as merging into the original — so
    // load(save(a)) ∘ merge(b) == load(save(a ∘ merge(b))) bit-for-bit
    check(
        "sketch-load-merge-commute",
        60,
        |r: &mut Rng| r.next_u64(),
        |&seed| {
            let mut rng = Rng::new(seed);
            let a = random_sketch_set(&mut rng);
            let mut b = random_sketch_set(&mut rng);
            // merge wants matching layouts: rebuild b on a's layout
            if b.n_layers() != a.n_layers() || b.n_buckets() != a.n_buckets() {
                b = msfp::recal::SketchSet::new(
                    a.n_layers(),
                    a.n_buckets(),
                    8,
                    100,
                    seed ^ 0xB,
                );
                for _ in 0..30 {
                    let l = rng.below(a.n_layers());
                    b.observe(l, rng.range(0.0, 100.0), &[rng.normal()]);
                }
            }
            let mut loaded_then_merged =
                msfp::recal::SketchSet::from_bytes(&a.to_bytes()).unwrap();
            loaded_then_merged.merge(&b).unwrap();
            let mut a = a;
            a.merge(&b).unwrap();
            let merged_then_loaded =
                msfp::recal::SketchSet::from_bytes(&a.to_bytes()).unwrap();
            loaded_then_merged == merged_then_loaded
                && loaded_then_merged.to_bytes() == merged_then_loaded.to_bytes()
        },
    );
}

// Fleet-merge laws ----------------------------------------------------

#[test]
fn prop_fleet_canonical_merge_is_partition_invariant() {
    // the fleet aggregator's headline law: feed one deterministic traffic
    // tape either unsharded, or partitioned across 2 or 4 shards by the
    // fleet router, and `merge_canonical` rebuilds the SAME window —
    // byte-identical between the 2-way and 4-way partitions, and exact
    // (count / extrema, moments to fp-reorder tolerance) against the
    // unsharded feed
    use msfp::coordinator::route;
    use msfp::recal::SketchSet;
    check(
        "fleet-partition-invariant",
        40,
        |r: &mut Rng| r.next_u64(),
        |&seed| {
            let mut rng = Rng::new(seed);
            let n_layers = 1 + rng.below(3);
            let n_buckets = 1 + rng.below(3);
            // large enough that every shard reservoir stays lossless
            // (≤ 50 observations × ≤ 8 samples) — the regime the
            // invariance contract covers
            let cap = 512;
            let salt = rng.next_u64();
            // one traffic tape: (producer id, layer, t, samples | widen)
            let mut tape: Vec<(u64, usize, f32, Result<Vec<f32>, (f32, f32)>)> = Vec::new();
            for id in 0..(10 + rng.below(40)) as u64 {
                let l = rng.below(n_layers);
                let t = rng.range(0.0, 100.0);
                if rng.below(6) == 0 {
                    let w = rng.range(0.1, 8.0);
                    tape.push((id, l, t, Err((-w, w))));
                } else {
                    let vals: Vec<f32> =
                        (0..1 + rng.below(8)).map(|_| rng.normal()).collect();
                    tape.push((id, l, t, Ok(vals)));
                }
            }
            let feed = |set: &mut SketchSet, slice: Option<(usize, usize)>| {
                for (id, l, t, ev) in &tape {
                    if let Some((shard, n)) = slice {
                        if route(*id, salt, n) != shard {
                            continue;
                        }
                    }
                    match ev {
                        Ok(vals) => set.observe(*l, *t, vals),
                        Err((lo, hi)) => set.widen_layer(*l, *t, *lo, *hi),
                    }
                }
            };
            let mut full = SketchSet::new(n_layers, n_buckets, cap, 100, 7);
            feed(&mut full, None);
            let merged_for = |n: usize| {
                let mut shards: Vec<SketchSet> = (0..n)
                    .map(|s| SketchSet::new(n_layers, n_buckets, cap, 100, 0x5EED ^ s as u64))
                    .collect();
                for (s, set) in shards.iter_mut().enumerate() {
                    feed(set, Some((s, n)));
                }
                let refs: Vec<&SketchSet> = shards.iter().collect();
                SketchSet::merge_canonical(&refs).unwrap()
            };
            let m2 = merged_for(2);
            let m4 = merged_for(4);
            if m2.lossy_positions != 0 || m4.lossy_positions != 0 {
                return false;
            }
            if m2.window.to_bytes() != m4.window.to_bytes() {
                return false;
            }
            (0..n_layers).all(|l| {
                (0..n_buckets).all(|b| {
                    let f = full.sketch(l, b);
                    let m = m2.window.sketch(l, b);
                    f.count() == m.count()
                        && f.min.to_bits() == m.min.to_bits()
                        && f.max.to_bits() == m.max.to_bits()
                        && (f.mean() - m.mean()).abs() <= 1e-9 * f.mean().abs().max(1.0)
                        && (f.var() - m.var()).abs() <= 1e-6 * f.var().abs().max(1.0)
                })
            })
        },
    );
}

/// Random per-shard [`msfp::coordinator::Metrics`]: a plausible spread of
/// sample series, counters and swap audits. Backend is fixed by the
/// caller — the merge keeps the first non-empty backend, so the algebra
/// holds over a homogeneous fleet (which is what `Fleet::spawn` builds).
fn random_metrics(rng: &mut Rng, backend: &'static str) -> msfp::coordinator::Metrics {
    use std::time::Duration;
    let mut m = msfp::coordinator::Metrics {
        backend,
        images_done: rng.below(50),
        evals: rng.below(900),
        rounds: rng.below(40),
        wall: Duration::from_micros(rng.next_u64() % 1_000_000),
        round_exec: Duration::from_micros(rng.next_u64() % 500_000),
        round_sched: Duration::from_micros(rng.next_u64() % 100_000),
        sel_hits: rng.next_u64() % 100,
        sel_misses: rng.next_u64() % 100,
        recal_checks: rng.below(5),
        recal_swaps: rng.below(3),
        recal_layers: rng.below(6),
        first_swap_round: if rng.below(2) == 0 { Some(rng.below(30)) } else { None },
        shed: [rng.below(4), rng.below(4), rng.below(4)],
        rung_rounds: (0..rng.below(4)).map(|_| rng.below(20)).collect(),
        packed_bytes: rng.below(1 << 16),
        swap_audits: (0..rng.below(3))
            .map(|_| msfp::obs::SwapAudit {
                round: rng.below(40) as u64,
                check: rng.below(5) as u64,
                old_fp: rng.next_u64(),
                new_fp: rng.next_u64(),
                drifted: vec![(rng.below(6) as u32, rng.normal())],
                rungs: vec![(4, 4, rng.below(2) == 0)],
            })
            .collect(),
        ..msfp::coordinator::Metrics::default()
    };
    for _ in 0..rng.below(20) {
        m.latencies.push(Duration::from_micros(rng.next_u64() % 50_000));
    }
    for _ in 0..rng.below(12) {
        m.batch_sizes.push(1 + rng.below(8));
        m.batch_fills.push(rng.range(0.0, 1.0));
    }
    for q in &mut m.queue_waits {
        let n = rng.next_u64() % 10;
        for _ in 0..n {
            q.push(rng.next_u64() % 10_000);
        }
    }
    m
}

/// Full-strength Metrics equality: the raw sample series and audit trail
/// bit-for-bit, plus the derived [`msfp::obs::MetricsSnapshot`] (which
/// covers every counter, the percentiles and the throughput math).
fn metrics_eq(a: &msfp::coordinator::Metrics, b: &msfp::coordinator::Metrics) -> bool {
    a.latencies == b.latencies
        && a.batch_sizes == b.batch_sizes
        && a.batch_fills == b.batch_fills
        && a.queue_waits == b.queue_waits
        && a.rung_rounds == b.rung_rounds
        && a.swap_audits == b.swap_audits
        && a.snapshot() == b.snapshot()
}

#[test]
fn prop_fleet_metrics_merge_commutative_and_associative() {
    // the fleet report is a fold of per-shard Metrics; the fold must not
    // care which shard harvests first or how shards are grouped — merge
    // canonicalizes every series (sorted-multiset form), so the law is
    // bitwise, not approximate
    check(
        "fleet-metrics-merge-algebra",
        60,
        |r: &mut Rng| r.next_u64(),
        |&seed| {
            let mut rng = Rng::new(seed);
            let a = random_metrics(&mut rng, "graph");
            let b = random_metrics(&mut rng, "graph");
            let c = random_metrics(&mut rng, "graph");
            let mut ab = a.clone();
            ab.merge(&b);
            let mut ba = b.clone();
            ba.merge(&a);
            let mut ab_c = ab.clone();
            ab_c.merge(&c);
            let mut bc = b.clone();
            bc.merge(&c);
            let mut a_bc = a.clone();
            a_bc.merge(&bc);
            metrics_eq(&ab, &ba) && metrics_eq(&ab_c, &a_bc)
        },
    );
}

// Packed sub-byte storage vs fake-qdq oracle --------------------------

/// Edge inputs for a quantizer with FP format (e, m) and scale-defining
/// maxval: zeros (both signs), the clamp boundary, outliers past it, and
/// every binade boundary of the grid down to the subnormal binade at
/// `e_min_of(e)` — plus half-step offsets that force rounding decisions.
fn fp_edge_values(e: i32, m: i32, maxval: f32) -> Vec<f32> {
    let full = 2.0 - exp2_int(-m);
    let a = maxval / full;
    let mut xs = vec![0.0, -0.0, maxval, -maxval, maxval * 3.0, -maxval * 3.0];
    for eb in e_min_of(e)..=0 {
        let step = exp2_int(eb - m);
        let binade = exp2_int(eb) * a;
        xs.extend([binade, -binade, binade + 0.5 * step * a, binade - 0.25 * step * a]);
    }
    xs
}

#[test]
fn prop_packed_roundtrip_bit_exact_exhaustive_formats_and_edges() {
    // every ExMy format x signed/unsigned(+zp) on edge values: the packed
    // code table must reproduce the scalar fake-qdq output bit-for-bit
    for e in 0..=3 {
        for m in 0..=3 {
            for &maxval in &[0.35f32, 1.0, 6.0] {
                let q = Quantizer::SignedFp { fmt: FpFormat::new(e, m), maxval };
                let xs = fp_edge_values(e, m, maxval);
                let got = PackedTensor::pack(&xs, &q).unwrap().dequantize();
                for (x, g) in xs.iter().zip(&got) {
                    let want = q.qdq(*x);
                    assert_eq!(
                        g.to_bits(),
                        want.to_bits(),
                        "signed E{e}M{m} maxval {maxval}: x={x} got {g} want {want}"
                    );
                }
                if m == 0 {
                    continue; // unsigned formats need m >= 1
                }
                for &zp in &[0.0f32, -0.18, -0.3] {
                    let q = Quantizer::UnsignedFp { fmt: FpFormat::new(e, m), maxval, zp };
                    let xs: Vec<f32> =
                        fp_edge_values(e, m, maxval).iter().map(|v| v + zp).collect();
                    let got = PackedTensor::pack(&xs, &q).unwrap().dequantize();
                    for (x, g) in xs.iter().zip(&got) {
                        let want = q.qdq(*x);
                        assert_eq!(
                            g.to_bits(),
                            want.to_bits(),
                            "unsigned E{e}M{m} maxval {maxval} zp {zp}: x={x}"
                        );
                    }
                }
            }
        }
    }
}

#[test]
fn prop_packed_roundtrip_bit_exact_random_all_kinds() {
    // randomized inputs across all four quantizer kinds (the four Methods'
    // building blocks): pack -> dequantize == qdq, bit-for-bit
    check(
        "packed-roundtrip",
        200,
        |r| {
            let maxval = r.range(0.1, 6.0);
            let q = match r.below(4) {
                0 => Quantizer::SignedFp {
                    fmt: FpFormat::new(r.below(4) as i32, r.below(4) as i32),
                    maxval,
                },
                1 => Quantizer::UnsignedFp {
                    fmt: FpFormat::new(r.below(4) as i32, 1 + r.below(3) as i32),
                    maxval,
                    zp: -r.range(0.0, 0.3),
                },
                2 => Quantizer::IntSym { n_bits: 2 + r.below(7) as i32, maxval },
                _ => Quantizer::IntAsym {
                    n_bits: 2 + r.below(7) as i32,
                    lo: -r.range(0.0, 1.0),
                    hi: r.range(0.1, 3.0),
                },
            };
            let mut xs = vec_f32(r, 128, maxval);
            xs.extend([0.0, -0.0, maxval, -maxval, maxval * 2.5]);
            (xs, q)
        },
        |(xs, q)| {
            let got = PackedTensor::pack(xs, q).unwrap().dequantize();
            xs.iter().zip(&got).all(|(x, g)| g.to_bits() == q.qdq(*x).to_bits())
        },
    );
}

#[test]
fn prop_fused_matmul_bitwise_matches_scalar_reference() {
    // randomized shapes, worker counts, and optional LoRA/bias: the fused
    // dequantize-matmul kernel is bit-identical to the dequantize-then-
    // matmul scalar reference (the fixed-accumulation-order contract)
    check(
        "fused-bitwise",
        30,
        |r| {
            let rows = 1 + r.below(48);
            let cols = 1 + r.below(96);
            let b_cols = 1 + r.below(6);
            let rank = 1 + r.below(4);
            let with_lora = r.below(4) != 0;
            let with_bias = r.below(4) != 0;
            let workers = [1, 2, 3, 5, 8][r.below(5)];
            let fmts = weight_formats(4);
            let q = Quantizer::SignedFp { fmt: fmts[r.below(fmts.len())], maxval: 0.6 };
            let w: Vec<f32> = (0..rows * cols).map(|_| r.normal() * 0.2).collect();
            let x: Vec<f32> = (0..cols * b_cols).map(|_| r.normal()).collect();
            let a: Vec<f32> = (0..rank * cols).map(|_| r.normal() * 0.05).collect();
            let b: Vec<f32> = (0..rows * rank).map(|_| r.normal() * 0.05).collect();
            let bias: Vec<f32> = (0..rows).map(|_| r.normal()).collect();
            ((rows, cols, b_cols, rank), (with_lora, with_bias, workers), q, (w, x, a, b, bias))
        },
        |((rows, cols, b_cols, rank), (with_lora, with_bias, workers), q, (w, x, a, b, bias))| {
            let m = PackedMat::pack(w, *rows, *cols, q).unwrap();
            let lora = LoraTerm { a, b, rank: *rank, scale: 1.0 / *rank as f32 };
            let lora = with_lora.then_some(&lora);
            let bias = with_bias.then_some(bias.as_slice());
            let (mut want, mut got) = (Vec::new(), Vec::new());
            m.fused_matmul_ref(x, *b_cols, lora, bias, &mut want);
            m.fused_matmul_into(x, *b_cols, lora, bias, *workers, &mut got);
            want.len() == got.len()
                && want.iter().zip(&got).all(|(a, b)| a.to_bits() == b.to_bits())
        },
    );
}

#[test]
fn prop_frechet_is_metric_like() {
    // symmetry + identity + sensitivity on random gaussian clouds
    check(
        "frechet-metric",
        10,
        |r| r.next_u64(),
        |&seed| {
            let mut rng = Rng::new(seed);
            let a = Mat::from_vec(300, 5, (0..1500).map(|_| rng.normal()).collect()).unwrap();
            let b =
                Mat::from_vec(300, 5, (0..1500).map(|_| rng.normal() + 0.5).collect()).unwrap();
            let (m1, c1) = mean_cov(&a).unwrap();
            let (m2, c2) = mean_cov(&b).unwrap();
            let dab = frechet(&m1, &c1, &m2, &c2).unwrap();
            let dba = frechet(&m2, &c2, &m1, &c1).unwrap();
            let daa = frechet(&m1, &c1, &m1, &c1).unwrap();
            (dab - dba).abs() < 0.05 * dab.max(0.1) && daa < 0.05 && dab > daa
        },
    );
}
