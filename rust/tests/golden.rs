//! Golden cross-checks: the Rust quantizer mirror and router must agree
//! with the Python reference that generated the serving artifacts.
//! Goldens are emitted by `make artifacts` (aot.py).

use std::path::PathBuf;

use msfp::lora::Router;
use msfp::quant::fp::{fp_qdq_signed, fp_qdq_unsigned};
use msfp::quant::int::{int_qdq_asym, int_qdq_sym};
use msfp::quant::msfp::LayerCalib;
use msfp::recal::{drift_score, LayerSketch};
use msfp::util::json::Json;

fn golden_dir() -> Option<PathBuf> {
    let d = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts").join("golden");
    d.exists().then_some(d)
}

fn mixup_rust(x: f32, sign: f32, maxval: f32, e: f32, m: f32, zp: f32) -> f32 {
    if e >= 0.0 {
        if sign >= 0.5 {
            fp_qdq_signed(x, maxval, e as i32, m as i32)
        } else {
            fp_qdq_unsigned(x, maxval, e as i32, m as i32, zp)
        }
    } else if sign >= 0.5 {
        int_qdq_sym(x, maxval, m as i32)
    } else {
        int_qdq_asym(x, zp, maxval, m as i32)
    }
}

fn weight_rust(x: f32, maxval: f32, e: f32, m: f32) -> f32 {
    if e >= 0.0 {
        fp_qdq_signed(x, maxval, e as i32, m as i32)
    } else {
        int_qdq_sym(x, maxval, m as i32)
    }
}

/// Pinned drift-score vector for a fixed sketch/baseline pair (no
/// artifacts needed — the fixture is rng-free, so the reservoir holds the
/// exact input sequence). The expected values were computed with a bit-
/// exact float32 mirror of `recal::drift::drift_score`; any change to the
/// quantile resolution, index rounding, normalization or range term moves
/// them far beyond the tolerance, so scoring changes cannot slip through
/// silently. (Unit-level margin tests only bound scores; this pins them.)
#[test]
fn drift_score_golden_vector() {
    // baseline: 101 evenly spaced values on [-1, 1]; scale = 1.0
    let base_acts: Vec<f32> = (0..=100).map(|i| i as f32 * 0.02 - 1.0).collect();
    let base = LayerCalib::from_samples("golden", base_acts.clone(), false);

    // rng-free sketch: count stays <= cap, so samples() is the input
    let sketch_of = |vals: &[f32]| -> LayerSketch {
        let mut sk = LayerSketch::new(256, 1);
        for &v in vals {
            sk.push(v);
        }
        sk
    };

    // (name, live values, widen, expected score) — mirror-computed
    let cubic: Vec<f32> = base_acts.iter().map(|&x| x * x * x).collect();
    let affine: Vec<f32> = base_acts.iter().map(|&x| x * 1.3 + 0.2).collect();
    let mut outlier = base_acts.clone();
    outlier.push(3.0);
    let cases: [(&str, &[f32], Option<(f32, f32)>, f32); 5] = [
        ("identical", &base_acts, None, 0.0),          // exact replay
        ("cubic", &cubic, None, 0.266_666_68),         // quantile term only
        ("affine", &affine, None, 0.5),                // range term dominates
        ("outlier", &outlier, None, 2.0),              // tail growth
        ("widen", &base_acts, Some((-2.5, 2.5)), 1.5), // widen-only extrema
    ];
    for (layer, (name, vals, widen, expect)) in cases.iter().enumerate() {
        let mut sk = sketch_of(vals);
        if let Some((lo, hi)) = widen {
            sk.widen(*lo, *hi);
        }
        let d = drift_score(layer, &base, &sk, 9);
        assert_eq!(d.layer, layer);
        assert_eq!(d.samples, vals.len());
        assert!(
            (d.score - expect).abs() <= 1e-5 * expect.max(1.0),
            "{name}: score {} drifted from pinned {expect}",
            d.score
        );
    }
}

#[test]
fn quant_golden_agreement() {
    let Some(dir) = golden_dir() else {
        msfp::log_warn!("skipping: goldens not built (run `make artifacts`)");
        return;
    };
    let j = Json::parse(&std::fs::read_to_string(dir.join("quant_golden.json")).unwrap()).unwrap();
    let arrays = j.get("arrays").unwrap().obj().unwrap();
    let mut checked = 0usize;
    let mut max_err = 0f32;
    for case in j.get("cases").unwrap().arr().unwrap() {
        let arr = arrays[case.get("array").unwrap().str().unwrap()].f32_vec().unwrap();
        let sign = case.get("sign").unwrap().f32().unwrap();
        let maxval = case.get("maxval").unwrap().f32().unwrap();
        let e = case.get("e_bits").unwrap().f32().unwrap();
        let m = case.get("m_bits").unwrap().f32().unwrap();
        let zp = case.get("zp").unwrap().f32().unwrap();
        let mixup = case.get("mixup").unwrap().f32_vec().unwrap();
        let weight = case.get("weight").unwrap().f32_vec().unwrap();
        for (i, &x) in arr.iter().enumerate() {
            let r = mixup_rust(x, sign, maxval, e, m, zp);
            let err = (r - mixup[i]).abs();
            max_err = max_err.max(err);
            assert!(
                err <= 2e-6 * maxval.max(1.0),
                "mixup mismatch: x={x} sign={sign} maxval={maxval} E{e}M{m} zp={zp}: rust {r} vs py {}",
                mixup[i]
            );
            let rw = weight_rust(x, maxval, e, m);
            assert!(
                (rw - weight[i]).abs() <= 2e-6 * maxval.max(1.0),
                "weight mismatch: x={x} maxval={maxval} E{e}M{m}: rust {rw} vs py {}",
                weight[i]
            );
            checked += 2;
        }
    }
    assert!(checked > 8000, "golden file unexpectedly small: {checked}");
    eprintln!("quant golden: {checked} values checked, max err {max_err:.2e}");
}

#[test]
fn router_golden_agreement() {
    let Some(dir) = golden_dir() else {
        msfp::log_warn!("skipping: goldens not built");
        return;
    };
    let j =
        Json::parse(&std::fs::read_to_string(dir.join("router_golden.json")).unwrap()).unwrap();
    let temb_dim = j.get("temb_dim").unwrap().usize().unwrap();
    let n_layers = j.get("n_layers").unwrap().usize().unwrap();
    let h = j.get("hub").unwrap().usize().unwrap();
    let flat = j.get("router").unwrap().f32_vec().unwrap();
    let router = Router { flat, temb_dim, n_layers, h };
    let mut total = 0usize;
    let mut agree = 0usize;
    for case in j.get("cases").unwrap().arr().unwrap() {
        let t = case.get("t").unwrap().f32().unwrap();
        let mask: Vec<f32> =
            case.get("mask").unwrap().arr().unwrap().iter().map(|v| v.f32().unwrap()).collect();
        let want = case.get("sel").unwrap().usize_vec().unwrap();
        let got = router.select(t, &mask);
        for (a, b) in got.iter().zip(&want) {
            total += 1;
            if a == b {
                agree += 1;
            }
        }
        // masked slots must never be selected, regardless of ulp noise
        for (&s, _) in got.iter().zip(&want) {
            assert!(mask[s] == 1.0, "masked slot selected");
        }
    }
    let frac = agree as f32 / total as f32;
    eprintln!("router golden: {agree}/{total} selections agree ({frac:.3})");
    // sin/exp may differ by 1 ulp from XLA near logit ties; demand >= 95%
    assert!(frac >= 0.95, "router agreement too low: {frac}");
}
